//! Convergence study (paper §4.5 / Table 3): train GraphSAGE on
//! products-mini single-socket and distributed, reporting the epoch at
//! which test accuracy reaches within 1% of the single-socket target —
//! the paper's criterion for claiming HEC does not hurt accuracy.

use distgnn_mb::config::{TrainConfig, TrainMode};
use distgnn_mb::train::Driver;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn run(ranks: usize, mode: TrainMode, epochs: usize, lr: f32) -> anyhow::Result<(f64, Option<usize>, Vec<f64>)> {
    let mut cfg = TrainConfig::default();
    cfg.preset = "products-mini".into();
    cfg.ranks = ranks;
    cfg.epochs = epochs;
    cfg.lr = lr;
    cfg.mode = mode;
    cfg.eval_every = 1;
    if let Ok(v) = std::env::var("DISTGNN_MAX_MB") {
        cfg.max_minibatches = v.parse().ok();
    }
    let mut driver = Driver::new(cfg)?;
    let report = driver.train(None)?;
    let accs: Vec<f64> = report
        .epochs
        .iter()
        .filter_map(|e| e.test_acc)
        .collect();
    Ok((report.final_test_acc.unwrap_or(0.0), None, accs))
}

fn main() -> anyhow::Result<()> {
    let epochs = env_usize("DISTGNN_EPOCHS", 5);

    println!("=== convergence study: GraphSAGE on products-mini ===");
    // single-socket target (paper Table 3 establishes targets this way)
    let (target, _, accs1) = run(1, TrainMode::Aep, epochs, 3e-3)?;
    println!("single-socket accuracy curve: {:?}", accs1);
    println!("target accuracy (single socket, {epochs} epochs): {target:.4}");

    // distributed with HEC: must reach within 1% of target
    let (acc4, _, accs4) = run(4, TrainMode::Aep, epochs, 6e-3)?;
    println!("4-rank AEP accuracy curve:    {:?}", accs4);
    let converged = accs4
        .iter()
        .position(|&a| target - a < 0.01)
        .map(|i| i + 1);
    match converged {
        Some(e) => println!("4-rank AEP within 1% of target at epoch {e} (final {acc4:.4})"),
        None => println!("4-rank AEP did not reach target - 1% in {epochs} epochs (final {acc4:.4})"),
    }

    // ablation: no communication at all (halos dropped)
    let (acc_nc, _, _) = run(4, TrainMode::NoComm, epochs, 6e-3)?;
    println!("4-rank NoComm final accuracy: {acc_nc:.4} (HEC value = {:+.4})", acc4 - acc_nc);
    Ok(())
}
