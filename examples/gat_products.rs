//! GAT on products-mini across 4 virtual ranks — the paper's second model
//! (eq. 2 with the bias+ReLU-before-attention modification), exercising the
//! fused linear Pallas kernel, 4-head edge-softmax attention, HEC at every
//! layer and the AEP push path.
//!
//! Expected shape (paper §4.4): BWD dominates GAT epoch time.

use distgnn_mb::config::{ModelKind, TrainConfig};
use distgnn_mb::train::Driver;

fn main() -> anyhow::Result<()> {
    let mut cfg = TrainConfig::default();
    cfg.preset = "products-mini".into();
    cfg.model = ModelKind::Gat;
    cfg.lr = 1e-3; // paper Table 2
    cfg.ranks = 4;
    cfg.epochs = std::env::var("DISTGNN_EPOCHS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4);
    cfg.eval_every = 2;
    if let Ok(v) = std::env::var("DISTGNN_MAX_MB") {
        cfg.max_minibatches = v.parse().ok();
    }

    println!("=== GAT (4 heads) on products-mini, {} ranks ===", cfg.ranks);
    let mut driver = Driver::new(cfg)?;
    let report = driver.train(None)?;
    for e in &report.epochs {
        println!("{}", e.render());
    }
    let c = report.mean_comps(1);
    println!(
        "\ncomponent shares: MBC {:.0}% FWD {:.0}% BWD {:.0}% ARed {:.0}%",
        100.0 * c.mbc / c.total(),
        100.0 * c.fwd / c.total(),
        100.0 * c.bwd / c.total(),
        100.0 * c.ared / c.total()
    );
    anyhow::ensure!(
        c.bwd >= c.mbc && c.bwd >= c.ared,
        "expected BWD to dominate GAT epoch time (paper §4.4)"
    );
    println!("GAT example OK (BWD dominates, as in the paper)");
    Ok(())
}
