//! End-to-end driver (EXPERIMENTS.md §E2E): the full system on the
//! papers100m-mini workload — GraphSAGE (~120k-vertex graph, 128-dim
//! features, 172 classes), 8 virtual ranks, trained for several epochs with
//! the complete AEP + HEC machinery, logging the loss curve, epoch-time
//! breakdown, HEC hit rates and final test accuracy.
//!
//! Mirrors the paper's headline workload (GraphSAGE on OGBN-Papers100M,
//! §4.4/§4.5) at mini scale. Configure with env vars:
//!   DISTGNN_EPOCHS (default 8), DISTGNN_RANKS (default 8),
//!   DISTGNN_MAX_MB (default all), DISTGNN_TARGET_ACC (default none).

use distgnn_mb::config::TrainConfig;
use distgnn_mb::train::Driver;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let mut cfg = TrainConfig::default();
    cfg.preset = "papers100m-mini".into();
    cfg.ranks = env_usize("DISTGNN_RANKS", 8);
    cfg.epochs = env_usize("DISTGNN_EPOCHS", 8);
    cfg.lr = 6e-3; // paper Table 2: multi-socket lr for GraphSAGE
    cfg.eval_every = 1;
    if let Ok(v) = std::env::var("DISTGNN_MAX_MB") {
        cfg.max_minibatches = v.parse().ok();
    }
    let target_acc = std::env::var("DISTGNN_TARGET_ACC")
        .ok()
        .and_then(|v| v.parse::<f64>().ok());

    println!("=== DistGNN-MB end-to-end: GraphSAGE on papers100m-mini ===");
    println!("config: {}", cfg.to_json().to_json());
    let mut driver = Driver::new(cfg)?;
    println!(
        "dataset: {} vertices / {} directed edges / {} train / {} test",
        driver.ds.num_vertices(),
        driver.ds.graph.num_directed_edges(),
        driver.ds.train_vertices.len(),
        driver.ds.test_vertices.len()
    );
    let report = driver.train(target_acc)?.clone();

    println!("\n--- loss curve ---");
    println!("epoch  time(s)     MBC     FWD     BWD    ARed    loss   train  test    imb  hec%(L0/L1/L2)  comm");
    for e in &report.epochs {
        println!(
            "{:>5}  {:>7.3}  {:>6.3}  {:>6.3}  {:>6.3}  {:>6.3}  {:>6.4}  {:>5.3}  {:>5}  {:>5.2}  {:>14}  {:>6.1}MB",
            e.epoch,
            e.epoch_time,
            e.comps.mbc,
            e.comps.fwd,
            e.comps.bwd,
            e.comps.ared,
            e.train_loss,
            e.train_acc,
            e.test_acc.map(|a| format!("{a:.3}")).unwrap_or("-".into()),
            e.load_imbalance,
            e.hec_hit_rates
                .iter()
                .map(|h| format!("{:.0}", h * 100.0))
                .collect::<Vec<_>>()
                .join("/"),
            e.comm_bytes as f64 / 1e6,
        );
    }
    println!("\nmean epoch time (skip warmup): {:.3}s", report.mean_epoch_time(1));
    if let Some(e) = report.converged_epoch {
        println!("converged (within 1% of target) at epoch {e}");
    }
    if let Some(a) = report.final_test_acc {
        println!("final test accuracy: {a:.4}");
    }
    std::fs::create_dir_all("reports").ok();
    std::fs::write(
        "reports/papers100m_mini_e2e.json",
        report.to_json().to_json_pretty(),
    )?;
    println!("report written to reports/papers100m_mini_e2e.json");
    Ok(())
}
