//! Quickstart: train GraphSAGE with DistGNN-MB on the `tiny` synthetic
//! dataset across 2 virtual ranks, evaluating test accuracy each epoch.
//!
//! Run: `cargo run --release --offline --example quickstart`
//! (requires `make artifacts` once beforehand).

use distgnn_mb::config::TrainConfig;
use distgnn_mb::train::Driver;

fn main() -> anyhow::Result<()> {
    let mut cfg = TrainConfig::default();
    cfg.preset = "tiny".into();
    cfg.ranks = 2;
    cfg.epochs = 5;
    cfg.eval_every = 1;

    println!("DistGNN-MB quickstart — GraphSAGE on '{}', {} ranks", cfg.preset, cfg.ranks);
    let mut driver = Driver::new(cfg)?;
    println!(
        "dataset: {} vertices, {} directed edges; fwd fraction {:.2}",
        driver.ds.num_vertices(),
        driver.ds.graph.num_directed_edges(),
        driver.fwd_fraction
    );
    let report = driver.train(None)?;
    println!("\nepoch  time(s)   loss    train-acc  test-acc  hec-hit%");
    for e in &report.epochs {
        println!(
            "{:>5}  {:>7.3}  {:>6.4}  {:>9.3}  {:>8}  {}",
            e.epoch,
            e.epoch_time,
            e.train_loss,
            e.train_acc,
            e.test_acc.map(|a| format!("{a:.3}")).unwrap_or("-".into()),
            e.hec_hit_rates
                .iter()
                .map(|h| format!("{:.0}", h * 100.0))
                .collect::<Vec<_>>()
                .join("/")
        );
    }
    let final_acc = report.final_test_acc.unwrap_or(0.0);
    println!("\nfinal test accuracy: {final_acc:.3}");
    anyhow::ensure!(final_acc > 0.5, "quickstart accuracy unexpectedly low");
    println!("quickstart OK");
    Ok(())
}
