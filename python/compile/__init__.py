"""Build-time compile path: Pallas kernels (L1), JAX models (L2), AOT
lowering to HLO-text artifacts. Never imported at runtime."""
