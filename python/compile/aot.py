"""AOT compiler: lowers every program variant to HLO *text* and writes the
artifact manifest consumed by the Rust runtime.

HLO text (not `.serialize()`d protos) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version the `xla` crate binds) rejects; the text parser reassigns ids
and round-trips cleanly.

Run once via `make artifacts`; Python never executes on the training path.
"""

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.kernels.fused_update import sage_update
from compile.shapes import PRESETS, ModelShapes

MANIFEST_VERSION = 1


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_name(dt) -> str:
    return {"float32": "f32", "int32": "i32", "uint32": "u32"}[jnp.dtype(dt).name]


def _tensor_spec(name, shape, dtype):
    return {"name": name, "dtype": _dtype_name(dtype), "shape": list(shape)}


def lower_program(name, fn, in_specs, out_names, out_dir, meta):
    """Lower `fn` at the given input specs; return the manifest entry."""
    t0 = time.time()
    args = [jax.ShapeDtypeStruct(s, d) for (_, s, d) in in_specs]
    lowered = jax.jit(fn, keep_unused=True).lower(*args)
    text = to_hlo_text(lowered)
    hlo_file = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, hlo_file), "w") as f:
        f.write(text)
    # output specs from the jax trace
    out_shapes = jax.eval_shape(fn, *args)
    assert len(out_shapes) == len(out_names), (name, len(out_shapes), out_names)
    outputs = [
        _tensor_spec(n, o.shape, o.dtype) for n, o in zip(out_names, out_shapes)
    ]
    print(f"  {name}: {len(text) / 1e6:.2f} MB HLO, {time.time() - t0:.1f}s")
    return {
        "name": name,
        "hlo_file": hlo_file,
        "inputs": [_tensor_spec(n, s, d) for (n, s, d) in in_specs],
        "outputs": outputs,
        "meta": meta,
    }


def build_model_programs(preset: str, shapes: ModelShapes, out_dir):
    entries = []
    for model in ("sage", "gat"):
        pspecs = M.sage_param_specs(shapes) if model == "sage" else M.gat_param_specs(shapes)
        bspecs = M.batch_specs(shapes, self_loops=(model == "gat"))
        in_specs = [(n, s, jnp.float32) for (n, s) in pspecs] + bspecs
        n_embeds = shapes.n_layers - 1
        caps = dataclasses.replace(shapes, self_loops=(model == "gat")).node_caps()
        meta = {
            "model": model,
            "preset": preset,
            "batch": shapes.batch,
            "fanouts": list(shapes.fanouts),
            "hidden": shapes.hidden,
            "num_heads": shapes.num_heads,
            "num_classes": shapes.num_classes,
            "feat_dim": shapes.feat_dim,
            "dropout": shapes.dropout,
            "node_caps": caps,
            "self_loops": model == "gat",
            "n_params": len(pspecs),
        }
        for train in (True, False):
            kind = "train" if train else "fwd"
            fn, _, _ = M.make_step_fn(model, shapes, train)
            outs = ["loss", "correct"] + [f"h{l}" for l in range(1, shapes.n_layers)]
            if train:
                outs += [f"grad_{n}" for (n, _) in pspecs]
            entries.append(
                lower_program(
                    f"{model}_{kind}_{preset}", fn, in_specs, outs, out_dir,
                    {**meta, "kind": kind},
                )
            )
    return entries


def build_update_micro_programs(preset: str, shapes: ModelShapes, out_dir):
    """Fig. 2 micro programs: the UPDATE primitive as one fused Pallas
    program vs an op-by-op chain of separate executables (emulating
    unfused DGL/PyTorch op dispatch with intermediate materialization)."""
    n = shapes.node_caps()[0]
    f, h = shapes.feat_dim, shapes.hidden
    f32 = jnp.float32
    xn = ("xn", (n, f), f32)
    xs = ("xs", (n, f), f32)
    wn = ("wn", (f, h), f32)
    ws = ("ws", (f, h), f32)
    b = ("b", (h,), f32)
    mask = ("mask", (n, h), f32)
    y = ("y", (n, h), f32)
    y2 = ("y2", (n, h), f32)
    meta = {"preset": preset, "rows": n, "d_in": f, "d_out": h}
    entries = [
        lower_program(
            f"update_fused_{preset}",
            lambda xn, xs, wn, ws, b, mask: (sage_update(xn, xs, wn, ws, b, mask, True),),
            [xn, xs, wn, ws, b, mask], ["y"], out_dir, {**meta, "kind": "fused"},
        ),
        lower_program(
            f"update_unfused_full_{preset}",
            lambda xn, xs, wn, ws, b, mask: (
                jnp.maximum(xn @ wn + xs @ ws + b[None, :], 0.0) * mask,
            ),
            [xn, xs, wn, ws, b, mask], ["y"], out_dir, {**meta, "kind": "unfused_full"},
        ),
        lower_program(
            f"update_mm_{preset}",
            lambda x, w: (x @ w,),
            [xn, wn], ["y"], out_dir, {**meta, "kind": "op_mm"},
        ),
        lower_program(
            f"update_add_bias_{preset}",
            lambda a, c, b: (a + c + b[None, :],),
            [y, y2, b], ["out"], out_dir, {**meta, "kind": "op_add_bias"},
        ),
        lower_program(
            f"update_relu_{preset}",
            lambda a: (jnp.maximum(a, 0.0),),
            [y], ["out"], out_dir, {**meta, "kind": "op_relu"},
        ),
        lower_program(
            f"update_dropout_{preset}",
            lambda a, mask: (a * mask,),
            [y, mask], ["out"], out_dir, {**meta, "kind": "op_dropout"},
        ),
    ]
    return entries


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--presets", default="tiny,products-mini,papers100m-mini")
    ap.add_argument("--micro-preset", default="products-mini",
                    help="preset whose dims the Fig.2 micro programs use")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    programs = []
    presets = [p for p in args.presets.split(",") if p]
    for preset in presets:
        shapes = PRESETS[preset]
        print(f"[aot] lowering model programs for '{preset}'")
        programs += build_model_programs(preset, shapes, args.out_dir)
    if args.micro_preset in presets:
        print(f"[aot] lowering UPDATE micro programs ({args.micro_preset})")
        programs += build_update_micro_programs(
            args.micro_preset, PRESETS[args.micro_preset], args.out_dir
        )

    manifest = {
        "version": MANIFEST_VERSION,
        "build_config": {
            "jax_version": jax.__version__,
            "presets": presets,
            "caps": {
                p: {
                    "node_caps": PRESETS[p].node_caps(),
                    "edge_caps": PRESETS[p].edge_caps(),
                }
                for p in presets
            },
        },
        "programs": programs,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {len(programs)} programs to {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
