"""Layer-1 Pallas kernels: the paper's performance-optimized UPDATE
primitive (§3.3), re-thought for the TPU execution model.

The paper fuses matmul + bias + ReLU + Dropout with LIBXSMM TPPs and blocks
tensors 2-D→4-D so intermediates stay in the Xeon L2 cache. The TPU-shaped
equivalent implemented here:

* grid over row blocks (`BN` = 64 rows); each grid step stages a
  `BN x K` input tile and the full `K x N` weight panel in VMEM
  (VMEM plays the L2's role, the MXU the FMA pipeline's);
* the epilogue (second matmul accumulate, bias, ReLU, dropout mask) runs on
  the output tile while it is still VMEM-resident — one HBM round-trip per
  tile instead of four;
* backward-by-weight uses the paper's pattern (parallelize the large N
  dimension, reduce partial W-gradients) expressed as an N-blocked Pallas
  matmul with accumulation across grid steps.

All kernels are lowered with `interpret=True` (the CPU PJRT plugin cannot
execute Mosaic custom-calls); real-TPU efficiency is estimated in
EXPERIMENTS.md §Perf from the block shapes.

`jax.grad` cannot differentiate through `pallas_call`, so each public entry
point carries a `custom_vjp` whose backward pass is itself built from the
blocked Pallas matmul.
"""

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BN = 64  # row-block size of the *blocked* path (shapes.ROW_ALIGN matches)


def _row_block(m: int) -> int:
    """Row-block size to use for an m-row operand.

    0 = single-block launch (no grid). Default on this target: the XLA CPU
    backend executing interpret-mode Pallas pays ~1 ms per grid step in
    loop/dynamic-slice overhead (measured: BN=64 is 60x slower than a
    single block at products-mini dims — EXPERIMENTS.md §Perf), and its
    fused dot already does cache blocking internally, so the grid only
    helps on real TPUs where VMEM capacity forces tiling. Set
    DISTGNN_PALLAS_BN at artifact-build time to emit the blocked variant
    (the TPU-shaped schedule; also exercised by the kernel test suite).
    """
    bn = int(os.environ.get("DISTGNN_PALLAS_BN", "0"))
    if bn > 0 and m % bn == 0:
        return bn
    return 0


# --------------------------------------------------------------------------
# blocked matmul (building block for the backward passes)
# --------------------------------------------------------------------------
def _matmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] @ b_ref[...]


def matmul_pallas(a, b):
    """C[M,N] = A[M,K] @ B[K,N], grid over M row-blocks (full K, N panels).

    M must be a multiple of BN or small enough for a single block.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bn = _row_block(m)
    if bn == 0:
        # single-block launch (see _row_block)
        return pl.pallas_call(
            _matmul_kernel,
            out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
            interpret=True,
        )(a, b)
    grid = (m // bn,)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


# --------------------------------------------------------------------------
# BWD_W: dW[K,N] = X[M,K]^T @ G[M,N], parallelized over M with reduction
# (the paper's backward-by-weight pattern).
# --------------------------------------------------------------------------
def _bwd_w_kernel(x_ref, g_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += x_ref[...].T @ g_ref[...]


def bwd_w_pallas(x, g):
    """dW = x^T @ g with the M dimension blocked and accumulated."""
    m, k = x.shape
    m2, n = g.shape
    assert m == m2
    let_bn = _row_block(m)
    if let_bn == 0:
        return matmul_pallas(x.T, g)
    grid = (m // let_bn,)
    return pl.pallas_call(
        _bwd_w_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((let_bn, k), lambda i: (i, 0)),
            pl.BlockSpec((let_bn, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((k, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, n), jnp.float32),
        interpret=True,
    )(x, g)


# --------------------------------------------------------------------------
# fused GraphSAGE UPDATE
# --------------------------------------------------------------------------
def _sage_fwd_kernel(xn_ref, xs_ref, wn_ref, ws_ref, b_ref, m_ref, o_ref, *, activate):
    acc = xn_ref[...] @ wn_ref[...] + xs_ref[...] @ ws_ref[...] + b_ref[...]
    if activate:
        acc = jnp.maximum(acc, 0.0) * m_ref[...]
    o_ref[...] = acc


def _sage_update_fwd_pallas(xn, xs, wn, ws, b, drop_mask, activate):
    m, k = xn.shape
    n = wn.shape[1]
    kern = functools.partial(_sage_fwd_kernel, activate=activate)
    bn = _row_block(m)
    if bn == 0:
        return pl.pallas_call(
            kern,
            out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
            interpret=True,
        )(xn, xs, wn, ws, b.reshape(1, n), drop_mask)
    grid = (m // bn,)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, k), lambda i: (i, 0)),
            pl.BlockSpec((bn, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((bn, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(xn, xs, wn, ws, b.reshape(1, n), drop_mask)


@functools.partial(jax.custom_vjp, nondiff_argnums=(6,))
def sage_update(xn, xs, wn, ws, b, drop_mask, activate=True):
    """Dropout(ReLU(xn·wn + xs·ws + b)) — GraphSAGE eq. (1) UPDATE.

    activate=False yields the final-layer linear variant.
    drop_mask is an inverted-dropout mask (0 or 1/keep_p); pass ones for
    inference.
    """
    return _sage_update_fwd_pallas(xn, xs, wn, ws, b, drop_mask, activate)


def _sage_update_fwd(xn, xs, wn, ws, b, drop_mask, activate):
    y = _sage_update_fwd_pallas(xn, xs, wn, ws, b, drop_mask, activate)
    return y, (xn, xs, wn, ws, drop_mask, y)


def _sage_update_bwd(activate, res, g):
    xn, xs, wn, ws, drop_mask, y = res
    if activate:
        # d/dpre of Dropout(ReLU(pre)): mask * 1[pre > 0]; since
        # y = relu(pre)*mask and mask >= 0, (y > 0) == (pre > 0 && mask > 0).
        gp = g * drop_mask * (y > 0.0).astype(g.dtype)
    else:
        gp = g
    dxn = matmul_pallas(gp, wn.T)
    dxs = matmul_pallas(gp, ws.T)
    dwn = bwd_w_pallas(xn, gp)
    dws = bwd_w_pallas(xs, gp)
    db = jnp.sum(gp, axis=0)
    return dxn, dxs, dwn, dws, db, None


sage_update.defvjp(_sage_update_fwd, _sage_update_bwd)


# --------------------------------------------------------------------------
# fused linear + activation (GAT projection z = ReLU(W·f + b))
# --------------------------------------------------------------------------
def _linear_kernel(x_ref, w_ref, b_ref, o_ref, *, activate):
    acc = x_ref[...] @ w_ref[...] + b_ref[...]
    if activate:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc


def _linear_act_fwd_pallas(x, w, b, activate):
    m, k = x.shape
    n = w.shape[1]
    kern = functools.partial(_linear_kernel, activate=activate)
    bn = _row_block(m)
    if bn == 0:
        return pl.pallas_call(
            kern,
            out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
            interpret=True,
        )(x, w, b.reshape(1, n))
    grid = (m // bn,)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, b.reshape(1, n))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def linear_act(x, w, b, activate=True):
    """y = ReLU(x·w + b) (activate=False: linear)."""
    return _linear_act_fwd_pallas(x, w, b, activate)


def _linear_act_fwd(x, w, b, activate):
    y = _linear_act_fwd_pallas(x, w, b, activate)
    return y, (x, w, y)


def _linear_act_bwd(activate, res, g):
    x, w, y = res
    gp = g * (y > 0.0).astype(g.dtype) if activate else g
    dx = matmul_pallas(gp, w.T)
    dw = bwd_w_pallas(x, gp)
    db = jnp.sum(gp, axis=0)
    return dx, dw, db


linear_act.defvjp(_linear_act_fwd, _linear_act_bwd)
