"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: pytest sweeps shapes (hypothesis)
and asserts the Pallas kernels (values AND custom-VJP gradients) match these
references to float32 tolerance.
"""

import jax
import jax.numpy as jnp


def sage_update_ref(xn, xs, wn, ws, b, drop_mask, activate: bool):
    """UPDATE of GraphSAGE eq. (1): Dropout(ReLU(Wn·h_N + Ws·h_v + b)).

    `drop_mask` is a precomputed inverted-dropout mask (0 or 1/keep_p);
    `activate=False` gives the final-layer linear variant (no ReLU, no
    dropout).
    """
    y = xn @ wn + xs @ ws + b[None, :]
    if activate:
        y = jnp.maximum(y, 0.0) * drop_mask
    return y


def linear_act_ref(x, w, b, activate: bool):
    """GAT eq. (2) projection: ReLU(W·f + b) (the paper's modification puts
    bias + non-linearity before the attention coefficients)."""
    y = x @ w + b[None, :]
    if activate:
        y = jnp.maximum(y, 0.0)
    return y


def matmul_ref(a, b):
    return a @ b


def gat_attention_ref(z_src, e_src, e_dst, esrc, edst, emask, n_dst, negative_slope=0.2):
    """Edge-softmax attention aggregation (GAT), reference implementation.

    z_src  [NS, heads, Dh] projected source embeddings
    e_src  [NS, heads] source attention logits (a_u ∘ z_u)
    e_dst  [ND, heads] destination attention logits
    esrc/edst [E] edge endpoints (src into A_l, dst into A_{l+1})
    emask  [E] 1.0 valid / 0.0 padding
    returns [ND, heads, Dh]
    """
    s = e_src[esrc] + e_dst[edst]  # [E, heads]
    s = jnp.where(s >= 0, s, negative_slope * s)  # LeakyReLU
    s = jnp.where(emask[:, None] > 0, s, -1e30)
    smax = jax.ops.segment_max(s, edst, num_segments=n_dst)
    smax = jnp.maximum(smax, -1e29)  # dst rows with no valid edge
    ex = jnp.exp(s - smax[edst]) * emask[:, None]
    denom = jax.ops.segment_sum(ex, edst, num_segments=n_dst)
    denom = jnp.maximum(denom, 1e-9)
    alpha = ex / denom[edst]  # [E, heads]
    msgs = alpha[:, :, None] * z_src[esrc]  # [E, heads, Dh]
    return jax.ops.segment_sum(msgs, edst, num_segments=n_dst)


def mean_aggregate_ref(h_src, esrc, edst, ew, n_dst):
    """Weighted (mean) neighbor aggregation: AGG of GraphSAGE eq. (1).
    `ew` carries 1/deg weights with zeros for padded/dropped edges."""
    msgs = h_src[esrc] * ew[:, None]
    return jax.ops.segment_sum(msgs, edst, num_segments=n_dst)
