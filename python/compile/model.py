"""Layer-2 JAX models: GraphSAGE (paper eq. 1) and GAT (paper eq. 2) over
padded message-flow blocks, calling the Layer-1 Pallas kernels.

Minibatch layout (see shapes.py): node sets A_0 ⊇ A_1 ⊇ ... ⊇ A_L with
A_L = seed batch and A_{l+1} a prefix of A_l. Block l aggregates source
embeddings h_l[A_l] into destinations A_{l+1} through padded edge arrays
(esrc, edst, ew); `ew` carries mean-aggregation weights (1/deg) for
GraphSAGE and a 0/1 validity mask for GAT.

Historical embeddings from the Rust-side HEC enter each inner layer
through a scatter-overwrite: `h = h.at[hec_idx].set(hec_val, mode="drop")`.
Halo vertices with a cache hit get their stale embedding; misses keep an
out-of-bounds index (dropped scatter) and the Rust packer zeroes the
corresponding edge weights — exactly the paper's Algorithm 2 line 11
fallback (eliminate the halo vertex from minibatch execution). Gradients do
not flow into hec_val rows beyond the overwrite (historical embeddings are
constants), matching GNNAutoScale-style HE training.

These functions are traced once by aot.py and never run in production —
the Rust coordinator executes their lowered HLO through PJRT.
"""

import jax
import jax.numpy as jnp

from compile.kernels.fused_update import linear_act, sage_update
from compile.kernels.ref import gat_attention_ref, mean_aggregate_ref
from compile.shapes import ModelShapes


def _dropout_mask(key, shape, rate, enabled):
    if not enabled or rate <= 0.0:
        return jnp.ones(shape, jnp.float32)
    keep = 1.0 - rate
    return jax.random.bernoulli(key, keep, shape).astype(jnp.float32) / keep


def _loss_and_metrics(logits, labels, lmask):
    logp = jax.nn.log_softmax(logits, axis=-1)
    ce = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    denom = jnp.maximum(lmask.sum(), 1.0)
    loss = (ce * lmask).sum() / denom
    pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    correct = ((pred == labels).astype(jnp.float32) * lmask).sum()
    return loss, correct


# --------------------------------------------------------------------------
# GraphSAGE
# --------------------------------------------------------------------------
def sage_forward(params, batch, shapes: ModelShapes, train: bool):
    """Returns (loss, (correct, per-layer embeddings h_1..h_{L-1}))."""
    caps = shapes.node_caps()
    L = shapes.n_layers
    key = jax.random.PRNGKey(batch["seed"].astype(jnp.uint32))
    h = batch["feats"]
    embeds = []
    for l in range(L):
        nd = caps[l + 1]
        wn, ws, b = params[3 * l], params[3 * l + 1], params[3 * l + 2]
        agg = mean_aggregate_ref(h, batch[f"esrc{l}"], batch[f"edst{l}"], batch[f"ew{l}"], nd)
        hself = h[:nd]
        last = l == L - 1
        if last:
            ones = jnp.ones((nd, wn.shape[1]), jnp.float32)
            h = sage_update(agg, hself, wn, ws, b, ones, False)
        else:
            mask = _dropout_mask(jax.random.fold_in(key, l), (nd, wn.shape[1]),
                                 shapes.dropout, train)
            h = sage_update(agg, hself, wn, ws, b, mask, True)
            # historical-embedding overwrite for halo rows of A_{l+1}
            h = h.at[batch[f"hec_idx{l + 1}"]].set(batch[f"hec_val{l + 1}"], mode="drop")
            embeds.append(h)
    loss, correct = _loss_and_metrics(h, batch["labels"], batch["lmask"])
    return loss, (correct, embeds)


# --------------------------------------------------------------------------
# GAT (paper's modified formulation: bias + ReLU applied to the projection
# *before* attention coefficients)
# --------------------------------------------------------------------------
def gat_forward(params, batch, shapes: ModelShapes, train: bool):
    caps = shapes.node_caps()
    L = shapes.n_layers
    heads = shapes.num_heads
    key = jax.random.PRNGKey(batch["seed"].astype(jnp.uint32))
    h = batch["feats"]
    embeds = []
    for l in range(L):
        nd = caps[l + 1]
        w, b, au, av = params[4 * l], params[4 * l + 1], params[4 * l + 2], params[4 * l + 3]
        last = l == L - 1
        dh = w.shape[1] // heads
        z = linear_act(h, w, b, True)  # ReLU(W·f + b), fused Pallas kernel
        zr = z.reshape(-1, heads, dh)
        e_src = (zr * au[None, :, :]).sum(-1)  # a_u ∘ z_u
        e_dst = (zr[:nd] * av[None, :, :]).sum(-1)
        hn = gat_attention_ref(zr, e_src, e_dst, batch[f"esrc{l}"], batch[f"edst{l}"],
                               batch[f"ew{l}"], nd)
        if last:
            h = hn.mean(axis=1)  # average heads into class logits
        else:
            h = hn.reshape(nd, heads * dh)
            mask = _dropout_mask(jax.random.fold_in(key, l), h.shape, shapes.dropout, train)
            h = h * mask
            h = h.at[batch[f"hec_idx{l + 1}"]].set(batch[f"hec_val{l + 1}"], mode="drop")
            embeds.append(h)
    loss, correct = _loss_and_metrics(h, batch["labels"], batch["lmask"])
    return loss, (correct, embeds)


# --------------------------------------------------------------------------
# program builders (traced by aot.py)
# --------------------------------------------------------------------------
def sage_param_specs(shapes: ModelShapes):
    specs = []
    for (d_in, d_out) in shapes.layer_dims():
        specs += [("wn", (d_in, d_out)), ("ws", (d_in, d_out)), ("b", (d_out,))]
    return [(f"{n}{i // 3}", s) for i, (n, s) in enumerate(specs)]


def gat_param_specs(shapes: ModelShapes):
    heads = shapes.num_heads
    specs = []
    d_in = shapes.feat_dim
    for l in range(shapes.n_layers):
        last = l == shapes.n_layers - 1
        dh = shapes.num_classes if last else shapes.hidden // heads
        specs += [
            (f"w{l}", (d_in, heads * dh)),
            (f"b{l}", (heads * dh,)),
            (f"au{l}", (heads, dh)),
            (f"av{l}", (heads, dh)),
        ]
        d_in = heads * dh if not last else d_in
    return specs


def batch_specs(shapes: ModelShapes, self_loops: bool):
    """Ordered (name, shape, dtype) for the minibatch inputs."""
    import dataclasses
    sh = dataclasses.replace(shapes, self_loops=self_loops)
    caps = sh.node_caps()
    ecaps = sh.edge_caps()
    hec_dims = sh.hec_dims()
    specs = [("feats", (caps[0], sh.feat_dim), jnp.float32)]
    for l in range(sh.n_layers):
        specs += [
            (f"esrc{l}", (ecaps[l],), jnp.int32),
            (f"edst{l}", (ecaps[l],), jnp.int32),
            (f"ew{l}", (ecaps[l],), jnp.float32),
        ]
    for l in range(1, sh.n_layers):
        specs += [
            (f"hec_idx{l}", (caps[l],), jnp.int32),
            (f"hec_val{l}", (caps[l], hec_dims[l]), jnp.float32),
        ]
    specs += [
        ("labels", (sh.batch,), jnp.int32),
        ("lmask", (sh.batch,), jnp.float32),
        ("seed", (), jnp.int32),
    ]
    return specs


def make_step_fn(model: str, shapes: ModelShapes, train: bool):
    """Build the flat-signature function to lower.

    Signature: f(*params, *batch_tensors) -> (loss, correct, h1.., grads..)
    train=False omits gradients (pure forward/eval program).
    """
    fwd = sage_forward if model == "sage" else gat_forward
    pspecs = sage_param_specs(shapes) if model == "sage" else gat_param_specs(shapes)
    bspecs = batch_specs(shapes, self_loops=(model == "gat"))
    n_params = len(pspecs)

    def fn(*args):
        params = args[:n_params]
        batch = {name: args[n_params + i] for i, (name, _, _) in enumerate(bspecs)}
        if train:
            (loss, (correct, embeds)), grads = jax.value_and_grad(
                fwd, has_aux=True)(params, batch, shapes, True)
            return (loss, correct, *embeds, *grads)
        loss, (correct, embeds) = fwd(params, batch, shapes, False)
        return (loss, correct, *embeds)

    return fn, pspecs, bspecs
