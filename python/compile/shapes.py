"""Shape-cap derivation for AOT-compiled minibatch programs.

The Rust runtime executes fixed-shape XLA programs, so every sampled
minibatch is padded to the caps computed here. The caps are recorded in the
artifact manifest; the Rust packer reads them from the manifest (single
source of truth — there is deliberately no Rust re-implementation of this
formula).

Node sets follow the message-flow-graph convention: A_0 ⊇ A_1 ⊇ ... ⊇ A_L
with A_L = the seed batch, and A_{l+1} stored as a prefix of A_l. Block l
(l = 0 is the input-most hop) aggregates embeddings of A_l into A_{l+1}.
"""

import dataclasses
import math

ROW_ALIGN = 64  # row caps are multiples of the Pallas row-block size


def round_up(x: int, m: int = ROW_ALIGN) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelShapes:
    """Shape configuration of one (dataset, model) artifact family."""

    preset: str
    batch: int
    fanouts: tuple  # fan-out per block, input-most first (len = n_layers)
    feat_dim: int
    hidden: int
    num_classes: int
    num_heads: int  # GAT only
    dropout: float
    # Fraction of the worst-case frontier growth actually provisioned.
    # Sampled frontiers dedup heavily on power-law graphs, so caps sized at
    # the worst case would waste memory and compute; overflow is truncated
    # (and counted) by the Rust packer.
    cap_factor: float
    self_loops: bool  # GAT aggregates its own vertex via an explicit edge

    @property
    def n_layers(self) -> int:
        return len(self.fanouts)

    def node_caps(self) -> list:
        """[NS_0, ..., NS_L]; NS_L = batch (seed set, unpadded rows used)."""
        caps = [self.batch]
        for fo in reversed(self.fanouts):  # from seeds outward
            worst = caps[0] * (1 + fo)
            provisioned = max(caps[0] + ROW_ALIGN, int(math.ceil(worst * self.cap_factor)))
            caps.insert(0, round_up(provisioned))
        return caps

    def edge_caps(self) -> list:
        """[E_0, ..., E_{L-1}]; block l has dst set A_{l+1}."""
        caps = self.node_caps()
        out = []
        for l, fo in enumerate(self.fanouts):
            dst = caps[l + 1]
            e = dst * fo + (dst if self.self_loops else 0)
            out.append(e)
        return out

    def layer_dims(self) -> list:
        """(d_in, d_out) per layer for GraphSAGE."""
        dims = []
        d_in = self.feat_dim
        for l in range(self.n_layers):
            d_out = self.num_classes if l == self.n_layers - 1 else self.hidden
            dims.append((d_in, d_out))
            d_in = d_out
        return dims

    def hec_dims(self) -> list:
        """Embedding width cached at each HEC level (level 0 = raw feats)."""
        return [self.feat_dim] + [self.hidden] * (self.n_layers - 1)


PRESETS = {
    "tiny": ModelShapes(
        preset="tiny",
        batch=32,
        fanouts=(4, 6, 8),
        feat_dim=32,
        hidden=64,
        num_classes=8,
        num_heads=4,
        dropout=0.2,
        cap_factor=0.7,
        self_loops=False,
    ),
    "products-mini": ModelShapes(
        preset="products-mini",
        batch=64,
        fanouts=(4, 8, 12),
        feat_dim=100,
        hidden=64,
        num_classes=47,
        num_heads=4,
        dropout=0.2,
        cap_factor=0.5,
        self_loops=False,
    ),
    "papers100m-mini": ModelShapes(
        preset="papers100m-mini",
        batch=64,
        fanouts=(4, 8, 12),
        feat_dim=128,
        hidden=64,
        num_classes=172,
        num_heads=4,
        dropout=0.2,
        cap_factor=0.5,
        self_loops=False,
    ),
}
