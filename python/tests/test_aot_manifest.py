"""Artifact manifest contract tests.

Validates the manifest that `make artifacts` produced against the shape
derivations in shapes.py — this is the same contract the Rust packer
enforces at run time, checked here at build time from the Python side.
Skipped when artifacts/ has not been built yet.
"""

import json
import os

import pytest

from compile.shapes import PRESETS

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first"
)


@pytest.fixture(scope="module")
def manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def programs_by_name(manifest):
    return {p["name"]: p for p in manifest["programs"]}


def test_manifest_version_and_presets(manifest):
    assert manifest["version"] == 1
    assert set(manifest["build_config"]["presets"]) >= {"tiny", "products-mini"}


@pytest.mark.parametrize("preset", ["tiny", "products-mini", "papers100m-mini"])
@pytest.mark.parametrize("model", ["sage", "gat"])
def test_model_programs_present_with_consistent_shapes(manifest, preset, model):
    progs = programs_by_name(manifest)
    name = f"{model}_train_{preset}"
    assert name in progs, f"missing {name}"
    prog = progs[name]
    sh = PRESETS[preset]
    import dataclasses

    shx = dataclasses.replace(sh, self_loops=(model == "gat"))
    caps = shx.node_caps()
    ecaps = shx.edge_caps()
    inputs = {t["name"]: t for t in prog["inputs"]}
    # feats shape
    assert inputs["feats"]["shape"] == [caps[0], sh.feat_dim]
    # edge arrays match derived caps
    for l in range(sh.n_layers):
        assert inputs[f"esrc{l}"]["shape"] == [ecaps[l]]
        assert inputs[f"esrc{l}"]["dtype"] == "i32"
        assert inputs[f"ew{l}"]["dtype"] == "f32"
    # hec inputs for inner layers
    for l in range(1, sh.n_layers):
        assert inputs[f"hec_idx{l}"]["shape"] == [caps[l]]
        assert inputs[f"hec_val{l}"]["shape"] == [caps[l], sh.hidden]
    # labels/seed
    assert inputs["labels"]["shape"] == [sh.batch]
    assert inputs["seed"]["shape"] == []
    # meta echoes
    assert prog["meta"]["node_caps"] == caps
    assert prog["meta"]["n_params"] == (9 if model == "sage" else 12)
    # outputs: loss, correct, h1..h_{L-1}, grads
    outs = [t["name"] for t in prog["outputs"]]
    assert outs[0] == "loss" and outs[1] == "correct"
    n_embeds = sh.n_layers - 1
    assert len(outs) == 2 + n_embeds + prog["meta"]["n_params"]
    # grads mirror param shapes (first n_params inputs)
    for i in range(prog["meta"]["n_params"]):
        pin = prog["inputs"][i]
        gout = prog["outputs"][2 + n_embeds + i]
        assert gout["name"] == f"grad_{pin['name']}"
        assert gout["shape"] == pin["shape"]


def test_fwd_programs_have_no_grads(manifest):
    progs = programs_by_name(manifest)
    for preset in ("tiny", "products-mini"):
        fwd = progs[f"sage_fwd_{preset}"]
        train = progs[f"sage_train_{preset}"]
        assert len(fwd["inputs"]) == len(train["inputs"])
        assert len(fwd["outputs"]) == 2 + (PRESETS[preset].n_layers - 1)


def test_hlo_files_exist_and_are_text(manifest):
    for p in manifest["programs"]:
        path = os.path.join(ART, p["hlo_file"])
        assert os.path.exists(path), path
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head, f"{path} does not look like HLO text"


def test_node_caps_row_aligned(manifest):
    # Pallas row-block alignment contract (shapes.ROW_ALIGN)
    for preset, caps in manifest["build_config"]["caps"].items():
        for c in caps["node_caps"][:-1]:  # all but seed layer
            assert c % 64 == 0, f"{preset} cap {c} not 64-aligned"
