"""L1 correctness: Pallas kernels vs pure-jnp oracles (values + VJPs).

Hypothesis sweeps shapes and seeds; interpret-mode Pallas on CPU must match
the references to ~1e-5 relative tolerance (float32 matmul accumulation
order differs, so exact equality is not expected).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fused_update import (
    BN,
    bwd_w_pallas,
    linear_act,
    matmul_pallas,
    sage_update,
)


def rand(key, *shape):
    return jax.random.normal(key, shape, jnp.float32)


def keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


ATOL = 2e-4
RTOL = 2e-4


def assert_close(a, b, label=""):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=RTOL, atol=ATOL,
                               err_msg=label)


# ---------------------------------------------------------------------------
# matmul building block
# ---------------------------------------------------------------------------
@settings(max_examples=12, deadline=None)
@given(
    m=st.sampled_from([BN, 2 * BN, 3 * BN, 7, 50, 65]),
    k=st.integers(1, 96),
    n=st.integers(1, 80),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_pallas_matches_ref(m, k, n, seed):
    ka, kb = keys(seed, 2)
    a, b = rand(ka, m, k), rand(kb, k, n)
    assert_close(matmul_pallas(a, b), ref.matmul_ref(a, b))


@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([BN, 4 * BN, 33]),
    k=st.integers(1, 64),
    n=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_bwd_w_accumulation_matches_xt_g(m, k, n, seed):
    kx, kg = keys(seed, 2)
    x, g = rand(kx, m, k), rand(kg, m, n)
    assert_close(bwd_w_pallas(x, g), x.T @ g)


# ---------------------------------------------------------------------------
# fused GraphSAGE UPDATE: values
# ---------------------------------------------------------------------------
@settings(max_examples=12, deadline=None)
@given(
    m=st.sampled_from([BN, 2 * BN, 32, 100]),
    k=st.integers(2, 100),
    n=st.integers(2, 64),
    activate=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_sage_update_matches_ref(m, k, n, activate, seed):
    k1, k2, k3, k4, k5, k6 = keys(seed, 6)
    xn, xs = rand(k1, m, k), rand(k2, m, k)
    wn, ws = rand(k3, k, n), rand(k4, k, n)
    b = rand(k5, n)
    mask = (jax.random.bernoulli(k6, 0.8, (m, n)).astype(jnp.float32)) / 0.8
    got = sage_update(xn, xs, wn, ws, b, mask, activate)
    want = ref.sage_update_ref(xn, xs, wn, ws, b, mask, activate)
    assert_close(got, want, f"activate={activate}")


# ---------------------------------------------------------------------------
# fused GraphSAGE UPDATE: custom VJP vs autodiff of the reference
# ---------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(
    m=st.sampled_from([BN, 2 * BN, 48]),
    k=st.integers(2, 48),
    n=st.integers(2, 32),
    activate=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_sage_update_vjp_matches_ref_grad(m, k, n, activate, seed):
    k1, k2, k3, k4, k5, k6 = keys(seed, 6)
    xn, xs = rand(k1, m, k), rand(k2, m, k)
    wn, ws = rand(k3, k, n), rand(k4, k, n)
    b = rand(k5, n)
    mask = (jax.random.bernoulli(k6, 0.7, (m, n)).astype(jnp.float32)) / 0.7

    def loss_kernel(xn, xs, wn, ws, b):
        y = sage_update(xn, xs, wn, ws, b, mask, activate)
        return (y * jnp.cos(y.shape[1] + jnp.arange(y.size).reshape(y.shape))).sum()

    def loss_ref(xn, xs, wn, ws, b):
        y = ref.sage_update_ref(xn, xs, wn, ws, b, mask, activate)
        return (y * jnp.cos(y.shape[1] + jnp.arange(y.size).reshape(y.shape))).sum()

    g_kernel = jax.grad(loss_kernel, argnums=(0, 1, 2, 3, 4))(xn, xs, wn, ws, b)
    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2, 3, 4))(xn, xs, wn, ws, b)
    for a, b_, name in zip(g_kernel, g_ref, ["dxn", "dxs", "dwn", "dws", "db"]):
        assert_close(a, b_, name)


# ---------------------------------------------------------------------------
# fused linear + activation (GAT projection)
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([BN, 3 * BN, 20]),
    k=st.integers(2, 80),
    n=st.integers(2, 96),
    activate=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_linear_act_matches_ref(m, k, n, activate, seed):
    k1, k2, k3 = keys(seed, 3)
    x, w, b = rand(k1, m, k), rand(k2, k, n), rand(k3, n)
    assert_close(linear_act(x, w, b, activate), ref.linear_act_ref(x, w, b, activate))


@settings(max_examples=6, deadline=None)
@given(
    m=st.sampled_from([BN, 2 * BN]),
    k=st.integers(2, 32),
    n=st.integers(2, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_linear_act_vjp_matches_ref_grad(m, k, n, seed):
    k1, k2, k3 = keys(seed, 3)
    x, w, b = rand(k1, m, k), rand(k2, k, n), rand(k3, n)

    gk = jax.grad(lambda x, w, b: (linear_act(x, w, b, True) ** 2).sum(),
                  argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(lambda x, w, b: (ref.linear_act_ref(x, w, b, True) ** 2).sum(),
                  argnums=(0, 1, 2))(x, w, b)
    for a, b_, name in zip(gk, gr, ["dx", "dw", "db"]):
        assert_close(a, b_, name)


# ---------------------------------------------------------------------------
# deterministic edge cases
# ---------------------------------------------------------------------------
def test_zero_mask_kills_activated_output_and_grads():
    m, k, n = BN, 8, 8
    k1, k2, k3, k4 = keys(0, 4)
    xn, xs = rand(k1, m, k), rand(k2, m, k)
    wn, ws = rand(k3, k, n), rand(k4, k, n)
    b = jnp.zeros((n,))
    mask = jnp.zeros((m, n))
    y = sage_update(xn, xs, wn, ws, b, mask, True)
    assert float(jnp.abs(y).max()) == 0.0
    g = jax.grad(lambda wn: sage_update(xn, xs, wn, ws, b, mask, True).sum())(wn)
    assert float(jnp.abs(g).max()) == 0.0


def test_relu_boundary_exact_zero():
    # pre-activation exactly zero must not propagate gradient (subgradient 0)
    m, n = BN, 4
    xn = jnp.zeros((m, 2))
    xs = jnp.zeros((m, 2))
    wn = jnp.ones((2, n))
    ws = jnp.ones((2, n))
    b = jnp.zeros((n,))
    mask = jnp.ones((m, n))
    g = jax.grad(lambda b: sage_update(xn, xs, wn, ws, b, mask, True).sum())(b)
    assert float(jnp.abs(g).max()) == 0.0


# ---------------------------------------------------------------------------
# blocked (grid) path — the TPU-shaped schedule selected via
# DISTGNN_PALLAS_BN; must agree with the single-block default bit-for-bit
# up to f32 accumulation order.
# ---------------------------------------------------------------------------
def test_blocked_path_matches_single_block(monkeypatch):
    import os
    m, k, n = 4 * BN, 48, 32
    k1, k2, k3, k4, k5, k6 = keys(11, 6)
    xn, xs = rand(k1, m, k), rand(k2, m, k)
    wn, ws = rand(k3, k, n), rand(k4, k, n)
    b = rand(k5, n)
    mask = (jax.random.bernoulli(k6, 0.9, (m, n)).astype(jnp.float32)) / 0.9

    monkeypatch.delenv("DISTGNN_PALLAS_BN", raising=False)
    y_single = sage_update(xn, xs, wn, ws, b, mask, True)
    g_single = jax.grad(lambda wn: sage_update(xn, xs, wn, ws, b, mask, True).sum())(wn)

    monkeypatch.setenv("DISTGNN_PALLAS_BN", str(BN))
    y_blocked = sage_update(xn, xs, wn, ws, b, mask, True)
    g_blocked = jax.grad(lambda wn: sage_update(xn, xs, wn, ws, b, mask, True).sum())(wn)

    assert_close(y_single, y_blocked, "fwd blocked vs single")
    assert_close(g_single, g_blocked, "bwd blocked vs single")


def test_blocked_matmul_and_bwd_w(monkeypatch):
    monkeypatch.setenv("DISTGNN_PALLAS_BN", str(BN))
    m, k, n = 3 * BN, 20, 24
    k1, k2 = keys(12, 2)
    a, g = rand(k1, m, k), rand(k2, m, n)
    assert_close(matmul_pallas(a, a.T @ a + 0 * a.T @ a), a @ (a.T @ a), "chained")
    assert_close(bwd_w_pallas(a, g), a.T @ g, "bwd_w blocked")
