"""L2 correctness: model programs on synthetic padded minibatches.

Checks (eager, CPU):
* loss is finite and decreases under SGD on a fixed synthetic minibatch
  (the train_step's gradients actually descend);
* the HEC scatter-overwrite semantics: in-bounds indices replace rows,
  out-of-bounds (cache-miss padding) indices are dropped;
* train/fwd program consistency: same params + batch, dropout off, must
  produce identical loss;
* masked (padded) seeds contribute nothing to loss or correct-count;
* GAT attention reference: softmax normalization and padding exclusion.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels.ref import gat_attention_ref
from compile.shapes import PRESETS


SH = PRESETS["tiny"]


def synth_batch(model: str, seed: int, miss_fraction: float = 0.0):
    """Build a random but structurally valid padded minibatch."""
    sh = dataclasses.replace(SH, self_loops=(model == "gat"))
    caps = sh.node_caps()
    ecaps = sh.edge_caps()
    hdims = sh.hec_dims()
    rng = np.random.default_rng(seed)
    batch = {}
    batch["feats"] = jnp.array(rng.normal(size=(caps[0], sh.feat_dim)).astype(np.float32))
    for l in range(sh.n_layers):
        e, nd, ns = ecaps[l], caps[l + 1], caps[l]
        esrc = rng.integers(0, ns, e).astype(np.int32)
        edst = rng.integers(0, nd, e).astype(np.int32)
        valid = (rng.random(e) > 0.2).astype(np.float32)
        # mean-normalize weights per dst like the Rust packer does
        deg = np.zeros(nd, np.float32)
        np.add.at(deg, edst, valid)
        ew = valid / np.maximum(deg[edst], 1.0)
        batch[f"esrc{l}"] = jnp.array(esrc)
        batch[f"edst{l}"] = jnp.array(edst)
        batch[f"ew{l}"] = jnp.array(ew if model == "sage" else valid)
    for l in range(1, sh.n_layers):
        n, d = caps[l], hdims[l]
        idx = rng.integers(0, n, n).astype(np.int32)
        if miss_fraction > 0:
            miss = rng.random(n) < miss_fraction
            idx[miss] = n  # out-of-bounds -> dropped scatter
        batch[f"hec_idx{l}"] = jnp.array(idx)
        batch[f"hec_val{l}"] = jnp.array(rng.normal(size=(n, d)).astype(np.float32))
    batch["labels"] = jnp.array(rng.integers(0, sh.num_classes, sh.batch).astype(np.int32))
    batch["lmask"] = jnp.ones((sh.batch,), jnp.float32)
    batch["seed"] = jnp.int32(seed)
    return sh, batch


def init_params(model: str, sh, seed=0):
    specs = M.sage_param_specs(sh) if model == "sage" else M.gat_param_specs(sh)
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in specs:
        key, sub = jax.random.split(key)
        scale = 0.1 if len(shape) > 1 else 0.0
        params.append(scale * jax.random.normal(sub, shape, jnp.float32))
    return params


@pytest.mark.parametrize("model", ["sage", "gat"])
def test_loss_finite_and_grads_shaped(model):
    sh, batch = synth_batch(model, 1)
    params = init_params(model, sh)
    fwd = M.sage_forward if model == "sage" else M.gat_forward
    (loss, (correct, embeds)), grads = jax.value_and_grad(fwd, has_aux=True)(
        params, batch, sh, True
    )
    assert np.isfinite(float(loss))
    assert 0.0 <= float(correct) <= sh.batch
    assert len(embeds) == sh.n_layers - 1
    for p, g in zip(params, grads):
        assert p.shape == g.shape
        assert np.all(np.isfinite(np.asarray(g)))


@pytest.mark.parametrize("model", ["sage", "gat"])
def test_sgd_descends_on_fixed_batch(model):
    sh, batch = synth_batch(model, 2)
    params = init_params(model, sh)
    fwd = M.sage_forward if model == "sage" else M.gat_forward
    vg = jax.jit(
        lambda p: jax.value_and_grad(lambda q: fwd(q, batch, sh, False)[0])(p)
    )
    lr = 0.5 if model == "sage" else 2.0
    loss0, _ = vg(params)
    losses = [float(loss0)]
    for _ in range(15):
        loss, grads = vg(params)
        params = [p - lr * g for p, g in zip(params, grads)]
        losses.append(float(loss))
    assert losses[-1] < 0.9 * losses[0], losses
    assert losses[-1] < losses[0] - 0.15, losses


@pytest.mark.parametrize("model", ["sage", "gat"])
def test_dropout_off_train_eq_fwd(model):
    sh0, batch = synth_batch(model, 3)
    sh = dataclasses.replace(sh0, dropout=0.0)
    params = init_params(model, sh)
    fwd = M.sage_forward if model == "sage" else M.gat_forward
    l_train, _ = fwd(params, batch, sh, True)
    l_eval, _ = fwd(params, batch, sh, False)
    np.testing.assert_allclose(float(l_train), float(l_eval), rtol=1e-6)


def test_hec_overwrite_in_bounds_replaces_out_of_bounds_drops():
    sh, batch = synth_batch("sage", 4)
    caps = sh.node_caps()
    n1 = caps[1]
    # all hec_idx1 out of bounds: h1 must be untouched by hec_val1
    b_miss = dict(batch)
    b_miss["hec_idx1"] = jnp.full((n1,), n1, jnp.int32)
    params = init_params("sage", sh)
    _, (_, embeds_miss) = M.sage_forward(params, b_miss, sh, False)
    # all hits at row 0..n1: row content equals hec_val1 rows
    b_hit = dict(batch)
    b_hit["hec_idx1"] = jnp.arange(n1, dtype=jnp.int32)
    _, (_, embeds_hit) = M.sage_forward(params, b_hit, sh, False)
    np.testing.assert_allclose(
        np.asarray(embeds_hit[0]), np.asarray(b_hit["hec_val1"]), rtol=1e-6
    )
    assert not np.allclose(np.asarray(embeds_miss[0]), np.asarray(b_hit["hec_val1"]))


def test_masked_seeds_do_not_contribute():
    sh, batch = synth_batch("sage", 5)
    params = init_params("sage", sh)
    full_mask = batch["lmask"]
    half = np.ones(sh.batch, np.float32)
    half[sh.batch // 2 :] = 0.0
    b_half = dict(batch)
    b_half["lmask"] = jnp.array(half)
    loss_h, (correct_h, _) = M.sage_forward(params, b_half, sh, False)
    # flipping labels of masked seeds changes nothing
    b_flip = dict(b_half)
    labels = np.asarray(batch["labels"]).copy()
    labels[sh.batch // 2 :] = (labels[sh.batch // 2 :] + 1) % sh.num_classes
    b_flip["labels"] = jnp.array(labels)
    loss_f, (correct_f, _) = M.sage_forward(params, b_flip, sh, False)
    np.testing.assert_allclose(float(loss_h), float(loss_f), rtol=1e-6)
    assert float(correct_h) == float(correct_f)
    assert float(correct_h) <= sh.batch // 2


def test_gat_attention_normalizes_and_ignores_padding():
    rng = np.random.default_rng(0)
    ns, nd, e, heads, dh = 10, 4, 12, 2, 3
    z = jnp.array(rng.normal(size=(ns, heads, dh)).astype(np.float32))
    es = jnp.array(rng.normal(size=(ns, heads)).astype(np.float32))
    ed = jnp.array(rng.normal(size=(nd, heads)).astype(np.float32))
    esrc = jnp.array(rng.integers(0, ns, e).astype(np.int32))
    edst = jnp.array(rng.integers(0, nd, e).astype(np.int32))
    emask = jnp.ones((e,), jnp.float32)
    out_full = gat_attention_ref(z, es, ed, esrc, edst, emask, nd)
    # convex combination: each dst/head output within min/max of its sources
    out = np.asarray(out_full)
    for d in range(nd):
        srcs = [int(esrc[i]) for i in range(e) if int(edst[i]) == d]
        if not srcs:
            continue
        zmax = np.asarray(z)[srcs].max(axis=0)
        zmin = np.asarray(z)[srcs].min(axis=0)
        assert np.all(out[d] <= zmax + 1e-5)
        assert np.all(out[d] >= zmin - 1e-5)
    # masked edges are excluded
    emask2 = emask.at[0].set(0.0)
    out_masked = gat_attention_ref(z, es, ed, esrc, edst, emask2, nd)
    d0 = int(edst[0])
    others = [i for i in range(1, e) if int(edst[i]) == d0]
    if others:
        assert not np.allclose(np.asarray(out_masked)[d0], out[d0])
    # dst with no edges -> exactly zero output
    out_none = gat_attention_ref(z, es, ed, esrc, edst, jnp.zeros((e,)), nd)
    assert np.abs(np.asarray(out_none)).max() == 0.0
