//! Flat vs hierarchical fabric: bytes on the wire and ring-allreduce
//! cost at 4–16 ranks.
//!
//! Three measurements, one table each:
//!
//! * **Ring volume** — `ring_average_f32` run for real over in-memory
//!   channel links with per-rank byte counters. The reduce-scatter +
//!   allgather ring must move exactly `2(k-1)·N/k` bytes per rank (the
//!   optimal ring volume; the old allgather-everything ring moved
//!   `2(k-1)·N`), and its result must be bit-identical to the serial
//!   [`average_inplace`] reference — both are asserted.
//! * **Bytes on the wire** — the same per-rank volume classified by a
//!   host-major `--hosts` topology at 2 ranks/host: only ranks whose
//!   ring successor lives on another host put chunks on the wire, so the
//!   hierarchical placement crosses hosts on `k/2` of the `k` edges.
//!   Modeled allreduce time uses [`NetSim::allreduce_contended`]: flat
//!   (topology-oblivious) placement puts 2 concurrent chunk streams on
//!   every NIC, host-major exactly one.
//! * **Training cells (sim)** — full training runs, flat vs `--hosts`,
//!   asserting `losses_bit_identical` per cell (placement classifies
//!   accounting, never what is computed) and that hierarchical
//!   `comm_wire_bytes` lands strictly below flat at 8 ranks.
//!
//! Section `fabric_ring`; default output `BENCH_fabric.json`.

use std::sync::mpsc;
use std::time::Instant;

use distgnn_mb::benchkit::{fmt_gb, print_table, run, write_bench_section};
use distgnn_mb::comm::allreduce::{average_inplace, ring_average_f32, RingLink};
use distgnn_mb::comm::NetSim;
use distgnn_mb::config::{NetConfig, TrainConfig};
use distgnn_mb::util::json::{self, Value};

/// In-memory ring link with a sent-byte counter (the bench's
/// "instrumented wire").
struct ChanLink {
    tx_next: mpsc::Sender<Vec<u8>>,
    rx_prev: mpsc::Receiver<Vec<u8>>,
    sent_bytes: u64,
}

impl RingLink for ChanLink {
    fn send_next(&mut self, payload: &[u8]) -> anyhow::Result<()> {
        self.sent_bytes += payload.len() as u64;
        self.tx_next
            .send(payload.to_vec())
            .map_err(|_| anyhow::anyhow!("ring successor gone"))
    }
    fn recv_prev(&mut self) -> anyhow::Result<Vec<u8>> {
        self.rx_prev
            .recv()
            .map_err(|_| anyhow::anyhow!("ring predecessor gone"))
    }
}

/// Run one k-rank ring allreduce over threads; returns (per-rank sent
/// bytes, wall seconds, reduced vectors).
fn ring_once(k: usize, n: usize) -> anyhow::Result<(Vec<u64>, f64, Vec<Vec<f32>>)> {
    // rank r's successor link: channel r feeds rank (r+1)%k's receiver
    let (txs, rxs): (Vec<_>, Vec<_>) = (0..k).map(|_| mpsc::channel::<Vec<u8>>()).unzip();
    let mut rxs: Vec<Option<mpsc::Receiver<Vec<u8>>>> = rxs.into_iter().map(Some).collect();
    let mut links: Vec<ChanLink> = Vec::with_capacity(k);
    for (r, tx) in txs.into_iter().enumerate() {
        links.push(ChanLink {
            tx_next: tx,
            rx_prev: rxs[(r + k - 1) % k].take().expect("receiver unused"),
            sent_bytes: 0,
        });
    }
    let t0 = Instant::now();
    let handles: Vec<_> = links
        .into_iter()
        .enumerate()
        .map(|(r, mut link)| {
            std::thread::spawn(move || -> anyhow::Result<(u64, Vec<f32>)> {
                // deterministic per-rank payload: averages are exact
                let mut local: Vec<f32> = (0..n).map(|i| (r + i % 13) as f32).collect();
                ring_average_f32(r, k, &mut local, &mut link)?;
                Ok((link.sent_bytes, local))
            })
        })
        .collect();
    let mut sent = Vec::with_capacity(k);
    let mut reduced = Vec::with_capacity(k);
    for h in handles {
        let (bytes, vec) = h.join().expect("ring thread panicked")?;
        sent.push(bytes);
        reduced.push(vec);
    }
    Ok((sent, t0.elapsed().as_secs_f64(), reduced))
}

fn base() -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.preset = "products-mini".into();
    // random partitioning maximizes the cut: real AEP traffic to classify
    cfg.partitioner = "random".into();
    cfg.epochs = std::env::var("DISTGNN_EPOCHS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2);
    cfg.max_minibatches = Some(
        std::env::var("DISTGNN_MAX_MB")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(6),
    );
    cfg
}

fn main() -> anyhow::Result<()> {
    if std::env::var("DISTGNN_BENCH_OUT").is_err() {
        std::env::set_var("DISTGNN_BENCH_OUT", "BENCH_fabric.json");
    }
    let net = NetSim::new(NetConfig::default());
    let n_elems = 1usize << 16; // 256 KiB of f32 gradients, k | N for all k below
    let n_bytes = n_elems * 4;
    let ranks_per_host = 2usize;

    // ---- ring volume + wire classification at k = 4, 8, 16 ----
    let mut ring_rows = Vec::new();
    let mut ring_json = Vec::new();
    for &k in &[4usize, 8, 16] {
        let (sent, wall_s, reduced) = ring_once(k, n_elems)?;
        // optimal ring volume, per rank, exactly
        let optimal = (2 * (k - 1) * n_bytes / k) as u64;
        for (r, &b) in sent.iter().enumerate() {
            anyhow::ensure!(
                b == optimal,
                "rank {r}/{k} moved {b} B, want 2(k-1)N/k = {optimal}"
            );
        }
        // bit-identical to the serial canonical fold
        let mut reference: Vec<Vec<f32>> = (0..k)
            .map(|r| (0..n_elems).map(|i| (r + i % 13) as f32).collect())
            .collect();
        average_inplace(&mut reference);
        anyhow::ensure!(
            reduced == reference,
            "ring result diverged from the serial canonical fold at k={k}"
        );
        // flat placement charges every ring edge; host-major placement
        // crosses hosts on one edge per host (the host's last rank)
        let flat_wire = optimal * k as u64;
        let hier_wire = optimal * (k / ranks_per_host) as u64;
        let t_flat = net.allreduce_contended(k, n_bytes, ranks_per_host);
        let t_hier = net.allreduce_contended(k, n_bytes, 1);
        anyhow::ensure!(hier_wire < flat_wire, "hier must cut wire bytes at k={k}");
        ring_rows.push(vec![
            format!("k={k}"),
            format!("{optimal}"),
            fmt_gb(flat_wire as f64),
            fmt_gb(hier_wire as f64),
            format!("{:.1}x", flat_wire as f64 / hier_wire as f64),
            format!("{:.2}ms", t_flat * 1e3),
            format!("{:.2}ms", t_hier * 1e3),
            format!("{:.3}ms", wall_s * 1e3),
        ]);
        ring_json.push(json::obj(vec![
            ("k", json::num(k as f64)),
            ("n_bytes", json::num(n_bytes as f64)),
            ("bytes_per_rank", json::num(optimal as f64)),
            ("optimal_bytes_per_rank", json::num(optimal as f64)),
            ("flat_wire_bytes", json::num(flat_wire as f64)),
            ("hier_wire_bytes", json::num(hier_wire as f64)),
            ("modeled_flat_allreduce_s", json::num(t_flat)),
            ("modeled_hier_allreduce_s", json::num(t_hier)),
            ("measured_ring_wall_s", json::num(wall_s)),
        ]));
    }
    print_table(
        &format!(
            "reduce-scatter+allgather ring, N = {n_bytes} B, {ranks_per_host} ranks/host \
             (wire bytes: flat charges every edge, host-major only host boundaries)"
        ),
        &[
            "ring", "B/rank", "flat wire", "hier wire", "cut", "t flat", "t hier", "wall",
        ],
        &ring_rows,
    );

    // ---- training cells: flat vs --hosts, losses must not move ----
    let mut cell_rows = Vec::new();
    let mut cell_json = Vec::new();
    let mut losses_bit_identical = true;
    let mut hier_wire_below_flat_at_8 = true;
    for &k in &[4usize, 8] {
        let mut flat_cfg = base();
        flat_cfg.ranks = k;
        let flat = run(flat_cfg)?;
        let mut hier_cfg = base();
        hier_cfg.ranks = k;
        hier_cfg.hosts = vec![ranks_per_host.to_string(); k / ranks_per_host].join(",");
        let hier = run(hier_cfg)?;
        let (fl, hl) = (
            flat.epochs.last().expect("flat epochs"),
            hier.epochs.last().expect("hier epochs"),
        );
        let identical = fl.train_loss == hl.train_loss;
        losses_bit_identical &= identical;
        if k >= 8 {
            hier_wire_below_flat_at_8 &= hl.comm_wire_bytes < fl.comm_wire_bytes;
        }
        cell_rows.push(vec![
            format!("k={k}"),
            format!("{:.6}", fl.train_loss),
            format!("{:.6}", hl.train_loss),
            if identical { "yes".into() } else { "NO".into() },
            fmt_gb(fl.comm_wire_bytes as f64),
            fmt_gb(hl.comm_wire_bytes as f64),
            format!(
                "{:.0}%",
                100.0 * (1.0 - hl.comm_wire_bytes as f64 / fl.comm_wire_bytes.max(1) as f64)
            ),
        ]);
        cell_json.push(json::obj(vec![
            ("k", json::num(k as f64)),
            ("hosts", json::s(&format!("{} x {ranks_per_host}", k / ranks_per_host))),
            ("flat_loss", json::num(fl.train_loss)),
            ("hier_loss", json::num(hl.train_loss)),
            ("losses_bit_identical", Value::Bool(identical)),
            ("flat_wire_bytes", json::num(fl.comm_wire_bytes as f64)),
            ("hier_wire_bytes", json::num(hl.comm_wire_bytes as f64)),
            ("flat_comm_bytes", json::num(fl.comm_bytes as f64)),
            ("hier_comm_bytes", json::num(hl.comm_bytes as f64)),
        ]));
    }
    print_table(
        "training, flat vs host-major --hosts (sim fabric, random partition)",
        &[
            "cell", "flat loss", "hier loss", "bit-identical", "flat wire", "hier wire",
            "wire cut",
        ],
        &cell_rows,
    );

    write_bench_section(
        "fabric_ring",
        vec![
            ("ring", json::arr(ring_json)),
            ("cells", json::arr(cell_json)),
            ("losses_bit_identical", Value::Bool(losses_bit_identical)),
            (
                "hier_wire_below_flat_at_8_ranks",
                Value::Bool(hier_wire_below_flat_at_8),
            ),
        ],
    )?;

    if !losses_bit_identical {
        anyhow::bail!("placement changed losses — topology must classify bytes, not math");
    }
    if !hier_wire_below_flat_at_8 {
        anyhow::bail!("hierarchical wire bytes not below flat at 8 ranks");
    }
    println!("\nexpected shapes: every rank moves exactly 2(k-1)N/k ring bytes;");
    println!("host-major placement cuts wire bytes by the ranks-per-host factor");
    println!("(only host-boundary edges touch the network); losses never move.");
    Ok(())
}
