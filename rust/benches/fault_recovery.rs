//! Fault-recovery costs, measured: how fast a dead peer is detected, what
//! a checkpoint costs to write and read, and how long a kill → resume →
//! retrain cycle takes end to end.
//!
//! Three numbers back the robustness story's claims:
//!
//! * **detection latency** — wall time from a peer severing its
//!   connections (the `drop_conn` fault) to the survivor holding a typed
//!   `PeerDied`. The claim: seconds at most (EOF propagation through the
//!   reader threads), never the 120 s receive timeout.
//! * **checkpoint I/O** — save/load wall time and file size for a
//!   ~200k-parameter model with optimizer state (the periodic
//!   `--ckpt-every` cost a run pays at each boundary).
//! * **recovery wall time** — construct a fresh driver, `resume_from` the
//!   checkpoint, retrain the remaining epochs; asserts the resumed losses
//!   are bit-identical to the uninterrupted reference while measuring
//!   what the recovery actually costs.
//!
//! Section `fault_recovery`; default output `BENCH_fault.json`.

use std::path::PathBuf;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use distgnn_mb::benchkit::{print_table, write_bench_section};
use distgnn_mb::comm::{Fabric, FaultPlan, PeerDied, SocketConfig, SocketFabric};
use distgnn_mb::config::TrainConfig;
use distgnn_mb::model::Checkpoint;
use distgnn_mb::train::Driver;
use distgnn_mb::util::json::{self, Value};

fn tiny_cfg(cache: &PathBuf, ckpt: &str) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.preset = "tiny".into();
    cfg.ranks = 2;
    cfg.epochs = 2;
    cfg.seed = 42;
    cfg.max_minibatches = Some(4);
    cfg.data_cache = cache.to_string_lossy().to_string();
    cfg.ckpt_every = 1;
    cfg.ckpt_path = ckpt.to_string();
    cfg
}

fn losses(driver: &Driver) -> Vec<f64> {
    driver.report.epochs.iter().map(|e| e.train_loss).collect()
}

/// One detection trial: two in-process socket fabrics over unix sockets;
/// rank 1's plan severs every connection at iteration 1, rank 0 measures
/// sever → typed `PeerDied` wall time.
fn detection_trial(trial: usize) -> anyhow::Result<f64> {
    let base = std::env::temp_dir().join(format!(
        "distgnn-faultbench-{}-{trial}",
        std::process::id()
    ));
    let peers: Vec<String> = (0..2)
        .map(|r| base.join(format!("r{r}.sock")).to_string_lossy().to_string())
        .collect();
    let (tx, rx) = mpsc::channel::<Instant>();
    let p1 = peers.clone();
    let h1 = std::thread::spawn(move || -> anyhow::Result<()> {
        let mut cfg = SocketConfig::new(1, p1);
        cfg.fault_plan = FaultPlan::parse("drop_conn:rank=1,iter=1")?;
        let mut f = SocketFabric::connect(cfg)?;
        f.complete_iteration(1, 0)?;
        tx.send(Instant::now()).ok();
        let _ = f.complete_iteration(1, 1); // the fault severs everything
        f.shutdown()?;
        Ok(())
    });

    let mut cfg = SocketConfig::new(0, peers);
    cfg.recv_timeout = Duration::from_secs(30);
    let mut f = SocketFabric::connect(cfg)?;
    f.complete_iteration(0, 0)?;
    let (msgs, _) = f.receive_upto(0, 0, 0.0)?;
    anyhow::ensure!(msgs.is_empty());
    let err = f.receive_upto(0, 1, 0.0).unwrap_err();
    let detected = Instant::now();
    anyhow::ensure!(err.is::<PeerDied>(), "expected typed PeerDied: {err:#}");
    let severed = rx.recv()?;
    f.shutdown()?;
    h1.join()
        .map_err(|_| anyhow::anyhow!("peer thread panicked"))??;
    let _ = std::fs::remove_dir_all(&base);
    Ok(detected.duration_since(severed).as_secs_f64() * 1000.0)
}

fn main() -> anyhow::Result<()> {
    if std::env::var("DISTGNN_BENCH_OUT").is_err() {
        std::env::set_var("DISTGNN_BENCH_OUT", "BENCH_fault.json");
    }
    println!("### bench: fault_recovery");
    let root = std::env::temp_dir().join(format!("distgnn-faultbench-{}", std::process::id()));
    let cache = root.join("cache");
    std::fs::create_dir_all(&root)?;

    // ---- detection latency -------------------------------------------------
    let mut trials: Vec<f64> = (0..5).map(detection_trial).collect::<Result<_, _>>()?;
    trials.sort_by(f64::total_cmp);
    let detect_median = trials[trials.len() / 2];
    let detect_max = *trials.last().unwrap();
    anyhow::ensure!(
        detect_max < 5_000.0,
        "detection latency {detect_max:.0} ms blows the 5 s budget"
    );

    // ---- checkpoint I/O ----------------------------------------------------
    let n = 200_000usize;
    let ck = Checkpoint {
        epoch: 3,
        seed: 42,
        iter: 120,
        params: (0..n).map(|i| (i % 997) as f32 * 1e-3).collect(),
        opt_state: vec![
            ("adam_m".to_string(), vec![0.125f32; n]),
            ("adam_v".to_string(), vec![0.25f32; n]),
        ],
        config: json::obj(vec![("preset", json::s("bench"))]),
        shards: None,
    };
    let ck_path = root.join("bench.dgnc");
    let t = Instant::now();
    ck.save(&ck_path)?;
    let save_ms = t.elapsed().as_secs_f64() * 1000.0;
    let ck_bytes = std::fs::metadata(&ck_path)?.len();
    let t = Instant::now();
    let back = Checkpoint::load(&ck_path)?;
    let load_ms = t.elapsed().as_secs_f64() * 1000.0;
    anyhow::ensure!(
        back.params == ck.params && back.opt_state == ck.opt_state && back.iter == ck.iter,
        "checkpoint round-trip corrupted"
    );

    // ---- kill → resume → retrain -------------------------------------------
    let ck_run = root.join("run.dgnc").to_string_lossy().to_string();
    // uninterrupted reference (same checkpoint schedule)
    let mut driver = Driver::new(tiny_cfg(&cache, &ck_run))?;
    driver.train(None)?;
    let ref_losses = losses(&driver);
    let m_max = driver.report.epochs[0].minibatches;
    drop(driver);

    // the same run, killed one iteration into epoch 1
    let mut cfg = tiny_cfg(&cache, &ck_run);
    cfg.fault_plan = format!("kill:rank=1,iter={m_max}");
    let mut driver = Driver::new(cfg)?;
    let err = driver.train(None).unwrap_err();
    anyhow::ensure!(err.is::<PeerDied>(), "{err:#}");
    drop(driver);

    // recovery: fresh driver + resume + retrain the remaining epoch
    let t = Instant::now();
    let mut driver = Driver::new(tiny_cfg(&cache, &ck_run))?;
    let resumed_at = driver.resume_from(&ck_run)?;
    driver.train(None)?;
    let recovery_ms = t.elapsed().as_secs_f64() * 1000.0;
    let resumed_losses = losses(&driver);
    let bit_identical = resumed_losses == ref_losses[resumed_at..].to_vec();
    anyhow::ensure!(
        bit_identical,
        "resumed losses diverged from the uninterrupted reference"
    );
    drop(driver);

    print_table(
        "fault recovery costs",
        &["metric", "value"],
        &[
            vec!["detection median (ms)".into(), format!("{detect_median:.2}")],
            vec!["detection max of 5 (ms)".into(), format!("{detect_max:.2}")],
            vec![format!("ckpt save, {n} params (ms)"), format!("{save_ms:.2}")],
            vec!["ckpt load (ms)".into(), format!("{load_ms:.2}")],
            vec!["ckpt size (bytes)".into(), format!("{ck_bytes}")],
            vec!["resume + retrain 1 epoch (ms)".into(), format!("{recovery_ms:.2}")],
            vec!["resumed losses bit-identical".into(), format!("{bit_identical}")],
        ],
    );

    write_bench_section(
        "fault_recovery",
        vec![
            ("detection_ms_median", json::num(detect_median)),
            ("detection_ms_max", json::num(detect_max)),
            ("detection_trials", json::num(trials.len() as f64)),
            ("detection_budget_ms", json::num(5_000.0)),
            ("ckpt_params", json::num(n as f64)),
            ("ckpt_bytes", json::num(ck_bytes as f64)),
            ("ckpt_save_ms", json::num(save_ms)),
            ("ckpt_load_ms", json::num(load_ms)),
            ("resumed_at_epoch", json::num(resumed_at as f64)),
            ("recovery_ms", json::num(recovery_ms)),
            ("recovery_bit_identical", Value::Bool(bit_identical)),
        ],
    )?;

    let _ = std::fs::remove_dir_all(&root);
    println!("\nexpected shapes: detection is milliseconds (EOF through the reader");
    println!("threads), orders of magnitude under the 5 s budget and the 120 s");
    println!("receive timeout; checkpoint I/O is a few ms for ~2.4 MB; recovery is");
    println!("dominated by retraining the lost epoch, not by resume bookkeeping.");
    Ok(())
}
