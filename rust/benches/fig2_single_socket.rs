//! Figure 2: single-socket epoch time — baseline vs OPT_UPDATE vs
//! OPT_UPDATE + SYNC_MBC, for GraphSAGE and GAT on both datasets.
//!
//! Decomposition of the reproduction (DESIGN.md §3):
//! * **SYNC_MBC** is measured directly: the baseline sampler emulates
//!   DGL's dataloader-worker IPC (serialize + copy + deserialize per
//!   minibatch); the optimized sampler is the synchronous in-process one.
//! * **OPT_UPDATE** is measured at the primitive level: the UPDATE chain
//!   executed op-by-op as separate PJRT executables with host-visible
//!   intermediates (DGL/PyTorch-style op dispatch) vs the single fused
//!   Pallas program; the per-epoch delta is the per-call delta times the
//!   number of UPDATE calls (layers x minibatches).
//!
//! Paper shape: all optimizations combined make GraphSAGE 1.5-2x and GAT
//! 1.4-1.7x faster than baseline DGL.

use distgnn_mb::benchkit::{fmt_s, print_table, run};
use distgnn_mb::config::{ModelKind, SamplerKind, TrainConfig};
use distgnn_mb::runtime::{HostTensor, Manifest, Runtime};
use distgnn_mb::util::rng::Pcg64;

/// Measure mean seconds/call of a program with random inputs.
fn time_program(
    rt: &Runtime,
    name: &str,
    reps: usize,
    rng: &mut Pcg64,
) -> anyhow::Result<(f64, Vec<HostTensor>)> {
    let exe = rt.program(name)?;
    let inputs: Vec<HostTensor> = exe
        .spec
        .inputs
        .iter()
        .map(|s| {
            let n: usize = s.shape.iter().product();
            HostTensor::f32(
                s.shape.clone(),
                &(0..n).map(|_| rng.gen_f32() - 0.5).collect::<Vec<_>>(),
            )
        })
        .collect();
    exe.run(&inputs)?; // warmup
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        exe.run(&inputs)?;
    }
    Ok((t0.elapsed().as_secs_f64() / reps as f64, inputs))
}

fn update_micro(artifacts: &str) -> anyhow::Result<(f64, f64, f64)> {
    let manifest = Manifest::load_or_builtin(artifacts)?;
    let mut rt = Runtime::cpu()?;
    for p in [
        "update_fused_products-mini",
        "update_unfused_full_products-mini",
        "update_mm_products-mini",
        "update_add_bias_products-mini",
        "update_relu_products-mini",
        "update_dropout_products-mini",
    ] {
        rt.load_program(&manifest, p)?;
    }
    let mut rng = Pcg64::seeded(1);
    let reps = 5;
    let (t_fused, _) = time_program(&rt, "update_fused_products-mini", reps, &mut rng)?;
    let (t_unfused_xla, _) = time_program(&rt, "update_unfused_full_products-mini", reps, &mut rng)?;
    // op-by-op chain: two matmuls + add_bias + relu + dropout as separate
    // executables (host round-trips between ops, like framework op dispatch)
    let (t_mm, _) = time_program(&rt, "update_mm_products-mini", reps, &mut rng)?;
    let (t_add, _) = time_program(&rt, "update_add_bias_products-mini", reps, &mut rng)?;
    let (t_relu, _) = time_program(&rt, "update_relu_products-mini", reps, &mut rng)?;
    let (t_drop, _) = time_program(&rt, "update_dropout_products-mini", reps, &mut rng)?;
    let t_opbyop = 2.0 * t_mm + t_add + t_relu + t_drop;
    Ok((t_opbyop, t_unfused_xla, t_fused))
}

fn main() -> anyhow::Result<()> {
    println!("### bench: fig2_single_socket (paper Fig. 2)");
    let epochs: usize = std::env::var("DISTGNN_EPOCHS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);
    let max_mb: usize = std::env::var("DISTGNN_MAX_MB")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8);

    // --- UPDATE primitive micro-comparison -------------------------------
    let (t_opbyop, t_unfused_xla, t_fused) = update_micro("artifacts")?;
    print_table(
        "UPDATE primitive (products-mini dims, per call)",
        &["variant", "sec/call"],
        &[
            vec!["op-by-op (5 executables, host round-trips)".into(), fmt_s(t_opbyop)],
            vec!["unfused single program (XLA auto-fusion)".into(), fmt_s(t_unfused_xla)],
            vec!["fused Pallas program (OPT_UPDATE)".into(), fmt_s(t_fused)],
        ],
    );
    let update_delta = (t_opbyop - t_fused).max(0.0);

    // --- epoch-level comparison ------------------------------------------
    for (model, lr) in [(ModelKind::Sage, 3e-3f32), (ModelKind::Gat, 1e-3)] {
        for preset in ["products-mini", "papers100m-mini"] {
            let mut rows = Vec::new();
            let run_cfg = |sampler: SamplerKind| -> anyhow::Result<f64> {
                let mut cfg = TrainConfig::default();
                cfg.preset = preset.into();
                cfg.model = model;
                cfg.lr = lr;
                cfg.ranks = 1;
                cfg.epochs = epochs;
                cfg.sampler = sampler;
                cfg.max_minibatches = Some(max_mb);
                Ok(run(cfg)?.mean_epoch_time(1))
            };
            let t_ipc = run_cfg(SamplerKind::SerialIpc)?;
            let t_sync = run_cfg(SamplerKind::Parallel)?;
            // modeled baseline: IPC sampler + unfused op-by-op UPDATE
            let n_update_calls = (max_mb * 3) as f64; // 3 layers per minibatch
            let t_baseline = t_ipc + update_delta * n_update_calls;
            rows.push(vec!["baseline (IPC sampler + op-by-op UPDATE)".into(), fmt_s(t_baseline)]);
            rows.push(vec!["OPT_UPDATE (fused, IPC sampler)".into(), fmt_s(t_ipc)]);
            rows.push(vec!["OPT_UPDATE + SYNC_MBC".into(), fmt_s(t_sync)]);
            rows.push(vec![
                "total speedup".into(),
                format!("{:.2}x", t_baseline / t_sync),
            ]);
            print_table(
                &format!(
                    "Fig. 2 — single-socket {} on {preset} (epoch sec)",
                    if model == ModelKind::Sage { "GraphSAGE" } else { "GAT" }
                ),
                &["variant", "epoch"],
                &rows,
            );
        }
    }
    println!("\nshape check vs paper: fused UPDATE + synchronous sampler beat the");
    println!("op-dispatch + IPC-worker baseline (paper: 1.4-2x overall).");
    Ok(())
}
