//! Figure 3: GraphSAGE epoch time (with MBC/FWD/BWD/ARed breakdown) and
//! relative speedup as ranks scale, on both OGBN-mini datasets.
//!
//! Paper reference points (absolute seconds are testbed-specific; the
//! reproduction criterion is the *shape*): epoch time falls monotonically
//! with ranks; MBC and BWD scale ~linearly; FWD and ARed scale at 40% /
//! 69% efficiency; best speedup ~10x at 16x more ranks (papers100M,
//! 4 -> 64 ranks).

use distgnn_mb::benchkit::{fmt_s, fmt_x, print_table, run};
use distgnn_mb::config::TrainConfig;

fn main() -> anyhow::Result<()> {
    let rank_counts: Vec<usize> = std::env::var("DISTGNN_RANKS")
        .map(|v| v.split(',').filter_map(|x| x.parse().ok()).collect())
        .unwrap_or_else(|_| vec![2, 4, 8, 16, 32]);
    let epochs: usize = std::env::var("DISTGNN_EPOCHS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);
    // strong scaling needs full epochs: per-rank minibatch counts must
    // shrink as ranks grow. DISTGNN_MAX_MB caps them for quick runs.
    let max_mb: Option<usize> = std::env::var("DISTGNN_MAX_MB")
        .ok()
        .and_then(|v| v.parse().ok());

    for preset in ["products-mini", "papers100m-mini"] {
        let mut rows = Vec::new();
        let mut base_time = None;
        for &ranks in &rank_counts {
            let mut cfg = TrainConfig::default();
            cfg.preset = preset.into();
            cfg.ranks = ranks;
            cfg.epochs = epochs;
            cfg.max_minibatches = max_mb;
            let report = run(cfg)?;
            let t = report.mean_epoch_time(1);
            let c = report.mean_comps(1);
            if base_time.is_none() {
                base_time = Some(t);
            }
            let speedup = base_time.unwrap() / t;
            let last = report.epochs.last().unwrap();
            rows.push(vec![
                ranks.to_string(),
                fmt_s(t),
                fmt_s(c.mbc),
                fmt_s(c.fwd),
                fmt_s(c.bwd),
                fmt_s(c.ared),
                fmt_x(speedup),
                format!("{:.2}", last.load_imbalance),
                last.hec_hit_rates
                    .iter()
                    .map(|h| format!("{:.0}", h * 100.0))
                    .collect::<Vec<_>>()
                    .join("/"),
            ]);
        }
        print_table(
            &format!("Fig. 3 — GraphSAGE scaling on {preset} (epoch seconds, virtual cluster)"),
            &[
                "ranks", "epoch", "MBC", "FWD", "BWD", "ARed", "speedup", "imb", "hec%L0/L1/L2",
            ],
            &rows,
        );
    }
    println!("\nshape checks vs paper: epoch time monotone down, speedup grows with ranks,");
    println!("FWD share grows at scale (comm pre/post-processing), MBC/BWD shrink ~linearly.");
    Ok(())
}
