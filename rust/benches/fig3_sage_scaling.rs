//! Figure 3: GraphSAGE epoch time (with MBC/FWD/BWD/ARed breakdown) and
//! relative speedup as ranks scale, on both OGBN-mini datasets.
//!
//! Paper reference points (absolute seconds are testbed-specific; the
//! reproduction criterion is the *shape*): epoch time falls monotonically
//! with ranks; MBC and BWD scale ~linearly; FWD and ARed scale at 40% /
//! 69% efficiency; best speedup ~10x at 16x more ranks (papers100M,
//! 4 -> 64 ranks).
//!
//! The sweep ends with a **papers100M-class shard cell**: the same SAGE
//! config trained out-of-core from a synthetic R-MAT shard set
//! (`papers100m-mini` shapes, `DISTGNN_OOC_SCALE`/`DISTGNN_OOC_EDGES`
//! sized; CI defaults, scale 27 with 10⁹ draws is paper-class), mapped
//! vs heap-copied, recording bytes mapped, fault stall seconds, peak RSS
//! and epoch time with the loss curves asserted bit-identical. Section
//! `fig3_shard_cell`; default output `BENCH_pipeline.json`.

use distgnn_mb::benchkit::{fmt_s, fmt_x, print_table, run, write_bench_section};
use distgnn_mb::config::TrainConfig;
use distgnn_mb::graph::generator::{generate_rmat_shards, ShardGenConfig};
use distgnn_mb::graph::io::{self as graph_io, ShardVerify};
use distgnn_mb::util::json::{self, Value};
use distgnn_mb::util::mmap;

fn env_or(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let rank_counts: Vec<usize> = std::env::var("DISTGNN_RANKS")
        .map(|v| v.split(',').filter_map(|x| x.parse().ok()).collect())
        .unwrap_or_else(|_| vec![2, 4, 8, 16, 32]);
    let epochs: usize = std::env::var("DISTGNN_EPOCHS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);
    // strong scaling needs full epochs: per-rank minibatch counts must
    // shrink as ranks grow. DISTGNN_MAX_MB caps them for quick runs.
    let max_mb: Option<usize> = std::env::var("DISTGNN_MAX_MB")
        .ok()
        .and_then(|v| v.parse().ok());

    for preset in ["products-mini", "papers100m-mini"] {
        let mut rows = Vec::new();
        let mut base_time = None;
        for &ranks in &rank_counts {
            let mut cfg = TrainConfig::default();
            cfg.preset = preset.into();
            cfg.ranks = ranks;
            cfg.epochs = epochs;
            cfg.max_minibatches = max_mb;
            let report = run(cfg)?;
            let t = report.mean_epoch_time(1);
            let c = report.mean_comps(1);
            if base_time.is_none() {
                base_time = Some(t);
            }
            let speedup = base_time.unwrap() / t;
            let last = report.epochs.last().unwrap();
            rows.push(vec![
                ranks.to_string(),
                fmt_s(t),
                fmt_s(c.mbc),
                fmt_s(c.fwd),
                fmt_s(c.bwd),
                fmt_s(c.ared),
                fmt_x(speedup),
                format!("{:.2}", last.load_imbalance),
                last.hec_hit_rates
                    .iter()
                    .map(|h| format!("{:.0}", h * 100.0))
                    .collect::<Vec<_>>()
                    .join("/"),
            ]);
        }
        print_table(
            &format!("Fig. 3 — GraphSAGE scaling on {preset} (epoch seconds, virtual cluster)"),
            &[
                "ranks", "epoch", "MBC", "FWD", "BWD", "ARed", "speedup", "imb", "hec%L0/L1/L2",
            ],
            &rows,
        );
    }
    // ---- papers100M-class shard cell: SAGE out-of-core -----------------
    let seed = 42u64;
    let ranks = env_or("DISTGNN_OOC_RANKS", 4) as usize;
    let scale = env_or("DISTGNN_OOC_SCALE", 13) as u32;
    let edges = env_or("DISTGNN_OOC_EDGES", 12u64 << scale);
    let dir = std::env::temp_dir().join(format!("distgnn-fig3-shards-{}", std::process::id()));
    let stats = generate_rmat_shards(
        &ShardGenConfig::new("papers100m-mini", scale, edges, ranks, seed),
        &dir,
    )?;

    let mut cfg = TrainConfig::default();
    cfg.preset = "papers100m-mini".into();
    cfg.ranks = ranks;
    cfg.seed = seed;
    cfg.epochs = epochs;
    cfg.max_minibatches = max_mb.or(Some(4));
    cfg.data_shards = dir.to_string_lossy().to_string();

    let mut copied_cfg = cfg.clone();
    copied_cfg.data_shards_mmap = false;
    let copied = run(copied_cfg)?;

    // time the cold-ish page walk over every payload, then the mapped run
    let set = graph_io::ShardSet::open(&dir)?;
    let mut stall_s = 0.0f64;
    for r in 0..set.k() {
        let shard = set.open_shard(r, ShardVerify::Header)?;
        stall_s += mmap::touch_pages(shard.payload_bytes()).1;
    }
    let mapped_before = mmap::bytes_mapped_total();
    cfg.data_shards_mmap = true;
    let mapped = run(cfg)?;
    let bytes_mapped = mmap::bytes_mapped_total() - mapped_before;

    let ls = |rep: &distgnn_mb::train::metrics::RunReport| -> Vec<f64> {
        rep.epochs.iter().map(|e| e.train_loss).collect()
    };
    let bit_identical = ls(&copied) == ls(&mapped);
    anyhow::ensure!(
        bit_identical,
        "shard residency changed SAGE losses: copied {:?} vs mapped {:?}",
        ls(&copied),
        ls(&mapped)
    );
    print_table(
        &format!(
            "Fig. 3 cell — GraphSAGE out-of-core, rmat 2^{scale} shards ({ranks} ranks)"
        ),
        &["residency", "epoch(s)", "final loss"],
        &[
            vec![
                "heap-copied".into(),
                fmt_s(copied.mean_epoch_time(1)),
                format!("{:.6}", ls(&copied).last().unwrap()),
            ],
            vec![
                "mmapped".into(),
                fmt_s(mapped.mean_epoch_time(1)),
                format!("{:.6}", ls(&mapped).last().unwrap()),
            ],
        ],
    );

    write_bench_section(
        "fig3_shard_cell",
        vec![
            ("preset", json::s("papers100m-mini")),
            ("ranks", json::num(ranks as f64)),
            ("scale", json::num(scale as f64)),
            ("edge_draws", json::num(edges as f64)),
            ("directed_edges", json::num(stats.directed_edges as f64)),
            ("shard_bytes_written", json::num(stats.bytes_written as f64)),
            ("epoch_s_copied", json::num(copied.mean_epoch_time(1))),
            ("epoch_s_mapped", json::num(mapped.mean_epoch_time(1))),
            ("bytes_mapped", json::num(bytes_mapped as f64)),
            ("page_fault_stall_s", json::num(stall_s)),
            (
                "peak_rss_bytes",
                mmap::peak_rss_bytes()
                    .map(|b| json::num(b as f64))
                    .unwrap_or(Value::Null),
            ),
            ("losses_bit_identical", Value::Bool(bit_identical)),
        ],
    )?;
    let _ = std::fs::remove_dir_all(&dir);

    println!("\nshape checks vs paper: epoch time monotone down, speedup grows with ranks,");
    println!("FWD share grows at scale (comm pre/post-processing), MBC/BWD shrink ~linearly;");
    println!("the out-of-core cell is loss-bit-identical across residencies by construction.");
    Ok(())
}
