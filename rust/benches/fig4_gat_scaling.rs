//! Figure 4: GAT epoch time and relative speedup vs ranks, on the native
//! executor (edge-softmax attention forward + backward).
//!
//! Paper shape: BWD dominates GAT epoch time; best epoch 4.9s at 64 ranks
//! (papers100M) with 17.2x speedup vs 4 ranks; MBC and BWD scale linearly,
//! FWD at 74% and ARed at 85% efficiency.
//!
//! Besides the table, the bench writes a `gat_scaling` section into the
//! benchkit report (`BENCH_pipeline.json` by default): per preset and
//! rank count the steady-state epoch ms, comm bytes, speedup, and the
//! per-layer attention-phase seconds drained from the native executor's
//! counters, normalized to per-epoch (the raw counters span all epochs,
//! calibration and eval, summed over every simulated rank) — so GAT
//! kernel perf is tracked across PRs like the SAGE baseline
//! (`bf16_kernels.bf16_speedup_vs_f32_scalar`).

use distgnn_mb::benchkit::{fmt_s, fmt_x, print_table, run, write_bench_section};
use distgnn_mb::config::{ModelKind, TrainConfig};
use distgnn_mb::runtime::builtin::builtin_manifest;
use distgnn_mb::runtime::native::take_gat_attention_secs;
use distgnn_mb::util::json::{self, Value};

fn main() -> anyhow::Result<()> {
    let rank_counts: Vec<usize> = std::env::var("DISTGNN_RANKS")
        .map(|v| v.split(',').filter_map(|x| x.parse().ok()).collect())
        .unwrap_or_else(|_| vec![2, 4, 8, 16, 32]);
    let epochs: usize = std::env::var("DISTGNN_EPOCHS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);
    // strong scaling needs full epochs: per-rank minibatch counts must
    // shrink as ranks grow. DISTGNN_MAX_MB caps them for quick runs.
    let max_mb: Option<usize> = std::env::var("DISTGNN_MAX_MB")
        .ok()
        .and_then(|v| v.parse().ok());

    let mut all_sections: Vec<(String, Value)> = Vec::new();
    for preset in ["products-mini", "papers100m-mini"] {
        // layer count from the program meta (not hardcoded)
        let n_layers = builtin_manifest()
            .program(&format!("gat_train_{preset}"))?
            .meta
            .get("fanouts")
            .and_then(|v| v.as_arr())
            .map(|a| a.len())
            .unwrap_or(3);
        let mut rows = Vec::new();
        let mut section: Vec<(String, Value)> = Vec::new();
        let mut base_time = None;
        for &ranks in &rank_counts {
            let mut cfg = TrainConfig::default();
            cfg.preset = preset.into();
            cfg.model = ModelKind::Gat;
            cfg.lr = 1e-3;
            cfg.ranks = ranks;
            cfg.epochs = epochs;
            cfg.max_minibatches = max_mb;
            // drain *every* profile slot so no residue can leak between
            // rank-count runs even if a preset grows more layers
            let _ = take_gat_attention_secs(usize::MAX);
            let report = run(cfg)?;
            // normalize the drained total to per-epoch seconds so the
            // tracked metric is comparable across runs with different
            // DISTGNN_EPOCHS (the total spans all epochs, the warmup
            // epoch, Driver::new calibration and eval passes, summed
            // over every simulated rank)
            let epochs_run = report.epochs.len().max(1) as f64;
            let attn: Vec<f64> = take_gat_attention_secs(n_layers)
                .into_iter()
                .map(|s| s / epochs_run)
                .collect();
            let t = report.mean_epoch_time(1);
            let c = report.mean_comps(1);
            let comm = report.epochs.last().map(|e| e.comm_bytes).unwrap_or(0);
            if base_time.is_none() {
                base_time = Some(t);
            }
            let speedup = base_time.unwrap() / t;
            rows.push(vec![
                ranks.to_string(),
                fmt_s(t),
                fmt_s(c.mbc),
                fmt_s(c.fwd),
                fmt_s(c.bwd),
                fmt_s(c.ared),
                fmt_s(attn.iter().sum::<f64>()),
                fmt_x(speedup),
                format!("{:.2}", report.epochs.last().unwrap().load_imbalance),
            ]);
            section.push((
                format!("ranks_{ranks}"),
                json::obj(vec![
                    ("epoch_ms", json::num(t * 1e3)),
                    ("comm_bytes", json::num(comm as f64)),
                    ("speedup", json::num(speedup)),
                    (
                        "attention_secs_per_layer_per_epoch",
                        json::arr(attn.iter().map(|&s| json::num(s)).collect()),
                    ),
                ]),
            ));
        }
        print_table(
            &format!("Fig. 4 — GAT scaling on {preset} (epoch seconds, virtual cluster)"),
            &["ranks", "epoch", "MBC", "FWD", "BWD", "ARed", "attn", "speedup", "imb"],
            &rows,
        );
        let preset_obj: Vec<(&str, Value)> =
            section.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
        all_sections.push((preset.to_string(), json::obj(preset_obj)));
    }
    let entries: Vec<(&str, Value)> = all_sections
        .iter()
        .map(|(k, v)| (k.as_str(), v.clone()))
        .collect();
    write_bench_section("gat_scaling", entries)?;
    println!("\nshape check vs paper: BWD dominates GAT epoch time at low rank counts;");
    println!("FWD (comm pre/post-processing) share grows with scale.");
    Ok(())
}
