//! Figure 4: GAT epoch time and relative speedup vs ranks.
//!
//! Paper shape: BWD dominates GAT epoch time; best epoch 4.9s at 64 ranks
//! (papers100M) with 17.2x speedup vs 4 ranks; MBC and BWD scale linearly,
//! FWD at 74% and ARed at 85% efficiency.

use distgnn_mb::benchkit::{fmt_s, fmt_x, print_table, run};
use distgnn_mb::config::{ModelKind, TrainConfig};

fn main() -> anyhow::Result<()> {
    let rank_counts: Vec<usize> = std::env::var("DISTGNN_RANKS")
        .map(|v| v.split(',').filter_map(|x| x.parse().ok()).collect())
        .unwrap_or_else(|_| vec![2, 4, 8, 16, 32]);
    let epochs: usize = std::env::var("DISTGNN_EPOCHS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);
    // strong scaling needs full epochs: per-rank minibatch counts must
    // shrink as ranks grow. DISTGNN_MAX_MB caps them for quick runs.
    let max_mb: Option<usize> = std::env::var("DISTGNN_MAX_MB")
        .ok()
        .and_then(|v| v.parse().ok());

    for preset in ["products-mini", "papers100m-mini"] {
        let mut rows = Vec::new();
        let mut base_time = None;
        for &ranks in &rank_counts {
            let mut cfg = TrainConfig::default();
            cfg.preset = preset.into();
            cfg.model = ModelKind::Gat;
            cfg.lr = 1e-3;
            cfg.ranks = ranks;
            cfg.epochs = epochs;
            cfg.max_minibatches = max_mb;
            let report = run(cfg)?;
            let t = report.mean_epoch_time(1);
            let c = report.mean_comps(1);
            if base_time.is_none() {
                base_time = Some(t);
            }
            rows.push(vec![
                ranks.to_string(),
                fmt_s(t),
                fmt_s(c.mbc),
                fmt_s(c.fwd),
                fmt_s(c.bwd),
                fmt_s(c.ared),
                fmt_x(base_time.unwrap() / t),
                format!("{:.2}", report.epochs.last().unwrap().load_imbalance),
            ]);
        }
        print_table(
            &format!("Fig. 4 — GAT scaling on {preset} (epoch seconds, virtual cluster)"),
            &["ranks", "epoch", "MBC", "FWD", "BWD", "ARed", "speedup", "imb"],
            &rows,
        );
    }
    println!("\nshape check vs paper: BWD dominates GAT epoch time at low rank counts;");
    println!("FWD (comm pre/post-processing) share grows with scale.");
    Ok(())
}
