//! Figure 5: DistGNN-MB vs DistDGL, GraphSAGE on papers100m-mini.
//!
//! Paper shape: DistGNN-MB consistently faster from 8-64 ranks, reaching
//! 5.2x per epoch at 64 ranks. The gap comes from (a) DistDGL's blocking
//! per-hop sampling RPCs and synchronous feature fetches on the critical
//! path vs AEP's delay-d overlapped pushes, and (b) the KVStore RPC stack
//! latency vs MPI (DESIGN.md §5).

use distgnn_mb::benchkit::{fmt_s, fmt_x, print_table, run};
use distgnn_mb::config::{TrainConfig, TrainMode};

fn main() -> anyhow::Result<()> {
    let rank_counts: Vec<usize> = std::env::var("DISTGNN_RANKS")
        .map(|v| v.split(',').filter_map(|x| x.parse().ok()).collect())
        .unwrap_or_else(|_| vec![8, 16, 32]);
    let epochs: usize = std::env::var("DISTGNN_EPOCHS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);
    // strong scaling needs full epochs (see fig3); cap only for quick runs.
    let max_mb: Option<usize> = std::env::var("DISTGNN_MAX_MB")
        .ok()
        .and_then(|v| v.parse().ok());

    let mut rows = Vec::new();
    for &ranks in &rank_counts {
        let mut times = Vec::new();
        let mut bytes = Vec::new();
        for mode in [TrainMode::Aep, TrainMode::DistDgl] {
            let mut cfg = TrainConfig::default();
            cfg.preset = "papers100m-mini".into();
            cfg.ranks = ranks;
            cfg.epochs = epochs;
            cfg.mode = mode;
            cfg.max_minibatches = max_mb;
            let report = run(cfg)?;
            times.push(report.mean_epoch_time(1));
            bytes.push(
                report.epochs.iter().skip(1).map(|e| e.comm_bytes).sum::<u64>()
                    / (epochs.max(2) as u64 - 1),
            );
        }
        rows.push(vec![
            ranks.to_string(),
            fmt_s(times[0]),
            fmt_s(times[1]),
            fmt_x(times[1] / times[0]),
            format!("{:.1}MB", bytes[0] as f64 / 1e6),
            format!("{:.1}MB", bytes[1] as f64 / 1e6),
        ]);
    }
    print_table(
        "Fig. 5 — GraphSAGE on papers100m-mini: DistGNN-MB (AEP) vs DistDGL",
        &[
            "ranks",
            "aep epoch",
            "distdgl epoch",
            "speedup",
            "aep comm/ep",
            "distdgl comm/ep",
        ],
        &rows,
    );
    println!("\nshape check vs paper: DistGNN-MB faster at every scale; gap widens with ranks");
    println!("(paper: 5.2x at 64 ranks).");
    Ok(())
}
