//! HEC parameter ablation (§4.4 parameter settings + our extension).
//!
//! Sweeps the four HEC knobs — delay d, life-span ls, push threshold nc,
//! cache size cs — on products-mini and reports epoch time, per-layer hit
//! rates, AEP traffic and accuracy after a fixed budget. Also includes the
//! NoComm lower bound (drop all halos) to isolate the accuracy value of
//! historical embeddings, and an f32-vs-bf16 storage comparison (cache +
//! push GB moved, loss drift) for the `--dtype bf16` path. The lookahead
//! prefetch sweep (on/off × pipeline depth × delay d) verifies losses are
//! bit-identical with prefetch on while the effective L0 hit rate rises
//! and the modeled stall seconds fall.

use distgnn_mb::benchkit::{fmt_pct, fmt_s, print_table, run, write_bench_section};
use distgnn_mb::config::{DtypeKind, TrainConfig, TrainMode};
use distgnn_mb::util::json;

fn base() -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.preset = "products-mini".into();
    cfg.ranks = 4;
    cfg.epochs = std::env::var("DISTGNN_EPOCHS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);
    cfg.max_minibatches = Some(
        std::env::var("DISTGNN_MAX_MB")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(6),
    );
    cfg.eval_every = cfg.epochs;
    cfg
}

fn row(label: &str, cfg: TrainConfig) -> anyhow::Result<Vec<String>> {
    let report = run(cfg)?;
    let t = report.mean_epoch_time(1);
    let last = report.epochs.last().unwrap();
    Ok(vec![
        label.to_string(),
        fmt_s(t),
        last.hec_hit_rates
            .iter()
            .map(|h| format!("{:.0}", h * 100.0))
            .collect::<Vec<_>>()
            .join("/"),
        format!("{:.1}MB", last.comm_bytes as f64 / 1e6),
        report
            .final_test_acc
            .map(|a| fmt_pct(a))
            .unwrap_or_else(|| "-".into()),
    ])
}

fn main() -> anyhow::Result<()> {
    let headers = ["variant", "epoch(s)", "hec% L0/L1/L2", "comm/ep", "test acc"];

    // delay d (the phased driver defines d >= 1; 0 would alias 1)
    let mut rows = Vec::new();
    for d in [1usize, 2, 4, 8] {
        let mut cfg = base();
        cfg.hec.d = d;
        rows.push(row(&format!("d={d}"), cfg)?);
    }
    print_table("HEC ablation — communication delay d", &headers, &rows);

    // life span ls
    let mut rows = Vec::new();
    for ls in [1u32, 2, 4, 8] {
        let mut cfg = base();
        cfg.hec.ls = ls;
        rows.push(row(&format!("ls={ls}"), cfg)?);
    }
    print_table("HEC ablation — cache-line life-span ls", &headers, &rows);

    // push threshold nc
    let mut rows = Vec::new();
    for nc in [32usize, 128, 256, 1024] {
        let mut cfg = base();
        cfg.hec.nc = nc;
        rows.push(row(&format!("nc={nc}"), cfg)?);
    }
    print_table("HEC ablation — push threshold nc", &headers, &rows);

    // cache size cs
    let mut rows = Vec::new();
    for cs in [1024usize, 8192, 65536] {
        let mut cfg = base();
        cfg.hec.cs = cs;
        rows.push(row(&format!("cs={cs}"), cfg)?);
    }
    print_table("HEC ablation — cache size cs", &headers, &rows);

    // HEC value: AEP vs NoComm. Random partitioning maximizes the edge
    // cut so most aggregation signal crosses ranks — the regime HEC is
    // for; with a min-cut partition at mini scale halos barely matter.
    let mut rows = Vec::new();
    let stress = || {
        let mut cfg = base();
        cfg.partitioner = "random".into();
        cfg.ranks = 8;
        cfg.epochs = 4;
        cfg.max_minibatches = Some(10);
        cfg.eval_every = 4;
        cfg
    };
    rows.push(row("aep (HEC on)", stress())?);
    let mut cfg = stress();
    cfg.mode = TrainMode::NoComm;
    rows.push(row("nocomm (halos dropped)", cfg)?);
    print_table("HEC value — accuracy vs dropping halos", &headers, &rows);

    // ---- overlapped pipeline vs serial execution --------------------------
    // Same seed, same minibatches, same losses (the pipeline moves *when*
    // work runs, not *what* runs); only the simulated AEP epoch time and
    // the hidden-MBC share differ.
    let mut pipe_cfg = base();
    pipe_cfg.pipeline = true;
    let mut serial_cfg = base();
    serial_cfg.pipeline = false;
    let rep_pipe = run(pipe_cfg)?;
    let rep_serial = run(serial_cfg)?;
    let t_pipe = rep_pipe.mean_epoch_time(1);
    let t_serial = rep_serial.mean_epoch_time(1);
    let last = rep_pipe.epochs.last().unwrap();
    let mbc_total = last.comps.mbc + last.mbc_hidden;
    let mbc_hidden_frac = if mbc_total > 0.0 {
        last.mbc_hidden / mbc_total
    } else {
        0.0
    };
    let aep_overlap_eff = if last.aep_flight > 0.0 {
        1.0 - last.aep_wait / last.aep_flight
    } else {
        1.0
    };
    let losses_match = rep_pipe
        .epochs
        .iter()
        .zip(&rep_serial.epochs)
        .all(|(a, b)| a.train_loss == b.train_loss);
    print_table(
        "pipeline — overlapped vs serial iteration loop",
        &["variant", "epoch(s)", "mbc hidden", "aep overlap", "losses =="],
        &[
            vec![
                "pipelined".into(),
                fmt_s(t_pipe),
                fmt_pct(mbc_hidden_frac),
                fmt_pct(aep_overlap_eff),
                losses_match.to_string(),
            ],
            vec![
                "serial (DISTGNN_PIPELINE=0)".into(),
                fmt_s(t_serial),
                "0%".into(),
                "-".into(),
                losses_match.to_string(),
            ],
        ],
    );

    write_bench_section(
        "hec_ablation_pipeline",
        vec![
            ("epoch_s_pipelined", json::num(t_pipe)),
            ("epoch_s_serial", json::num(t_serial)),
            ("pipeline_speedup", json::num(t_serial / t_pipe.max(1e-12))),
            ("mbc_hidden_fraction", json::num(mbc_hidden_frac)),
            ("aep_overlap_efficiency", json::num(aep_overlap_eff)),
            (
                "losses_bit_identical",
                distgnn_mb::util::json::Value::Bool(losses_match),
            ),
        ],
    )?;

    // ---- lookahead prefetch: on/off × pipeline depth p × AEP delay d ------
    // Prefetch is an accounting side-car (losses MUST be bit-identical on
    // or off at every combination); what it buys is the *effective* L0 hit
    // rate — misses whose rows arrived before the packer's read — and the
    // matching drop in modeled stall seconds. Random partitioning
    // maximizes the cut so level-0 misses actually occur.
    let mut rows = Vec::new();
    let mut combos = Vec::new();
    let mut all_identical = true;
    for p in [1usize, 2, 4, 8] {
        for d in [1usize, 2, 4] {
            let mk = |prefetch: bool| {
                let mut cfg = base();
                cfg.partitioner = "random".into();
                cfg.pipeline = true;
                cfg.pipeline_depth = p;
                cfg.hec.d = d;
                cfg.hec.prefetch = prefetch;
                cfg
            };
            let rep_on = run(mk(true))?;
            let rep_off = run(mk(false))?;
            let identical = rep_on.epochs.len() == rep_off.epochs.len()
                && rep_on
                    .epochs
                    .iter()
                    .zip(&rep_off.epochs)
                    .all(|(a, b)| a.train_loss == b.train_loss);
            all_identical &= identical;
            let on = rep_on.epochs.last().unwrap();
            let off = rep_off.epochs.last().unwrap();
            rows.push(vec![
                format!("p={p} d={d}"),
                fmt_pct(off.effective_l0_hit_rate()),
                fmt_pct(on.effective_l0_hit_rate()),
                fmt_pct(on.prefetch_coverage()),
                fmt_s(off.hec_stall_secs),
                fmt_s(on.hec_stall_secs),
                identical.to_string(),
            ]);
            combos.push(json::obj(vec![
                ("p", json::num(p as f64)),
                ("d", json::num(d as f64)),
                ("eff_hit_l0_off", json::num(off.effective_l0_hit_rate())),
                ("eff_hit_l0_on", json::num(on.effective_l0_hit_rate())),
                ("prefetch_coverage", json::num(on.prefetch_coverage())),
                ("prefetch_issued", json::num(on.prefetch_issued as f64)),
                ("prefetch_landed", json::num(on.prefetch_landed as f64)),
                ("prefetch_late", json::num(on.prefetch_late as f64)),
                ("prefetch_wasted", json::num(on.prefetch_wasted as f64)),
                ("stall_s_off", json::num(off.hec_stall_secs)),
                ("stall_s_on", json::num(on.hec_stall_secs)),
                (
                    "stall_s_saved",
                    json::num(off.hec_stall_secs - on.hec_stall_secs),
                ),
                (
                    "losses_bit_identical",
                    distgnn_mb::util::json::Value::Bool(identical),
                ),
            ]));
        }
    }
    print_table(
        "HEC lookahead prefetch — effective L0 hit rate and modeled stall",
        &[
            "variant",
            "eff hit (off)",
            "eff hit (on)",
            "coverage",
            "stall off",
            "stall on",
            "losses ==",
        ],
        &rows,
    );
    write_bench_section(
        "hec_ablation",
        vec![
            ("combos", json::arr(combos)),
            (
                "all_losses_bit_identical",
                distgnn_mb::util::json::Value::Bool(all_identical),
            ),
        ],
    )?;

    // ---- storage dtype: f32 vs bf16 (HEC lines + AEP push payloads) -------
    // Same seed and schedule; only feature/embedding *storage* differs, so
    // comm GB halves (minus the 4-byte-per-vid overhead) while the loss
    // drifts by at most one bf16 rounding per stored row.
    let run_dtype = |dtype: DtypeKind| -> anyhow::Result<(f64, f64, f64)> {
        let mut cfg = base();
        cfg.partitioner = "random".into(); // maximize cut => real AEP traffic
        cfg.dtype = dtype;
        let rep = run(cfg)?;
        let last = rep.epochs.last().unwrap();
        Ok((
            rep.mean_epoch_time(1),
            last.comm_bytes as f64,
            last.train_loss,
        ))
    };
    let (t_f32, bytes_f32, loss_f32) = run_dtype(DtypeKind::F32)?;
    let (t_b16, bytes_b16, loss_b16) = run_dtype(DtypeKind::Bf16)?;
    print_table(
        "HEC storage dtype — f32 vs bf16 (random partition)",
        &["dtype", "epoch(s)", "comm/ep", "final loss"],
        &[
            vec![
                "f32".into(),
                fmt_s(t_f32),
                format!("{:.2}MB", bytes_f32 / 1e6),
                format!("{loss_f32:.4}"),
            ],
            vec![
                "bf16".into(),
                fmt_s(t_b16),
                format!("{:.2}MB", bytes_b16 / 1e6),
                format!("{loss_b16:.4}"),
            ],
        ],
    );
    write_bench_section(
        "hec_bf16",
        vec![
            ("epoch_s_f32", json::num(t_f32)),
            ("epoch_s_bf16", json::num(t_b16)),
            ("comm_gb_f32", json::num(bytes_f32 / 1e9)),
            ("comm_gb_bf16", json::num(bytes_b16 / 1e9)),
            (
                "comm_bytes_ratio",
                json::num(bytes_b16 / bytes_f32.max(1.0)),
            ),
            ("final_loss_f32", json::num(loss_f32)),
            ("final_loss_bf16", json::num(loss_b16)),
            ("loss_gap", json::num((loss_f32 - loss_b16).abs())),
        ],
    )?;

    println!("\nexpected shapes: hit rate rises with ls and cs, falls with d;");
    println!("traffic rises with nc; accuracy: aep >= nocomm; pipelined epoch");
    println!("time <= serial with identical losses; bf16 comm ~= half of f32");
    println!("with final loss within the documented tolerance (README);");
    println!("prefetch: losses bit-identical on/off at every (p, d), effective");
    println!("L0 hit rate higher and stall seconds lower with prefetch on at p>=2.");
    Ok(())
}
