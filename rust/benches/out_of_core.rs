//! Out-of-core shard residency: what mapping the training data costs and
//! what it saves, with the bit-identity contract asserted per cell.
//!
//! Two cells, each trained three ways where a reference exists:
//!
//! * **preset cell** — the `products-mini` preset partitioned and written
//!   as a shard set; trained (a) fully in RAM from the materialized
//!   partitions, (b) from shards copied to heap (`--shards-mmap off`),
//!   (c) from mmapped shards. All three loss curves must be
//!   **bit-identical** — residency changes *where* bytes live, never
//!   *what* the packer reads.
//! * **papers100M-class cell** — a synthetic R-MAT shard set written
//!   directly by the streaming generator (`papers100m-mini` shapes; the
//!   graph never exists in RAM, so the in-RAM arm does not apply).
//!   Copied vs mapped must still be bit-identical.
//!
//! Per cell the bench records the out-of-core counters: cumulative bytes
//! mapped, page-fault stall seconds (timed cold page-touch over every
//! shard payload), minor/major fault deltas across the mapped run, peak
//! RSS, and steady-state epoch seconds for each residency.
//!
//! Scale knobs: `DISTGNN_OOC_SCALE` / `DISTGNN_OOC_EDGES` size the
//! synthetic graph (defaults are CI-sized; scale 27 with 10⁹ edge draws
//! is the paper-class setting), `DISTGNN_OOC_RANKS`, `DISTGNN_EPOCHS`,
//! `DISTGNN_MAX_MB` shape the runs. Section `out_of_core`; default
//! output `BENCH_pipeline.json`.

use std::path::Path;

use distgnn_mb::benchkit::{fmt_gb, fmt_s, print_table, run, write_bench_section};
use distgnn_mb::config::TrainConfig;
use distgnn_mb::graph::generator::{generate_rmat_shards, ShardGenConfig};
use distgnn_mb::graph::io::{self as graph_io, ShardVerify};
use distgnn_mb::graph::DatasetPreset;
use distgnn_mb::partition::metis_like::MetisLikePartitioner;
use distgnn_mb::partition::{write_shards, Partitioner};
use distgnn_mb::util::json::{self, Value};
use distgnn_mb::util::mmap;

const SEED: u64 = 42;

fn env_or(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn base_cfg(preset: &str, ranks: usize, cache: &Path) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.preset = preset.into();
    cfg.partitioner = "metis-like".into();
    cfg.ranks = ranks;
    cfg.seed = SEED;
    cfg.epochs = env_or("DISTGNN_EPOCHS", 2) as usize;
    cfg.max_minibatches = Some(env_or("DISTGNN_MAX_MB", 4) as usize);
    cfg.data_cache = cache.to_string_lossy().to_string();
    cfg
}

fn losses_and_epoch_s(cfg: TrainConfig) -> anyhow::Result<(Vec<f64>, f64)> {
    let rep = run(cfg)?;
    let losses = rep.epochs.iter().map(|e| e.train_loss).collect();
    Ok((losses, rep.mean_epoch_time(1)))
}

/// Touch every payload page of every shard in `dir` through a fresh
/// mapping and time it: on a cold cache this is pure fault stall, warm
/// it measures the page-walk floor.
fn fault_stall(dir: &Path) -> anyhow::Result<(u64, f64)> {
    let set = graph_io::ShardSet::open(dir)?;
    let mut bytes = 0u64;
    let mut secs = 0.0f64;
    for r in 0..set.k() {
        let shard = set.open_shard(r, ShardVerify::Header)?;
        let (b, s) = mmap::touch_pages(shard.payload_bytes());
        bytes += b;
        secs += s;
    }
    Ok((bytes, secs))
}

struct CellReport {
    name: &'static str,
    epoch_s_ram: Option<f64>,
    epoch_s_copied: f64,
    epoch_s_mapped: f64,
    bytes_mapped: u64,
    stall_bytes: u64,
    stall_s: f64,
    minor_faults: u64,
    major_faults: u64,
    peak_rss: Option<u64>,
    bit_identical: bool,
}

/// Train `cfg` through the shard set twice (heap-copied, then mmapped),
/// optionally against an in-RAM reference, and assert every loss curve
/// is bit-identical before reporting the residency counters.
fn measure_cell(
    name: &'static str,
    cfg: TrainConfig,
    shards: &Path,
    ram_reference: bool,
) -> anyhow::Result<CellReport> {
    let shards_str = shards.to_string_lossy().to_string();
    let with_shards = |mapped: bool| {
        let mut c = cfg.clone();
        c.data_shards = shards_str.clone();
        c.data_shards_mmap = mapped;
        c
    };

    let ram = if ram_reference {
        Some(losses_and_epoch_s(cfg.clone())?)
    } else {
        None
    };
    let (copied_losses, epoch_s_copied) = losses_and_epoch_s(with_shards(false))?;

    let (stall_bytes, stall_s) = fault_stall(shards)?;
    let mapped_before = mmap::bytes_mapped_total();
    let faults_before = mmap::page_fault_counts();
    let (mapped_losses, epoch_s_mapped) = losses_and_epoch_s(with_shards(true))?;
    let bytes_mapped = mmap::bytes_mapped_total() - mapped_before;
    let (minor_faults, major_faults) = match (faults_before, mmap::page_fault_counts()) {
        (Some((min0, maj0)), Some((min1, maj1))) => (min1 - min0, maj1 - maj0),
        _ => (0, 0),
    };

    let bit_identical = copied_losses == mapped_losses
        && ram.as_ref().map_or(true, |(l, _)| *l == mapped_losses);
    anyhow::ensure!(
        bit_identical,
        "{name}: shard residency changed the losses (ram={:?} copied={copied_losses:?} mapped={mapped_losses:?})",
        ram.as_ref().map(|(l, _)| l)
    );
    anyhow::ensure!(
        mapped_losses.iter().all(|l| l.is_finite()),
        "{name}: non-finite losses"
    );

    Ok(CellReport {
        name,
        epoch_s_ram: ram.map(|(_, t)| t),
        epoch_s_copied,
        epoch_s_mapped,
        bytes_mapped,
        stall_bytes,
        stall_s,
        minor_faults,
        major_faults,
        peak_rss: mmap::peak_rss_bytes(),
        bit_identical,
    })
}

fn cell_json(c: &CellReport) -> Value {
    json::obj(vec![
        ("cell", json::s(c.name)),
        (
            "epoch_s_ram",
            c.epoch_s_ram.map(json::num).unwrap_or(Value::Null),
        ),
        ("epoch_s_copied", json::num(c.epoch_s_copied)),
        ("epoch_s_mapped", json::num(c.epoch_s_mapped)),
        ("bytes_mapped", json::num(c.bytes_mapped as f64)),
        ("page_touch_bytes", json::num(c.stall_bytes as f64)),
        ("page_fault_stall_s", json::num(c.stall_s)),
        ("minor_faults", json::num(c.minor_faults as f64)),
        ("major_faults", json::num(c.major_faults as f64)),
        (
            "peak_rss_bytes",
            c.peak_rss.map(|b| json::num(b as f64)).unwrap_or(Value::Null),
        ),
        ("losses_bit_identical", Value::Bool(c.bit_identical)),
    ])
}

fn main() -> anyhow::Result<()> {
    println!("### bench: out_of_core");
    let root = std::env::temp_dir().join(format!("distgnn-oocbench-{}", std::process::id()));
    let cache = root.join("cache");
    std::fs::create_dir_all(&root)?;

    let ranks = env_or("DISTGNN_OOC_RANKS", 4) as usize;
    let scale = env_or("DISTGNN_OOC_SCALE", 13) as u32;
    let edges = env_or("DISTGNN_OOC_EDGES", 12u64 << scale);

    // ---- preset cell: in-RAM reference exists --------------------------
    let preset_dir = root.join("shards-preset");
    let preset = DatasetPreset::by_name("products-mini")?;
    let ds = graph_io::load_or_generate(&preset, &cache)?;
    let assignment =
        MetisLikePartitioner::default().partition(&ds.graph, &ds.train_vertices, ranks, SEED);
    write_shards(&ds, &assignment, &preset_dir, "products-mini", "metis-like", SEED)?;
    drop(ds);
    let preset_cell = measure_cell(
        "products-mini-preset",
        base_cfg("products-mini", ranks, &cache),
        &preset_dir,
        true,
    )?;

    // ---- papers100M-class cell: the graph only ever exists as shards ---
    let synth_dir = root.join("shards-synth");
    let gen_cfg = ShardGenConfig::new("papers100m-mini", scale, edges, ranks, SEED);
    let sw = std::time::Instant::now();
    let stats = generate_rmat_shards(&gen_cfg, &synth_dir)?;
    let gen_s = sw.elapsed().as_secs_f64();
    println!(
        "generated 2^{scale} vertices, {} directed edges, {} from {edges} draws in {gen_s:.2}s",
        stats.directed_edges,
        fmt_gb(stats.bytes_written as f64),
    );
    let synth_cell = measure_cell(
        "papers100m-class-rmat",
        base_cfg("papers100m-mini", ranks, &cache),
        &synth_dir,
        false,
    )?;

    let cells = [preset_cell, synth_cell];
    let rows: Vec<Vec<String>> = cells
        .iter()
        .map(|c| {
            vec![
                c.name.to_string(),
                c.epoch_s_ram.map(fmt_s).unwrap_or_else(|| "-".into()),
                fmt_s(c.epoch_s_copied),
                fmt_s(c.epoch_s_mapped),
                fmt_gb(c.bytes_mapped as f64),
                format!("{:.4}", c.stall_s),
                format!("{}/{}", c.minor_faults, c.major_faults),
                c.peak_rss
                    .map(|b| fmt_gb(b as f64))
                    .unwrap_or_else(|| "-".into()),
                c.bit_identical.to_string(),
            ]
        })
        .collect();
    print_table(
        &format!("out-of-core residency ({ranks} ranks, seed {SEED})"),
        &[
            "cell", "epoch ram(s)", "epoch copy(s)", "epoch mmap(s)", "mapped", "stall(s)",
            "flt mn/mj", "peak rss", "bit-identical",
        ],
        &rows,
    );

    write_bench_section(
        "out_of_core",
        vec![
            ("ranks", json::num(ranks as f64)),
            ("scale", json::num(scale as f64)),
            ("edge_draws", json::num(edges as f64)),
            ("directed_edges", json::num(stats.directed_edges as f64)),
            ("shard_bytes_written", json::num(stats.bytes_written as f64)),
            ("generate_s", json::num(gen_s)),
            ("cells", json::arr(cells.iter().map(cell_json).collect())),
        ],
    )?;

    let _ = std::fs::remove_dir_all(&root);
    println!("\nexpected shapes: all cells bit-identical by construction (the");
    println!("assert, not the table, is the contract); mmap epochs track the");
    println!("copied epochs once pages are warm; peak RSS for the synthetic cell");
    println!("stays bounded by minibatch working sets, not by shard bytes.");
    Ok(())
}
