//! Partitioner quality ablation (§3.1): metis-like vs LDG vs random/hash,
//! across rank counts — edge-cut, balance, halo counts, partition time,
//! and the downstream effect on epoch time + HEC hit rate.

use distgnn_mb::benchkit::{fmt_s, print_table, run};
use distgnn_mb::config::TrainConfig;
use distgnn_mb::graph::{io as graph_io, DatasetPreset};
use distgnn_mb::partition::{
    ldg::LdgPartitioner, metis_like::MetisLikePartitioner, random::RandomPartitioner,
    Partitioner, PartitionStats,
};

fn main() -> anyhow::Result<()> {
    let preset = DatasetPreset::by_name("products-mini")?;
    let ds = graph_io::load_or_generate(&preset, "data-cache")?;
    let partitioners: Vec<Box<dyn Partitioner>> = vec![
        Box::new(MetisLikePartitioner::default()),
        Box::new(LdgPartitioner),
        Box::new(RandomPartitioner),
    ];

    // static quality
    let mut rows = Vec::new();
    for k in [4usize, 8, 16] {
        for p in &partitioners {
            let t0 = std::time::Instant::now();
            let a = p.partition(&ds.graph, &ds.train_vertices, k, 42);
            let dt = t0.elapsed().as_secs_f64();
            let s = PartitionStats::compute(&ds.graph, &ds.train_vertices, &a);
            rows.push(vec![
                format!("{}/k={k}", p.name()),
                format!("{:.3}", s.edge_cut_fraction),
                format!("{:.3}", s.vertex_imbalance),
                format!("{:.3}", s.train_imbalance),
                format!(
                    "{:.0}",
                    s.halo_counts.iter().sum::<usize>() as f64 / k as f64
                ),
                fmt_s(dt),
            ]);
        }
    }
    print_table(
        "partitioner quality on products-mini",
        &["partitioner", "edge-cut", "v-imb", "t-imb", "halos/rank", "part(s)"],
        &rows,
    );

    // downstream training effect
    let epochs: usize = std::env::var("DISTGNN_EPOCHS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2);
    let mut rows = Vec::new();
    for name in ["metis-like", "ldg", "random"] {
        let mut cfg = TrainConfig::default();
        cfg.preset = "products-mini".into();
        cfg.ranks = 8;
        cfg.epochs = epochs;
        cfg.max_minibatches = Some(4);
        cfg.partitioner = name.into();
        let report = run(cfg)?;
        let last = report.epochs.last().unwrap();
        rows.push(vec![
            name.into(),
            fmt_s(report.mean_epoch_time(1)),
            last.hec_hit_rates
                .iter()
                .map(|h| format!("{:.0}", h * 100.0))
                .collect::<Vec<_>>()
                .join("/"),
            format!("{:.1}MB", last.comm_bytes as f64 / 1e6),
        ]);
    }
    print_table(
        "downstream effect (8 ranks, GraphSAGE)",
        &["partitioner", "epoch(s)", "hec% L0/L1/L2", "comm/ep"],
        &rows,
    );
    println!("\nexpected shape: metis-like < ldg < random on edge-cut and comm volume.");
    Ok(())
}
