//! The staleness/throughput crossover: pipeline depth `p` swept against
//! the AEP delay `d`.
//!
//! The paper hides MBC sampling and AEP communication behind compute
//! under a `d`-delayed HEC update window. Two independent knobs shape
//! that overlap:
//!
//! * **depth `p`** (`--pipeline-depth`) moves *when sampling runs* — it
//!   must never change the losses (sampling streams are keyed by
//!   iteration, not schedule). Its win is throughput: deeper rings hide
//!   more MBC seconds behind exec windows.
//! * **delay `d`** (`--hec-d`) moves *which embeddings the HEC serves* —
//!   staleness. Its win is overlap opportunity for the pushes; its cost
//!   is a real loss delta.
//!
//! This bench measures both axes on one grid: for every `(d, p)` it
//! records epoch seconds, hidden MBC seconds, ring occupancy and the
//! final loss; asserts the depth axis is loss-invariant (bit-identical to
//! `p = 1` at the same `d`); and reports the staleness deltas along the
//! `d` axis — the measured form of the paper's crossover argument. The
//! `pipeline_depth` section lands in `BENCH_pipeline.json`.

use distgnn_mb::benchkit::{fmt_s, print_header, print_table, run, write_bench_section};
use distgnn_mb::config::TrainConfig;
use distgnn_mb::util::json::{self, Value};

fn base() -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.preset = "products-mini".into();
    cfg.ranks = 4;
    // random partitioning maximizes the cut: real AEP traffic, so the
    // delay d actually changes which embeddings the HECs serve
    cfg.partitioner = "random".into();
    cfg.epochs = std::env::var("DISTGNN_EPOCHS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);
    cfg.max_minibatches = Some(
        std::env::var("DISTGNN_MAX_MB")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(6),
    );
    cfg.pipeline = true;
    cfg
}

struct Cell {
    d: usize,
    p: usize,
    epoch_s: f64,
    mbc_s: f64,
    mbc_hidden_s: f64,
    ring_occupancy: f64,
    aep_wait_s: f64,
    aep_flight_s: f64,
    final_loss: f64,
}

fn main() -> anyhow::Result<()> {
    let depths = [1usize, 2, 4, 8];
    let delays = [1usize, 2, 4, 8];
    print_header("pipeline_depth", &base());

    let mut cells: Vec<Cell> = Vec::new();
    for &d in &delays {
        for &p in &depths {
            let mut cfg = base();
            cfg.hec.d = d;
            cfg.pipeline_depth = p;
            let rep = run(cfg)?;
            let last = rep.epochs.last().unwrap();
            cells.push(Cell {
                d,
                p,
                epoch_s: rep.mean_epoch_time(1),
                mbc_s: last.comps.mbc,
                mbc_hidden_s: last.mbc_hidden,
                ring_occupancy: last.ring_occupancy,
                aep_wait_s: last.aep_wait,
                aep_flight_s: last.aep_flight,
                final_loss: last.train_loss,
            });
        }
    }

    // the depth axis must be loss-invariant: p > 1 is bit-identical to
    // p = 1 at the same d (the tentpole contract, here in measured form)
    let p1_loss = |d: usize| {
        cells
            .iter()
            .find(|c| c.d == d && c.p == 1)
            .map(|c| c.final_loss)
            .unwrap()
    };
    let depth_invariant = cells.iter().all(|c| c.final_loss == p1_loss(c.d));

    let rows: Vec<Vec<String>> = cells
        .iter()
        .map(|c| {
            let mbc_total = c.mbc_s + c.mbc_hidden_s;
            let hidden_frac = if mbc_total > 0.0 {
                c.mbc_hidden_s / mbc_total
            } else {
                0.0
            };
            vec![
                format!("d={} p={}", c.d, c.p),
                fmt_s(c.epoch_s),
                format!("{:.3}", c.mbc_hidden_s),
                format!("{:.0}%", hidden_frac * 100.0),
                format!("{:.2}", c.ring_occupancy),
                format!("{:.6}", c.final_loss),
                format!("{:+.2e}", c.final_loss - p1_loss(c.d)),
            ]
        })
        .collect();
    print_table(
        "pipeline depth p vs AEP delay d (sim fabric, random partition)",
        &[
            "cell", "epoch(s)", "mbc hidden(s)", "hidden%", "ring occ", "final loss",
            "loss Δ vs p=1",
        ],
        &rows,
    );

    // staleness along the d axis at fixed p = 1: the loss price of delay
    let d1_loss = p1_loss(delays[0]);
    let staleness: Vec<Value> = delays
        .iter()
        .map(|&d| {
            json::obj(vec![
                ("d", json::num(d as f64)),
                ("loss", json::num(p1_loss(d))),
                ("loss_delta_vs_d1", json::num(p1_loss(d) - d1_loss)),
            ])
        })
        .collect();

    // throughput along the p axis: fastest depth per delay (the
    // crossover point of hiding gains vs nothing left to hide)
    let best_p: Vec<Value> = delays
        .iter()
        .map(|&d| {
            let best = cells
                .iter()
                .filter(|c| c.d == d)
                .min_by(|a, b| a.epoch_s.total_cmp(&b.epoch_s))
                .unwrap();
            json::obj(vec![
                ("d", json::num(d as f64)),
                ("best_p", json::num(best.p as f64)),
                ("epoch_s", json::num(best.epoch_s)),
            ])
        })
        .collect();

    let cell_rows: Vec<Value> = cells
        .iter()
        .map(|c| {
            json::obj(vec![
                ("d", json::num(c.d as f64)),
                ("p", json::num(c.p as f64)),
                ("epoch_s", json::num(c.epoch_s)),
                ("mbc_s", json::num(c.mbc_s)),
                ("mbc_hidden_s", json::num(c.mbc_hidden_s)),
                ("ring_occupancy", json::num(c.ring_occupancy)),
                ("aep_wait_s", json::num(c.aep_wait_s)),
                ("aep_flight_s", json::num(c.aep_flight_s)),
                ("final_loss", json::num(c.final_loss)),
                (
                    "loss_delta_vs_p1",
                    json::num(c.final_loss - p1_loss(c.d)),
                ),
            ])
        })
        .collect();

    write_bench_section(
        "pipeline_depth",
        vec![
            ("cells", json::arr(cell_rows)),
            ("losses_depth_invariant", Value::Bool(depth_invariant)),
            ("staleness_by_d", json::arr(staleness)),
            ("best_p_by_d", json::arr(best_p)),
        ],
    )?;

    if !depth_invariant {
        anyhow::bail!("pipeline depth changed losses — the ring moved WHAT runs, not just WHEN");
    }
    println!("\nexpected shapes: loss Δ vs p=1 is exactly 0 at every depth (the");
    println!("ring moves when sampling runs, never what runs); hidden MBC seconds");
    println!("rise with p until the exec windows are saturated; the staleness");
    println!("loss delta moves along d only — that pair of curves is the");
    println!("paper's staleness/throughput crossover, measured.");
    Ok(())
}
