//! Minibatch-creation (MBC) bench: synchronous thread-parallel sampler vs
//! serial vs DGL-worker-IPC emulation (the SYNC_MBC comparison of §3.3),
//! plus the combined sample+pack pipeline-stage throughput at 1 vs N
//! worker threads. Writes the `sampler` section of BENCH_pipeline.json.

use distgnn_mb::benchkit::{print_table, write_bench_section};
use distgnn_mb::config::SamplerKind;
use distgnn_mb::graph::{io as graph_io, DatasetPreset};
use distgnn_mb::hec::Hec;
use distgnn_mb::model::Packer;
use distgnn_mb::partition::{materialize, metis_like::MetisLikePartitioner, Partitioner, RankPartition};
use distgnn_mb::runtime::Manifest;
use distgnn_mb::sampler::neighbor::{make_seed_batches, NeighborSampler};
use distgnn_mb::util::json;
use distgnn_mb::util::rng::Pcg64;

/// Sample + pack every seed batch once; returns minibatches per second.
fn sample_pack_throughput(
    part: &RankPartition,
    packer: &Packer,
    fanouts: &[usize],
    batches: &[Vec<u32>],
    reps: usize,
) -> anyhow::Result<f64> {
    let mut sampler = NeighborSampler::new(
        fanouts.to_vec(),
        packer.node_caps.clone(),
        false,
        SamplerKind::Parallel,
    );
    let mut hecs: Vec<Hec> = {
        let mut dims = vec![packer.feat_dim];
        dims.extend(std::iter::repeat(packer.hidden).take(packer.n_layers - 1));
        dims.iter().map(|&d| Hec::new(65_536, 1000, d)).collect()
    };
    // warm the level-0 cache with every halo's "remote features" so the
    // pack exercises the batched HECSearch/HECLoad hit path
    {
        let mut srng = Pcg64::seeded(17);
        for seeds in batches {
            let mb = sampler.sample(part, seeds, &mut srng);
            for (level, hec) in hecs.iter_mut().enumerate() {
                let dim = if level == 0 { packer.feat_dim } else { packer.hidden };
                let row = vec![0.25f32; dim];
                for &v in mb.layers.get(level).map(|l| l.as_slice()).unwrap_or(&[]) {
                    if part.is_halo(v) {
                        hec.store(part.vid_o[v as usize], &row);
                    }
                }
            }
        }
    }
    let t0 = std::time::Instant::now();
    let mut count = 0usize;
    for _ in 0..reps {
        let mut srng = Pcg64::seeded(17);
        for seeds in batches {
            let mb = sampler.sample(part, seeds, &mut srng);
            let (tensors, _) = packer.pack(part, &mb, &mut hecs, None, 1)?;
            std::hint::black_box(&tensors);
            count += 1;
        }
    }
    Ok(count as f64 / t0.elapsed().as_secs_f64())
}

fn main() -> anyhow::Result<()> {
    println!("### bench: sampler_bench (MBC component)");
    let preset = DatasetPreset::by_name("products-mini")?;
    let ds = graph_io::load_or_generate(&preset, "data-cache")?;
    let a = MetisLikePartitioner::default().partition(&ds.graph, &ds.train_vertices, 4, 42);
    let parts = materialize(&ds, &a);
    let part = &parts[0];

    let manifest = Manifest::load_or_builtin("artifacts")?;
    let prog = manifest.program("sage_train_products-mini")?;
    let node_caps: Vec<usize> = prog
        .meta
        .get("node_caps")
        .and_then(|v| v.as_arr())
        .map(|ar| ar.iter().filter_map(|x| x.as_usize()).collect())
        .unwrap();
    let fanouts: Vec<usize> = prog
        .meta
        .get("fanouts")
        .and_then(|v| v.as_arr())
        .map(|ar| ar.iter().filter_map(|x| x.as_usize()).collect())
        .unwrap();
    let batch = prog.meta_usize("batch")?;
    let packer = Packer::from_program(prog)?;

    let mut rng = Pcg64::seeded(3);
    let batches = make_seed_batches(&part.train_vertices, batch, &mut rng, Some(40));
    let reps: usize = std::env::var("DISTGNN_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);

    let mut rows = Vec::new();
    for kind in [
        SamplerKind::Parallel,
        SamplerKind::Serial,
        SamplerKind::SerialIpc,
    ] {
        let mut sampler = NeighborSampler::new(fanouts.clone(), node_caps.clone(), false, kind);
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            let mut srng = Pcg64::seeded(11);
            for seeds in &batches {
                let mb = sampler.sample(part, seeds, &mut srng);
                std::hint::black_box(&mb);
            }
        }
        let per_mb = t0.elapsed().as_secs_f64() / (reps * batches.len()) as f64;
        rows.push(vec![
            kind.as_str().to_string(),
            format!("{:.1}us", per_mb * 1e6),
            format!(
                "{:.0}",
                sampler.stats.sampled_nodes as f64 / sampler.stats.minibatches as f64
            ),
            format!(
                "{:.0}",
                sampler.stats.sampled_edges as f64 / sampler.stats.minibatches as f64
            ),
            format!(
                "{:.2}%",
                100.0 * sampler.stats.overflow_nodes as f64
                    / sampler.stats.sampled_nodes.max(1) as f64
            ),
            format!("{:.0}KB", sampler.stats.ipc_bytes as f64 / 1e3 / reps as f64),
        ]);
    }
    print_table(
        "sampler comparison (products-mini, 4-rank partition 0)",
        &["sampler", "per-mb", "nodes/mb", "edges/mb", "overflow", "ipc bytes"],
        &rows,
    );

    // ---- sample+pack stage throughput, 1 thread vs 4 ----------------------
    // (the thread-parallel SYNC_MBC + batched HEC/packing claim of §3.2/3.3)
    let prev_threads = std::env::var("DISTGNN_THREADS").ok();
    std::env::set_var("DISTGNN_THREADS", "1");
    let t1 = sample_pack_throughput(part, &packer, &fanouts, &batches, reps)?;
    std::env::set_var("DISTGNN_THREADS", "4");
    let t4 = sample_pack_throughput(part, &packer, &fanouts, &batches, reps)?;
    match &prev_threads {
        Some(v) => std::env::set_var("DISTGNN_THREADS", v),
        None => std::env::remove_var("DISTGNN_THREADS"),
    }
    let speedup = t4 / t1.max(1e-9);
    print_table(
        "sample+pack stage throughput (minibatches/s)",
        &["threads", "mb/s", "speedup"],
        &[
            vec!["1".into(), format!("{t1:.1}"), "1.00x".into()],
            vec!["4".into(), format!("{t4:.1}"), format!("{speedup:.2}x")],
        ],
    );

    write_bench_section(
        "sampler",
        vec![
            ("pack_sample_mb_per_s_t1", json::num(t1)),
            ("pack_sample_mb_per_s_t4", json::num(t4)),
            ("pack_sample_speedup_t4_vs_t1", json::num(speedup)),
            ("minibatches", json::num(batches.len() as f64)),
            ("reps", json::num(reps as f64)),
        ],
    )?;

    println!("\nnote: 'parallel' vs 'serial' shows the SYNC_MBC structure; 'serial-ipc'");
    println!("carries the per-minibatch serialize/deserialize cost the paper removes.");
    println!("The threads sweep needs >= 2 physical cores to show wallclock speedup.");
    Ok(())
}
