//! Minibatch-creation (MBC) bench: synchronous thread-parallel sampler vs
//! serial vs DGL-worker-IPC emulation (the SYNC_MBC comparison of §3.3),
//! plus sampled-size statistics and cap-overflow accounting.

use distgnn_mb::benchkit::print_table;
use distgnn_mb::config::SamplerKind;
use distgnn_mb::graph::{io as graph_io, DatasetPreset};
use distgnn_mb::partition::{materialize, metis_like::MetisLikePartitioner, Partitioner};
use distgnn_mb::runtime::Manifest;
use distgnn_mb::sampler::neighbor::{make_seed_batches, NeighborSampler};
use distgnn_mb::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    println!("### bench: sampler_bench (MBC component)");
    let preset = DatasetPreset::by_name("products-mini")?;
    let ds = graph_io::load_or_generate(&preset, "data-cache")?;
    let a = MetisLikePartitioner::default().partition(&ds.graph, &ds.train_vertices, 4, 42);
    let parts = materialize(&ds, &a);
    let part = &parts[0];

    let manifest = Manifest::load("artifacts")?;
    let prog = manifest.program("sage_train_products-mini")?;
    let node_caps: Vec<usize> = prog
        .meta
        .get("node_caps")
        .and_then(|v| v.as_arr())
        .map(|ar| ar.iter().filter_map(|x| x.as_usize()).collect())
        .unwrap();
    let fanouts: Vec<usize> = prog
        .meta
        .get("fanouts")
        .and_then(|v| v.as_arr())
        .map(|ar| ar.iter().filter_map(|x| x.as_usize()).collect())
        .unwrap();
    let batch = prog.meta_usize("batch")?;

    let mut rng = Pcg64::seeded(3);
    let batches = make_seed_batches(&part.train_vertices, batch, &mut rng, Some(40));
    let reps: usize = std::env::var("DISTGNN_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);

    let mut rows = Vec::new();
    for kind in [
        SamplerKind::Parallel,
        SamplerKind::Serial,
        SamplerKind::SerialIpc,
    ] {
        let mut sampler = NeighborSampler::new(fanouts.clone(), node_caps.clone(), false, kind);
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            let mut srng = Pcg64::seeded(11);
            for seeds in &batches {
                let mb = sampler.sample(part, seeds, &mut srng);
                std::hint::black_box(&mb);
            }
        }
        let per_mb = t0.elapsed().as_secs_f64() / (reps * batches.len()) as f64;
        rows.push(vec![
            kind.as_str().to_string(),
            format!("{:.1}us", per_mb * 1e6),
            format!(
                "{:.0}",
                sampler.stats.sampled_nodes as f64 / sampler.stats.minibatches as f64
            ),
            format!(
                "{:.0}",
                sampler.stats.sampled_edges as f64 / sampler.stats.minibatches as f64
            ),
            format!(
                "{:.2}%",
                100.0 * sampler.stats.overflow_nodes as f64
                    / sampler.stats.sampled_nodes.max(1) as f64
            ),
            format!("{:.0}KB", sampler.stats.ipc_bytes as f64 / 1e3 / reps as f64),
        ]);
    }
    print_table(
        "sampler comparison (products-mini, 4-rank partition 0)",
        &["sampler", "per-mb", "nodes/mb", "edges/mb", "overflow", "ipc bytes"],
        &rows,
    );
    println!("\nnote: single-core sandbox — 'parallel' shows its benefit in structure, not");
    println!("wallclock; 'serial-ipc' carries the per-minibatch serialize/deserialize cost");
    println!("the paper's SYNC_MBC removes. Sec/mb deltas here feed the Fig. 2 model.");
    Ok(())
}
