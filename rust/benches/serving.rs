//! Closed-loop serving load generator: latency percentiles vs offered
//! load vs HEC hit rate, f32 vs bf16.
//!
//! For each dtype, a tiny model is trained briefly and checkpointed,
//! then one fresh server (fresh engine, cold served-embedding cache) is
//! started per load point and driven by N closed-loop clients — each
//! fires its next request only after the previous reply, so offered
//! load scales with the client count, not with a fixed rate. Per cell:
//!
//! * throughput (replies/s), p50/p99 latency, level-0 HEC hit rate and
//!   mean coalesced batch size, straight from [`ServeMetrics`];
//! * typed overload rejections, counted at the clients via
//!   [`ServeRejected`] downcasts — asserted **zero at one client** (a
//!   single closed-loop client can never overflow the queue);
//! * a determinism probe: one canonical vid set scored before and after
//!   the storm, and across every load point of the dtype — all replies
//!   must be bit-identical (the cache warms observably, scores never
//!   move).
//!
//! Section `serving`; default output `BENCH_serving.json`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use distgnn_mb::benchkit::{print_table, write_bench_section};
use distgnn_mb::config::{DtypeKind, TrainConfig};
use distgnn_mb::serve::{ScoreClient, ScoreEngine, ServeOptions, ServeRejected, Server};
use distgnn_mb::train::Driver;
use distgnn_mb::util::json::{self, Value};
use distgnn_mb::util::rng::Pcg64;

fn base_cfg(dtype: DtypeKind) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.preset = "tiny".into();
    cfg.ranks = 2;
    cfg.epochs = 1;
    cfg.max_minibatches = Some(4);
    cfg.dtype = dtype;
    cfg
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

struct Cell {
    dtype: &'static str,
    clients: usize,
    served: u64,
    rejected: u64,
    wall_s: f64,
    rps: f64,
    p50_ms: f64,
    p99_ms: f64,
    hit_rate: f64,
    mean_batch: f64,
    batches: u64,
}

fn main() -> anyhow::Result<()> {
    if std::env::var("DISTGNN_BENCH_OUT").is_err() {
        std::env::set_var("DISTGNN_BENCH_OUT", "BENCH_serving.json");
    }
    let reqs = env_usize("DISTGNN_SERVE_REQS", 40);
    let loads = [1usize, 4, 16];
    let mut cells: Vec<Cell> = Vec::new();
    let mut deterministic = true;

    for (dname, dtype) in [("f32", DtypeKind::F32), ("bf16", DtypeKind::Bf16)] {
        let cfg = base_cfg(dtype);
        let ckpt = std::env::temp_dir()
            .join(format!("distgnn-serving-bench-{dname}.dgnc"))
            .to_string_lossy()
            .to_string();
        {
            let mut d = Driver::new(cfg.clone())?;
            d.train(None)?;
            d.save_checkpoint(&ckpt, 1)?;
            d.shutdown()?;
        }
        // canonical scores must be identical across every load point of
        // this dtype (fresh engine each time — pure function of ckpt)
        let mut canonical: Option<Vec<u32>> = None;
        for &clients in &loads {
            let engine = ScoreEngine::new(cfg.clone(), &ckpt)?;
            let nc = engine.num_classes();
            let hosted: Arc<Vec<u32>> =
                Arc::new((0..60_000u32).filter(|&v| engine.knows(v)).collect());
            anyhow::ensure!(!hosted.is_empty(), "engine hosts no vertices");
            let sock = std::env::temp_dir()
                .join(format!("distgnn-serving-bench-{dname}-{clients}.sock"))
                .to_string_lossy()
                .to_string();
            let opts = ServeOptions {
                socket: sock.clone(),
                deadline: Duration::from_millis(1),
                queue: 64,
            };
            let server = Server::start(engine, opts)?;
            let mut probe = ScoreClient::connect(&sock)?;
            let probe_vids: Vec<u32> = hosted.iter().step_by(97).take(8).copied().collect();
            let (before, _) = probe.score(&probe_vids)?;

            let t0 = Instant::now();
            let handles: Vec<_> = (0..clients)
                .map(|c| {
                    let sock = sock.clone();
                    let hosted = hosted.clone();
                    std::thread::spawn(move || -> anyhow::Result<(u64, u64)> {
                        let mut cl = ScoreClient::connect(&sock)?;
                        let mut rng = Pcg64::new(0xBE7C, c as u64);
                        let (mut ok, mut rej) = (0u64, 0u64);
                        for _ in 0..reqs {
                            let vids: Vec<u32> = (0..4)
                                .map(|_| hosted[rng.gen_range(hosted.len())])
                                .collect();
                            match cl.score(&vids) {
                                Ok(_) => ok += 1,
                                Err(e) if e.downcast_ref::<ServeRejected>().is_some() => rej += 1,
                                Err(e) => return Err(e),
                            }
                        }
                        Ok((ok, rej))
                    })
                })
                .collect();
            let mut ok_total = 0u64;
            let mut rej_total = 0u64;
            for h in handles {
                let (ok, rej) = h.join().expect("client thread panicked")?;
                ok_total += ok;
                rej_total += rej;
            }
            let wall_s = t0.elapsed().as_secs_f64();

            let (after, _) = probe.score(&probe_vids)?;
            let cell_deterministic = bits(&before) == bits(&after)
                && canonical.as_ref().map_or(true, |c| c == &bits(&before));
            deterministic &= cell_deterministic;
            canonical.get_or_insert_with(|| bits(&before));

            let m = server.stop()?;
            anyhow::ensure!(
                m.served == ok_total + 2,
                "served {} but clients saw {} OK replies (+2 probes)",
                m.served,
                ok_total
            );
            anyhow::ensure!(m.rejected == rej_total, "rejection counts disagree");
            anyhow::ensure!(m.bad_requests == 0, "bench sent only well-formed requests");
            if clients == 1 {
                anyhow::ensure!(
                    rej_total == 0,
                    "a single closed-loop client cannot overflow the queue"
                );
            }
            anyhow::ensure!(before.len() == probe_vids.len() * nc);
            cells.push(Cell {
                dtype: dname,
                clients,
                served: m.served,
                rejected: m.rejected,
                wall_s,
                rps: m.served as f64 / wall_s.max(1e-9),
                p50_ms: m.p50() * 1e3,
                p99_ms: m.p99() * 1e3,
                hit_rate: m.hit_rate(),
                mean_batch: m.batch_sizes.mean(),
                batches: m.batches,
            });
        }
    }

    print_table(
        "closed-loop serving: latency vs offered load vs HEC hit rate",
        &[
            "dtype", "clients", "served", "rejected", "rps", "p50", "p99", "hec hit", "batch",
        ],
        &cells
            .iter()
            .map(|c| {
                vec![
                    c.dtype.to_string(),
                    format!("{}", c.clients),
                    format!("{}", c.served),
                    format!("{}", c.rejected),
                    format!("{:.0}", c.rps),
                    format!("{:.2}ms", c.p50_ms),
                    format!("{:.2}ms", c.p99_ms),
                    format!("{:.1}%", c.hit_rate * 100.0),
                    format!("{:.1}", c.mean_batch),
                ]
            })
            .collect::<Vec<_>>(),
    );

    let cell_json: Vec<Value> = cells
        .iter()
        .map(|c| {
            json::obj(vec![
                ("dtype", json::s(c.dtype)),
                ("clients", json::num(c.clients as f64)),
                ("served", json::num(c.served as f64)),
                ("rejected", json::num(c.rejected as f64)),
                ("wall_s", json::num(c.wall_s)),
                ("throughput_rps", json::num(c.rps)),
                ("p50_ms", json::num(c.p50_ms)),
                ("p99_ms", json::num(c.p99_ms)),
                ("hec_hit_rate", json::num(c.hit_rate)),
                ("mean_batch_vids", json::num(c.mean_batch)),
                ("batches", json::num(c.batches as f64)),
            ])
        })
        .collect();
    write_bench_section(
        "serving",
        vec![
            ("requests_per_client", json::num(reqs as f64)),
            ("cells", json::arr(cell_json)),
            ("scores_bit_identical", Value::Bool(deterministic)),
        ],
    )?;

    if !deterministic {
        anyhow::bail!("served scores moved across repeats/load points — determinism broken");
    }
    println!("\nexpected shapes: p99 grows with the client count while throughput");
    println!("rises then saturates at the single scoring thread; the HEC hit rate");
    println!("climbs as the served-embedding cache warms; scores never move.");
    Ok(())
}
