//! Table 1: benchmark dataset statistics (mini-preset analogs).
//!
//! Prints the paper's Table 1 row format for each synthetic preset next to
//! the original OGBN statistics, with the scale ratios the substitution
//! preserves (DESIGN.md §1) — plus a **papers100M-class shard cell**: an
//! R-MAT graph written directly as an out-of-core shard set (never held
//! in RAM), trained once through the mapped path to record what the
//! residency costs. `DISTGNN_OOC_SCALE` / `DISTGNN_OOC_EDGES` size it;
//! the defaults are CI-sized, scale 27 with 10⁹ draws is paper-class.
//! Section `table1_shard_cell`; default output `BENCH_pipeline.json`.

use distgnn_mb::benchkit::{print_table, run, write_bench_section};
use distgnn_mb::config::TrainConfig;
use distgnn_mb::graph::generator::{generate_rmat_shards, ShardGenConfig};
use distgnn_mb::graph::{io as graph_io, DatasetPreset};
use distgnn_mb::train::metrics::RunReport;
use distgnn_mb::util::json::{self, Value};
use distgnn_mb::util::mmap;

fn env_or(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    println!("### bench: table1_datasets (paper Table 1)");
    let mut rows = Vec::new();
    // paper originals for reference
    rows.push(vec![
        "OGBN-Products (paper)".into(),
        "2449029".into(),
        "123718280".into(),
        "100".into(),
        "47".into(),
        "196615".into(),
        "2213091".into(),
    ]);
    rows.push(vec![
        "OGBN-Papers100M (paper)".into(),
        "111059956".into(),
        "3231371744".into(),
        "128".into(),
        "172".into(),
        "1207179".into(),
        "214338".into(),
    ]);
    for name in ["tiny", "products-mini", "papers100m-mini"] {
        let preset = DatasetPreset::by_name(name)?;
        let ds = graph_io::load_or_generate(&preset, "data-cache")?;
        rows.push(vec![
            ds.name.clone(),
            ds.num_vertices().to_string(),
            ds.graph.num_directed_edges().to_string(),
            ds.feat_dim.to_string(),
            ds.num_classes.to_string(),
            ds.train_vertices.len().to_string(),
            ds.test_vertices.len().to_string(),
        ]);
        println!(
            "{name}: mean degree {:.1}, max degree {} (power-law overlay active)",
            ds.graph.mean_degree(),
            ds.graph.max_degree()
        );
    }

    // papers100M-class cell: the graph exists only as a shard set on
    // disk; its Table-1 row comes from the manifest, not a Dataset.
    let seed = 42u64;
    let ranks = env_or("DISTGNN_OOC_RANKS", 4) as usize;
    let scale = env_or("DISTGNN_OOC_SCALE", 13) as u32;
    let edges = env_or("DISTGNN_OOC_EDGES", 12u64 << scale);
    let dir = std::env::temp_dir().join(format!("distgnn-table1-shards-{}", std::process::id()));
    let stats = generate_rmat_shards(
        &ShardGenConfig::new("papers100m-mini", scale, edges, ranks, seed),
        &dir,
    )?;
    let set = graph_io::ShardSet::open(&dir)?;
    let m = &set.manifest;
    let n_train: u64 = m.ranks.iter().map(|r| r.n_train).sum();
    let n_test: u64 = m.ranks.iter().map(|r| r.n_test).sum();
    rows.push(vec![
        format!("rmat-shards 2^{scale} (out-of-core)"),
        stats.n_vertices.to_string(),
        stats.directed_edges.to_string(),
        m.feat_dim.to_string(),
        m.num_classes.to_string(),
        n_train.to_string(),
        n_test.to_string(),
    ]);

    print_table(
        "Table 1 — datasets",
        &["dataset", "#vertex", "#edge", "#feat", "#class", "#train", "#test"],
        &rows,
    );

    // one short run over the shard cell per residency, with the counters
    // and the bit-identity contract (mapped == heap-copied) on record
    let mut cfg = TrainConfig::default();
    cfg.preset = "papers100m-mini".into();
    cfg.ranks = ranks;
    cfg.seed = seed;
    cfg.epochs = env_or("DISTGNN_EPOCHS", 2) as usize;
    cfg.max_minibatches = Some(env_or("DISTGNN_MAX_MB", 4) as usize);
    cfg.data_shards = dir.to_string_lossy().to_string();

    let mut copied_cfg = cfg.clone();
    copied_cfg.data_shards_mmap = false;
    let copied = run(copied_cfg)?;

    let (stall_bytes, stall_s) = {
        let mut bytes = 0u64;
        let mut secs = 0.0f64;
        for r in 0..set.k() {
            let shard = set.open_shard(r, graph_io::ShardVerify::Header)?;
            let (b, s) = mmap::touch_pages(shard.payload_bytes());
            bytes += b;
            secs += s;
        }
        (bytes, secs)
    };
    let mapped_before = mmap::bytes_mapped_total();
    let faults_before = mmap::page_fault_counts();
    cfg.data_shards_mmap = true;
    let mapped = run(cfg)?;
    let bytes_mapped = mmap::bytes_mapped_total() - mapped_before;
    let (minor, major) = match (faults_before, mmap::page_fault_counts()) {
        (Some((a0, b0)), Some((a1, b1))) => (a1 - a0, b1 - b0),
        _ => (0, 0),
    };
    let ls = |rep: &RunReport| -> Vec<f64> {
        rep.epochs.iter().map(|e| e.train_loss).collect()
    };
    let bit_identical = ls(&copied) == ls(&mapped);
    anyhow::ensure!(
        bit_identical,
        "shard residency changed the losses: copied {:?} vs mapped {:?}",
        ls(&copied),
        ls(&mapped)
    );
    println!(
        "shard cell: epoch {:.3}s mapped vs {:.3}s copied; {bytes_mapped} bytes mapped, \
         {stall_s:.4}s fault stall over {stall_bytes} payload bytes; losses bit-identical",
        mapped.mean_epoch_time(1),
        copied.mean_epoch_time(1),
    );

    write_bench_section(
        "table1_shard_cell",
        vec![
            ("preset", json::s("papers100m-mini")),
            ("ranks", json::num(ranks as f64)),
            ("scale", json::num(scale as f64)),
            ("edge_draws", json::num(edges as f64)),
            ("n_vertices", json::num(stats.n_vertices as f64)),
            ("directed_edges", json::num(stats.directed_edges as f64)),
            ("shard_bytes_written", json::num(stats.bytes_written as f64)),
            ("epoch_s_copied", json::num(copied.mean_epoch_time(1))),
            ("epoch_s_mapped", json::num(mapped.mean_epoch_time(1))),
            ("bytes_mapped", json::num(bytes_mapped as f64)),
            ("page_fault_stall_s", json::num(stall_s)),
            ("minor_faults", json::num(minor as f64)),
            ("major_faults", json::num(major as f64)),
            (
                "peak_rss_bytes",
                mmap::peak_rss_bytes()
                    .map(|b| json::num(b as f64))
                    .unwrap_or(Value::Null),
            ),
            ("losses_bit_identical", Value::Bool(bit_identical)),
        ],
    )?;

    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
