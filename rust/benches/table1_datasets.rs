//! Table 1: benchmark dataset statistics (mini-preset analogs).
//!
//! Prints the paper's Table 1 row format for each synthetic preset next to
//! the original OGBN statistics, with the scale ratios the substitution
//! preserves (DESIGN.md §1).

use distgnn_mb::benchkit::print_table;
use distgnn_mb::graph::{io as graph_io, DatasetPreset};

fn main() -> anyhow::Result<()> {
    println!("### bench: table1_datasets (paper Table 1)");
    let mut rows = Vec::new();
    // paper originals for reference
    rows.push(vec![
        "OGBN-Products (paper)".into(),
        "2449029".into(),
        "123718280".into(),
        "100".into(),
        "47".into(),
        "196615".into(),
        "2213091".into(),
    ]);
    rows.push(vec![
        "OGBN-Papers100M (paper)".into(),
        "111059956".into(),
        "3231371744".into(),
        "128".into(),
        "172".into(),
        "1207179".into(),
        "214338".into(),
    ]);
    for name in ["tiny", "products-mini", "papers100m-mini"] {
        let preset = DatasetPreset::by_name(name)?;
        let ds = graph_io::load_or_generate(&preset, "data-cache")?;
        rows.push(vec![
            ds.name.clone(),
            ds.num_vertices().to_string(),
            ds.graph.num_directed_edges().to_string(),
            ds.feat_dim.to_string(),
            ds.num_classes.to_string(),
            ds.train_vertices.len().to_string(),
            ds.test_vertices.len().to_string(),
        ]);
        println!(
            "{name}: mean degree {:.1}, max degree {} (power-law overlay active)",
            ds.graph.mean_degree(),
            ds.graph.max_degree()
        );
    }
    print_table(
        "Table 1 — datasets",
        &["dataset", "#vertex", "#edge", "#feat", "#class", "#train", "#test"],
        &rows,
    );
    Ok(())
}
