//! UPDATE-primitive micro-benchmark (L1 perf deliverable).
//!
//! Compares, at products-mini dimensions:
//!   * the fused UPDATE program (matmul+matmul+bias+ReLU+dropout in one
//!     pass over the output tile);
//!   * the same chain as one unfused program with materialized
//!     intermediates;
//!   * the op-by-op chain across five separate executables with
//!     host-visible intermediates (framework-style op dispatch).
//!
//! Also benchmarks the bf16 packed row-block kernels (NN/TN/NT) against
//! the f32 scalar baseline at the same dimensions, reporting time, GB
//! moved and effective GB/s for each dtype (the paper's bf16-halves-the-
//! bytes argument, measured); reports the full train-step and fwd program
//! costs per call, which anchor the FWD/BWD split calibration (DESIGN.md
//! §7); and writes the `update_kernel` + `bf16_kernels` sections of
//! BENCH_pipeline.json.

use distgnn_mb::benchkit::{fmt_gb, gbps, print_table, write_bench_section};
use distgnn_mb::runtime::native;
use distgnn_mb::runtime::bf16;
use distgnn_mb::runtime::{HostTensor, Manifest, Runtime};
use distgnn_mb::util::json;
use distgnn_mb::util::rng::Pcg64;

fn rand_inputs(rt: &Runtime, name: &str, rng: &mut Pcg64) -> anyhow::Result<Vec<HostTensor>> {
    let exe = rt.program(name)?;
    Ok(exe
        .spec
        .inputs
        .iter()
        .map(|s| {
            let n: usize = s.shape.iter().product();
            match s.dtype {
                distgnn_mb::runtime::DType::F32 => HostTensor::f32(
                    s.shape.clone(),
                    &(0..n).map(|_| rng.gen_f32() - 0.5).collect::<Vec<_>>(),
                ),
                distgnn_mb::runtime::DType::Bf16 => HostTensor::bf16_from_f32(
                    s.shape.clone(),
                    &(0..n).map(|_| rng.gen_f32() - 0.5).collect::<Vec<_>>(),
                ),
                distgnn_mb::runtime::DType::I32 => {
                    HostTensor::i32(s.shape.clone(), &vec![0i32; n])
                }
                distgnn_mb::runtime::DType::U32 => {
                    HostTensor::u32(s.shape.clone(), &vec![0u32; n])
                }
            }
        })
        .collect())
}

fn time_call(rt: &Runtime, name: &str, reps: usize, rng: &mut Pcg64) -> anyhow::Result<f64> {
    let inputs = rand_inputs(rt, name, rng)?;
    let exe = rt.program(name)?;
    exe.run(&inputs)?;
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        std::hint::black_box(exe.run(&inputs)?);
    }
    Ok(t0.elapsed().as_secs_f64() / reps as f64)
}

fn main() -> anyhow::Result<()> {
    println!("### bench: update_kernel_bench");
    let manifest = Manifest::load_or_builtin("artifacts")?;
    let mut rt = Runtime::cpu()?;
    let progs = [
        "update_fused_products-mini",
        "update_unfused_full_products-mini",
        "update_mm_products-mini",
        "update_add_bias_products-mini",
        "update_relu_products-mini",
        "update_dropout_products-mini",
    ];
    for p in progs {
        rt.load_program(&manifest, p)?;
    }
    let reps: usize = std::env::var("DISTGNN_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10);
    let mut rng = Pcg64::seeded(5);

    let t_fused = time_call(&rt, "update_fused_products-mini", reps, &mut rng)?;
    let t_unfused = time_call(&rt, "update_unfused_full_products-mini", reps, &mut rng)?;
    let t_mm = time_call(&rt, "update_mm_products-mini", reps, &mut rng)?;
    let t_add = time_call(&rt, "update_add_bias_products-mini", reps, &mut rng)?;
    let t_relu = time_call(&rt, "update_relu_products-mini", reps, &mut rng)?;
    let t_drop = time_call(&rt, "update_dropout_products-mini", reps, &mut rng)?;
    let t_chain = 2.0 * t_mm + t_add + t_relu + t_drop;

    let spec = manifest.program("update_fused_products-mini")?;
    let rows_n = spec.meta_usize("rows")?;
    let d_in = spec.meta_usize("d_in")?;
    let d_out = spec.meta_usize("d_out")?;
    let flops = 2.0 * rows_n as f64 * d_in as f64 * d_out as f64 * 2.0; // two matmuls
    let table = vec![
        vec![
            "op-by-op chain (5 exes)".into(),
            format!("{:.3}ms", t_chain * 1e3),
            format!("{:.2}", flops / t_chain / 1e9),
            format!("{:.2}x", t_chain / t_fused),
        ],
        vec![
            "unfused single program".into(),
            format!("{:.3}ms", t_unfused * 1e3),
            format!("{:.2}", flops / t_unfused / 1e9),
            format!("{:.2}x", t_unfused / t_fused),
        ],
        vec![
            "fused Pallas program".into(),
            format!("{:.3}ms", t_fused * 1e3),
            format!("{:.2}", flops / t_fused / 1e9),
            "1.00x".into(),
        ],
    ];
    print_table(
        &format!("UPDATE primitive, rows={rows_n} d_in={d_in} d_out={d_out} (per call)"),
        &["variant", "time", "GFLOP/s", "vs fused"],
        &table,
    );

    // full model programs for context
    let mut rows = Vec::new();
    let mut t_train_step = 0f64;
    for p in ["sage_train_products-mini", "sage_fwd_products-mini"] {
        rt.load_program(&manifest, p)?;
        let t = time_call(&rt, p, 3, &mut rng)?;
        if p.contains("train") {
            t_train_step = t;
        }
        rows.push(vec![p.into(), format!("{:.3}ms", t * 1e3)]);
    }
    print_table("full L2 programs (per call)", &["program", "time"], &rows);

    write_bench_section(
        "update_kernel",
        vec![
            ("fused_ms", json::num(t_fused * 1e3)),
            ("unfused_ms", json::num(t_unfused * 1e3)),
            ("op_chain_ms", json::num(t_chain * 1e3)),
            ("fused_gflops", json::num(flops / t_fused / 1e9)),
            ("chain_vs_fused", json::num(t_chain / t_fused.max(1e-12))),
            ("train_step_ms", json::num(t_train_step * 1e3)),
        ],
    )?;

    // ---- bf16 packed row-block kernels vs the f32 scalar baseline ---------
    // Same UPDATE dimensions, dense random data (no zero-row shortcut for
    // either side). GB moved counts each operand once: bf16 halves the A
    // bytes, the 4-unrolled row-block loop quarters the output-tile
    // traffic — together the acceptance target is >= 1.5x on this bench.
    let (m, kd, n) = (rows_n, d_in, d_out);
    let a: Vec<f32> = (0..m * kd).map(|_| rng.gen_f32() - 0.5).collect();
    let bmat: Vec<f32> = (0..kd * n).map(|_| rng.gen_f32() - 0.5).collect();
    let g: Vec<f32> = (0..m * n).map(|_| rng.gen_f32() - 0.5).collect();
    let a16 = bf16::pack_slice(&a);
    let g16 = bf16::pack_slice(&g);
    let time = |f: &dyn Fn() -> Vec<f32>| -> f64 {
        std::hint::black_box(f()); // warmup
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            std::hint::black_box(f());
        }
        t0.elapsed().as_secs_f64() / reps as f64
    };
    let t_nn_f32 = time(&|| native::matmul(&a, m, kd, &bmat, n));
    let t_nn_b16 = time(&|| native::matmul_bf16(&a16, m, kd, &bmat, n));
    let t_tn_f32 = time(&|| native::matmul_tn(&a, m, kd, &g, n));
    let t_tn_b16 = time(&|| native::matmul_tn_bf16(&a16, m, kd, &g, n));
    let t_nt_f32 = time(&|| native::matmul_nt(&g, m, n, &bmat, kd));
    let t_nt_b16 = time(&|| native::matmul_nt_bf16(&g16, m, n, &bmat, kd));
    // bytes per call: A once + B once + C written once
    let gb_f32 = ((m * kd + kd * n + m * n) * 4) as f64;
    let gb_b16 = (m * kd * 2 + (kd * n + m * n) * 4) as f64;
    let speedup_nn = t_nn_f32 / t_nn_b16.max(1e-12);
    let rows2 = vec![
        vec![
            "NN  C=A.B".into(),
            format!("{:.3}ms", t_nn_f32 * 1e3),
            format!("{:.3}ms", t_nn_b16 * 1e3),
            fmt_gb(gb_f32),
            fmt_gb(gb_b16),
            format!("{:.2}x", speedup_nn),
        ],
        vec![
            "TN  dW=A^T.G".into(),
            format!("{:.3}ms", t_tn_f32 * 1e3),
            format!("{:.3}ms", t_tn_b16 * 1e3),
            fmt_gb(gb_f32),
            fmt_gb(gb_b16),
            format!("{:.2}x", t_tn_f32 / t_tn_b16.max(1e-12)),
        ],
        vec![
            "NT  dX=G.W^T".into(),
            format!("{:.3}ms", t_nt_f32 * 1e3),
            format!("{:.3}ms", t_nt_b16 * 1e3),
            fmt_gb(((m * n + kd * n + m * kd) * 4) as f64),
            fmt_gb((m * n * 2 + (kd * n + m * kd) * 4) as f64),
            format!("{:.2}x", t_nt_f32 / t_nt_b16.max(1e-12)),
        ],
    ];
    print_table(
        &format!("bf16 row-block kernels vs f32 scalar, m={m} k={kd} n={n} (per call)"),
        &["kernel", "f32", "bf16", "f32 GB", "bf16 GB", "speedup"],
        &rows2,
    );

    write_bench_section(
        "bf16_kernels",
        vec![
            ("m", json::num(m as f64)),
            ("k", json::num(kd as f64)),
            ("n", json::num(n as f64)),
            ("f32_nn_ms", json::num(t_nn_f32 * 1e3)),
            ("bf16_nn_ms", json::num(t_nn_b16 * 1e3)),
            ("f32_tn_ms", json::num(t_tn_f32 * 1e3)),
            ("bf16_tn_ms", json::num(t_tn_b16 * 1e3)),
            ("f32_nt_ms", json::num(t_nt_f32 * 1e3)),
            ("bf16_nt_ms", json::num(t_nt_b16 * 1e3)),
            ("f32_gb_moved", json::num(gb_f32 / 1e9)),
            ("bf16_gb_moved", json::num(gb_b16 / 1e9)),
            ("f32_gbps", json::num(gbps(gb_f32, t_nn_f32))),
            ("bf16_gbps", json::num(gbps(gb_b16, t_nn_b16))),
            ("bf16_speedup_vs_f32_scalar", json::num(speedup_nn)),
            (
                "bf16_tn_speedup",
                json::num(t_tn_f32 / t_tn_b16.max(1e-12)),
            ),
            (
                "bf16_nt_speedup",
                json::num(t_nt_f32 / t_nt_b16.max(1e-12)),
            ),
        ],
    )?;
    Ok(())
}
