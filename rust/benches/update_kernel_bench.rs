//! UPDATE-primitive micro-benchmark (L1 perf deliverable).
//!
//! Compares, at products-mini dimensions:
//!   * the fused UPDATE program (matmul+matmul+bias+ReLU+dropout in one
//!     pass over the output tile);
//!   * the same chain as one unfused program with materialized
//!     intermediates;
//!   * the op-by-op chain across five separate executables with
//!     host-visible intermediates (framework-style op dispatch).
//!
//! Also reports the full train-step and fwd program costs per call, which
//! anchor the FWD/BWD split calibration (DESIGN.md §7), and writes the
//! `update_kernel` section of BENCH_pipeline.json.

use distgnn_mb::benchkit::{print_table, write_bench_section};
use distgnn_mb::runtime::{HostTensor, Manifest, Runtime};
use distgnn_mb::util::json;
use distgnn_mb::util::rng::Pcg64;

fn rand_inputs(rt: &Runtime, name: &str, rng: &mut Pcg64) -> anyhow::Result<Vec<HostTensor>> {
    let exe = rt.program(name)?;
    Ok(exe
        .spec
        .inputs
        .iter()
        .map(|s| {
            let n: usize = s.shape.iter().product();
            match s.dtype {
                distgnn_mb::runtime::DType::F32 => HostTensor::f32(
                    s.shape.clone(),
                    &(0..n).map(|_| rng.gen_f32() - 0.5).collect::<Vec<_>>(),
                ),
                distgnn_mb::runtime::DType::I32 => {
                    HostTensor::i32(s.shape.clone(), &vec![0i32; n])
                }
                distgnn_mb::runtime::DType::U32 => {
                    HostTensor::u32(s.shape.clone(), &vec![0u32; n])
                }
            }
        })
        .collect())
}

fn time_call(rt: &Runtime, name: &str, reps: usize, rng: &mut Pcg64) -> anyhow::Result<f64> {
    let inputs = rand_inputs(rt, name, rng)?;
    let exe = rt.program(name)?;
    exe.run(&inputs)?;
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        std::hint::black_box(exe.run(&inputs)?);
    }
    Ok(t0.elapsed().as_secs_f64() / reps as f64)
}

fn main() -> anyhow::Result<()> {
    println!("### bench: update_kernel_bench");
    let manifest = Manifest::load_or_builtin("artifacts")?;
    let mut rt = Runtime::cpu()?;
    let progs = [
        "update_fused_products-mini",
        "update_unfused_full_products-mini",
        "update_mm_products-mini",
        "update_add_bias_products-mini",
        "update_relu_products-mini",
        "update_dropout_products-mini",
    ];
    for p in progs {
        rt.load_program(&manifest, p)?;
    }
    let reps: usize = std::env::var("DISTGNN_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10);
    let mut rng = Pcg64::seeded(5);

    let t_fused = time_call(&rt, "update_fused_products-mini", reps, &mut rng)?;
    let t_unfused = time_call(&rt, "update_unfused_full_products-mini", reps, &mut rng)?;
    let t_mm = time_call(&rt, "update_mm_products-mini", reps, &mut rng)?;
    let t_add = time_call(&rt, "update_add_bias_products-mini", reps, &mut rng)?;
    let t_relu = time_call(&rt, "update_relu_products-mini", reps, &mut rng)?;
    let t_drop = time_call(&rt, "update_dropout_products-mini", reps, &mut rng)?;
    let t_chain = 2.0 * t_mm + t_add + t_relu + t_drop;

    let spec = manifest.program("update_fused_products-mini")?;
    let rows_n = spec.meta_usize("rows")?;
    let d_in = spec.meta_usize("d_in")?;
    let d_out = spec.meta_usize("d_out")?;
    let flops = 2.0 * rows_n as f64 * d_in as f64 * d_out as f64 * 2.0; // two matmuls
    let table = vec![
        vec![
            "op-by-op chain (5 exes)".into(),
            format!("{:.3}ms", t_chain * 1e3),
            format!("{:.2}", flops / t_chain / 1e9),
            format!("{:.2}x", t_chain / t_fused),
        ],
        vec![
            "unfused single program".into(),
            format!("{:.3}ms", t_unfused * 1e3),
            format!("{:.2}", flops / t_unfused / 1e9),
            format!("{:.2}x", t_unfused / t_fused),
        ],
        vec![
            "fused Pallas program".into(),
            format!("{:.3}ms", t_fused * 1e3),
            format!("{:.2}", flops / t_fused / 1e9),
            "1.00x".into(),
        ],
    ];
    print_table(
        &format!("UPDATE primitive, rows={rows_n} d_in={d_in} d_out={d_out} (per call)"),
        &["variant", "time", "GFLOP/s", "vs fused"],
        &table,
    );

    // full model programs for context
    let mut rows = Vec::new();
    let mut t_train_step = 0f64;
    for p in ["sage_train_products-mini", "sage_fwd_products-mini"] {
        rt.load_program(&manifest, p)?;
        let t = time_call(&rt, p, 3, &mut rng)?;
        if p.contains("train") {
            t_train_step = t;
        }
        rows.push(vec![p.into(), format!("{:.3}ms", t * 1e3)]);
    }
    print_table("full L2 programs (per call)", &["program", "time"], &rows);

    write_bench_section(
        "update_kernel",
        vec![
            ("fused_ms", json::num(t_fused * 1e3)),
            ("unfused_ms", json::num(t_unfused * 1e3)),
            ("op_chain_ms", json::num(t_chain * 1e3)),
            ("fused_gflops", json::num(flops / t_fused / 1e9)),
            ("chain_vs_fused", json::num(t_chain / t_fused.max(1e-12))),
            ("train_step_ms", json::num(t_train_step * 1e3)),
        ],
    )?;
    Ok(())
}
