//! Shared helpers for the benchmark binaries (`benches/*.rs`).
//!
//! The offline environment has no criterion, so each bench is a
//! `harness = false` binary that prints a paper-style table; this module
//! centralizes run orchestration and formatting.

use anyhow::Result;

use crate::config::TrainConfig;
use crate::train::metrics::RunReport;
use crate::train::Driver;

/// Run a config for its configured epochs; returns the report.
/// The first epoch is a warmup (cold HEC, JIT-warm caches) — use
/// `RunReport::mean_epoch_time(1)` for steady-state numbers.
pub fn run(cfg: TrainConfig) -> Result<RunReport> {
    let mut driver = Driver::new(cfg)?;
    driver.train(None)?;
    Ok(driver.report.clone())
}

/// Render an ASCII table.
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: Vec<String>| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!("{}", line(headers.iter().map(|s| s.to_string()).collect()));
    println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
    for row in rows {
        println!("{}", line(row.clone()));
    }
}

/// Format seconds with 3 decimals.
pub fn fmt_s(x: f64) -> String {
    format!("{x:.3}")
}

/// Format a ratio ("speedup").
pub fn fmt_x(x: f64) -> String {
    format!("{x:.2}x")
}

/// Format a percentage.
pub fn fmt_pct(x: f64) -> String {
    format!("{:.0}%", x * 100.0)
}

/// Standard bench header echoing environment facts that matter for
/// interpreting the virtual-time numbers.
pub fn print_header(name: &str, cfg: &TrainConfig) {
    println!("### bench: {name}");
    println!("host cores: {}", crate::util::parallel::num_threads());
    println!("config: {}", cfg.to_json().to_json());
    println!(
        "note: epoch times are virtual-cluster seconds (measured compute + modeled network; DESIGN.md §1/§7)"
    );
}
