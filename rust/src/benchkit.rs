//! Shared helpers for the benchmark binaries (`benches/*.rs`).
//!
//! The offline environment has no criterion, so each bench is a
//! `harness = false` binary that prints a paper-style table; this module
//! centralizes run orchestration and formatting.

use anyhow::Result;

use crate::config::TrainConfig;
use crate::train::metrics::RunReport;
use crate::train::Driver;
use crate::util::json::{self, Value};

/// Run a config for its configured epochs; returns the report.
/// The first epoch is a warmup (cold HEC, JIT-warm caches) — use
/// `RunReport::mean_epoch_time(1)` for steady-state numbers.
pub fn run(cfg: TrainConfig) -> Result<RunReport> {
    let mut driver = Driver::new(cfg)?;
    driver.train(None)?;
    Ok(driver.report.clone())
}

/// Render an ASCII table.
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: Vec<String>| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!("{}", line(headers.iter().map(|s| s.to_string()).collect()));
    println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
    for row in rows {
        println!("{}", line(row.clone()));
    }
}

/// Format seconds with 3 decimals.
pub fn fmt_s(x: f64) -> String {
    format!("{x:.3}")
}

/// Format a ratio ("speedup").
pub fn fmt_x(x: f64) -> String {
    format!("{x:.2}x")
}

/// Format a percentage.
pub fn fmt_pct(x: f64) -> String {
    format!("{:.0}%", x * 100.0)
}

/// Format a byte count in GB (decimal, 3 decimals — kernel working sets).
pub fn fmt_gb(bytes: f64) -> String {
    format!("{:.3}GB", bytes / 1e9)
}

/// Effective memory throughput in GB/s for `bytes` moved in `secs`.
pub fn gbps(bytes: f64, secs: f64) -> f64 {
    bytes / secs.max(1e-12) / 1e9
}

/// Machine-readable bench output: merge `entries` as object `section` of
/// the JSON report (default `BENCH_pipeline.json`, override with
/// `DISTGNN_BENCH_OUT`). Each bench writes its own section, so the file
/// accumulates the run's whole perf picture and the repo's perf trajectory
/// stays diffable from this PR onward.
pub fn write_bench_section(section: &str, entries: Vec<(&str, Value)>) -> Result<()> {
    let path =
        std::env::var("DISTGNN_BENCH_OUT").unwrap_or_else(|_| "BENCH_pipeline.json".to_string());
    let mut root = std::fs::read_to_string(&path)
        .ok()
        .and_then(|text| json::parse(&text).ok())
        .unwrap_or_else(|| json::obj(vec![]));
    if root.as_obj().is_none() {
        root = json::obj(vec![]);
    }
    if let Value::Obj(map) = &mut root {
        map.insert("host_threads".to_string(), json::num(crate::util::parallel::num_threads() as f64));
        map.insert(section.to_string(), json::obj(entries));
    }
    std::fs::write(&path, root.to_json_pretty())?;
    println!("[benchkit] wrote section '{section}' to {path}");
    Ok(())
}

/// Standard bench header echoing environment facts that matter for
/// interpreting the virtual-time numbers.
pub fn print_header(name: &str, cfg: &TrainConfig) {
    println!("### bench: {name}");
    println!("host cores: {}", crate::util::parallel::num_threads());
    println!("config: {}", cfg.to_json().to_json());
    if cfg.fabric == crate::config::FabricKind::Socket {
        println!(
            "note: socket fabric — comm times are measured wall-clock on real sockets"
        );
    } else {
        println!(
            "note: epoch times are virtual-cluster seconds (measured compute + modeled network; DESIGN.md §1/§7)"
        );
    }
}
