//! Gradient all-reduce.
//!
//! The arithmetic (averaging the per-rank flattened gradient vectors) runs
//! for real; the wire time comes from the ring-all-reduce formula in
//! [`crate::comm::netsim`]. Data-parallel training synchronizes at this
//! point, so the driver also aligns all virtual clocks to
//! `max(rank clocks) + ring cost` — rank idle time at the barrier is how
//! load imbalance manifests, exactly as in the paper's ARed component.

use crate::comm::netsim::NetSim;

/// Average `grads[r]` element-wise across ranks, in place.
/// Returns the measured local reduction time in seconds.
pub fn average_inplace(grads: &mut [Vec<f32>]) -> f64 {
    let t0 = std::time::Instant::now();
    let k = grads.len();
    if k <= 1 {
        return t0.elapsed().as_secs_f64();
    }
    let n = grads[0].len();
    debug_assert!(grads.iter().all(|g| g.len() == n));
    let inv = 1.0 / k as f32;
    // reduce into rank 0's buffer
    let (first, rest) = grads.split_at_mut(1);
    let acc = &mut first[0];
    for g in rest.iter() {
        for (a, &b) in acc.iter_mut().zip(g.iter()) {
            *a += b;
        }
    }
    for a in acc.iter_mut() {
        *a *= inv;
    }
    // broadcast back
    let (first, rest) = grads.split_at_mut(1);
    for g in rest.iter_mut() {
        g.copy_from_slice(&first[0]);
    }
    t0.elapsed().as_secs_f64()
}

/// Synchronize clocks at the all-reduce barrier: every rank leaves at
/// `max(clock) + ring_time`. Returns (new common clock, per-rank ared time
/// charged = idle wait + wire time).
pub fn barrier_allreduce(
    clocks: &mut [f64],
    bytes: usize,
    netsim: &NetSim,
    measured_reduce: f64,
) -> Vec<f64> {
    let k = clocks.len();
    let maxc = clocks.iter().cloned().fold(0.0f64, f64::max);
    let wire = netsim.allreduce(k, bytes) + measured_reduce;
    let mut charged = Vec::with_capacity(k);
    for c in clocks.iter_mut() {
        charged.push((maxc - *c) + wire);
        *c = maxc + wire;
    }
    charged
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetConfig;

    #[test]
    fn average_is_exact() {
        let mut g = vec![vec![1.0f32, 2.0, 3.0], vec![3.0, 2.0, 1.0], vec![2.0, 2.0, 2.0]];
        average_inplace(&mut g);
        for r in 0..3 {
            assert_eq!(g[r], vec![2.0, 2.0, 2.0]);
        }
    }

    #[test]
    fn single_rank_noop() {
        let mut g = vec![vec![5.0f32, 7.0]];
        average_inplace(&mut g);
        assert_eq!(g[0], vec![5.0, 7.0]);
    }

    #[test]
    fn barrier_aligns_clocks_and_charges_idle() {
        let net = NetSim::new(NetConfig {
            latency: 0.0,
            bandwidth: 1e9,
            rpc_latency: 0.0,
            kvstore_bandwidth: 1e18,
        });
        let mut clocks = vec![1.0, 3.0, 2.0];
        let charged = barrier_allreduce(&mut clocks, 1_000_000_000, &net, 0.0);
        // wire = 2*(2/3)*1.0 = 4/3
        let wire = 4.0 / 3.0;
        assert!((clocks[0] - (3.0 + wire)).abs() < 1e-9);
        assert!(clocks.iter().all(|&c| (c - clocks[0]).abs() < 1e-12));
        // slowest rank charged only the wire time; fastest charged idle+wire
        assert!((charged[1] - wire).abs() < 1e-9);
        assert!((charged[0] - (2.0 + wire)).abs() < 1e-9);
    }
}
