//! Gradient all-reduce: the single-process reference reduction, the
//! modeled barrier, and the transport-agnostic ring collective the socket
//! fabric runs over real wires.
//!
//! In the sim path the arithmetic (averaging the per-rank flattened
//! gradient vectors) runs for real and the wire time comes from the
//! ring-all-reduce formula in [`crate::comm::netsim`]. Data-parallel
//! training synchronizes at this point, so the driver also aligns all
//! virtual clocks to `max(rank clocks) + ring cost` — rank idle time at
//! the barrier is how load imbalance manifests, exactly as in the paper's
//! ARed component.
//!
//! The real-transport ring ([`ring_average_f32`]) is a true
//! reduce-scatter followed by an allgather, moving the optimal
//! `2·(k-1)/k·N` bytes per rank. The repo-wide *canonical reduction
//! order* is the order this ring naturally produces: the buffer is split
//! into `k` contiguous chunks ([`chunk_bounds`]) and chunk `c`'s sum is
//! the left fold over ranks `c, c+1, …, c+k-1 (mod k)`, then scaled by
//! `1/k as f32`. [`average_inplace`] — the single-process / `SimFabric`
//! reference — applies the *identical* chunked rotated fold, so ring and
//! serial results are bit-identical for every k and the
//! bit-identical-losses contract between `SimFabric` and `SocketFabric`
//! holds by construction. (IEEE-754 addition is commutative, so for
//! k ≤ 2 this order coincides with the plain rank-0..k fold.)
//!
//! A rank dying mid-collective surfaces here as a typed
//! [`crate::comm::PeerDied`] out of [`RingLink::recv_prev`] (the socket
//! implementation fails fast on peer EOF / heartbeat staleness instead of
//! waiting out the receive timeout); the ring helpers propagate it
//! unchanged so the driver can exit retryably for a supervisor.

use anyhow::Result;

use crate::comm::netsim::NetSim;

/// One rank's view of a ring: send to the next neighbor `(rank+1) % k`,
/// receive from the previous `(rank+k-1) % k`. Implementations: in-memory
/// channels (tests) and framed sockets (`SocketFabric`).
pub trait RingLink {
    fn send_next(&mut self, payload: &[u8]) -> Result<()>;
    fn recv_prev(&mut self) -> Result<Vec<u8>>;
}

fn f32s_to_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn bytes_to_f32s(b: &[u8]) -> Result<Vec<f32>> {
    anyhow::ensure!(b.len() % 4 == 0, "ring payload not f32-aligned");
    Ok(b.chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

fn f64s_to_bytes(xs: &[f64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 8);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn bytes_to_f64s(b: &[u8]) -> Result<Vec<f64>> {
    anyhow::ensure!(b.len() % 8 == 0, "ring payload not f64-aligned");
    Ok(b.chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Ring allgather of one byte payload per rank; returns all `k` payloads
/// in rank order. `k-1` hops: each hop forwards the payload received on
/// the previous hop (starting with our own), so after `k-1` steps every
/// rank holds every origin's payload bit-exactly.
pub fn ring_allgather(
    rank: usize,
    k: usize,
    local: Vec<u8>,
    link: &mut dyn RingLink,
) -> Result<Vec<Vec<u8>>> {
    let mut parts: Vec<Option<Vec<u8>>> = (0..k).map(|_| None).collect();
    for s in 1..k {
        // forward what the previous hop delivered (hop 1 sends our own)
        let outgoing: &[u8] = if s == 1 {
            &local
        } else {
            parts[(rank + k - (s - 1)) % k].as_deref().expect("prior hop filled")
        };
        link.send_next(outgoing)?;
        let incoming = link.recv_prev()?;
        // hop s delivers the payload that originated s ranks behind us
        parts[(rank + k - s) % k] = Some(incoming);
    }
    parts[rank] = Some(local);
    Ok(parts.into_iter().map(|p| p.expect("ring filled")).collect())
}

/// Bounds `[start, end)` of chunk `c` when a length-`n` buffer is split
/// into `k` contiguous chunks: the first `n % k` chunks get one extra
/// element. This split is part of the canonical reduction order — the
/// serial reference and the ring must agree on it exactly.
pub fn chunk_bounds(n: usize, k: usize, c: usize) -> (usize, usize) {
    debug_assert!(c < k);
    let base = n / k;
    let rem = n % k;
    let start = c * base + c.min(rem);
    let end = start + base + usize::from(c < rem);
    (start, end)
}

/// Ring all-reduce (average) of `local` across `k` ranks, in place, as a
/// reduce-scatter followed by an allgather — `2·(k-1)/k·N` bytes per
/// rank, the optimal ring volume.
///
/// Reduce-scatter step `s` (of `k-1`): rank `r` sends its running sum of
/// chunk `(r-s) mod k` and folds the received chunk `(r-1-s) mod k` into
/// its own contribution (`recv + own`, a left fold along the ring). After
/// `k-1` steps rank `r` owns the fully reduced chunk `(r+1) mod k`, which
/// it scales by `1/k as f32`. The allgather then circulates the scaled
/// chunks. Chunk `c`'s accumulation order is therefore the left fold over
/// ranks `c, c+1, …, c+k-1 (mod k)` — exactly the canonical order
/// [`average_inplace`] applies, so results are bit-identical to the
/// serial reference for every k.
pub fn ring_average_f32(
    rank: usize,
    k: usize,
    local: &mut [f32],
    link: &mut dyn RingLink,
) -> Result<()> {
    if k <= 1 {
        return Ok(());
    }
    let n = local.len();
    // --- reduce-scatter: k-1 steps of send-chunk / fold-received ---
    for s in 0..k - 1 {
        let send_c = (rank + k - s) % k;
        let recv_c = (rank + 2 * k - 1 - s) % k;
        let (ss, se) = chunk_bounds(n, k, send_c);
        link.send_next(&f32s_to_bytes(&local[ss..se]))?;
        let incoming = bytes_to_f32s(&link.recv_prev()?)?;
        let (rs, re) = chunk_bounds(n, k, recv_c);
        anyhow::ensure!(
            incoming.len() == re - rs,
            "reduce-scatter chunk length mismatch: got {} want {}",
            incoming.len(),
            re - rs
        );
        // left fold along the ring: the received running sum comes first
        for (a, &b) in local[rs..re].iter_mut().zip(incoming.iter()) {
            *a = b + *a;
        }
    }
    // rank r now owns fully reduced chunk (r+1) mod k — scale it
    let own_c = (rank + 1) % k;
    let inv = 1.0 / k as f32;
    let (os, oe) = chunk_bounds(n, k, own_c);
    for a in local[os..oe].iter_mut() {
        *a *= inv;
    }
    // --- allgather: circulate the scaled chunks ---
    for s in 0..k - 1 {
        let send_c = (rank + 1 + k - s) % k;
        let recv_c = (rank + k - s) % k;
        let (ss, se) = chunk_bounds(n, k, send_c);
        link.send_next(&f32s_to_bytes(&local[ss..se]))?;
        let incoming = bytes_to_f32s(&link.recv_prev()?)?;
        let (rs, re) = chunk_bounds(n, k, recv_c);
        anyhow::ensure!(
            incoming.len() == re - rs,
            "allgather chunk length mismatch: got {} want {}",
            incoming.len(),
            re - rs
        );
        local[rs..re].copy_from_slice(&incoming);
    }
    Ok(())
}

/// Ring allgather of one f64 vector per rank; returns all `k` vectors in
/// rank order, transported bit-exactly.
pub fn ring_allgather_f64(
    rank: usize,
    k: usize,
    local: &[f64],
    link: &mut dyn RingLink,
) -> Result<Vec<Vec<f64>>> {
    if k <= 1 {
        return Ok(vec![local.to_vec()]);
    }
    let parts = ring_allgather(rank, k, f64s_to_bytes(local), link)?;
    parts.iter().map(|p| bytes_to_f64s(p)).collect()
}

/// Average `grads[r]` element-wise across ranks, in place, using the
/// canonical chunked rotated-fold order: the buffer splits into `k`
/// contiguous chunks ([`chunk_bounds`]) and chunk `c` accumulates as the
/// left fold over ranks `c, c+1, …, c+k-1 (mod k)`, then scales by
/// `1/k as f32`. This is exactly the order [`ring_average_f32`]'s
/// reduce-scatter produces, so serial and ring results are bit-identical.
/// Returns the measured local reduction time in seconds.
pub fn average_inplace(grads: &mut [Vec<f32>]) -> f64 {
    let t0 = std::time::Instant::now();
    let k = grads.len();
    if k <= 1 {
        return t0.elapsed().as_secs_f64();
    }
    let n = grads[0].len();
    debug_assert!(grads.iter().all(|g| g.len() == n));
    let inv = 1.0 / k as f32;
    let mut out = vec![0.0f32; n];
    for c in 0..k {
        let (cs, ce) = chunk_bounds(n, k, c);
        let acc = &mut out[cs..ce];
        acc.copy_from_slice(&grads[c][cs..ce]);
        for hop in 1..k {
            let r = (c + hop) % k;
            for (a, &b) in acc.iter_mut().zip(grads[r][cs..ce].iter()) {
                *a += b;
            }
        }
        for a in acc.iter_mut() {
            *a *= inv;
        }
    }
    for g in grads.iter_mut() {
        g.copy_from_slice(&out);
    }
    t0.elapsed().as_secs_f64()
}

/// Synchronize clocks at the all-reduce barrier: every rank leaves at
/// `max(clock) + ring_time`. Returns (new common clock, per-rank ared time
/// charged = idle wait + wire time).
pub fn barrier_allreduce(
    clocks: &mut [f64],
    bytes: usize,
    netsim: &NetSim,
    measured_reduce: f64,
) -> Vec<f64> {
    let k = clocks.len();
    let maxc = clocks.iter().cloned().fold(0.0f64, f64::max);
    let wire = netsim.allreduce(k, bytes) + measured_reduce;
    let mut charged = Vec::with_capacity(k);
    for c in clocks.iter_mut() {
        charged.push((maxc - *c) + wire);
        *c = maxc + wire;
    }
    charged
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetConfig;

    #[test]
    fn average_is_exact() {
        let mut g = vec![vec![1.0f32, 2.0, 3.0], vec![3.0, 2.0, 1.0], vec![2.0, 2.0, 2.0]];
        average_inplace(&mut g);
        for r in 0..3 {
            assert_eq!(g[r], vec![2.0, 2.0, 2.0]);
        }
    }

    #[test]
    fn single_rank_noop() {
        let mut g = vec![vec![5.0f32, 7.0]];
        average_inplace(&mut g);
        assert_eq!(g[0], vec![5.0, 7.0]);
    }

    /// In-memory ring link over mpsc channels (one thread per rank).
    struct ChanLink {
        tx_next: std::sync::mpsc::Sender<Vec<u8>>,
        rx_prev: std::sync::mpsc::Receiver<Vec<u8>>,
    }

    impl RingLink for ChanLink {
        fn send_next(&mut self, payload: &[u8]) -> Result<()> {
            self.tx_next
                .send(payload.to_vec())
                .map_err(|_| anyhow::anyhow!("ring peer gone"))
        }
        fn recv_prev(&mut self) -> Result<Vec<u8>> {
            self.rx_prev
                .recv_timeout(std::time::Duration::from_secs(10))
                .map_err(|e| anyhow::anyhow!("ring recv: {e}"))
        }
    }

    /// Build a k-rank ring of channel links: rank r sends into channel
    /// (r+1)%k and receives from channel r.
    fn ring_links(k: usize) -> Vec<ChanLink> {
        let (txs, rxs): (Vec<_>, Vec<_>) =
            (0..k).map(|_| std::sync::mpsc::channel::<Vec<u8>>()).unzip();
        rxs.into_iter()
            .enumerate()
            .map(|(r, rx_prev)| ChanLink {
                tx_next: txs[(r + 1) % k].clone(),
                rx_prev,
            })
            .collect()
    }

    /// Run the ring average across k threads; returns every rank's result.
    fn run_ring_average(inputs: Vec<Vec<f32>>) -> Vec<Vec<f32>> {
        let k = inputs.len();
        let links = ring_links(k);
        let handles: Vec<_> = inputs
            .into_iter()
            .zip(links)
            .enumerate()
            .map(|(r, (mut local, mut link))| {
                std::thread::spawn(move || {
                    ring_average_f32(r, k, &mut local, &mut link).unwrap();
                    local
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    /// Satellite: ring allreduce result equivalence across 1/2/8 ranks,
    /// bit-identical to the serial `average_inplace` reference.
    #[test]
    fn ring_average_matches_serial_reference_across_rank_counts() {
        for &k in &[1usize, 2, 8] {
            let n = 37;
            let inputs: Vec<Vec<f32>> = (0..k)
                .map(|r| {
                    (0..n)
                        .map(|i| ((r * 31 + i * 7) as f32).sin() * 3.7 + 0.1)
                        .collect()
                })
                .collect();
            // serial reference
            let mut reference = inputs.clone();
            average_inplace(&mut reference);
            let results = run_ring_average(inputs);
            for (r, res) in results.iter().enumerate() {
                for (i, (&a, &b)) in res.iter().zip(reference[0].iter()).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "k={k} rank {r} element {i}: ring {a} != serial {b}"
                    );
                }
            }
        }
    }

    /// Link wrapper that counts payload bytes sent by one rank.
    struct CountingLink {
        inner: ChanLink,
        sent: std::sync::Arc<std::sync::atomic::AtomicUsize>,
    }

    impl RingLink for CountingLink {
        fn send_next(&mut self, payload: &[u8]) -> Result<()> {
            self.sent
                .fetch_add(payload.len(), std::sync::atomic::Ordering::Relaxed);
            self.inner.send_next(payload)
        }
        fn recv_prev(&mut self) -> Result<Vec<u8>> {
            self.inner.recv_prev()
        }
    }

    /// Satellite: the reduce-scatter + allgather ring moves exactly
    /// `2·(k-1)·N/k` bytes per rank (N = payload bytes) when k divides n —
    /// the optimal ring volume, not the allgather ring's `(k-1)·N`.
    #[test]
    fn ring_average_bytes_per_rank_match_reduce_scatter_formula() {
        for &(k, n) in &[(4usize, 64usize), (8, 64), (3, 37)] {
            let counters: Vec<_> = (0..k)
                .map(|_| std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0)))
                .collect();
            let links = ring_links(k);
            let handles: Vec<_> = links
                .into_iter()
                .enumerate()
                .map(|(r, inner)| {
                    let sent = counters[r].clone();
                    std::thread::spawn(move || {
                        let mut local: Vec<f32> = (0..n).map(|i| (r * n + i) as f32).collect();
                        let mut link = CountingLink { inner, sent };
                        ring_average_f32(r, k, &mut local, &mut link).unwrap();
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            // rank r sends chunks (r-s)%k in reduce-scatter and
            // (r+1-s)%k in allgather — with uneven chunks the exact total
            // is the sum of those chunk sizes; with k | n it is exactly
            // 2*(k-1)*N/k bytes.
            for (r, cnt) in counters.iter().enumerate() {
                let mut expect = 0usize;
                for s in 0..k - 1 {
                    let (a, b) = chunk_bounds(n, k, (r + k - s) % k);
                    expect += (b - a) * 4;
                    let (a, b) = chunk_bounds(n, k, (r + 1 + k - s) % k);
                    expect += (b - a) * 4;
                }
                let got = cnt.load(std::sync::atomic::Ordering::Relaxed);
                assert_eq!(got, expect, "k={k} n={n} rank {r}");
                if n % k == 0 {
                    assert_eq!(got, 2 * (k - 1) * (n * 4) / k, "k={k} n={n} rank {r}");
                }
            }
        }
    }

    #[test]
    fn chunk_bounds_cover_buffer_exactly() {
        for &(n, k) in &[(0usize, 3usize), (1, 4), (37, 3), (64, 8), (5, 8)] {
            let mut next = 0;
            for c in 0..k {
                let (s, e) = chunk_bounds(n, k, c);
                assert_eq!(s, next, "n={n} k={k} c={c}");
                assert!(e >= s);
                next = e;
            }
            assert_eq!(next, n, "n={n} k={k}");
        }
    }

    #[test]
    fn ring_allgather_f64_returns_rank_order_bit_exact() {
        let k = 4;
        let links = ring_links(k);
        let handles: Vec<_> = links
            .into_iter()
            .enumerate()
            .map(|(r, mut link)| {
                std::thread::spawn(move || {
                    let local = vec![r as f64 * 1.25 + 0.1, -(r as f64)];
                    ring_allgather_f64(r, k, &local, &mut link).unwrap()
                })
            })
            .collect();
        let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for res in &results {
            assert_eq!(res.len(), k);
            for (origin, v) in res.iter().enumerate() {
                assert_eq!(v[0].to_bits(), (origin as f64 * 1.25 + 0.1).to_bits());
                assert_eq!(v[1].to_bits(), (-(origin as f64)).to_bits());
            }
        }
    }

    #[test]
    fn ring_average_single_rank_noop() {
        let mut local = vec![5.0f32, 7.0];
        // k=1 never touches the link
        struct NoLink;
        impl RingLink for NoLink {
            fn send_next(&mut self, _: &[u8]) -> Result<()> {
                panic!("k=1 must not use the link")
            }
            fn recv_prev(&mut self) -> Result<Vec<u8>> {
                panic!("k=1 must not use the link")
            }
        }
        ring_average_f32(0, 1, &mut local, &mut NoLink).unwrap();
        assert_eq!(local, vec![5.0, 7.0]);
    }

    #[test]
    fn barrier_aligns_clocks_and_charges_idle() {
        let net = NetSim::new(NetConfig {
            latency: 0.0,
            bandwidth: 1e9,
            rpc_latency: 0.0,
            kvstore_bandwidth: 1e18,
        });
        let mut clocks = vec![1.0, 3.0, 2.0];
        let charged = barrier_allreduce(&mut clocks, 1_000_000_000, &net, 0.0);
        // wire = 2*(2/3)*1.0 = 4/3
        let wire = 4.0 / 3.0;
        assert!((clocks[0] - (3.0 + wire)).abs() < 1e-9);
        assert!(clocks.iter().all(|&c| (c - clocks[0]).abs() < 1e-12));
        // slowest rank charged only the wire time; fastest charged idle+wire
        assert!((charged[1] - wire).abs() < 1e-9);
        assert!((charged[0] - (2.0 + wire)).abs() < 1e-9);
    }
}
