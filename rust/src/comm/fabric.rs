//! The pluggable communication fabric: one trait, two transports.
//!
//! [`Fabric`] is the seam between the training driver and whatever moves
//! AEP pushes and gradients between ranks. [`SimFabric`] (this file) is
//! the in-memory implementation with netsim-modeled time — the
//! single-process default and the deterministic test path, where every
//! rank lives in one process and "time" is a virtual clock. The real
//! multi-process transport over TCP/Unix sockets is
//! [`crate::comm::socket::SocketFabric`]; it implements the same trait
//! with wall-clock accounting, so the driver is transport-agnostic.
//!
//! [`PushMsg`] carries one AEP payload: (layer, VID_o list, embeddings).
//! Messages are enqueued with the (global) iteration at which they were
//! sent; the receiver drains messages sent at iteration `<= k - d` when
//! processing its own iteration `k` (Algorithm 2 lines 7-9) and charges
//! the non-overlapped wait.
//!
//! # Iteration-window delivery contract
//!
//! Both transports implement the same delivery semantics, which the
//! bit-identical-loss guarantee depends on:
//!
//! 1. `receive_upto(rank, w)` returns **exactly** the messages sent at
//!    global iteration `<= w`, in sender-rank order with FIFO order
//!    within a sender — never a prefix, never extras. The sim's stepped
//!    loop makes this trivial; the socket transport blocks until every
//!    peer's ITER_DONE watermark passes `w` before draining (see
//!    [`crate::comm::socket`]).
//! 2. `complete_iteration(rank, k)` is the sender-side watermark: after
//!    it, no further messages with `sent_iter <= k` will ever be sent.
//!    Every rank must watermark every AEP iteration — even ones where it
//!    pushed nothing — or a real transport's receivers deadlock.
//! 3. **Sliding window** (`set_pipeline_window(p)`): a sender may have
//!    pushes for at most `p` iterations outstanding past its own
//!    watermark — the depth-`p` generalization of the double buffer's
//!    implicit "previous iteration complete" promise. Both transports
//!    enforce it through [`crate::comm::netsim::IterWindow`]: a push with
//!    `sent_iter > watermark + p` is a typed protocol error, never silent
//!    unbounded buffering. The socket transport advertises `p` in its
//!    rendezvous HELLO and on every windowed ITER_DONE frame; the sim
//!    checks its own senders directly.
//! 4. Payload bits are transported exactly (raw IEEE-754 f32 or raw bf16
//!    patterns, [`PushPayload`]), so HEC contents — and therefore losses —
//!    cannot depend on the transport.

use std::collections::VecDeque;
use std::sync::Arc;

use anyhow::Result;

use crate::comm::allreduce;
use crate::comm::faults::{FaultPlan, PeerDied};
use crate::comm::netsim::{IterWindow, NetSim};

/// Server side of the lookahead-prefetch seam: a rank's locally owned
/// (solid) feature rows, served to peers' `PREFETCH_REQ` pulls. The
/// driver registers one per local rank
/// ([`Fabric::register_prefetch_source`]); the sim fabric calls it
/// inline, the socket fabric from its per-peer reader threads (hence
/// `Send + Sync`).
pub trait PrefetchSource: Send + Sync {
    /// Feature dimensionality of the served rows.
    fn dim(&self) -> usize;
    /// The f32 feature row of `vid_o`, or `None` if this rank does not
    /// own that vertex.
    fn row(&self, vid_o: u32) -> Option<Vec<f32>>;
}

/// One prefetched feature row, landed and awaiting drain.
#[derive(Clone, Debug, PartialEq)]
pub struct PrefetchedRow {
    /// Original vertex id (VID_o).
    pub vid: u32,
    /// Virtual time at which the row is fully received (SimFabric's
    /// modeled pull round trip); 0.0 on real transports, where presence
    /// in the drain already means "arrived".
    pub arrival: f64,
    /// The owner's f32 feature row.
    pub row: Vec<f32>,
}

/// Embedding rows of one push, in the run's storage dtype
/// (`--dtype`): raw f32 values or packed bf16 bit patterns
/// ([`crate::runtime::bf16`]). bf16 payloads halve AEP wire bytes — the
/// netsim prices and the socket frames both see the packed size.
#[derive(Clone, Debug, PartialEq)]
pub enum PushPayload {
    F32(Vec<f32>),
    Bf16(Vec<u16>),
}

impl PushPayload {
    /// Number of embedding elements (rows x dim).
    pub fn len(&self) -> usize {
        match self {
            PushPayload::F32(v) => v.len(),
            PushPayload::Bf16(v) => v.len(),
        }
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Bytes per element on the wire (4 or 2).
    pub fn elem_bytes(&self) -> usize {
        match self {
            PushPayload::F32(_) => 4,
            PushPayload::Bf16(_) => 2,
        }
    }
    /// Total payload bytes.
    pub fn bytes(&self) -> usize {
        self.len() * self.elem_bytes()
    }
}

/// One asynchronous embedding push.
#[derive(Clone, Debug, PartialEq)]
pub struct PushMsg {
    pub from: u32,
    pub layer: usize,
    /// Original vertex ids (HEC tags).
    pub vids: Vec<u32>,
    /// Row-major embeddings, vids.len() x dim, in storage dtype.
    pub embeds: PushPayload,
    pub dim: usize,
    /// Sender iteration index (global across epochs: `epoch * m_max + k`).
    pub sent_iter: usize,
    /// Virtual time at which the payload is fully received (SimFabric);
    /// unused on real transports.
    pub arrival: f64,
}

impl PushMsg {
    pub fn bytes(&self) -> usize {
        self.vids.len() * 4 + self.embeds.bytes()
    }
}

/// Cumulative traffic and overlap statistics of a fabric.
///
/// For [`SimFabric`] the time fields are modeled (virtual seconds); for a
/// real transport they are measured wall-clock seconds. `1 - wait/flight`
/// is the overlap efficiency the benches report.
#[derive(Clone, Copy, Debug, Default)]
pub struct FabricStats {
    pub msgs_sent: u64,
    pub bytes_sent: u64,
    /// Bytes the topology says actually leave a host: pushes, prefetch
    /// round trips, and ring-allreduce chunks whose endpoints live on
    /// different hosts. Intra-host (shared-memory) traffic is excluded.
    /// Without a `--hosts` topology every rank is its own host, so this
    /// equals the full traffic — the topology-oblivious flat baseline.
    pub wire_bytes: u64,
    /// Message flight time (send → arrival): the overlap *opportunity* of
    /// the delayed-push window. On a real transport this is the time
    /// payloads sat fully received before the receiver consumed them.
    pub flight_secs: f64,
    /// Receiver wait actually charged (the non-hidden remainder).
    pub wait_secs: f64,
}

/// Transport seam between the training driver and the network.
///
/// All collective methods (`allreduce_grads`, `align_clocks`,
/// `allgather_stats`) must be called in the same order by every rank —
/// they are matched positionally on real transports. `grads`/`clocks`
/// hold one entry per *local* rank: all `k` ranks for [`SimFabric`],
/// exactly one for a multi-process transport.
pub trait Fabric: Send {
    /// Total rank count (global, not local).
    fn ranks(&self) -> usize;

    /// Whether comm time is measured wall-clock (real transport) rather
    /// than modeled by netsim.
    fn is_real(&self) -> bool;

    /// Inject one iteration's fan-out of pushes from a single sender.
    /// All messages share the sender's injection port, so the sender-side
    /// cost is priced as one alltoall (cumulative bytes over bandwidth +
    /// one latency per *destination*), not per message. Returns the
    /// seconds charged to the sender's clock.
    fn send_pushes(&mut self, sends: Vec<(u32, PushMsg)>, sender_now: f64) -> Result<f64>;

    /// Drain every message destined to `rank` that was sent at (global)
    /// iteration `<= max_sent_iter`, in sender-rank order (FIFO within a
    /// sender). Returns (messages, non-overlapped wait seconds).
    fn receive_upto(
        &mut self,
        rank: u32,
        max_sent_iter: usize,
        receiver_now: f64,
    ) -> Result<(Vec<PushMsg>, f64)>;

    /// Watermark: `rank` finished the push phase of (global) iteration
    /// `iter`. Real transports broadcast this so receivers know the
    /// delayed-delivery window is complete; the sim records it locally to
    /// enforce the sliding pipeline window on its own senders.
    fn complete_iteration(&mut self, rank: u32, iter: usize) -> Result<()>;

    /// Declare the run's pipeline depth `p`: senders promise never to
    /// have pushes for more than `p` iterations outstanding past their
    /// own watermark, and receivers enforce that promise (the sliding
    /// ITER_DONE window). Call once, before the first push; defaults to 1
    /// (the classic double buffer).
    fn set_pipeline_window(&mut self, depth: usize) -> Result<()>;

    /// Arm a deterministic fault-injection plan for restart generation
    /// `gen` (see [`crate::comm::faults`]). Actions fire at the matching
    /// `complete_iteration` call: a real transport aborts the process /
    /// drops its connections; the sim models the death as a typed
    /// [`PeerDied`]. Default: ignore (fault injection off).
    fn set_fault_plan(&mut self, _plan: FaultPlan, _gen: u32) -> Result<()> {
        Ok(())
    }

    /// Declare that this process restarted from a checkpoint taken at
    /// `(epoch, iter)` — call once after rendezvous, before any push.
    /// Baselines peer watermarks to `iter - 1` so the sliding window
    /// accepts the first post-resume push, and (on real transports)
    /// announces the resume point to peers, who verify it matches their
    /// own — a mismatch means some rank restarted from a stale
    /// checkpoint. Default: no-op (fresh run).
    fn set_resume_point(&mut self, _epoch: u64, _iter: u64) -> Result<()> {
        Ok(())
    }

    /// Register the serving side of the prefetch seam for a local rank:
    /// peers' PREFETCH_REQ pulls for vertices owned by `rank` are
    /// answered from `src`. Call once per local rank before the first
    /// `prefetch_pull`. Default: ignore (transport serves no prefetch).
    fn register_prefetch_source(&mut self, _rank: u32, _src: Arc<dyn PrefetchSource>) {}

    /// Issue one batched lookahead pull from `from_rank`: `per_owner[o]`
    /// lists the VID_o misses owned by rank `o` (empty entries are
    /// skipped, as is `per_owner[from_rank]`). Rows land asynchronously
    /// in `from_rank`'s staging queue and are collected by
    /// [`Fabric::drain_prefetch`]; the pull never blocks the caller and
    /// is never charged to the sender's clock — hiding that cost is the
    /// whole point. `now` is the issuing rank's current (virtual) time,
    /// used by modeled transports to stamp arrivals. Default: no-op.
    fn prefetch_pull(&mut self, _from_rank: u32, _per_owner: &[Vec<u32>], _now: f64) -> Result<()> {
        Ok(())
    }

    /// Force any transport-side push batching to emit its pending frames
    /// now. Batching transports (`--push-batch` on the socket fabric)
    /// hold up to `push_batch` iterations of pushes in a pending buffer;
    /// a checkpoint taken while that buffer is non-empty would let a
    /// frame straddle the checkpoint write and break the ckpt+resume
    /// bit-identity contract. The driver calls this immediately before
    /// the all-ranks HEC flush that precedes a checkpoint. Default:
    /// no-op (unbatched transports have nothing pending).
    fn flush_pushes(&mut self) -> Result<()> {
        Ok(())
    }

    /// Collect every prefetched row that has landed for `rank` since the
    /// last drain. Rows may arrive in any order and may include vertices
    /// the packer no longer needs (the wasted-prefetch case); the staging
    /// layer above classifies them. Default: empty.
    fn drain_prefetch(&mut self, _rank: u32) -> Vec<PrefetchedRow> {
        Vec::new()
    }

    /// Average the per-local-rank gradient vectors across *all* ranks,
    /// in place, and advance `clocks` past the all-reduce barrier.
    /// Returns the per-local-rank seconds charged (idle + wire).
    /// The reduction order is the canonical chunked rotated fold
    /// ([`crate::comm::allreduce`]): the buffer splits into `k`
    /// contiguous chunks and chunk `c` accumulates as the left fold over
    /// ranks `c, c+1, …, c+k-1 (mod k)` — exactly what a reduce-scatter
    /// ring produces — so results are bit-identical across transports
    /// and rank placements.
    fn allreduce_grads(&mut self, grads: &mut [Vec<f32>], clocks: &mut [f64]) -> Result<Vec<f64>>;

    /// Align `clocks` to the global maximum across all ranks (the
    /// post-optimizer barrier).
    fn align_clocks(&mut self, clocks: &mut [f64]) -> Result<()>;

    /// Allgather per-local-rank stat vectors; returns all `k` ranks'
    /// vectors in global rank order. Values are transported bit-exactly.
    fn allgather_stats(&mut self, local: Vec<Vec<f64>>) -> Result<Vec<Vec<f64>>>;

    /// Cumulative traffic/overlap stats of this process's fabric.
    fn stats(&self) -> FabricStats;

    /// Clean shutdown (close connections, join reader threads).
    fn shutdown(&mut self) -> Result<()>;
}

/// Per-pair in-memory FIFO queues with modeled delivery accounting — the
/// single-process default and the deterministic test path.
pub struct SimFabric {
    k: usize,
    /// queues[to][from]
    queues: Vec<Vec<VecDeque<PushMsg>>>,
    pub netsim: NetSim,
    stats: FabricStats,
    /// Sliding ITER_DONE window over the sim's own senders: watermarks
    /// come from `complete_iteration`, the window from
    /// `set_pipeline_window` (1 until declared).
    window: IterWindow,
    depth: u32,
    /// Armed fault plan (empty = off; one `is_empty` check on the
    /// non-fault path).
    faults: FaultPlan,
    /// Restart generation the plan is evaluated against.
    fault_gen: u32,
    /// Per-rank prefetch servers (all ranks are local under sim).
    prefetch_sources: Vec<Option<Arc<dyn PrefetchSource>>>,
    /// Landed-but-undrained prefetch rows, per requesting rank.
    prefetch_q: Vec<Vec<PrefetchedRow>>,
    /// Host index per rank (`--hosts`, host-major): ranks sharing a host
    /// exchange traffic without touching the wire, so `wire_bytes` counts
    /// only cross-host volume. `None` = every rank its own host.
    hosts: Option<Vec<usize>>,
}

impl SimFabric {
    pub fn new(k: usize, netsim: NetSim) -> SimFabric {
        SimFabric {
            k,
            queues: (0..k).map(|_| (0..k).map(|_| VecDeque::new()).collect()).collect(),
            netsim,
            stats: FabricStats::default(),
            window: IterWindow::new(k),
            depth: 1,
            faults: FaultPlan::empty(),
            fault_gen: 0,
            prefetch_sources: (0..k).map(|_| None).collect(),
            prefetch_q: (0..k).map(|_| Vec::new()).collect(),
            hosts: None,
        }
    }

    /// Declare the rank→host placement (the `--fabric hier` topology).
    /// `hosts` must have one entry per rank, host-major (each host's
    /// ranks contiguous). Only `wire_bytes` classification changes —
    /// delivery semantics and modeled queues are placement-oblivious, so
    /// losses stay bit-identical to the flat mesh.
    pub fn with_hosts(mut self, hosts: Vec<usize>) -> SimFabric {
        assert_eq!(hosts.len(), self.k, "one host entry per rank");
        self.hosts = Some(hosts);
        self
    }

    /// Whether traffic between ranks `a` and `b` leaves a host. Without a
    /// topology every pair is cross-host (the flat baseline).
    fn crosses_wire(&self, a: u32, b: u32) -> bool {
        match &self.hosts {
            Some(h) => h[a as usize] != h[b as usize],
            None => true,
        }
    }

    /// Messages currently in flight to `rank` (diagnostics).
    pub fn pending(&self, rank: u32) -> usize {
        self.queues[rank as usize].iter().map(|q| q.len()).sum()
    }
}

impl Fabric for SimFabric {
    fn ranks(&self) -> usize {
        self.k
    }

    fn is_real(&self) -> bool {
        false
    }

    fn send_pushes(&mut self, sends: Vec<(u32, PushMsg)>, sender_now: f64) -> Result<f64> {
        if sends.is_empty() {
            return Ok(0.0);
        }
        // One alltoall-priced injection for the whole fan-out: latency is
        // charged once per destination (messages to the same peer share a
        // connection), bytes serialize through the one injection port.
        let mut per_dest = vec![0usize; self.k];
        for (to, msg) in &sends {
            // the same sliding-window promise the socket receivers
            // enforce on frame arrival: a sender may not run more than
            // its declared pipeline depth past its own watermark
            self.window.check_push(msg.from as usize, msg.sent_iter)?;
            per_dest[*to as usize] += msg.bytes();
        }
        let inject = self.netsim.alltoall_send(&per_dest);
        for (to, mut msg) in sends {
            let bytes = msg.bytes();
            let flight = self.netsim.p2p(bytes);
            msg.arrival = sender_now + flight;
            self.stats.flight_secs += flight;
            self.stats.msgs_sent += 1;
            self.stats.bytes_sent += bytes as u64;
            if self.crosses_wire(msg.from, to) {
                self.stats.wire_bytes += bytes as u64;
            }
            self.queues[to as usize][msg.from as usize].push_back(msg);
        }
        Ok(inject)
    }

    fn receive_upto(
        &mut self,
        rank: u32,
        max_sent_iter: usize,
        receiver_now: f64,
    ) -> Result<(Vec<PushMsg>, f64)> {
        let mut out = Vec::new();
        let mut latest_arrival: f64 = 0.0;
        for from in 0..self.k {
            let q = &mut self.queues[rank as usize][from];
            while let Some(front) = q.front() {
                if front.sent_iter <= max_sent_iter {
                    let msg = q.pop_front().unwrap();
                    latest_arrival = latest_arrival.max(msg.arrival);
                    out.push(msg);
                } else {
                    break;
                }
            }
        }
        let wait = (latest_arrival - receiver_now).max(0.0);
        self.stats.wait_secs += wait;
        Ok((out, wait))
    }

    fn complete_iteration(&mut self, rank: u32, iter: usize) -> Result<()> {
        if !self.faults.is_empty() {
            // Modeled death: under sim every rank lives in this process,
            // so both `kill` and `drop_conn` surface as the driver
            // observing the faulted rank die at the end of iteration
            // `iter` — before watermarking it, matching the socket
            // transport where peers last saw watermark `iter - 1`.
            if self.faults.action_at(rank, iter as u64, self.fault_gen).is_some() {
                return Err(anyhow::Error::new(PeerDied {
                    rank,
                    last_iter: iter as i64 - 1,
                }));
            }
        }
        // delivery ordering comes from the stepped loop; the watermark is
        // still recorded so the sliding pipeline window is enforceable
        self.window.on_watermark(rank as usize, iter as u64, self.depth);
        Ok(())
    }

    fn set_pipeline_window(&mut self, depth: usize) -> Result<()> {
        anyhow::ensure!(depth >= 1, "pipeline window must be >= 1");
        self.depth = depth.clamp(1, u32::MAX as usize) as u32;
        // all senders are local under sim and share the run's depth; seed
        // their windows now so the bound holds from the very first push
        // (the socket transport gets the same effect from HELLO frames)
        for j in 0..self.k {
            self.window.set_window(j, self.depth);
        }
        Ok(())
    }

    fn set_fault_plan(&mut self, plan: FaultPlan, gen: u32) -> Result<()> {
        self.faults = plan;
        self.fault_gen = gen;
        Ok(())
    }

    fn set_resume_point(&mut self, _epoch: u64, iter: u64) -> Result<()> {
        // all senders are local: baseline every watermark so the first
        // post-resume push (sent_iter == iter) passes the sliding window
        self.window.resume_at(iter);
        Ok(())
    }

    fn register_prefetch_source(&mut self, rank: u32, src: Arc<dyn PrefetchSource>) {
        self.prefetch_sources[rank as usize] = Some(src);
    }

    fn prefetch_pull(&mut self, from_rank: u32, per_owner: &[Vec<u32>], now: f64) -> Result<()> {
        anyhow::ensure!(per_owner.len() == self.k, "per_owner must have one entry per rank");
        // Request fan-out priced as one alltoall injection, like pushes:
        // all REQ frames leave through the issuer's port together. Frame
        // byte layout mirrors comm/wire: REQ = tag + from + n + vids.
        let mut req_bytes = vec![0usize; self.k];
        for (owner, vids) in per_owner.iter().enumerate() {
            if owner != from_rank as usize && !vids.is_empty() {
                req_bytes[owner] = 9 + 4 * vids.len();
            }
        }
        let inject = self.netsim.alltoall_send(&req_bytes);
        for (owner, vids) in per_owner.iter().enumerate() {
            if req_bytes[owner] == 0 {
                continue;
            }
            let src = match &self.prefetch_sources[owner] {
                Some(s) => Arc::clone(s),
                None => continue, // owner serves no prefetch: misses stay cold
            };
            let dim = src.dim();
            let served: Vec<(u32, Vec<f32>)> = vids
                .iter()
                .filter_map(|&vid| src.row(vid).map(|row| (vid, row)))
                .collect();
            // Reply priced at f32 rows (4 B/elem) regardless of the run's
            // storage dtype — level-0 features are served from the owner's
            // f32 store; REP = tag + from + dim + dtype + n + n_elems +
            // vids + rows, sized by what the owner actually serves.
            let rep_bytes = 21 + served.len() * (4 + 4 * dim);
            let arrival = now + inject + self.netsim.pull_roundtrip(req_bytes[owner], rep_bytes);
            self.stats.msgs_sent += 2; // REQ + REP
            self.stats.bytes_sent += (req_bytes[owner] + rep_bytes) as u64;
            if self.crosses_wire(from_rank, owner as u32) {
                self.stats.wire_bytes += (req_bytes[owner] + rep_bytes) as u64;
            }
            for (vid, row) in served {
                self.prefetch_q[from_rank as usize].push(PrefetchedRow { vid, arrival, row });
            }
        }
        Ok(())
    }

    fn drain_prefetch(&mut self, rank: u32) -> Vec<PrefetchedRow> {
        std::mem::take(&mut self.prefetch_q[rank as usize])
    }

    fn allreduce_grads(&mut self, grads: &mut [Vec<f32>], clocks: &mut [f64]) -> Result<Vec<f64>> {
        debug_assert_eq!(grads.len(), self.k);
        let t_reduce = allreduce::average_inplace(grads);
        let bytes = grads.first().map(|g| g.len() * 4).unwrap_or(0);
        // Wire volume of the host-major ring: rank r sends every chunk
        // except (r+1)%k during reduce-scatter and every chunk except
        // (r+2)%k during allgather — 2(k-1)·N/k bytes when k divides N —
        // but only ranks whose ring successor lives on another host put
        // those chunks on the wire.
        let n = grads.first().map(|g| g.len()).unwrap_or(0);
        if self.k > 1 && n > 0 {
            let chunk_len = |c: usize| {
                let (s, e) = allreduce::chunk_bounds(n, self.k, c);
                e - s
            };
            for r in 0..self.k {
                if self.crosses_wire(r as u32, ((r + 1) % self.k) as u32) {
                    let elems = 2 * n - chunk_len((r + 1) % self.k) - chunk_len((r + 2) % self.k);
                    self.stats.wire_bytes += 4 * elems as u64;
                }
            }
        }
        Ok(allreduce::barrier_allreduce(clocks, bytes, &self.netsim, t_reduce))
    }

    fn align_clocks(&mut self, clocks: &mut [f64]) -> Result<()> {
        let maxc = clocks.iter().cloned().fold(0.0f64, f64::max);
        for c in clocks.iter_mut() {
            *c = maxc;
        }
        Ok(())
    }

    fn allgather_stats(&mut self, local: Vec<Vec<f64>>) -> Result<Vec<Vec<f64>>> {
        anyhow::ensure!(local.len() == self.k, "sim fabric hosts all ranks locally");
        Ok(local)
    }

    fn stats(&self) -> FabricStats {
        self.stats
    }

    fn shutdown(&mut self) -> Result<()> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetConfig;

    fn fabric(k: usize) -> SimFabric {
        SimFabric::new(
            k,
            NetSim::new(NetConfig {
                latency: 1e-6,
                bandwidth: 1e9,
                rpc_latency: 1e-4,
                kvstore_bandwidth: 2e9,
            }),
        )
    }

    fn msg(from: u32, sent_iter: usize, n: usize) -> PushMsg {
        PushMsg {
            from,
            layer: 0,
            vids: (0..n as u32).collect(),
            embeds: PushPayload::F32(vec![0.5; n * 4]),
            dim: 4,
            sent_iter,
            arrival: 0.0,
        }
    }

    fn send_one(f: &mut SimFabric, to: u32, m: PushMsg, now: f64) -> f64 {
        f.send_pushes(vec![(to, m)], now).unwrap()
    }

    #[test]
    fn delayed_delivery_respects_iteration_window() {
        let mut f = fabric(2);
        send_one(&mut f, 1, msg(0, 0, 10), 0.0);
        f.complete_iteration(0, 0).unwrap();
        send_one(&mut f, 1, msg(0, 1, 10), 1.0);
        // at iter 1 with d=1: deliver sent_iter <= 0 only
        let (got, _) = f.receive_upto(1, 0, 10.0).unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].sent_iter, 0);
        assert_eq!(f.pending(1), 1);
        let (got2, _) = f.receive_upto(1, 1, 10.0).unwrap();
        assert_eq!(got2.len(), 1);
        assert_eq!(f.pending(1), 0);
    }

    #[test]
    fn wait_charged_only_when_arrival_in_future() {
        let mut f = fabric(2);
        send_one(&mut f, 1, msg(0, 0, 1000), 5.0);
        // receiver far in the future: no wait
        let (_, wait) = f.receive_upto(1, 0, 100.0).unwrap();
        assert_eq!(wait, 0.0);
        // receiver in the past: waits until arrival
        f.complete_iteration(0, 0).unwrap();
        send_one(&mut f, 1, msg(0, 1, 1000), 5.0);
        let (_, wait2) = f.receive_upto(1, 1, 0.0).unwrap();
        assert!(wait2 > 5.0, "wait {wait2}");
    }

    #[test]
    fn overlap_stats_track_flight_and_charged_wait() {
        let mut f = fabric(2);
        send_one(&mut f, 1, msg(0, 0, 1000), 0.0);
        assert!(f.stats().flight_secs > 0.0);
        // receiver arrives late: whole flight hidden, nothing charged
        let (_, w) = f.receive_upto(1, 0, 100.0).unwrap();
        assert_eq!(w, 0.0);
        assert_eq!(f.stats().wait_secs, 0.0);
        // receiver arrives early: remainder charged
        f.complete_iteration(0, 0).unwrap();
        send_one(&mut f, 1, msg(0, 1, 1000), 50.0);
        let (_, w2) = f.receive_upto(1, 1, 50.0).unwrap();
        assert!(w2 > 0.0);
        assert!((f.stats().wait_secs - w2).abs() < 1e-12);
        assert!(f.stats().wait_secs <= f.stats().flight_secs);
    }

    #[test]
    fn traffic_stats_accumulate() {
        let mut f = fabric(3);
        let cost = send_one(&mut f, 2, msg(0, 0, 8), 0.0);
        assert!(cost > 0.0);
        send_one(&mut f, 2, msg(1, 0, 8), 0.0);
        assert_eq!(f.stats().msgs_sent, 2);
        assert!(f.stats().bytes_sent > 0);
    }

    /// Satellite regression: a multi-message fan-out within one iteration
    /// is priced as ONE alltoall injection — latency charged once per
    /// destination, not once per (destination, layer) message.
    #[test]
    fn multi_destination_fanout_priced_as_one_alltoall_injection() {
        let mut f = fabric(3);
        // two layers to rank 1, one layer to rank 2 — 3 messages, 2 dests
        let m_a = msg(0, 0, 10);
        let m_b = msg(0, 0, 20);
        let m_c = msg(0, 0, 30);
        let (b_a, b_b, b_c) = (m_a.bytes(), m_b.bytes(), m_c.bytes());
        let net = f.netsim;
        let cost = f
            .send_pushes(vec![(1, m_a), (1, m_b), (2, m_c)], 0.0)
            .unwrap();
        let expect = net.alltoall_send(&[0, b_a + b_b, b_c]);
        assert!((cost - expect).abs() < 1e-15, "cost {cost} expect {expect}");
        // the old per-message accounting charged latency 3x (+ implicit
        // p2p floor per message); the fixed cost must be strictly below it
        let old = (net.p2p(0) + b_a as f64 / net.cfg.bandwidth)
            + (net.p2p(0) + b_b as f64 / net.cfg.bandwidth)
            + (net.p2p(0) + b_c as f64 / net.cfg.bandwidth);
        assert!(cost < old, "cost {cost} not below legacy {old}");
        // exactly one destination-latency saved (3 msgs -> 2 dests)
        assert!((old - cost - net.cfg.latency).abs() < 1e-15);
        // delivery semantics unchanged: all three arrive
        let (got1, _) = f.receive_upto(1, 0, 1.0).unwrap();
        let (got2, _) = f.receive_upto(2, 0, 1.0).unwrap();
        assert_eq!(got1.len(), 2);
        assert_eq!(got2.len(), 1);
    }

    /// bf16 push payloads halve the embedding bytes the cost model sees
    /// (vid overhead unchanged), so modeled comm time shrinks with them.
    #[test]
    fn bf16_payload_halves_modeled_embed_bytes() {
        let mut f = fabric(2);
        let m_f32 = msg(0, 0, 10);
        let mut m_b16 = msg(0, 0, 10);
        m_b16.embeds = PushPayload::Bf16(vec![0x3F00; 10 * 4]);
        assert_eq!(m_b16.embeds.len(), m_f32.embeds.len());
        assert_eq!(m_b16.embeds.elem_bytes(), 2);
        assert_eq!(m_f32.bytes() - m_b16.bytes(), 10 * 4 * 2);
        let (bf, bb) = (m_f32.bytes() as u64, m_b16.bytes() as u64);
        send_one(&mut f, 1, m_f32, 0.0);
        assert_eq!(f.stats().bytes_sent, bf);
        send_one(&mut f, 1, m_b16, 0.0);
        assert_eq!(f.stats().bytes_sent, bf + bb);
    }

    /// The sliding ITER_DONE window is enforced on the sim's own senders:
    /// running more than the declared pipeline depth past the sender's
    /// watermark is a typed protocol error, and a deeper declared window
    /// widens the bound exactly.
    #[test]
    fn sliding_window_enforced_on_sim_senders() {
        let mut f = fabric(2);
        // window 1 (default): iteration 1 without watermarking 0 is a
        // violation — the double buffer's implicit promise, now checked
        let err = f.send_pushes(vec![(1, msg(0, 1, 4))], 0.0).unwrap_err();
        assert!(
            format!("{err:#}").contains("pipeline-window violation"),
            "{err:#}"
        );
        assert_eq!(f.stats().msgs_sent, 0, "violating push must not enqueue");

        // declare depth 3: after watermarking iteration 0 the sender may
        // push iterations 1..=3 but not 4
        f.set_pipeline_window(3).unwrap();
        f.complete_iteration(0, 0).unwrap();
        for it in 1..=3usize {
            send_one(&mut f, 1, msg(0, it, 4), 0.0);
        }
        assert!(f.send_pushes(vec![(1, msg(0, 4, 4))], 0.0).is_err());
        // delivery semantics unchanged: the in-window pushes all arrive
        let (got, _) = f.receive_upto(1, 3, 10.0).unwrap();
        assert_eq!(got.len(), 3);
        assert!(f.set_pipeline_window(0).is_err());
    }

    /// A modeled fault fires exactly at its (rank, iter, gen) and
    /// surfaces as a typed [`PeerDied`]; other generations and iterations
    /// are untouched, and the empty plan costs nothing.
    #[test]
    fn sim_fault_plan_models_peer_death_at_the_scheduled_iteration() {
        use crate::comm::faults::FaultPlan;
        let mut f = fabric(2);
        f.set_fault_plan(FaultPlan::parse("kill:rank=1,iter=2").unwrap(), 0)
            .unwrap();
        f.complete_iteration(0, 0).unwrap();
        f.complete_iteration(1, 0).unwrap();
        f.complete_iteration(1, 1).unwrap();
        f.complete_iteration(0, 2).unwrap(); // other rank unaffected
        let err = f.complete_iteration(1, 2).unwrap_err();
        let died = err.downcast_ref::<PeerDied>().expect("typed PeerDied");
        assert_eq!((died.rank, died.last_iter), (1, 1));

        // the same plan armed for generation 1 never fires at gen 0
        let mut g = fabric(2);
        g.set_fault_plan(FaultPlan::parse("kill:rank=1,iter=2,gen=1").unwrap(), 0)
            .unwrap();
        for it in 0..4 {
            g.complete_iteration(1, it).unwrap();
        }
    }

    /// After `set_resume_point(epoch, iter)` the first post-resume push
    /// (sent_iter == iter) passes the sliding window even at depth 1.
    #[test]
    fn sim_resume_point_baselines_the_sliding_window() {
        let mut f = fabric(2);
        // without the baseline, pushing iteration 8 on a fresh window
        // is a pipeline-window violation
        assert!(f.send_pushes(vec![(1, msg(0, 8, 4))], 0.0).is_err());
        let mut f = fabric(2);
        f.set_resume_point(2, 8).unwrap();
        send_one(&mut f, 1, msg(0, 8, 4), 0.0);
        assert!(f.send_pushes(vec![(1, msg(0, 9, 4))], 0.0).is_err());
    }

    #[test]
    fn empty_fanout_costs_nothing() {
        let mut f = fabric(2);
        assert_eq!(f.send_pushes(vec![], 0.0).unwrap(), 0.0);
        assert_eq!(f.stats().msgs_sent, 0);
    }

    /// A toy prefetch server: owns vids `base..base+n`, serves rows whose
    /// elements encode the vid so tests can verify row identity.
    struct ToySource {
        base: u32,
        n: u32,
        dim: usize,
    }

    impl PrefetchSource for ToySource {
        fn dim(&self) -> usize {
            self.dim
        }
        fn row(&self, vid_o: u32) -> Option<Vec<f32>> {
            (vid_o >= self.base && vid_o < self.base + self.n)
                .then(|| vec![vid_o as f32; self.dim])
        }
    }

    #[test]
    fn prefetch_pull_lands_rows_with_future_arrival_and_drain_empties() {
        let mut f = fabric(3);
        f.register_prefetch_source(1, Arc::new(ToySource { base: 100, n: 10, dim: 4 }));
        f.register_prefetch_source(2, Arc::new(ToySource { base: 200, n: 10, dim: 4 }));
        // rank 0 pulls misses owned by ranks 1 and 2
        let per_owner = vec![vec![], vec![100, 105], vec![201]];
        f.prefetch_pull(0, &per_owner, 7.0).unwrap();
        let mut rows = f.drain_prefetch(0);
        rows.sort_by_key(|r| r.vid);
        assert_eq!(rows.iter().map(|r| r.vid).collect::<Vec<_>>(), vec![100, 105, 201]);
        for r in &rows {
            assert!(r.arrival > 7.0, "arrival {} must be after issue time", r.arrival);
            assert_eq!(r.row, vec![r.vid as f32; 4]);
        }
        // drain is destructive
        assert!(f.drain_prefetch(0).is_empty());
        // REQ + REP per contacted owner, bytes counted both directions
        assert_eq!(f.stats().msgs_sent, 4);
        assert!(f.stats().bytes_sent > 0);
    }

    #[test]
    fn prefetch_pull_skips_unknown_vids_unregistered_owners_and_self() {
        let mut f = fabric(3);
        f.register_prefetch_source(1, Arc::new(ToySource { base: 100, n: 10, dim: 4 }));
        // vid 999 is not owned by rank 1's source; rank 2 has no source;
        // the self entry must be ignored even if non-empty
        let per_owner = vec![vec![7], vec![100, 999], vec![50]];
        f.prefetch_pull(0, &per_owner, 0.0).unwrap();
        let rows = f.drain_prefetch(0);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].vid, 100);
        // only the registered owner was contacted
        assert_eq!(f.stats().msgs_sent, 2);
        // empty pull is free and flight/wait accounting is untouched
        f.prefetch_pull(0, &[vec![], vec![], vec![]], 0.0).unwrap();
        assert_eq!(f.stats().msgs_sent, 2);
        assert_eq!(f.stats().flight_secs, 0.0);
        assert_eq!(f.stats().wait_secs, 0.0);
    }

    #[test]
    fn prefetch_arrival_matches_modeled_alltoall_plus_pull_roundtrip() {
        let mut f = fabric(2);
        f.register_prefetch_source(1, Arc::new(ToySource { base: 0, n: 100, dim: 8 }));
        let net = f.netsim;
        f.prefetch_pull(0, &[vec![], vec![1, 2, 3]], 2.0).unwrap();
        let rows = f.drain_prefetch(0);
        assert_eq!(rows.len(), 3);
        let req = 9 + 4 * 3;
        let rep = 21 + 3 * (4 + 4 * 8);
        let expect = 2.0 + net.alltoall_send(&[0, req]) + net.pull_roundtrip(req, rep);
        for r in &rows {
            assert!((r.arrival - expect).abs() < 1e-15, "arrival {} expect {expect}", r.arrival);
        }
    }

    /// `wire_bytes` classifies traffic by the `--hosts` topology:
    /// intra-host pushes, prefetch pulls, and ring chunks between
    /// co-located ranks never touch the wire, while the flat
    /// (topology-oblivious) fabric charges everything. Placement changes
    /// accounting only — reduced gradients stay bit-identical.
    #[test]
    fn hosts_topology_classifies_wire_bytes() {
        let mut flat = fabric(4);
        let m = msg(0, 0, 8);
        let mb = m.bytes() as u64;
        send_one(&mut flat, 1, m, 0.0);
        assert_eq!(flat.stats().wire_bytes, mb);
        assert_eq!(flat.stats().bytes_sent, mb);

        // two hosts x two ranks, host-major: {0,1} and {2,3} co-located
        let mut hier = fabric(4).with_hosts(vec![0, 0, 1, 1]);
        send_one(&mut hier, 1, msg(0, 0, 8), 0.0); // intra-host: no wire
        assert_eq!(hier.stats().wire_bytes, 0);
        send_one(&mut hier, 2, msg(1, 0, 8), 0.0); // cross-host: charged
        assert_eq!(hier.stats().wire_bytes, mb);
        assert_eq!(hier.stats().bytes_sent, 2 * mb);

        // prefetch: only the cross-host owner's round trip is wire
        hier.register_prefetch_source(1, Arc::new(ToySource { base: 0, n: 10, dim: 4 }));
        hier.register_prefetch_source(2, Arc::new(ToySource { base: 100, n: 10, dim: 4 }));
        hier.prefetch_pull(0, &[vec![], vec![1], vec![100], vec![]], 0.0)
            .unwrap();
        let (req, rep) = (9 + 4, 21 + (4 + 4 * 4));
        assert_eq!(hier.stats().wire_bytes, mb + (req + rep) as u64);
        assert_eq!(hier.drain_prefetch(0).len(), 2);

        // ring allreduce, k | N: every rank moves 2(k-1)·N/k bytes, but
        // host-major placement puts only the host-boundary ranks (1 and
        // 3) on the wire — half the flat volume at 2 ranks/host
        let n_elems = 8usize;
        let per_rank = (2 * 3 * n_elems * 4 / 4) as u64; // 2(k-1)·N/k
        let mut grads_f = vec![vec![1.0f32; n_elems]; 4];
        let mut clocks = vec![0.0f64; 4];
        let w_flat = flat.stats().wire_bytes;
        flat.allreduce_grads(&mut grads_f, &mut clocks).unwrap();
        assert_eq!(flat.stats().wire_bytes - w_flat, 4 * per_rank);
        let mut grads_h = vec![vec![1.0f32; n_elems]; 4];
        let w_hier = hier.stats().wire_bytes;
        hier.allreduce_grads(&mut grads_h, &mut clocks).unwrap();
        assert_eq!(hier.stats().wire_bytes - w_hier, 2 * per_rank);
        assert_eq!(grads_h, grads_f, "placement must never change the bits");
    }

    #[test]
    fn sim_collectives_match_direct_helpers() {
        let mut f = fabric(3);
        let mut grads = vec![vec![1.0f32, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]];
        let mut clocks = vec![0.5, 1.5, 1.0];
        let charged = f.allreduce_grads(&mut grads, &mut clocks).unwrap();
        for g in &grads {
            assert_eq!(g, &vec![3.0, 4.0]);
        }
        assert_eq!(charged.len(), 3);
        assert!(clocks.iter().all(|&c| (c - clocks[0]).abs() < 1e-12));
        let mut skew = vec![1.0, 9.0, 4.0];
        f.align_clocks(&mut skew).unwrap();
        assert_eq!(skew, vec![9.0, 9.0, 9.0]);
        let stats = vec![vec![1.0], vec![2.0], vec![3.0]];
        assert_eq!(f.allgather_stats(stats.clone()).unwrap(), stats);
    }
}
