//! In-memory message fabric for the stepped multi-rank driver.
//!
//! [`PushMsg`] carries one AEP payload: (layer, VID_o list, embeddings).
//! Messages are enqueued with the iteration at which they were sent and a
//! virtual arrival time; the receiver drains messages sent at iteration
//! `<= k - d` when processing its own iteration `k` (Algorithm 2 lines
//! 7-9) and charges `max(0, arrival - now)` of non-overlapped wait.

use std::collections::VecDeque;

use crate::comm::netsim::NetSim;

/// One asynchronous embedding push.
#[derive(Clone, Debug)]
pub struct PushMsg {
    pub from: u32,
    pub layer: usize,
    /// Original vertex ids (HEC tags).
    pub vids: Vec<u32>,
    /// Row-major embeddings, vids.len() x dim.
    pub embeds: Vec<f32>,
    pub dim: usize,
    /// Sender iteration index.
    pub sent_iter: usize,
    /// Virtual time at which the payload is fully received.
    pub arrival: f64,
}

impl PushMsg {
    pub fn bytes(&self) -> usize {
        self.vids.len() * 4 + self.embeds.len() * 4
    }
}

/// Per-pair FIFO queues with delivery accounting.
pub struct Fabric {
    k: usize,
    /// queues[to][from]
    queues: Vec<Vec<VecDeque<PushMsg>>>,
    pub netsim: NetSim,
    /// Cumulative traffic stats.
    pub msgs_sent: u64,
    pub bytes_sent: u64,
    /// Cumulative message flight time (send → arrival), the overlap
    /// *opportunity* of the delayed-push window.
    pub flight_secs: f64,
    /// Cumulative receiver wait actually charged (the non-hidden
    /// remainder). `1 - wait/flight` is the overlap efficiency the
    /// benches report.
    pub wait_secs: f64,
}

impl Fabric {
    pub fn new(k: usize, netsim: NetSim) -> Fabric {
        Fabric {
            k,
            queues: (0..k).map(|_| (0..k).map(|_| VecDeque::new()).collect()).collect(),
            netsim,
            msgs_sent: 0,
            bytes_sent: 0,
            flight_secs: 0.0,
            wait_secs: 0.0,
        }
    }

    pub fn ranks(&self) -> usize {
        self.k
    }

    /// Enqueue a push from `msg.from` to `to`; returns the sender-side
    /// injection cost (charged to the sender's clock by the caller).
    pub fn send(&mut self, to: u32, mut msg: PushMsg, sender_now: f64) -> f64 {
        let bytes = msg.bytes();
        let inject = self.netsim.p2p(0); // header/latency charged on arrival
        let flight = self.netsim.p2p(bytes);
        msg.arrival = sender_now + flight;
        self.flight_secs += flight;
        self.msgs_sent += 1;
        self.bytes_sent += bytes as u64;
        self.queues[to as usize][msg.from as usize].push_back(msg);
        // sender pays serialization (bytes/bandwidth) but not the flight
        // latency; modeled as half the p2p cost floor
        inject + bytes as f64 / self.netsim.cfg.bandwidth
    }

    /// Drain every message destined to `rank` that was sent at iteration
    /// `<= max_sent_iter`. Returns (messages, non-overlapped wait time).
    pub fn receive_upto(
        &mut self,
        rank: u32,
        max_sent_iter: usize,
        receiver_now: f64,
    ) -> (Vec<PushMsg>, f64) {
        let mut out = Vec::new();
        let mut latest_arrival: f64 = 0.0;
        for from in 0..self.k {
            let q = &mut self.queues[rank as usize][from];
            while let Some(front) = q.front() {
                if front.sent_iter <= max_sent_iter {
                    let msg = q.pop_front().unwrap();
                    latest_arrival = latest_arrival.max(msg.arrival);
                    out.push(msg);
                } else {
                    break;
                }
            }
        }
        let wait = (latest_arrival - receiver_now).max(0.0);
        self.wait_secs += wait;
        (out, wait)
    }

    /// Messages currently in flight to `rank` (diagnostics).
    pub fn pending(&self, rank: u32) -> usize {
        self.queues[rank as usize].iter().map(|q| q.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetConfig;

    fn fabric(k: usize) -> Fabric {
        Fabric::new(
            k,
            NetSim::new(NetConfig {
                latency: 1e-6,
                bandwidth: 1e9,
                rpc_latency: 1e-4,
                kvstore_bandwidth: 2e9,
            }),
        )
    }

    fn msg(from: u32, sent_iter: usize, n: usize) -> PushMsg {
        PushMsg {
            from,
            layer: 0,
            vids: (0..n as u32).collect(),
            embeds: vec![0.5; n * 4],
            dim: 4,
            sent_iter,
            arrival: 0.0,
        }
    }

    #[test]
    fn delayed_delivery_respects_iteration_window() {
        let mut f = fabric(2);
        f.send(1, msg(0, 0, 10), 0.0);
        f.send(1, msg(0, 1, 10), 1.0);
        // at iter 1 with d=1: deliver sent_iter <= 0 only
        let (got, _) = f.receive_upto(1, 0, 10.0);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].sent_iter, 0);
        assert_eq!(f.pending(1), 1);
        let (got2, _) = f.receive_upto(1, 1, 10.0);
        assert_eq!(got2.len(), 1);
        assert_eq!(f.pending(1), 0);
    }

    #[test]
    fn wait_charged_only_when_arrival_in_future() {
        let mut f = fabric(2);
        f.send(1, msg(0, 0, 1000), 5.0);
        // receiver far in the future: no wait
        let (_, wait) = f.receive_upto(1, 0, 100.0);
        assert_eq!(wait, 0.0);
        // receiver in the past: waits until arrival
        f.send(1, msg(0, 1, 1000), 5.0);
        let (_, wait2) = f.receive_upto(1, 1, 0.0);
        assert!(wait2 > 5.0, "wait {wait2}");
    }

    #[test]
    fn overlap_stats_track_flight_and_charged_wait() {
        let mut f = fabric(2);
        f.send(1, msg(0, 0, 1000), 0.0);
        assert!(f.flight_secs > 0.0);
        // receiver arrives late: whole flight hidden, nothing charged
        let (_, w) = f.receive_upto(1, 0, 100.0);
        assert_eq!(w, 0.0);
        assert_eq!(f.wait_secs, 0.0);
        // receiver arrives early: remainder charged
        f.send(1, msg(0, 1, 1000), 50.0);
        let (_, w2) = f.receive_upto(1, 1, 50.0);
        assert!(w2 > 0.0);
        assert!((f.wait_secs - w2).abs() < 1e-12);
        assert!(f.wait_secs <= f.flight_secs);
    }

    #[test]
    fn traffic_stats_accumulate() {
        let mut f = fabric(3);
        let cost = f.send(2, msg(0, 0, 8), 0.0);
        assert!(cost > 0.0);
        f.send(2, msg(1, 0, 8), 0.0);
        assert_eq!(f.msgs_sent, 2);
        assert!(f.bytes_sent > 0);
    }
}
