//! Deterministic fault injection and typed failure errors.
//!
//! A [`FaultPlan`] is a seedless, fully deterministic schedule of rank
//! deaths parsed from `--fault-plan` / `DISTGNN_FAULT_PLAN`, e.g.
//!
//! ```text
//! kill:rank=1,iter=7;drop_conn:rank=2,iter=3
//! ```
//!
//! Both transports honor the plan at the same point — the completion of a
//! global iteration — so a chaos run behaves identically whether the
//! fabric is modeled ([`SimFabric`](crate::comm::SimFabric)) or real
//! ([`SocketFabric`](crate::comm::SocketFabric)):
//!
//! * `kill` — under sockets the faulted process calls
//!   [`std::process::abort`] (a real `SIGABRT`, indistinguishable from a
//!   `SIGKILL` to its peers); under sim the driver observes a modeled
//!   [`PeerDied`].
//! * `drop_conn` — under sockets the faulted rank `shutdown(2)`s every
//!   live connection (peers see EOF and fail fast) and its own training
//!   loop gets a typed [`FaultInjected`]; under sim it is modeled the same
//!   as `kill`.
//!
//! Each action carries an optional restart *generation* (`gen=G`,
//! default 0). The supervisor (`--restarts`) exports the attempt number as
//! `DISTGNN_RESTART_GEN`, so a plan written for generation 0 fires once
//! and the restarted incarnation runs to completion instead of re-killing
//! itself.
//!
//! Fault injection is off by default: an empty plan is a single
//! `is_empty()` check on the non-fault path.

use std::time::Duration;

use anyhow::{bail, Result};

/// Exit code a rank uses for failures a supervisor should retry
/// (`EX_TEMPFAIL`): peer death and self-inflicted injected faults. Any
/// other nonzero exit is treated as permanent.
pub const EXIT_RETRYABLE: i32 = 75;

/// Environment variable the supervisor sets to the restart attempt number.
pub const RESTART_GEN_ENV: &str = "DISTGNN_RESTART_GEN";

/// Environment variable overriding the `--fault-plan` flag.
pub const FAULT_PLAN_ENV: &str = "DISTGNN_FAULT_PLAN";

/// Typed error: a peer rank died (EOF without BYE, heartbeat staleness,
/// or a modeled fault under sim). `last_iter` is the highest global
/// iteration the peer watermarked before dying (`-1` if none).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PeerDied {
    /// Global rank of the dead peer.
    pub rank: u32,
    /// Last global iteration the peer completed, `-1` if none.
    pub last_iter: i64,
}

impl std::fmt::Display for PeerDied {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "peer rank {} died (last completed iteration {})",
            self.rank, self.last_iter
        )
    }
}

impl std::error::Error for PeerDied {}

/// Typed error: this rank executed an injected fault (`drop_conn`) and
/// must stop; the supervisor treats it as retryable, like [`PeerDied`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultInjected {
    /// The faulted rank (this rank).
    pub rank: u32,
    /// Global iteration at which the fault fired.
    pub iter: u64,
}

impl std::fmt::Display for FaultInjected {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "fault injection: rank {} dropped its connections at iteration {}",
            self.rank, self.iter
        )
    }
}

impl std::error::Error for FaultInjected {}

/// Whether an error should make the process exit with [`EXIT_RETRYABLE`]
/// so a supervisor relaunches it from the last checkpoint.
pub fn is_retryable(err: &anyhow::Error) -> bool {
    err.is::<PeerDied>() || err.is::<FaultInjected>()
}

/// What an action does to its rank when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Abort the process (socket) / model the rank's death (sim).
    Kill,
    /// `shutdown(2)` all live connections (socket) / model death (sim).
    DropConn,
}

/// One scheduled fault: `kind:rank=R,iter=I[,gen=G]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultAction {
    /// What happens.
    pub kind: FaultKind,
    /// Global rank the action applies to.
    pub rank: u32,
    /// Global iteration at whose completion the action fires — the rank
    /// dies *before* watermarking this iteration, so peers observe
    /// `last_iter == iter - 1`.
    pub iter: u64,
    /// Restart generation the action is armed for (default 0).
    pub gen: u32,
}

/// A deterministic schedule of [`FaultAction`]s.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    actions: Vec<FaultAction>,
}

impl FaultPlan {
    /// The empty plan (fault injection off).
    pub fn empty() -> FaultPlan {
        FaultPlan::default()
    }

    /// True when no actions are scheduled — the only check on the
    /// non-fault hot path.
    pub fn is_empty(&self) -> bool {
        self.actions.is_empty()
    }

    /// Parse `kill:rank=1,iter=7;drop_conn:rank=2,iter=3` (semicolons
    /// separate actions; each action is `kind:key=value,...` with required
    /// `rank` and `iter` and optional `gen`). An empty or all-whitespace
    /// string is the empty plan.
    pub fn parse(text: &str) -> Result<FaultPlan> {
        let mut actions = Vec::new();
        for part in text.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let Some((kind, fields)) = part.split_once(':') else {
                bail!("fault action '{part}' is missing ':' (want kind:rank=R,iter=I)");
            };
            let kind = match kind.trim() {
                "kill" => FaultKind::Kill,
                "drop_conn" => FaultKind::DropConn,
                other => bail!("unknown fault kind '{other}' (want kill or drop_conn)"),
            };
            let (mut rank, mut iter, mut gen) = (None, None, 0u32);
            for field in fields.split(',') {
                let field = field.trim();
                if field.is_empty() {
                    continue;
                }
                let Some((key, value)) = field.split_once('=') else {
                    bail!("fault field '{field}' is missing '=' (want key=value)");
                };
                match key.trim() {
                    "rank" => {
                        rank = Some(value.trim().parse::<u32>().map_err(|e| {
                            anyhow::anyhow!("bad rank '{}' in fault plan: {e}", value.trim())
                        })?)
                    }
                    "iter" => {
                        iter = Some(value.trim().parse::<u64>().map_err(|e| {
                            anyhow::anyhow!("bad iter '{}' in fault plan: {e}", value.trim())
                        })?)
                    }
                    "gen" => {
                        gen = value.trim().parse::<u32>().map_err(|e| {
                            anyhow::anyhow!("bad gen '{}' in fault plan: {e}", value.trim())
                        })?
                    }
                    other => bail!("unknown fault field '{other}' (want rank/iter/gen)"),
                }
            }
            let Some(rank) = rank else {
                bail!("fault action '{part}' is missing rank=");
            };
            let Some(iter) = iter else {
                bail!("fault action '{part}' is missing iter=");
            };
            actions.push(FaultAction { kind, rank, iter, gen });
        }
        Ok(FaultPlan { actions })
    }

    /// Resolve the effective plan: `DISTGNN_FAULT_PLAN` overrides the
    /// config string when set (same precedence as the other env knobs).
    pub fn resolve(cfg_text: &str) -> Result<FaultPlan> {
        match std::env::var(FAULT_PLAN_ENV) {
            Ok(env_text) => FaultPlan::parse(&env_text),
            Err(_) => FaultPlan::parse(cfg_text),
        }
    }

    /// The action scheduled for `(rank, iter)` in restart generation
    /// `gen`, if any.
    pub fn action_at(&self, rank: u32, iter: u64, gen: u32) -> Option<FaultAction> {
        self.actions
            .iter()
            .copied()
            .find(|a| a.rank == rank && a.iter == iter && a.gen == gen)
    }
}

/// Current restart generation: `DISTGNN_RESTART_GEN`, default 0.
pub fn restart_gen() -> u32 {
    std::env::var(RESTART_GEN_ENV)
        .ok()
        .and_then(|v| v.trim().parse::<u32>().ok())
        .unwrap_or(0)
}

/// Deterministic capped exponential backoff: `base_ms << attempt`, capped
/// at `cap_ms`. Used by both the rendezvous dial loop and the supervisor's
/// restart loop — no jitter, so chaos tests replay exactly.
pub fn backoff_delay(attempt: u32, base_ms: u64, cap_ms: u64) -> Duration {
    let exp = attempt.min(20); // avoid shift overflow; cap dominates anyway
    Duration::from_millis(base_ms.saturating_mul(1u64 << exp).min(cap_ms))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_documented_grammar() {
        let plan = FaultPlan::parse("kill:rank=1,iter=7;drop_conn:rank=2,iter=3").unwrap();
        assert_eq!(
            plan.action_at(1, 7, 0),
            Some(FaultAction { kind: FaultKind::Kill, rank: 1, iter: 7, gen: 0 })
        );
        assert_eq!(
            plan.action_at(2, 3, 0),
            Some(FaultAction { kind: FaultKind::DropConn, rank: 2, iter: 3, gen: 0 })
        );
        assert_eq!(plan.action_at(0, 7, 0), None);
        assert_eq!(plan.action_at(1, 6, 0), None);
        assert!(!plan.is_empty());
    }

    #[test]
    fn empty_and_whitespace_plans_are_empty() {
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("  ;  ; ").unwrap().is_empty());
        assert!(FaultPlan::empty().is_empty());
    }

    #[test]
    fn gen_gates_actions_to_one_restart_generation() {
        let plan = FaultPlan::parse("kill:rank=0,iter=5,gen=2").unwrap();
        assert_eq!(plan.action_at(0, 5, 0), None);
        assert_eq!(plan.action_at(0, 5, 1), None);
        assert!(plan.action_at(0, 5, 2).is_some());
        // default gen is 0: a restarted run (gen 1) does not re-fire
        let plan0 = FaultPlan::parse("kill:rank=0,iter=5").unwrap();
        assert!(plan0.action_at(0, 5, 0).is_some());
        assert_eq!(plan0.action_at(0, 5, 1), None);
    }

    #[test]
    fn bad_grammar_is_a_typed_error_not_a_panic() {
        for bad in [
            "explode:rank=1,iter=2",
            "kill rank=1",
            "kill:rank=1",
            "kill:iter=2",
            "kill:rank=x,iter=2",
            "kill:rank=1,iter=-3",
            "kill:rank=1,iter=2,zen=1",
            "kill:rank=1,iter",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn backoff_doubles_and_caps() {
        let ms =
            |a: u32| backoff_delay(a, 10, 1000).as_millis() as u64;
        assert_eq!(ms(0), 10);
        assert_eq!(ms(1), 20);
        assert_eq!(ms(2), 40);
        assert_eq!(ms(6), 640);
        assert_eq!(ms(7), 1000);
        assert_eq!(ms(63), 1000); // shift overflow guarded
    }

    #[test]
    fn typed_errors_downcast_through_anyhow() {
        let e = anyhow::Error::new(PeerDied { rank: 3, last_iter: 41 }).context("allreduce");
        assert!(is_retryable(&e));
        let p = e.downcast_ref::<PeerDied>().unwrap();
        assert_eq!((p.rank, p.last_iter), (3, 41));
        let f = anyhow::Error::new(FaultInjected { rank: 1, iter: 7 });
        assert!(is_retryable(&f));
        assert!(!is_retryable(&anyhow::anyhow!("plain failure")));
    }
}
