//! Communication layer: a pluggable [`Fabric`] trait with two transports.
//!
//! [`SimFabric`] is the single-process default: inter-rank communication
//! is *modeled* rather than physically transported — message payloads
//! move through in-memory queues with delivery timestamps computed by the
//! [`netsim`] cost model, and the stepped driver charges each rank the
//! non-overlapped wait time. This preserves exactly what the paper's
//! claims are about — message counts, volumes, the delay-d overlap window
//! and the blocking vs asynchronous distinction — while replacing only
//! the clock of the missing Mellanox fabric (DESIGN.md §1, §5).
//!
//! [`SocketFabric`] is the real multi-process transport: one OS process
//! per rank, AEP pushes as length-prefixed frames ([`wire`]) over
//! TCP/Unix sockets, a real ring all-reduce for gradients, and wall-clock
//! comm accounting. With identical seeds both transports produce
//! bit-identical per-epoch losses — the fabric moves *where* ranks run,
//! never *what* they compute.
//!
//! The hierarchical (`--fabric hier`) configuration composes the two
//! levels the paper's cluster has: ranks co-located by the `--hosts`
//! topology exchange frames over [`shm`] mapped ring buffers while the
//! socket mesh carries only inter-host traffic, and the gradient ring
//! runs host-major so exactly one stream per host crosses the network.

pub mod allreduce;
pub mod fabric;
pub mod faults;
pub mod netsim;
pub mod shm;
pub mod socket;
pub mod wire;

pub use fabric::{Fabric, FabricStats, PushMsg, PushPayload, SimFabric};
pub use faults::{FaultAction, FaultInjected, FaultKind, FaultPlan, PeerDied};
pub use netsim::NetSim;
pub use socket::{SocketConfig, SocketFabric};
