//! Communication fabric with virtual-time semantics.
//!
//! The sandbox is a single host (one core), so inter-rank communication is
//! *modeled* rather than physically transported: message payloads move
//! through in-memory queues with delivery timestamps computed by the
//! [`netsim`] cost model, and the stepped driver charges each rank the
//! non-overlapped wait time. This preserves exactly what the paper's
//! claims are about — message counts, volumes, the delay-d overlap window
//! and the blocking vs asynchronous distinction — while replacing only the
//! clock of the missing Mellanox fabric (DESIGN.md §1, §5).

pub mod allreduce;
pub mod fabric;
pub mod netsim;

pub use fabric::{Fabric, PushMsg};
pub use netsim::NetSim;
