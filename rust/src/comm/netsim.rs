//! Network cost model (DESIGN.md §5) and the transport-agnostic sliding
//! ITER_DONE window state ([`IterWindow`]).
//!
//! Point-to-point message: `t = latency + bytes / bandwidth`.
//! Ring all-reduce over R ranks of an N-byte buffer:
//! `2 (R-1)/R · N / bandwidth + 2 (R-1) · latency`.
//! Alltoall of per-destination payloads: each destination message priced
//! independently (they share the injection port, so serialize at the
//! sender: cumulative bytes over bandwidth + per-message latency).

use anyhow::{bail, Result};

use crate::config::NetConfig;

/// Sliding ITER_DONE window: the per-peer watermark/window bookkeeping
/// both transports share (`SimFabric` enforces it on the modeled queues,
/// `SocketFabric` on real frame arrival).
///
/// The original watermark protocol implicitly assumed the classic double
/// buffer: a peer's pushes for iteration `k` arrive only between its
/// `ITER_DONE k-1` and `ITER_DONE k` — at most **one** iteration
/// outstanding past its watermark. A depth-`p` pipeline generalizes that
/// to a *window*: every peer advertises its pipeline depth `p` on each
/// (windowed) ITER_DONE, promising it will never have pushes for more
/// than `p` iterations outstanding past its own watermark. The receiver
/// holds each peer to that promise — a push with
/// `sent_iter > watermark + window` is a typed protocol error (a buggy or
/// desynchronized peer), never silent unbounded buffering.
#[derive(Clone, Debug)]
pub struct IterWindow {
    /// Highest watermarked (global) iteration per peer; -1 = none yet.
    watermark: Vec<i64>,
    /// Advertised pipeline window per peer. Defaults to 1 — the classic
    /// double-buffer promise, which un-windowed ITER_DONE frames imply.
    window: Vec<u32>,
}

impl IterWindow {
    pub fn new(ranks: usize) -> IterWindow {
        IterWindow {
            watermark: vec![-1; ranks],
            window: vec![1; ranks],
        }
    }

    pub fn watermark(&self, peer: usize) -> i64 {
        self.watermark[peer]
    }

    pub fn peer_window(&self, peer: usize) -> u32 {
        self.window[peer]
    }

    /// Record `peer`'s window advertisement without a watermark (the
    /// rendezvous HELLO carries the depth, so enforcement is correct for
    /// a depth-`p` sender from its very first push — before any
    /// ITER_DONE has been exchanged).
    pub fn set_window(&mut self, peer: usize, window: u32) {
        self.window[peer] = window.max(1);
    }

    /// Record `ITER_DONE {iter, window}` from `peer`. Watermarks are
    /// monotonic (a late or duplicate frame never rewinds); the window is
    /// the peer's latest advertisement.
    pub fn on_watermark(&mut self, peer: usize, iter: u64, window: u32) {
        let w = &mut self.watermark[peer];
        *w = (*w).max(iter as i64);
        self.window[peer] = window.max(1);
    }

    /// Baseline every peer's watermark to `iter - 1` after a checkpoint
    /// restart. The first post-resume push carries `sent_iter == iter`,
    /// and without the baseline a fresh window (watermark -1) would
    /// reject it as a pipeline-window violation. Monotonic like
    /// [`IterWindow::on_watermark`]; a resume at iteration 0 is a no-op.
    pub fn resume_at(&mut self, iter: u64) {
        if iter == 0 {
            return;
        }
        for w in self.watermark.iter_mut() {
            *w = (*w).max(iter as i64 - 1);
        }
    }

    /// Validate a push from `peer` against its advertised window.
    pub fn check_push(&self, peer: usize, sent_iter: usize) -> Result<()> {
        let limit = self.watermark[peer] + self.window[peer] as i64;
        if sent_iter as i64 > limit {
            bail!(
                "pipeline-window violation: peer {peer} pushed iteration {sent_iter} \
                 but its watermark is {} with window {} (limit {limit})",
                self.watermark[peer],
                self.window[peer]
            );
        }
        Ok(())
    }

}

#[derive(Clone, Copy, Debug)]
pub struct NetSim {
    pub cfg: NetConfig,
}

impl NetSim {
    pub fn new(cfg: NetConfig) -> NetSim {
        NetSim { cfg }
    }

    /// Time for one point-to-point message of `bytes`.
    pub fn p2p(&self, bytes: usize) -> f64 {
        self.cfg.latency + bytes as f64 / self.cfg.bandwidth
    }

    /// Sender-side serialization time of a sequence of messages
    /// (alltoall injection): per-message latency plus cumulative bytes.
    pub fn alltoall_send(&self, per_dest_bytes: &[usize]) -> f64 {
        let total: usize = per_dest_bytes.iter().sum();
        let msgs = per_dest_bytes.iter().filter(|&&b| b > 0).count();
        msgs as f64 * self.cfg.latency + total as f64 / self.cfg.bandwidth
    }

    /// Ring all-reduce of an N-byte buffer across `ranks`.
    pub fn allreduce(&self, ranks: usize, bytes: usize) -> f64 {
        if ranks <= 1 {
            return 0.0;
        }
        let r = ranks as f64;
        2.0 * (r - 1.0) / r * bytes as f64 / self.cfg.bandwidth
            + 2.0 * (r - 1.0) * self.cfg.latency
    }

    /// Ring all-reduce when `streams` of the ring's edges share each
    /// host's injection port. Topology-oblivious placement on `m`-rank
    /// hosts puts `m` concurrent chunk streams on every NIC, so the
    /// bandwidth term stretches by `m`; host-major placement (the
    /// hierarchical fabric) leaves exactly one cross-host stream per
    /// host and `streams = 1` recovers [`NetSim::allreduce`]. Latency is
    /// per ring step either way — every step waits on its slowest
    /// (network) edge.
    pub fn allreduce_contended(&self, ranks: usize, bytes: usize, streams: usize) -> f64 {
        if ranks <= 1 {
            return 0.0;
        }
        let r = ranks as f64;
        streams.max(1) as f64 * 2.0 * (r - 1.0) / r * bytes as f64 / self.cfg.bandwidth
            + 2.0 * (r - 1.0) * self.cfg.latency
    }

    /// Blocking request/response round trip moving `bytes` back
    /// (DistDGL-style remote fetch).
    pub fn roundtrip(&self, bytes: usize) -> f64 {
        2.0 * self.cfg.latency + bytes as f64 / self.cfg.bandwidth
    }

    /// Asymmetric pull round trip: a `req_bytes` request out, a
    /// `rep_bytes` reply back (the HEC lookahead-prefetch pull). One
    /// latency each way; both directions pay wire time.
    pub fn pull_roundtrip(&self, req_bytes: usize, rep_bytes: usize) -> f64 {
        2.0 * self.cfg.latency + (req_bytes + rep_bytes) as f64 / self.cfg.bandwidth
    }

    /// DistDGL KVStore/RPC round trip: TCP + Python stack latency per
    /// request, wire time, plus the KVStore serialization/copy cost on the
    /// payload (client + server).
    pub fn rpc_roundtrip(&self, bytes: usize) -> f64 {
        2.0 * self.cfg.rpc_latency
            + bytes as f64 / self.cfg.bandwidth
            + bytes as f64 / self.cfg.kvstore_bandwidth
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim() -> NetSim {
        NetSim::new(NetConfig {
            latency: 1e-6,
            bandwidth: 1e9,
            rpc_latency: 1e-4,
            kvstore_bandwidth: 2e9,
        })
    }

    #[test]
    fn p2p_scales_linearly() {
        let s = sim();
        let t1 = s.p2p(1_000_000);
        let t2 = s.p2p(2_000_000);
        assert!(t2 > t1);
        assert!((t2 - t1 - 1e-3).abs() < 1e-9);
    }

    #[test]
    fn allreduce_grows_with_ranks_but_sublinearly() {
        let s = sim();
        let t2 = s.allreduce(2, 1 << 20);
        let t16 = s.allreduce(16, 1 << 20);
        let t64 = s.allreduce(64, 1 << 20);
        assert!(t2 < t16 && t16 < t64);
        // bandwidth term saturates at 2N/B
        assert!(t64 < 2.5 * (1 << 20) as f64 / 1e9 + 64.0 * 2e-6 * 2.0);
        assert_eq!(s.allreduce(1, 1 << 20), 0.0);
    }

    /// Host-major placement (one cross-host stream per NIC) prices
    /// exactly like the uncontended ring; scattering `m` ranks of a host
    /// across the ring stretches the bandwidth term by `m`.
    #[test]
    fn contended_allreduce_stretches_bandwidth_term_only() {
        let s = sim();
        let (k, n) = (8, 1 << 20);
        assert_eq!(s.allreduce_contended(k, n, 1), s.allreduce(k, n));
        assert_eq!(s.allreduce_contended(k, n, 0), s.allreduce(k, n));
        let flat = s.allreduce_contended(k, n, 4);
        let hier = s.allreduce_contended(k, n, 1);
        assert!(flat > hier);
        // the gap is purely bandwidth: 3 extra copies of 2(k-1)/k·N/bw
        let extra = 3.0 * 2.0 * 7.0 / 8.0 * n as f64 / 1e9;
        assert!((flat - hier - extra).abs() < 1e-12, "{flat} {hier}");
        assert_eq!(s.allreduce_contended(1, n, 4), 0.0);
    }

    #[test]
    fn pull_roundtrip_prices_both_directions() {
        let s = sim();
        let t = s.pull_roundtrip(100, 4000);
        assert!((t - (2.0 * 1e-6 + 4100.0 / 1e9)).abs() < 1e-15);
        // a pull never beats a bare roundtrip of its reply
        assert!(t >= s.roundtrip(4000));
    }

    #[test]
    fn alltoall_counts_only_nonempty() {
        let s = sim();
        let t = s.alltoall_send(&[0, 1000, 0, 1000]);
        assert!((t - (2.0 * 1e-6 + 2000.0 / 1e9)).abs() < 1e-12);
    }

    #[test]
    fn iter_window_tracks_watermarks_monotonically() {
        let mut w = IterWindow::new(3);
        assert_eq!(w.watermark(1), -1);
        assert_eq!(w.peer_window(1), 1);
        w.on_watermark(1, 5, 2);
        assert_eq!(w.watermark(1), 5);
        assert_eq!(w.peer_window(1), 2);
        // a late/duplicate frame never rewinds the watermark
        w.on_watermark(1, 3, 2);
        assert_eq!(w.watermark(1), 5);
        // a zero window advertisement clamps to the protocol minimum
        w.on_watermark(2, 0, 0);
        assert_eq!(w.peer_window(2), 1);
        // a rendezvous-time advertisement sets the window, not the mark
        w.set_window(2, 5);
        assert_eq!(w.peer_window(2), 5);
        assert_eq!(w.watermark(2), 0);
        w.set_window(2, 0);
        assert_eq!(w.peer_window(2), 1);
    }

    #[test]
    fn iter_window_enforces_push_bound() {
        let mut w = IterWindow::new(2);
        // fresh peer (watermark -1, window 1): only iteration 0 may push
        w.check_push(0, 0).unwrap();
        assert!(w.check_push(0, 1).is_err());
        w.on_watermark(0, 0, 1);
        w.check_push(0, 1).unwrap();
        assert!(w.check_push(0, 2).is_err());
        // a depth-4 peer may run 4 iterations past its watermark, no more
        w.on_watermark(0, 0, 4);
        w.check_push(0, 4).unwrap();
        let err = w.check_push(0, 5).unwrap_err();
        assert!(
            format!("{err:#}").contains("pipeline-window violation"),
            "{err:#}"
        );
        // a depth advertised at rendezvous is honored before ANY
        // watermark: a fresh depth-3 peer may push iterations 0..=2
        let mut w = IterWindow::new(2);
        w.set_window(1, 3);
        w.check_push(1, 2).unwrap();
        assert!(w.check_push(1, 3).is_err());
    }

    #[test]
    fn iter_window_resume_baselines_all_peers() {
        let mut w = IterWindow::new(3);
        // resuming at iteration 0 (fresh run) changes nothing
        w.resume_at(0);
        assert_eq!(w.watermark(1), -1);
        // resuming at iteration 8: the first post-resume push (iter 8)
        // must pass even at window 1
        w.resume_at(8);
        for peer in 0..3 {
            assert_eq!(w.watermark(peer), 7);
            w.check_push(peer, 8).unwrap();
            assert!(w.check_push(peer, 9).is_err());
        }
        // monotonic: a live watermark past the resume point is kept
        w.on_watermark(2, 20, 2);
        w.resume_at(8);
        assert_eq!(w.watermark(2), 20);
    }
}
