//! Network cost model (DESIGN.md §5).
//!
//! Point-to-point message: `t = latency + bytes / bandwidth`.
//! Ring all-reduce over R ranks of an N-byte buffer:
//! `2 (R-1)/R · N / bandwidth + 2 (R-1) · latency`.
//! Alltoall of per-destination payloads: each destination message priced
//! independently (they share the injection port, so serialize at the
//! sender: cumulative bytes over bandwidth + per-message latency).

use crate::config::NetConfig;

#[derive(Clone, Copy, Debug)]
pub struct NetSim {
    pub cfg: NetConfig,
}

impl NetSim {
    pub fn new(cfg: NetConfig) -> NetSim {
        NetSim { cfg }
    }

    /// Time for one point-to-point message of `bytes`.
    pub fn p2p(&self, bytes: usize) -> f64 {
        self.cfg.latency + bytes as f64 / self.cfg.bandwidth
    }

    /// Sender-side serialization time of a sequence of messages
    /// (alltoall injection): per-message latency plus cumulative bytes.
    pub fn alltoall_send(&self, per_dest_bytes: &[usize]) -> f64 {
        let total: usize = per_dest_bytes.iter().sum();
        let msgs = per_dest_bytes.iter().filter(|&&b| b > 0).count();
        msgs as f64 * self.cfg.latency + total as f64 / self.cfg.bandwidth
    }

    /// Ring all-reduce of an N-byte buffer across `ranks`.
    pub fn allreduce(&self, ranks: usize, bytes: usize) -> f64 {
        if ranks <= 1 {
            return 0.0;
        }
        let r = ranks as f64;
        2.0 * (r - 1.0) / r * bytes as f64 / self.cfg.bandwidth
            + 2.0 * (r - 1.0) * self.cfg.latency
    }

    /// Blocking request/response round trip moving `bytes` back
    /// (DistDGL-style remote fetch).
    pub fn roundtrip(&self, bytes: usize) -> f64 {
        2.0 * self.cfg.latency + bytes as f64 / self.cfg.bandwidth
    }

    /// DistDGL KVStore/RPC round trip: TCP + Python stack latency per
    /// request, wire time, plus the KVStore serialization/copy cost on the
    /// payload (client + server).
    pub fn rpc_roundtrip(&self, bytes: usize) -> f64 {
        2.0 * self.cfg.rpc_latency
            + bytes as f64 / self.cfg.bandwidth
            + bytes as f64 / self.cfg.kvstore_bandwidth
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim() -> NetSim {
        NetSim::new(NetConfig {
            latency: 1e-6,
            bandwidth: 1e9,
            rpc_latency: 1e-4,
            kvstore_bandwidth: 2e9,
        })
    }

    #[test]
    fn p2p_scales_linearly() {
        let s = sim();
        let t1 = s.p2p(1_000_000);
        let t2 = s.p2p(2_000_000);
        assert!(t2 > t1);
        assert!((t2 - t1 - 1e-3).abs() < 1e-9);
    }

    #[test]
    fn allreduce_grows_with_ranks_but_sublinearly() {
        let s = sim();
        let t2 = s.allreduce(2, 1 << 20);
        let t16 = s.allreduce(16, 1 << 20);
        let t64 = s.allreduce(64, 1 << 20);
        assert!(t2 < t16 && t16 < t64);
        // bandwidth term saturates at 2N/B
        assert!(t64 < 2.5 * (1 << 20) as f64 / 1e9 + 64.0 * 2e-6 * 2.0);
        assert_eq!(s.allreduce(1, 1 << 20), 0.0);
    }

    #[test]
    fn alltoall_counts_only_nonempty() {
        let s = sim();
        let t = s.alltoall_send(&[0, 1000, 0, 1000]);
        assert!((t - (2.0 * 1e-6 + 2000.0 / 1e9)).abs() < 1e-12);
    }
}
