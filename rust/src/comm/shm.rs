//! Shared-memory intra-node transport: SPSC byte rings over `MAP_SHARED`
//! file mappings ([`crate::util::mmap::MmapMut`]).
//!
//! Ranks that the `--hosts` topology places on one host exchange their
//! frames — AEP pushes, prefetch replies, gradient ring chunks, control
//! frames — through a pair of mapped ring buffers instead of the socket
//! stack. Each ordered byte stream `i -> j` of the socket mesh maps to
//! exactly one ring file, so *all* framing, watermark, and delivery
//! machinery runs unchanged on top: the transport moves where the bytes
//! travel, never what a reader observes, which is how the
//! bit-identical-losses contract survives by construction.
//!
//! # Ring layout
//!
//! A ring file is a 64-byte header followed by `capacity` data bytes:
//!
//! ```text
//! offset  field     semantics
//! 0       magic     DSHMRING1 constant, verified on open
//! 8       capacity  data-region bytes, verified against the file length
//! 16      head      total bytes ever written (producer-owned)
//! 24      tail      total bytes ever read (consumer-owned)
//! 32      closed    nonzero once either side shuts the stream down
//! ```
//!
//! `head` and `tail` are free-running byte counters, not wrapped offsets:
//! readable bytes are `head - tail`, free space is
//! `capacity - (head - tail)`, and the physical position of a counter is
//! `counter % capacity`. The producer publishes data with a release store
//! of `head` after copying payload bytes in; the consumer acquires `head`
//! before copying bytes out and releases `tail` after. That pairing is
//! the entire memory-ordering protocol — data and counters live in one
//! `MAP_SHARED` region, so the same acquire/release edges work across
//! threads and across processes.
//!
//! # Rendezvous and staleness
//!
//! The *receiving* rank creates its inbound ring files (fresh, via
//! create-temp-then-rename) **before** binding its socket listener; a
//! dialing rank opens a ring only **after** its socket dial to that
//! listener succeeds. Connect-success therefore happens-after ring
//! creation, so a dialer can never map a stale ring left by a dead run —
//! the same ordering trick `Listener::bind` uses for stale unix socket
//! paths, with the socket mesh itself as the barrier. The first frame a
//! producer writes is `SHM_ATTACH {from, capacity}`, which the consumer
//! cross-checks against the ring it created, closing the loop.
//!
//! Frames larger than the ring stream through it: the producer blocks in
//! bounded spins while the consumer (a dedicated reader thread that
//! always drains, exactly like the socket readers) frees space, so a
//! 4 MiB ring carries pushes of any size.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::util::mmap::MmapMut;

/// Ring-file magic ("DSHMRING1" squeezed into 8 bytes).
pub const SHM_MAGIC: u64 = 0x4453_484D_5249_4E47;

/// Header size; the data region starts here. 64 bytes keeps every
/// counter on its own cache line's worth of separation from the data.
pub const SHM_HDR_BYTES: usize = 64;

const OFF_MAGIC: usize = 0;
const OFF_CAP: usize = 8;
const OFF_HEAD: usize = 16;
const OFF_TAIL: usize = 24;
const OFF_CLOSED: usize = 32;

/// Default data capacity per ring (`DISTGNN_SHM_RING_CAP` overrides).
/// Large enough that a typical minibatch push fits without wrapping;
/// bigger frames stream through in pieces.
pub const DEFAULT_RING_CAPACITY: usize = 4 << 20;

/// FNV-1a 64-bit hash — used to tag ring filenames with the rendezvous
/// peer list (so unrelated runs sharing a directory cannot collide) and
/// to fingerprint the `--hosts` spec in TOPO handshake frames.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Path of the ring carrying the `from -> to` byte stream of mesh `tag`.
pub fn ring_path(dir: &Path, tag: u64, from: usize, to: usize) -> PathBuf {
    dir.join(format!("distgnn-ring-{tag:016x}-{from}-to-{to}.shm"))
}

/// One single-producer single-consumer byte ring in a shared mapping.
pub struct ShmRing {
    map: MmapMut,
    capacity: usize,
}

impl ShmRing {
    /// The mapped header fields are plain `u64` slots at fixed offsets in
    /// a page-aligned mapping, so viewing them as `AtomicU64` is sound
    /// (aligned, and all concurrent access goes through these atomics).
    fn word(&self, off: usize) -> &AtomicU64 {
        debug_assert!(off % 8 == 0 && off + 8 <= SHM_HDR_BYTES);
        unsafe { &*(self.map.as_ptr().add(off) as *const AtomicU64) }
    }

    fn data_ptr(&self) -> *mut u8 {
        unsafe { self.map.as_ptr().add(SHM_HDR_BYTES) }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Create a fresh ring at `path`: size and zero a temp file in the
    /// same directory, map it, initialize the header through the mapping,
    /// then atomically rename over `path` — a concurrent opener sees
    /// either the old file or a fully initialized new one, never a
    /// half-written header.
    pub fn create(path: &Path, capacity: usize) -> Result<ShmRing> {
        anyhow::ensure!(capacity > 0, "shm ring capacity must be positive");
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating shm dir {}", dir.display()))?;
            }
        }
        let tmp = path.with_extension(format!("tmp{}", std::process::id()));
        {
            let f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating shm ring {}", tmp.display()))?;
            f.set_len((SHM_HDR_BYTES + capacity) as u64)
                .with_context(|| format!("sizing shm ring {}", tmp.display()))?;
        }
        let map = MmapMut::map_rw(&tmp)?;
        let ring = ShmRing { map, capacity };
        ring.word(OFF_CAP).store(capacity as u64, Ordering::Relaxed);
        ring.word(OFF_HEAD).store(0, Ordering::Relaxed);
        ring.word(OFF_TAIL).store(0, Ordering::Relaxed);
        ring.word(OFF_CLOSED).store(0, Ordering::Relaxed);
        // magic last, released: an opener that sees the magic sees a
        // complete header
        ring.word(OFF_MAGIC).store(SHM_MAGIC, Ordering::Release);
        std::fs::rename(&tmp, path)
            .with_context(|| format!("publishing shm ring {}", path.display()))?;
        Ok(ring)
    }

    /// Map an existing ring and verify its header. Callers must have a
    /// happens-after edge past the creator's `create` (the socket-dial
    /// barrier provides it), so a valid-magic, consistent-length mapping
    /// is the live incarnation.
    pub fn open(path: &Path) -> Result<ShmRing> {
        let map = MmapMut::map_rw(path)?;
        anyhow::ensure!(
            map.len() > SHM_HDR_BYTES,
            "shm ring {} is {} bytes, smaller than its header",
            path.display(),
            map.len()
        );
        let magic = unsafe { &*(map.as_ptr() as *const AtomicU64) }.load(Ordering::Acquire);
        anyhow::ensure!(
            magic == SHM_MAGIC,
            "shm ring {} has bad magic {magic:#x}",
            path.display()
        );
        let cap = unsafe { &*(map.as_ptr().add(OFF_CAP) as *const AtomicU64) }
            .load(Ordering::Acquire) as usize;
        anyhow::ensure!(
            SHM_HDR_BYTES + cap == map.len(),
            "shm ring {} header claims {cap} data bytes but the file has {}",
            path.display(),
            map.len() - SHM_HDR_BYTES
        );
        Ok(ShmRing { map, capacity: cap })
    }

    /// Whether either side has shut the stream down.
    pub fn closed(&self) -> bool {
        self.word(OFF_CLOSED).load(Ordering::Acquire) != 0
    }

    /// Shut the stream down (idempotent; either side may call it). The
    /// consumer still drains bytes written before the close.
    pub fn close(&self) {
        self.word(OFF_CLOSED).store(1, Ordering::Release);
    }

    /// Consumer side: copy up to `buf.len()` available bytes out; returns
    /// how many (0 = ring currently empty). Never blocks.
    pub fn try_read(&self, buf: &mut [u8]) -> usize {
        if buf.is_empty() {
            return 0;
        }
        let head = self.word(OFF_HEAD).load(Ordering::Acquire);
        // we are the only writer of tail
        let tail = self.word(OFF_TAIL).load(Ordering::Relaxed);
        let avail = (head - tail) as usize;
        if avail == 0 {
            return 0;
        }
        let n = avail.min(buf.len());
        let pos = (tail % self.capacity as u64) as usize;
        let first = n.min(self.capacity - pos);
        unsafe {
            std::ptr::copy_nonoverlapping(self.data_ptr().add(pos), buf.as_mut_ptr(), first);
            if n > first {
                std::ptr::copy_nonoverlapping(
                    self.data_ptr(),
                    buf.as_mut_ptr().add(first),
                    n - first,
                );
            }
        }
        self.word(OFF_TAIL).store(tail + n as u64, Ordering::Release);
        n
    }

    /// Producer side: write all of `buf`, blocking (bounded spins, then
    /// 100 µs sleeps) while the consumer frees space. Frames larger than
    /// the capacity stream through in pieces. Errors with `BrokenPipe` if
    /// the stream is closed, `TimedOut` past `timeout` with no progress
    /// possible.
    pub fn write_all(&self, mut buf: &[u8], timeout: Duration) -> std::io::Result<()> {
        let deadline = Instant::now() + timeout;
        let mut spins = 0u32;
        while !buf.is_empty() {
            if self.closed() {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::BrokenPipe,
                    "shm ring closed",
                ));
            }
            // we are the only writer of head
            let head = self.word(OFF_HEAD).load(Ordering::Relaxed);
            let tail = self.word(OFF_TAIL).load(Ordering::Acquire);
            let free = self.capacity - (head - tail) as usize;
            if free == 0 {
                if Instant::now() >= deadline {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::TimedOut,
                        "shm ring full and consumer not draining",
                    ));
                }
                spins += 1;
                if spins < 128 {
                    std::hint::spin_loop();
                } else {
                    std::thread::sleep(Duration::from_micros(100));
                }
                continue;
            }
            spins = 0;
            let n = free.min(buf.len());
            let pos = (head % self.capacity as u64) as usize;
            let first = n.min(self.capacity - pos);
            unsafe {
                std::ptr::copy_nonoverlapping(buf.as_ptr(), self.data_ptr().add(pos), first);
                if n > first {
                    std::ptr::copy_nonoverlapping(
                        buf.as_ptr().add(first),
                        self.data_ptr(),
                        n - first,
                    );
                }
            }
            self.word(OFF_HEAD).store(head + n as u64, Ordering::Release);
            buf = &buf[n..];
        }
        Ok(())
    }
}

/// One endpoint of a shared-memory byte stream, shaped like a socket:
/// `Read` on the consumer side (with `WouldBlock` timeouts so
/// [`crate::comm::wire::read_frame_poll`] stays responsive to shutdown),
/// `Write` on the producer side, and a close flag both sides observe as
/// EOF / `BrokenPipe` — the semantics `shutdown(2)` gives sockets.
pub struct ShmConn {
    ring: ShmRing,
    producer: bool,
    /// Consumer read timeout in milliseconds (0 = block until data/EOF).
    read_timeout_ms: AtomicU64,
    /// Bound on a blocked producer write (a dead consumer must surface
    /// as an error, not a hang).
    write_timeout: Duration,
}

impl ShmConn {
    pub fn producer(ring: ShmRing, write_timeout: Duration) -> ShmConn {
        ShmConn {
            ring,
            producer: true,
            read_timeout_ms: AtomicU64::new(0),
            write_timeout,
        }
    }

    pub fn consumer(ring: ShmRing) -> ShmConn {
        ShmConn {
            ring,
            producer: false,
            read_timeout_ms: AtomicU64::new(0),
            write_timeout: Duration::ZERO,
        }
    }

    pub fn capacity(&self) -> usize {
        self.ring.capacity()
    }

    pub fn set_read_timeout(&self, t: Option<Duration>) {
        let ms = t.map(|d| (d.as_millis() as u64).max(1)).unwrap_or(0);
        self.read_timeout_ms.store(ms, Ordering::Relaxed);
    }

    /// Sever the stream in both directions (socket `shutdown(2)`
    /// equivalent): the peer's reader sees EOF after draining, and any
    /// blocked writer errors out with `BrokenPipe`.
    pub fn shutdown_both(&self) {
        self.ring.close();
    }

    fn read_some(&self, buf: &mut [u8]) -> std::io::Result<usize> {
        if self.producer {
            return Err(std::io::Error::new(
                std::io::ErrorKind::Unsupported,
                "producer end of a shm ring is write-only",
            ));
        }
        if buf.is_empty() {
            return Ok(0);
        }
        let ms = self.read_timeout_ms.load(Ordering::Relaxed);
        let deadline = (ms > 0).then(|| Instant::now() + Duration::from_millis(ms));
        let mut spins = 0u32;
        loop {
            let n = self.ring.try_read(buf);
            if n > 0 {
                return Ok(n);
            }
            if self.ring.closed() {
                // the close flag was set after any final payload bytes
                // (release/acquire pairing), so one more drain attempt
                // observes them; an empty ring here is a true EOF
                let n = self.ring.try_read(buf);
                return Ok(n);
            }
            if let Some(d) = deadline {
                if Instant::now() >= d {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::WouldBlock,
                        "shm read timed out",
                    ));
                }
            }
            spins += 1;
            if spins < 128 {
                std::hint::spin_loop();
            } else {
                std::thread::sleep(Duration::from_micros(100));
            }
        }
    }
}

impl std::io::Read for ShmConn {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        self.read_some(buf)
    }
}

impl std::io::Write for ShmConn {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        if !self.producer {
            return Err(std::io::Error::new(
                std::io::ErrorKind::Unsupported,
                "consumer end of a shm ring is read-only",
            ));
        }
        self.ring.write_all(buf, self.write_timeout)?;
        Ok(buf.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("distgnn-shm-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    /// A producer streams far more bytes than the ring capacity while a
    /// consumer drains concurrently; the byte stream arrives intact and
    /// in order, and close-after-final-write surfaces as clean EOF.
    #[test]
    fn ring_streams_bytes_in_order_past_capacity() {
        let p = tmp("stream.shm");
        let rx = ShmRing::create(&p, 4096).unwrap();
        let tx = ShmRing::open(&p).unwrap();
        let total = 1 << 20; // 256x the capacity
        let pattern = |i: usize| (i as u64).wrapping_mul(0x9E37_79B9).to_le_bytes()[0];
        let producer = std::thread::spawn(move || {
            let data: Vec<u8> = (0..total).map(pattern).collect();
            // uneven chunk sizes exercise wraparound at odd offsets
            for chunk in data.chunks(977) {
                tx.write_all(chunk, Duration::from_secs(30)).unwrap();
            }
            tx.close();
        });
        let mut got = Vec::with_capacity(total);
        let mut buf = [0u8; 1500];
        loop {
            let n = rx.try_read(&mut buf);
            if n > 0 {
                got.extend_from_slice(&buf[..n]);
                continue;
            }
            if rx.closed() {
                let n = rx.try_read(&mut buf);
                if n == 0 {
                    break;
                }
                got.extend_from_slice(&buf[..n]);
                continue;
            }
            std::thread::yield_now();
        }
        producer.join().unwrap();
        assert_eq!(got.len(), total);
        assert!(got.iter().enumerate().all(|(i, &b)| b == pattern(i)));
        std::fs::remove_file(p).ok();
    }

    /// Whole frames round-trip through a ShmConn pair using the exact
    /// wire helpers the fabric uses, including a frame larger than the
    /// ring capacity (it streams), and shutdown gives read_frame a clean
    /// EOF while a subsequent write gets BrokenPipe.
    #[test]
    fn conn_carries_wire_frames_and_shuts_down_cleanly() {
        use crate::comm::wire;
        let p = tmp("frames.shm");
        let rx_ring = ShmRing::create(&p, 8192).unwrap();
        let tx_ring = ShmRing::open(&p).unwrap();
        let mut tx = ShmConn::producer(tx_ring, Duration::from_secs(30));
        let mut rx = ShmConn::consumer(rx_ring);
        rx.set_read_timeout(Some(Duration::from_millis(50)));
        let big = wire::encode_ring(&vec![0xA5u8; 64 * 1024]); // 8x capacity
        let small = wire::encode_bye(7);
        let writer = std::thread::spawn(move || {
            wire::write_frame(&mut tx, &small).unwrap();
            wire::write_frame(&mut tx, &big).unwrap();
            tx.shutdown_both();
            tx
        });
        let f1 = wire::read_frame_poll(&mut rx, || false).unwrap().unwrap();
        assert!(matches!(
            wire::decode_frame(&f1).unwrap(),
            wire::Frame::Bye { from: 7 }
        ));
        let f2 = wire::read_frame_poll(&mut rx, || false).unwrap().unwrap();
        match wire::decode_frame(&f2).unwrap() {
            wire::Frame::Ring(b) => {
                assert_eq!(b.len(), 64 * 1024);
                assert!(b.iter().all(|&x| x == 0xA5));
            }
            other => panic!("{other:?}"),
        }
        // clean EOF after the peer shut down
        assert!(wire::read_frame_poll(&mut rx, || false).unwrap().is_none());
        let mut tx = writer.join().unwrap();
        let err = wire::write_frame(&mut tx, &wire::encode_bye(1)).unwrap_err();
        assert!(format!("{err:#}").contains("closed"), "{err:#}");
        std::fs::remove_file(p).ok();
    }

    /// The consumer's read honors its timeout with WouldBlock (the
    /// shutdown-poll contract read_frame_poll relies on), and a full
    /// ring with no consumer times out the producer instead of hanging.
    #[test]
    fn timeouts_surface_as_would_block_and_timed_out() {
        let p = tmp("timeouts.shm");
        let rx_ring = ShmRing::create(&p, 64).unwrap();
        let tx_ring = ShmRing::open(&p).unwrap();
        let mut rx = ShmConn::consumer(rx_ring);
        rx.set_read_timeout(Some(Duration::from_millis(20)));
        let mut buf = [0u8; 8];
        let err = rx.read(&mut buf).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::WouldBlock);
        // fill the ring, then one more write must time out (nobody drains)
        let tx = ShmRing::open(&p).unwrap();
        tx.write_all(&[1u8; 64], Duration::from_millis(50)).unwrap();
        let err = tx.write_all(&[2u8; 8], Duration::from_millis(50)).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::TimedOut);
        drop(tx_ring);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn open_rejects_bad_magic_and_truncation() {
        let p = tmp("bad.shm");
        std::fs::write(&p, vec![0u8; SHM_HDR_BYTES + 64]).unwrap();
        assert!(ShmRing::open(&p).is_err(), "zero magic accepted");
        std::fs::write(&p, vec![0u8; 16]).unwrap();
        assert!(ShmRing::open(&p).is_err(), "truncated header accepted");
        // a freshly created ring opens fine and agrees on capacity
        let r = ShmRing::create(&p, 512).unwrap();
        assert_eq!(r.capacity(), 512);
        let o = ShmRing::open(&p).unwrap();
        assert_eq!(o.capacity(), 512);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn fnv_is_stable_and_path_names_are_directional() {
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_ne!(fnv1a64(b"a"), fnv1a64(b"b"));
        let d = std::env::temp_dir();
        assert_ne!(ring_path(&d, 7, 0, 1), ring_path(&d, 7, 1, 0));
        assert_ne!(ring_path(&d, 7, 0, 1), ring_path(&d, 8, 0, 1));
    }
}
