//! Real multi-process transport: AEP pushes and ring collectives over
//! TCP or Unix-domain sockets.
//!
//! Rendezvous: every rank binds a listener on its own entry of the
//! `peers` list (index = rank; addresses containing `/` are Unix socket
//! paths, anything else is `host:port` TCP). Each rank then dials every
//! other peer (retrying until the connect timeout) and accepts `k-1`
//! inbound connections. The *dialed* connection is our send channel to
//! that peer; the *accepted* connection (identified by the HELLO frame
//! the dialer writes first) is our receive channel from it — one ordered
//! byte stream per direction per pair, so per-peer FIFO delivery matches
//! `SimFabric`'s queues exactly.
//!
//! A dedicated reader thread per peer decodes frames and feeds shared
//! queues: pushes land in per-peer FIFOs, ITER_DONE advances the peer's
//! iteration watermark, RING payloads feed the collectives. Because the
//! readers always drain the wire, a rank blocked writing a large frame
//! can never deadlock against a peer doing the same.
//!
//! # The ITER_DONE watermark protocol
//!
//! Pushes are asynchronous, so a receiver cannot tell from its queues
//! alone whether iteration `k - d`'s delivery window is complete — a slow
//! peer's frame may still be in flight. The watermark closes that race:
//!
//! 1. after its push phase of global iteration `k`, every rank sends
//!    `ITER_DONE_W {rank, k, p}` to every peer — **even when it pushed
//!    nothing** (the driver watermarks unconditionally in AEP mode). The
//!    windowed frame carries the sender's pipeline depth `p`: a promise
//!    that it never has pushes for more than `p` iterations outstanding
//!    past its own watermark (legacy un-windowed `ITER_DONE` implies
//!    `p = 1`, the classic double buffer). The rendezvous HELLO already
//!    advertised the same `p`, so the bound holds from the very first
//!    push;
//! 2. because each pair shares one ordered byte stream per direction, a
//!    peer's `ITER_DONE k` frame arrives after all of its `sent_iter <= k`
//!    pushes — the watermark proves the prefix complete;
//! 3. `receive_upto(w)` blocks until every live peer's watermark is
//!    `>= w`, then drains per-peer FIFOs in rank order (a peer that
//!    closed *before* watermarking `w` is an error, not silent loss);
//! 4. the readers enforce the sliding window on arrival
//!    ([`crate::comm::netsim::IterWindow`]): a push with
//!    `sent_iter > watermark + p` is a typed protocol error — a buggy or
//!    desynchronized peer fails the run instead of buffering without
//!    bound.
//!
//! This makes the delivered message set — and hence HEC contents and
//! losses — bit-identical to [`crate::comm::fabric::SimFabric`]'s stepped
//! delivery; only the clock differs (wall time vs netsim). Payload bits
//! (f32 or bf16 rows) are transported raw, completing the invariant.
//!
//! # Two-level (hierarchical) meshes
//!
//! With a `hosts` topology map, peers the map co-locates with this rank
//! exchange their byte streams over [`crate::comm::shm`] mapped ring
//! buffers instead of sockets: the receiving rank creates its inbound
//! rings *before* binding its listener, the dialer's successful socket
//! connect is the freshness barrier, and the short-lived socket
//! connection carries only the identifying HELLO. Everything above the
//! byte stream — framing, watermarks, FIFO delivery — is unchanged, so
//! the delivered message set (and the losses) cannot depend on which
//! transport a frame rode. A TOPO handshake cross-checks every rank's
//! view of the hosts map and per-host leaders at mesh-up, and
//! [`FabricStats::wire_bytes`] counts only bytes the topology says leave
//! the host.
//!
//! With `push_batch = p > 1`, a sender defers its encoded pushes and
//! watermarks, emitting one PUSH_BATCH frame (plus the latest watermark)
//! per destination every `p` completed iterations — fewer, larger frames
//! on the wire. Stream order stays pushes-before-watermark, so the
//! prefix-completeness guarantee (and with it bit-identical delivery) is
//! untouched; config validation keeps `p` within the pipeline window so
//! deferred watermarks can never stall a receiver.

use std::collections::VecDeque;
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::comm::allreduce::{self, RingLink};
use crate::comm::fabric::{Fabric, FabricStats, PrefetchSource, PrefetchedRow, PushMsg, PushPayload};
use crate::comm::faults::{self, FaultInjected, FaultKind, FaultPlan, PeerDied};
use crate::comm::netsim::IterWindow;
use crate::comm::shm::{self, ShmConn, ShmRing};
use crate::comm::wire::{self, Frame};

/// Socket fabric configuration (from `--fabric socket --rank R --peers ...`).
#[derive(Clone, Debug)]
pub struct SocketConfig {
    /// This process's global rank.
    pub rank: u32,
    /// Rendezvous addresses, one per rank (index = rank). Addresses with a
    /// `/` are Unix socket paths; others are `host:port` TCP endpoints.
    pub peers: Vec<String>,
    /// Pipeline depth `p` advertised in our HELLO and windowed
    /// watermarks — the sliding-window promise peers enforce on our
    /// pushes. Fixed at rendezvous (the driver resolves the run's depth
    /// before connecting), so enforcement is correct from the very first
    /// push.
    pub pipeline_window: usize,
    /// How long to retry dialing peers during rendezvous.
    pub connect_timeout: Duration,
    /// How long `receive_upto` / ring collectives wait for a *live* peer
    /// to make progress before failing the run. A peer known dead (EOF
    /// without BYE, heartbeat staleness) fails fast as a typed
    /// [`PeerDied`] without waiting this out.
    pub recv_timeout: Duration,
    /// Interval between HEARTBEAT beacons to every peer
    /// (`DISTGNN_HEARTBEAT_MS`, default 500 ms; 0 disables the beacon
    /// thread).
    pub heartbeat_interval: Duration,
    /// A peer from which *nothing* (heartbeat or any other frame) has
    /// arrived for this long is declared dead — the silent-wedge /
    /// partition case EOF detection cannot cover
    /// (`DISTGNN_PEER_TIMEOUT_MS`, default 10 s; 0 disables staleness
    /// detection).
    pub peer_timeout: Duration,
    /// Deterministic fault-injection plan (empty = off) and the restart
    /// generation it is evaluated against; see [`crate::comm::faults`].
    pub fault_plan: FaultPlan,
    pub fault_gen: u32,
    /// Host index per rank (`None` = flat mesh). Peers sharing this
    /// rank's host exchange frames over shm rings; the map must be
    /// identical on every rank (the TOPO handshake enforces it).
    pub hosts: Option<Vec<usize>>,
    /// Directory for the shm ring files (defaults to the system temp
    /// dir; filenames are tagged with a hash of the peer list so
    /// unrelated meshes sharing the directory cannot collide).
    pub shm_dir: Option<PathBuf>,
    /// Data capacity of each shm ring (`DISTGNN_SHM_RING_CAP`); larger
    /// frames stream through in pieces.
    pub shm_ring_capacity: usize,
    /// Batch `p` iterations of pushes into one PUSH_BATCH frame before
    /// watermarking (1 = send every push immediately, the default).
    pub push_batch: usize,
}

impl SocketConfig {
    pub fn new(rank: usize, peers: Vec<String>) -> SocketConfig {
        let secs = |var: &str, default: u64| {
            std::env::var(var)
                .ok()
                .and_then(|v| v.parse::<u64>().ok())
                .unwrap_or(default)
        };
        SocketConfig {
            rank: rank as u32,
            peers,
            pipeline_window: 1,
            connect_timeout: Duration::from_secs(secs("DISTGNN_FABRIC_CONNECT_TIMEOUT", 30)),
            recv_timeout: Duration::from_secs(secs("DISTGNN_FABRIC_TIMEOUT", 120)),
            heartbeat_interval: Duration::from_millis(secs("DISTGNN_HEARTBEAT_MS", 500)),
            peer_timeout: Duration::from_millis(secs("DISTGNN_PEER_TIMEOUT_MS", 10_000)),
            fault_plan: FaultPlan::empty(),
            fault_gen: 0,
            hosts: None,
            shm_dir: None,
            shm_ring_capacity: std::env::var("DISTGNN_SHM_RING_CAP")
                .ok()
                .and_then(|v| v.parse::<usize>().ok())
                .filter(|&c| c > 0)
                .unwrap_or(shm::DEFAULT_RING_CAPACITY),
            push_batch: 1,
        }
    }
}

/// Leader of each rank's host: the highest rank the map places on that
/// host. In the host-major ring the leader is the rank whose successor
/// edge crosses to the next host — the one rank per host that talks
/// inter-node during collectives.
fn leaders_of(hosts: &[usize]) -> Vec<u32> {
    hosts
        .iter()
        .map(|&h| {
            hosts
                .iter()
                .enumerate()
                .filter(|&(_, &x)| x == h)
                .map(|(r, _)| r as u32)
                .max()
                .unwrap_or(0)
        })
        .collect()
}

/// Order-sensitive fingerprint of the hosts map, exchanged in TOPO
/// frames so ranks launched with inconsistent `--hosts` fail loudly.
fn topo_fingerprint(hosts: &[usize]) -> u64 {
    let mut bytes = Vec::with_capacity(hosts.len() * 8);
    for &h in hosts {
        bytes.extend_from_slice(&(h as u64).to_le_bytes());
    }
    shm::fnv1a64(&bytes)
}

/// This rank's view of the topology, cross-checked against every peer's
/// TOPO announcement by the reader threads.
struct TopoCheck {
    fnv: u64,
    /// leader_of[rank] = leader of that rank's host.
    leader_of: Vec<u32>,
}

fn is_unix_addr(addr: &str) -> bool {
    addr.contains('/')
}

/// A connected stream of any transport family. `Shm` is one endpoint of
/// a mapped ring buffer between co-located ranks — same frame protocol,
/// different substrate.
enum Conn {
    Tcp(TcpStream),
    Unix(UnixStream),
    Shm(ShmConn),
}

impl Conn {
    /// Dial with an upper bound: a plain `TcpStream::connect` can block
    /// for the OS default (minutes) against a SYN-dropping host, blowing
    /// straight through the rendezvous deadline.
    fn dial(addr: &str, timeout: Duration) -> Result<Conn> {
        if is_unix_addr(addr) {
            Ok(Conn::Unix(UnixStream::connect(addr)?))
        } else {
            use std::net::ToSocketAddrs;
            let sa = addr
                .to_socket_addrs()?
                .next()
                .ok_or_else(|| anyhow::anyhow!("cannot resolve {addr}"))?;
            let s = TcpStream::connect_timeout(&sa, timeout)?;
            s.set_nodelay(true)?;
            Ok(Conn::Tcp(s))
        }
    }

    fn set_nonblocking(&self, nb: bool) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.set_nonblocking(nb),
            Conn::Unix(s) => s.set_nonblocking(nb),
            Conn::Shm(_) => Ok(()), // ring reads are poll-based already
        }
    }

    fn set_read_timeout(&self, t: Option<Duration>) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.set_read_timeout(t),
            Conn::Unix(s) => s.set_read_timeout(t),
            Conn::Shm(s) => {
                s.set_read_timeout(t);
                Ok(())
            }
        }
    }

    /// Ring capacity when this stream is a shm ring (used to cross-check
    /// SHM_ATTACH announcements); `None` for sockets.
    fn shm_capacity(&self) -> Option<u64> {
        match self {
            Conn::Shm(s) => Some(s.capacity() as u64),
            _ => None,
        }
    }

    /// `shutdown(2)` both directions. Needed for explicit teardown: the
    /// heartbeat thread holds `Arc` clones of the sender connections, so
    /// merely dropping our handles would keep the sockets open and peers
    /// would never see EOF. Also how the `drop_conn` fault severs live
    /// connections. A shm ring's close flag gives its peer the same
    /// EOF-after-drain semantics.
    fn shutdown_both(&self) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.shutdown(std::net::Shutdown::Both),
            Conn::Unix(s) => s.shutdown(std::net::Shutdown::Both),
            Conn::Shm(s) => {
                s.shutdown_both();
                Ok(())
            }
        }
    }
}

impl std::io::Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.read(buf),
            Conn::Unix(s) => s.read(buf),
            Conn::Shm(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.write(buf),
            Conn::Unix(s) => s.write(buf),
            Conn::Shm(s) => s.write(buf),
        }
    }
    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.flush(),
            Conn::Unix(s) => s.flush(),
            Conn::Shm(s) => s.flush(),
        }
    }
}

enum Listener {
    Tcp(TcpListener),
    Unix(UnixListener),
}

impl Listener {
    fn bind(addr: &str) -> Result<Listener> {
        if is_unix_addr(addr) {
            let _ = std::fs::remove_file(addr); // stale socket from a dead run
            if let Some(dir) = std::path::Path::new(addr).parent() {
                if !dir.as_os_str().is_empty() {
                    std::fs::create_dir_all(dir)?;
                }
            }
            Ok(Listener::Unix(
                UnixListener::bind(addr).with_context(|| format!("bind unix {addr}"))?,
            ))
        } else {
            Ok(Listener::Tcp(
                TcpListener::bind(addr).with_context(|| format!("bind tcp {addr}"))?,
            ))
        }
    }

    fn set_nonblocking(&self, nb: bool) -> std::io::Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(nb),
            Listener::Unix(l) => l.set_nonblocking(nb),
        }
    }

    /// Non-blocking accept: `Ok(None)` when no connection is pending.
    fn try_accept(&self) -> Result<Option<Conn>> {
        let res = match self {
            Listener::Tcp(l) => l.accept().map(|(s, _)| {
                let _ = s.set_nodelay(true);
                Conn::Tcp(s)
            }),
            Listener::Unix(l) => l.accept().map(|(s, _)| Conn::Unix(s)),
        };
        match res {
            Ok(c) => Ok(Some(c)),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Ok(None),
            Err(e) => Err(e.into()),
        }
    }
}

/// A push as it sits in the receive queue, stamped with its arrival
/// instant (for the hidden-overlap accounting).
struct QueuedPush {
    msg: PushMsg,
    arrived: Instant,
}

/// State shared between the driver thread and the per-peer readers.
struct RecvState {
    /// push_queues[from]: FIFO of decoded pushes from that peer.
    push_queues: Vec<VecDeque<QueuedPush>>,
    /// ring_queues[from]: FIFO of ring-collective payloads from that peer.
    ring_queues: Vec<VecDeque<Vec<u8>>>,
    /// Per-peer ITER_DONE watermarks and advertised pipeline windows; the
    /// readers enforce the sliding-window push bound on frame arrival.
    iters: IterWindow,
    /// Peers whose inbound stream has closed (BYE or EOF/error).
    closed: Vec<bool>,
    /// First reader error, surfaced to the driver. Protocol violations
    /// only — transport-level death lands in `dead` instead.
    error: Option<String>,
    /// Last instant *anything* (heartbeat or data frame) arrived from each
    /// peer; the staleness sweep in `wait_state` declares a peer dead when
    /// this falls `peer_timeout` behind.
    last_heard: Vec<Instant>,
    /// Peers declared dead (EOF without BYE, read error, or heartbeat
    /// staleness), holding the peer's last watermark at detection time —
    /// the `last_iter` of the typed [`PeerDied`] the driver receives.
    dead: Vec<Option<i64>>,
    /// Resume point `(epoch, iter)` each peer announced via a RESUME
    /// frame, cross-checked against our own so a rank restarting from a
    /// stale checkpoint fails loudly instead of silently diverging.
    peer_resume: Vec<Option<(u64, u64)>>,
    /// Our own announced resume point, if any.
    my_resume: Option<(u64, u64)>,
    /// Prefetched feature rows landed by PREFETCH_REP frames, awaiting
    /// `drain_prefetch` (this process hosts exactly one rank, so one
    /// staging vec suffices). Arrival is 0.0: on a real transport,
    /// presence at drain time already means "arrived in time".
    prefetch_rows: Vec<PrefetchedRow>,
}

struct Shared {
    state: Mutex<RecvState>,
    cv: Condvar,
    /// Set by shutdown; reader threads poll it between read timeouts so a
    /// wedged peer (alive but silent) cannot pin them in `read()` and
    /// block the shutdown join forever.
    shutting_down: std::sync::atomic::AtomicBool,
    /// Our rank, stamped into prefetch replies served by reader threads.
    my_rank: u32,
    /// The local rank's registered [`PrefetchSource`] (None until the
    /// driver registers one; PREFETCH_REQs arriving before then are
    /// dropped — prefetch is best-effort, misses just stay cold).
    prefetch_src: Mutex<Option<Arc<dyn PrefetchSource>>>,
    /// Outbound connections the readers use to answer PREFETCH_REQs.
    /// Connections are directional (the inbound stream a reader drains
    /// cannot carry replies), so replies go out on the dialed send
    /// channel — populated once the rendezvous dial completes, which is
    /// long before any peer's driver issues its first pull.
    reply_senders: Mutex<Vec<Option<Arc<Mutex<Conn>>>>>,
    /// Our topology view (None = flat mesh), cross-checked against the
    /// TOPO frame every peer sends at mesh-up.
    topo: Option<TopoCheck>,
}

/// Reader sockets carry a short read timeout purely as a shutdown poll
/// interval ([`wire::read_frame_poll`] keeps waiting across timeouts).
const READER_POLL: Duration = Duration::from_millis(500);

/// Real socket transport implementing [`Fabric`] for one rank per process.
pub struct SocketFabric {
    rank: u32,
    k: usize,
    cfg: SocketConfig,
    /// Outbound connections, indexed by peer rank (`None` for self).
    /// Shared with the heartbeat thread behind a mutex: `write_frame` is
    /// two `write_all` calls, so interleaving writers would corrupt the
    /// stream framing.
    senders: Vec<Option<Arc<Mutex<Conn>>>>,
    shared: Arc<Shared>,
    readers: Vec<std::thread::JoinHandle<()>>,
    stats: FabricStats,
    /// Pipeline depth advertised on our windowed ITER_DONE frames.
    depth: u32,
    /// Latest global iteration this rank completed (`-1` = none yet); the
    /// heartbeat thread advertises `last_iter + 1` as its `iters_done`.
    last_iter: Arc<std::sync::atomic::AtomicI64>,
    shut: bool,
    /// colocated[j]: peer j shares our host (its stream rides a shm
    /// ring, and its traffic does not count as wire bytes). All-false in
    /// a flat mesh — a topology-oblivious mesh charges everything to the
    /// wire.
    colocated: Vec<bool>,
    /// Deferred encoded PUSH bodies per destination plus the number of
    /// iterations completed since the last watermark went out — the
    /// `push_batch > 1` batching state.
    pending_push: Vec<Vec<Vec<u8>>>,
    pending_iters: u32,
    /// Inbound shm ring files this rank created (removed at shutdown).
    ring_files: Vec<PathBuf>,
}

impl SocketFabric {
    /// Rendezvous with every peer; returns once the full mesh is up.
    pub fn connect(cfg: SocketConfig) -> Result<SocketFabric> {
        let k = cfg.peers.len();
        let rank = cfg.rank;
        anyhow::ensure!((rank as usize) < k, "rank {rank} out of range for {k} peers");
        if let Some(h) = &cfg.hosts {
            anyhow::ensure!(
                h.len() == k,
                "hosts map has {} entries for {k} ranks",
                h.len()
            );
        }
        // Which peers share our host: their frames ride shm rings.
        let colocated: Vec<bool> = match &cfg.hosts {
            Some(h) => (0..k)
                .map(|j| j != rank as usize && h[j] == h[rank as usize])
                .collect(),
            None => vec![false; k],
        };
        let mesh_tag = shm::fnv1a64(cfg.peers.join(",").as_bytes());
        let shm_dir = cfg.shm_dir.clone().unwrap_or_else(std::env::temp_dir);
        // Create our inbound rings BEFORE binding the listener: a peer's
        // dial succeeds only after we bind, so connect-success proves the
        // rings it is about to map exist and belong to this run — no
        // stale-incarnation race, the same ordering trick bind() plays
        // with stale unix socket paths.
        let mut inbound: Vec<Option<ShmRing>> = (0..k).map(|_| None).collect();
        let mut ring_files: Vec<PathBuf> = Vec::new();
        for (j, colo) in colocated.iter().enumerate() {
            if *colo {
                let p = shm::ring_path(&shm_dir, mesh_tag, j, rank as usize);
                inbound[j] = Some(
                    ShmRing::create(&p, cfg.shm_ring_capacity)
                        .with_context(|| format!("creating inbound shm ring from rank {j}"))?,
                );
                ring_files.push(p);
            }
        }
        let listener = Listener::bind(&cfg.peers[rank as usize])?;

        let shared = Arc::new(Shared {
            state: Mutex::new(RecvState {
                push_queues: (0..k).map(|_| VecDeque::new()).collect(),
                ring_queues: (0..k).map(|_| VecDeque::new()).collect(),
                iters: IterWindow::new(k),
                closed: vec![false; k],
                error: None,
                last_heard: vec![Instant::now(); k],
                dead: vec![None; k],
                peer_resume: vec![None; k],
                my_resume: None,
                prefetch_rows: Vec::new(),
            }),
            cv: Condvar::new(),
            shutting_down: std::sync::atomic::AtomicBool::new(false),
            my_rank: rank,
            prefetch_src: Mutex::new(None),
            reply_senders: Mutex::new((0..k).map(|_| None).collect()),
            topo: cfg.hosts.as_ref().map(|h| TopoCheck {
                fnv: topo_fingerprint(h),
                leader_of: leaders_of(h),
            }),
        });

        // Dial every peer on a helper thread while we accept inbound
        // connections — doing both concurrently avoids rendezvous deadlock.
        let dial_peers = cfg.peers.clone();
        let depth = cfg.pipeline_window.clamp(1, u32::MAX as usize) as u32;
        let deadline = Instant::now() + cfg.connect_timeout;
        let dial_colocated = colocated.clone();
        let dial_shm_dir = shm_dir.clone();
        let shm_write_timeout = cfg.recv_timeout;
        let dialer = std::thread::spawn(move || -> Result<Vec<Option<Arc<Mutex<Conn>>>>> {
            let mut out: Vec<Option<Arc<Mutex<Conn>>>> = (0..k).map(|_| None).collect();
            for (j, addr) in dial_peers.iter().enumerate() {
                if j == rank as usize {
                    continue;
                }
                let mut attempt = 0u32;
                let mut conn = loop {
                    let remaining = deadline
                        .saturating_duration_since(Instant::now())
                        .max(Duration::from_millis(50));
                    match Conn::dial(addr, remaining) {
                        Ok(c) => break c,
                        Err(e) => {
                            if Instant::now() >= deadline {
                                bail!("rank {rank}: dialing peer {j} at {addr} timed out: {e}");
                            }
                            // deterministic capped exponential backoff: a
                            // supervised restart re-dials a mesh whose other
                            // members are still relaunching, and a hot loop
                            // would hammer the listener for the whole window
                            std::thread::sleep(faults::backoff_delay(attempt, 10, 1000));
                            attempt += 1;
                        }
                    }
                };
                wire::write_frame(&mut conn, &wire::encode_hello(rank, depth))
                    .with_context(|| format!("hello to peer {j}"))?;
                if dial_colocated[j] {
                    // The successful dial is the freshness barrier: peer j
                    // bound its listener only after creating its inbound
                    // rings, so this mapping is the live incarnation. The
                    // socket conn has served its purpose (the identifying
                    // HELLO) and drops at the end of this iteration; our
                    // data stream to j is the ring from here on.
                    let ring =
                        ShmRing::open(&shm::ring_path(&dial_shm_dir, mesh_tag, rank as usize, j))
                            .with_context(|| format!("attaching shm ring to rank {j}"))?;
                    let cap = ring.capacity() as u64;
                    let mut sc = Conn::Shm(ShmConn::producer(ring, shm_write_timeout));
                    // first ring frame: lets the consumer cross-check that
                    // the right rank attached to the right ring
                    wire::write_frame(&mut sc, &wire::encode_shm_attach(rank, cap))
                        .with_context(|| format!("shm attach to peer {j}"))?;
                    out[j] = Some(Arc::new(Mutex::new(sc)));
                } else {
                    out[j] = Some(Arc::new(Mutex::new(conn)));
                }
            }
            Ok(out)
        });

        // Accept k-1 inbound connections; the HELLO frame names the peer.
        // Non-blocking polling so a failed dialer (peer never comes up)
        // surfaces as an error instead of wedging the accept loop forever.
        listener.set_nonblocking(true)?;
        let mut dialer = Some(dialer);
        let mut senders: Option<Vec<Option<Conn>>> = None;
        let mut readers = Vec::new();
        let mut seen = vec![false; k];
        let mut accepted = 0usize;
        while accepted < k.saturating_sub(1) {
            if dialer.as_ref().map(|h| h.is_finished()).unwrap_or(false) {
                let res = dialer
                    .take()
                    .unwrap()
                    .join()
                    .map_err(|_| anyhow::anyhow!("dialer thread panicked"))?;
                senders = Some(res?); // propagate dial failure promptly
            }
            let Some(mut conn) = listener.try_accept()? else {
                if Instant::now() >= deadline {
                    bail!(
                        "rank {rank}: rendezvous timed out with {accepted}/{} peers connected",
                        k - 1
                    );
                }
                std::thread::sleep(Duration::from_millis(10));
                continue;
            };
            conn.set_nonblocking(false)?;
            // HELLO must arrive promptly; never hand an anonymous stream
            // on (the deadline-stop bounds a silent dialer)
            conn.set_read_timeout(Some(READER_POLL))?;
            let payload = wire::read_frame_poll(&mut conn, || Instant::now() >= deadline)?
                .ok_or_else(|| anyhow::anyhow!("peer closed or sent no HELLO in time"))?;
            let (from, peer_window) = match wire::decode_frame(&payload)? {
                Frame::Hello { from, window } => (from, window),
                other => bail!("expected HELLO, got {other:?}"),
            };
            anyhow::ensure!((from as usize) < k && from != rank, "bad HELLO rank {from}");
            anyhow::ensure!(!seen[from as usize], "duplicate HELLO from rank {from}");
            seen[from as usize] = true;
            // the peer's advertised pipeline depth bounds its pushes from
            // frame one — before any watermark has been exchanged
            shared
                .state
                .lock()
                .unwrap()
                .iters
                .set_window(from as usize, peer_window);
            let shared_r = Arc::clone(&shared);
            if colocated[from as usize] {
                // barrier connection: this peer's data stream arrives on
                // the shm ring we created before binding; the socket conn
                // carried only the identifying HELLO and drops here
                let ring = inbound[from as usize]
                    .take()
                    .ok_or_else(|| anyhow::anyhow!("no inbound shm ring for rank {from}"))?;
                let sc = ShmConn::consumer(ring);
                sc.set_read_timeout(Some(READER_POLL));
                readers.push(std::thread::spawn(move || {
                    reader_loop(Conn::Shm(sc), from, shared_r);
                }));
            } else {
                // READER_POLL read timeout from the HELLO wait stays in
                // effect as the reader thread's shutdown poll interval
                readers.push(std::thread::spawn(move || {
                    reader_loop(conn, from, shared_r);
                }));
            }
            accepted += 1;
        }

        let senders = match senders {
            Some(s) => s,
            None => dialer
                .take()
                .unwrap()
                .join()
                .map_err(|_| anyhow::anyhow!("dialer thread panicked"))??,
        };
        // Hand the readers the send channels so they can answer
        // PREFETCH_REQs (replies travel on the dialed connection — the
        // accepted stream a reader drains is one-directional).
        *shared.reply_senders.lock().unwrap() = senders.clone();
        // Topology handshake: announce our hosts fingerprint and our own
        // host's leader to every peer; their readers cross-check against
        // their own view, so a mesh launched with inconsistent --hosts
        // maps fails loudly instead of silently misrouting traffic.
        if let Some(t) = &shared.topo {
            let frame = wire::encode_topo(rank, t.fnv, t.leader_of[rank as usize]);
            for conn in senders.iter().flatten() {
                wire::write_frame(&mut *conn.lock().unwrap(), &frame)
                    .context("announcing topology")?;
            }
        }
        // Baseline liveness at mesh-up: rendezvous can legitimately take
        // most of the connect timeout, and a stale `last_heard` from the
        // accept phase would trip the staleness sweep on the first wait.
        {
            let mut st = shared.state.lock().unwrap();
            let now = Instant::now();
            for t in st.last_heard.iter_mut() {
                *t = now;
            }
        }
        // Heartbeat beacon: periodically tell every peer we are alive and
        // how far we have progressed, so a silently wedged (not crashed)
        // peer is detected by staleness within `peer_timeout`.
        let last_iter = Arc::new(std::sync::atomic::AtomicI64::new(-1));
        if cfg.heartbeat_interval > Duration::ZERO && k > 1 {
            let hb_senders: Vec<Option<Arc<Mutex<Conn>>>> = senders.clone();
            let hb_shared = Arc::clone(&shared);
            let hb_iter = Arc::clone(&last_iter);
            let interval = cfg.heartbeat_interval;
            readers.push(std::thread::spawn(move || {
                let step = Duration::from_millis(50);
                'beacon: loop {
                    let mut slept = Duration::ZERO;
                    while slept < interval {
                        if hb_shared
                            .shutting_down
                            .load(std::sync::atomic::Ordering::Relaxed)
                        {
                            break 'beacon;
                        }
                        let d = step.min(interval - slept);
                        std::thread::sleep(d);
                        slept += d;
                    }
                    let done = hb_iter.load(std::sync::atomic::Ordering::Relaxed) + 1;
                    let frame = wire::encode_heartbeat(rank, done as u64);
                    for conn in hb_senders.iter().flatten() {
                        // best effort: a write failure means the connection
                        // is dying, which peer-side detection handles
                        let _ = wire::write_frame(&mut *conn.lock().unwrap(), &frame);
                    }
                }
            }));
        }
        crate::log_debug!("socket fabric up: rank {rank}/{k}");
        Ok(SocketFabric {
            rank,
            k,
            cfg,
            senders,
            shared,
            readers,
            stats: FabricStats::default(),
            depth,
            last_iter,
            shut: false,
            colocated,
            pending_push: (0..k).map(|_| Vec::new()).collect(),
            pending_iters: 0,
            ring_files,
        })
    }

    fn sender(&self, to: u32) -> Result<Arc<Mutex<Conn>>> {
        self.senders[to as usize]
            .as_ref()
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("no connection to rank {to}"))
    }

    /// Flush deferred batched pushes and the deferred watermark: one
    /// PUSH_BATCH frame per destination with pending bodies, then the
    /// watermark of the latest completed iteration — preserving the
    /// pushes-before-watermark stream order the prefix-completeness
    /// guarantee rests on. No-op when nothing is deferred. Called at the
    /// batch boundary and defensively on entry to every collective,
    /// resume announcement, and shutdown, so a deferred watermark can
    /// never outlive the window a receiver is waiting on.
    fn flush_pending(&mut self) -> Result<()> {
        if self.pending_iters == 0 {
            return Ok(());
        }
        let iter = self
            .last_iter
            .load(std::sync::atomic::Ordering::Relaxed)
            .max(0) as u64;
        let wm = wire::encode_iter_done_w(self.rank, iter, self.depth);
        for j in 0..self.k {
            if j == self.rank as usize {
                continue;
            }
            let conn = self.sender(j as u32)?;
            let mut c = conn.lock().unwrap();
            if !self.pending_push[j].is_empty() {
                let batch = wire::encode_push_batch(self.rank, &self.pending_push[j])?;
                wire::write_frame(&mut *c, &batch)
                    .with_context(|| format!("batched pushes to rank {j}"))?;
            }
            wire::write_frame(&mut *c, &wm)
                .with_context(|| format!("iter-done to rank {j}"))?;
        }
        for v in self.pending_push.iter_mut() {
            v.clear();
        }
        self.pending_iters = 0;
        Ok(())
    }

    /// Block until `pred` holds on the shared state, bounded by the recv
    /// timeout. `what` names the wait for the error message.
    ///
    /// Every pass first sweeps heartbeat staleness (a peer silent for
    /// `peer_timeout` is declared dead) and then fails fast with a typed
    /// [`PeerDied`] if any peer has died — the full `recv_timeout` is only
    /// ever waited out against peers that are demonstrably alive.
    fn wait_state<T>(
        &self,
        what: &str,
        mut pred: impl FnMut(&mut RecvState) -> Option<T>,
    ) -> Result<T> {
        let deadline = Instant::now() + self.cfg.recv_timeout;
        let me = self.rank as usize;
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if let Some(err) = &st.error {
                bail!("rank {}: fabric reader failed: {err}", self.rank);
            }
            if self.cfg.peer_timeout > Duration::ZERO {
                for j in 0..self.k {
                    if j != me
                        && !st.closed[j]
                        && st.last_heard[j].elapsed() > self.cfg.peer_timeout
                    {
                        st.closed[j] = true;
                        st.dead[j] = Some(st.iters.watermark(j));
                    }
                }
            }
            if let Some(v) = pred(&mut st) {
                return Ok(v);
            }
            if let Some(j) = (0..self.k).find(|&j| st.dead[j].is_some()) {
                let last_iter = st.dead[j].unwrap();
                return Err(anyhow::Error::new(PeerDied {
                    rank: j as u32,
                    last_iter,
                })
                .context(format!("rank {}: waiting for {what}", self.rank)));
            }
            let now = Instant::now();
            if now >= deadline {
                bail!(
                    "rank {}: timed out after {:?} waiting for {what}",
                    self.rank,
                    self.cfg.recv_timeout
                );
            }
            // cap the sleep so the staleness sweep runs even when no
            // frames (and hence no condvar notifications) are arriving
            let wait = (deadline - now).min(Duration::from_millis(250));
            let (guard, _) = self.shared.cv.wait_timeout(st, wait).unwrap();
            st = guard;
        }
    }

    fn shutdown_inner(&mut self, join: bool) -> Result<()> {
        if self.shut {
            return Ok(());
        }
        self.shut = true;
        // best-effort: peers still waiting on a deferred watermark get it
        // before the BYE
        let _ = self.flush_pending();
        self.shared
            .shutting_down
            .store(true, std::sync::atomic::Ordering::Relaxed);
        for j in 0..self.k {
            if let Some(conn) = self.senders[j].as_ref() {
                let mut c = conn.lock().unwrap();
                let _ = wire::write_frame(&mut *c, &wire::encode_bye(self.rank));
                // the heartbeat thread's Arc clones would keep the socket
                // open past the drop below (peers would never see EOF), so
                // sever explicitly; shutdown(2) still flushes the BYE
                let _ = c.shutdown_both();
            }
        }
        for s in self.senders.iter_mut() {
            *s = None;
        }
        // the readers' reply table holds Arc clones of the same sockets
        for s in self.shared.reply_senders.lock().unwrap().iter_mut() {
            *s = None;
        }
        if join {
            for h in self.readers.drain(..) {
                let _ = h.join();
            }
        }
        // remove our unix socket path
        let addr = &self.cfg.peers[self.rank as usize];
        if is_unix_addr(addr) {
            let _ = std::fs::remove_file(addr);
        }
        // and the shm ring files we created (producers keep their live
        // mappings until they drop — unlink only removes the name)
        for p in &self.ring_files {
            let _ = std::fs::remove_file(p);
        }
        Ok(())
    }
}

fn reader_loop(mut conn: Conn, from: u32, shared: Arc<Shared>) {
    // Protocol violations (bad frames, window breaches) are run-fatal and
    // land in `error`; transport-level failures (read errors, EOF without
    // BYE) mean the *peer* died and land in `dead[from]` so the driver
    // fails fast with a typed PeerDied instead of an opaque string.
    let fail = |shared: &Shared, msg: String| {
        let mut st = shared.state.lock().unwrap();
        st.closed[from as usize] = true;
        if st.error.is_none() {
            st.error = Some(msg);
        }
        shared.cv.notify_all();
    };
    let mark_dead = |shared: &Shared| {
        let mut st = shared.state.lock().unwrap();
        st.closed[from as usize] = true;
        if st.dead[from as usize].is_none() {
            st.dead[from as usize] = Some(st.iters.watermark(from as usize));
        }
        shared.cv.notify_all();
    };
    let mut got_bye = false;
    // capacity of this stream's shm ring (None = socket stream), for
    // cross-checking SHM_ATTACH announcements
    let shm_cap = conn.shm_capacity();
    loop {
        let stop = || shared.shutting_down.load(std::sync::atomic::Ordering::Relaxed);
        match wire::read_frame_poll(&mut conn, stop) {
            Ok(None) => break, // EOF (or local shutdown)
            Ok(Some(payload)) => match wire::decode_frame(&payload) {
                Ok(frame) => {
                    let mut st = shared.state.lock().unwrap();
                    st.last_heard[from as usize] = Instant::now();
                    match frame {
                        Frame::Push(msg) => {
                            // sliding-window flow control: the peer promised
                            // (via its windowed watermarks) never to run more
                            // than its pipeline depth past its own ITER_DONE —
                            // hold it to that instead of buffering unboundedly
                            if let Err(e) = st.iters.check_push(from as usize, msg.sent_iter) {
                                drop(st);
                                fail(&shared, format!("push from rank {from}: {e}"));
                                return;
                            }
                            st.push_queues[from as usize].push_back(QueuedPush {
                                msg,
                                arrived: Instant::now(),
                            });
                        }
                        // legacy un-windowed watermark: implies window 1
                        Frame::IterDone { iter, .. } => {
                            st.iters.on_watermark(from as usize, iter, 1);
                        }
                        Frame::IterDoneW { iter, window, .. } => {
                            st.iters.on_watermark(from as usize, iter, window);
                        }
                        Frame::Ring(bytes) => {
                            st.ring_queues[from as usize].push_back(bytes);
                        }
                        Frame::Heartbeat { .. } => {} // liveness: last_heard above
                        Frame::PrefetchReq { vids, .. } => {
                            // serve outside the state lock: feature reads
                            // and the reply write can be slow, and nothing
                            // here touches RecvState
                            drop(st);
                            serve_prefetch_req(&shared, from, &vids);
                            continue;
                        }
                        Frame::PrefetchRep { dim, vids, rows, .. } => {
                            // decode validated n_vids * dim == n_elems, so
                            // the per-row slicing below cannot go out of
                            // bounds; rows always land as f32 (the HEC
                            // stages level-0 features, which are f32)
                            let flat = match rows {
                                PushPayload::F32(v) => v,
                                PushPayload::Bf16(v) => {
                                    v.into_iter().map(crate::runtime::bf16::to_f32).collect()
                                }
                            };
                            for (i, vid) in vids.into_iter().enumerate() {
                                st.prefetch_rows.push(PrefetchedRow {
                                    vid,
                                    arrival: 0.0,
                                    row: flat[i * dim..(i + 1) * dim].to_vec(),
                                });
                            }
                        }
                        Frame::Resume { epoch, iter, window, .. } => {
                            // the peer resumed from a checkpoint: baseline its
                            // watermark so its first post-resume push (iter)
                            // passes the window check, and cross-check the
                            // resume point against our own — a mismatch means
                            // someone restarted from a stale checkpoint, which
                            // must fail loudly, not silently diverge
                            if iter > 0 {
                                st.iters.on_watermark(from as usize, iter - 1, window);
                            }
                            st.peer_resume[from as usize] = Some((epoch, iter));
                            if let Some((my_e, my_i)) = st.my_resume {
                                if (my_e, my_i) != (epoch, iter) {
                                    drop(st);
                                    fail(
                                        &shared,
                                        format!(
                                            "resume point mismatch: rank {from} resumed at \
                                             epoch {epoch} iteration {iter} but we resumed at \
                                             epoch {my_e} iteration {my_i} (stale checkpoint?)"
                                        ),
                                    );
                                    return;
                                }
                            }
                        }
                        Frame::PushBatch { from: bf, pushes } => {
                            if bf != from {
                                drop(st);
                                fail(
                                    &shared,
                                    format!("PUSH_BATCH from rank {from} claims rank {bf}"),
                                );
                                return;
                            }
                            // each batched push passes the same sliding
                            // window and lands in the same FIFO as an
                            // unbatched one — delivery order is untouched
                            for msg in pushes {
                                if let Err(e) = st.iters.check_push(from as usize, msg.sent_iter) {
                                    drop(st);
                                    fail(&shared, format!("batched push from rank {from}: {e}"));
                                    return;
                                }
                                st.push_queues[from as usize].push_back(QueuedPush {
                                    msg,
                                    arrived: Instant::now(),
                                });
                            }
                        }
                        Frame::ShmAttach { from: af, capacity } => {
                            // the producer's first ring frame; cross-check
                            // that the right rank attached to the right ring
                            if af != from || shm_cap != Some(capacity) {
                                drop(st);
                                fail(
                                    &shared,
                                    format!(
                                        "bad SHM_ATTACH from rank {from}: announced rank {af} \
                                         capacity {capacity}, stream capacity {shm_cap:?}"
                                    ),
                                );
                                return;
                            }
                        }
                        Frame::Topo { from: tf, host_fnv, leader } => {
                            let ok = match &shared.topo {
                                Some(t) => {
                                    tf == from
                                        && host_fnv == t.fnv
                                        && leader == t.leader_of[from as usize]
                                }
                                None => false,
                            };
                            if !ok {
                                drop(st);
                                fail(
                                    &shared,
                                    format!(
                                        "topology mismatch: rank {from} announced hosts \
                                         fingerprint {host_fnv:#x} / leader {leader}, which \
                                         disagrees with our view (inconsistent --hosts?)"
                                    ),
                                );
                                return;
                            }
                        }
                        Frame::Bye { .. } => {
                            got_bye = true;
                            drop(st);
                            shared.cv.notify_all();
                            break;
                        }
                        Frame::Hello { .. } => {} // late/duplicate hello: ignore
                        Frame::ScoreReq { .. } | Frame::ScoreRep { .. } => {
                            // serving frames belong to `distgnn serve`
                            // connections, never the training mesh
                            drop(st);
                            fail(&shared, format!("unexpected serving frame from rank {from}"));
                            return;
                        }
                    }
                    drop(st);
                    shared.cv.notify_all();
                }
                Err(e) => {
                    fail(&shared, format!("decoding frame from rank {from}: {e}"));
                    return;
                }
            },
            Err(_) => {
                // a read error is connection death (reset, severed socket),
                // not a protocol violation: the peer is dead
                mark_dead(&shared);
                return;
            }
        }
    }
    let mut st = shared.state.lock().unwrap();
    st.closed[from as usize] = true;
    // EOF without a BYE while we are not shutting down: the peer vanished
    // (SIGKILL, abort, dropped connection) — record it as a death so waits
    // fail fast instead of running out the full recv timeout
    if !got_bye
        && !shared.shutting_down.load(std::sync::atomic::Ordering::Relaxed)
        && st.dead[from as usize].is_none()
    {
        st.dead[from as usize] = Some(st.iters.watermark(from as usize));
    }
    shared.cv.notify_all();
}

/// Answer one PREFETCH_REQ from `from`: look up the registered source,
/// gather the rows it owns, and write a PREFETCH_REP on the dialed send
/// channel to that peer (under its mutex, like heartbeats). Entirely
/// best-effort: no registered source, no sender yet, nothing owned, or a
/// failed write just leaves the requester's misses cold — correctness
/// never depends on a prefetch reply arriving.
fn serve_prefetch_req(shared: &Shared, from: u32, vids: &[u32]) {
    let src = shared.prefetch_src.lock().unwrap().clone();
    let Some(src) = src else { return };
    let sender = shared
        .reply_senders
        .lock()
        .unwrap()
        .get(from as usize)
        .and_then(|o| o.clone());
    let Some(conn) = sender else { return };
    let dim = src.dim();
    let mut served = Vec::new();
    let mut flat = Vec::new();
    for &vid in vids {
        if let Some(row) = src.row(vid) {
            debug_assert_eq!(row.len(), dim);
            served.push(vid);
            flat.extend_from_slice(&row);
        }
    }
    if served.is_empty() {
        return;
    }
    // prefetch is best-effort accounting: an unframeable reply is dropped
    // like a lost wire frame, never an abort
    let Ok(frame) =
        wire::encode_prefetch_rep(shared.my_rank, dim, &served, &PushPayload::F32(flat))
    else {
        return;
    };
    let _ = wire::write_frame(&mut *conn.lock().unwrap(), &frame);
}

/// Ring link view over the socket mesh: send to `(rank+1) % k`, receive
/// RING frames queued from `(rank+k-1) % k`.
struct SocketRing<'a> {
    fabric: &'a mut SocketFabric,
}

impl RingLink for SocketRing<'_> {
    fn send_next(&mut self, payload: &[u8]) -> Result<()> {
        let next = ((self.fabric.rank as usize + 1) % self.fabric.k) as u32;
        // ring traffic is not counted in the AEP push stats, so the
        // traffic numbers stay comparable with SimFabric's — but chunks
        // whose successor edge leaves the host do count as wire bytes
        if self.fabric.k > 1 && !self.fabric.colocated[next as usize] {
            self.fabric.stats.wire_bytes += payload.len() as u64;
        }
        let frame = wire::encode_ring(payload);
        let conn = self.fabric.sender(next)?;
        let mut c = conn.lock().unwrap();
        wire::write_frame(&mut *c, &frame)
    }

    fn recv_prev(&mut self) -> Result<Vec<u8>> {
        let prev = (self.fabric.rank as usize + self.fabric.k - 1) % self.fabric.k;
        self.fabric.wait_state("ring payload", |st| {
            if let Some(b) = st.ring_queues[prev].pop_front() {
                return Some(Ok(b));
            }
            if st.closed[prev] {
                return Some(Err(anyhow::Error::new(PeerDied {
                    rank: prev as u32,
                    last_iter: st.iters.watermark(prev),
                })
                .context(format!("ring peer {prev} disconnected"))));
            }
            None
        })?
    }
}

impl Fabric for SocketFabric {
    fn ranks(&self) -> usize {
        self.k
    }

    fn is_real(&self) -> bool {
        true
    }

    fn send_pushes(&mut self, sends: Vec<(u32, PushMsg)>, _sender_now: f64) -> Result<f64> {
        let t0 = Instant::now();
        let batching = self.cfg.push_batch > 1;
        for (to, msg) in sends {
            debug_assert_ne!(to, self.rank);
            let payload = wire::encode_push(&msg)?;
            self.stats.msgs_sent += 1;
            self.stats.bytes_sent += msg.bytes() as u64;
            if !self.colocated[to as usize] {
                // bytes that actually leave the host over the NIC (shm
                // ring traffic stays local)
                self.stats.wire_bytes += msg.bytes() as u64;
            }
            if batching {
                // deferred: rides a PUSH_BATCH frame at the next watermark
                // flush — still ahead of the watermark in stream order
                self.pending_push[to as usize].push(payload);
                continue;
            }
            let conn = self.sender(to)?;
            wire::write_frame(&mut *conn.lock().unwrap(), &payload)
                .with_context(|| format!("pushing to rank {to}"))?;
        }
        Ok(t0.elapsed().as_secs_f64())
    }

    fn receive_upto(
        &mut self,
        rank: u32,
        max_sent_iter: usize,
        _receiver_now: f64,
    ) -> Result<(Vec<PushMsg>, f64)> {
        debug_assert_eq!(rank, self.rank);
        let t0 = Instant::now();
        let me = self.rank as usize;
        let k = self.k;
        // Block until every peer has finished pushing iteration
        // max_sent_iter (their ITER_DONE watermark passed it) — then the
        // delayed window is complete, exactly the sim's delivery set.
        let mut out_q = self.wait_state("AEP watermarks", |st| {
            let lagging = (0..k).any(|j| {
                j != me && !st.closed[j] && st.iters.watermark(j) < max_sent_iter as i64
            });
            if lagging {
                return None;
            }
            if let Some(j) = (0..k)
                .find(|&j| j != me && st.closed[j] && st.iters.watermark(j) < max_sent_iter as i64)
            {
                return Some(Err(anyhow::Error::new(PeerDied {
                    rank: j as u32,
                    last_iter: st.iters.watermark(j),
                })
                .context(format!(
                    "peer {j} disconnected before iteration {max_sent_iter}"
                ))));
            }
            // drain in sender-rank order, FIFO within a sender (matches
            // SimFabric: HEC store order is part of the bit-identical
            // contract)
            let mut out = Vec::new();
            for j in 0..k {
                let q = &mut st.push_queues[j];
                while let Some(front) = q.front() {
                    if front.msg.sent_iter <= max_sent_iter {
                        out.push(q.pop_front().unwrap());
                    } else {
                        break;
                    }
                }
            }
            Some(Ok(out))
        })??;
        let wait = t0.elapsed().as_secs_f64();
        self.stats.wait_secs += wait;
        let delivered = Instant::now();
        let msgs = out_q
            .drain(..)
            .map(|q| {
                // queue-resident time: how long the payload sat fully
                // received before consumption (the hidden overlap window)
                self.stats.flight_secs += delivered.duration_since(q.arrived).as_secs_f64();
                q.msg
            })
            .collect();
        Ok((msgs, wait))
    }

    fn complete_iteration(&mut self, rank: u32, iter: usize) -> Result<()> {
        debug_assert_eq!(rank, self.rank);
        // Deterministic fault injection fires at the completion of the
        // scheduled iteration, BEFORE the watermark frame goes out: peers
        // observe last_iter == iter - 1, exactly like a mid-iteration crash.
        if !self.cfg.fault_plan.is_empty() {
            if let Some(action) =
                self.cfg
                    .fault_plan
                    .action_at(self.rank, iter as u64, self.cfg.fault_gen)
            {
                match action.kind {
                    FaultKind::Kill => {
                        eprintln!("rank {}: fault plan: abort at iteration {iter}", self.rank);
                        std::process::abort();
                    }
                    FaultKind::DropConn => {
                        for conn in self.senders.iter().flatten() {
                            let _ = conn.lock().unwrap().shutdown_both();
                        }
                        return Err(anyhow::Error::new(FaultInjected {
                            rank: self.rank,
                            iter: iter as u64,
                        }));
                    }
                }
            }
        }
        self.last_iter
            .store(iter as i64, std::sync::atomic::Ordering::Relaxed);
        if self.cfg.push_batch > 1 {
            // batched mode: defer the watermark too; every push_batch-th
            // completion flushes the accumulated PUSH_BATCH frames
            // followed by this (latest) watermark
            self.pending_iters += 1;
            if (self.pending_iters as usize) >= self.cfg.push_batch {
                self.flush_pending()
                    .with_context(|| format!("flushing push batch at iteration {iter}"))?;
            }
            return Ok(());
        }
        // windowed watermark: advertise our pipeline depth alongside the
        // completed iteration so peers can bound our outstanding pushes
        let frame = wire::encode_iter_done_w(self.rank, iter as u64, self.depth);
        for j in 0..self.k as u32 {
            if j == self.rank {
                continue;
            }
            let conn = self.sender(j)?;
            wire::write_frame(&mut *conn.lock().unwrap(), &frame)
                .with_context(|| format!("iter-done to rank {j}"))?;
        }
        Ok(())
    }

    fn set_fault_plan(&mut self, plan: FaultPlan, gen: u32) -> Result<()> {
        self.cfg.fault_plan = plan;
        self.cfg.fault_gen = gen;
        Ok(())
    }

    fn flush_pushes(&mut self) -> Result<()> {
        self.flush_pending()
    }

    fn set_resume_point(&mut self, epoch: u64, iter: u64) -> Result<()> {
        // nothing deferred may straddle a resume announcement
        self.flush_pending()?;
        // Announce our resume point to every peer before any push: they
        // baseline our watermark (so our first post-resume push passes
        // their sliding-window check) and cross-check the point against
        // their own — restarting from a stale checkpoint fails loudly.
        let frame = wire::encode_resume(self.rank, epoch, iter, self.depth);
        for j in 0..self.k as u32 {
            if j == self.rank {
                continue;
            }
            let conn = self.sender(j)?;
            wire::write_frame(&mut *conn.lock().unwrap(), &frame)
                .with_context(|| format!("resume announce to rank {j}"))?;
        }
        let mut st = self.shared.state.lock().unwrap();
        st.my_resume = Some((epoch, iter));
        st.iters.resume_at(iter);
        for j in 0..self.k {
            if let Some((pe, pi)) = st.peer_resume[j] {
                anyhow::ensure!(
                    (pe, pi) == (epoch, iter),
                    "resume point mismatch: rank {j} resumed at epoch {pe} iteration {pi} \
                     but we resumed at epoch {epoch} iteration {iter} (stale checkpoint?)"
                );
            }
        }
        drop(st);
        if iter > 0 {
            self.last_iter
                .store(iter as i64 - 1, std::sync::atomic::Ordering::Relaxed);
        }
        Ok(())
    }

    fn register_prefetch_source(&mut self, rank: u32, src: Arc<dyn PrefetchSource>) {
        // one rank per process: sources for other ranks live in their own
        // processes, so a foreign registration is meaningless here
        if rank == self.rank {
            *self.shared.prefetch_src.lock().unwrap() = Some(src);
        }
    }

    fn prefetch_pull(&mut self, from_rank: u32, per_owner: &[Vec<u32>], _now: f64) -> Result<()> {
        debug_assert_eq!(from_rank, self.rank);
        for (owner, vids) in per_owner.iter().enumerate() {
            if owner == self.rank as usize || vids.is_empty() {
                continue;
            }
            let frame = wire::encode_prefetch_req(self.rank, vids)?;
            self.stats.msgs_sent += 1;
            self.stats.bytes_sent += frame.len() as u64;
            if !self.colocated[owner] {
                self.stats.wire_bytes += frame.len() as u64;
            }
            let conn = self.sender(owner as u32)?;
            wire::write_frame(&mut *conn.lock().unwrap(), &frame)
                .with_context(|| format!("prefetch request to rank {owner}"))?;
        }
        Ok(())
    }

    fn drain_prefetch(&mut self, rank: u32) -> Vec<PrefetchedRow> {
        debug_assert_eq!(rank, self.rank);
        std::mem::take(&mut self.shared.state.lock().unwrap().prefetch_rows)
    }

    fn set_pipeline_window(&mut self, depth: usize) -> Result<()> {
        anyhow::ensure!(depth >= 1, "pipeline window must be >= 1");
        // peers learned our depth from the rendezvous HELLO; silently
        // widening it afterwards would break their enforcement
        anyhow::ensure!(
            depth as u32 == self.depth,
            "socket pipeline window is fixed at rendezvous (HELLO advertised {}, got {depth}); \
             set SocketConfig::pipeline_window before connecting",
            self.depth
        );
        Ok(())
    }

    fn allreduce_grads(&mut self, grads: &mut [Vec<f32>], clocks: &mut [f64]) -> Result<Vec<f64>> {
        anyhow::ensure!(
            grads.len() == 1 && clocks.len() == 1,
            "socket fabric hosts exactly one rank per process"
        );
        // peers may be blocked in receive_upto on a deferred watermark;
        // flush before we block in the collective ourselves
        self.flush_pending()?;
        let (rank, k) = (self.rank as usize, self.k);
        let t0 = Instant::now();
        {
            let mut link = SocketRing { fabric: self };
            allreduce::ring_average_f32(rank, k, &mut grads[0], &mut link)?;
        }
        // measured wall time includes waiting for stragglers — the real
        // barrier idle the sim models as (max clock - own clock)
        let measured = t0.elapsed().as_secs_f64();
        let before = clocks[0];
        let local_done = before + measured;
        let all = {
            let mut link = SocketRing { fabric: self };
            allreduce::ring_allgather_f64(rank, k, &[local_done], &mut link)?
        };
        let maxc = all.iter().map(|v| v[0]).fold(local_done, f64::max);
        clocks[0] = maxc;
        Ok(vec![maxc - before])
    }

    fn align_clocks(&mut self, clocks: &mut [f64]) -> Result<()> {
        anyhow::ensure!(clocks.len() == 1, "socket fabric hosts one rank per process");
        self.flush_pending()?;
        let (rank, k) = (self.rank as usize, self.k);
        let mut link = SocketRing { fabric: self };
        let all = allreduce::ring_allgather_f64(rank, k, &[clocks[0]], &mut link)?;
        clocks[0] = all.iter().map(|v| v[0]).fold(clocks[0], f64::max);
        Ok(())
    }

    fn allgather_stats(&mut self, local: Vec<Vec<f64>>) -> Result<Vec<Vec<f64>>> {
        anyhow::ensure!(local.len() == 1, "socket fabric hosts one rank per process");
        self.flush_pending()?;
        let (rank, k) = (self.rank as usize, self.k);
        let mut link = SocketRing { fabric: self };
        allreduce::ring_allgather_f64(rank, k, &local[0], &mut link)
    }

    fn stats(&self) -> FabricStats {
        self.stats
    }

    fn shutdown(&mut self) -> Result<()> {
        self.shutdown_inner(true)
    }
}

impl Drop for SocketFabric {
    fn drop(&mut self) {
        // best effort; skip the join so a hung peer can't wedge Drop
        let _ = self.shutdown_inner(false);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::fabric::PushPayload;

    fn tmp_peers(n: usize, tag: &str) -> Vec<String> {
        let base = std::env::temp_dir().join(format!(
            "distgnn-sock-{}-{tag}",
            std::process::id()
        ));
        (0..n)
            .map(|r| base.join(format!("r{r}.sock")).to_string_lossy().to_string())
            .collect()
    }

    fn push(from: u32, sent_iter: usize, n: usize) -> PushMsg {
        PushMsg {
            from,
            layer: 0,
            vids: (0..n as u32).collect(),
            embeds: PushPayload::F32((0..n * 3).map(|i| i as f32 * 0.5).collect()),
            dim: 3,
            sent_iter,
            arrival: 0.0,
        }
    }

    /// Two in-process fabrics over unix sockets: pushes respect the
    /// iteration window, collectives agree, shutdown is clean.
    #[test]
    fn two_rank_unix_mesh_end_to_end() {
        let peers = tmp_peers(2, "e2e");
        let p0 = peers.clone();
        let p1 = peers.clone();
        let h0 = std::thread::spawn(move || -> Result<Vec<f64>> {
            let mut f = SocketFabric::connect(SocketConfig::new(0, p0))?;
            let mut b16 = push(0, 0, 2);
            b16.embeds = PushPayload::Bf16(vec![0x3FC0, 0x8000, 0x7F80, 0x0001, 0xBF12, 0x0000]);
            f.send_pushes(vec![(1, push(0, 0, 4)), (1, push(0, 0, 2)), (1, b16)], 0.0)?;
            f.complete_iteration(0, 0)?;
            f.send_pushes(vec![(1, push(0, 1, 8))], 0.0)?;
            f.complete_iteration(0, 1)?;
            let mut grads = vec![vec![1.0f32, 3.0]];
            let mut clocks = vec![0.25];
            f.allreduce_grads(&mut grads, &mut clocks)?;
            assert_eq!(grads[0], vec![2.0, 4.0]);
            let all = f.allgather_stats(vec![vec![7.0, 0.5]])?;
            f.shutdown()?;
            Ok(all.into_iter().flatten().collect())
        });
        let h1 = std::thread::spawn(move || -> Result<Vec<f64>> {
            let mut f = SocketFabric::connect(SocketConfig::new(1, p1))?;
            // nothing sent from rank 1 this iteration, but the watermark
            // still advances so rank 0-side receives can't stall
            f.complete_iteration(1, 0)?;
            f.complete_iteration(1, 1)?;
            // window <= 0: only the three iteration-0 pushes, FIFO order
            let (msgs, _) = f.receive_upto(1, 0, 0.0)?;
            assert_eq!(msgs.len(), 3);
            assert_eq!(msgs[0].vids.len(), 4);
            assert_eq!(msgs[1].vids.len(), 2);
            // the bf16 payload crossed the real wire bit-exactly
            assert_eq!(
                msgs[2].embeds,
                PushPayload::Bf16(vec![0x3FC0, 0x8000, 0x7F80, 0x0001, 0xBF12, 0x0000])
            );
            // window <= 1: the remaining push
            let (msgs2, _) = f.receive_upto(1, 1, 0.0)?;
            assert_eq!(msgs2.len(), 1);
            assert_eq!(msgs2[0].sent_iter, 1);
            assert_eq!(
                msgs2[0].embeds,
                PushPayload::F32((0..24).map(|i| i as f32 * 0.5).collect())
            );
            let mut grads = vec![vec![3.0f32, 5.0]];
            let mut clocks = vec![0.75];
            f.allreduce_grads(&mut grads, &mut clocks)?;
            assert_eq!(grads[0], vec![2.0, 4.0]);
            let all = f.allgather_stats(vec![vec![-1.0, 2.5]])?;
            f.shutdown()?;
            Ok(all.into_iter().flatten().collect())
        });
        let a = h0.join().unwrap().unwrap();
        let b = h1.join().unwrap().unwrap();
        // both ranks saw the same rank-ordered stats
        assert_eq!(a, vec![7.0, 0.5, -1.0, 2.5]);
        assert_eq!(a, b);
    }

    #[test]
    fn single_rank_socket_fabric_is_trivial() {
        let peers = tmp_peers(1, "solo");
        let mut f = SocketFabric::connect(SocketConfig::new(0, peers)).unwrap();
        let mut grads = vec![vec![2.0f32]];
        let mut clocks = vec![0.0];
        f.allreduce_grads(&mut grads, &mut clocks).unwrap();
        assert_eq!(grads[0], vec![2.0]);
        let all = f.allgather_stats(vec![vec![4.0]]).unwrap();
        assert_eq!(all, vec![vec![4.0]]);
        f.shutdown().unwrap();
    }

    /// A planned `drop_conn` fault on rank 1 severs its connections: rank 1
    /// itself gets a typed [`FaultInjected`], and rank 0's next receive
    /// fails fast with a typed [`PeerDied`] naming rank 1 — within seconds,
    /// not the full recv timeout.
    #[test]
    fn drop_conn_fault_surfaces_as_typed_peer_died() {
        let peers = tmp_peers(2, "dropconn");
        let p0 = peers.clone();
        let p1 = peers.clone();
        let h0 = std::thread::spawn(move || {
            let mut cfg = SocketConfig::new(0, p0);
            cfg.recv_timeout = Duration::from_secs(60);
            let mut f = SocketFabric::connect(cfg).unwrap();
            f.complete_iteration(0, 0).unwrap();
            let (msgs, _) = f.receive_upto(0, 0, 0.0).unwrap();
            assert!(msgs.is_empty());
            let t0 = Instant::now();
            let err = f.receive_upto(0, 1, 0.0).unwrap_err();
            let waited = t0.elapsed();
            let died = err
                .downcast_ref::<PeerDied>()
                .unwrap_or_else(|| panic!("expected typed PeerDied, got: {err:#}"));
            assert_eq!(died.rank, 1);
            assert_eq!(died.last_iter, 0);
            assert!(waited < Duration::from_secs(5), "detection took {waited:?}");
            // teardown after a peer death must not hang
            f.shutdown().unwrap();
        });
        let h1 = std::thread::spawn(move || {
            let mut cfg = SocketConfig::new(1, p1);
            cfg.fault_plan = FaultPlan::parse("drop_conn:rank=1,iter=1").unwrap();
            let mut f = SocketFabric::connect(cfg).unwrap();
            f.complete_iteration(1, 0).unwrap();
            let (msgs, _) = f.receive_upto(1, 0, 0.0).unwrap();
            assert!(msgs.is_empty());
            let err = f.complete_iteration(1, 1).unwrap_err();
            assert!(err.is::<FaultInjected>(), "{err:#}");
            let fi = err.downcast_ref::<FaultInjected>().unwrap();
            assert_eq!((fi.rank, fi.iter), (1, 1));
            f.shutdown().unwrap();
        });
        h0.join().unwrap();
        h1.join().unwrap();
    }

    /// A resume announcement baselines the sliding window on both sides so
    /// the first post-resume push is accepted, and mismatched resume points
    /// (a stale checkpoint) fail the run loudly.
    #[test]
    fn resume_handshake_baselines_windows_across_the_wire() {
        let peers = tmp_peers(2, "resume");
        let p0 = peers.clone();
        let p1 = peers.clone();
        let h0 = std::thread::spawn(move || {
            let mut f = SocketFabric::connect(SocketConfig::new(0, p0)).unwrap();
            f.set_resume_point(2, 6).unwrap();
            // first post-resume push carries sent_iter == 6: without the
            // baseline the peer's fresh window (watermark -1) would reject
            f.send_pushes(vec![(1, push(0, 6, 3))], 0.0).unwrap();
            f.complete_iteration(0, 6).unwrap();
            f.shutdown().unwrap();
        });
        let h1 = std::thread::spawn(move || {
            let mut f = SocketFabric::connect(SocketConfig::new(1, p1)).unwrap();
            f.set_resume_point(2, 6).unwrap();
            f.complete_iteration(1, 6).unwrap();
            let (msgs, _) = f.receive_upto(1, 6, 0.0).unwrap();
            assert_eq!(msgs.len(), 1);
            assert_eq!(msgs[0].sent_iter, 6);
            f.shutdown().unwrap();
        });
        h0.join().unwrap();
        h1.join().unwrap();
    }

    /// Prefetch pulls cross the real wire: rank 0 requests feature rows
    /// owned by rank 1, the PREFETCH_REP lands in rank 0's staging area
    /// with arrival 0.0 and bit-exact f32 payloads, and vids the owner
    /// does not hold are silently skipped.
    #[test]
    fn prefetch_pull_round_trips_rows_across_the_mesh() {
        struct Src;
        impl PrefetchSource for Src {
            fn dim(&self) -> usize {
                3
            }
            fn row(&self, vid_o: u32) -> Option<Vec<f32>> {
                (10..20).contains(&vid_o).then(|| vec![vid_o as f32, -0.0, 0.5])
            }
        }
        let peers = tmp_peers(2, "prefetch");
        let p0 = peers.clone();
        let p1 = peers.clone();
        let h0 = std::thread::spawn(move || {
            let mut f = SocketFabric::connect(SocketConfig::new(0, p0)).unwrap();
            // re-issue the pull until a reply lands: the peer may still be
            // registering its source when the first REQ arrives (prefetch
            // is best-effort, so an early REQ is legitimately dropped)
            let deadline = Instant::now() + Duration::from_secs(20);
            let rows = 'outer: loop {
                f.prefetch_pull(0, &[vec![], vec![10, 15, 999]], 0.0).unwrap();
                let retry_at = Instant::now() + Duration::from_millis(500);
                while Instant::now() < retry_at {
                    let rows = f.drain_prefetch(0);
                    if !rows.is_empty() {
                        break 'outer rows;
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                assert!(Instant::now() < deadline, "prefetch reply never arrived");
            };
            // each reply carries the owned subset in request order (999 is
            // not owned by rank 1); a retry may have produced duplicates
            assert!(rows.len() >= 2, "rows {:?}", rows.len());
            assert_eq!((rows[0].vid, rows[1].vid), (10, 15));
            assert_eq!(rows[0].row[0], 10.0);
            assert_eq!(rows[0].row[1].to_bits(), (-0.0f32).to_bits());
            assert_eq!(rows[1].row, vec![15.0, -0.0, 0.5]);
            assert!(rows.iter().all(|r| r.arrival == 0.0));
            // REQ traffic is counted on the requester
            assert!(f.stats().msgs_sent >= 1);
            // watermark signals the peer it may tear down
            f.complete_iteration(0, 0).unwrap();
            f.shutdown().unwrap();
        });
        let h1 = std::thread::spawn(move || {
            let mut f = SocketFabric::connect(SocketConfig::new(1, p1)).unwrap();
            f.register_prefetch_source(1, Arc::new(Src));
            // block until rank 0 watermarks iteration 0 — which it only
            // does after draining the reply — then tear down
            f.complete_iteration(1, 0).unwrap();
            let (msgs, _) = f.receive_upto(1, 0, 0.0).unwrap();
            assert!(msgs.is_empty());
            f.shutdown().unwrap();
        });
        h0.join().unwrap();
        h1.join().unwrap();
    }

    #[test]
    fn rendezvous_timeout_fails_cleanly() {
        let mut peers = tmp_peers(2, "timeout");
        peers[1] = "/nonexistent-dir-for-distgnn/never.sock".into();
        let mut cfg = SocketConfig::new(0, peers);
        cfg.connect_timeout = Duration::from_millis(200);
        let err = SocketFabric::connect(cfg).unwrap_err();
        assert!(format!("{err:#}").contains("timed out"), "{err:#}");
    }

    /// Two co-located ranks: every frame — pushes (bf16 bits included),
    /// watermarks, ring collectives, BYE — rides the shm rings, delivery
    /// matches the socket path exactly, and no byte is charged to the
    /// wire.
    #[test]
    fn shm_mesh_end_to_end_with_zero_wire_bytes() {
        let peers = tmp_peers(2, "shm");
        let hier = |rank: usize, peers: Vec<String>| {
            let mut cfg = SocketConfig::new(rank, peers);
            cfg.hosts = Some(vec![0, 0]);
            cfg
        };
        let p0 = peers.clone();
        let p1 = peers.clone();
        let h0 = std::thread::spawn(move || -> Result<u64> {
            let mut f = SocketFabric::connect(hier(0, p0))?;
            let mut b16 = push(0, 0, 2);
            b16.embeds = PushPayload::Bf16(vec![0x3FC0, 0x8000, 0x7F80, 0x0001, 0xBF12, 0x0000]);
            f.send_pushes(vec![(1, push(0, 0, 4)), (1, b16)], 0.0)?;
            f.complete_iteration(0, 0)?;
            let mut grads = vec![vec![1.0f32, 3.0]];
            let mut clocks = vec![0.25];
            f.allreduce_grads(&mut grads, &mut clocks)?;
            assert_eq!(grads[0], vec![2.0, 4.0]);
            let wire = f.stats().wire_bytes;
            f.shutdown()?;
            Ok(wire)
        });
        let h1 = std::thread::spawn(move || -> Result<u64> {
            let mut f = SocketFabric::connect(hier(1, p1))?;
            f.complete_iteration(1, 0)?;
            let (msgs, _) = f.receive_upto(1, 0, 0.0)?;
            assert_eq!(msgs.len(), 2);
            assert_eq!(msgs[0].vids.len(), 4);
            // bf16 payload crossed the mapped ring bit-exactly
            assert_eq!(
                msgs[1].embeds,
                PushPayload::Bf16(vec![0x3FC0, 0x8000, 0x7F80, 0x0001, 0xBF12, 0x0000])
            );
            let mut grads = vec![vec![3.0f32, 5.0]];
            let mut clocks = vec![0.75];
            f.allreduce_grads(&mut grads, &mut clocks)?;
            assert_eq!(grads[0], vec![2.0, 4.0]);
            let wire = f.stats().wire_bytes;
            f.shutdown()?;
            Ok(wire)
        });
        assert_eq!(h0.join().unwrap().unwrap(), 0);
        assert_eq!(h1.join().unwrap().unwrap(), 0);
    }

    /// A mixed mesh (hosts a:2,b:1): pushes to the co-located rank stay
    /// off the wire, pushes to the remote host are charged, and the
    /// hier gradient ring charges only the cross-host edges.
    #[test]
    fn hier_mesh_charges_only_cross_host_bytes() {
        let peers = tmp_peers(3, "mixed");
        let hier = |rank: usize, peers: Vec<String>| {
            let mut cfg = SocketConfig::new(rank, peers);
            cfg.hosts = Some(vec![0, 0, 1]);
            cfg
        };
        let mk = |rank: usize, peers: Vec<String>| {
            std::thread::spawn(move || -> Result<(FabricStats, Vec<f32>)> {
                let mut f = SocketFabric::connect(hier(rank, peers))?;
                if rank == 0 {
                    f.send_pushes(vec![(1, push(0, 0, 4)), (2, push(0, 0, 4))], 0.0)?;
                }
                f.complete_iteration(rank as u32, 0)?;
                let (msgs, _) = f.receive_upto(rank as u32, 0, 0.0)?;
                assert_eq!(msgs.len(), usize::from(rank != 0));
                let mut grads = vec![vec![rank as f32, 1.0]];
                let mut clocks = vec![0.0];
                f.allreduce_grads(&mut grads, &mut clocks)?;
                let stats = f.stats();
                f.shutdown()?;
                Ok((stats, grads.remove(0)))
            })
        };
        let handles: Vec<_> = (0..3).map(|r| mk(r, peers.clone())).collect();
        let results: Vec<(FabricStats, Vec<f32>)> = handles
            .into_iter()
            .map(|h| h.join().unwrap().unwrap())
            .collect();
        // all ranks agree on the average (0 + 1 + 2) / 3, (1+1+1)/3
        for (_, g) in &results {
            assert_eq!(*g, vec![1.0, 1.0]);
        }
        let one_push = push(0, 0, 4).bytes() as u64;
        // rank 0: push to colocated rank 1 is free, push to rank 2 is
        // wire; its ring successor (rank 1) is colocated, so no ring
        // bytes are charged
        assert_eq!(results[0].0.wire_bytes, one_push);
        assert_eq!(results[0].0.bytes_sent, 2 * one_push);
        // rank 1's successor is rank 2 (cross-host): its ring chunks are
        // wire bytes; rank 2's successor is rank 0 (cross-host) likewise
        assert!(results[1].0.wire_bytes > 0);
        assert!(results[2].0.wire_bytes > 0);
    }

    /// `push_batch = 2` defers pushes and watermarks to every second
    /// completion (and to collective entry), yet the receiver drains the
    /// exact same messages in the exact same order as unbatched mode.
    #[test]
    fn batched_pushes_flush_at_boundaries_with_identical_delivery() {
        let peers = tmp_peers(2, "batch");
        let p0 = peers.clone();
        let p1 = peers.clone();
        let h0 = std::thread::spawn(move || -> Result<()> {
            let mut cfg = SocketConfig::new(0, p0);
            cfg.pipeline_window = 2;
            cfg.push_batch = 2;
            let mut f = SocketFabric::connect(cfg)?;
            f.send_pushes(vec![(1, push(0, 0, 3))], 0.0)?;
            f.complete_iteration(0, 0)?; // deferred
            f.send_pushes(vec![(1, push(0, 1, 5))], 0.0)?;
            f.complete_iteration(0, 1)?; // boundary: flush batch + wm(1)
            f.send_pushes(vec![(1, push(0, 2, 7))], 0.0)?;
            f.complete_iteration(0, 2)?; // deferred again
            // collective entry flushes the tail batch before blocking
            let all = f.allgather_stats(vec![vec![0.5]])?;
            assert_eq!(all, vec![vec![0.5], vec![1.5]]);
            f.shutdown()?;
            Ok(())
        });
        let h1 = std::thread::spawn(move || -> Result<()> {
            let mut f = SocketFabric::connect(SocketConfig::new(1, p1))?;
            f.complete_iteration(1, 0)?;
            f.complete_iteration(1, 1)?;
            f.complete_iteration(1, 2)?;
            let (msgs, _) = f.receive_upto(1, 0, 0.0)?;
            assert_eq!(msgs.len(), 1);
            assert_eq!((msgs[0].sent_iter, msgs[0].vids.len()), (0, 3));
            let (msgs, _) = f.receive_upto(1, 1, 0.0)?;
            assert_eq!(msgs.len(), 1);
            assert_eq!((msgs[0].sent_iter, msgs[0].vids.len()), (1, 5));
            let (msgs, _) = f.receive_upto(1, 2, 0.0)?;
            assert_eq!(msgs.len(), 1);
            assert_eq!((msgs[0].sent_iter, msgs[0].vids.len()), (2, 7));
            let all = f.allgather_stats(vec![vec![1.5]])?;
            assert_eq!(all, vec![vec![0.5], vec![1.5]]);
            f.shutdown()?;
            Ok(())
        });
        h0.join().unwrap().unwrap();
        h1.join().unwrap().unwrap();
    }

    /// Satellite regression: the heartbeat beacon runs on its own thread,
    /// so a rank blocked inside a long collective keeps ticking and its
    /// peers never declare it dead by staleness. Rank 1 dawdles for well
    /// past the peer timeout before joining the allreduce; rank 0 blocks
    /// in `recv_prev` the whole time and must still succeed.
    #[test]
    fn heartbeat_keeps_beating_through_long_blocking_collectives() {
        let peers = tmp_peers(2, "hbcoll");
        let mk = |rank: usize, peers: Vec<String>| {
            let mut cfg = SocketConfig::new(rank, peers);
            cfg.heartbeat_interval = Duration::from_millis(100);
            cfg.peer_timeout = Duration::from_millis(1200);
            cfg.recv_timeout = Duration::from_secs(60);
            cfg
        };
        let p0 = peers.clone();
        let p1 = peers.clone();
        let h0 = std::thread::spawn(move || -> Result<Vec<f32>> {
            let mut f = SocketFabric::connect(mk(0, p0))?;
            let mut grads = vec![vec![1.0f32, 3.0]];
            let mut clocks = vec![0.0];
            // blocks ~3s waiting for rank 1 — more than twice the peer
            // timeout; only rank 1's heartbeats keep this from PeerDied
            f.allreduce_grads(&mut grads, &mut clocks)?;
            f.shutdown()?;
            Ok(grads.remove(0))
        });
        let h1 = std::thread::spawn(move || -> Result<Vec<f32>> {
            let mut f = SocketFabric::connect(mk(1, p1))?;
            std::thread::sleep(Duration::from_millis(3000));
            let mut grads = vec![vec![3.0f32, 5.0]];
            let mut clocks = vec![0.0];
            f.allreduce_grads(&mut grads, &mut clocks)?;
            f.shutdown()?;
            Ok(grads.remove(0))
        });
        assert_eq!(h0.join().unwrap().unwrap(), vec![2.0, 4.0]);
        assert_eq!(h1.join().unwrap().unwrap(), vec![2.0, 4.0]);
    }

    /// Ranks launched with disagreeing --hosts maps (same co-location
    /// pattern, different host labels -> different fingerprints) fail
    /// loudly at the TOPO handshake instead of silently misrouting.
    #[test]
    fn mismatched_hosts_maps_fail_loudly() {
        let peers = tmp_peers(2, "topomiss");
        let mk = |rank: usize, peers: Vec<String>, hosts: Vec<usize>| {
            let mut cfg = SocketConfig::new(rank, peers);
            cfg.hosts = Some(hosts);
            cfg.recv_timeout = Duration::from_secs(30);
            cfg
        };
        let p0 = peers.clone();
        let p1 = peers.clone();
        let h0 = std::thread::spawn(move || {
            let mut f = SocketFabric::connect(mk(0, p0, vec![0, 0])).unwrap();
            f.complete_iteration(0, 0).unwrap();
            let err = f.receive_upto(0, 0, 0.0).unwrap_err();
            assert!(format!("{err:#}").contains("topology mismatch"), "{err:#}");
            f.shutdown().unwrap();
        });
        let h1 = std::thread::spawn(move || {
            let mut f = SocketFabric::connect(mk(1, p1, vec![1, 1])).unwrap();
            f.complete_iteration(1, 0).unwrap();
            let err = f.receive_upto(1, 0, 0.0).unwrap_err();
            assert!(format!("{err:#}").contains("topology mismatch"), "{err:#}");
            f.shutdown().unwrap();
        });
        h0.join().unwrap();
        h1.join().unwrap();
    }
}
