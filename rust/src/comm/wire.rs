//! Length-prefixed binary framing for the socket fabric.
//!
//! Every frame on the wire is `u32 payload_len (LE)` followed by exactly
//! `payload_len` bytes. The first payload byte is a tag; the remainder is
//! the tag-specific body. All integers are little-endian; embeddings are
//! raw IEEE-754 f32 bits or raw bf16 bit patterns (the push body carries
//! a dtype code), so a [`PushMsg`] round-trips bit-exactly — the socket
//! fabric's bit-identical-losses guarantee rests on this.
//!
//! Frame kinds:
//! * `HELLO {from, window}` — sent once by the dialing rank right after
//!   connecting, so the acceptor learns which peer the inbound stream
//!   belongs to — and that peer's pipeline depth, so the sliding push
//!   window is enforceable from the very first push (before any
//!   ITER_DONE has been exchanged).
//! * `PUSH {PushMsg}`    — one AEP embedding push (layer, vids, embeds).
//! * `ITER_DONE {from, iter}` — watermark: the sender finished the push
//!   phase of (global) iteration `iter`; the receiver's delayed delivery
//!   window is complete once every peer's watermark passes `k - d`.
//!   Implies the classic double-buffer promise (window 1).
//! * `ITER_DONE_W {from, iter, window}` — windowed watermark: same as
//!   `ITER_DONE`, plus the sender advertises its pipeline depth `p` — a
//!   promise that it never has pushes for more than `p` iterations
//!   outstanding past its own watermark (the sliding window the depth-`p`
//!   pipeline rides on; receivers enforce it, see
//!   [`crate::comm::netsim::IterWindow`]).
//! * `RING {bytes}`      — one hop of a ring collective (allreduce /
//!   allgather payloads, opaque to the framing layer).
//! * `BYE {from}`        — clean shutdown notice.
//! * `HEARTBEAT {from, iters_done}` — periodic liveness beacon carrying
//!   how many global iterations the sender has completed. A peer whose
//!   heartbeats (or any other frames) stop arriving for the staleness
//!   timeout is declared dead ([`crate::comm::faults::PeerDied`]) even if
//!   its socket never closes — the silent-wedge / partition case EOF
//!   detection cannot cover.
//! * `PREFETCH_REQ {from, vids}` — lookahead pull request: the sender's
//!   depth-`p` ring staged a future minibatch whose level-0 halo vids
//!   missed its HEC; the owner should reply with their feature rows.
//!   Purely an accounting/overlap frame — replies land in a side-car
//!   staging area, never in the packer-visible cache, so losses stay
//!   bit-identical whether or not prefetch is on.
//! * `PREFETCH_REP {from, dim, dtype, vids, rows}` — the owner's reply:
//!   one feature row per requested vid it owns, in the run's storage
//!   dtype (bf16 rows cost half the wire bytes, exactly like `PUSH`).
//! * `RESUME {from, epoch, iter, window}` — windowed-resume announcement,
//!   sent once by every rank restarting from a checkpoint before any
//!   post-resume push. Receivers baseline the sender's watermark to
//!   `iter - 1` (the sliding push window would otherwise reject the first
//!   post-resume push as a pipeline-window violation) and verify the
//!   announced `(epoch, iter)` matches their own resume point — a
//!   mismatch means some rank restarted from a stale checkpoint.
//! * `SHM_ATTACH {from, capacity}` — first frame a rank writes into a
//!   freshly mapped shared-memory ring (`comm/shm.rs`): the writer's rank
//!   and the data capacity it mapped. The reader cross-checks both
//!   against the ring header it created, so a stale mapping from an
//!   earlier incarnation can never be mistaken for the live peer.
//! * `TOPO {from, host_fnv, leader}` — hierarchical-topology handshake:
//!   after rendezvous every rank broadcasts the FNV-1a hash of its
//!   `--hosts` spec and the rank it believes is its host's leader.
//!   Receivers verify both match their own view; a mismatch is a typed
//!   config error (two ranks launched with different topology specs).
//! * `PUSH_BATCH {from, count, count × (len, push-body)}` — `count`
//!   whole `PUSH` frame payloads packed into one frame. Senders batching
//!   `p` iterations of AEP pushes emit one `PUSH_BATCH` followed by one
//!   watermark, amortizing framing and wakeups; receivers unpack and
//!   enqueue the inner pushes in order, so delivery order — and therefore
//!   the loss sequence — is identical to unbatched sends. Inner bodies
//!   must be `PUSH` frames from the same sender (nesting is rejected).
//! * `SCORE_REQ {req_id, vids}` — serving-path request (`distgnn serve`):
//!   score/classify these vertex ids (VID_o) with the loaded checkpoint.
//!   `req_id` is an opaque client-chosen correlation id echoed in the
//!   reply, so a client may pipeline requests over one connection while
//!   the server coalesces arrivals into deadline batches.
//! * `SCORE_REP {req_id, status, num_classes, vids, scores}` — the
//!   server's reply: per-vid class logits (raw f32 little-endian bits, so
//!   repeated requests compare bit-exactly), or an empty body with a
//!   nonzero `status` code — [`SCORE_OVERLOADED`] (admission control
//!   rejected the request: bounded queue full) or [`SCORE_BAD_REQUEST`]
//!   (unknown vertex id / malformed request).
//!
//! Counts and dimensions ride the wire as `u32`. Every encoder routes
//! them through a checked conversion: a value past `u32::MAX` is a typed
//! [`FieldTooLarge`] error at encode time, never a silent `as u32`
//! truncation that would frame a self-inconsistent payload.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use crate::comm::fabric::{PushMsg, PushPayload};
use crate::runtime::tensor::as_bytes;

pub const TAG_HELLO: u8 = 1;
pub const TAG_PUSH: u8 = 2;
pub const TAG_ITER_DONE: u8 = 3;
pub const TAG_RING: u8 = 4;
pub const TAG_BYE: u8 = 5;
pub const TAG_ITER_DONE_W: u8 = 6;
pub const TAG_HEARTBEAT: u8 = 7;
pub const TAG_RESUME: u8 = 8;
pub const TAG_PREFETCH_REQ: u8 = 9;
pub const TAG_PREFETCH_REP: u8 = 10;
pub const TAG_SHM_ATTACH: u8 = 11;
pub const TAG_TOPO: u8 = 12;
pub const TAG_PUSH_BATCH: u8 = 13;
pub const TAG_SCORE_REQ: u8 = 14;
pub const TAG_SCORE_REP: u8 = 15;

/// `SCORE_REP` status: request served, scores present.
pub const SCORE_OK: u32 = 0;
/// `SCORE_REP` status: admission control rejected the request (bounded
/// queue full). The typed client-side form is
/// [`crate::serve::ServeRejected`].
pub const SCORE_OVERLOADED: u32 = 1;
/// `SCORE_REP` status: the request named a vertex the server does not
/// own, or was otherwise malformed.
pub const SCORE_BAD_REQUEST: u32 = 2;

/// Hard cap on a frame payload: guards allocations against corrupt or
/// malicious length prefixes (1 GiB is far above any real minibatch push).
pub const MAX_FRAME: usize = 1 << 30;

/// Typed error: a frame payload exceeds [`MAX_FRAME`]. Returned by
/// [`write_frame`] *before any bytes hit the wire* — past `u32::MAX` the
/// length prefix would wrap and desync the stream, and even below that a
/// frame over the cap would be rejected by every receiver, so the sender
/// fails fast and the stream stays framable. Recover the typed value with
/// `err.downcast_ref::<FrameTooLarge>()`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FrameTooLarge {
    /// The offending payload length in bytes.
    pub len: usize,
    /// The cap it exceeded ([`MAX_FRAME`]).
    pub cap: usize,
}

impl std::fmt::Display for FrameTooLarge {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "frame payload {} bytes exceeds cap {} bytes",
            self.len, self.cap
        )
    }
}

impl std::error::Error for FrameTooLarge {}

/// Typed error: a count or dimension field does not fit the wire
/// format's `u32` representation. Returned by the encoders *before any
/// bytes are produced* — a bare `as u32` cast here would silently
/// truncate the count and frame a self-inconsistent payload that every
/// receiver rejects (or worse, accepts with the wrong shape). Same
/// recovery pattern as [`FrameTooLarge`]:
/// `err.downcast_ref::<FieldTooLarge>()`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FieldTooLarge {
    /// Which field overflowed (e.g. `"push dim"`).
    pub field: &'static str,
    /// The offending value.
    pub value: usize,
}

impl std::fmt::Display for FieldTooLarge {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "wire field {} = {} exceeds u32::MAX ({})",
            self.field,
            self.value,
            u32::MAX
        )
    }
}

impl std::error::Error for FieldTooLarge {}

/// Checked `usize -> u32` for wire counts/dims: overflow is a typed
/// [`FieldTooLarge`], never a truncating cast.
fn try_u32(v: usize, field: &'static str) -> Result<u32> {
    u32::try_from(v).map_err(|_| anyhow::Error::new(FieldTooLarge { field, value: v }))
}

/// A decoded frame.
#[derive(Debug)]
pub enum Frame {
    /// Rendezvous greeting: the dialer's rank and pipeline depth.
    Hello { from: u32, window: u32 },
    Push(PushMsg),
    IterDone { from: u32, iter: u64 },
    /// Windowed watermark: `ITER_DONE` plus the sender's pipeline depth.
    IterDoneW { from: u32, iter: u64, window: u32 },
    Ring(Vec<u8>),
    Bye { from: u32 },
    /// Liveness beacon: the sender has completed `iters_done` global
    /// iterations (watermark + 1, so a rank that has not finished any
    /// iteration yet beacons 0).
    Heartbeat { from: u32, iters_done: u64 },
    /// Windowed-resume announcement: the sender restarted from a
    /// checkpoint at `(epoch, iter)` and will push with pipeline depth
    /// `window`; receivers baseline its watermark to `iter - 1`.
    Resume { from: u32, epoch: u64, iter: u64, window: u32 },
    /// Lookahead prefetch pull: `from` asks for the feature rows of
    /// `vids` (VID_o, all owned by the receiving rank).
    PrefetchReq { from: u32, vids: Vec<u32> },
    /// Prefetch reply: one `dim`-wide feature row per vid, in the payload
    /// dtype (raw f32 or bf16 bits — same bit-exact framing as `Push`).
    PrefetchRep {
        from: u32,
        dim: usize,
        vids: Vec<u32>,
        rows: PushPayload,
    },
    /// Shared-memory ring attach: the writer's rank and the data capacity
    /// it mapped, cross-checked against the ring the reader created.
    ShmAttach { from: u32, capacity: u64 },
    /// Hierarchical-topology handshake: the sender's FNV-1a hash of the
    /// `--hosts` spec and the rank it elected leader of its host.
    Topo { from: u32, host_fnv: u64, leader: u32 },
    /// A batch of whole `PUSH` messages from one sender, delivered in
    /// order — the batched-sender frame (`p` iterations per watermark).
    PushBatch { from: u32, pushes: Vec<PushMsg> },
    /// Serving-path request: score these vertex ids. `req_id` is an
    /// opaque correlation id echoed in the reply.
    ScoreReq { req_id: u64, vids: Vec<u32> },
    /// Serving-path reply: one `num_classes`-wide logit row per vid when
    /// `status` is [`SCORE_OK`]; empty body otherwise.
    ScoreRep {
        req_id: u64,
        status: u32,
        num_classes: usize,
        vids: Vec<u32>,
        scores: Vec<f32>,
    },
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!(
                "truncated frame: need {} bytes at offset {}, have {}",
                n,
                self.pos,
                self.buf.len() - self.pos
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("frame has {} trailing bytes", self.buf.len() - self.pos);
        }
        Ok(())
    }
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

/// Push-body dtype codes (one u32 after `dim`).
const PUSH_DTYPE_F32: u32 = 0;
const PUSH_DTYPE_BF16: u32 = 1;

/// Encode a push payload (tag + body, no length prefix).
///
/// Layout after the tag byte: `from u32, layer u32, sent_iter u64, dim u32,
/// dtype u32 (0 = f32, 1 = bf16), n_vids u32, n_embeds u32,
/// vids [u32; n_vids], embeds [f32|bf16; n_embeds]` (raw little-endian
/// bits — bf16 rows cost 2 bytes per element on the wire).
/// `n_embeds` is redundant (`n_vids * dim`) but encoded so a decoder can
/// reject inconsistent frames without trusting the length prefix alone.
/// Counts/dims past `u32::MAX` are a typed [`FieldTooLarge`] error.
pub fn encode_push(msg: &PushMsg) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(1 + 32 + msg.vids.len() * 4 + msg.embeds.bytes());
    out.push(TAG_PUSH);
    put_u32(&mut out, msg.from);
    put_u32(&mut out, try_u32(msg.layer, "push layer")?);
    put_u64(&mut out, msg.sent_iter as u64);
    put_u32(&mut out, try_u32(msg.dim, "push dim")?);
    let dtype = match &msg.embeds {
        PushPayload::F32(_) => PUSH_DTYPE_F32,
        PushPayload::Bf16(_) => PUSH_DTYPE_BF16,
    };
    put_u32(&mut out, dtype);
    put_u32(&mut out, try_u32(msg.vids.len(), "push vid count")?);
    put_u32(&mut out, try_u32(msg.embeds.len(), "push embed count")?);
    for &v in &msg.vids {
        put_u32(&mut out, v);
    }
    // one block copy per payload (little-endian host, checked at compile
    // time by as_bytes) — the hot AEP path serializes without a per-element
    // loop
    match &msg.embeds {
        PushPayload::F32(es) => out.extend_from_slice(as_bytes(es)),
        PushPayload::Bf16(es) => out.extend_from_slice(as_bytes(es)),
    }
    Ok(out)
}

/// Rendezvous greeting: the dialing rank and its pipeline depth.
pub fn encode_hello(from: u32, window: u32) -> Vec<u8> {
    let mut out = vec![TAG_HELLO];
    put_u32(&mut out, from);
    put_u32(&mut out, window);
    out
}

pub fn encode_iter_done(from: u32, iter: u64) -> Vec<u8> {
    let mut out = vec![TAG_ITER_DONE];
    put_u32(&mut out, from);
    put_u64(&mut out, iter);
    out
}

/// Windowed watermark: `iter` complete, at pipeline depth `window`.
pub fn encode_iter_done_w(from: u32, iter: u64, window: u32) -> Vec<u8> {
    let mut out = vec![TAG_ITER_DONE_W];
    put_u32(&mut out, from);
    put_u64(&mut out, iter);
    put_u32(&mut out, window);
    out
}

pub fn encode_ring(bytes: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(1 + bytes.len());
    out.push(TAG_RING);
    out.extend_from_slice(bytes);
    out
}

pub fn encode_bye(from: u32) -> Vec<u8> {
    let mut out = vec![TAG_BYE];
    put_u32(&mut out, from);
    out
}

/// Liveness beacon: `iters_done` global iterations completed so far.
pub fn encode_heartbeat(from: u32, iters_done: u64) -> Vec<u8> {
    let mut out = vec![TAG_HEARTBEAT];
    put_u32(&mut out, from);
    put_u64(&mut out, iters_done);
    out
}

/// Windowed-resume announcement: restart from checkpoint `(epoch, iter)`
/// at pipeline depth `window`.
pub fn encode_resume(from: u32, epoch: u64, iter: u64, window: u32) -> Vec<u8> {
    let mut out = vec![TAG_RESUME];
    put_u32(&mut out, from);
    put_u64(&mut out, epoch);
    put_u64(&mut out, iter);
    put_u32(&mut out, window);
    out
}

/// Lookahead prefetch pull request.
///
/// Layout after the tag byte: `from u32, n_vids u32, vids [u32; n_vids]`.
pub fn encode_prefetch_req(from: u32, vids: &[u32]) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(1 + 8 + vids.len() * 4);
    out.push(TAG_PREFETCH_REQ);
    put_u32(&mut out, from);
    put_u32(&mut out, try_u32(vids.len(), "prefetch request vid count")?);
    for &v in vids {
        put_u32(&mut out, v);
    }
    Ok(out)
}

/// Prefetch reply: the owner's feature rows for `vids`.
///
/// Layout after the tag byte: `from u32, dim u32, dtype u32 (0 = f32,
/// 1 = bf16), n_vids u32, n_elems u32, vids [u32; n_vids],
/// rows [f32|bf16; n_elems]` (raw little-endian bits). `n_elems` is
/// redundant (`n_vids * dim`) but encoded so a decoder can reject
/// inconsistent frames, exactly like `PUSH`.
pub fn encode_prefetch_rep(
    from: u32,
    dim: usize,
    vids: &[u32],
    rows: &PushPayload,
) -> Result<Vec<u8>> {
    debug_assert_eq!(rows.len(), vids.len() * dim);
    let mut out = Vec::with_capacity(1 + 24 + vids.len() * 4 + rows.bytes());
    out.push(TAG_PREFETCH_REP);
    put_u32(&mut out, from);
    put_u32(&mut out, try_u32(dim, "prefetch reply dim")?);
    let dtype = match rows {
        PushPayload::F32(_) => PUSH_DTYPE_F32,
        PushPayload::Bf16(_) => PUSH_DTYPE_BF16,
    };
    put_u32(&mut out, dtype);
    put_u32(&mut out, try_u32(vids.len(), "prefetch reply vid count")?);
    put_u32(&mut out, try_u32(rows.len(), "prefetch reply elem count")?);
    for &v in vids {
        put_u32(&mut out, v);
    }
    match rows {
        PushPayload::F32(es) => out.extend_from_slice(as_bytes(es)),
        PushPayload::Bf16(es) => out.extend_from_slice(as_bytes(es)),
    }
    Ok(out)
}

/// Shared-memory ring attach: the writer's rank and the mapped data
/// capacity, cross-checked by the ring's creator against its own header.
pub fn encode_shm_attach(from: u32, capacity: u64) -> Vec<u8> {
    let mut out = vec![TAG_SHM_ATTACH];
    put_u32(&mut out, from);
    put_u64(&mut out, capacity);
    out
}

/// Hierarchical-topology handshake: the sender's FNV-1a hash of the
/// `--hosts` spec and the rank it elected leader of its host.
pub fn encode_topo(from: u32, host_fnv: u64, leader: u32) -> Vec<u8> {
    let mut out = vec![TAG_TOPO];
    put_u32(&mut out, from);
    put_u64(&mut out, host_fnv);
    put_u32(&mut out, leader);
    out
}

/// Pack pre-encoded `PUSH` frame payloads (each exactly the output of
/// [`encode_push`]) into one `PUSH_BATCH` frame.
///
/// Layout after the tag byte: `from u32, count u32,
/// count × (body_len u32, body [u8; body_len])`. The inner bodies stay
/// bit-exact, so a batched push decodes to the same [`PushMsg`]s as the
/// unbatched frames would.
pub fn encode_push_batch(from: u32, bodies: &[Vec<u8>]) -> Result<Vec<u8>> {
    let total: usize = bodies.iter().map(|b| 4 + b.len()).sum();
    let mut out = Vec::with_capacity(1 + 8 + total);
    out.push(TAG_PUSH_BATCH);
    put_u32(&mut out, from);
    put_u32(&mut out, try_u32(bodies.len(), "push batch entry count")?);
    for b in bodies {
        debug_assert_eq!(b.first(), Some(&TAG_PUSH), "batch entry must be a PUSH frame");
        put_u32(&mut out, try_u32(b.len(), "push batch entry length")?);
        out.extend_from_slice(b);
    }
    Ok(out)
}

/// Serving-path request: score these vertex ids (VID_o).
///
/// Layout after the tag byte: `req_id u64, n_vids u32, vids [u32; n_vids]`.
pub fn encode_score_req(req_id: u64, vids: &[u32]) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(1 + 12 + vids.len() * 4);
    out.push(TAG_SCORE_REQ);
    put_u64(&mut out, req_id);
    put_u32(&mut out, try_u32(vids.len(), "score request vid count")?);
    for &v in vids {
        put_u32(&mut out, v);
    }
    Ok(out)
}

/// Serving-path reply: one `num_classes`-wide logit row per vid (raw f32
/// little-endian bits — bit-exact round trip, like `PUSH`), or an empty
/// body with a nonzero status ([`SCORE_OVERLOADED`] /
/// [`SCORE_BAD_REQUEST`]).
///
/// Layout after the tag byte: `req_id u64, status u32, num_classes u32,
/// n_vids u32, n_scores u32, vids [u32; n_vids], scores [f32; n_scores]`.
/// `n_scores` is redundant (`n_vids * num_classes`) but encoded so a
/// decoder can reject inconsistent frames without trusting the length
/// prefix alone.
pub fn encode_score_rep(
    req_id: u64,
    status: u32,
    num_classes: usize,
    vids: &[u32],
    scores: &[f32],
) -> Result<Vec<u8>> {
    debug_assert_eq!(scores.len(), vids.len() * num_classes);
    let mut out = Vec::with_capacity(1 + 24 + vids.len() * 4 + scores.len() * 4);
    out.push(TAG_SCORE_REP);
    put_u64(&mut out, req_id);
    put_u32(&mut out, status);
    put_u32(&mut out, try_u32(num_classes, "score reply class count")?);
    put_u32(&mut out, try_u32(vids.len(), "score reply vid count")?);
    put_u32(&mut out, try_u32(scores.len(), "score reply score count")?);
    for &v in vids {
        put_u32(&mut out, v);
    }
    out.extend_from_slice(as_bytes(scores));
    Ok(out)
}

/// Decode one frame payload (the bytes after the length prefix).
pub fn decode_frame(payload: &[u8]) -> Result<Frame> {
    let Some((&tag, body)) = payload.split_first() else {
        bail!("empty frame");
    };
    let mut c = Cursor { buf: body, pos: 0 };
    match tag {
        TAG_HELLO => {
            let from = c.u32()?;
            let window = c.u32()?;
            if window == 0 {
                bail!("HELLO advertises pipeline window 0 (minimum is 1)");
            }
            c.done()?;
            Ok(Frame::Hello { from, window })
        }
        TAG_PUSH => {
            let from = c.u32()?;
            let layer = c.u32()? as usize;
            let sent_iter = c.u64()? as usize;
            let dim = c.u32()? as usize;
            let dtype = c.u32()?;
            let n_vids = c.u32()? as usize;
            let n_embeds = c.u32()? as usize;
            if n_vids.checked_mul(dim) != Some(n_embeds) {
                bail!("push frame inconsistent: {n_vids} vids x dim {dim} != {n_embeds} embeds");
            }
            let vid_bytes = c.take(n_vids * 4).context("truncated push frame (vids)")?;
            let vids: Vec<u32> = vid_bytes
                .chunks_exact(4)
                .map(|b| u32::from_le_bytes(b.try_into().unwrap()))
                .collect();
            let embeds = match dtype {
                PUSH_DTYPE_F32 => {
                    let emb_bytes = c
                        .take(n_embeds * 4)
                        .context("truncated push frame (embeds)")?;
                    PushPayload::F32(
                        emb_bytes
                            .chunks_exact(4)
                            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                            .collect(),
                    )
                }
                PUSH_DTYPE_BF16 => {
                    let emb_bytes = c
                        .take(n_embeds * 2)
                        .context("truncated push frame (embeds)")?;
                    PushPayload::Bf16(
                        emb_bytes
                            .chunks_exact(2)
                            .map(|b| u16::from_le_bytes(b.try_into().unwrap()))
                            .collect(),
                    )
                }
                other => bail!("push frame has unknown dtype code {other}"),
            };
            c.done()?;
            Ok(Frame::Push(PushMsg {
                from,
                layer,
                vids,
                embeds,
                dim,
                sent_iter,
                arrival: 0.0,
            }))
        }
        TAG_ITER_DONE => {
            let from = c.u32()?;
            let iter = c.u64()?;
            c.done()?;
            Ok(Frame::IterDone { from, iter })
        }
        TAG_ITER_DONE_W => {
            let from = c.u32()?;
            let iter = c.u64()?;
            let window = c.u32()?;
            if window == 0 {
                bail!("windowed ITER_DONE advertises window 0 (minimum is 1)");
            }
            c.done()?;
            Ok(Frame::IterDoneW { from, iter, window })
        }
        TAG_RING => Ok(Frame::Ring(body.to_vec())),
        TAG_BYE => {
            let from = c.u32()?;
            c.done()?;
            Ok(Frame::Bye { from })
        }
        TAG_HEARTBEAT => {
            let from = c.u32()?;
            let iters_done = c.u64()?;
            c.done()?;
            Ok(Frame::Heartbeat { from, iters_done })
        }
        TAG_RESUME => {
            let from = c.u32()?;
            let epoch = c.u64()?;
            let iter = c.u64()?;
            let window = c.u32()?;
            if window == 0 {
                bail!("RESUME advertises pipeline window 0 (minimum is 1)");
            }
            c.done()?;
            Ok(Frame::Resume { from, epoch, iter, window })
        }
        TAG_PREFETCH_REQ => {
            let from = c.u32()?;
            let n_vids = c.u32()? as usize;
            let vid_bytes = c
                .take(n_vids * 4)
                .context("truncated prefetch request (vids)")?;
            let vids: Vec<u32> = vid_bytes
                .chunks_exact(4)
                .map(|b| u32::from_le_bytes(b.try_into().unwrap()))
                .collect();
            c.done()?;
            Ok(Frame::PrefetchReq { from, vids })
        }
        TAG_PREFETCH_REP => {
            let from = c.u32()?;
            let dim = c.u32()? as usize;
            let dtype = c.u32()?;
            let n_vids = c.u32()? as usize;
            let n_elems = c.u32()? as usize;
            if n_vids.checked_mul(dim) != Some(n_elems) {
                bail!(
                    "prefetch reply inconsistent: {n_vids} vids x dim {dim} != {n_elems} elems"
                );
            }
            let vid_bytes = c
                .take(n_vids * 4)
                .context("truncated prefetch reply (vids)")?;
            let vids: Vec<u32> = vid_bytes
                .chunks_exact(4)
                .map(|b| u32::from_le_bytes(b.try_into().unwrap()))
                .collect();
            let rows = match dtype {
                PUSH_DTYPE_F32 => {
                    let row_bytes = c
                        .take(n_elems * 4)
                        .context("truncated prefetch reply (rows)")?;
                    PushPayload::F32(
                        row_bytes
                            .chunks_exact(4)
                            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                            .collect(),
                    )
                }
                PUSH_DTYPE_BF16 => {
                    let row_bytes = c
                        .take(n_elems * 2)
                        .context("truncated prefetch reply (rows)")?;
                    PushPayload::Bf16(
                        row_bytes
                            .chunks_exact(2)
                            .map(|b| u16::from_le_bytes(b.try_into().unwrap()))
                            .collect(),
                    )
                }
                other => bail!("prefetch reply has unknown dtype code {other}"),
            };
            c.done()?;
            Ok(Frame::PrefetchRep { from, dim, vids, rows })
        }
        TAG_SHM_ATTACH => {
            let from = c.u32()?;
            let capacity = c.u64()?;
            if capacity == 0 {
                bail!("SHM_ATTACH advertises capacity 0");
            }
            c.done()?;
            Ok(Frame::ShmAttach { from, capacity })
        }
        TAG_TOPO => {
            let from = c.u32()?;
            let host_fnv = c.u64()?;
            let leader = c.u32()?;
            c.done()?;
            Ok(Frame::Topo { from, host_fnv, leader })
        }
        TAG_PUSH_BATCH => {
            let from = c.u32()?;
            let count = c.u32()? as usize;
            // each entry costs at least its 4-byte length prefix, so a
            // count that cannot possibly fit is rejected before any
            // entry-proportional work happens
            if count > c.remaining() / 4 {
                bail!(
                    "push batch claims {count} entries in {} remaining bytes",
                    c.remaining()
                );
            }
            let mut pushes = Vec::new();
            for i in 0..count {
                let len = c.u32()? as usize;
                let body = c
                    .take(len)
                    .with_context(|| format!("truncated push batch entry {i}"))?;
                // only whole PUSH frames may nest — anything else
                // (including a nested batch) is a protocol error, which
                // also bounds decode recursion at one level
                if body.first() != Some(&TAG_PUSH) {
                    bail!("push batch entry {i} is not a PUSH frame");
                }
                match decode_frame(body).with_context(|| format!("push batch entry {i}"))? {
                    Frame::Push(m) => {
                        if m.from != from {
                            bail!(
                                "push batch from rank {from} contains a push from rank {}",
                                m.from
                            );
                        }
                        pushes.push(m);
                    }
                    other => bail!("push batch entry {i} decoded as {other:?}"),
                }
            }
            c.done()?;
            Ok(Frame::PushBatch { from, pushes })
        }
        TAG_SCORE_REQ => {
            let req_id = c.u64()?;
            let n_vids = c.u32()? as usize;
            let vid_bytes = c
                .take(n_vids * 4)
                .context("truncated score request (vids)")?;
            let vids: Vec<u32> = vid_bytes
                .chunks_exact(4)
                .map(|b| u32::from_le_bytes(b.try_into().unwrap()))
                .collect();
            c.done()?;
            Ok(Frame::ScoreReq { req_id, vids })
        }
        TAG_SCORE_REP => {
            let req_id = c.u64()?;
            let status = c.u32()?;
            if status > SCORE_BAD_REQUEST {
                bail!("score reply has unknown status code {status}");
            }
            let num_classes = c.u32()? as usize;
            let n_vids = c.u32()? as usize;
            let n_scores = c.u32()? as usize;
            if n_vids.checked_mul(num_classes) != Some(n_scores) {
                bail!(
                    "score reply inconsistent: {n_vids} vids x {num_classes} classes != {n_scores} scores"
                );
            }
            if status != SCORE_OK && n_vids != 0 {
                bail!("score reply carries {n_vids} vids despite error status {status}");
            }
            let vid_bytes = c
                .take(n_vids * 4)
                .context("truncated score reply (vids)")?;
            let vids: Vec<u32> = vid_bytes
                .chunks_exact(4)
                .map(|b| u32::from_le_bytes(b.try_into().unwrap()))
                .collect();
            let score_bytes = c
                .take(n_scores * 4)
                .context("truncated score reply (scores)")?;
            let scores: Vec<f32> = score_bytes
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                .collect();
            c.done()?;
            Ok(Frame::ScoreRep { req_id, status, num_classes, vids, scores })
        }
        other => bail!("unknown frame tag {other}"),
    }
}

/// Write one length-prefixed frame. Oversized payloads are a typed
/// [`FrameTooLarge`] error even in release builds, returned *before any
/// bytes hit the wire*: past `u32::MAX` the length prefix would wrap and
/// desync the stream, turning one bad send into receiver-side garbage
/// instead of a clean failure.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME {
        return Err(anyhow::Error::new(FrameTooLarge {
            len: payload.len(),
            cap: MAX_FRAME,
        }));
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one length-prefixed frame payload. Returns `Ok(None)` on a clean
/// EOF at a frame boundary; EOF mid-frame is an error.
pub fn read_frame(r: &mut impl Read) -> Result<Option<Vec<u8>>> {
    read_frame_poll(r, || false)
}

/// Like [`read_frame`], but tolerant of read timeouts (`WouldBlock` /
/// `TimedOut`): each timeout polls `stop` and either keeps waiting or
/// returns `Ok(None)` as if the stream had closed cleanly. This is how a
/// reader thread on a socket with a short read timeout stays responsive
/// to shutdown without a wedged peer being able to pin it in `read()`
/// forever.
pub fn read_frame_poll(r: &mut impl Read, stop: impl Fn() -> bool) -> Result<Option<Vec<u8>>> {
    let mut len_buf = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        match r.read(&mut len_buf[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(None); // clean EOF between frames
                }
                bail!("EOF inside frame length prefix");
            }
            Ok(n) => got += n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if stop() {
                    return Ok(None);
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        bail!("frame length {len} exceeds cap {MAX_FRAME}");
    }
    let mut payload = vec![0u8; len];
    let mut got = 0;
    while got < len {
        match r.read(&mut payload[got..]) {
            Ok(0) => bail!("EOF inside frame payload"),
            Ok(n) => got += n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if stop() {
                    return Ok(None);
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(Some(payload))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(n: usize, dim: usize) -> PushMsg {
        PushMsg {
            from: 3,
            layer: 1,
            vids: (0..n as u32).map(|v| v * 7 + 1).collect(),
            embeds: PushPayload::F32((0..n * dim).map(|i| (i as f32) * 0.125 - 3.5).collect()),
            dim,
            sent_iter: 41,
            arrival: 0.0,
        }
    }

    fn sample_bf16(n: usize, dim: usize) -> PushMsg {
        PushMsg {
            from: 2,
            layer: 0,
            vids: (0..n as u32).map(|v| v * 3 + 2).collect(),
            embeds: PushPayload::Bf16((0..n * dim).map(|i| (i as u16) ^ 0x3F12).collect()),
            dim,
            sent_iter: 9,
            arrival: 0.0,
        }
    }

    fn roundtrip(msg: &PushMsg) -> PushMsg {
        let payload = encode_push(msg).unwrap();
        match decode_frame(&payload).unwrap() {
            Frame::Push(m) => m,
            other => panic!("expected push, got {other:?}"),
        }
    }

    #[test]
    fn push_roundtrip_empty_payload() {
        let msg = sample(0, 16);
        let back = roundtrip(&msg);
        assert_eq!(back, msg);
        assert!(back.vids.is_empty() && back.embeds.is_empty());
    }

    #[test]
    fn push_roundtrip_max_dim_rows_bit_exact() {
        // wide rows with awkward float values (subnormal, -0.0, inf-adjacent)
        let mut msg = sample(3, 1024);
        if let PushPayload::F32(es) = &mut msg.embeds {
            es[0] = f32::MIN_POSITIVE / 2.0; // subnormal
            es[1] = -0.0;
            es[2] = f32::MAX;
            es[3] = f32::MIN;
        }
        let back = roundtrip(&msg);
        assert_eq!(back, msg);
        let (a, b) = match (&back.embeds, &msg.embeds) {
            (PushPayload::F32(a), PushPayload::F32(b)) => (a, b),
            other => panic!("{other:?}"),
        };
        assert_eq!(a[0].to_bits(), b[0].to_bits());
        assert_eq!(a[1].to_bits(), (-0.0f32).to_bits());
    }

    /// bf16 pushes round-trip bit-exactly and spend half the embed bytes
    /// of the equivalent f32 frame.
    #[test]
    fn bf16_push_roundtrip_bit_exact_and_half_size() {
        let msg = sample_bf16(5, 8);
        let back = roundtrip(&msg);
        assert_eq!(back, msg);
        let f32_frame = encode_push(&sample(5, 8)).unwrap();
        let b16_frame = encode_push(&msg).unwrap();
        assert_eq!(f32_frame.len() - b16_frame.len(), 5 * 8 * 2);
        // truncation of a bf16 frame is an error, never a panic
        for cut in 0..b16_frame.len() - 1 {
            assert!(decode_frame(&b16_frame[..cut]).is_err(), "cut {cut}");
        }
        // an unknown dtype code is rejected (offset: tag 1 + from 4 +
        // layer 4 + iter 8 + dim 4)
        let mut bad = encode_push(&msg).unwrap();
        let off = 1 + 4 + 4 + 8 + 4;
        bad[off..off + 4].copy_from_slice(&7u32.to_le_bytes());
        assert!(decode_frame(&bad).is_err());
    }

    #[test]
    fn truncated_frame_is_an_error_not_a_panic() {
        let payload = encode_push(&sample(8, 4)).unwrap();
        // cut at every prefix length: must error cleanly, never panic
        for cut in 0..payload.len() - 1 {
            assert!(
                decode_frame(&payload[..cut]).is_err(),
                "cut at {cut} decoded"
            );
        }
        assert!(decode_frame(&payload).is_ok());
    }

    #[test]
    fn inconsistent_counts_rejected() {
        let mut payload = encode_push(&sample(4, 2)).unwrap();
        // corrupt n_embeds (offset: tag 1 + from 4 + layer 4 + iter 8 +
        // dim 4 + dtype 4 + n_vids 4)
        let off = 1 + 4 + 4 + 8 + 4 + 4 + 4;
        payload[off..off + 4].copy_from_slice(&100u32.to_le_bytes());
        assert!(decode_frame(&payload).is_err());
    }

    #[test]
    fn trailing_garbage_rejected() {
        let mut payload = encode_push(&sample(2, 2)).unwrap();
        payload.push(0xAB);
        assert!(decode_frame(&payload).is_err());
    }

    #[test]
    fn unknown_tag_rejected() {
        assert!(decode_frame(&[0xFF, 0, 0]).is_err());
        assert!(decode_frame(&[]).is_err());
    }

    #[test]
    fn control_frames_roundtrip() {
        match decode_frame(&encode_hello(9, 4)).unwrap() {
            Frame::Hello { from, window } => assert_eq!((from, window), (9, 4)),
            other => panic!("{other:?}"),
        }
        // a window-0 greeting is a protocol error, not a frame
        assert!(decode_frame(&encode_hello(9, 0)).is_err());
        match decode_frame(&encode_iter_done(2, 77)).unwrap() {
            Frame::IterDone { from, iter } => {
                assert_eq!((from, iter), (2, 77));
            }
            other => panic!("{other:?}"),
        }
        match decode_frame(&encode_iter_done_w(5, 123, 8)).unwrap() {
            Frame::IterDoneW { from, iter, window } => {
                assert_eq!((from, iter, window), (5, 123, 8));
            }
            other => panic!("{other:?}"),
        }
        // a window-0 advertisement is a protocol error, not a frame
        assert!(decode_frame(&encode_iter_done_w(5, 123, 0)).is_err());
        match decode_frame(&encode_ring(&[1, 2, 3])).unwrap() {
            Frame::Ring(b) => assert_eq!(b, vec![1, 2, 3]),
            other => panic!("{other:?}"),
        }
        match decode_frame(&encode_bye(1)).unwrap() {
            Frame::Bye { from } => assert_eq!(from, 1),
            other => panic!("{other:?}"),
        }
        match decode_frame(&encode_heartbeat(2, 0)).unwrap() {
            Frame::Heartbeat { from, iters_done } => {
                assert_eq!((from, iters_done), (2, 0));
            }
            other => panic!("{other:?}"),
        }
        match decode_frame(&encode_heartbeat(1, u64::MAX)).unwrap() {
            Frame::Heartbeat { from, iters_done } => {
                assert_eq!((from, iters_done), (1, u64::MAX));
            }
            other => panic!("{other:?}"),
        }
        match decode_frame(&encode_resume(3, 2, 48, 4)).unwrap() {
            Frame::Resume { from, epoch, iter, window } => {
                assert_eq!((from, epoch, iter, window), (3, 2, 48, 4));
            }
            other => panic!("{other:?}"),
        }
        // a window-0 resume is a protocol error, not a frame
        assert!(decode_frame(&encode_resume(3, 2, 48, 0)).is_err());
    }

    fn sample_prefetch_rep(n: usize, dim: usize, bf16: bool) -> Vec<u8> {
        let vids: Vec<u32> = (0..n as u32).map(|v| v * 5 + 3).collect();
        let rows = if bf16 {
            PushPayload::Bf16((0..n * dim).map(|i| (i as u16) ^ 0x40A1).collect())
        } else {
            PushPayload::F32((0..n * dim).map(|i| (i as f32) * 0.25 - 1.0).collect())
        };
        encode_prefetch_rep(2, dim, &vids, &rows).unwrap()
    }

    fn sample_push_batch() -> Vec<u8> {
        // both entries must carry the batch's sender rank (from = 3)
        let mut bf16 = sample_bf16(4, 3);
        bf16.from = 3;
        encode_push_batch(
            3,
            &[encode_push(&sample(2, 5)).unwrap(), encode_push(&bf16).unwrap()],
        )
        .unwrap()
    }

    fn sample_score_rep(n: usize, classes: usize) -> Vec<u8> {
        let vids: Vec<u32> = (0..n as u32).map(|v| v * 11 + 2).collect();
        let scores: Vec<f32> = (0..n * classes).map(|i| (i as f32) * 0.5 - 2.0).collect();
        encode_score_rep(0xFEED_BEEF, SCORE_OK, classes, &vids, &scores).unwrap()
    }

    /// One encoding of every frame type, named — the robustness corpus.
    fn corpus() -> Vec<(&'static str, Vec<u8>)> {
        vec![
            ("hello", encode_hello(3, 2)),
            ("push_f32", encode_push(&sample(6, 5)).unwrap()),
            ("push_bf16", encode_push(&sample_bf16(4, 3)).unwrap()),
            ("iter_done", encode_iter_done(2, 99)),
            ("iter_done_w", encode_iter_done_w(1, 12, 4)),
            ("ring", encode_ring(&[9, 8, 7, 6])),
            ("bye", encode_bye(0)),
            ("heartbeat", encode_heartbeat(1, 37)),
            ("resume", encode_resume(0, 3, 96, 4)),
            ("prefetch_req", encode_prefetch_req(1, &[4, 9, 16, 25]).unwrap()),
            ("prefetch_rep_f32", sample_prefetch_rep(5, 4, false)),
            ("prefetch_rep_bf16", sample_prefetch_rep(3, 6, true)),
            ("shm_attach", encode_shm_attach(1, 1 << 20)),
            ("topo", encode_topo(2, 0x9E3779B97F4A7C15, 1)),
            ("push_batch", sample_push_batch()),
            ("score_req", encode_score_req(0xABCD_0123, &[7, 12, 99]).unwrap()),
            ("score_rep", sample_score_rep(3, 4)),
            (
                "score_rep_overloaded",
                encode_score_rep(9, SCORE_OVERLOADED, 0, &[], &[]).unwrap(),
            ),
        ]
    }

    /// The new two-level-fabric frames round-trip bit-exactly, and a
    /// batched push decodes to the same `PushMsg`s the unbatched frames
    /// carry, in order.
    #[test]
    fn shm_topo_and_push_batch_roundtrip() {
        match decode_frame(&encode_shm_attach(5, 4096)).unwrap() {
            Frame::ShmAttach { from, capacity } => {
                assert_eq!((from, capacity), (5, 4096));
            }
            other => panic!("{other:?}"),
        }
        // a zero-capacity attach is a protocol error, not a frame
        assert!(decode_frame(&encode_shm_attach(5, 0)).is_err());
        match decode_frame(&encode_topo(7, u64::MAX, 6)).unwrap() {
            Frame::Topo { from, host_fnv, leader } => {
                assert_eq!((from, host_fnv, leader), (7, u64::MAX, 6));
            }
            other => panic!("{other:?}"),
        }
        let mut bf16 = sample_bf16(4, 3);
        bf16.from = 3;
        let (a, b) = (sample(2, 5), bf16);
        let frame =
            encode_push_batch(3, &[encode_push(&a).unwrap(), encode_push(&b).unwrap()]).unwrap();
        match decode_frame(&frame).unwrap() {
            Frame::PushBatch { from, pushes } => {
                assert_eq!(from, 3);
                assert_eq!(pushes.len(), 2);
                assert_eq!(pushes[0], a);
                assert_eq!(pushes[1], b);
            }
            other => panic!("{other:?}"),
        }
        // an empty batch is a valid (if pointless) frame
        match decode_frame(&encode_push_batch(0, &[]).unwrap()).unwrap() {
            Frame::PushBatch { from, pushes } => {
                assert_eq!(from, 0);
                assert!(pushes.is_empty());
            }
            other => panic!("{other:?}"),
        }
    }

    /// Batch-specific protocol violations are typed errors: a non-PUSH
    /// inner body (including a nested batch), and a sender-rank mismatch
    /// between the batch header and an inner push.
    #[test]
    fn push_batch_rejects_foreign_and_nested_entries() {
        // inner body that is a valid frame but not a PUSH
        let bad = encode_push_batch_raw(3, &[encode_bye(3)]);
        assert!(decode_frame(&bad).is_err());
        // nested batch (recursion guard)
        let inner = sample_push_batch();
        let bad = encode_push_batch_raw(3, &[inner]);
        assert!(decode_frame(&bad).is_err());
        // from mismatch: batch says 3, inner push says 2
        let bad = encode_push_batch_raw(3, &[encode_push(&sample_bf16(2, 2)).unwrap()]);
        assert!(decode_frame(&bad).is_err());
        // an impossible count is rejected up front
        let mut hdr = vec![TAG_PUSH_BATCH];
        hdr.extend_from_slice(&3u32.to_le_bytes());
        hdr.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_frame(&hdr).is_err());
    }

    /// Like `encode_push_batch` but without the PUSH-only debug assert —
    /// builds deliberately malformed batches for the rejection tests.
    fn encode_push_batch_raw(from: u32, bodies: &[Vec<u8>]) -> Vec<u8> {
        let mut out = vec![TAG_PUSH_BATCH];
        put_u32(&mut out, from);
        put_u32(&mut out, bodies.len() as u32);
        for b in bodies {
            put_u32(&mut out, b.len() as u32);
            out.extend_from_slice(b);
        }
        out
    }

    /// Satellite regression: an oversized payload is a typed
    /// [`FrameTooLarge`] from `write_frame`, and *zero* bytes hit the
    /// wire — the stream stays framable for the next send.
    #[test]
    fn oversized_payload_is_typed_error_before_any_bytes_hit_the_wire() {
        let payload = vec![0u8; MAX_FRAME + 1];
        let mut sink: Vec<u8> = Vec::new();
        let err = write_frame(&mut sink, &payload).unwrap_err();
        let typed = err
            .downcast_ref::<FrameTooLarge>()
            .expect("FrameTooLarge should survive as a typed error");
        assert_eq!(typed.len, MAX_FRAME + 1);
        assert_eq!(typed.cap, MAX_FRAME);
        assert!(sink.is_empty(), "bytes were written before the size check");
        // the same stream accepts a normal frame right after the rejection
        let ok = vec![0u8; 8];
        write_frame(&mut sink, &ok).unwrap();
        assert_eq!(sink.len(), 4 + 8);
    }

    #[test]
    fn prefetch_frames_roundtrip_bit_exact() {
        match decode_frame(&encode_prefetch_req(7, &[10, 20, 30]).unwrap()).unwrap() {
            Frame::PrefetchReq { from, vids } => {
                assert_eq!(from, 7);
                assert_eq!(vids, vec![10, 20, 30]);
            }
            other => panic!("{other:?}"),
        }
        // an empty pull is still a valid frame (an owner with no misses)
        match decode_frame(&encode_prefetch_req(0, &[]).unwrap()).unwrap() {
            Frame::PrefetchReq { from, vids } => {
                assert_eq!(from, 0);
                assert!(vids.is_empty());
            }
            other => panic!("{other:?}"),
        }
        let rows = PushPayload::F32(vec![1.5, -0.0, f32::MIN_POSITIVE / 2.0, 4.0]);
        match decode_frame(&encode_prefetch_rep(3, 2, &[8, 9], &rows).unwrap()).unwrap() {
            Frame::PrefetchRep { from, dim, vids, rows: back } => {
                assert_eq!((from, dim), (3, 2));
                assert_eq!(vids, vec![8, 9]);
                match back {
                    PushPayload::F32(es) => {
                        assert_eq!(es.len(), 4);
                        assert_eq!(es[1].to_bits(), (-0.0f32).to_bits());
                        assert_eq!(es[2].to_bits(), (f32::MIN_POSITIVE / 2.0).to_bits());
                    }
                    other => panic!("{other:?}"),
                }
            }
            other => panic!("{other:?}"),
        }
        // bf16 rows round-trip bit-exactly at half the row bytes
        let bits = PushPayload::Bf16(vec![0x3FC0, 0x8000, 0x7F80]);
        let frame = encode_prefetch_rep(1, 3, &[5], &bits).unwrap();
        let f32_frame =
            encode_prefetch_rep(1, 3, &[5], &PushPayload::F32(vec![0.0; 3])).unwrap();
        assert_eq!(f32_frame.len() - frame.len(), 3 * 2);
        match decode_frame(&frame).unwrap() {
            Frame::PrefetchRep { rows: PushPayload::Bf16(es), .. } => {
                assert_eq!(es, vec![0x3FC0, 0x8000, 0x7F80]);
            }
            other => panic!("{other:?}"),
        }
    }

    /// Serving frames round-trip bit-exactly: a request echoes its vids,
    /// a reply echoes `req_id` and carries raw-f32-bit logit rows, and an
    /// overload rejection is an empty body with the typed status code.
    #[test]
    fn score_frames_roundtrip_bit_exact() {
        match decode_frame(&encode_score_req(u64::MAX, &[3, 1, 4, 1, 5]).unwrap()).unwrap() {
            Frame::ScoreReq { req_id, vids } => {
                assert_eq!(req_id, u64::MAX);
                assert_eq!(vids, vec![3, 1, 4, 1, 5]);
            }
            other => panic!("{other:?}"),
        }
        // an empty request is still a frame (the server replies bad-request)
        match decode_frame(&encode_score_req(0, &[]).unwrap()).unwrap() {
            Frame::ScoreReq { req_id, vids } => {
                assert_eq!(req_id, 0);
                assert!(vids.is_empty());
            }
            other => panic!("{other:?}"),
        }
        let scores = vec![1.5f32, -0.0, f32::MIN_POSITIVE / 2.0, 42.0];
        let frame = encode_score_rep(77, SCORE_OK, 2, &[8, 9], &scores).unwrap();
        match decode_frame(&frame).unwrap() {
            Frame::ScoreRep { req_id, status, num_classes, vids, scores: back } => {
                assert_eq!((req_id, status, num_classes), (77, SCORE_OK, 2));
                assert_eq!(vids, vec![8, 9]);
                assert_eq!(back.len(), 4);
                assert_eq!(back[1].to_bits(), (-0.0f32).to_bits());
                assert_eq!(back[2].to_bits(), (f32::MIN_POSITIVE / 2.0).to_bits());
            }
            other => panic!("{other:?}"),
        }
        for code in [SCORE_OVERLOADED, SCORE_BAD_REQUEST] {
            match decode_frame(&encode_score_rep(5, code, 0, &[], &[]).unwrap()).unwrap() {
                Frame::ScoreRep { req_id, status, vids, scores, .. } => {
                    assert_eq!((req_id, status), (5, code));
                    assert!(vids.is_empty() && scores.is_empty());
                }
                other => panic!("{other:?}"),
            }
        }
    }

    /// Score-reply protocol violations are typed errors: an unknown
    /// status code, inconsistent vid/class/score counts, and a reply that
    /// carries scores despite an error status.
    #[test]
    fn score_rep_rejects_bad_status_and_inconsistent_counts() {
        let good = sample_score_rep(3, 4);
        // unknown status code (offset: tag 1 + req_id 8)
        let mut bad = good.clone();
        bad[9..13].copy_from_slice(&7u32.to_le_bytes());
        assert!(decode_frame(&bad).is_err());
        // corrupt n_scores (offset: tag 1 + req_id 8 + status 4 +
        // classes 4 + n_vids 4)
        let mut bad = good.clone();
        let off = 1 + 8 + 4 + 4 + 4;
        bad[off..off + 4].copy_from_slice(&100u32.to_le_bytes());
        assert!(decode_frame(&bad).is_err());
        // error status with a non-empty body
        let mut bad = good;
        bad[9..13].copy_from_slice(&SCORE_OVERLOADED.to_le_bytes());
        assert!(decode_frame(&bad).is_err());
    }

    /// Satellite regression: a count/dim field past `u32::MAX` is a typed
    /// [`FieldTooLarge`] from the encoder — not a silent `as u32`
    /// truncation that frames a self-inconsistent payload.
    #[test]
    fn oversized_counts_are_typed_errors_not_silent_truncation() {
        // an empty push with an absurd dim: the old cast would have
        // wrapped it to 0 and framed a "valid" frame
        let mut msg = sample(0, 4);
        msg.dim = u32::MAX as usize + 1;
        let err = encode_push(&msg).unwrap_err();
        let typed = err
            .downcast_ref::<FieldTooLarge>()
            .expect("FieldTooLarge should survive as a typed error");
        assert_eq!(typed.field, "push dim");
        assert_eq!(typed.value, u32::MAX as usize + 1);

        let rows = PushPayload::F32(Vec::new());
        let err = encode_prefetch_rep(1, u32::MAX as usize + 1, &[], &rows).unwrap_err();
        assert!(err.downcast_ref::<FieldTooLarge>().is_some());

        let err = encode_score_rep(1, SCORE_OK, u32::MAX as usize + 1, &[], &[]).unwrap_err();
        assert!(err.downcast_ref::<FieldTooLarge>().is_some());

        // in-range values still encode
        msg.dim = 4;
        assert!(encode_push(&msg).is_ok());
    }

    #[test]
    fn prefetch_rep_inconsistent_counts_and_dtype_rejected() {
        let mut bad = sample_prefetch_rep(4, 2, false);
        // corrupt n_elems (offset: tag 1 + from 4 + dim 4 + dtype 4 +
        // n_vids 4)
        let off = 1 + 4 + 4 + 4 + 4;
        bad[off..off + 4].copy_from_slice(&100u32.to_le_bytes());
        assert!(decode_frame(&bad).is_err());
        for msg in [sample_prefetch_rep(4, 2, false), sample_prefetch_rep(4, 2, true)] {
            let mut bad = msg;
            let off = 1 + 4 + 4; // dtype code
            for code in [2u32, 9, u32::MAX] {
                bad[off..off + 4].copy_from_slice(&code.to_le_bytes());
                assert!(decode_frame(&bad).is_err(), "dtype code {code} accepted");
            }
        }
    }

    /// Truncation at every byte boundary of every frame type is a typed
    /// decode error — never a panic, never a silent partial decode. (The
    /// one principled exception: a RING body is opaque bytes, so any
    /// prefix that keeps the tag is itself a valid, shorter RING frame.)
    #[test]
    fn corpus_truncation_at_every_boundary_is_typed_error() {
        for (name, payload) in corpus() {
            for cut in 0..payload.len() {
                let res = decode_frame(&payload[..cut]);
                if name == "ring" && cut >= 1 {
                    assert!(res.is_ok(), "{name} cut {cut} should stay a ring frame");
                } else {
                    assert!(res.is_err(), "{name} cut at {cut} decoded");
                }
            }
            assert!(decode_frame(&payload).is_ok(), "{name} full frame");
        }
    }

    /// Seeded mutation corpus: random byte flips, overwrites, truncations
    /// and garbage suffixes over every frame type. `decode_frame` must
    /// always *return* (Ok for a mutation that happens to stay
    /// structurally valid, a typed Err otherwise) — any panic fails the
    /// test harness.
    #[test]
    fn corpus_seeded_mutations_never_panic() {
        let mut rng = crate::util::rng::Pcg64::seeded(0xA11CE);
        for (name, payload) in corpus() {
            for trial in 0..500u32 {
                let mut mutated = payload.clone();
                match rng.gen_range(4) {
                    0 => {
                        let i = rng.gen_range(mutated.len());
                        mutated[i] ^= 1u8 << rng.gen_range(8);
                    }
                    1 => {
                        let i = rng.gen_range(mutated.len());
                        mutated[i] = rng.next_u32() as u8;
                    }
                    2 => {
                        mutated.truncate(rng.gen_range(mutated.len() + 1));
                    }
                    _ => {
                        for _ in 0..=rng.gen_range(8) {
                            mutated.push(rng.next_u32() as u8);
                        }
                    }
                }
                // must return, never panic; exercise Debug on success too
                if let Ok(frame) = decode_frame(&mutated) {
                    let _ = format!("{frame:?}");
                }
                let _ = (name, trial);
            }
        }
    }

    /// Dtype-code corruption (both stored dtypes, several bogus codes)
    /// and an oversized length prefix are rejected up front — the length
    /// guard fires before any allocation can balloon.
    #[test]
    fn corrupted_dtype_and_oversized_length_prefix_rejected() {
        let off = 1 + 4 + 4 + 8 + 4; // tag + from + layer + iter + dim
        for msg in [sample(4, 2), sample_bf16(4, 2)] {
            let mut bad = encode_push(&msg).unwrap();
            for code in [2u32, 7, u32::MAX] {
                bad[off..off + 4].copy_from_slice(&code.to_le_bytes());
                assert!(decode_frame(&bad).is_err(), "dtype code {code} accepted");
            }
        }
        let mut stream = Vec::new();
        stream.extend_from_slice(&(MAX_FRAME as u32 + 1).to_le_bytes());
        stream.extend_from_slice(&[0u8; 64]);
        let mut r = &stream[..];
        let err = read_frame(&mut r).unwrap_err();
        assert!(format!("{err:#}").contains("exceeds cap"), "{err:#}");
    }

    #[test]
    fn stream_framing_roundtrip_and_clean_eof() {
        let mut buf: Vec<u8> = Vec::new();
        write_frame(&mut buf, &encode_hello(1, 1)).unwrap();
        write_frame(&mut buf, &encode_push(&sample(5, 3)).unwrap()).unwrap();
        let mut r = &buf[..];
        assert!(matches!(
            decode_frame(&read_frame(&mut r).unwrap().unwrap()).unwrap(),
            Frame::Hello { from: 1, window: 1 }
        ));
        assert!(matches!(
            decode_frame(&read_frame(&mut r).unwrap().unwrap()).unwrap(),
            Frame::Push(_)
        ));
        assert!(read_frame(&mut r).unwrap().is_none()); // clean EOF
        // EOF mid-frame errors
        let mut trunc = &buf[..buf.len() - 2];
        read_frame(&mut trunc).unwrap();
        assert!(read_frame(&mut trunc).is_err());
    }
}
