//! Run configuration: dataset/model presets, HEC parameters, network model,
//! trainer mode. Loadable from JSON (`--config file.json`) with CLI
//! overrides; every bench records its config in its report header.

use anyhow::{bail, Result};

use crate::util::json::{self, Value};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    Sage,
    Gat,
}

impl ModelKind {
    pub fn parse(s: &str) -> Result<ModelKind> {
        match s {
            "sage" | "graphsage" => Ok(ModelKind::Sage),
            "gat" => Ok(ModelKind::Gat),
            other => bail!("unknown model '{other}' (sage|gat)"),
        }
    }
    pub fn as_str(self) -> &'static str {
        match self {
            ModelKind::Sage => "sage",
            ModelKind::Gat => "gat",
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrainMode {
    /// DistGNN-MB: HEC + asynchronous embedding push (Algorithm 2).
    Aep,
    /// DistDGL baseline: blocking distributed sampling + feature fetch.
    DistDgl,
    /// No communication at all (halo edges always dropped) — lower bound
    /// used by the HEC ablation.
    NoComm,
}

impl TrainMode {
    pub fn parse(s: &str) -> Result<TrainMode> {
        match s {
            "aep" | "distgnn-mb" => Ok(TrainMode::Aep),
            "distdgl" => Ok(TrainMode::DistDgl),
            "nocomm" => Ok(TrainMode::NoComm),
            other => bail!("unknown mode '{other}' (aep|distdgl|nocomm)"),
        }
    }
    pub fn as_str(self) -> &'static str {
        match self {
            TrainMode::Aep => "aep",
            TrainMode::DistDgl => "distdgl",
            TrainMode::NoComm => "nocomm",
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SamplerKind {
    /// Thread-parallel synchronous sampler (the paper's SYNC_MBC).
    Parallel,
    /// Serial sampler.
    Serial,
    /// DGL-dataloader emulation: serial sampling + worker-IPC
    /// serialize/deserialize round-trip per minibatch (Fig. 2 baseline).
    SerialIpc,
}

impl SamplerKind {
    pub fn parse(s: &str) -> Result<SamplerKind> {
        match s {
            "parallel" | "sync" => Ok(SamplerKind::Parallel),
            "serial" => Ok(SamplerKind::Serial),
            "serial-ipc" | "ipc" => Ok(SamplerKind::SerialIpc),
            other => bail!("unknown sampler '{other}' (parallel|serial|serial-ipc)"),
        }
    }
    pub fn as_str(self) -> &'static str {
        match self {
            SamplerKind::Parallel => "parallel",
            SamplerKind::Serial => "serial",
            SamplerKind::SerialIpc => "serial-ipc",
        }
    }
}

/// Which [`crate::comm::Fabric`] implementation moves bytes between ranks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FabricKind {
    /// In-memory queues with netsim-modeled time; every rank lives in this
    /// process (the default, and the deterministic test path).
    Sim,
    /// Real TCP/Unix-domain sockets; one OS process per rank, wall-clock
    /// comm accounting. Requires `rank` and `peers`.
    Socket,
    /// Two-level hierarchical transport: the socket mesh carries only
    /// inter-host traffic while ranks co-located by the `--hosts`
    /// topology exchange frames over shared-memory rings
    /// ([`crate::comm::shm`]). Requires `rank`, `peers`, and `hosts`.
    Hier,
}

impl FabricKind {
    pub fn parse(s: &str) -> Result<FabricKind> {
        match s {
            "sim" | "netsim" => Ok(FabricKind::Sim),
            "socket" => Ok(FabricKind::Socket),
            "hier" | "hierarchical" => Ok(FabricKind::Hier),
            other => bail!("unknown fabric '{other}' (sim|socket|hier)"),
        }
    }
    pub fn as_str(self) -> &'static str {
        match self {
            FabricKind::Sim => "sim",
            FabricKind::Socket => "socket",
            FabricKind::Hier => "hier",
        }
    }
}

/// Storage precision of feature/embedding blocks on the minibatch path:
/// HEC cache lines, packed minibatch features, and AEP push payloads.
///
/// `bf16` halves those bytes (the paper's LIBXSMM-TPP-style bf16 storage
/// with f32 accumulation); weights, gradients, activations and the
/// gradient all-reduce always stay f32, so losses track the f32 run
/// within the tolerance documented in the README ("Numerics and
/// precision") and asserted by `tests/bf16_equivalence.rs`. The DistDGL
/// baseline mode always packs f32 (its blocking fetch path bypasses the
/// HEC entirely).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DtypeKind {
    F32,
    Bf16,
}

impl DtypeKind {
    pub fn parse(s: &str) -> Result<DtypeKind> {
        match s {
            "f32" | "float32" => Ok(DtypeKind::F32),
            "bf16" | "bfloat16" => Ok(DtypeKind::Bf16),
            other => bail!("unknown dtype '{other}' (f32|bf16)"),
        }
    }
    pub fn as_str(self) -> &'static str {
        match self {
            DtypeKind::F32 => "f32",
            DtypeKind::Bf16 => "bf16",
        }
    }
    /// The matching host-tensor element type.
    pub fn tensor_dtype(self) -> crate::runtime::tensor::DType {
        match self {
            DtypeKind::F32 => crate::runtime::tensor::DType::F32,
            DtypeKind::Bf16 => crate::runtime::tensor::DType::Bf16,
        }
    }
    /// Bytes per stored element (4 or 2).
    pub fn elem_bytes(self) -> usize {
        match self {
            DtypeKind::F32 => 4,
            DtypeKind::Bf16 => 2,
        }
    }
}

/// HEC replacement policy (`--hec-policy`).
///
/// `ocf` is the paper's oldest-cache-line-first contract and the default:
/// eviction order is a pure function of store order, so every transport /
/// depth / dtype pairing sees byte-identical caches. `reuse` layers two
/// protections on top of the same FIFO: lines referenced by any in-flight
/// pipeline-ring entry are pinned (never evicted while pinned), and lines
/// with accumulated search hits trade half their reuse credit for another
/// lap instead of dying on their first turn (CLOCK-style second chance),
/// so hot halo vertices survive cache churn.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HecPolicyKind {
    Ocf,
    Reuse,
}

impl HecPolicyKind {
    pub fn parse(s: &str) -> Result<HecPolicyKind> {
        match s {
            "ocf" | "fifo" => Ok(HecPolicyKind::Ocf),
            "reuse" => Ok(HecPolicyKind::Reuse),
            other => bail!("unknown hec policy '{other}' (ocf|reuse)"),
        }
    }
    pub fn as_str(self) -> &'static str {
        match self {
            HecPolicyKind::Ocf => "ocf",
            HecPolicyKind::Reuse => "reuse",
        }
    }
}

/// HEC parameters (paper §3.2 / §4.4). Defaults are the paper's settings
/// scaled to the mini datasets (~1/1000 vertices): cs 1M -> 64Ki entries
/// per layer, nc 2000 -> 256.
#[derive(Clone, Copy, Debug)]
pub struct HecConfig {
    /// Cache size (entries per GNN layer).
    pub cs: usize,
    /// Cache-line communication threshold: max solid vertices pushed per
    /// remote rank per iteration (degree-biased subsample above this).
    pub nc: usize,
    /// Cache-line life span in iterations; older lines are purged.
    pub ls: u32,
    /// Communication delay d (iterations) for the asynchronous push.
    /// The phased driver stages every rank's receive before any rank's
    /// push within an iteration, so same-iteration delivery cannot exist:
    /// d = 0 is interpreted as d = 1.
    pub d: usize,
    /// Replacement policy: `ocf` (paper default, bit-identity contract)
    /// or `reuse` (pin in-flight ring lines, second-chance hot lines).
    /// Env `DISTGNN_HEC_POLICY=ocf|reuse` overrides at runtime.
    pub policy: HecPolicyKind,
    /// Lookahead prefetch: when the depth-`p` ring stages a future
    /// minibatch, pull its level-0 HEC misses from their owner ranks
    /// ahead of time. Accounting-only with respect to the model: staged
    /// rows never alter what the packer reads, so losses stay
    /// bit-identical on/off. Env `DISTGNN_HEC_PREFETCH=0|1` overrides.
    pub prefetch: bool,
}

impl Default for HecConfig {
    fn default() -> Self {
        HecConfig {
            cs: 65_536,
            nc: 256,
            ls: 2,
            d: 1,
            policy: HecPolicyKind::Ocf,
            prefetch: false,
        }
    }
}

/// Network cost model (DESIGN.md §5): Mellanox HDR-class fabric.
#[derive(Clone, Copy, Debug)]
pub struct NetConfig {
    /// Point-to-point latency per message (seconds) — MPI over HDR.
    pub latency: f64,
    /// Effective per-socket bandwidth (bytes/second).
    pub bandwidth: f64,
    /// Request/response latency of DistDGL's TCP + Python KVStore/RPC
    /// stack (seconds). Orders of magnitude above raw MPI pt2pt; this is
    /// a large part of why blocking per-minibatch fetches hurt (§4.6).
    pub rpc_latency: f64,
    /// Effective KVStore serialization throughput (bytes/second): the
    /// pickle/tensor-slice/copy path every DistDGL fetch pays on top of
    /// the wire (client+server CPU), cf. the DistDGL paper's RPC-bound
    /// profile. AEP pushes bypass this (raw MPI buffers).
    pub kvstore_bandwidth: f64,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            latency: 2e-6,
            bandwidth: 21e9,
            rpc_latency: 300e-6,
            kvstore_bandwidth: 2e9,
        }
    }
}

/// Full training-run configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Dataset + shape preset: tiny | products-mini | papers100m-mini.
    pub preset: String,
    pub model: ModelKind,
    pub ranks: usize,
    pub epochs: usize,
    pub lr: f32,
    pub seed: u64,
    pub hec: HecConfig,
    pub net: NetConfig,
    /// Partitioner: metis-like | ldg | random.
    pub partitioner: String,
    pub mode: TrainMode,
    pub sampler: SamplerKind,
    pub artifacts_dir: String,
    pub data_cache: String,
    /// Cap on minibatches per rank per epoch (bench mode); None = all.
    pub max_minibatches: Option<usize>,
    /// Evaluate test accuracy every N epochs (0 = never).
    pub eval_every: usize,
    /// Optimizer: adam | sgd.
    pub optimizer: String,
    /// Overlapped iteration pipeline: sample upcoming iterations on a
    /// worker thread while the current one runs fwd/bwd. Moves *when*
    /// work runs, never *what* runs — losses are bit-identical either
    /// way. Env `DISTGNN_PIPELINE=0|1` overrides this at runtime.
    pub pipeline: bool,
    /// Pipeline depth `p`: how many sampled minibatches may be in flight
    /// per rank (1 = the classic double buffer — prefetch exactly the
    /// next iteration). Deeper rings let a long sample hide behind
    /// several exec windows; losses stay bit-identical at every depth
    /// because sampling streams are keyed by (seed, iteration, rank),
    /// never by when the sample runs. Env `DISTGNN_PIPELINE_DEPTH=p`
    /// overrides this at runtime. Only meaningful with `pipeline` on.
    pub pipeline_depth: usize,
    /// Storage precision of feature/embedding blocks (HEC lines, packed
    /// minibatch features, AEP push payloads): f32 or bf16. Env
    /// `DISTGNN_DTYPE=f32|bf16` overrides this at runtime.
    pub dtype: DtypeKind,
    /// Transport backend: sim (all ranks in-process, modeled time) or
    /// socket (one process per rank over real sockets).
    pub fabric: FabricKind,
    /// This process's global rank (socket fabric only).
    pub rank: usize,
    /// Rendezvous addresses, one per rank, index = rank (socket fabric
    /// only). Entries containing `/` are Unix socket paths, anything else
    /// is a `host:port` TCP endpoint.
    pub peers: Vec<String>,
    /// Rank→host topology spec, host-major: `"a:2,b:2"` (or bare counts
    /// `"2,2"`) places ranks 0-1 on host 0 and ranks 2-3 on host 1. Each
    /// comma-separated entry is one host; names are documentation only.
    /// Required by `--fabric hier`; under `--fabric sim` it refines the
    /// wire-byte classification without changing anything else. Empty =
    /// every rank its own host (the flat baseline).
    pub hosts: String,
    /// Batch `p` iterations of AEP pushes into one frame per peer before
    /// watermarking (1 = the classic push-then-watermark every
    /// iteration). Amortizes per-frame wire latency; delivery is
    /// unchanged because receivers drain by watermark, never by arrival
    /// time. Must satisfy `push_batch <= min(hec_d, pipeline_depth)` —
    /// receivers block for watermark `k - d` while a batching sender's
    /// watermark lags by up to `push_batch - 1`, and every batched push
    /// must fit the advertised pipeline window.
    pub push_batch: usize,
    /// Deterministic fault-injection plan, e.g.
    /// `kill:rank=1,iter=7;drop_conn:rank=2,iter=3` (empty = off;
    /// `DISTGNN_FAULT_PLAN` overrides). See [`crate::comm::faults`].
    pub fault_plan: String,
    /// Save a distributed checkpoint every N epochs (0 = never). Requires
    /// `ckpt_path`.
    pub ckpt_every: usize,
    /// Checkpoint file path for periodic saves (`--ckpt`) and
    /// supervised-restart resume.
    pub ckpt_path: String,
    /// Out-of-core data path: directory of a shard set (`shards.json` +
    /// `shard-r<rank>.dshd`, written by `distgnn-mb shard`). When set the
    /// driver skips dataset generation/partitioning entirely and reads
    /// partitions out of the shard files; `preset` is taken from the
    /// manifest. Empty = classic in-RAM path. Env `DISTGNN_DATA_SHARDS`
    /// overrides at runtime.
    pub data_shards: String,
    /// Read shard sections through mmap views (true, the out-of-core
    /// mode) or copy them into heap vectors at load (false — the
    /// bit-identity comparator used by tests/benches). Either way the
    /// packer reads the same bytes. Env `DISTGNN_SHARDS_MMAP=0|1`
    /// overrides at runtime.
    pub data_shards_mmap: bool,
    /// Serving: deadline-batching window in milliseconds. After the first
    /// request of a batch arrives, `distgnn serve` coalesces further
    /// arrivals for up to this long (or until the packed batch is full)
    /// before running one forward pass. 0 = no coalescing, every request
    /// runs alone. Env `DISTGNN_SERVE_DEADLINE_MS` overrides at runtime.
    pub serve_deadline_ms: u64,
    /// Serving: admission-control bound — the maximum number of accepted
    /// requests queued ahead of the scoring loop. Arrivals beyond it are
    /// rejected immediately with a typed overload reply
    /// ([`crate::comm::wire::SCORE_OVERLOADED`]) rather than queued into
    /// unbounded latency. Env `DISTGNN_SERVE_QUEUE` overrides at runtime.
    pub serve_queue: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            preset: "tiny".into(),
            model: ModelKind::Sage,
            ranks: 2,
            epochs: 2,
            lr: 3e-3,
            seed: 42,
            hec: HecConfig::default(),
            net: NetConfig::default(),
            partitioner: "metis-like".into(),
            mode: TrainMode::Aep,
            sampler: SamplerKind::Parallel,
            artifacts_dir: "artifacts".into(),
            data_cache: "data-cache".into(),
            max_minibatches: None,
            eval_every: 0,
            optimizer: "adam".into(),
            pipeline: true,
            pipeline_depth: 1,
            dtype: DtypeKind::F32,
            fabric: FabricKind::Sim,
            rank: 0,
            peers: Vec::new(),
            hosts: String::new(),
            push_batch: 1,
            fault_plan: String::new(),
            ckpt_every: 0,
            ckpt_path: String::new(),
            data_shards: String::new(),
            data_shards_mmap: true,
            serve_deadline_ms: 2,
            serve_queue: 64,
        }
    }
}

impl TrainConfig {
    /// Merge fields from a JSON object (unknown keys rejected).
    pub fn apply_json(&mut self, v: &Value) -> Result<()> {
        let obj = v
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("config root must be an object"))?;
        for (k, val) in obj {
            match k.as_str() {
                "preset" => self.preset = val.as_str().unwrap_or(&self.preset).to_string(),
                "model" => self.model = ModelKind::parse(val.as_str().unwrap_or(""))?,
                "ranks" => self.ranks = val.as_usize().unwrap_or(self.ranks),
                "epochs" => self.epochs = val.as_usize().unwrap_or(self.epochs),
                "lr" => self.lr = val.as_f64().unwrap_or(self.lr as f64) as f32,
                "seed" => self.seed = val.as_i64().unwrap_or(self.seed as i64) as u64,
                "hec_cs" => self.hec.cs = val.as_usize().unwrap_or(self.hec.cs),
                "hec_nc" => self.hec.nc = val.as_usize().unwrap_or(self.hec.nc),
                "hec_ls" => self.hec.ls = val.as_usize().unwrap_or(self.hec.ls as usize) as u32,
                "hec_d" => self.hec.d = val.as_usize().unwrap_or(self.hec.d),
                "hec_policy" => {
                    self.hec.policy = HecPolicyKind::parse(val.as_str().unwrap_or(""))?
                }
                "hec_prefetch" => self.hec.prefetch = val.as_bool().unwrap_or(self.hec.prefetch),
                "net_latency" => self.net.latency = val.as_f64().unwrap_or(self.net.latency),
                "net_rpc_latency" => {
                    self.net.rpc_latency = val.as_f64().unwrap_or(self.net.rpc_latency)
                }
                "net_kvstore_bandwidth" => {
                    self.net.kvstore_bandwidth =
                        val.as_f64().unwrap_or(self.net.kvstore_bandwidth)
                }
                "net_bandwidth" => self.net.bandwidth = val.as_f64().unwrap_or(self.net.bandwidth),
                "partitioner" => {
                    self.partitioner = val.as_str().unwrap_or(&self.partitioner).to_string()
                }
                "mode" => self.mode = TrainMode::parse(val.as_str().unwrap_or(""))?,
                "sampler" => self.sampler = SamplerKind::parse(val.as_str().unwrap_or(""))?,
                "artifacts_dir" => {
                    self.artifacts_dir = val.as_str().unwrap_or(&self.artifacts_dir).to_string()
                }
                "data_cache" => {
                    self.data_cache = val.as_str().unwrap_or(&self.data_cache).to_string()
                }
                "max_minibatches" => self.max_minibatches = val.as_usize(),
                "eval_every" => self.eval_every = val.as_usize().unwrap_or(self.eval_every),
                "optimizer" => {
                    self.optimizer = val.as_str().unwrap_or(&self.optimizer).to_string()
                }
                "pipeline" => self.pipeline = val.as_bool().unwrap_or(self.pipeline),
                "pipeline_depth" => {
                    self.pipeline_depth = val.as_usize().unwrap_or(self.pipeline_depth)
                }
                "dtype" => self.dtype = DtypeKind::parse(val.as_str().unwrap_or(""))?,
                "fabric" => self.fabric = FabricKind::parse(val.as_str().unwrap_or(""))?,
                "rank" => self.rank = val.as_usize().unwrap_or(self.rank),
                "peers" => {
                    self.peers = match val {
                        Value::Arr(a) => a
                            .iter()
                            .filter_map(|v| v.as_str().map(|s| s.to_string()))
                            .collect(),
                        Value::Str(s) => {
                            s.split(',').map(|p| p.trim().to_string()).collect()
                        }
                        _ => bail!("peers must be an array or comma-separated string"),
                    }
                }
                "hosts" => self.hosts = val.as_str().unwrap_or(&self.hosts).to_string(),
                "push_batch" => self.push_batch = val.as_usize().unwrap_or(self.push_batch),
                "fault_plan" => {
                    self.fault_plan = val.as_str().unwrap_or(&self.fault_plan).to_string()
                }
                "ckpt_every" => self.ckpt_every = val.as_usize().unwrap_or(self.ckpt_every),
                "ckpt_path" => {
                    self.ckpt_path = val.as_str().unwrap_or(&self.ckpt_path).to_string()
                }
                "data_shards" => {
                    self.data_shards = val.as_str().unwrap_or(&self.data_shards).to_string()
                }
                "data_shards_mmap" => {
                    self.data_shards_mmap = val.as_bool().unwrap_or(self.data_shards_mmap)
                }
                "serve_deadline_ms" => {
                    self.serve_deadline_ms =
                        val.as_usize().unwrap_or(self.serve_deadline_ms as usize) as u64
                }
                "serve_queue" => self.serve_queue = val.as_usize().unwrap_or(self.serve_queue),
                other => bail!("unknown config key '{other}'"),
            }
        }
        self.validate()
    }

    pub fn load_file(path: &str) -> Result<TrainConfig> {
        let text = std::fs::read_to_string(path)?;
        let v = json::parse(&text)?;
        let mut cfg = TrainConfig::default();
        cfg.apply_json(&v)?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.ranks == 0 {
            bail!("ranks must be >= 1");
        }
        if self.hec.cs == 0 || self.hec.nc == 0 {
            bail!("hec cs/nc must be positive");
        }
        if !matches!(self.partitioner.as_str(), "metis-like" | "ldg" | "random") {
            bail!("unknown partitioner '{}'", self.partitioner);
        }
        if !matches!(self.optimizer.as_str(), "adam" | "sgd") {
            bail!("unknown optimizer '{}'", self.optimizer);
        }
        if self.pipeline_depth == 0 || self.pipeline_depth > MAX_PIPELINE_DEPTH {
            bail!(
                "pipeline_depth must be in 1..={MAX_PIPELINE_DEPTH} (got {})",
                self.pipeline_depth
            );
        }
        if self.ckpt_every > 0 && self.ckpt_path.is_empty() {
            bail!("--ckpt-every needs a checkpoint path (--ckpt)");
        }
        // fail at startup, not at the scheduled iteration, on a bad plan
        crate::comm::faults::FaultPlan::parse(&self.fault_plan)?;
        if !self.data_shards_effective().is_empty() && self.mode == TrainMode::DistDgl {
            bail!("distdgl mode samples from the global in-RAM graph; --data-shards needs aep or nocomm");
        }
        if self.push_batch == 0 {
            bail!("push_batch must be >= 1");
        }
        if self.serve_queue == 0 {
            bail!("serve_queue must be >= 1 (admission control needs room for one request)");
        }
        if self.push_batch > 1 {
            let d = self.hec.d.max(1);
            if self.push_batch > d || self.push_batch > self.pipeline_depth {
                bail!(
                    "push_batch {} must be <= min(hec_d {d}, pipeline_depth {}): receivers \
                     block for watermark k-d while a batching sender's watermark lags by \
                     push_batch-1, and batched pushes must fit the advertised pipeline window",
                    self.push_batch,
                    self.pipeline_depth
                );
            }
        }
        if !self.hosts.is_empty() {
            // fail at startup on a malformed or mis-sized topology
            parse_hosts(&self.hosts, self.ranks)?;
        }
        if self.fabric == FabricKind::Hier && self.hosts.is_empty() {
            bail!("--fabric hier needs a --hosts topology (e.g. a:2,b:2)");
        }
        if matches!(self.fabric, FabricKind::Socket | FabricKind::Hier) {
            if self.peers.len() != self.ranks {
                bail!(
                    "{} fabric needs one --peers address per rank ({} given, {} ranks)",
                    self.fabric.as_str(),
                    self.peers.len(),
                    self.ranks
                );
            }
            if self.rank >= self.ranks {
                bail!("--rank {} out of range for {} ranks", self.rank, self.ranks);
            }
            if self.mode == TrainMode::DistDgl {
                bail!("distdgl mode samples across all ranks in-process; use --fabric sim");
            }
        }
        Ok(())
    }

    /// The parsed `--hosts` topology: host index per rank, or `None` when
    /// no topology was given (every rank its own host).
    pub fn host_map(&self) -> Result<Option<Vec<usize>>> {
        if self.hosts.is_empty() {
            Ok(None)
        } else {
            parse_hosts(&self.hosts, self.ranks).map(Some)
        }
    }

    /// Artifact program name for this config.
    pub fn program_name(&self, kind: &str) -> String {
        format!("{}_{}_{}", self.model.as_str(), kind, self.preset)
    }

    /// Echo as JSON (report headers).
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("preset", json::s(&self.preset)),
            ("model", json::s(self.model.as_str())),
            ("ranks", json::num(self.ranks as f64)),
            ("epochs", json::num(self.epochs as f64)),
            ("lr", json::num(self.lr as f64)),
            ("seed", json::num(self.seed as f64)),
            ("hec_cs", json::num(self.hec.cs as f64)),
            ("hec_nc", json::num(self.hec.nc as f64)),
            ("hec_ls", json::num(self.hec.ls as f64)),
            ("hec_d", json::num(self.hec.d as f64)),
            ("hec_policy", json::s(self.hec_policy_effective().as_str())),
            ("hec_prefetch", Value::Bool(self.hec_prefetch_effective())),
            ("partitioner", json::s(&self.partitioner)),
            ("mode", json::s(self.mode.as_str())),
            ("sampler", json::s(self.sampler.as_str())),
            ("optimizer", json::s(&self.optimizer)),
            ("pipeline", Value::Bool(self.pipeline)),
            (
                "pipeline_depth",
                json::num(self.pipeline_depth_effective() as f64),
            ),
            ("dtype", json::s(self.dtype_effective().as_str())),
            ("fabric", json::s(self.fabric.as_str())),
            ("rank", json::num(self.rank as f64)),
            ("hosts", json::s(&self.hosts)),
            ("push_batch", json::num(self.push_batch as f64)),
            ("fault_plan", json::s(&self.fault_plan)),
            ("ckpt_every", json::num(self.ckpt_every as f64)),
            ("data_shards", json::s(&self.data_shards_effective())),
            ("data_shards_mmap", Value::Bool(self.shards_mmap_effective())),
            (
                "serve_deadline_ms",
                json::num(self.serve_deadline_ms_effective() as f64),
            ),
            ("serve_queue", json::num(self.serve_queue_effective() as f64)),
        ])
    }

    /// Effective pipeline switch: the config flag, overridable at runtime
    /// via `DISTGNN_PIPELINE=0|1` (the serial escape hatch).
    pub fn pipeline_enabled(&self) -> bool {
        pipeline_override(std::env::var("DISTGNN_PIPELINE").ok().as_deref(), self.pipeline)
    }

    /// Effective storage dtype: the config field, overridable at runtime
    /// via `DISTGNN_DTYPE=f32|bf16`. The driver resolves this once at
    /// construction, so a mid-run env change cannot split the dtype
    /// between HECs and push payloads.
    pub fn dtype_effective(&self) -> DtypeKind {
        dtype_override(std::env::var("DISTGNN_DTYPE").ok().as_deref(), self.dtype)
    }

    /// Effective pipeline depth `p`: the config field, overridable at
    /// runtime via `DISTGNN_PIPELINE_DEPTH=p`. The driver resolves this
    /// once at construction (the ring and the fabric's sliding ITER_DONE
    /// window must agree for the whole run).
    pub fn pipeline_depth_effective(&self) -> usize {
        depth_override(
            std::env::var("DISTGNN_PIPELINE_DEPTH").ok().as_deref(),
            self.pipeline_depth,
        )
    }

    /// Effective HEC replacement policy: the config field, overridable at
    /// runtime via `DISTGNN_HEC_POLICY=ocf|reuse`. The driver resolves
    /// this once at construction so every layer's cache runs one policy
    /// for the whole run.
    pub fn hec_policy_effective(&self) -> HecPolicyKind {
        hec_policy_override(
            std::env::var("DISTGNN_HEC_POLICY").ok().as_deref(),
            self.hec.policy,
        )
    }

    /// Effective lookahead-prefetch switch: the config field, overridable
    /// at runtime via `DISTGNN_HEC_PREFETCH=0|1`.
    pub fn hec_prefetch_effective(&self) -> bool {
        hec_prefetch_override(
            std::env::var("DISTGNN_HEC_PREFETCH").ok().as_deref(),
            self.hec.prefetch,
        )
    }

    /// Effective shard-set directory: the config field, overridable at
    /// runtime via `DISTGNN_DATA_SHARDS=<dir>`. Empty = in-RAM path.
    pub fn data_shards_effective(&self) -> String {
        data_shards_override(
            std::env::var("DISTGNN_DATA_SHARDS").ok().as_deref(),
            &self.data_shards,
        )
    }

    /// Effective shard read mode: mmap views (true) or heap copies
    /// (false), overridable at runtime via `DISTGNN_SHARDS_MMAP=0|1`.
    pub fn shards_mmap_effective(&self) -> bool {
        shards_mmap_override(
            std::env::var("DISTGNN_SHARDS_MMAP").ok().as_deref(),
            self.data_shards_mmap,
        )
    }

    /// Effective serving deadline-batching window (ms), overridable at
    /// runtime via `DISTGNN_SERVE_DEADLINE_MS=<ms>`.
    pub fn serve_deadline_ms_effective(&self) -> u64 {
        serve_deadline_override(
            std::env::var("DISTGNN_SERVE_DEADLINE_MS").ok().as_deref(),
            self.serve_deadline_ms,
        )
    }

    /// Effective serving admission-queue bound, overridable at runtime
    /// via `DISTGNN_SERVE_QUEUE=<n>` (0 is rejected: admission control
    /// needs room for at least one request).
    pub fn serve_queue_effective(&self) -> usize {
        serve_queue_override(
            std::env::var("DISTGNN_SERVE_QUEUE").ok().as_deref(),
            self.serve_queue,
        )
    }
}

/// Parse a `--hosts` topology spec into a host index per rank,
/// host-major: `"a:2,b:2"` (or bare counts `"2,2"`) places ranks 0-1 on
/// host 0 and ranks 2-3 on host 1. Each comma-separated entry is one
/// host; an optional `name:` prefix is documentation only. The counts
/// must sum to `ranks` exactly.
pub fn parse_hosts(spec: &str, ranks: usize) -> Result<Vec<usize>> {
    let mut host_of = Vec::with_capacity(ranks);
    for (h, entry) in spec.split(',').enumerate() {
        let entry = entry.trim();
        if entry.is_empty() {
            bail!("empty host entry in --hosts '{spec}'");
        }
        let count_s = entry.rsplit(':').next().unwrap_or(entry).trim();
        let count: usize = count_s.parse().map_err(|_| {
            anyhow::anyhow!(
                "bad rank count '{count_s}' in --hosts entry '{entry}' (want name:count or count)"
            )
        })?;
        if count == 0 {
            bail!("--hosts entry '{entry}' places zero ranks");
        }
        host_of.extend(std::iter::repeat(h).take(count));
    }
    if host_of.len() != ranks {
        bail!(
            "--hosts '{spec}' places {} ranks but the run has {ranks}",
            host_of.len()
        );
    }
    Ok(host_of)
}

/// Upper bound on the pipeline depth: far above any useful prefetch ring
/// (the ring holds whole sampled minibatches in memory), low enough that a
/// typo'd knob cannot balloon allocation.
pub const MAX_PIPELINE_DEPTH: usize = 64;

/// Resolve the `DISTGNN_PIPELINE_DEPTH` override against the config
/// default (pure — unit-testable; unparseable or out-of-range values fall
/// back to the default).
fn depth_override(env: Option<&str>, default: usize) -> usize {
    env.and_then(|v| v.parse::<usize>().ok())
        .filter(|&p| p >= 1 && p <= MAX_PIPELINE_DEPTH)
        .unwrap_or(default)
}

/// Resolve the `DISTGNN_PIPELINE` override against the config default
/// (pure — unit-testable without mutating process environment).
fn pipeline_override(env: Option<&str>, default: bool) -> bool {
    match env {
        Some(v) if v == "0" || v.eq_ignore_ascii_case("off") => false,
        Some(v) if v == "1" || v.eq_ignore_ascii_case("on") => true,
        _ => default,
    }
}

/// Resolve the `DISTGNN_DTYPE` override against the config default
/// (pure — unit-testable; unparseable values fall back to the default).
fn dtype_override(env: Option<&str>, default: DtypeKind) -> DtypeKind {
    env.and_then(|v| DtypeKind::parse(v).ok()).unwrap_or(default)
}

/// Resolve the `DISTGNN_HEC_POLICY` override against the config default
/// (pure — unit-testable; unparseable values fall back to the default).
fn hec_policy_override(env: Option<&str>, default: HecPolicyKind) -> HecPolicyKind {
    env.and_then(|v| HecPolicyKind::parse(v).ok()).unwrap_or(default)
}

/// Resolve the `DISTGNN_HEC_PREFETCH` override against the config default
/// (pure — unit-testable without mutating process environment).
fn hec_prefetch_override(env: Option<&str>, default: bool) -> bool {
    match env {
        Some(v) if v == "0" || v.eq_ignore_ascii_case("off") => false,
        Some(v) if v == "1" || v.eq_ignore_ascii_case("on") => true,
        _ => default,
    }
}

/// Resolve the `DISTGNN_DATA_SHARDS` override against the config default
/// (pure — unit-testable without mutating process environment).
fn data_shards_override(env: Option<&str>, default: &str) -> String {
    match env {
        Some(v) if !v.trim().is_empty() => v.trim().to_string(),
        _ => default.to_string(),
    }
}

/// Resolve the `DISTGNN_SHARDS_MMAP` override against the config default
/// (pure — unit-testable without mutating process environment).
fn shards_mmap_override(env: Option<&str>, default: bool) -> bool {
    match env {
        Some(v) if v == "0" || v.eq_ignore_ascii_case("off") => false,
        Some(v) if v == "1" || v.eq_ignore_ascii_case("on") => true,
        _ => default,
    }
}

/// Resolve the `DISTGNN_SERVE_DEADLINE_MS` override against the config
/// default (pure — unit-testable; unparseable values fall back).
fn serve_deadline_override(env: Option<&str>, default: u64) -> u64 {
    env.and_then(|v| v.trim().parse::<u64>().ok()).unwrap_or(default)
}

/// Resolve the `DISTGNN_SERVE_QUEUE` override against the config default
/// (pure — unit-testable; zero or unparseable values fall back).
fn serve_queue_override(env: Option<&str>, default: usize) -> usize {
    env.and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_valid() {
        TrainConfig::default().validate().unwrap();
    }

    #[test]
    fn pipeline_env_override_parsing() {
        assert!(!pipeline_override(Some("0"), true));
        assert!(!pipeline_override(Some("off"), true));
        assert!(pipeline_override(Some("1"), false));
        assert!(pipeline_override(Some("ON"), false));
        assert!(pipeline_override(Some("garbage"), true));
        assert!(!pipeline_override(Some("garbage"), false));
        assert!(pipeline_override(None, true));
        assert!(!pipeline_override(None, false));
    }

    #[test]
    fn pipeline_depth_parsing_validation_and_env_override() {
        let mut cfg = TrainConfig::default();
        assert_eq!(cfg.pipeline_depth, 1);
        cfg.apply_json(&json::parse(r#"{"pipeline_depth": 4}"#).unwrap())
            .unwrap();
        assert_eq!(cfg.pipeline_depth, 4);
        cfg.pipeline_depth = 0;
        assert!(cfg.validate().is_err(), "depth 0 must fail validation");
        cfg.pipeline_depth = MAX_PIPELINE_DEPTH + 1;
        assert!(cfg.validate().is_err(), "oversized depth must fail");
        cfg.pipeline_depth = MAX_PIPELINE_DEPTH;
        cfg.validate().unwrap();

        assert_eq!(depth_override(Some("8"), 1), 8);
        assert_eq!(depth_override(Some("0"), 2), 2, "0 is out of range");
        assert_eq!(depth_override(Some("999"), 2), 2, "cap enforced");
        assert_eq!(depth_override(Some("garbage"), 3), 3);
        assert_eq!(depth_override(None, 5), 5);
    }

    #[test]
    fn json_roundtrip_overrides() {
        let mut cfg = TrainConfig::default();
        let v = json::parse(
            r#"{"model": "gat", "ranks": 8, "hec_d": 2, "mode": "distdgl", "lr": 0.001}"#,
        )
        .unwrap();
        cfg.apply_json(&v).unwrap();
        assert_eq!(cfg.model, ModelKind::Gat);
        assert_eq!(cfg.ranks, 8);
        assert_eq!(cfg.hec.d, 2);
        assert_eq!(cfg.mode, TrainMode::DistDgl);
        assert!((cfg.lr - 0.001).abs() < 1e-9);
    }

    #[test]
    fn unknown_key_rejected() {
        let mut cfg = TrainConfig::default();
        let v = json::parse(r#"{"bogus": 1}"#).unwrap();
        assert!(cfg.apply_json(&v).is_err());
    }

    #[test]
    fn parse_enums() {
        assert!(ModelKind::parse("nope").is_err());
        assert_eq!(TrainMode::parse("aep").unwrap(), TrainMode::Aep);
        assert_eq!(SamplerKind::parse("ipc").unwrap(), SamplerKind::SerialIpc);
    }

    #[test]
    fn fabric_parsing_and_validation() {
        assert_eq!(FabricKind::parse("sim").unwrap(), FabricKind::Sim);
        assert_eq!(FabricKind::parse("socket").unwrap(), FabricKind::Socket);
        assert!(FabricKind::parse("rdma").is_err());

        let mut cfg = TrainConfig::default();
        cfg.fabric = FabricKind::Socket;
        assert!(cfg.validate().is_err(), "socket without peers must fail");
        cfg.peers = vec!["/tmp/a.sock".into(), "/tmp/b.sock".into()];
        cfg.validate().unwrap();
        cfg.rank = 2;
        assert!(cfg.validate().is_err(), "rank out of range must fail");
        cfg.rank = 0;
        cfg.mode = TrainMode::DistDgl;
        assert!(cfg.validate().is_err(), "socket + distdgl must fail");
    }

    #[test]
    fn hosts_spec_parses_host_major_and_rejects_bad_shapes() {
        assert_eq!(parse_hosts("a:2,b:2", 4).unwrap(), vec![0, 0, 1, 1]);
        assert_eq!(parse_hosts("2,1", 3).unwrap(), vec![0, 0, 1]);
        assert_eq!(parse_hosts(" node-x:1 , node-y:3 ", 4).unwrap(), vec![0, 1, 1, 1]);
        assert!(parse_hosts("a:2,b:2", 3).is_err(), "sum mismatch must fail");
        assert!(parse_hosts("a:0,b:4", 4).is_err(), "zero-rank host must fail");
        assert!(parse_hosts("a:x", 1).is_err(), "non-numeric count must fail");
        assert!(parse_hosts("a:2,,b:2", 4).is_err(), "empty entry must fail");
    }

    #[test]
    fn hier_fabric_requires_hosts_and_peers() {
        assert_eq!(FabricKind::parse("hier").unwrap(), FabricKind::Hier);
        assert_eq!(FabricKind::parse("hierarchical").unwrap(), FabricKind::Hier);
        assert_eq!(FabricKind::Hier.as_str(), "hier");

        let mut cfg = TrainConfig::default();
        cfg.fabric = FabricKind::Hier;
        cfg.peers = vec!["/tmp/a.sock".into(), "/tmp/b.sock".into()];
        assert!(cfg.validate().is_err(), "hier without hosts must fail");
        cfg.hosts = "a:2".into();
        cfg.validate().unwrap();
        cfg.hosts = "a:1,b:2".into();
        assert!(cfg.validate().is_err(), "hosts/ranks mismatch must fail");
        cfg.hosts = "a:1,b:1".into();
        cfg.peers.pop();
        assert!(cfg.validate().is_err(), "hier without full peers must fail");

        // a hosts map under sim is legal (wire-byte classification only)
        let mut sim = TrainConfig::default();
        sim.hosts = "a:1,b:1".into();
        sim.validate().unwrap();
        assert_eq!(sim.host_map().unwrap(), Some(vec![0, 1]));
        sim.hosts.clear();
        assert_eq!(sim.host_map().unwrap(), None);
    }

    #[test]
    fn push_batch_bounded_by_delay_and_pipeline_depth() {
        let mut cfg = TrainConfig::default();
        assert_eq!(cfg.push_batch, 1);
        cfg.apply_json(
            &json::parse(r#"{"push_batch": 2, "hec_d": 2, "pipeline_depth": 2}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.push_batch, 2);

        cfg.push_batch = 0;
        assert!(cfg.validate().is_err(), "push_batch 0 must fail");
        cfg.push_batch = 3;
        assert!(cfg.validate().is_err(), "push_batch > hec_d must fail");
        cfg.hec.d = 4;
        assert!(
            cfg.validate().is_err(),
            "push_batch > pipeline_depth must fail even with deep d"
        );
        cfg.pipeline_depth = 3;
        cfg.validate().unwrap();
    }

    #[test]
    fn peers_json_accepts_array_and_comma_string() {
        let mut cfg = TrainConfig::default();
        cfg.apply_json(&json::parse(r#"{"peers": ["a:1", "b:2"]}"#).unwrap())
            .unwrap();
        assert_eq!(cfg.peers, vec!["a:1", "b:2"]);
        cfg.apply_json(&json::parse(r#"{"peers": "c:3, d:4"}"#).unwrap())
            .unwrap();
        assert_eq!(cfg.peers, vec!["c:3", "d:4"]);
        assert!(cfg
            .apply_json(&json::parse(r#"{"fabric": "bogus"}"#).unwrap())
            .is_err());
    }

    #[test]
    fn fault_and_checkpoint_knobs_parse_and_validate() {
        let mut cfg = TrainConfig::default();
        cfg.apply_json(
            &json::parse(
                r#"{"fault_plan": "kill:rank=1,iter=7", "ckpt_every": 2, "ckpt_path": "c.ckpt"}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.fault_plan, "kill:rank=1,iter=7");
        assert_eq!(cfg.ckpt_every, 2);
        assert_eq!(cfg.ckpt_path, "c.ckpt");

        cfg.ckpt_path = String::new();
        assert!(cfg.validate().is_err(), "ckpt_every without path must fail");
        cfg.ckpt_every = 0;
        cfg.validate().unwrap();

        cfg.fault_plan = "explode:rank=1,iter=2".into();
        assert!(cfg.validate().is_err(), "bad fault plan must fail early");
    }

    #[test]
    fn hec_policy_and_prefetch_knobs() {
        assert_eq!(HecPolicyKind::parse("ocf").unwrap(), HecPolicyKind::Ocf);
        assert_eq!(HecPolicyKind::parse("fifo").unwrap(), HecPolicyKind::Ocf);
        assert_eq!(HecPolicyKind::parse("reuse").unwrap(), HecPolicyKind::Reuse);
        assert!(HecPolicyKind::parse("lru").is_err());

        let mut cfg = TrainConfig::default();
        assert_eq!(cfg.hec.policy, HecPolicyKind::Ocf);
        assert!(!cfg.hec.prefetch);
        cfg.apply_json(&json::parse(r#"{"hec_policy": "reuse", "hec_prefetch": true}"#).unwrap())
            .unwrap();
        assert_eq!(cfg.hec.policy, HecPolicyKind::Reuse);
        assert!(cfg.hec.prefetch);
        assert!(cfg
            .apply_json(&json::parse(r#"{"hec_policy": "lru"}"#).unwrap())
            .is_err());

        assert_eq!(
            hec_policy_override(Some("reuse"), HecPolicyKind::Ocf),
            HecPolicyKind::Reuse
        );
        assert_eq!(
            hec_policy_override(Some("garbage"), HecPolicyKind::Ocf),
            HecPolicyKind::Ocf
        );
        assert_eq!(
            hec_policy_override(None, HecPolicyKind::Reuse),
            HecPolicyKind::Reuse
        );
        assert!(hec_prefetch_override(Some("1"), false));
        assert!(hec_prefetch_override(Some("on"), false));
        assert!(!hec_prefetch_override(Some("0"), true));
        assert!(!hec_prefetch_override(Some("off"), true));
        assert!(hec_prefetch_override(Some("garbage"), true));
        assert!(!hec_prefetch_override(None, false));
    }

    #[test]
    fn data_shards_knobs_parse_validate_and_override() {
        let mut cfg = TrainConfig::default();
        assert!(cfg.data_shards.is_empty());
        assert!(cfg.data_shards_mmap);
        cfg.apply_json(
            &json::parse(r#"{"data_shards": "/tmp/shards", "data_shards_mmap": false}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.data_shards, "/tmp/shards");
        assert!(!cfg.data_shards_mmap);

        cfg.mode = TrainMode::DistDgl;
        assert!(cfg.validate().is_err(), "distdgl + shards must fail");
        cfg.mode = TrainMode::Aep;
        cfg.validate().unwrap();

        assert_eq!(data_shards_override(Some("/a/b"), ""), "/a/b");
        assert_eq!(data_shards_override(Some("  "), "/keep"), "/keep");
        assert_eq!(data_shards_override(None, "/keep"), "/keep");
        assert!(!shards_mmap_override(Some("0"), true));
        assert!(shards_mmap_override(Some("on"), false));
        assert!(shards_mmap_override(Some("garbage"), true));
        assert!(!shards_mmap_override(None, false));
    }

    #[test]
    fn serve_knobs_parse_validate_and_override() {
        let mut cfg = TrainConfig::default();
        assert_eq!(cfg.serve_deadline_ms, 2);
        assert_eq!(cfg.serve_queue, 64);
        cfg.apply_json(&json::parse(r#"{"serve_deadline_ms": 10, "serve_queue": 8}"#).unwrap())
            .unwrap();
        assert_eq!(cfg.serve_deadline_ms, 10);
        assert_eq!(cfg.serve_queue, 8);

        cfg.serve_queue = 0;
        assert!(cfg.validate().is_err(), "zero admission queue must fail");
        cfg.serve_queue = 1;
        cfg.validate().unwrap();

        // a zero deadline is legal: it disables coalescing
        cfg.serve_deadline_ms = 0;
        cfg.validate().unwrap();

        assert_eq!(serve_deadline_override(Some("7"), 2), 7);
        assert_eq!(serve_deadline_override(Some("0"), 2), 0);
        assert_eq!(serve_deadline_override(Some("garbage"), 2), 2);
        assert_eq!(serve_deadline_override(None, 2), 2);
        assert_eq!(serve_queue_override(Some("16"), 64), 16);
        assert_eq!(serve_queue_override(Some("0"), 64), 64, "zero falls back");
        assert_eq!(serve_queue_override(Some("garbage"), 64), 64);
        assert_eq!(serve_queue_override(None, 64), 64);

        // knobs echo through the report header
        let hdr = TrainConfig::default().to_json();
        assert_eq!(hdr.get("serve_deadline_ms").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(hdr.get("serve_queue").and_then(|v| v.as_usize()), Some(64));
    }

    #[test]
    fn program_names() {
        let cfg = TrainConfig::default();
        assert_eq!(cfg.program_name("train"), "sage_train_tiny");
    }

    #[test]
    fn dtype_parsing_json_and_env_override() {
        assert_eq!(DtypeKind::parse("f32").unwrap(), DtypeKind::F32);
        assert_eq!(DtypeKind::parse("bfloat16").unwrap(), DtypeKind::Bf16);
        assert!(DtypeKind::parse("fp8").is_err());
        assert_eq!(DtypeKind::Bf16.elem_bytes(), 2);
        assert_eq!(
            DtypeKind::Bf16.tensor_dtype(),
            crate::runtime::tensor::DType::Bf16
        );

        let mut cfg = TrainConfig::default();
        assert_eq!(cfg.dtype, DtypeKind::F32);
        cfg.apply_json(&json::parse(r#"{"dtype": "bf16"}"#).unwrap())
            .unwrap();
        assert_eq!(cfg.dtype, DtypeKind::Bf16);
        assert!(cfg
            .apply_json(&json::parse(r#"{"dtype": "fp64"}"#).unwrap())
            .is_err());

        assert_eq!(dtype_override(Some("bf16"), DtypeKind::F32), DtypeKind::Bf16);
        assert_eq!(dtype_override(Some("f32"), DtypeKind::Bf16), DtypeKind::F32);
        assert_eq!(
            dtype_override(Some("garbage"), DtypeKind::Bf16),
            DtypeKind::Bf16
        );
        assert_eq!(dtype_override(None, DtypeKind::F32), DtypeKind::F32);
    }
}
