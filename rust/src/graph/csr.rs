//! Compressed sparse row adjacency.

use crate::graph::Vid;
use crate::util::mmap::Storage;

/// Undirected graph in CSR form (every edge stored in both directions).
///
/// The arrays live in [`Storage`]: plain heap vectors for in-RAM graphs
/// (the builtin generator, `Csr::from_edges`), or slices viewed inside a
/// memory-mapped shard file on the out-of-core path. Every accessor goes
/// through the deref'd slices, so readers cannot tell the difference.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    /// Row pointers, length `n + 1`.
    pub indptr: Storage<u64>,
    /// Column indices (neighbor vertex ids), length = number of directed
    /// edges; each neighbor list is sorted ascending.
    pub indices: Storage<Vid>,
}

impl Csr {
    /// Build from an edge list. Edges are symmetrized (u→v and v→u),
    /// self-loops and duplicates removed. This mirrors the paper's Table 1
    /// note: "directed edges in the original graph are converted to
    /// un-directed edges".
    pub fn from_edges(n: usize, edges: &[(Vid, Vid)]) -> Csr {
        let mut deg = vec![0u64; n];
        for &(u, v) in edges {
            debug_assert!((u as usize) < n && (v as usize) < n);
            if u == v {
                continue;
            }
            deg[u as usize] += 1;
            deg[v as usize] += 1;
        }
        let mut indptr = vec![0u64; n + 1];
        for i in 0..n {
            indptr[i + 1] = indptr[i] + deg[i];
        }
        let mut indices = vec![0 as Vid; indptr[n] as usize];
        let mut cursor: Vec<u64> = indptr[..n].to_vec();
        for &(u, v) in edges {
            if u == v {
                continue;
            }
            indices[cursor[u as usize] as usize] = v;
            cursor[u as usize] += 1;
            indices[cursor[v as usize] as usize] = u;
            cursor[v as usize] += 1;
        }
        // Sort + dedup each row, then rebuild compactly.
        let mut out_indices = Vec::with_capacity(indices.len());
        let mut out_indptr = vec![0u64; n + 1];
        for v in 0..n {
            let row = &mut indices[indptr[v] as usize..indptr[v + 1] as usize];
            row.sort_unstable();
            let mut prev: Option<Vid> = None;
            for &x in row.iter() {
                if Some(x) != prev {
                    out_indices.push(x);
                    prev = Some(x);
                }
            }
            out_indptr[v + 1] = out_indices.len() as u64;
        }
        Csr {
            indptr: out_indptr.into(),
            indices: out_indices.into(),
        }
    }

    /// Number of vertices.
    pub fn num_vertices(&self) -> usize {
        self.indptr.len() - 1
    }

    /// Number of directed edges (2x undirected edge count).
    pub fn num_directed_edges(&self) -> usize {
        self.indices.len()
    }

    /// Degree of vertex `v`.
    #[inline]
    pub fn degree(&self, v: Vid) -> usize {
        (self.indptr[v as usize + 1] - self.indptr[v as usize]) as usize
    }

    /// Neighbor slice of vertex `v` (sorted ascending).
    #[inline]
    pub fn neighbors(&self, v: Vid) -> &[Vid] {
        &self.indices[self.indptr[v as usize] as usize..self.indptr[v as usize + 1] as usize]
    }

    /// True if edge (u, v) exists. O(log deg(u)).
    pub fn has_edge(&self, u: Vid, v: Vid) -> bool {
        self.neighbors(u).binary_search(&v).is_ok()
    }

    /// Maximum degree.
    pub fn max_degree(&self) -> usize {
        (0..self.num_vertices())
            .map(|v| self.degree(v as Vid))
            .max()
            .unwrap_or(0)
    }

    /// Mean degree.
    pub fn mean_degree(&self) -> f64 {
        if self.num_vertices() == 0 {
            return 0.0;
        }
        self.num_directed_edges() as f64 / self.num_vertices() as f64
    }

    /// Validate structural invariants (used by property tests).
    pub fn validate(&self) -> anyhow::Result<()> {
        let n = self.num_vertices();
        if self.indptr[0] != 0 || *self.indptr.last().unwrap() as usize != self.indices.len() {
            anyhow::bail!("indptr endpoints corrupt");
        }
        for v in 0..n {
            let row = self.neighbors(v as Vid);
            for w in row.windows(2) {
                if w[0] >= w[1] {
                    anyhow::bail!("row {v} not strictly sorted");
                }
            }
            for &u in row {
                if u as usize >= n {
                    anyhow::bail!("row {v} has out-of-range neighbor {u}");
                }
                if u == v as Vid {
                    anyhow::bail!("self loop at {v}");
                }
                if !self.has_edge(u, v as Vid) {
                    anyhow::bail!("asymmetric edge {v}->{u}");
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_edges_symmetrizes_and_dedups() {
        // includes a duplicate and a self-loop
        let g = Csr::from_edges(4, &[(0, 1), (1, 0), (1, 2), (2, 2), (3, 1)]);
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.neighbors(0), &[1]);
        assert_eq!(g.neighbors(1), &[0, 2, 3]);
        assert_eq!(g.neighbors(2), &[1]);
        assert_eq!(g.neighbors(3), &[1]);
        assert_eq!(g.num_directed_edges(), 6);
        g.validate().unwrap();
    }

    #[test]
    fn degrees_and_stats() {
        let g = Csr::from_edges(5, &[(0, 1), (0, 2), (0, 3), (0, 4)]);
        assert_eq!(g.degree(0), 4);
        assert_eq!(g.max_degree(), 4);
        assert!((g.mean_degree() - 8.0 / 5.0).abs() < 1e-12);
        assert!(g.has_edge(3, 0));
        assert!(!g.has_edge(3, 4));
    }

    #[test]
    fn empty_graph() {
        let g = Csr::from_edges(3, &[]);
        assert_eq!(g.num_directed_edges(), 0);
        assert_eq!(g.neighbors(1), &[] as &[Vid]);
        g.validate().unwrap();
    }
}
