//! Dataset presets: graph + features + labels + train/test split.
//!
//! `products-mini` and `papers100m-mini` mirror the paper's Table 1 at
//! ~1/1000 scale: same feature dims, class counts and train-split ratios;
//! degree skew from an R-MAT overlay; label signal from planted SBM
//! communities with class-correlated features.

use crate::graph::generator::{rmat_edges, sbm_edges, skewed_communities};
use crate::graph::{Csr, Vid};
use crate::util::rng::Pcg64;

/// A complete node-property-prediction dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub graph: Csr,
    /// Row-major `n x feat_dim` features.
    pub features: Vec<f32>,
    pub feat_dim: usize,
    /// Class label per vertex.
    pub labels: Vec<u32>,
    pub num_classes: usize,
    pub train_vertices: Vec<Vid>,
    pub test_vertices: Vec<Vid>,
}

impl Dataset {
    pub fn num_vertices(&self) -> usize {
        self.graph.num_vertices()
    }

    pub fn feature_row(&self, v: Vid) -> &[f32] {
        let d = self.feat_dim;
        &self.features[v as usize * d..(v as usize + 1) * d]
    }

    /// Paper Table 1-style row: name, #vertex, #edge(directed), #feat,
    /// #class, #train, #test.
    pub fn table1_row(&self) -> String {
        format!(
            "{:<18} {:>9} {:>11} {:>6} {:>7} {:>9} {:>9}",
            self.name,
            self.num_vertices(),
            self.graph.num_directed_edges(),
            self.feat_dim,
            self.num_classes,
            self.train_vertices.len(),
            self.test_vertices.len()
        )
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        let n = self.num_vertices();
        if self.features.len() != n * self.feat_dim {
            anyhow::bail!("feature matrix size mismatch");
        }
        if self.labels.len() != n {
            anyhow::bail!("labels size mismatch");
        }
        if self.labels.iter().any(|&l| l as usize >= self.num_classes) {
            anyhow::bail!("label out of range");
        }
        let mut seen = vec![false; n];
        for &v in self.train_vertices.iter().chain(&self.test_vertices) {
            if v as usize >= n {
                anyhow::bail!("split vertex out of range");
            }
            if seen[v as usize] {
                anyhow::bail!("vertex {v} in both splits");
            }
            seen[v as usize] = true;
        }
        Ok(())
    }
}

/// Generation parameters for a synthetic dataset.
#[derive(Clone, Debug)]
pub struct DatasetPreset {
    pub name: String,
    pub num_vertices: usize,
    /// Undirected edge samples from the SBM (community) component.
    pub sbm_edges: usize,
    /// Edge samples from the R-MAT (skew) overlay.
    pub rmat_edges: usize,
    pub feat_dim: usize,
    pub num_classes: usize,
    /// Fraction of intra-community SBM edges.
    pub p_intra: f64,
    /// Community size skew exponent.
    pub community_skew: f64,
    /// Feature noise sigma around the class centroid.
    pub feat_noise: f64,
    pub train_fraction: f64,
    pub test_fraction: f64,
    pub seed: u64,
}

impl DatasetPreset {
    /// OGBN-Products analog (2.45M/124M/100feat/47cls/8% train in the
    /// paper) at ~1/50 vertex scale.
    pub fn products_mini() -> DatasetPreset {
        DatasetPreset {
            name: "products-mini".into(),
            num_vertices: 48_000,
            sbm_edges: 480_000,
            rmat_edges: 240_000,
            feat_dim: 100,
            num_classes: 47,
            p_intra: 0.85,
            community_skew: 0.6,
            feat_noise: 1.0,
            train_fraction: 0.08,
            test_fraction: 0.30,
            seed: 0x0902_5001,
        }
    }

    /// OGBN-Papers100M analog (111M/3.2B/128feat/172cls in the paper).
    /// The train fraction is raised from the paper's 1.1% so that the
    /// minibatch-count-per-rank regime at high rank counts matches the
    /// paper's (≈19 minibatches/rank at max scale) — see DESIGN.md §1.
    pub fn papers100m_mini() -> DatasetPreset {
        DatasetPreset {
            name: "papers100m-mini".into(),
            num_vertices: 120_000,
            sbm_edges: 900_000,
            rmat_edges: 540_000,
            feat_dim: 128,
            num_classes: 172,
            p_intra: 0.80,
            community_skew: 0.5,
            feat_noise: 1.2,
            train_fraction: 0.10,
            test_fraction: 0.20,
            seed: 0x0902_5002,
        }
    }

    /// Small preset for unit/integration tests and quickstart.
    pub fn tiny() -> DatasetPreset {
        DatasetPreset {
            name: "tiny".into(),
            num_vertices: 3_000,
            sbm_edges: 24_000,
            rmat_edges: 9_000,
            feat_dim: 32,
            num_classes: 8,
            p_intra: 0.85,
            community_skew: 0.4,
            feat_noise: 0.8,
            train_fraction: 0.15,
            test_fraction: 0.25,
            seed: 0x0902_5003,
        }
    }

    pub fn by_name(name: &str) -> anyhow::Result<DatasetPreset> {
        match name {
            "products-mini" | "products" => Ok(Self::products_mini()),
            "papers100m-mini" | "papers" => Ok(Self::papers100m_mini()),
            "tiny" => Ok(Self::tiny()),
            other => anyhow::bail!("unknown dataset preset '{other}'"),
        }
    }

    /// Generate the dataset (deterministic in `seed`).
    pub fn generate(&self) -> Dataset {
        let mut rng = Pcg64::new(self.seed, 0);
        let n = self.num_vertices;
        let labels = skewed_communities(n, self.num_classes, self.community_skew, &mut rng);

        // Topology: SBM signal + R-MAT skew overlay (R-MAT vertex ids are
        // hashed into [0, n) to decouple skew from community layout).
        let mut edges = sbm_edges(&labels, self.num_classes, self.sbm_edges, self.p_intra, &mut rng);
        let scale = (usize::BITS - (n - 1).leading_zeros()) as u32; // ceil(log2 n)
        let rmat = rmat_edges(scale, self.rmat_edges, (0.57, 0.19, 0.19, 0.05), &mut rng);
        for (u, v) in rmat {
            let u = (crate::util::rng::splitmix64(u as u64) % n as u64) as Vid;
            let v = (crate::util::rng::splitmix64(v as u64 ^ 0xABCD) % n as u64) as Vid;
            if u != v {
                edges.push((u, v));
            }
        }
        let graph = Csr::from_edges(n, &edges);

        // Features: class centroid + gaussian noise. Centroids are random
        // unit-ish vectors, so classes are linearly separable in
        // expectation but individual nodes need neighborhood aggregation
        // (the GNN's job) to denoise.
        let d = self.feat_dim;
        let mut centroids = vec![0f32; self.num_classes * d];
        let mut crng = Pcg64::new(self.seed, 1);
        for x in centroids.iter_mut() {
            *x = crng.gen_normal() as f32;
        }
        let mut features = vec![0f32; n * d];
        let mut frng = Pcg64::new(self.seed, 2);
        for v in 0..n {
            let c = labels[v] as usize;
            for j in 0..d {
                features[v * d + j] =
                    centroids[c * d + j] + (frng.gen_normal() as f64 * self.feat_noise) as f32;
            }
        }

        // Train/test split.
        let mut order: Vec<Vid> = (0..n as u32).collect();
        let mut srng = Pcg64::new(self.seed, 3);
        srng.shuffle(&mut order);
        let n_train = ((n as f64) * self.train_fraction).round() as usize;
        let n_test = ((n as f64) * self.test_fraction).round() as usize;
        let train_vertices = order[..n_train].to_vec();
        let test_vertices = order[n_train..n_train + n_test].to_vec();

        Dataset {
            name: self.name.clone(),
            graph,
            features,
            feat_dim: d,
            labels,
            num_classes: self.num_classes,
            train_vertices,
            test_vertices,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_dataset_is_valid_and_learnable_shaped() {
        let ds = DatasetPreset::tiny().generate();
        ds.validate().unwrap();
        assert_eq!(ds.feat_dim, 32);
        assert_eq!(ds.num_classes, 8);
        assert_eq!(ds.train_vertices.len(), 450);
        assert!(ds.graph.mean_degree() > 4.0);
        // Homophily: most edges connect same-label vertices (signal for the GNN).
        let mut same = 0usize;
        let mut total = 0usize;
        for v in 0..ds.num_vertices() {
            for &u in ds.graph.neighbors(v as Vid) {
                total += 1;
                if ds.labels[u as usize] == ds.labels[v] {
                    same += 1;
                }
            }
        }
        let h = same as f64 / total as f64;
        assert!(h > 0.5, "homophily {h} too low for a learnable task");
    }

    #[test]
    fn generation_is_deterministic() {
        let a = DatasetPreset::tiny().generate();
        let b = DatasetPreset::tiny().generate();
        assert_eq!(a.graph, b.graph);
        assert_eq!(a.features, b.features);
        assert_eq!(a.train_vertices, b.train_vertices);
    }

    #[test]
    fn preset_lookup() {
        assert!(DatasetPreset::by_name("products-mini").is_ok());
        assert!(DatasetPreset::by_name("papers").is_ok());
        assert!(DatasetPreset::by_name("nope").is_err());
    }

    #[test]
    fn feature_rows_match_labels_in_expectation() {
        // Mean feature of same-class vertices should be closer than across
        // classes (centroid separation sanity).
        let ds = DatasetPreset::tiny().generate();
        let d = ds.feat_dim;
        let mut means = vec![0f32; ds.num_classes * d];
        let mut counts = vec![0usize; ds.num_classes];
        for v in 0..ds.num_vertices() {
            let c = ds.labels[v] as usize;
            counts[c] += 1;
            for j in 0..d {
                means[c * d + j] += ds.features[v * d + j];
            }
        }
        for c in 0..ds.num_classes {
            for j in 0..d {
                means[c * d + j] /= counts[c].max(1) as f32;
            }
        }
        // distance between two class means should exceed typical noise/sqrt(n)
        let dist: f32 = (0..d)
            .map(|j| (means[j] - means[d + j]).powi(2))
            .sum::<f32>()
            .sqrt();
        assert!(dist > 1.0, "class centroids too close: {dist}");
    }
}
