//! Synthetic graph generators.
//!
//! Three families, composable by edge-list union:
//! * [`sbm_edges`] — stochastic block model with planted communities. Gives
//!   the node-property-prediction task its signal (labels = communities).
//! * [`rmat_edges`] — recursive-matrix (Kronecker) generator producing the
//!   heavy-tailed degree distribution characteristic of OGBN graphs; this
//!   is what stresses partitioning, halo counts and degree-biased
//!   solid-vertex subsampling.
//! * [`erdos_renyi_edges`] — uniform background noise edges.
//!
//! Plus the out-of-core scale path: [`generate_rmat_shards`] draws an
//! R-MAT graph of up to 10⁸–10⁹ edges and writes it **directly as a
//! per-rank shard set** (`graph/io.rs` format) without ever holding the
//! full graph in memory — edges stream through per-rank spill files, and
//! feature blocks stream straight into the shard writer. Every random
//! quantity (edge endpoints, vertex ownership, labels, features, splits)
//! is a pure function of `(seed, index)`, so the output is bit-identical
//! across thread counts and across regenerations.

use std::collections::HashMap;
use std::io::{BufWriter, Read, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::graph::io::{
    shard_file_name, SectionKind, ShardDtype, ShardManifest, ShardMeta, ShardWriter,
};
use crate::graph::{DatasetPreset, Vid};
use crate::util::mmap::Mmap;
use crate::util::parallel;
use crate::util::rng::{splitmix64, Pcg64};

/// SBM: vertices are pre-assigned to `communities.len()` blocks
/// (`communities[v]` = block of v). Emits ~`m` undirected edges; a fraction
/// `p_intra` of them connect two vertices of the same block.
pub fn sbm_edges(
    communities: &[u32],
    num_blocks: usize,
    m: usize,
    p_intra: f64,
    rng: &mut Pcg64,
) -> Vec<(Vid, Vid)> {
    let n = communities.len();
    assert!(n >= 2 && num_blocks >= 1);
    // Bucket vertices by community for fast intra-edge sampling.
    let mut members: Vec<Vec<Vid>> = vec![Vec::new(); num_blocks];
    for (v, &c) in communities.iter().enumerate() {
        members[c as usize].push(v as Vid);
    }
    let mut edges = Vec::with_capacity(m);
    for _ in 0..m {
        if rng.gen_bool(p_intra) {
            // intra-community edge: pick a block weighted by size, then two
            // distinct members.
            let v = rng.gen_range(n) as Vid;
            let block = &members[communities[v as usize] as usize];
            if block.len() < 2 {
                continue;
            }
            let u = block[rng.gen_range(block.len())];
            if u != v {
                edges.push((u, v));
            }
        } else {
            let u = rng.gen_range(n) as Vid;
            let v = rng.gen_range(n) as Vid;
            if u != v {
                edges.push((u, v));
            }
        }
    }
    edges
}

/// R-MAT: emits `m` edges over `2^scale` vertices with quadrant
/// probabilities (a, b, c, d), a + b + c + d = 1. Standard Graph500
/// parameters are (0.57, 0.19, 0.19, 0.05).
pub fn rmat_edges(
    scale: u32,
    m: usize,
    (a, b, c, _d): (f64, f64, f64, f64),
    rng: &mut Pcg64,
) -> Vec<(Vid, Vid)> {
    let mut edges = Vec::with_capacity(m);
    for _ in 0..m {
        let (mut u, mut v) = (0u64, 0u64);
        for _ in 0..scale {
            let r = rng.gen_f64();
            let (bu, bv) = if r < a {
                (0, 0)
            } else if r < a + b {
                (0, 1)
            } else if r < a + b + c {
                (1, 0)
            } else {
                (1, 1)
            };
            u = (u << 1) | bu;
            v = (v << 1) | bv;
        }
        if u != v {
            edges.push((u as Vid, v as Vid));
        }
    }
    edges
}

/// Uniform random edges.
pub fn erdos_renyi_edges(n: usize, m: usize, rng: &mut Pcg64) -> Vec<(Vid, Vid)> {
    let mut edges = Vec::with_capacity(m);
    for _ in 0..m {
        let u = rng.gen_range(n) as Vid;
        let v = rng.gen_range(n) as Vid;
        if u != v {
            edges.push((u, v));
        }
    }
    edges
}

/// Assign `n` vertices to `k` communities with skewed (power-law-ish) sizes,
/// shuffled so community membership is not correlated with vertex id.
pub fn skewed_communities(n: usize, k: usize, skew: f64, rng: &mut Pcg64) -> Vec<u32> {
    // Zipf-like weights w_i = (i+1)^-skew.
    let weights: Vec<f64> = (0..k).map(|i| ((i + 1) as f64).powf(-skew)).collect();
    let total: f64 = weights.iter().sum();
    let mut assign = Vec::with_capacity(n);
    for (i, w) in weights.iter().enumerate() {
        let cnt = ((w / total) * n as f64).round() as usize;
        for _ in 0..cnt {
            assign.push(i as u32);
        }
    }
    while assign.len() < n {
        assign.push(rng.gen_range(k) as u32);
    }
    assign.truncate(n);
    rng.shuffle(&mut assign);
    assign
}

// ---------------------------------------------------------------------------
// Out-of-core sharded R-MAT generation
// ---------------------------------------------------------------------------

const SALT_EDGE: u64 = 0x6564_6765; // "edge"
const SALT_OWNER: u64 = 0x6f77_6e72; // "ownr"
const SALT_LABEL: u64 = 0x6c61_626c; // "labl"
const SALT_SPLIT: u64 = 0x7370_6c74; // "splt"
const SALT_CENT: u64 = 0x6365_6e74; // "cent"
const SALT_NOISE: u64 = 0x6e6f_6973; // "nois"

/// Edges drawn per parallel work unit.
const EDGE_CHUNK: u64 = 1 << 14;
/// Work units in flight per wave (bounds generation RSS to
/// `WAVE_CHUNKS * EDGE_CHUNK * 8` bytes of edge buffer).
const WAVE_CHUNKS: u64 = 64;

/// Configuration for [`generate_rmat_shards`].
#[derive(Clone, Debug)]
pub struct ShardGenConfig {
    /// `2^scale` vertices (capped at 31: vertex ids are u32).
    pub scale: u32,
    /// R-MAT edge draws (self-loops skipped, duplicates deduped, so the
    /// kept undirected edge count is somewhat lower).
    pub edges: u64,
    /// Ranks (= shard files).
    pub k: usize,
    pub seed: u64,
    /// Builtin preset supplying the training-program shapes: feat_dim,
    /// num_classes, feature noise. The graph itself comes from `scale` /
    /// `edges`, so a papers100M-class cell is `--preset papers100m-mini`
    /// with a large scale.
    pub preset: String,
    /// R-MAT quadrant probabilities (Graph500 default).
    pub rmat: (f64, f64, f64, f64),
    /// Per-mille of solid vertices marked train / test (disjoint).
    pub train_per_mille: u32,
    pub test_per_mille: u32,
}

impl ShardGenConfig {
    pub fn new(preset: &str, scale: u32, edges: u64, k: usize, seed: u64) -> ShardGenConfig {
        ShardGenConfig {
            scale,
            edges,
            k,
            seed,
            preset: preset.to_string(),
            rmat: (0.57, 0.19, 0.19, 0.05),
            train_per_mille: 100,
            test_per_mille: 50,
        }
    }
}

/// What a generation run produced (echoed by the CLI and benches).
#[derive(Clone, Debug)]
pub struct ShardGenStats {
    pub n_vertices: u64,
    pub edge_draws: u64,
    /// Directed (symmetrized, deduped) edges summed over shards.
    pub directed_edges: u64,
    pub checksums: Vec<u64>,
    pub bytes_written: u64,
}

/// Hash-ownership of a vertex: a pure function of `(seed, v)`, so every
/// rank (and every regeneration) agrees without communication.
pub fn shard_owner(v: Vid, k: usize, seed: u64) -> u32 {
    (splitmix64(v as u64 ^ seed.wrapping_add(SALT_OWNER)) % k as u64) as u32
}

fn vertex_label(v: Vid, classes: usize, seed: u64) -> u32 {
    (splitmix64(v as u64 ^ seed.wrapping_add(SALT_LABEL)) % classes as u64) as u32
}

/// Uniform value in [-1, 1] from a hashed key.
fn unit(x: u64) -> f64 {
    ((x >> 11) as f64) * (2.0 / (1u64 << 53) as f64) - 1.0
}

/// Feature j of vertex v: class centroid + per-(v, j) uniform noise —
/// the same signal structure as the in-RAM preset generator, but pure in
/// `(seed, v, j)` so rows can be streamed in any order by any number of
/// threads.
fn feature_value(v: Vid, j: usize, label: u32, d: usize, sigma: f64, seed: u64) -> f32 {
    let centroid = unit(splitmix64(
        (label as u64 * d as u64 + j as u64) ^ seed.wrapping_add(SALT_CENT),
    ));
    let noise = unit(splitmix64(
        (v as u64 * d as u64 + j as u64) ^ seed.wrapping_add(SALT_NOISE),
    ));
    (centroid + sigma * noise) as f32
}

/// The i-th R-MAT edge draw: each edge has its own keyed RNG stream, so
/// the edge list is independent of how draws are chunked across threads.
fn rmat_edge_at(scale: u32, (a, b, c, _d): (f64, f64, f64, f64), seed: u64, i: u64) -> (Vid, Vid) {
    let mut rng = Pcg64::new(seed ^ SALT_EDGE, i);
    let (mut u, mut v) = (0u64, 0u64);
    for _ in 0..scale {
        let r = rng.gen_f64();
        let (bu, bv) = if r < a {
            (0, 0)
        } else if r < a + b {
            (0, 1)
        } else if r < a + b + c {
            (1, 0)
        } else {
            (1, 1)
        };
        u = (u << 1) | bu;
        v = (v << 1) | bv;
    }
    (u as Vid, v as Vid)
}

fn spill_path(dir: &Path, rank: usize) -> std::path::PathBuf {
    dir.join(format!("spill-r{rank}.tmp"))
}

fn deg_path(dir: &Path, rank: usize) -> std::path::PathBuf {
    dir.join(format!("deg-r{rank}.tmp"))
}

fn read_pairs(path: &Path) -> Result<Vec<(u32, u32)>> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening spill {}", path.display()))?;
    let mut data = Vec::new();
    f.read_to_end(&mut data)?;
    anyhow::ensure!(data.len() % 8 == 0, "torn spill file {}", path.display());
    Ok(data
        .chunks_exact(8)
        .map(|c| {
            (
                u32::from_le_bytes([c[0], c[1], c[2], c[3]]),
                u32::from_le_bytes([c[4], c[5], c[6], c[7]]),
            )
        })
        .collect())
}

fn write_pairs(path: &Path, pairs: &[(u32, u32)]) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating spill {}", path.display()))?;
    let mut w = BufWriter::new(f);
    for &(a, b) in pairs {
        w.write_all(&a.to_le_bytes())?;
        w.write_all(&b.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

/// Degree of `v` in a sorted `(vid, degree)` pair file (0 if absent).
fn deg_lookup(map: &Mmap, v: Vid) -> u32 {
    let bytes = map.as_bytes();
    let n = bytes.len() / 8;
    let at = |i: usize| {
        (
            u32::from_le_bytes(bytes[i * 8..i * 8 + 4].try_into().unwrap()),
            u32::from_le_bytes(bytes[i * 8 + 4..i * 8 + 8].try_into().unwrap()),
        )
    };
    let (mut lo, mut hi) = (0usize, n);
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        let (vid, deg) = at(mid);
        match vid.cmp(&v) {
            std::cmp::Ordering::Equal => return deg,
            std::cmp::Ordering::Less => lo = mid + 1,
            std::cmp::Ordering::Greater => hi = mid,
        }
    }
    0
}

/// Generate an R-MAT graph of `2^scale` vertices / `edges` draws and
/// write it directly as a `k`-rank shard set in `dir` — the full graph is
/// never resident. Three bounded-memory phases:
///
/// 1. **Draw + spill** — edges are drawn in parallel waves (each edge's
///    RNG keyed by its index, so thread count cannot change the output)
///    and appended to per-rank spill files, one record per direction.
/// 2. **Sort + degree** — each rank's spill is sorted/deduped in turn
///    (peak RSS: one rank's edge list) and its solid degrees written to a
///    sorted lookup file.
/// 3. **Build + write** — each rank's CSR, halo tables, labels, splits
///    and streamed feature rows go through [`ShardWriter`]; halo full
///    degrees come from the owners' degree files via binary search over
///    a mapping (never loading a remote partition).
///
/// The manifest is written last; spill/degree files are deleted on
/// success.
pub fn generate_rmat_shards(cfg: &ShardGenConfig, dir: &Path) -> Result<ShardGenStats> {
    anyhow::ensure!(cfg.scale >= 1 && cfg.scale <= 31, "scale must be in [1, 31]");
    anyhow::ensure!(cfg.k >= 1, "need at least one rank");
    anyhow::ensure!(cfg.edges >= 1, "need at least one edge draw");
    anyhow::ensure!(
        cfg.train_per_mille + cfg.test_per_mille <= 1000,
        "train + test per-mille exceed 1000"
    );
    let preset = DatasetPreset::by_name(&cfg.preset)?;
    let n = 1u64 << cfg.scale;
    let d = preset.feat_dim;
    let classes = preset.num_classes;
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating shard dir {}", dir.display()))?;

    // Phase 1: draw edges in deterministic parallel waves, spill per rank.
    let mut spills: Vec<BufWriter<std::fs::File>> = (0..cfg.k)
        .map(|r| {
            std::fs::File::create(spill_path(dir, r))
                .map(BufWriter::new)
                .with_context(|| format!("creating spill for rank {r}"))
        })
        .collect::<Result<_>>()?;
    let n_chunks = cfg.edges.div_ceil(EDGE_CHUNK);
    let mut wave_start = 0u64;
    while wave_start < n_chunks {
        let wave_len = WAVE_CHUNKS.min(n_chunks - wave_start) as usize;
        let produced: Vec<Vec<(Vid, Vid)>> = parallel::parallel_map(wave_len, |ci| {
            let c = wave_start + ci as u64;
            let lo = c * EDGE_CHUNK;
            let hi = cfg.edges.min(lo + EDGE_CHUNK);
            let mut out = Vec::with_capacity((hi - lo) as usize);
            for i in lo..hi {
                let (u, v) = rmat_edge_at(cfg.scale, cfg.rmat, cfg.seed, i);
                if u != v {
                    out.push((u, v));
                }
            }
            out
        });
        for chunk in produced {
            for (u, v) in chunk {
                let (ou, ov) = (
                    shard_owner(u, cfg.k, cfg.seed) as usize,
                    shard_owner(v, cfg.k, cfg.seed) as usize,
                );
                spills[ou].write_all(&u.to_le_bytes())?;
                spills[ou].write_all(&v.to_le_bytes())?;
                spills[ov].write_all(&v.to_le_bytes())?;
                spills[ov].write_all(&u.to_le_bytes())?;
            }
        }
        wave_start += wave_len as u64;
    }
    for s in &mut spills {
        s.flush()?;
    }
    drop(spills);

    // Phase 2: per rank, sort + dedup the spill and write solid degrees.
    for r in 0..cfg.k {
        let mut edges_r = read_pairs(&spill_path(dir, r))?;
        edges_r.sort_unstable();
        edges_r.dedup();
        write_pairs(&spill_path(dir, r), &edges_r)?;
        let f = std::fs::File::create(deg_path(dir, r))?;
        let mut w = BufWriter::new(f);
        let mut i = 0usize;
        while i < edges_r.len() {
            let src = edges_r[i].0;
            let mut j = i;
            while j < edges_r.len() && edges_r[j].0 == src {
                j += 1;
            }
            w.write_all(&src.to_le_bytes())?;
            w.write_all(&((j - i) as u32).to_le_bytes())?;
            i = j;
        }
        w.flush()?;
    }

    // Phase 3: per rank, build the partition arrays and stream the shard.
    let mut manifest = ShardManifest::new(&cfg.preset, cfg.k, cfg.seed, "hash");
    manifest.feat_dim = d as u32;
    manifest.num_classes = classes as u32;
    let mut checksums = Vec::with_capacity(cfg.k);
    let mut directed_edges = 0u64;
    let mut bytes_written = 0u64;
    for r in 0..cfg.k {
        // solids: ascending enumeration of hash-owned vertices
        const BLOCK: u64 = 1 << 16;
        let nb = n.div_ceil(BLOCK) as usize;
        let blocks: Vec<Vec<Vid>> = parallel::parallel_map(nb, |b| {
            let lo = b as u64 * BLOCK;
            let hi = n.min(lo + BLOCK);
            (lo..hi)
                .map(|v| v as Vid)
                .filter(|&v| shard_owner(v, cfg.k, cfg.seed) == r as u32)
                .collect()
        });
        let solids: Vec<Vid> = blocks.concat();
        let n_solid = solids.len();
        let mut g2l: HashMap<Vid, u32> = HashMap::with_capacity(n_solid * 2);
        for (i, &v) in solids.iter().enumerate() {
            g2l.insert(v, i as u32);
        }
        let edges_r = read_pairs(&spill_path(dir, r))?; // sorted, deduped
        directed_edges += edges_r.len() as u64;

        // halos in (src asc, dst asc) discovery order
        let mut vid_o: Vec<Vid> = solids.clone();
        let mut halo_owner: Vec<u32> = Vec::new();
        for &(_, dst) in &edges_r {
            if let std::collections::hash_map::Entry::Vacant(e) = g2l.entry(dst) {
                e.insert(vid_o.len() as u32);
                vid_o.push(dst);
                halo_owner.push(shard_owner(dst, cfg.k, cfg.seed));
            }
        }
        let n_local = vid_o.len();

        // CSR rows: merge walk over ascending solids x ascending edge srcs
        let mut indptr = vec![0u64; n_local + 1];
        let mut indices = vec![0u32; edges_r.len()];
        let mut e = 0usize;
        for (i, &v) in solids.iter().enumerate() {
            let start = e;
            while e < edges_r.len() && edges_r[e].0 == v {
                indices[e] = g2l[&edges_r[e].1];
                e += 1;
            }
            indptr[i + 1] = indptr[i] + (e - start) as u64;
        }
        anyhow::ensure!(e == edges_r.len(), "spill for rank {r} holds non-solid sources");
        for i in n_solid..n_local {
            indptr[i + 1] = indptr[i];
        }

        // full degrees: solids from their own rows, halos from the
        // owners' degree files (mapped, binary-searched)
        let mut full_degree = vec![0u32; n_local];
        for i in 0..n_solid {
            full_degree[i] = (indptr[i + 1] - indptr[i]) as u32;
        }
        let mut deg_maps: HashMap<u32, std::sync::Arc<Mmap>> = HashMap::new();
        for h in 0..n_local - n_solid {
            let owner = halo_owner[h];
            let map = match deg_maps.get(&owner) {
                Some(m) => m.clone(),
                None => {
                    let m = Mmap::map_file(&deg_path(dir, owner as usize))?;
                    deg_maps.insert(owner, m.clone());
                    m
                }
            };
            full_degree[n_solid + h] = deg_lookup(&map, vid_o[n_solid + h]);
        }
        drop(deg_maps);

        let labels: Vec<u32> = solids
            .iter()
            .map(|&v| vertex_label(v, classes, cfg.seed))
            .collect();
        let mut train_vertices: Vec<u32> = Vec::new();
        let mut test_vertices: Vec<u32> = Vec::new();
        for (i, &v) in solids.iter().enumerate() {
            let bucket = (splitmix64(v as u64 ^ cfg.seed.wrapping_add(SALT_SPLIT)) % 1000) as u32;
            if bucket < cfg.train_per_mille {
                train_vertices.push(i as u32);
            } else if bucket < cfg.train_per_mille + cfg.test_per_mille {
                test_vertices.push(i as u32);
            }
        }

        let meta = ShardMeta {
            k: cfg.k as u32,
            rank: r as u32,
            feat_dim: d as u32,
            num_classes: classes as u32,
            dtype: ShardDtype::F32,
            n_solid: n_solid as u64,
            n_local: n_local as u64,
            nnz: edges_r.len() as u64,
            n_train: train_vertices.len() as u64,
            n_test: test_vertices.len() as u64,
        };
        drop(edges_r);
        let file = shard_file_name(r as u32);
        let path = dir.join(&file);
        let mut w = ShardWriter::create(&path, meta, SectionKind::ALL.len())?;
        w.put_u64s(SectionKind::Indptr, &indptr)?;
        w.put_u32s(SectionKind::Indices, &indices)?;
        w.put_u32s(SectionKind::VidO, &vid_o)?;
        w.put_u32s(SectionKind::HaloOwner, &halo_owner)?;
        w.put_u32s(SectionKind::Train, &train_vertices)?;
        w.put_u32s(SectionKind::Test, &test_vertices)?;
        w.put_u32s(SectionKind::Labels, &labels)?;
        w.put_u32s(SectionKind::FullDegree, &full_degree)?;
        // feature rows stream straight to disk: bounded chunks, rows
        // generated in parallel but consumed in order
        w.begin(SectionKind::Features, ShardDtype::F32.elem_size())?;
        const ROWS: usize = 4096;
        let sigma = preset.feat_noise;
        let mut start = 0usize;
        while start < n_solid {
            let m = ROWS.min(n_solid - start);
            let rows: Vec<Vec<f32>> = parallel::parallel_map(m, |i| {
                let v = solids[start + i];
                let label = labels[start + i];
                (0..d)
                    .map(|j| feature_value(v, j, label, d, sigma, cfg.seed))
                    .collect()
            });
            for row in &rows {
                w.chunk(crate::graph::io::scalar_bytes(row))?;
            }
            start += m;
        }
        let crc = w.finish()?;
        bytes_written += std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
        manifest.push_rank_meta(&file, crc, &meta);
        checksums.push(crc);
    }
    manifest.save(dir)?;
    for r in 0..cfg.k {
        std::fs::remove_file(spill_path(dir, r)).ok();
        std::fs::remove_file(deg_path(dir, r)).ok();
    }
    Ok(ShardGenStats {
        n_vertices: n,
        edge_draws: cfg.edges,
        directed_edges,
        checksums,
        bytes_written,
    })
}

/// Naive in-RAM reference of the sharded generator's edge list (property
/// tests compare against this at small scale): the same per-index draws,
/// collected serially.
pub fn rmat_edges_reference(cfg: &ShardGenConfig) -> Vec<(Vid, Vid)> {
    (0..cfg.edges)
        .map(|i| rmat_edge_at(cfg.scale, cfg.rmat, cfg.seed, i))
        .filter(|&(u, v)| u != v)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Csr;

    #[test]
    fn sbm_respects_intra_fraction() {
        let mut rng = Pcg64::seeded(1);
        let comms = skewed_communities(2000, 10, 0.5, &mut rng);
        let edges = sbm_edges(&comms, 10, 20_000, 0.8, &mut rng);
        let intra = edges
            .iter()
            .filter(|(u, v)| comms[*u as usize] == comms[*v as usize])
            .count();
        let frac = intra as f64 / edges.len() as f64;
        assert!(frac > 0.70 && frac < 0.92, "intra fraction {frac}");
    }

    #[test]
    fn rmat_is_skewed() {
        let mut rng = Pcg64::seeded(2);
        let edges = rmat_edges(12, 40_000, (0.57, 0.19, 0.19, 0.05), &mut rng);
        let g = Csr::from_edges(1 << 12, &edges);
        // Power-law: max degree far above the mean.
        assert!(g.max_degree() as f64 > 10.0 * g.mean_degree());
        g.validate().unwrap();
    }

    #[test]
    fn er_edges_in_range() {
        let mut rng = Pcg64::seeded(3);
        let edges = erdos_renyi_edges(100, 500, &mut rng);
        assert!(edges.iter().all(|&(u, v)| (u as usize) < 100 && (v as usize) < 100 && u != v));
    }

    #[test]
    fn communities_cover_all_blocks() {
        let mut rng = Pcg64::seeded(4);
        let comms = skewed_communities(5000, 47, 0.4, &mut rng);
        assert_eq!(comms.len(), 5000);
        let mut seen = vec![false; 47];
        for &c in &comms {
            seen[c as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "some community empty");
        // Skew: biggest community much larger than smallest.
        let mut counts = vec![0usize; 47];
        for &c in &comms {
            counts[c as usize] += 1;
        }
        assert!(counts.iter().max().unwrap() > &(2 * counts.iter().min().unwrap()));
    }

    #[test]
    fn generators_deterministic() {
        let e1 = rmat_edges(8, 100, (0.57, 0.19, 0.19, 0.05), &mut Pcg64::seeded(9));
        let e2 = rmat_edges(8, 100, (0.57, 0.19, 0.19, 0.05), &mut Pcg64::seeded(9));
        assert_eq!(e1, e2);
    }

    fn gen_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("distgnn-gen-test-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn dir_bytes(dir: &Path) -> Vec<(String, Vec<u8>)> {
        let mut files: Vec<String> = std::fs::read_dir(dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .collect();
        files.sort();
        files
            .into_iter()
            .map(|f| {
                let bytes = std::fs::read(dir.join(&f)).unwrap();
                (f, bytes)
            })
            .collect()
    }

    #[test]
    fn sharded_generator_is_bit_deterministic() {
        let cfg = ShardGenConfig::new("tiny", 8, 2000, 3, 42);
        let d1 = gen_dir("det-a");
        let d2 = gen_dir("det-b");
        let s1 = generate_rmat_shards(&cfg, &d1).unwrap();
        let s2 = generate_rmat_shards(&cfg, &d2).unwrap();
        assert_eq!(s1.checksums, s2.checksums);
        assert_eq!(s1.directed_edges, s2.directed_edges);
        let b1 = dir_bytes(&d1);
        assert_eq!(b1, dir_bytes(&d2), "regeneration changed shard bytes");
        // spill/degree temps cleaned up: k shards + the manifest remain
        assert_eq!(b1.len(), cfg.k + 1);
        let _ = std::fs::remove_dir_all(&d1);
        let _ = std::fs::remove_dir_all(&d2);
    }

    #[test]
    fn sharded_generator_matches_reference_graph() {
        let cfg = ShardGenConfig::new("tiny", 7, 1500, 2, 7);
        let dir = gen_dir("ref");
        generate_rmat_shards(&cfg, &dir).unwrap();
        let reference = Csr::from_edges(1 << cfg.scale, &rmat_edges_reference(&cfg));
        let set = crate::graph::io::ShardSet::open(&dir).unwrap();
        let mut seen_directed = 0usize;
        let mut seen_solids = 0usize;
        for r in 0..cfg.k {
            let part = set.load_partition(r, false).unwrap();
            part.validate().unwrap();
            assert_eq!(part.rank, r as u32);
            seen_solids += part.n_solid;
            for i in 0..part.n_solid {
                let g = part.vid_o[i];
                assert_eq!(shard_owner(g, cfg.k, cfg.seed), r as u32);
                let mut row: Vec<Vid> = part
                    .local
                    .neighbors(i as Vid)
                    .iter()
                    .map(|&l| part.vid_o[l as usize])
                    .collect();
                seen_directed += row.len();
                row.sort_unstable();
                assert_eq!(row, reference.neighbors(g), "row of global vertex {g}");
                assert_eq!(part.full_degree[i] as usize, reference.degree(g));
            }
            // halo full degrees come from the owners' degree files; they
            // must agree with the global graph
            for h in 0..part.n_halo() {
                let g = part.vid_o[part.n_solid + h];
                assert_ne!(part.halo_owner[h], r as u32);
                assert_eq!(
                    part.full_degree[part.n_solid + h] as usize,
                    reference.degree(g),
                    "halo degree of {g}"
                );
            }
            // train/test are solid, disjoint, and match the split hash
            let train: std::collections::HashSet<u32> =
                part.train_vertices.iter().copied().collect();
            for &t in part.test_vertices.iter() {
                assert!(!train.contains(&t));
            }
        }
        assert_eq!(seen_solids, 1usize << cfg.scale);
        assert_eq!(seen_directed, reference.num_directed_edges());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sharded_generator_feature_rows_are_pure_functions() {
        let cfg = ShardGenConfig::new("tiny", 6, 400, 2, 11);
        let dir = gen_dir("feat");
        generate_rmat_shards(&cfg, &dir).unwrap();
        let preset = DatasetPreset::by_name(&cfg.preset).unwrap();
        let set = crate::graph::io::ShardSet::open(&dir).unwrap();
        for r in 0..cfg.k {
            let part = set.load_partition(r, true).unwrap();
            assert_eq!(part.feat_dim, preset.feat_dim);
            for i in 0..part.n_solid {
                let v = part.vid_o[i];
                let label = vertex_label(v, preset.num_classes, cfg.seed);
                assert_eq!(part.labels[i], label);
                let expect: Vec<f32> = (0..preset.feat_dim)
                    .map(|j| {
                        feature_value(v, j, label, preset.feat_dim, preset.feat_noise, cfg.seed)
                    })
                    .collect();
                assert_eq!(part.feature_row(i as u32), &expect[..], "features of {v}");
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
