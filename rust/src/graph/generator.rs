//! Synthetic graph generators.
//!
//! Three families, composable by edge-list union:
//! * [`sbm_edges`] — stochastic block model with planted communities. Gives
//!   the node-property-prediction task its signal (labels = communities).
//! * [`rmat_edges`] — recursive-matrix (Kronecker) generator producing the
//!   heavy-tailed degree distribution characteristic of OGBN graphs; this
//!   is what stresses partitioning, halo counts and degree-biased
//!   solid-vertex subsampling.
//! * [`erdos_renyi_edges`] — uniform background noise edges.

use crate::graph::Vid;
use crate::util::rng::Pcg64;

/// SBM: vertices are pre-assigned to `communities.len()` blocks
/// (`communities[v]` = block of v). Emits ~`m` undirected edges; a fraction
/// `p_intra` of them connect two vertices of the same block.
pub fn sbm_edges(
    communities: &[u32],
    num_blocks: usize,
    m: usize,
    p_intra: f64,
    rng: &mut Pcg64,
) -> Vec<(Vid, Vid)> {
    let n = communities.len();
    assert!(n >= 2 && num_blocks >= 1);
    // Bucket vertices by community for fast intra-edge sampling.
    let mut members: Vec<Vec<Vid>> = vec![Vec::new(); num_blocks];
    for (v, &c) in communities.iter().enumerate() {
        members[c as usize].push(v as Vid);
    }
    let mut edges = Vec::with_capacity(m);
    for _ in 0..m {
        if rng.gen_bool(p_intra) {
            // intra-community edge: pick a block weighted by size, then two
            // distinct members.
            let v = rng.gen_range(n) as Vid;
            let block = &members[communities[v as usize] as usize];
            if block.len() < 2 {
                continue;
            }
            let u = block[rng.gen_range(block.len())];
            if u != v {
                edges.push((u, v));
            }
        } else {
            let u = rng.gen_range(n) as Vid;
            let v = rng.gen_range(n) as Vid;
            if u != v {
                edges.push((u, v));
            }
        }
    }
    edges
}

/// R-MAT: emits `m` edges over `2^scale` vertices with quadrant
/// probabilities (a, b, c, d), a + b + c + d = 1. Standard Graph500
/// parameters are (0.57, 0.19, 0.19, 0.05).
pub fn rmat_edges(
    scale: u32,
    m: usize,
    (a, b, c, _d): (f64, f64, f64, f64),
    rng: &mut Pcg64,
) -> Vec<(Vid, Vid)> {
    let mut edges = Vec::with_capacity(m);
    for _ in 0..m {
        let (mut u, mut v) = (0u64, 0u64);
        for _ in 0..scale {
            let r = rng.gen_f64();
            let (bu, bv) = if r < a {
                (0, 0)
            } else if r < a + b {
                (0, 1)
            } else if r < a + b + c {
                (1, 0)
            } else {
                (1, 1)
            };
            u = (u << 1) | bu;
            v = (v << 1) | bv;
        }
        if u != v {
            edges.push((u as Vid, v as Vid));
        }
    }
    edges
}

/// Uniform random edges.
pub fn erdos_renyi_edges(n: usize, m: usize, rng: &mut Pcg64) -> Vec<(Vid, Vid)> {
    let mut edges = Vec::with_capacity(m);
    for _ in 0..m {
        let u = rng.gen_range(n) as Vid;
        let v = rng.gen_range(n) as Vid;
        if u != v {
            edges.push((u, v));
        }
    }
    edges
}

/// Assign `n` vertices to `k` communities with skewed (power-law-ish) sizes,
/// shuffled so community membership is not correlated with vertex id.
pub fn skewed_communities(n: usize, k: usize, skew: f64, rng: &mut Pcg64) -> Vec<u32> {
    // Zipf-like weights w_i = (i+1)^-skew.
    let weights: Vec<f64> = (0..k).map(|i| ((i + 1) as f64).powf(-skew)).collect();
    let total: f64 = weights.iter().sum();
    let mut assign = Vec::with_capacity(n);
    for (i, w) in weights.iter().enumerate() {
        let cnt = ((w / total) * n as f64).round() as usize;
        for _ in 0..cnt {
            assign.push(i as u32);
        }
    }
    while assign.len() < n {
        assign.push(rng.gen_range(k) as u32);
    }
    assign.truncate(n);
    rng.shuffle(&mut assign);
    assign
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Csr;

    #[test]
    fn sbm_respects_intra_fraction() {
        let mut rng = Pcg64::seeded(1);
        let comms = skewed_communities(2000, 10, 0.5, &mut rng);
        let edges = sbm_edges(&comms, 10, 20_000, 0.8, &mut rng);
        let intra = edges
            .iter()
            .filter(|(u, v)| comms[*u as usize] == comms[*v as usize])
            .count();
        let frac = intra as f64 / edges.len() as f64;
        assert!(frac > 0.70 && frac < 0.92, "intra fraction {frac}");
    }

    #[test]
    fn rmat_is_skewed() {
        let mut rng = Pcg64::seeded(2);
        let edges = rmat_edges(12, 40_000, (0.57, 0.19, 0.19, 0.05), &mut rng);
        let g = Csr::from_edges(1 << 12, &edges);
        // Power-law: max degree far above the mean.
        assert!(g.max_degree() as f64 > 10.0 * g.mean_degree());
        g.validate().unwrap();
    }

    #[test]
    fn er_edges_in_range() {
        let mut rng = Pcg64::seeded(3);
        let edges = erdos_renyi_edges(100, 500, &mut rng);
        assert!(edges.iter().all(|&(u, v)| (u as usize) < 100 && (v as usize) < 100 && u != v));
    }

    #[test]
    fn communities_cover_all_blocks() {
        let mut rng = Pcg64::seeded(4);
        let comms = skewed_communities(5000, 47, 0.4, &mut rng);
        assert_eq!(comms.len(), 5000);
        let mut seen = vec![false; 47];
        for &c in &comms {
            seen[c as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "some community empty");
        // Skew: biggest community much larger than smallest.
        let mut counts = vec![0usize; 47];
        for &c in &comms {
            counts[c as usize] += 1;
        }
        assert!(counts.iter().max().unwrap() > &(2 * counts.iter().min().unwrap()));
    }

    #[test]
    fn generators_deterministic() {
        let e1 = rmat_edges(8, 100, (0.57, 0.19, 0.19, 0.05), &mut Pcg64::seeded(9));
        let e2 = rmat_edges(8, 100, (0.57, 0.19, 0.19, 0.05), &mut Pcg64::seeded(9));
        assert_eq!(e1, e2);
    }
}
