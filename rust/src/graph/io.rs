//! Binary dataset serialization: the whole-dataset cache format and the
//! per-rank out-of-core **shard** format.
//!
//! Dataset cache format (little-endian, magic "DGNB"):
//!   u32 magic, u32 version,
//!   u64 n, u64 nnz, u32 feat_dim, u32 num_classes,
//!   u64 n_train, u64 n_test,
//!   name: u32 len + bytes,
//!   indptr[n+1] u64, indices[nnz] u32,
//!   features[n*feat_dim] f32, labels[n] u32,
//!   train[n_train] u32, test[n_test] u32.
//!
//! Generating the mini datasets takes seconds, but partition+cache reuse in
//! benches makes on-disk caching worthwhile.
//!
//! Shard format (magic "DSHD", version 1) — one file per rank holding
//! everything a [`RankPartition`] needs, laid out so the trainer can
//! memory-map it and read CSR rows / feature rows in place:
//!
//! ```text
//!  0: magic u32  version u32  k u32  rank u32
//! 16: feat_dim u32  num_classes u32  dtype u32  n_sections u32
//! 32: n_solid u64  n_local u64  nnz u64  n_train u64  n_test u64
//! 72: section table — n_sections x { kind u32, elem_size u32,
//!                                     offset u64, len_bytes u64 }
//!  +: content_crc u64   (FNV-1a-64 of [payload_start, EOF))
//!  +: header_crc u64    (FNV-1a-64 of every header byte before it,
//!                        which *includes* content_crc — flipping the
//!                        stored checksum is detected even on the lazy
//!                        open path)
//!  payload: sections, each 8-byte aligned
//! ```
//!
//! Robustness contract (same as `model/checkpoint.rs`): writes are
//! atomic (`.tmp` + fsync + rename), and both open paths — eager
//! ([`ShardVerify::Full`], streams the payload through a bounded buffer
//! to check `content_crc` without growing RSS) and lazy
//! ([`ShardVerify::Header`], validates the header, section bounds and
//! alignment only) — return a typed [`ShardError`] for any corrupt
//! input: wrong magic/version, truncation at any boundary, a flipped
//! checksum, an oversized or misaligned section offset. Never a panic.

use std::collections::HashMap;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::graph::{Csr, Dataset, Vid};
use crate::partition::RankPartition;
use crate::util::json::{self, Value};
use crate::util::mmap::{Mmap, Storage};

const MAGIC: u32 = 0x4247_4e44; // "DNGB" little-endian-ish tag
const VERSION: u32 = 1;

struct Writer<W: Write>(W);

impl<W: Write> Writer<W> {
    fn u32(&mut self, v: u32) -> Result<()> {
        self.0.write_all(&v.to_le_bytes())?;
        Ok(())
    }
    fn u64(&mut self, v: u64) -> Result<()> {
        self.0.write_all(&v.to_le_bytes())?;
        Ok(())
    }
    fn u32s(&mut self, vs: &[u32]) -> Result<()> {
        for &v in vs {
            self.u32(v)?;
        }
        Ok(())
    }
    fn u64s(&mut self, vs: &[u64]) -> Result<()> {
        for &v in vs {
            self.u64(v)?;
        }
        Ok(())
    }
    fn f32s(&mut self, vs: &[f32]) -> Result<()> {
        for &v in vs {
            self.0.write_all(&v.to_le_bytes())?;
        }
        Ok(())
    }
}

struct Reader<R: Read>(R);

impl<R: Read> Reader<R> {
    fn u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        self.0.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }
    fn u64(&mut self) -> Result<u64> {
        let mut b = [0u8; 8];
        self.0.read_exact(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }
    fn u32s(&mut self, n: usize) -> Result<Vec<u32>> {
        let mut bytes = vec![0u8; n * 4];
        self.0.read_exact(&mut bytes)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
    fn u64s(&mut self, n: usize) -> Result<Vec<u64>> {
        let mut bytes = vec![0u8; n * 8];
        self.0.read_exact(&mut bytes)?;
        Ok(bytes
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let mut bytes = vec![0u8; n * 4];
        self.0.read_exact(&mut bytes)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// Save a dataset to a binary file.
pub fn save(ds: &Dataset, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {}", path.as_ref().display()))?;
    let mut w = Writer(BufWriter::new(f));
    w.u32(MAGIC)?;
    w.u32(VERSION)?;
    let n = ds.num_vertices() as u64;
    w.u64(n)?;
    w.u64(ds.graph.indices.len() as u64)?;
    w.u32(ds.feat_dim as u32)?;
    w.u32(ds.num_classes as u32)?;
    w.u64(ds.train_vertices.len() as u64)?;
    w.u64(ds.test_vertices.len() as u64)?;
    w.u32(ds.name.len() as u32)?;
    w.0.write_all(ds.name.as_bytes())?;
    w.u64s(&ds.graph.indptr)?;
    w.u32s(&ds.graph.indices)?;
    w.f32s(&ds.features)?;
    w.u32s(&ds.labels)?;
    w.u32s(&ds.train_vertices)?;
    w.u32s(&ds.test_vertices)?;
    w.0.flush()?;
    Ok(())
}

/// Load a dataset from a binary file.
pub fn load(path: impl AsRef<Path>) -> Result<Dataset> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    let mut r = Reader(BufReader::new(f));
    if r.u32()? != MAGIC {
        bail!("bad magic (not a DistGNN-MB dataset file)");
    }
    if r.u32()? != VERSION {
        bail!("unsupported dataset file version");
    }
    let n = r.u64()? as usize;
    let nnz = r.u64()? as usize;
    let feat_dim = r.u32()? as usize;
    let num_classes = r.u32()? as usize;
    let n_train = r.u64()? as usize;
    let n_test = r.u64()? as usize;
    let name_len = r.u32()? as usize;
    let mut name_bytes = vec![0u8; name_len];
    r.0.read_exact(&mut name_bytes)?;
    let name = String::from_utf8(name_bytes)?;
    let indptr = r.u64s(n + 1)?;
    let indices = r.u32s(nnz)?;
    let features = r.f32s(n * feat_dim)?;
    let labels = r.u32s(n)?;
    let train_vertices = r.u32s(n_train)?;
    let test_vertices = r.u32s(n_test)?;
    let ds = Dataset {
        name,
        graph: Csr {
            indptr: indptr.into(),
            indices: indices.into(),
        },
        features,
        feat_dim,
        labels,
        num_classes,
        train_vertices,
        test_vertices,
    };
    ds.validate().context("loaded dataset fails validation")?;
    Ok(ds)
}

/// Load from cache or generate + save.
pub fn load_or_generate(
    preset: &crate::graph::DatasetPreset,
    cache_dir: impl AsRef<Path>,
) -> Result<Dataset> {
    let path = cache_dir
        .as_ref()
        .join(format!("{}-{:x}.dgnb", preset.name, preset.seed));
    if path.exists() {
        if let Ok(ds) = load(&path) {
            return Ok(ds);
        }
    }
    let ds = preset.generate();
    std::fs::create_dir_all(cache_dir.as_ref()).ok();
    save(&ds, &path).ok(); // cache failure is not fatal
    Ok(ds)
}

// ---------------------------------------------------------------------------
// Out-of-core shard format
// ---------------------------------------------------------------------------

pub const SHARD_MAGIC: u32 = 0x4448_5344; // "DSHD"
pub const SHARD_VERSION: u32 = 1;
/// Fixed header bytes before the section table.
const SHARD_FIXED: usize = 72;
/// Bytes per section-table entry.
const SECTION_ENTRY: usize = 24;
/// Sanity cap on the section count (the format defines 9 kinds).
const MAX_SECTIONS: usize = 32;

/// Typed error for a structurally invalid or corrupt shard file or
/// manifest. I/O failures (missing file, permissions) surface as ordinary
/// errors; `ShardError` means the bytes themselves are wrong.
#[derive(Debug)]
pub struct ShardError(pub String);

impl std::fmt::Display for ShardError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "invalid shard: {}", self.0)
    }
}

impl std::error::Error for ShardError {}

fn shard_corrupt<T>(msg: impl Into<String>) -> Result<T> {
    Err(anyhow::Error::new(ShardError(msg.into())))
}

/// Streaming FNV-1a-64 (the checkpoint format's checksum, reused so one
/// corruption-detection contract covers both file families).
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }
    fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
}

/// Feature-block element type of a shard.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardDtype {
    F32,
    Bf16,
}

impl ShardDtype {
    pub fn code(self) -> u32 {
        match self {
            ShardDtype::F32 => 0,
            ShardDtype::Bf16 => 1,
        }
    }
    pub fn elem_size(self) -> u32 {
        match self {
            ShardDtype::F32 => 4,
            ShardDtype::Bf16 => 2,
        }
    }
    fn from_code(c: u32) -> Result<ShardDtype> {
        match c {
            0 => Ok(ShardDtype::F32),
            1 => Ok(ShardDtype::Bf16),
            _ => shard_corrupt(format!("unknown feature dtype code {c}")),
        }
    }
}

/// Section kinds, in canonical file order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SectionKind {
    Indptr,
    Indices,
    VidO,
    HaloOwner,
    Train,
    Test,
    Labels,
    FullDegree,
    Features,
}

impl SectionKind {
    pub const ALL: [SectionKind; 9] = [
        SectionKind::Indptr,
        SectionKind::Indices,
        SectionKind::VidO,
        SectionKind::HaloOwner,
        SectionKind::Train,
        SectionKind::Test,
        SectionKind::Labels,
        SectionKind::FullDegree,
        SectionKind::Features,
    ];
    pub fn code(self) -> u32 {
        match self {
            SectionKind::Indptr => 1,
            SectionKind::Indices => 2,
            SectionKind::VidO => 3,
            SectionKind::HaloOwner => 4,
            SectionKind::Train => 5,
            SectionKind::Test => 6,
            SectionKind::Labels => 7,
            SectionKind::FullDegree => 8,
            SectionKind::Features => 9,
        }
    }
    fn from_code(c: u32) -> Result<SectionKind> {
        Self::ALL
            .iter()
            .copied()
            .find(|k| k.code() == c)
            .map_or_else(|| shard_corrupt(format!("unknown section kind {c}")), Ok)
    }
    /// Element size this kind must carry (`None`: dtype-dependent).
    fn fixed_elem_size(self) -> Option<u32> {
        match self {
            SectionKind::Indptr => Some(8),
            SectionKind::Features => None,
            _ => Some(4),
        }
    }
}

/// Shape metadata carried in every shard header.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardMeta {
    pub k: u32,
    pub rank: u32,
    pub feat_dim: u32,
    pub num_classes: u32,
    pub dtype: ShardDtype,
    pub n_solid: u64,
    pub n_local: u64,
    pub nnz: u64,
    pub n_train: u64,
    pub n_test: u64,
}

impl ShardMeta {
    /// Expected byte length of each section, from the header shapes — the
    /// cross-check that makes a lying section table a typed error.
    fn expected_len(&self, kind: SectionKind) -> u64 {
        match kind {
            SectionKind::Indptr => (self.n_local + 1) * 8,
            SectionKind::Indices => self.nnz * 4,
            SectionKind::VidO => self.n_local * 4,
            SectionKind::HaloOwner => (self.n_local - self.n_solid) * 4,
            SectionKind::Train => self.n_train * 4,
            SectionKind::Test => self.n_test * 4,
            SectionKind::Labels => self.n_solid * 4,
            SectionKind::FullDegree => self.n_local * 4,
            SectionKind::Features => {
                self.n_solid * self.feat_dim as u64 * self.dtype.elem_size() as u64
            }
        }
    }
}

#[derive(Clone, Copy, Debug)]
struct SectionEntry {
    kind: SectionKind,
    elem_size: u32,
    offset: u64,
    len_bytes: u64,
}

/// Canonical shard file name for a rank.
pub fn shard_file_name(rank: u32) -> String {
    format!("shard-r{rank}.dshd")
}

/// Streaming shard writer: sections are appended (whole or in chunks —
/// a billion-edge feature block never needs to be resident), the header
/// with both checksums is written last, and the rename is atomic.
pub struct ShardWriter {
    w: BufWriter<std::fs::File>,
    tmp: PathBuf,
    path: PathBuf,
    meta: ShardMeta,
    n_sections: usize,
    sections: Vec<SectionEntry>,
    crc: Fnv,
    pos: u64,
    cur: Option<(SectionKind, u32, u64)>,
}

impl ShardWriter {
    /// Open `path.tmp` and reserve a zero-filled header region sized for
    /// `n_sections` sections.
    pub fn create(path: &Path, meta: ShardMeta, n_sections: usize) -> Result<ShardWriter> {
        anyhow::ensure!(n_sections <= MAX_SECTIONS, "too many sections");
        let tmp = path.with_file_name(format!(
            "{}.tmp",
            path.file_name()
                .map(|n| n.to_string_lossy().to_string())
                .unwrap_or_else(|| "shard".into())
        ));
        let f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        let mut w = BufWriter::new(f);
        let header_len = SHARD_FIXED + n_sections * SECTION_ENTRY + 16;
        w.write_all(&vec![0u8; header_len])?;
        Ok(ShardWriter {
            w,
            tmp,
            path: path.to_path_buf(),
            meta,
            n_sections,
            sections: Vec::with_capacity(n_sections),
            crc: Fnv::new(),
            pos: header_len as u64,
            cur: None,
        })
    }

    fn close_section(&mut self) {
        if let Some((kind, elem_size, start)) = self.cur.take() {
            self.sections.push(SectionEntry {
                kind,
                elem_size,
                offset: start,
                len_bytes: self.pos - start,
            });
        }
    }

    /// Start a new section (closing any open one). Pads to 8-byte
    /// alignment first; padding bytes count toward the content checksum.
    pub fn begin(&mut self, kind: SectionKind, elem_size: u32) -> Result<()> {
        self.close_section();
        let pad = (8 - (self.pos % 8) as usize) % 8;
        if pad > 0 {
            let zeros = [0u8; 8];
            self.w.write_all(&zeros[..pad])?;
            self.crc.update(&zeros[..pad]);
            self.pos += pad as u64;
        }
        self.cur = Some((kind, elem_size, self.pos));
        Ok(())
    }

    /// Append raw bytes to the open section.
    pub fn chunk(&mut self, bytes: &[u8]) -> Result<()> {
        debug_assert!(self.cur.is_some(), "chunk() outside a section");
        self.w.write_all(bytes)?;
        self.crc.update(bytes);
        self.pos += bytes.len() as u64;
        Ok(())
    }

    pub fn put_u32s(&mut self, kind: SectionKind, vs: &[u32]) -> Result<()> {
        self.begin(kind, 4)?;
        self.chunk(scalar_bytes(vs))
    }

    pub fn put_u64s(&mut self, kind: SectionKind, vs: &[u64]) -> Result<()> {
        self.begin(kind, 8)?;
        self.chunk(scalar_bytes(vs))
    }

    pub fn put_f32s(&mut self, kind: SectionKind, vs: &[f32]) -> Result<()> {
        self.begin(kind, 4)?;
        self.chunk(scalar_bytes(vs))
    }

    pub fn put_u16s(&mut self, kind: SectionKind, vs: &[u16]) -> Result<()> {
        self.begin(kind, 2)?;
        self.chunk(scalar_bytes(vs))
    }

    /// Close the last section, write the header (both checksums), fsync
    /// and atomically rename into place. Returns the content checksum.
    pub fn finish(mut self) -> Result<u64> {
        self.close_section();
        anyhow::ensure!(
            self.sections.len() == self.n_sections,
            "shard writer planned {} sections, wrote {}",
            self.n_sections,
            self.sections.len()
        );
        let content_crc = self.crc.0;
        let mut h = Vec::with_capacity(SHARD_FIXED + self.n_sections * SECTION_ENTRY + 16);
        h.extend_from_slice(&SHARD_MAGIC.to_le_bytes());
        h.extend_from_slice(&SHARD_VERSION.to_le_bytes());
        h.extend_from_slice(&self.meta.k.to_le_bytes());
        h.extend_from_slice(&self.meta.rank.to_le_bytes());
        h.extend_from_slice(&self.meta.feat_dim.to_le_bytes());
        h.extend_from_slice(&self.meta.num_classes.to_le_bytes());
        h.extend_from_slice(&self.meta.dtype.code().to_le_bytes());
        h.extend_from_slice(&(self.n_sections as u32).to_le_bytes());
        h.extend_from_slice(&self.meta.n_solid.to_le_bytes());
        h.extend_from_slice(&self.meta.n_local.to_le_bytes());
        h.extend_from_slice(&self.meta.nnz.to_le_bytes());
        h.extend_from_slice(&self.meta.n_train.to_le_bytes());
        h.extend_from_slice(&self.meta.n_test.to_le_bytes());
        for s in &self.sections {
            h.extend_from_slice(&s.kind.code().to_le_bytes());
            h.extend_from_slice(&s.elem_size.to_le_bytes());
            h.extend_from_slice(&s.offset.to_le_bytes());
            h.extend_from_slice(&s.len_bytes.to_le_bytes());
        }
        h.extend_from_slice(&content_crc.to_le_bytes());
        let mut hcrc = Fnv::new();
        hcrc.update(&h);
        h.extend_from_slice(&hcrc.0.to_le_bytes());

        self.w.flush()?;
        let mut f = self
            .w
            .into_inner()
            .map_err(|e| anyhow::anyhow!("flushing shard writer: {e}"))?;
        f.seek(SeekFrom::Start(0))?;
        f.write_all(&h)?;
        f.sync_all()
            .with_context(|| format!("fsync {}", self.tmp.display()))?;
        drop(f);
        std::fs::rename(&self.tmp, &self.path).with_context(|| {
            format!("renaming {} -> {}", self.tmp.display(), self.path.display())
        })?;
        Ok(content_crc)
    }
}

/// Little-endian byte view of a scalar slice (host is little-endian on
/// every supported target; the dataset cache format makes the same
/// assumption).
pub(crate) fn scalar_bytes<T: crate::util::mmap::Scalar>(vs: &[T]) -> &[u8] {
    unsafe {
        std::slice::from_raw_parts(
            vs.as_ptr() as *const u8,
            std::mem::size_of_val(vs),
        )
    }
}

/// How much of a shard file to verify at open time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardVerify {
    /// Header checksum + section bounds/alignment only (lazy path — the
    /// payload is validated structurally, its bytes are trusted until
    /// read; cost is O(header)).
    Header,
    /// Additionally stream the payload through a bounded buffer and check
    /// `content_crc` (eager path — O(file) reads, O(1) memory).
    Full,
}

/// An open, validated shard file: header metadata plus a shared mapping
/// the typed section accessors slice into.
pub struct ShardFile {
    pub meta: ShardMeta,
    pub content_crc: u64,
    pub path: PathBuf,
    sections: Vec<SectionEntry>,
    map: Arc<Mmap>,
}

impl ShardFile {
    pub fn open(path: &Path, verify: ShardVerify) -> Result<ShardFile> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let file_len = f
            .metadata()
            .with_context(|| format!("stat {}", path.display()))?
            .len();
        let max_header = SHARD_FIXED + MAX_SECTIONS * SECTION_ENTRY + 16;
        let mut head = vec![0u8; (file_len as usize).min(max_header)];
        f.read_exact(&mut head)
            .with_context(|| format!("reading header of {}", path.display()))?;
        let (meta, sections, content_crc, payload_start) =
            parse_shard_header(&head, file_len)?;
        if verify == ShardVerify::Full {
            f.seek(SeekFrom::Start(payload_start))?;
            let mut crc = Fnv::new();
            let mut buf = vec![0u8; 1 << 20];
            loop {
                let n = f.read(&mut buf)?;
                if n == 0 {
                    break;
                }
                crc.update(&buf[..n]);
            }
            if crc.0 != content_crc {
                return shard_corrupt(format!(
                    "content checksum mismatch in {} (stored {content_crc:#018x}, \
                     computed {:#018x}) — the payload is corrupt",
                    path.display(),
                    crc.0
                ));
            }
        }
        drop(f);
        let map = Mmap::map_file(path)?;
        // the file could have been swapped between validation and mapping
        if (map.len() as u64) != file_len {
            return shard_corrupt(format!(
                "{} changed size while opening",
                path.display()
            ));
        }
        Ok(ShardFile {
            meta,
            content_crc,
            path: path.to_path_buf(),
            sections,
            map,
        })
    }

    fn section(&self, kind: SectionKind) -> Result<&SectionEntry> {
        self.sections
            .iter()
            .find(|s| s.kind == kind)
            .ok_or_else(|| {
                anyhow::Error::new(ShardError(format!(
                    "section {kind:?} missing from {}",
                    self.path.display()
                )))
            })
    }

    fn storage<T: crate::util::mmap::Scalar>(
        &self,
        kind: SectionKind,
    ) -> Result<Storage<T>> {
        let s = self.section(kind)?;
        let elem = std::mem::size_of::<T>() as u64;
        anyhow::ensure!(
            s.elem_size as u64 == elem,
            "section {kind:?} holds {}-byte elements, asked for {elem}",
            s.elem_size
        );
        Storage::mapped(
            self.map.clone(),
            s.offset as usize,
            (s.len_bytes / elem) as usize,
        )
    }

    pub fn u64s(&self, kind: SectionKind) -> Result<Storage<u64>> {
        self.storage(kind)
    }
    pub fn u32s(&self, kind: SectionKind) -> Result<Storage<u32>> {
        self.storage(kind)
    }
    pub fn u16s(&self, kind: SectionKind) -> Result<Storage<u16>> {
        self.storage(kind)
    }
    pub fn f32s(&self, kind: SectionKind) -> Result<Storage<f32>> {
        self.storage(kind)
    }

    /// Raw payload bytes (page-touch / stall measurement helper).
    pub fn payload_bytes(&self) -> &[u8] {
        let start = SHARD_FIXED + self.sections.len() * SECTION_ENTRY + 16;
        &self.map.as_bytes()[start.min(self.map.len())..]
    }

    /// Reconstruct this shard's [`RankPartition`]. With `mapped` the
    /// array fields view the file in place; otherwise every section is
    /// copied to RAM (the in-RAM comparator residency mode — identical
    /// bytes either way). bf16 feature blocks are expanded to f32 on
    /// load, so the training path is dtype-agnostic.
    pub fn load_partition(&self, mapped: bool) -> Result<RankPartition> {
        let m = &self.meta;
        let maybe_ram = |s: Storage<u32>| if mapped { s } else { s.to_ram() };
        let indptr = self.u64s(SectionKind::Indptr)?;
        let indptr = if mapped { indptr } else { indptr.to_ram() };
        let vid_o = maybe_ram(self.u32s(SectionKind::VidO)?);
        let features: Storage<f32> = match m.dtype {
            ShardDtype::F32 => {
                let s = self.f32s(SectionKind::Features)?;
                if mapped {
                    s
                } else {
                    s.to_ram()
                }
            }
            ShardDtype::Bf16 => {
                let packed = self.u16s(SectionKind::Features)?;
                crate::runtime::bf16::unpack_slice(&packed).into()
            }
        };
        let global_to_local = crate::partition::rebuild_global_to_local(&vid_o);
        let part = RankPartition {
            rank: m.rank,
            k: m.k as usize,
            local: Csr {
                indptr,
                indices: maybe_ram(self.u32s(SectionKind::Indices)?),
            },
            n_solid: m.n_solid as usize,
            vid_o,
            global_to_local,
            halo_owner: maybe_ram(self.u32s(SectionKind::HaloOwner)?),
            train_vertices: maybe_ram(self.u32s(SectionKind::Train)?),
            test_vertices: maybe_ram(self.u32s(SectionKind::Test)?),
            features,
            feat_dim: m.feat_dim as usize,
            labels: maybe_ram(self.u32s(SectionKind::Labels)?),
            full_degree: maybe_ram(self.u32s(SectionKind::FullDegree)?),
        };
        part.validate()
            .with_context(|| format!("shard {} fails partition validation", self.path.display()))?;
        Ok(part)
    }
}

fn parse_shard_header(
    head: &[u8],
    file_len: u64,
) -> Result<(ShardMeta, Vec<SectionEntry>, u64, u64)> {
    if head.len() < SHARD_FIXED {
        return shard_corrupt(format!(
            "file is {} bytes, too short for a shard header",
            head.len()
        ));
    }
    let u32_at = |off: usize| u32::from_le_bytes(head[off..off + 4].try_into().unwrap());
    let u64_at = |off: usize| u64::from_le_bytes(head[off..off + 8].try_into().unwrap());
    if u32_at(0) != SHARD_MAGIC {
        return shard_corrupt("not a DistGNN-MB shard (bad magic)");
    }
    let version = u32_at(4);
    if version != SHARD_VERSION {
        return shard_corrupt(format!(
            "unsupported shard version {version} (this build reads version {SHARD_VERSION})"
        ));
    }
    let n_sections = u32_at(28) as usize;
    if n_sections > MAX_SECTIONS {
        return shard_corrupt(format!("section count {n_sections} exceeds the format cap"));
    }
    let header_end = SHARD_FIXED + n_sections * SECTION_ENTRY + 16;
    if head.len() < header_end {
        return shard_corrupt(format!(
            "truncated header: {} bytes, need {header_end}",
            head.len()
        ));
    }
    let mut hcrc = Fnv::new();
    hcrc.update(&head[..header_end - 8]);
    let stored_hcrc = u64_at(header_end - 8);
    if hcrc.0 != stored_hcrc {
        return shard_corrupt(format!(
            "header checksum mismatch (stored {stored_hcrc:#018x}, computed {:#018x})",
            hcrc.0
        ));
    }
    let meta = ShardMeta {
        k: u32_at(8),
        rank: u32_at(12),
        feat_dim: u32_at(16),
        num_classes: u32_at(20),
        dtype: ShardDtype::from_code(u32_at(24))?,
        n_solid: u64_at(32),
        n_local: u64_at(40),
        nnz: u64_at(48),
        n_train: u64_at(56),
        n_test: u64_at(64),
    };
    if meta.n_solid > meta.n_local {
        return shard_corrupt(format!(
            "n_solid {} exceeds n_local {}",
            meta.n_solid, meta.n_local
        ));
    }
    if meta.k == 0 || meta.rank >= meta.k {
        return shard_corrupt(format!("rank {} out of range for k {}", meta.rank, meta.k));
    }
    let content_crc = u64_at(header_end - 16);
    let payload_start = header_end as u64;
    let mut sections = Vec::with_capacity(n_sections);
    let mut seen = 0u32;
    for i in 0..n_sections {
        let off = SHARD_FIXED + i * SECTION_ENTRY;
        let kind = SectionKind::from_code(u32_at(off))?;
        let elem_size = u32_at(off + 4);
        let offset = u64_at(off + 8);
        let len_bytes = u64_at(off + 16);
        let want_elem = kind
            .fixed_elem_size()
            .unwrap_or_else(|| meta.dtype.elem_size());
        if elem_size != want_elem {
            return shard_corrupt(format!(
                "section {kind:?} declares {elem_size}-byte elements, format requires {want_elem}"
            ));
        }
        if offset < payload_start || offset % 8 != 0 {
            return shard_corrupt(format!(
                "section {kind:?} offset {offset} is outside the payload or misaligned"
            ));
        }
        let end = offset.checked_add(len_bytes).ok_or_else(|| {
            anyhow::Error::new(ShardError(format!(
                "section {kind:?} range overflows"
            )))
        })?;
        if end > file_len {
            return shard_corrupt(format!(
                "section {kind:?} [{offset}, {end}) exceeds file size {file_len}"
            ));
        }
        if len_bytes % elem_size as u64 != 0 {
            return shard_corrupt(format!(
                "section {kind:?} length {len_bytes} is not a multiple of its element size"
            ));
        }
        let want_len = meta.expected_len(kind);
        if len_bytes != want_len {
            return shard_corrupt(format!(
                "section {kind:?} holds {len_bytes} bytes, header shapes imply {want_len}"
            ));
        }
        let bit = 1u32 << kind.code();
        if seen & bit != 0 {
            return shard_corrupt(format!("duplicate section {kind:?}"));
        }
        seen |= bit;
        sections.push(SectionEntry {
            kind,
            elem_size,
            offset,
            len_bytes,
        });
    }
    for kind in SectionKind::ALL {
        if seen & (1u32 << kind.code()) == 0 {
            return shard_corrupt(format!("required section {kind:?} missing"));
        }
    }
    Ok((meta, sections, content_crc, payload_start))
}

/// Write one rank's partition as a shard file. Returns the content
/// checksum (recorded in the shard-set manifest and in checkpoints that
/// bind to this set).
pub fn write_shard_from_partition(
    path: &Path,
    part: &RankPartition,
    num_classes: u32,
) -> Result<u64> {
    let meta = ShardMeta {
        k: part.k as u32,
        rank: part.rank,
        feat_dim: part.feat_dim as u32,
        num_classes,
        dtype: ShardDtype::F32,
        n_solid: part.n_solid as u64,
        n_local: part.n_local() as u64,
        nnz: part.local.indices.len() as u64,
        n_train: part.train_vertices.len() as u64,
        n_test: part.test_vertices.len() as u64,
    };
    let mut w = ShardWriter::create(path, meta, SectionKind::ALL.len())?;
    w.put_u64s(SectionKind::Indptr, &part.local.indptr)?;
    w.put_u32s(SectionKind::Indices, &part.local.indices)?;
    w.put_u32s(SectionKind::VidO, &part.vid_o)?;
    w.put_u32s(SectionKind::HaloOwner, &part.halo_owner)?;
    w.put_u32s(SectionKind::Train, &part.train_vertices)?;
    w.put_u32s(SectionKind::Test, &part.test_vertices)?;
    w.put_u32s(SectionKind::Labels, &part.labels)?;
    w.put_u32s(SectionKind::FullDegree, &part.full_degree)?;
    w.put_f32s(SectionKind::Features, &part.features)?;
    w.finish()
}

/// Per-rank entry of a shard-set manifest.
#[derive(Clone, Debug)]
pub struct ShardRankEntry {
    pub file: String,
    pub checksum: u64,
    pub n_solid: u64,
    pub n_local: u64,
    pub nnz: u64,
    pub n_train: u64,
    pub n_test: u64,
}

/// The `shards.json` manifest tying a directory of per-rank shard files
/// into one openable set: provenance (preset, seed, partitioner),
/// shapes, and every rank's file name + content checksum (stored as hex
/// strings — u64 checksums exceed JSON's exact-f64 range).
#[derive(Clone, Debug)]
pub struct ShardManifest {
    pub preset: String,
    pub k: usize,
    pub seed: u64,
    pub partitioner: String,
    pub feat_dim: u32,
    pub num_classes: u32,
    pub dtype: ShardDtype,
    pub ranks: Vec<ShardRankEntry>,
}

pub const SHARD_MANIFEST: &str = "shards.json";

impl ShardManifest {
    pub fn new(preset: &str, k: usize, seed: u64, partitioner: &str) -> ShardManifest {
        ShardManifest {
            preset: preset.to_string(),
            k,
            seed,
            partitioner: partitioner.to_string(),
            feat_dim: 0,
            num_classes: 0,
            dtype: ShardDtype::F32,
            ranks: Vec::new(),
        }
    }

    pub fn push_rank(&mut self, file: &str, checksum: u64, part: &RankPartition) {
        self.ranks.push(ShardRankEntry {
            file: file.to_string(),
            checksum,
            n_solid: part.n_solid as u64,
            n_local: part.n_local() as u64,
            nnz: part.local.indices.len() as u64,
            n_train: part.train_vertices.len() as u64,
            n_test: part.test_vertices.len() as u64,
        });
    }

    pub fn push_rank_meta(&mut self, file: &str, checksum: u64, meta: &ShardMeta) {
        self.ranks.push(ShardRankEntry {
            file: file.to_string(),
            checksum,
            n_solid: meta.n_solid,
            n_local: meta.n_local,
            nnz: meta.nnz,
            n_train: meta.n_train,
            n_test: meta.n_test,
        });
    }

    fn to_value(&self) -> Value {
        json::obj(vec![
            ("format_version", json::num(1.0)),
            ("preset", json::s(&self.preset)),
            ("k", json::num(self.k as f64)),
            ("seed", json::num(self.seed as f64)),
            ("partitioner", json::s(&self.partitioner)),
            ("feat_dim", json::num(self.feat_dim as f64)),
            ("num_classes", json::num(self.num_classes as f64)),
            ("dtype", json::num(self.dtype.code() as f64)),
            (
                "ranks",
                json::arr(
                    self.ranks
                        .iter()
                        .map(|r| {
                            json::obj(vec![
                                ("file", json::s(&r.file)),
                                ("checksum", json::s(&format!("{:016x}", r.checksum))),
                                ("n_solid", json::num(r.n_solid as f64)),
                                ("n_local", json::num(r.n_local as f64)),
                                ("nnz", json::num(r.nnz as f64)),
                                ("n_train", json::num(r.n_train as f64)),
                                ("n_test", json::num(r.n_test as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Atomically write `dir/shards.json` (written last by every shard
    /// producer, so a set missing its manifest is by construction
    /// incomplete and will not open).
    pub fn save(&self, dir: &Path) -> Result<()> {
        let path = dir.join(SHARD_MANIFEST);
        let tmp = dir.join(format!("{SHARD_MANIFEST}.tmp"));
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(self.to_value().to_json_pretty().as_bytes())?;
        f.sync_all()?;
        drop(f);
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))?;
        Ok(())
    }

    pub fn load(dir: &Path) -> Result<ShardManifest> {
        let path = dir.join(SHARD_MANIFEST);
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "opening shard manifest {} (is this a shard directory?)",
                path.display()
            )
        })?;
        let v = match json::parse(&text) {
            Ok(v) => v,
            Err(e) => return shard_corrupt(format!("manifest is not valid JSON: {e}")),
        };
        let fv = v.req_usize("format_version").map_err(typed)?;
        if fv != 1 {
            return shard_corrupt(format!("unsupported manifest format_version {fv}"));
        }
        let k = v.req_usize("k").map_err(typed)?;
        let mut m = ShardManifest {
            preset: v.req_str("preset").map_err(typed)?.to_string(),
            k,
            seed: v.req_usize("seed").map_err(typed)? as u64,
            partitioner: v.req_str("partitioner").map_err(typed)?.to_string(),
            feat_dim: v.req_usize("feat_dim").map_err(typed)? as u32,
            num_classes: v.req_usize("num_classes").map_err(typed)? as u32,
            dtype: ShardDtype::from_code(v.req_usize("dtype").map_err(typed)? as u32)?,
            ranks: Vec::new(),
        };
        for r in v.req_arr("ranks").map_err(typed)? {
            let hex = r.req_str("checksum").map_err(typed)?;
            let checksum = match u64::from_str_radix(hex, 16) {
                Ok(c) => c,
                Err(_) => {
                    return shard_corrupt(format!("manifest checksum '{hex}' is not hex"))
                }
            };
            m.ranks.push(ShardRankEntry {
                file: r.req_str("file").map_err(typed)?.to_string(),
                checksum,
                n_solid: r.req_usize("n_solid").map_err(typed)? as u64,
                n_local: r.req_usize("n_local").map_err(typed)? as u64,
                nnz: r.req_usize("nnz").map_err(typed)? as u64,
                n_train: r.req_usize("n_train").map_err(typed)? as u64,
                n_test: r.req_usize("n_test").map_err(typed)? as u64,
            });
        }
        if m.ranks.len() != k {
            return shard_corrupt(format!(
                "manifest lists {} rank entries for k {}",
                m.ranks.len(),
                k
            ));
        }
        Ok(m)
    }
}

/// Wrap a structural manifest error as a typed [`ShardError`].
fn typed(e: anyhow::Error) -> anyhow::Error {
    anyhow::Error::new(ShardError(format!("manifest: {e}")))
}

/// An opened shard directory: the validated manifest plus accessors that
/// cross-check every shard file against it before handing data out.
pub struct ShardSet {
    pub dir: PathBuf,
    pub manifest: ShardManifest,
}

impl ShardSet {
    pub fn open(dir: impl AsRef<Path>) -> Result<ShardSet> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = ShardManifest::load(&dir)?;
        for r in &manifest.ranks {
            let p = dir.join(&r.file);
            anyhow::ensure!(
                p.exists(),
                "shard file {} named by the manifest does not exist",
                p.display()
            );
        }
        Ok(ShardSet { dir, manifest })
    }

    pub fn k(&self) -> usize {
        self.manifest.k
    }

    /// Per-rank local train-seed counts (what the driver needs to compute
    /// every rank's minibatch count without loading remote shards).
    pub fn train_counts(&self) -> Vec<usize> {
        self.manifest.ranks.iter().map(|r| r.n_train as usize).collect()
    }

    /// Per-rank content checksums (the identity a checkpoint binds to).
    pub fn checksums(&self) -> Vec<u64> {
        self.manifest.ranks.iter().map(|r| r.checksum).collect()
    }

    /// Open one rank's shard, cross-checking its header against the
    /// manifest (rank id, shard count, content checksum) — a swapped or
    /// regenerated file is a typed error even on the lazy path.
    pub fn open_shard(&self, rank: usize, verify: ShardVerify) -> Result<ShardFile> {
        let entry = self.manifest.ranks.get(rank).ok_or_else(|| {
            anyhow::Error::new(ShardError(format!(
                "rank {rank} out of range for a {}-shard set",
                self.manifest.k
            )))
        })?;
        let sf = ShardFile::open(&self.dir.join(&entry.file), verify)?;
        if sf.meta.rank as usize != rank || sf.meta.k as u32 != self.manifest.k as u32 {
            return shard_corrupt(format!(
                "{} header says rank {}/{} but the manifest placed it at rank {rank}/{}",
                entry.file, sf.meta.rank, sf.meta.k, self.manifest.k
            ));
        }
        if sf.content_crc != entry.checksum {
            return shard_corrupt(format!(
                "{} content checksum {:016x} does not match the manifest's {:016x} — \
                 the shard set was modified after the manifest was written",
                entry.file, sf.content_crc, entry.checksum
            ));
        }
        Ok(sf)
    }

    /// Load one rank's partition (`mapped`: arrays view the file;
    /// otherwise RAM copies — the bit-identity comparator mode).
    pub fn load_partition(&self, rank: usize, mapped: bool) -> Result<RankPartition> {
        self.open_shard(rank, ShardVerify::Header)?.load_partition(mapped)
    }

    /// Eagerly verify every shard's content checksum (CI smoke / fsck).
    pub fn verify_all(&self) -> Result<()> {
        for rank in 0..self.manifest.k {
            self.open_shard(rank, ShardVerify::Full)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DatasetPreset;

    #[test]
    fn roundtrip_tiny() {
        let ds = DatasetPreset::tiny().generate();
        let dir = std::env::temp_dir().join("distgnn_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tiny.dgnb");
        save(&ds, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(ds.name, back.name);
        assert_eq!(ds.graph, back.graph);
        assert_eq!(ds.features, back.features);
        assert_eq!(ds.labels, back.labels);
        assert_eq!(ds.train_vertices, back.train_vertices);
        assert_eq!(ds.test_vertices, back.test_vertices);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_truncated_file() {
        let dir = std::env::temp_dir().join("distgnn_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.dgnb");
        std::fs::write(&path, b"DGNBxxxx").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn shard_set_roundtrips_partitions() {
        use crate::partition::metis_like::MetisLikePartitioner;
        use crate::partition::{materialize, write_shards, Partitioner};
        let preset = DatasetPreset::tiny();
        let ds = preset.generate();
        let a = MetisLikePartitioner::default().partition(&ds.graph, &ds.train_vertices, 3, 3);
        let parts = materialize(&ds, &a);
        let dir = std::env::temp_dir()
            .join(format!("distgnn-shardset-test-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        write_shards(&ds, &a, &dir, "tiny", "metis-like", preset.seed).unwrap();
        let set = ShardSet::open(&dir).unwrap();
        assert_eq!(set.k(), 3);
        set.verify_all().unwrap();
        for (r, want) in parts.iter().enumerate() {
            for &mapped in &[true, false] {
                let got = set.load_partition(r, mapped).unwrap();
                assert_eq!(got.local, want.local, "rank {r} mapped={mapped}");
                assert_eq!(got.vid_o, want.vid_o);
                assert_eq!(got.halo_owner, want.halo_owner);
                assert_eq!(got.train_vertices, want.train_vertices);
                assert_eq!(got.test_vertices, want.test_vertices);
                assert_eq!(got.features, want.features);
                assert_eq!(got.labels, want.labels);
                assert_eq!(got.full_degree, want.full_degree);
                assert_eq!(got.global_to_local, want.global_to_local);
                assert_eq!(got.n_solid, want.n_solid);
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
