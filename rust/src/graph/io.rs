//! Binary dataset serialization.
//!
//! Format (little-endian, magic "DGNB"):
//!   u32 magic, u32 version,
//!   u64 n, u64 nnz, u32 feat_dim, u32 num_classes,
//!   u64 n_train, u64 n_test,
//!   name: u32 len + bytes,
//!   indptr[n+1] u64, indices[nnz] u32,
//!   features[n*feat_dim] f32, labels[n] u32,
//!   train[n_train] u32, test[n_test] u32.
//!
//! Generating the mini datasets takes seconds, but partition+cache reuse in
//! benches makes on-disk caching worthwhile.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::graph::{Csr, Dataset};

const MAGIC: u32 = 0x4247_4e44; // "DNGB" little-endian-ish tag
const VERSION: u32 = 1;

struct Writer<W: Write>(W);

impl<W: Write> Writer<W> {
    fn u32(&mut self, v: u32) -> Result<()> {
        self.0.write_all(&v.to_le_bytes())?;
        Ok(())
    }
    fn u64(&mut self, v: u64) -> Result<()> {
        self.0.write_all(&v.to_le_bytes())?;
        Ok(())
    }
    fn u32s(&mut self, vs: &[u32]) -> Result<()> {
        for &v in vs {
            self.u32(v)?;
        }
        Ok(())
    }
    fn u64s(&mut self, vs: &[u64]) -> Result<()> {
        for &v in vs {
            self.u64(v)?;
        }
        Ok(())
    }
    fn f32s(&mut self, vs: &[f32]) -> Result<()> {
        for &v in vs {
            self.0.write_all(&v.to_le_bytes())?;
        }
        Ok(())
    }
}

struct Reader<R: Read>(R);

impl<R: Read> Reader<R> {
    fn u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        self.0.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }
    fn u64(&mut self) -> Result<u64> {
        let mut b = [0u8; 8];
        self.0.read_exact(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }
    fn u32s(&mut self, n: usize) -> Result<Vec<u32>> {
        let mut bytes = vec![0u8; n * 4];
        self.0.read_exact(&mut bytes)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
    fn u64s(&mut self, n: usize) -> Result<Vec<u64>> {
        let mut bytes = vec![0u8; n * 8];
        self.0.read_exact(&mut bytes)?;
        Ok(bytes
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let mut bytes = vec![0u8; n * 4];
        self.0.read_exact(&mut bytes)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// Save a dataset to a binary file.
pub fn save(ds: &Dataset, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {}", path.as_ref().display()))?;
    let mut w = Writer(BufWriter::new(f));
    w.u32(MAGIC)?;
    w.u32(VERSION)?;
    let n = ds.num_vertices() as u64;
    w.u64(n)?;
    w.u64(ds.graph.indices.len() as u64)?;
    w.u32(ds.feat_dim as u32)?;
    w.u32(ds.num_classes as u32)?;
    w.u64(ds.train_vertices.len() as u64)?;
    w.u64(ds.test_vertices.len() as u64)?;
    w.u32(ds.name.len() as u32)?;
    w.0.write_all(ds.name.as_bytes())?;
    w.u64s(&ds.graph.indptr)?;
    w.u32s(&ds.graph.indices)?;
    w.f32s(&ds.features)?;
    w.u32s(&ds.labels)?;
    w.u32s(&ds.train_vertices)?;
    w.u32s(&ds.test_vertices)?;
    w.0.flush()?;
    Ok(())
}

/// Load a dataset from a binary file.
pub fn load(path: impl AsRef<Path>) -> Result<Dataset> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    let mut r = Reader(BufReader::new(f));
    if r.u32()? != MAGIC {
        bail!("bad magic (not a DistGNN-MB dataset file)");
    }
    if r.u32()? != VERSION {
        bail!("unsupported dataset file version");
    }
    let n = r.u64()? as usize;
    let nnz = r.u64()? as usize;
    let feat_dim = r.u32()? as usize;
    let num_classes = r.u32()? as usize;
    let n_train = r.u64()? as usize;
    let n_test = r.u64()? as usize;
    let name_len = r.u32()? as usize;
    let mut name_bytes = vec![0u8; name_len];
    r.0.read_exact(&mut name_bytes)?;
    let name = String::from_utf8(name_bytes)?;
    let indptr = r.u64s(n + 1)?;
    let indices = r.u32s(nnz)?;
    let features = r.f32s(n * feat_dim)?;
    let labels = r.u32s(n)?;
    let train_vertices = r.u32s(n_train)?;
    let test_vertices = r.u32s(n_test)?;
    let ds = Dataset {
        name,
        graph: Csr { indptr, indices },
        features,
        feat_dim,
        labels,
        num_classes,
        train_vertices,
        test_vertices,
    };
    ds.validate().context("loaded dataset fails validation")?;
    Ok(ds)
}

/// Load from cache or generate + save.
pub fn load_or_generate(
    preset: &crate::graph::DatasetPreset,
    cache_dir: impl AsRef<Path>,
) -> Result<Dataset> {
    let path = cache_dir
        .as_ref()
        .join(format!("{}-{:x}.dgnb", preset.name, preset.seed));
    if path.exists() {
        if let Ok(ds) = load(&path) {
            return Ok(ds);
        }
    }
    let ds = preset.generate();
    std::fs::create_dir_all(cache_dir.as_ref()).ok();
    save(&ds, &path).ok(); // cache failure is not fatal
    Ok(ds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DatasetPreset;

    #[test]
    fn roundtrip_tiny() {
        let ds = DatasetPreset::tiny().generate();
        let dir = std::env::temp_dir().join("distgnn_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tiny.dgnb");
        save(&ds, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(ds.name, back.name);
        assert_eq!(ds.graph, back.graph);
        assert_eq!(ds.features, back.features);
        assert_eq!(ds.labels, back.labels);
        assert_eq!(ds.train_vertices, back.train_vertices);
        assert_eq!(ds.test_vertices, back.test_vertices);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_truncated_file() {
        let dir = std::env::temp_dir().join("distgnn_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.dgnb");
        std::fs::write(&path, b"DGNBxxxx").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
