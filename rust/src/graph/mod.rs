//! Graph substrate: CSR storage, synthetic generators, dataset presets and
//! binary I/O.
//!
//! The paper evaluates on OGBN-Products (2.4M vertices / 124M edges) and
//! OGBN-Papers100M (111M / 3.2B). Those datasets are not available here, so
//! `datasets` provides `products-mini` / `papers100m-mini`: synthetic graphs
//! combining planted community structure (for a learnable node-property
//! prediction task) with power-law degree skew, matching the originals'
//! feature dims, class counts and train-split ratios at ~1/1000 scale
//! (DESIGN.md §1).

pub mod csr;
pub mod datasets;
pub mod generator;
pub mod io;

pub use csr::Csr;
pub use datasets::{Dataset, DatasetPreset};

/// Vertex id within the full (original) graph — the paper's VID_o.
pub type Vid = u32;
