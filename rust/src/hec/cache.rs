//! Software-managed Historical Embedding Cache.
//!
//! Semantics per paper §3.2:
//! * fixed size `cs` cache-lines, each holding one vertex embedding;
//! * tags are original vertex ids (VID_o) with a hash index for O(1)
//!   HECSearch;
//! * each line has a life-span `ls` (iterations); expired lines are purged
//!   (lazily on access and on replacement);
//! * replacement policy is **oldest-cache-line-first (OCF)** — "this
//!   ensures fresher embeddings in the HEC";
//! * storing an existing tag refreshes the line in place (replace matching
//!   tag), otherwise a free/expired/oldest line is recycled.

use std::collections::{HashMap, VecDeque};

/// Hit/miss counters (paper §4.4 reports per-layer hit rates).
#[derive(Clone, Copy, Debug, Default)]
pub struct HecStats {
    pub searches: u64,
    pub hits: u64,
    pub stores: u64,
    pub refreshes: u64,
    pub expired_purges: u64,
    pub evictions: u64,
}

impl HecStats {
    pub fn hit_rate(&self) -> f64 {
        if self.searches == 0 {
            0.0
        } else {
            self.hits as f64 / self.searches as f64
        }
    }
}

const EMPTY: u32 = u32::MAX;

/// One layer's cache.
pub struct Hec {
    cs: usize,
    ls: u32,
    dim: usize,
    /// Line tags (VID_o); EMPTY = free line.
    tags: Vec<u32>,
    /// Iteration at which each line was stored.
    birth: Vec<u64>,
    /// Line payloads, cs x dim.
    data: Vec<f32>,
    /// tag -> line index.
    index: HashMap<u32, u32>,
    /// OCF order as (line, seq) entries; stale entries (seq mismatch) are
    /// skipped lazily on pop, so refresh/purge never scan the queue.
    fifo: VecDeque<(u32, u64)>,
    /// Per-line store sequence number (bumped on every write).
    seq: Vec<u64>,
    next_seq: u64,
    /// Never-used line watermark.
    next_fresh: usize,
    /// Recycled (purged) lines ready for reuse.
    free: Vec<u32>,
    /// Current iteration (advanced by `tick`).
    now: u64,
    pub stats: HecStats,
}

impl Hec {
    pub fn new(cs: usize, ls: u32, dim: usize) -> Hec {
        assert!(cs > 0 && dim > 0);
        Hec {
            cs,
            ls,
            dim,
            tags: vec![EMPTY; cs],
            birth: vec![0; cs],
            data: vec![0.0; cs * dim],
            index: HashMap::with_capacity(cs.min(1 << 16)),
            fifo: VecDeque::with_capacity(cs.min(1 << 16)),
            seq: vec![0; cs],
            next_seq: 1,
            next_fresh: 0,
            free: Vec::new(),
            now: 0,
            stats: HecStats::default(),
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }
    pub fn capacity(&self) -> usize {
        self.cs
    }
    pub fn len(&self) -> usize {
        self.index.len()
    }
    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }
    /// Fraction of lines currently live (diagnostics).
    pub fn occupancy(&self) -> f64 {
        self.len() as f64 / self.cs as f64
    }

    /// Advance the iteration clock (call once per minibatch iteration).
    pub fn tick(&mut self) {
        self.now += 1;
    }

    #[inline]
    fn expired(&self, line: u32) -> bool {
        self.now.saturating_sub(self.birth[line as usize]) > self.ls as u64
    }

    /// HECSearch: find a *live* line for `vid_o`; an expired line is purged
    /// and reported as a miss.
    pub fn search(&mut self, vid_o: u32) -> Option<u32> {
        self.stats.searches += 1;
        match self.index.get(&vid_o).copied() {
            None => None,
            Some(line) => {
                if self.expired(line) {
                    self.purge_line(line);
                    self.stats.expired_purges += 1;
                    None
                } else {
                    self.stats.hits += 1;
                    Some(line)
                }
            }
        }
    }

    /// HECLoad: embedding payload of a line returned by [`search`].
    pub fn load(&self, line: u32) -> &[f32] {
        let i = line as usize * self.dim;
        &self.data[i..i + self.dim]
    }

    /// HECStore: insert or refresh the embedding for `vid_o`.
    pub fn store(&mut self, vid_o: u32, embed: &[f32]) {
        debug_assert_eq!(embed.len(), self.dim);
        debug_assert_ne!(vid_o, EMPTY);
        self.stats.stores += 1;
        if let Some(&line) = self.index.get(&vid_o) {
            // refresh in place (replace matching tag); the old FIFO entry
            // goes stale (seq mismatch) and is skipped on pop
            self.write_line(line, vid_o, embed);
            self.stats.refreshes += 1;
            self.fifo.push_back((line, self.seq[line as usize]));
            self.maybe_compact();
            return;
        }
        let line = if let Some(line) = self.free.pop() {
            line
        } else if self.next_fresh < self.cs {
            let line = self.next_fresh as u32;
            self.next_fresh += 1;
            line
        } else {
            // OCF: evict the oldest live line, skipping stale FIFO entries
            let line = loop {
                let (line, s) = self.fifo.pop_front().expect("full cache has live fifo");
                if self.seq[line as usize] == s && self.tags[line as usize] != EMPTY {
                    break line;
                }
            };
            let old_tag = self.tags[line as usize];
            self.index.remove(&old_tag);
            if self.expired(line) {
                self.stats.expired_purges += 1;
            } else {
                self.stats.evictions += 1;
            }
            line
        };
        self.write_line(line, vid_o, embed);
        self.index.insert(vid_o, line);
        self.fifo.push_back((line, self.seq[line as usize]));
        self.maybe_compact();
    }

    fn write_line(&mut self, line: u32, tag: u32, embed: &[f32]) {
        self.tags[line as usize] = tag;
        self.birth[line as usize] = self.now;
        self.seq[line as usize] = self.next_seq;
        self.next_seq += 1;
        let i = line as usize * self.dim;
        self.data[i..i + self.dim].copy_from_slice(embed);
    }

    fn purge_line(&mut self, line: u32) {
        let tag = self.tags[line as usize];
        self.index.remove(&tag);
        self.tags[line as usize] = EMPTY;
        // stale FIFO entries are skipped lazily; bump seq so they mismatch
        self.seq[line as usize] = self.next_seq;
        self.next_seq += 1;
        self.free.push(line);
    }

    /// Drop accumulated stale FIFO entries when they dominate the queue.
    fn maybe_compact(&mut self) {
        if self.fifo.len() > 2 * self.cs + 16 {
            let seq = &self.seq;
            let tags = &self.tags;
            self.fifo
                .retain(|&(l, s)| seq[l as usize] == s && tags[l as usize] != EMPTY);
        }
    }

    /// Internal consistency check (property tests).
    #[cfg(test)]
    fn check_invariants(&self) {
        // every live line has exactly one LIVE fifo entry (stale ones ok)
        let mut live = std::collections::HashMap::new();
        for &(l, s) in &self.fifo {
            if self.seq[l as usize] == s && self.tags[l as usize] != EMPTY {
                *live.entry(l).or_insert(0) += 1;
            }
        }
        assert_eq!(live.len(), self.index.len());
        assert!(live.values().all(|&c| c == 1), "duplicate live fifo entries");
        for (&tag, &line) in &self.index {
            assert_eq!(self.tags[line as usize], tag);
        }
        for &l in &self.free {
            assert_eq!(self.tags[l as usize], EMPTY);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn emb(v: f32, dim: usize) -> Vec<f32> {
        vec![v; dim]
    }

    #[test]
    fn store_search_load_roundtrip() {
        let mut h = Hec::new(8, 4, 3);
        h.store(100, &emb(1.5, 3));
        h.store(200, &emb(2.5, 3));
        let l = h.search(100).unwrap();
        assert_eq!(h.load(l), &[1.5, 1.5, 1.5]);
        assert!(h.search(999).is_none());
        assert_eq!(h.stats.hits, 1);
        assert_eq!(h.stats.searches, 2);
        h.check_invariants();
    }

    #[test]
    fn refresh_updates_in_place() {
        let mut h = Hec::new(4, 10, 2);
        h.store(7, &emb(1.0, 2));
        h.store(7, &emb(9.0, 2));
        assert_eq!(h.len(), 1);
        let l = h.search(7).unwrap();
        assert_eq!(h.load(l), &[9.0, 9.0]);
        assert_eq!(h.stats.refreshes, 1);
        h.check_invariants();
    }

    #[test]
    fn lifespan_expiry_purges_and_slot_is_reused() {
        let mut h = Hec::new(4, 2, 1);
        h.store(1, &emb(1.0, 1));
        h.tick();
        h.tick();
        assert!(h.search(1).is_some(), "age 2 == ls still live");
        h.tick();
        assert!(h.search(1).is_none(), "age 3 > ls expired");
        assert_eq!(h.stats.expired_purges, 1);
        assert_eq!(h.len(), 0);
        h.check_invariants();
        // purged slot reusable without colliding with fresh slots
        h.store(2, &emb(2.0, 1));
        h.store(3, &emb(3.0, 1));
        h.store(4, &emb(4.0, 1));
        h.store(5, &emb(5.0, 1));
        assert_eq!(h.len(), 4);
        for v in 2..=5 {
            let l = h.search(v).unwrap();
            assert_eq!(h.load(l)[0], v as f32);
        }
        h.check_invariants();
    }

    #[test]
    fn ocf_evicts_oldest_first() {
        let mut h = Hec::new(3, 100, 1);
        h.store(1, &emb(1.0, 1));
        h.tick();
        h.store(2, &emb(2.0, 1));
        h.tick();
        h.store(3, &emb(3.0, 1));
        h.tick();
        h.store(4, &emb(4.0, 1)); // evicts 1 (oldest)
        assert!(h.search(1).is_none());
        assert!(h.search(2).is_some());
        assert!(h.search(3).is_some());
        assert!(h.search(4).is_some());
        assert_eq!(h.stats.evictions, 1);
        h.check_invariants();
    }

    #[test]
    fn refresh_moves_line_to_back_of_ocf_order() {
        let mut h = Hec::new(2, 100, 1);
        h.store(1, &emb(1.0, 1));
        h.tick();
        h.store(2, &emb(2.0, 1));
        h.tick();
        h.store(1, &emb(1.5, 1)); // refresh 1 -> now 2 is oldest
        h.tick();
        h.store(3, &emb(3.0, 1)); // should evict 2
        assert!(h.search(2).is_none());
        assert!(h.search(1).is_some());
        assert!(h.search(3).is_some());
        h.check_invariants();
    }

    #[test]
    fn property_capacity_and_consistency_under_churn() {
        // randomized store/search/tick churn; after every operation batch
        // the structural invariants must hold and lookups must return the
        // latest stored value.
        let mut h = Hec::new(16, 3, 4);
        let mut shadow: std::collections::HashMap<u32, f32> = Default::default();
        let mut rng = crate::util::rng::Pcg64::seeded(9);
        for it in 0..400u64 {
            for _ in 0..8 {
                let vid = rng.gen_range(60) as u32;
                let val = it as f32 + vid as f32 * 0.001;
                h.store(vid, &emb(val, 4));
                shadow.insert(vid, val);
            }
            for _ in 0..8 {
                let vid = rng.gen_range(60) as u32;
                if let Some(l) = h.search(vid) {
                    // a hit must return the latest stored value
                    assert_eq!(h.load(l)[0], shadow[&vid], "iter {it} vid {vid}");
                }
            }
            h.tick();
            assert!(h.len() <= 16);
            h.check_invariants();
        }
        assert!(h.stats.hits > 0);
        assert!(h.stats.evictions > 0);
    }

    #[test]
    fn hit_rate_computation() {
        let s = HecStats {
            searches: 10,
            hits: 7,
            ..Default::default()
        };
        assert!((s.hit_rate() - 0.7).abs() < 1e-12);
        assert_eq!(HecStats::default().hit_rate(), 0.0);
    }
}
