//! Software-managed Historical Embedding Cache.
//!
//! Semantics per paper §3.2:
//! * fixed size `cs` cache-lines, each holding one vertex embedding;
//! * tags are original vertex ids (VID_o) with a hash index for O(1)
//!   HECSearch;
//! * each line has a life-span `ls` (iterations); expired lines are purged
//!   (lazily on access and on replacement);
//! * replacement policy is **oldest-cache-line-first (OCF)** — "this
//!   ensures fresher embeddings in the HEC";
//! * storing an existing tag refreshes the line in place (replace matching
//!   tag), otherwise a free/expired/oldest line is recycled.
//!
//! Line payloads are stored in a configurable dtype
//! ([`crate::config::DtypeKind`]): f32 (default) or bf16, which halves
//! cache bytes. The replacement metadata (tags, FIFO, expiry) is dtype-
//! agnostic — only the payload copies differ, and bf16 rows round once on
//! store ([`crate::runtime::bf16`], round-to-nearest-even) and are
//! bit-preserved from then on (store → load → store is lossless).

use std::collections::{HashMap, VecDeque};

use crate::config::{DtypeKind, HecPolicyKind};
use crate::runtime::bf16;
use crate::runtime::tensor::as_bytes;
use crate::util::parallel;

/// Hit/miss counters (paper §4.4 reports per-layer hit rates), plus the
/// replacement-policy and lookahead-prefetch counters layered on by PR 7.
///
/// The prefetch counters describe the level-0 cache's side-car staging
/// area ([`crate::hec::prefetch::PrefetchStage`]); the driver mirrors
/// them here after classification so one struct carries the whole
/// hit/miss/coverage story per layer.
#[derive(Clone, Copy, Debug, Default)]
pub struct HecStats {
    pub searches: u64,
    pub hits: u64,
    pub stores: u64,
    pub refreshes: u64,
    pub expired_purges: u64,
    pub evictions: u64,
    /// `reuse` policy: fifo entries given a second chance because their
    /// tag was pinned by an in-flight pipeline-ring entry.
    pub pin_protected: u64,
    /// `reuse` policy: fifo entries that traded half their reuse credit
    /// for another lap instead of being evicted.
    pub reuse_deferrals: u64,
    /// `reuse` policy: stores refused because every live line was pinned.
    pub pinned_drops: u64,
    /// Prefetch pulls issued (vids requested from owner ranks).
    pub prefetch_issued: u64,
    /// Prefetched rows that landed before their minibatch was packed
    /// (the miss's stall was hidden).
    pub prefetch_landed: u64,
    /// Prefetched rows still in flight when their minibatch was packed.
    pub prefetch_late: u64,
    /// Prefetched rows never consumed by any pack (cleared at epoch /
    /// checkpoint / resume boundaries).
    pub prefetch_wasted: u64,
}

impl HecStats {
    pub fn hit_rate(&self) -> f64 {
        if self.searches == 0 {
            0.0
        } else {
            self.hits as f64 / self.searches as f64
        }
    }

    /// Hit rate counting covered (landed-in-time) prefetches as hits:
    /// the fraction of searches whose data was on-node when the packer
    /// needed it. Plain hits are bit-identical with prefetch on or off,
    /// so this is strictly >= [`HecStats::hit_rate`] and the prefetch
    /// ablation's headline number.
    pub fn effective_hit_rate(&self) -> f64 {
        if self.searches == 0 {
            0.0
        } else {
            (self.hits + self.prefetch_landed) as f64 / self.searches as f64
        }
    }

    /// Fraction of issued prefetches that landed in time.
    pub fn prefetch_coverage(&self) -> f64 {
        let classified = self.prefetch_landed + self.prefetch_late + self.prefetch_wasted;
        if classified == 0 {
            0.0
        } else {
            self.prefetch_landed as f64 / classified as f64
        }
    }
}

const EMPTY: u32 = u32::MAX;

/// Line payload storage in the cache's dtype.
enum Payload {
    F32(Vec<f32>),
    Bf16(Vec<u16>),
}

/// One layer's cache.
pub struct Hec {
    cs: usize,
    ls: u32,
    dim: usize,
    dtype: DtypeKind,
    /// Line tags (VID_o); EMPTY = free line.
    tags: Vec<u32>,
    /// Iteration at which each line was stored.
    birth: Vec<u64>,
    /// Line payloads, cs x dim, in `dtype` storage.
    data: Payload,
    /// tag -> line index.
    index: HashMap<u32, u32>,
    /// OCF order as (line, seq) entries; stale entries (seq mismatch) are
    /// skipped lazily on pop, so refresh/purge never scan the queue.
    fifo: VecDeque<(u32, u64)>,
    /// Per-line store sequence number (bumped on every write).
    seq: Vec<u64>,
    next_seq: u64,
    /// Never-used line watermark.
    next_fresh: usize,
    /// Recycled (purged) lines ready for reuse.
    free: Vec<u32>,
    /// Current iteration (advanced by `tick`).
    now: u64,
    /// Replacement policy. `Ocf` is the default and leaves every code
    /// path byte-identical to the pre-policy cache; `Reuse` adds pin
    /// protection and second-chance eviction on top of the same FIFO.
    policy: HecPolicyKind,
    /// Per-line search-hit credit (`Reuse` policy only; stays all-zero
    /// under `Ocf`). Reset when a line is assigned to a new tag, halved
    /// each time the line is spared at eviction time.
    reuse: Vec<u32>,
    /// Pinned tags (VID_o -> pin count): vertices referenced by an
    /// in-flight pipeline-ring entry. Pins protect against *capacity
    /// eviction* only — lazy expiry on access still purges stale data.
    pins: HashMap<u32, u32>,
    pub stats: HecStats,
}

impl Hec {
    /// An f32-payload cache (the default precision).
    pub fn new(cs: usize, ls: u32, dim: usize) -> Hec {
        Hec::new_with(cs, ls, dim, DtypeKind::F32)
    }

    /// A cache whose line payloads are stored in `dtype`.
    pub fn new_with(cs: usize, ls: u32, dim: usize, dtype: DtypeKind) -> Hec {
        assert!(cs > 0 && dim > 0);
        Hec {
            cs,
            ls,
            dim,
            dtype,
            tags: vec![EMPTY; cs],
            birth: vec![0; cs],
            data: match dtype {
                DtypeKind::F32 => Payload::F32(vec![0.0; cs * dim]),
                DtypeKind::Bf16 => Payload::Bf16(vec![0; cs * dim]),
            },
            index: HashMap::with_capacity(cs.min(1 << 16)),
            fifo: VecDeque::with_capacity(cs.min(1 << 16)),
            seq: vec![0; cs],
            next_seq: 1,
            next_fresh: 0,
            free: Vec::new(),
            now: 0,
            policy: HecPolicyKind::Ocf,
            reuse: vec![0; cs],
            pins: HashMap::new(),
            stats: HecStats::default(),
        }
    }

    /// Select the replacement policy (builder-style; default `Ocf`).
    pub fn with_policy(mut self, policy: HecPolicyKind) -> Hec {
        self.policy = policy;
        self
    }

    pub fn policy(&self) -> HecPolicyKind {
        self.policy
    }

    pub fn dim(&self) -> usize {
        self.dim
    }
    /// Payload storage precision of this cache.
    pub fn dtype(&self) -> DtypeKind {
        self.dtype
    }
    /// Bytes per stored line (diagnostics: cache memory = cs * row_len).
    pub fn row_len_bytes(&self) -> usize {
        self.dim * self.dtype.elem_bytes()
    }
    pub fn capacity(&self) -> usize {
        self.cs
    }
    pub fn len(&self) -> usize {
        self.index.len()
    }
    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }
    /// Fraction of lines currently live (diagnostics).
    pub fn occupancy(&self) -> f64 {
        self.len() as f64 / self.cs as f64
    }

    /// Advance the iteration clock (call once per minibatch iteration).
    pub fn tick(&mut self) {
        self.now += 1;
    }

    #[inline]
    fn expired(&self, line: u32) -> bool {
        self.now.saturating_sub(self.birth[line as usize]) > self.ls as u64
    }

    /// HECSearch: find a *live* line for `vid_o`; an expired line is purged
    /// and reported as a miss.
    pub fn search(&mut self, vid_o: u32) -> Option<u32> {
        self.stats.searches += 1;
        match self.index.get(&vid_o).copied() {
            None => None,
            Some(line) => {
                if self.expired(line) {
                    self.purge_line(line);
                    self.stats.expired_purges += 1;
                    None
                } else {
                    self.stats.hits += 1;
                    if self.policy == HecPolicyKind::Reuse {
                        let r = &mut self.reuse[line as usize];
                        *r = r.saturating_add(1);
                    }
                    Some(line)
                }
            }
        }
    }

    /// Side-effect-free hit test: would [`Hec::search`] for `vid_o` hit
    /// right now? Unlike `search` this touches no stats, performs no lazy
    /// expiry purge and earns no reuse credit — the prefetch planner diffs
    /// future minibatches against the cache through this, so planning a
    /// prefetch can never perturb the bit-identical training path.
    pub fn probe(&self, vid_o: u32) -> bool {
        match self.index.get(&vid_o) {
            Some(&line) => !self.expired(line),
            None => false,
        }
    }

    /// Pin `vid_o` against capacity eviction (`Reuse` policy; counted, so
    /// a vertex referenced by several in-flight ring entries stays pinned
    /// until every one of them has been consumed). Pinning a vid that is
    /// not currently cached is fine — the pin applies if it gets stored.
    pub fn pin(&mut self, vid_o: u32) {
        *self.pins.entry(vid_o).or_insert(0) += 1;
    }

    /// Release one pin on `vid_o` (no-op if it was not pinned).
    pub fn unpin(&mut self, vid_o: u32) {
        if let Some(c) = self.pins.get_mut(&vid_o) {
            *c -= 1;
            if *c == 0 {
                self.pins.remove(&vid_o);
            }
        }
    }

    /// Drop every pin (epoch / checkpoint / resume boundaries, where the
    /// pipeline ring is reset and in-flight entries are discarded).
    pub fn clear_pins(&mut self) {
        self.pins.clear();
    }

    /// Number of distinct pinned tags (diagnostics / tests).
    pub fn pinned_tags(&self) -> usize {
        self.pins.len()
    }

    /// Batched HECSearch over a slice of vertex ids. Semantics (stats,
    /// lazy expiry purges) are element-for-element identical to calling
    /// [`search`] in order; the batch form exists so the packer resolves a
    /// whole layer's halos in one pass.
    pub fn search_batch(&mut self, vids: &[u32]) -> Vec<Option<u32>> {
        vids.iter().map(|&v| self.search(v)).collect()
    }

    /// HECLoad: embedding payload of a line returned by [`search`].
    /// Only valid on f32 caches — the bf16 packer path copies raw rows
    /// through [`row_bytes`](Hec::row_bytes) instead.
    pub fn load(&self, line: u32) -> &[f32] {
        let i = line as usize * self.dim;
        match &self.data {
            Payload::F32(d) => &d[i..i + self.dim],
            Payload::Bf16(_) => panic!("Hec::load on a bf16 cache; use row_bytes/load_bf16"),
        }
    }

    /// HECLoad on a bf16 cache: the raw bf16 bit patterns of a line.
    pub fn load_bf16(&self, line: u32) -> &[u16] {
        let i = line as usize * self.dim;
        match &self.data {
            Payload::Bf16(d) => &d[i..i + self.dim],
            Payload::F32(_) => panic!("Hec::load_bf16 on an f32 cache; use load"),
        }
    }

    /// A line's payload as raw little-endian bytes (`row_len_bytes()`
    /// long), regardless of dtype — the packer block-copies these straight
    /// into tensor storage of the matching dtype.
    pub fn row_bytes(&self, line: u32) -> &[u8] {
        let i = line as usize * self.dim;
        match &self.data {
            Payload::F32(d) => as_bytes(&d[i..i + self.dim]),
            Payload::Bf16(d) => as_bytes(&d[i..i + self.dim]),
        }
    }

    /// Batched HECLoad: gather the payloads of `lines` into `out`
    /// (`out.len() == lines.len() * dim`) as contiguous f32 rows, copying
    /// (bf16: expanding) in thread-parallel row chunks. Byte-identical for
    /// any worker count.
    pub fn load_batch(&self, lines: &[u32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), lines.len() * self.dim);
        let dim = self.dim;
        match &self.data {
            Payload::F32(data) => {
                parallel::parallel_rows_mut(out, dim, |row0, chunk| {
                    for (j, dst) in chunk.chunks_exact_mut(dim).enumerate() {
                        let line = lines[row0 + j] as usize;
                        dst.copy_from_slice(&data[line * dim..line * dim + dim]);
                    }
                });
            }
            Payload::Bf16(data) => {
                parallel::parallel_rows_mut(out, dim, |row0, chunk| {
                    for (j, dst) in chunk.chunks_exact_mut(dim).enumerate() {
                        let line = lines[row0 + j] as usize;
                        bf16::unpack_into(&data[line * dim..line * dim + dim], dst);
                    }
                });
            }
        }
    }

    /// Batched HECLoad of raw row bytes (`out.len() == lines.len() *
    /// row_len_bytes()`), dtype-agnostic: the packer gathers hit rows into
    /// tensors of the cache's own dtype without conversion.
    pub fn load_batch_bytes(&self, lines: &[u32], out: &mut [u8]) {
        let rb = self.row_len_bytes();
        debug_assert_eq!(out.len(), lines.len() * rb);
        parallel::parallel_rows_mut(out, rb, |row0, chunk| {
            for (j, dst) in chunk.chunks_exact_mut(rb).enumerate() {
                dst.copy_from_slice(self.row_bytes(lines[row0 + j]));
            }
        });
    }

    /// HECStore: insert or refresh the embedding for `vid_o` (bf16 caches
    /// round the row once, to nearest-even).
    pub fn store(&mut self, vid_o: u32, embed: &[f32]) {
        debug_assert_eq!(embed.len(), self.dim);
        let Some(line) = self.store_meta(vid_o) else {
            return; // refused: fully pinned cache (Reuse policy only)
        };
        let line = line as usize;
        let (lo, hi) = (line * self.dim, (line + 1) * self.dim);
        match &mut self.data {
            Payload::F32(d) => d[lo..hi].copy_from_slice(embed),
            Payload::Bf16(d) => bf16::pack_into(embed, &mut d[lo..hi]),
        }
    }

    /// HECStore of raw bf16 rows (a bf16 AEP push payload) — bit-copied
    /// on bf16 caches, expanded on f32 caches.
    pub fn store_bf16(&mut self, vid_o: u32, embed: &[u16]) {
        debug_assert_eq!(embed.len(), self.dim);
        let Some(line) = self.store_meta(vid_o) else {
            return; // refused: fully pinned cache (Reuse policy only)
        };
        let line = line as usize;
        let (lo, hi) = (line * self.dim, (line + 1) * self.dim);
        match &mut self.data {
            Payload::Bf16(d) => d[lo..hi].copy_from_slice(embed),
            Payload::F32(d) => bf16::unpack_into(embed, &mut d[lo..hi]),
        }
    }

    /// Batched HECStore of `vids.len()` rows (`embeds` is row-major,
    /// `vids.len() x dim`). Line assignment runs sequentially with exactly
    /// the scalar [`store`] semantics (refresh in place, OCF eviction
    /// order); payload copies then run as parallel row copies over the
    /// assigned — pairwise disjoint — cache lines.
    pub fn store_batch(&mut self, vids: &[u32], embeds: &[f32]) {
        debug_assert_eq!(embeds.len(), vids.len() * self.dim);
        if vids.is_empty() {
            return;
        }
        let dim = self.dim;
        let assign = self.assign_lines(vids);
        match &mut self.data {
            Payload::F32(d) => scatter_assigned_rows(d, dim, assign, |dst, row| {
                dst.copy_from_slice(&embeds[row * dim..row * dim + dim]);
            }),
            Payload::Bf16(d) => scatter_assigned_rows(d, dim, assign, |dst, row| {
                bf16::pack_into(&embeds[row * dim..row * dim + dim], dst);
            }),
        }
    }

    /// Batched HECStore of raw bf16 rows (the receive side of a bf16 AEP
    /// push): same assignment semantics as [`store_batch`], payloads
    /// bit-copied (bf16 cache) or expanded (f32 cache).
    pub fn store_batch_bf16(&mut self, vids: &[u32], embeds: &[u16]) {
        debug_assert_eq!(embeds.len(), vids.len() * self.dim);
        if vids.is_empty() {
            return;
        }
        let dim = self.dim;
        let assign = self.assign_lines(vids);
        match &mut self.data {
            Payload::Bf16(d) => scatter_assigned_rows(d, dim, assign, |dst, row| {
                dst.copy_from_slice(&embeds[row * dim..row * dim + dim]);
            }),
            Payload::F32(d) => scatter_assigned_rows(d, dim, assign, |dst, row| {
                bf16::unpack_into(&embeds[row * dim..row * dim + dim], dst);
            }),
        }
    }

    /// Phase 1 of every batched store: sequential metadata/assignment
    /// (determines eviction order), exactly the scalar [`store`] path.
    fn assign_lines(&mut self, vids: &[u32]) -> Vec<(u32, u32)> {
        let mut assign: Vec<(u32, u32)> = Vec::with_capacity(vids.len());
        for (row, &vid) in vids.iter().enumerate() {
            if let Some(line) = self.store_meta(vid) {
                assign.push((line, row as u32));
            }
        }
        assign
    }

    /// Shared store bookkeeping: pick (or refresh) the line for `vid_o`,
    /// updating tags/index/FIFO/stats exactly as the scalar store, without
    /// touching the payload. Returns the assigned line, or `None` when the
    /// store is refused (`Reuse` policy with every live line pinned —
    /// impossible under `Ocf`, which never refuses).
    fn store_meta(&mut self, vid_o: u32) -> Option<u32> {
        debug_assert_ne!(vid_o, EMPTY);
        self.stats.stores += 1;
        if let Some(&line) = self.index.get(&vid_o) {
            // refresh in place (replace matching tag); the old FIFO entry
            // goes stale (seq mismatch) and is skipped on pop
            self.write_meta(line, vid_o);
            self.stats.refreshes += 1;
            self.fifo.push_back((line, self.seq[line as usize]));
            self.maybe_compact();
            return Some(line);
        }
        let line = if let Some(line) = self.free.pop() {
            line
        } else if self.next_fresh < self.cs {
            let line = self.next_fresh as u32;
            self.next_fresh += 1;
            line
        } else {
            let victim = match self.policy {
                // OCF: evict the oldest live line, skipping stale entries
                HecPolicyKind::Ocf => loop {
                    let (line, s) = self.fifo.pop_front().expect("full cache has live fifo");
                    if self.seq[line as usize] == s && self.tags[line as usize] != EMPTY {
                        break Some(line);
                    }
                },
                HecPolicyKind::Reuse => self.evict_victim_reuse(),
            };
            let Some(line) = victim else {
                self.stats.pinned_drops += 1;
                return None;
            };
            let old_tag = self.tags[line as usize];
            self.index.remove(&old_tag);
            if self.expired(line) {
                self.stats.expired_purges += 1;
            } else {
                self.stats.evictions += 1;
            }
            line
        };
        // a new tag starts with no reuse credit (noop under Ocf)
        self.reuse[line as usize] = 0;
        self.write_meta(line, vid_o);
        self.index.insert(vid_o, line);
        self.fifo.push_back((line, self.seq[line as usize]));
        self.maybe_compact();
        Some(line)
    }

    /// `Reuse` policy victim selection: oldest-first like OCF, but pinned
    /// lines are immune and a hot line (reuse credit > 0) trades half its
    /// credit for another lap of the FIFO instead of dying on its first
    /// turn (CLOCK-style second chance). Expired lines are dead data and
    /// evicted immediately unless pinned. Each pass halves every hot
    /// unpinned line's credit, so a victim emerges within ~32 passes;
    /// `None` only when every live line is pinned. Spared entries are
    /// re-queued at the back with their existing `(line, seq)` pair, so
    /// the one-live-entry-per-line FIFO invariant is untouched.
    fn evict_victim_reuse(&mut self) -> Option<u32> {
        loop {
            let n = self.fifo.len();
            if n == 0 {
                return None;
            }
            let mut saw_unpinned = false;
            for _ in 0..n {
                let Some((line, s)) = self.fifo.pop_front() else {
                    break;
                };
                let l = line as usize;
                if self.seq[l] != s || self.tags[l] == EMPTY {
                    continue; // stale entry: dropped for good
                }
                if self.pins.contains_key(&self.tags[l]) {
                    self.stats.pin_protected += 1;
                    self.fifo.push_back((line, s));
                    continue;
                }
                saw_unpinned = true;
                if !self.expired(line) && self.reuse[l] > 0 {
                    self.reuse[l] /= 2;
                    self.stats.reuse_deferrals += 1;
                    self.fifo.push_back((line, s));
                    continue;
                }
                return Some(line);
            }
            if !saw_unpinned {
                return None;
            }
        }
    }

    fn write_meta(&mut self, line: u32, tag: u32) {
        self.tags[line as usize] = tag;
        self.birth[line as usize] = self.now;
        self.seq[line as usize] = self.next_seq;
        self.next_seq += 1;
    }

    fn purge_line(&mut self, line: u32) {
        let tag = self.tags[line as usize];
        self.index.remove(&tag);
        self.tags[line as usize] = EMPTY;
        // stale FIFO entries are skipped lazily; bump seq so they mismatch
        self.seq[line as usize] = self.next_seq;
        self.next_seq += 1;
        self.free.push(line);
    }

    /// Drop accumulated stale FIFO entries when they dominate the queue.
    fn maybe_compact(&mut self) {
        if self.fifo.len() > 2 * self.cs + 16 {
            let seq = &self.seq;
            let tags = &self.tags;
            self.fifo
                .retain(|&(l, s)| seq[l as usize] == s && tags[l as usize] != EMPTY);
        }
    }

    /// Internal consistency check (property tests).
    #[cfg(test)]
    fn check_invariants(&self) {
        // every live line has exactly one LIVE fifo entry (stale ones ok)
        let mut live = std::collections::HashMap::new();
        for &(l, s) in &self.fifo {
            if self.seq[l as usize] == s && self.tags[l as usize] != EMPTY {
                *live.entry(l).or_insert(0) += 1;
            }
        }
        assert_eq!(live.len(), self.index.len());
        assert!(live.values().all(|&c| c == 1), "duplicate live fifo entries");
        for (&tag, &line) in &self.index {
            assert_eq!(self.tags[line as usize], tag);
        }
        for &l in &self.free {
            assert_eq!(self.tags[l as usize], EMPTY);
        }
    }
}

/// Phase 2 of every batched store, generic over the payload element type:
/// a line can be assigned twice within one batch (refresh, or eviction
/// recycling a just-written line); the last write must win, so keep only
/// each line's final source row. After the dedup the destination rows are
/// pairwise-disjoint slices of `data`, filled by `fill(dst_row, src_row)`
/// serially or in parallel chunks (row-disjointness makes the result
/// worker-count invariant).
fn scatter_assigned_rows<T, F>(data: &mut [T], dim: usize, mut assign: Vec<(u32, u32)>, fill: F)
where
    T: Copy + Send,
    F: Fn(&mut [T], usize) + Sync,
{
    assign.sort_by_key(|&(line, _)| line);
    let mut pairs: Vec<(&mut [T], usize)> = Vec::with_capacity(assign.len());
    let mut rest: &mut [T] = data;
    let mut consumed = 0usize;
    let mut i = 0usize;
    while i < assign.len() {
        let line = assign[i].0;
        let mut src_row = assign[i].1;
        while i + 1 < assign.len() && assign[i + 1].0 == line {
            i += 1;
            src_row = assign[i].1; // stable sort: last in run = last stored
        }
        i += 1;
        let skip = line as usize * dim - consumed;
        let (_, tail) = rest.split_at_mut(skip);
        let (row_slice, tail) = tail.split_at_mut(dim);
        rest = tail;
        consumed = line as usize * dim + dim;
        pairs.push((row_slice, src_row as usize));
    }
    let workers = parallel::num_threads();
    if workers <= 1 || pairs.len() < 64 {
        for (dst, row) in pairs {
            fill(dst, row);
        }
    } else {
        parallel::parallel_chunks_mut(&mut pairs, workers, |_, _, chunk| {
            for (dst, row) in chunk.iter_mut() {
                fill(&mut **dst, *row);
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn emb(v: f32, dim: usize) -> Vec<f32> {
        vec![v; dim]
    }

    #[test]
    fn store_search_load_roundtrip() {
        let mut h = Hec::new(8, 4, 3);
        h.store(100, &emb(1.5, 3));
        h.store(200, &emb(2.5, 3));
        let l = h.search(100).unwrap();
        assert_eq!(h.load(l), &[1.5, 1.5, 1.5]);
        assert!(h.search(999).is_none());
        assert_eq!(h.stats.hits, 1);
        assert_eq!(h.stats.searches, 2);
        h.check_invariants();
    }

    #[test]
    fn refresh_updates_in_place() {
        let mut h = Hec::new(4, 10, 2);
        h.store(7, &emb(1.0, 2));
        h.store(7, &emb(9.0, 2));
        assert_eq!(h.len(), 1);
        let l = h.search(7).unwrap();
        assert_eq!(h.load(l), &[9.0, 9.0]);
        assert_eq!(h.stats.refreshes, 1);
        h.check_invariants();
    }

    #[test]
    fn lifespan_expiry_purges_and_slot_is_reused() {
        let mut h = Hec::new(4, 2, 1);
        h.store(1, &emb(1.0, 1));
        h.tick();
        h.tick();
        assert!(h.search(1).is_some(), "age 2 == ls still live");
        h.tick();
        assert!(h.search(1).is_none(), "age 3 > ls expired");
        assert_eq!(h.stats.expired_purges, 1);
        assert_eq!(h.len(), 0);
        h.check_invariants();
        // purged slot reusable without colliding with fresh slots
        h.store(2, &emb(2.0, 1));
        h.store(3, &emb(3.0, 1));
        h.store(4, &emb(4.0, 1));
        h.store(5, &emb(5.0, 1));
        assert_eq!(h.len(), 4);
        for v in 2..=5 {
            let l = h.search(v).unwrap();
            assert_eq!(h.load(l)[0], v as f32);
        }
        h.check_invariants();
    }

    #[test]
    fn ocf_evicts_oldest_first() {
        let mut h = Hec::new(3, 100, 1);
        h.store(1, &emb(1.0, 1));
        h.tick();
        h.store(2, &emb(2.0, 1));
        h.tick();
        h.store(3, &emb(3.0, 1));
        h.tick();
        h.store(4, &emb(4.0, 1)); // evicts 1 (oldest)
        assert!(h.search(1).is_none());
        assert!(h.search(2).is_some());
        assert!(h.search(3).is_some());
        assert!(h.search(4).is_some());
        assert_eq!(h.stats.evictions, 1);
        h.check_invariants();
    }

    #[test]
    fn refresh_moves_line_to_back_of_ocf_order() {
        let mut h = Hec::new(2, 100, 1);
        h.store(1, &emb(1.0, 1));
        h.tick();
        h.store(2, &emb(2.0, 1));
        h.tick();
        h.store(1, &emb(1.5, 1)); // refresh 1 -> now 2 is oldest
        h.tick();
        h.store(3, &emb(3.0, 1)); // should evict 2
        assert!(h.search(2).is_none());
        assert!(h.search(1).is_some());
        assert!(h.search(3).is_some());
        h.check_invariants();
    }

    #[test]
    fn property_capacity_and_consistency_under_churn() {
        // randomized store/search/tick churn; after every operation batch
        // the structural invariants must hold and lookups must return the
        // latest stored value.
        let mut h = Hec::new(16, 3, 4);
        let mut shadow: std::collections::HashMap<u32, f32> = Default::default();
        let mut rng = crate::util::rng::Pcg64::seeded(9);
        for it in 0..400u64 {
            for _ in 0..8 {
                let vid = rng.gen_range(60) as u32;
                let val = it as f32 + vid as f32 * 0.001;
                h.store(vid, &emb(val, 4));
                shadow.insert(vid, val);
            }
            for _ in 0..8 {
                let vid = rng.gen_range(60) as u32;
                if let Some(l) = h.search(vid) {
                    // a hit must return the latest stored value
                    assert_eq!(h.load(l)[0], shadow[&vid], "iter {it} vid {vid}");
                }
            }
            h.tick();
            assert!(h.len() <= 16);
            h.check_invariants();
        }
        assert!(h.stats.hits > 0);
        assert!(h.stats.evictions > 0);
    }

    #[test]
    fn ocf_order_under_full_cache_with_interleaved_refreshes() {
        // A full cache must always retain exactly the `cs` most recently
        // (re)stored tags, in OCF order, across sustained eviction churn.
        let cs = 4;
        let mut h = Hec::new(cs, 1000, 2);
        let mut recency: Vec<u32> = Vec::new(); // oldest first
        let mut rng = crate::util::rng::Pcg64::seeded(21);
        for step in 0..300u32 {
            let vid = rng.gen_range(10) as u32;
            h.store(vid, &emb(step as f32, 2));
            recency.retain(|&v| v != vid);
            recency.push(vid);
            if recency.len() > cs {
                recency.remove(0);
            }
            h.check_invariants();
        }
        assert_eq!(h.len(), cs);
        for &v in &recency {
            assert!(h.search(v).is_some(), "recent tag {v} evicted early");
        }
        for v in 0..10u32 {
            if !recency.contains(&v) {
                assert!(h.search(v).is_none(), "stale tag {v} survived");
            }
        }
        assert!(h.stats.evictions > 0);
    }

    #[test]
    fn lazy_expiry_on_access_frees_slot_without_eviction() {
        let mut h = Hec::new(4, 1, 3);
        h.store(10, &emb(1.0, 3));
        h.store(20, &emb(2.0, 3));
        h.tick();
        h.tick(); // age 2 > ls=1: both expired, but purge only happens on access
        assert_eq!(h.len(), 2, "expiry is lazy");
        assert!(h.search(10).is_none());
        assert_eq!(h.stats.expired_purges, 1);
        assert_eq!(h.len(), 1, "accessed line purged");
        // freed slot is recycled before any fresh line or eviction
        h.store(30, &emb(3.0, 3));
        h.store(40, &emb(4.0, 3));
        h.store(50, &emb(5.0, 3));
        assert_eq!(h.stats.evictions, 0);
        assert!(h.search(30).is_some() && h.search(40).is_some() && h.search(50).is_some());
        h.check_invariants();
    }

    #[test]
    fn refresh_resets_birth_for_expiry() {
        let mut h = Hec::new(2, 2, 1);
        h.store(9, &emb(1.0, 1));
        h.tick();
        h.tick(); // age 2 == ls: still live
        h.store(9, &emb(2.0, 1)); // refresh in place resets birth to now
        h.tick();
        h.tick();
        let l = h.search(9).expect("refreshed line must expire from refresh time");
        assert_eq!(h.load(l), &[2.0]);
        h.tick();
        assert!(h.search(9).is_none(), "age past ls after refresh expires");
        h.check_invariants();
    }

    #[test]
    fn search_batch_matches_scalar_search() {
        let mut a = Hec::new(8, 2, 2);
        let mut b = Hec::new(8, 2, 2);
        for v in [1u32, 2, 3] {
            a.store(v, &emb(v as f32, 2));
            b.store(v, &emb(v as f32, 2));
        }
        a.tick();
        b.tick();
        for v in [4u32, 5] {
            a.store(v, &emb(v as f32, 2));
            b.store(v, &emb(v as f32, 2));
        }
        a.tick();
        b.tick();
        a.tick();
        b.tick(); // now: 1-3 expired (age 3 > ls 2), 4-5 still live (age 2)
        let query: Vec<u32> = vec![3, 99, 1, 1, 5, 42];
        let batch = a.search_batch(&query);
        let scalar: Vec<Option<u32>> = query.iter().map(|&v| b.search(v)).collect();
        assert_eq!(batch, scalar);
        assert_eq!(a.stats.searches, b.stats.searches);
        assert_eq!(a.stats.hits, b.stats.hits);
        assert_eq!(a.stats.expired_purges, b.stats.expired_purges);
        for (q, line) in query.iter().zip(&batch) {
            if let Some(l) = line {
                assert_eq!(a.load(*l)[0], *q as f32);
            }
        }
        a.check_invariants();
        b.check_invariants();
    }

    #[test]
    fn store_batch_matches_scalar_store() {
        // Random batches (with duplicate vids and eviction churn) driven
        // through scalar stores on one cache and store_batch on another
        // must leave identical contents, eviction order and stats.
        let mut scalar = Hec::new(16, 3, 4);
        let mut batched = Hec::new(16, 3, 4);
        let mut rng = crate::util::rng::Pcg64::seeded(31);
        for _round in 0..60 {
            let n = 1 + rng.gen_range(40);
            let mut vids = Vec::with_capacity(n);
            let mut rows = Vec::with_capacity(n * 4);
            for _ in 0..n {
                let v = rng.gen_range(48) as u32;
                vids.push(v);
                let val = rng.gen_f32();
                rows.extend_from_slice(&[val; 4]);
            }
            for (i, &v) in vids.iter().enumerate() {
                scalar.store(v, &rows[i * 4..(i + 1) * 4]);
            }
            batched.store_batch(&vids, &rows);
            scalar.tick();
            batched.tick();
            for v in 0..48u32 {
                let a = scalar.search(v);
                let b = batched.search(v);
                assert_eq!(a.is_some(), b.is_some(), "vid {v}");
                if let (Some(la), Some(lb)) = (a, b) {
                    assert_eq!(scalar.load(la), batched.load(lb), "vid {v}");
                }
            }
            assert_eq!(scalar.stats.stores, batched.stats.stores);
            assert_eq!(scalar.stats.refreshes, batched.stats.refreshes);
            assert_eq!(scalar.stats.evictions, batched.stats.evictions);
            scalar.check_invariants();
            batched.check_invariants();
        }
        assert!(batched.stats.evictions > 0, "test must exercise eviction");
    }

    #[test]
    fn load_batch_gathers_contiguous_rows() {
        let mut h = Hec::new(8, 100, 3);
        for v in 0..6u32 {
            h.store(v, &emb(v as f32 * 10.0, 3));
        }
        let lines: Vec<u32> = [5u32, 0, 3]
            .iter()
            .map(|&v| h.search(v).unwrap())
            .collect();
        let mut out = vec![0f32; 3 * 3];
        h.load_batch(&lines, &mut out);
        assert_eq!(out, vec![50.0, 50.0, 50.0, 0.0, 0.0, 0.0, 30.0, 30.0, 30.0]);
    }

    #[test]
    fn bf16_cache_rounds_once_and_roundtrips() {
        let mut h = Hec::new_with(8, 4, 3, DtypeKind::Bf16);
        assert_eq!(h.dtype(), DtypeKind::Bf16);
        assert_eq!(h.row_len_bytes(), 6);
        let row = vec![1.0f32, 0.1, -2.5]; // 0.1 is not bf16-exact
        h.store(5, &row);
        let l = h.search(5).unwrap();
        let expect: Vec<u16> = row.iter().map(|&x| bf16::from_f32(x)).collect();
        assert_eq!(h.load_bf16(l), &expect[..]);
        // row_bytes is the little-endian byte view of the same bits
        let rb = h.row_bytes(l);
        for (i, b) in expect.iter().enumerate() {
            assert_eq!(&rb[i * 2..i * 2 + 2], &b.to_le_bytes());
        }
        // load_batch expands to the rounded f32 values
        let mut out = vec![0f32; 3];
        h.load_batch(&[l], &mut out);
        assert_eq!(out, bf16::unpack_slice(&expect));
        // store -> load -> store is lossless after the first rounding
        let again = out.clone();
        h.store(5, &again);
        let l2 = h.search(5).unwrap();
        assert_eq!(h.load_bf16(l2), &expect[..]);
    }

    #[test]
    fn bf16_store_batch_and_raw_push_match_scalar_under_churn() {
        let mut scalar = Hec::new_with(16, 3, 4, DtypeKind::Bf16);
        let mut batched = Hec::new_with(16, 3, 4, DtypeKind::Bf16);
        let mut raw = Hec::new_with(16, 3, 4, DtypeKind::Bf16);
        let mut rng = crate::util::rng::Pcg64::seeded(33);
        for _round in 0..40 {
            let n = 1 + rng.gen_range(40);
            let mut vids = Vec::with_capacity(n);
            let mut rows = Vec::with_capacity(n * 4);
            for _ in 0..n {
                vids.push(rng.gen_range(48) as u32);
                let val = rng.gen_f32();
                rows.extend_from_slice(&[val; 4]);
            }
            for (i, &v) in vids.iter().enumerate() {
                scalar.store(v, &rows[i * 4..(i + 1) * 4]);
            }
            batched.store_batch(&vids, &rows);
            // a bf16 AEP push carries pre-rounded bits: bit-copied on store
            raw.store_batch_bf16(&vids, &bf16::pack_slice(&rows));
            scalar.tick();
            batched.tick();
            raw.tick();
            for v in 0..48u32 {
                let a = scalar.search(v);
                let b = batched.search(v);
                let c = raw.search(v);
                assert_eq!(a.is_some(), b.is_some(), "vid {v}");
                assert_eq!(a.is_some(), c.is_some(), "vid {v}");
                if let (Some(la), Some(lb), Some(lc)) = (a, b, c) {
                    assert_eq!(scalar.load_bf16(la), batched.load_bf16(lb), "vid {v}");
                    assert_eq!(scalar.load_bf16(la), raw.load_bf16(lc), "vid {v}");
                }
            }
            assert_eq!(scalar.stats.stores, batched.stats.stores);
            assert_eq!(scalar.stats.evictions, raw.stats.evictions);
            scalar.check_invariants();
            batched.check_invariants();
            raw.check_invariants();
        }
        assert!(batched.stats.evictions > 0, "test must exercise eviction");
    }

    #[test]
    fn load_batch_bytes_matches_row_bytes_for_both_dtypes() {
        for dtype in [DtypeKind::F32, DtypeKind::Bf16] {
            let mut h = Hec::new_with(8, 100, 3, dtype);
            for v in 0..6u32 {
                h.store(v, &emb(v as f32 * 10.0, 3));
            }
            let lines: Vec<u32> = [5u32, 0, 3].iter().map(|&v| h.search(v).unwrap()).collect();
            let mut out = vec![0u8; lines.len() * h.row_len_bytes()];
            h.load_batch_bytes(&lines, &mut out);
            let rb = h.row_len_bytes();
            for (i, &l) in lines.iter().enumerate() {
                assert_eq!(&out[i * rb..(i + 1) * rb], h.row_bytes(l), "{dtype:?}");
            }
        }
    }

    #[test]
    fn f32_cache_expands_a_bf16_push() {
        let mut h = Hec::new(4, 10, 2);
        let bits = bf16::pack_slice(&[1.5, -0.75]);
        h.store_bf16(9, &bits);
        let l = h.search(9).unwrap();
        assert_eq!(h.load(l), &[1.5, -0.75]);
        h.store_batch_bf16(&[10], &bits);
        let l2 = h.search(10).unwrap();
        assert_eq!(h.load(l2), &[1.5, -0.75]);
    }

    #[test]
    fn hit_rate_computation() {
        let s = HecStats {
            searches: 10,
            hits: 7,
            ..Default::default()
        };
        assert!((s.hit_rate() - 0.7).abs() < 1e-12);
        assert_eq!(HecStats::default().hit_rate(), 0.0);
        let s = HecStats {
            searches: 10,
            hits: 6,
            prefetch_landed: 2,
            prefetch_late: 1,
            prefetch_wasted: 1,
            ..Default::default()
        };
        assert!((s.effective_hit_rate() - 0.8).abs() < 1e-12);
        assert!((s.prefetch_coverage() - 0.5).abs() < 1e-12);
        assert_eq!(HecStats::default().prefetch_coverage(), 0.0);
    }

    #[test]
    fn probe_is_side_effect_free() {
        let mut h = Hec::new(4, 1, 2);
        h.store(7, &emb(1.0, 2));
        let stats_before = h.stats;
        assert!(h.probe(7));
        assert!(!h.probe(8));
        h.tick();
        h.tick(); // age 2 > ls=1: expired
        assert!(!h.probe(7), "expired line must probe as a miss");
        assert_eq!(h.len(), 1, "probe must not purge the expired line");
        assert_eq!(h.stats.searches, stats_before.searches);
        assert_eq!(h.stats.hits, stats_before.hits);
        assert_eq!(h.stats.expired_purges, 0);
        h.check_invariants();
    }

    #[test]
    fn reuse_policy_pins_survive_eviction_and_unpin_releases() {
        let mut h = Hec::new(2, 1000, 1).with_policy(HecPolicyKind::Reuse);
        h.store(1, &emb(1.0, 1));
        h.tick();
        h.store(2, &emb(2.0, 1));
        h.pin(1); // 1 is the OCF victim, but pinned
        h.tick();
        h.store(3, &emb(3.0, 1)); // must evict 2 instead
        assert!(h.search(1).is_some(), "pinned line evicted");
        assert!(h.search(2).is_none());
        assert!(h.search(3).is_some());
        assert!(h.stats.pin_protected > 0);
        h.check_invariants();
        // fully pinned cache refuses the store instead of evicting
        h.pin(3);
        h.tick();
        h.store(4, &emb(4.0, 1));
        h.store(4, &emb(4.0, 1));
        assert!(h.search(4).is_none(), "store into fully pinned cache must drop");
        assert_eq!(h.stats.pinned_drops, 2);
        h.check_invariants();
        // unpin re-enables eviction (1 is oldest -> victim)
        h.unpin(1);
        // drain 1's reuse credit (earned by the search hits above)
        while {
            h.store(5, &emb(5.0, 1));
            h.probe(1) && !h.probe(5)
        } {}
        assert!(h.probe(5), "unpinned cache must accept stores again");
        h.check_invariants();
    }

    #[test]
    fn reuse_policy_hot_line_gets_second_chance() {
        let mut h = Hec::new(2, 1000, 1).with_policy(HecPolicyKind::Reuse);
        h.store(1, &emb(1.0, 1));
        h.tick();
        h.store(2, &emb(2.0, 1));
        // heat line 1 (the OCF victim): one hit = one lap of protection
        assert!(h.search(1).is_some());
        h.tick();
        h.store(3, &emb(3.0, 1)); // second chance spares 1, evicts 2
        assert!(h.probe(1), "hot line must survive its first eviction turn");
        assert!(!h.probe(2));
        assert!(h.probe(3));
        assert_eq!(h.stats.reuse_deferrals, 1);
        // credit spent: next eviction takes 1 (oldest, now cold)
        h.tick();
        h.store(4, &emb(4.0, 1));
        assert!(!h.probe(1), "cold line must be evicted on its next turn");
        h.check_invariants();
    }

    #[test]
    fn reuse_policy_prefers_expired_victims_and_clear_pins_resets() {
        let mut h = Hec::new(2, 1, 1).with_policy(HecPolicyKind::Reuse);
        h.store(1, &emb(1.0, 1));
        assert!(h.search(1).is_some()); // hot
        h.tick();
        h.store(2, &emb(2.0, 1));
        h.tick();
        h.tick(); // 1 expired (age 3 > ls 1); hot but dead
        h.store(3, &emb(3.0, 1));
        assert!(!h.probe(1), "expired line evicted despite reuse credit");
        assert_eq!(h.stats.reuse_deferrals, 0);
        h.pin(2);
        h.pin(3);
        assert_eq!(h.pinned_tags(), 2);
        h.clear_pins();
        assert_eq!(h.pinned_tags(), 0);
        h.tick();
        h.store(4, &emb(4.0, 1));
        assert_eq!(h.stats.pinned_drops, 0, "cleared pins must not refuse");
        h.check_invariants();
    }

    #[test]
    fn ocf_policy_is_unchanged_by_pins_and_reuse_credit() {
        // under the default policy, pins and search heat must not disturb
        // the paper's OCF contract
        let mut h = Hec::new(2, 1000, 1);
        h.store(1, &emb(1.0, 1));
        h.tick();
        h.store(2, &emb(2.0, 1));
        h.pin(1);
        assert!(h.search(1).is_some()); // would earn credit under Reuse
        h.tick();
        h.store(3, &emb(3.0, 1));
        assert!(!h.probe(1), "OCF must evict the oldest line regardless");
        assert_eq!(h.stats.pin_protected, 0);
        assert_eq!(h.stats.reuse_deferrals, 0);
        h.check_invariants();
    }
}
