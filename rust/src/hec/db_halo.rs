//! db_halo: "one of the most important data structures in DistGNN-MB".
//!
//! On each rank it records, for every *local solid* vertex, the set of
//! remote ranks where that vertex appears as a halo. It is built at
//! initialization from a broadcast of every rank's halo lists (Algorithm 1:
//! `B <- Bcast(hv); db_halo <- CreateDB(B)`).
//!
//! The `Map` function (Algorithm 2 line 18) — "one of the most expensive
//! operations in DistGNN-MB" — maps the solid vertices of the current
//! minibatch to the subset needed by a given remote rank.

use std::collections::HashMap;

use crate::graph::Vid;
use crate::partition::RankPartition;
use crate::util::parallel;

pub struct DbHalo {
    /// My rank.
    pub rank: u32,
    pub k: usize,
    /// solid VID_o -> sorted list of remote ranks holding it as halo.
    map: HashMap<Vid, Vec<u32>>,
}

/// The slice of a rank's partition the db_halo broadcast actually reads:
/// its halo LUT tail and ownership table. On the out-of-core path these
/// borrow mapped shard sections directly, so building the database never
/// materializes remote ranks' full partitions (no feature block, no
/// VID_o→VID_p hash map — just two mapped arrays per remote shard).
pub struct HaloView<'a> {
    pub rank: u32,
    pub n_solid: usize,
    pub vid_o: &'a [Vid],
    pub halo_owner: &'a [u32],
}

impl<'a> HaloView<'a> {
    pub fn of(part: &'a RankPartition) -> HaloView<'a> {
        HaloView {
            rank: part.rank,
            n_solid: part.n_solid,
            vid_o: &part.vid_o,
            halo_owner: &part.halo_owner,
        }
    }
}

impl DbHalo {
    /// Build from all ranks' halo lists (the broadcast). `halos_by_owner[r]`
    /// is what rank r broadcast: for each owner rank, the halo VID_o it
    /// needs from that owner.
    pub fn create(rank: u32, parts: &[&RankPartition]) -> DbHalo {
        let views: Vec<HaloView> = parts.iter().map(|p| HaloView::of(p)).collect();
        Self::create_from_views(rank, &views)
    }

    /// Build from lightweight halo views (one per rank, in rank order).
    pub fn create_from_views(rank: u32, views: &[HaloView]) -> DbHalo {
        let k = views.len();
        let mut map: HashMap<Vid, Vec<u32>> = HashMap::new();
        for remote in views {
            if remote.rank == rank {
                continue;
            }
            // remote's halos owned by `rank`
            for (h, &owner) in remote.halo_owner.iter().enumerate() {
                if owner == rank {
                    let vid_o = remote.vid_o[remote.n_solid + h];
                    map.entry(vid_o).or_default().push(remote.rank);
                }
            }
        }
        for v in map.values_mut() {
            v.sort_unstable();
        }
        DbHalo { rank, k, map }
    }

    /// Number of solid vertices that are halo somewhere.
    pub fn len(&self) -> usize {
        self.map.len()
    }
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Does any remote rank need this solid vertex?
    pub fn is_needed(&self, vid_o: Vid) -> bool {
        self.map.contains_key(&vid_o)
    }

    /// Map (Algorithm 2 line 18): restrict `solids` (VID_o) to those that
    /// are halo on `remote_rank`. Thread-parallel like the paper's OpenMP
    /// implementation; order-preserving.
    pub fn map_solids(&self, solids: &[Vid], remote_rank: u32) -> Vec<Vid> {
        let hit = |v: &Vid| {
            self.map
                .get(v)
                .map(|ranks| ranks.binary_search(&remote_rank).is_ok())
                .unwrap_or(false)
        };
        if parallel::num_threads() <= 1 || solids.len() < 4096 {
            // serial fast path (hot in the AEP push; thread spawn overhead
            // dwarfs the hash probes below this size)
            return solids.iter().copied().filter(hit).collect();
        }
        let flags = parallel::parallel_map(solids.len(), |i| hit(&solids[i]));
        solids
            .iter()
            .zip(flags)
            .filter_map(|(&v, f)| if f { Some(v) } else { None })
            .collect()
    }

    /// Batched Map: restrict `solids` to the subset needed by *every*
    /// remote rank in one hash pass (`out[j]` = solids halo on rank j,
    /// order-preserving). The per-rank [`map_solids`] form probes the hash
    /// once per (solid, rank) pair; the AEP push calls Map for all k-1
    /// remote ranks every iteration, so this batched form cuts the hash
    /// traffic of the push hot path by ~(k-1)x.
    pub fn map_solids_multi(&self, solids: &[Vid]) -> Vec<Vec<Vid>> {
        let mut out: Vec<Vec<Vid>> = vec![Vec::new(); self.k];
        for &v in solids {
            if let Some(ranks) = self.map.get(&v) {
                for &r in ranks {
                    out[r as usize].push(v);
                }
            }
        }
        out
    }

    /// All remote ranks needing `vid_o` (for stats/tests).
    pub fn ranks_needing(&self, vid_o: Vid) -> &[u32] {
        self.map.get(&vid_o).map(|v| v.as_slice()).unwrap_or(&[])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DatasetPreset;
    use crate::partition::metis_like::MetisLikePartitioner;
    use crate::partition::{materialize, Partitioner};

    fn setup(k: usize) -> Vec<RankPartition> {
        let ds = DatasetPreset::tiny().generate();
        let a = MetisLikePartitioner::default().partition(&ds.graph, &ds.train_vertices, k, 5);
        materialize(&ds, &a)
    }

    #[test]
    fn db_matches_remote_halo_lists() {
        let parts = setup(4);
        let refs: Vec<&RankPartition> = parts.iter().collect();
        for p in &parts {
            let db = DbHalo::create(p.rank, &refs);
            // every entry is a solid of p and actually halo on the claimed rank
            for remote in &parts {
                if remote.rank == p.rank {
                    continue;
                }
                let mut expected: Vec<Vid> = remote
                    .halo_owner
                    .iter()
                    .enumerate()
                    .filter(|(_, &o)| o == p.rank)
                    .map(|(h, _)| remote.vid_o[remote.n_solid + h])
                    .collect();
                expected.sort_unstable();
                let mut got: Vec<Vid> = db
                    .map
                    .iter()
                    .filter(|(_, ranks)| ranks.contains(&remote.rank))
                    .map(|(&v, _)| v)
                    .collect();
                got.sort_unstable();
                assert_eq!(got, expected, "rank {} -> {}", p.rank, remote.rank);
            }
        }
    }

    #[test]
    fn map_solids_filters_and_preserves_order() {
        let parts = setup(3);
        let refs: Vec<&RankPartition> = parts.iter().collect();
        let p = &parts[0];
        let db = DbHalo::create(0, &refs);
        let solids: Vec<Vid> = p.vid_o[..p.n_solid].to_vec();
        for remote in 1..3u32 {
            let mapped = db.map_solids(&solids, remote);
            // mapped is a subsequence of solids
            let mut it = solids.iter();
            for &m in &mapped {
                assert!(it.any(|&s| s == m), "order broken");
            }
            for &m in &mapped {
                assert!(db.ranks_needing(m).contains(&remote));
            }
        }
    }

    #[test]
    fn map_solids_multi_matches_per_rank_map() {
        let parts = setup(4);
        let refs: Vec<&RankPartition> = parts.iter().collect();
        for p in &parts {
            let db = DbHalo::create(p.rank, &refs);
            let solids: Vec<Vid> = p.vid_o[..p.n_solid].to_vec();
            let multi = db.map_solids_multi(&solids);
            assert_eq!(multi.len(), 4);
            for r in 0..4u32 {
                assert_eq!(multi[r as usize], db.map_solids(&solids, r), "rank {} -> {r}", p.rank);
            }
            assert!(multi[p.rank as usize].is_empty());
        }
    }

    #[test]
    fn empty_for_single_rank() {
        let parts = setup(1);
        let refs: Vec<&RankPartition> = parts.iter().collect();
        let db = DbHalo::create(0, &refs);
        assert!(db.is_empty());
        assert!(db.map_solids(&[1, 2, 3], 0).is_empty());
    }
}
