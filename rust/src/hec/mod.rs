//! The Historical Embedding Cache (paper §3.2) and the db_halo database.
//!
//! Each rank keeps one [`Hec`] per GNN layer (level 0 caches raw features
//! of remote halo vertices, level l >= 1 caches their layer-l embeddings).
//! Remote ranks fill these caches through the Asynchronous Embedding Push;
//! local minibatches consult them for halo embeddings
//! (HECSearch/HECLoad/HECStore) — a cache miss removes the halo vertex
//! from minibatch execution (Algorithm 2 line 11).

pub mod cache;
pub mod db_halo;

pub use cache::{Hec, HecStats};
pub use db_halo::DbHalo;
