//! The Historical Embedding Cache (paper §3.2) and the db_halo database.
//!
//! Each rank keeps one [`Hec`] per GNN layer (level 0 caches raw features
//! of remote halo vertices, level l >= 1 caches their layer-l embeddings).
//! Remote ranks fill these caches through the Asynchronous Embedding Push;
//! local minibatches consult them for halo embeddings
//! (HECSearch/HECLoad/HECStore) — a cache miss removes the halo vertex
//! from minibatch execution (Algorithm 2 line 11).
//!
//! # Determinism invariant
//!
//! Everything that feeds training state is order-deterministic: batched
//! search/store have element-for-element scalar semantics (including stat
//! counters and OCF eviction order), and batched payload copies write
//! pairwise-disjoint rows, so cache contents are bit-identical for any
//! worker count. This is a prerequisite of the repo-wide bit-identical-
//! loss contract (see `ARCHITECTURE.md`).
//!
//! # Storage precision
//!
//! Line payloads are stored in the run's `--dtype` (f32 default, bf16
//! halves cache bytes; [`crate::runtime::bf16`]). The cache dtype always
//! matches the packer's tensor dtype, so hit rows block-copy into
//! minibatch tensors byte-for-byte ([`Hec::row_bytes`]); bf16 rows round
//! once on store and are bit-preserved thereafter.

pub mod cache;
pub mod db_halo;
pub mod prefetch;

pub use cache::{Hec, HecStats};
pub use db_halo::{DbHalo, HaloView};
pub use prefetch::{halo_vids_per_layer, plan_pulls, PartPrefetchSource, PrefetchOutcome, PrefetchStage};
