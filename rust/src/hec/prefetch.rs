//! Lookahead prefetch side-car for the historical-embedding cache.
//!
//! When the depth-`p` pipeline ring stages a future minibatch, the driver
//! diffs that minibatch's level-0 halo set against the HEC and pulls the
//! misses from their owning ranks ahead of time ([`plan_pulls`]). The
//! pulled rows land in a [`PrefetchStage`] — a *side-car*: prefetch may
//! only move **when** rows arrive, never **what** the packer reads. Staged
//! rows are classified (covered / late / cold) against the rank's virtual
//! clock at the packer's normal read point and then discarded; they are
//! never installed into the HEC and never reach the compute path, so
//! losses are bit-identical with prefetch on or off by construction.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use crate::comm::fabric::{PrefetchSource, PrefetchedRow};
use crate::hec::cache::Hec;
use crate::partition::materialize::RankPartition;
use crate::sampler::block::MinibatchBlocks;

/// What happened to one level-0 halo miss at pack time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrefetchOutcome {
    /// A prefetched row was staged and had arrived by the rank's clock.
    Covered,
    /// The row was requested (possibly even staged) but arrived too late.
    Late,
    /// The miss was never requested — outside the lookahead window.
    Cold,
}

/// Side-car staging area for in-flight and landed prefetch rows.
///
/// Counter invariant: every requested vid is eventually accounted exactly
/// once — `issued == landed + late + wasted` once the stage is drained
/// (end of epoch), with `landed + late` charged at pack time and `wasted`
/// charged to rows still staged or still in flight when the epoch ends.
#[derive(Clone, Debug, Default)]
pub struct PrefetchStage {
    /// VID_o of rows requested but not yet arrived.
    requested: HashSet<u32>,
    /// VID_o -> (arrival virtual-time, row) for rows that have arrived.
    staged: HashMap<u32, (f64, Vec<f32>)>,
    /// Cumulative pull requests issued.
    pub issued: u64,
    /// Requested rows that arrived before the packer needed them.
    pub landed: u64,
    /// Requested rows the packer needed before they arrived.
    pub late: u64,
    /// Requested rows never consumed by any pack (epoch-end leftovers).
    pub wasted: u64,
}

impl PrefetchStage {
    pub fn new() -> PrefetchStage {
        PrefetchStage::default()
    }

    /// Is `vid_o` already covered by an outstanding or landed pull?
    pub fn tracks(&self, vid_o: u32) -> bool {
        self.requested.contains(&vid_o) || self.staged.contains_key(&vid_o)
    }

    /// Number of rows currently staged (arrived, not yet classified).
    pub fn staged_len(&self) -> usize {
        self.staged.len()
    }

    /// Number of rows requested and still in flight.
    pub fn in_flight(&self) -> usize {
        self.requested.len()
    }

    /// Record that a pull for these vids was handed to the fabric.
    pub fn note_issued(&mut self, per_owner: &[Vec<u32>]) {
        for vids in per_owner {
            for &v in vids {
                if self.requested.insert(v) {
                    self.issued += 1;
                }
            }
        }
    }

    /// Land rows drained from the fabric. Unrequested or duplicate rows
    /// (a retried pull, a buggy peer) are dropped — the stage only ever
    /// holds rows it asked for, so the counter invariant survives.
    pub fn land(&mut self, rows: Vec<PrefetchedRow>) {
        for r in rows {
            if self.requested.remove(&r.vid) {
                self.staged.insert(r.vid, (r.arrival, r.row));
            }
        }
    }

    /// Classify one level-0 halo miss at the packer's read point. `now` is
    /// the rank's virtual clock (socket transport passes 0.0 and every
    /// arrival is 0.0, so anything staged counts as covered). Consumes the
    /// vid's staged/requested entry either way.
    pub fn classify(&mut self, vid_o: u32, now: f64) -> PrefetchOutcome {
        if let Some((arrival, _row)) = self.staged.remove(&vid_o) {
            if arrival <= now {
                self.landed += 1;
                PrefetchOutcome::Covered
            } else {
                self.late += 1;
                PrefetchOutcome::Late
            }
        } else if self.requested.remove(&vid_o) {
            self.late += 1;
            PrefetchOutcome::Late
        } else {
            PrefetchOutcome::Cold
        }
    }

    /// Epoch boundary: anything still staged or in flight was pulled for
    /// nothing. Charge it as wasted and clear the stage (the new epoch's
    /// minibatch sequence starts from a clean slate, mirroring the ring
    /// reset).
    pub fn end_epoch(&mut self) {
        self.wasted += (self.staged.len() + self.requested.len()) as u64;
        self.staged.clear();
        self.requested.clear();
    }
}

/// Serve prefetch pulls from a rank's feature shard. Registered with the
/// fabric so peers can pull level-0 feature rows this rank owns.
pub struct PartPrefetchSource {
    part: Arc<RankPartition>,
}

impl PartPrefetchSource {
    pub fn new(part: Arc<RankPartition>) -> PartPrefetchSource {
        PartPrefetchSource { part }
    }
}

impl PrefetchSource for PartPrefetchSource {
    fn dim(&self) -> usize {
        self.part.feat_dim
    }

    fn row(&self, vid_o: u32) -> Option<Vec<f32>> {
        let &vp = self.part.global_to_local.get(&vid_o)?;
        if self.part.is_halo(vp) {
            return None;
        }
        Some(self.part.feature_row(vp).to_vec())
    }
}

/// Diff a staged minibatch's level-0 halo set against the HEC and the
/// stage, grouping the remaining misses by owning rank — the per-owner
/// vid lists handed to `Fabric::prefetch_pull`. `hec0` is the level-0
/// cache; only [`Hec::probe`] is used, so planning has no side effects on
/// cache state or statistics.
pub fn plan_pulls(
    part: &RankPartition,
    mb: &MinibatchBlocks,
    hec0: &Hec,
    stage: &PrefetchStage,
) -> Vec<Vec<u32>> {
    let mut per_owner = vec![Vec::new(); part.k];
    if mb.layers.is_empty() {
        return per_owner;
    }
    let mut seen = HashSet::new();
    for &vp in &mb.layers[0] {
        if !part.is_halo(vp) {
            continue;
        }
        let vo = part.vid_o[vp as usize];
        if !seen.insert(vo) || hec0.probe(vo) || stage.tracks(vo) {
            continue;
        }
        let owner = part.halo_owner[vp as usize - part.n_solid] as usize;
        per_owner[owner].push(vo);
    }
    per_owner
}

/// Deduplicated halo VID_o list per HEC layer for a staged minibatch —
/// the lines a reuse-policy cache pins while the entry is in the ring.
/// `layers[l]` feeds `hecs[l]`; the seed layer (all solid) contributes
/// nothing.
pub fn halo_vids_per_layer(part: &RankPartition, mb: &MinibatchBlocks) -> Vec<Vec<u32>> {
    let mut out = Vec::with_capacity(mb.n_layers());
    for l in 0..mb.n_layers() {
        let mut seen = HashSet::new();
        let mut vids = Vec::new();
        for &vp in &mb.layers[l] {
            if part.is_halo(vp) {
                let vo = part.vid_o[vp as usize];
                if seen.insert(vo) {
                    vids.push(vo);
                }
            }
        }
        out.push(vids);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DatasetPreset;
    use crate::partition::materialize::materialize;
    use crate::partition::metis_like::MetisLikePartitioner;
    use crate::partition::Partitioner;
    use crate::sampler::block::BlockEdges;

    fn two_parts() -> Vec<RankPartition> {
        let ds = DatasetPreset::tiny().generate();
        let a = MetisLikePartitioner::default().partition(&ds.graph, &ds.train_vertices, 2, 3);
        materialize(&ds, &a)
    }

    fn row(vid: u32, arrival: f64) -> PrefetchedRow {
        PrefetchedRow {
            vid,
            arrival,
            row: vec![vid as f32; 4],
        }
    }

    #[test]
    fn stage_classifies_covered_late_and_cold() {
        let mut st = PrefetchStage::new();
        st.note_issued(&[vec![1, 2], vec![3]]);
        assert_eq!(st.issued, 3);
        assert!(st.tracks(2));
        assert_eq!(st.in_flight(), 3);

        // vid 1 arrives early, vid 2 arrives in the future, vid 3 never
        st.land(vec![row(1, 5.0), row(2, 50.0), row(99, 0.0)]);
        assert_eq!(st.staged_len(), 2, "unrequested vid 99 must be dropped");

        assert_eq!(st.classify(1, 10.0), PrefetchOutcome::Covered);
        assert_eq!(st.classify(2, 10.0), PrefetchOutcome::Late);
        assert_eq!(st.classify(3, 10.0), PrefetchOutcome::Late);
        assert_eq!(st.classify(7, 10.0), PrefetchOutcome::Cold);
        assert_eq!((st.landed, st.late), (1, 2));
        // classify consumed everything
        assert_eq!(st.staged_len() + st.in_flight(), 0);

        // re-request after consumption counts as a fresh issue
        st.note_issued(&[vec![1]]);
        assert_eq!(st.issued, 4);
    }

    #[test]
    fn end_epoch_charges_leftovers_as_wasted_and_clears() {
        let mut st = PrefetchStage::new();
        st.note_issued(&[vec![1, 2, 3]]);
        st.land(vec![row(1, 0.0)]);
        st.end_epoch();
        // one staged + two still in flight
        assert_eq!(st.wasted, 3);
        assert!(!st.tracks(1) && !st.tracks(2));
        assert_eq!(st.classify(1, 100.0), PrefetchOutcome::Cold);
        // invariant: issued == landed + late + wasted after drain
        assert_eq!(st.issued, st.landed + st.late + st.wasted);
    }

    #[test]
    fn duplicate_issues_are_counted_once() {
        let mut st = PrefetchStage::new();
        st.note_issued(&[vec![5, 5], vec![5]]);
        assert_eq!(st.issued, 1);
        st.land(vec![row(5, 0.0), row(5, 9.0)]);
        assert_eq!(st.staged_len(), 1);
        st.end_epoch();
        assert_eq!(st.issued, st.landed + st.late + st.wasted);
    }

    #[test]
    fn part_source_serves_solids_and_refuses_halos_and_strangers() {
        let parts = two_parts();
        let p0 = Arc::new(parts[0].clone());
        let src = PartPrefetchSource::new(p0.clone());
        assert_eq!(src.dim(), p0.feat_dim);

        // a solid vertex: row matches the shard exactly
        let vo = p0.vid_o[0];
        let got = src.row(vo).expect("solid row");
        assert_eq!(got, p0.feature_row(0).to_vec());

        // a halo vertex is present locally but NOT served (stale copy)
        if p0.n_halo() > 0 {
            let halo_vo = p0.vid_o[p0.n_solid];
            assert_eq!(src.row(halo_vo), None);
        }

        // a vid this rank has never heard of
        assert_eq!(src.row(u32::MAX), None);
    }

    #[test]
    fn plan_pulls_groups_misses_by_owner_and_skips_probe_hits() {
        let parts = two_parts();
        let part = &parts[0];
        assert!(part.n_halo() > 0, "tiny/2 must produce halos");

        // a minibatch whose level 0 is every local vertex (worst case)
        let mb = MinibatchBlocks {
            layers: vec![(0..part.n_local() as u32).collect(), vec![0]],
            edges: vec![BlockEdges::default()],
            overflow_nodes: 0,
            overflow_edges: 0,
        };

        let mut hec = Hec::new(1 << 12, 4, part.feat_dim);
        let stage = PrefetchStage::new();
        let pulls = plan_pulls(part, &mb, &hec, &stage);
        assert_eq!(pulls.len(), part.k);
        assert!(pulls[part.rank as usize].is_empty(), "never pull from self");
        let total: usize = pulls.iter().map(|v| v.len()).sum();
        assert_eq!(total, part.n_halo(), "cold cache: every halo is a miss");
        for (owner, vids) in pulls.iter().enumerate() {
            for &vo in vids {
                let vp = part.global_to_local[&vo];
                assert_eq!(part.halo_owner[vp as usize - part.n_solid], owner as u32);
            }
        }

        // warm one halo line into the cache: it drops out of the plan
        let first = pulls.iter().find(|v| !v.is_empty()).unwrap()[0];
        hec.store(first, &vec![0.0; part.feat_dim]);
        let pulls2 = plan_pulls(part, &mb, &hec, &stage);
        let total2: usize = pulls2.iter().map(|v| v.len()).sum();
        assert_eq!(total2, part.n_halo() - 1);
        assert!(pulls2.iter().all(|v| !v.contains(&first)));

        // a vid already tracked by the stage also drops out
        let mut stage = PrefetchStage::new();
        stage.note_issued(&pulls2);
        let pulls3 = plan_pulls(part, &mb, &hec, &stage);
        assert!(pulls3.iter().all(|v| v.is_empty()));
    }

    #[test]
    fn halo_vids_per_layer_dedupes_within_each_layer() {
        let parts = two_parts();
        let part = &parts[0];
        assert!(part.n_halo() > 0);
        let h0 = part.n_solid as u32; // first halo VID_p
        let mb = MinibatchBlocks {
            layers: vec![vec![0, h0, h0, 1], vec![0, h0], vec![0]],
            edges: vec![BlockEdges::default(), BlockEdges::default()],
            overflow_nodes: 0,
            overflow_edges: 0,
        };
        let per_layer = halo_vids_per_layer(part, &mb);
        assert_eq!(per_layer.len(), 2);
        let halo_vo = part.vid_o[h0 as usize];
        assert_eq!(per_layer[0], vec![halo_vo]);
        assert_eq!(per_layer[1], vec![halo_vo]);
    }
}
