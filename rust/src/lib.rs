//! # DistGNN-MB
//!
//! Reproduction of *DistGNN-MB: Distributed Large-Scale Graph Neural Network
//! Training on x86 via Minibatch Sampling* (Md et al., 2022) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the paper's coordination contribution:
//!   graph partitioning with training-vertex balance, thread-parallel
//!   minibatch neighbor sampling, the Historical Embedding Cache (HEC),
//!   the `db_halo` solid→halo database, the Asynchronous Embedding Push
//!   (AEP) training loop with compute/communication overlap, gradient
//!   all-reduce, and a virtual-time cluster driver that models a multi-rank
//!   x86 cluster on a single host.
//! * **Layer 2 (python/compile/model.py)** — GraphSAGE and GAT forward /
//!   backward as JAX programs over padded message-flow graphs, AOT-lowered
//!   to HLO text once at build time (`make artifacts`).
//! * **Layer 1 (python/compile/kernels/)** — the paper's fused UPDATE
//!   primitive (matmul + bias + ReLU + dropout) as Pallas kernels with
//!   custom VJPs, standing in for the paper's LIBXSMM TPP kernels.
//!
//! Python never runs on the training path: the Rust binary loads the
//! AOT-compiled artifacts through PJRT (`runtime`) and drives everything.
//!
//! `ARCHITECTURE.md` (repo root) maps the modules and the load-bearing
//! contracts: the stage/exec/finish pipeline ("moves when work runs,
//! never what runs"), the [`comm::Fabric`] iteration-window delivery
//! semantics, and the bf16 storage seam ([`runtime::bf16`],
//! `--dtype bf16`) that halves feature/HEC/push bytes while all math
//! accumulates in f32. This rustdoc is the canonical API reference —
//! CI builds it with `RUSTDOCFLAGS="-D warnings"`.

pub mod benchkit;
pub mod comm;
pub mod config;
pub mod graph;
pub mod hec;
pub mod model;
pub mod partition;
pub mod runtime;
pub mod sampler;
pub mod serve;
pub mod train;
pub mod util;
