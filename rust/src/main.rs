//! DistGNN-MB command-line interface.
//!
//! Subcommands:
//!   train      — run distributed minibatch training (AEP / DistDGL / NoComm)
//!   serve      — load a checkpoint and score vertex ids over a unix socket
//!   generate   — generate a dataset preset and print Table-1-style stats
//!   partition  — compare partitioners on a preset (edge-cut / balance / halos)
//!   shard      — write an out-of-core shard set (preset or streamed R-MAT)
//!   inspect    — list the artifact manifest programs
//!
//! Example:
//!   distgnn-mb train --preset products-mini --model sage --ranks 4 \
//!       --epochs 3 --eval-every 1 --report report.json

use anyhow::{Context, Result};

use distgnn_mb::benchkit;
use distgnn_mb::comm::faults;
use distgnn_mb::config::{
    DtypeKind, FabricKind, HecPolicyKind, ModelKind, SamplerKind, TrainConfig, TrainMode,
};
use distgnn_mb::util::json;
use distgnn_mb::graph::{generator, io as graph_io, DatasetPreset};
use distgnn_mb::partition::{
    self, ldg::LdgPartitioner, metis_like::MetisLikePartitioner, random::RandomPartitioner,
    Partitioner, PartitionStats,
};
use distgnn_mb::runtime::Manifest;
use distgnn_mb::serve::{ScoreEngine, ServeOptions, Server};
use distgnn_mb::train::Driver;
use distgnn_mb::util::logging;

/// Minimal `--key value` / `--flag` argument parser.
struct Args {
    cmd: String,
    kv: std::collections::BTreeMap<String, String>,
}

impl Args {
    fn parse() -> Result<Args> {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "help".to_string());
        let mut kv = std::collections::BTreeMap::new();
        let rest: Vec<String> = it.collect();
        let mut i = 0;
        while i < rest.len() {
            let key = rest[i]
                .strip_prefix("--")
                .ok_or_else(|| anyhow::anyhow!("expected --flag, got '{}'", rest[i]))?
                .to_string();
            if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                kv.insert(key, rest[i + 1].clone());
                i += 2;
            } else {
                kv.insert(key, "true".to_string());
                i += 1;
            }
        }
        Ok(Args { cmd, kv })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.kv.get(key).map(|s| s.as_str())
    }

    fn usize_of(&self, key: &str) -> Result<Option<usize>> {
        self.get(key)
            .map(|v| v.parse::<usize>().with_context(|| format!("--{key} {v}")))
            .transpose()
    }

    fn f64_of(&self, key: &str) -> Result<Option<f64>> {
        self.get(key)
            .map(|v| v.parse::<f64>().with_context(|| format!("--{key} {v}")))
            .transpose()
    }
}

fn build_config(args: &Args) -> Result<TrainConfig> {
    let mut cfg = if let Some(path) = args.get("config") {
        TrainConfig::load_file(path)?
    } else {
        TrainConfig::default()
    };
    if let Some(v) = args.get("preset") {
        cfg.preset = v.to_string();
    }
    if let Some(v) = args.get("model") {
        cfg.model = ModelKind::parse(v)?;
    }
    if let Some(v) = args.usize_of("ranks")? {
        cfg.ranks = v;
    }
    if let Some(v) = args.usize_of("epochs")? {
        cfg.epochs = v;
    }
    if let Some(v) = args.f64_of("lr")? {
        cfg.lr = v as f32;
    }
    if let Some(v) = args.usize_of("seed")? {
        cfg.seed = v as u64;
    }
    if let Some(v) = args.get("mode") {
        cfg.mode = TrainMode::parse(v)?;
    }
    if let Some(v) = args.get("sampler") {
        cfg.sampler = SamplerKind::parse(v)?;
    }
    if let Some(v) = args.get("partitioner") {
        cfg.partitioner = v.to_string();
    }
    if let Some(v) = args.usize_of("hec-cs")? {
        cfg.hec.cs = v;
    }
    if let Some(v) = args.usize_of("hec-nc")? {
        cfg.hec.nc = v;
    }
    if let Some(v) = args.usize_of("hec-ls")? {
        cfg.hec.ls = v as u32;
    }
    if let Some(v) = args.usize_of("hec-d")? {
        cfg.hec.d = v;
    }
    if let Some(v) = args.get("hec-policy") {
        cfg.hec.policy = HecPolicyKind::parse(v)?;
    }
    if let Some(v) = args.get("hec-prefetch") {
        cfg.hec.prefetch = match v {
            "true" | "1" | "on" => true,
            "false" | "0" | "off" => false,
            other => anyhow::bail!("--hec-prefetch {other} (expected on|off)"),
        };
    }
    if let Some(v) = args.usize_of("eval-every")? {
        cfg.eval_every = v;
    }
    if let Some(v) = args.usize_of("max-mb")? {
        cfg.max_minibatches = Some(v);
    }
    if let Some(v) = args.get("artifacts") {
        cfg.artifacts_dir = v.to_string();
    }
    if let Some(v) = args.get("optimizer") {
        cfg.optimizer = v.to_string();
    }
    if let Some(v) = args.usize_of("pipeline-depth")? {
        cfg.pipeline_depth = v;
    }
    if let Some(v) = args.get("dtype") {
        cfg.dtype = DtypeKind::parse(v)?;
    }
    if let Some(v) = args.get("fabric") {
        cfg.fabric = FabricKind::parse(v)?;
    }
    if let Some(v) = args.usize_of("rank")? {
        cfg.rank = v;
    }
    if let Some(v) = args.get("peers") {
        cfg.peers = v.split(',').map(|p| p.trim().to_string()).collect();
        // `--ranks` defaults to the peer count when not given explicitly
        if args.get("ranks").is_none() {
            cfg.ranks = cfg.peers.len();
        }
    }
    if let Some(v) = args.get("hosts") {
        cfg.hosts = v.to_string();
    }
    if let Some(v) = args.usize_of("push-batch")? {
        cfg.push_batch = v;
    }
    if let Some(v) = args.get("data-cache") {
        cfg.data_cache = v.to_string();
    }
    if let Some(v) = args.get("fault-plan") {
        cfg.fault_plan = v.to_string();
    }
    if let Some(v) = args.usize_of("ckpt-every")? {
        cfg.ckpt_every = v;
    }
    if let Some(v) = args.get("ckpt") {
        cfg.ckpt_path = v.to_string();
    }
    if let Some(v) = args.get("data-shards") {
        cfg.data_shards = v.to_string();
    }
    if let Some(v) = args.get("shards-mmap") {
        cfg.data_shards_mmap = match v {
            "true" | "1" | "on" => true,
            "false" | "0" | "off" => false,
            other => anyhow::bail!("--shards-mmap {other} (expected on|off)"),
        };
    }
    if let Some(v) = args.usize_of("serve-deadline-ms")? {
        cfg.serve_deadline_ms = v as u64;
    }
    if let Some(v) = args.usize_of("serve-queue")? {
        cfg.serve_queue = v;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<()> {
    // Supervised mode: run the training command as a child and relaunch
    // it from the last checkpoint when it dies retryably.
    if let Some(n) = args.usize_of("restarts")? {
        return supervise(args, n);
    }
    // Config/flag errors (unknown --mode/--fabric value, bad peer count,
    // malformed numbers) are usage errors: print the usage block and exit
    // nonzero. Runtime failures below propagate without the usage dump.
    let cfg = match build_config(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e:#}\n\n{}", usage());
            std::process::exit(2);
        }
    };
    let target = args.f64_of("target-acc")?;
    println!("config: {}", cfg.to_json().to_json());
    let mut driver = Driver::new(cfg)?;
    if let Some(path) = args.get("resume") {
        // bit-exact continuation of an interrupted run: restores the
        // training cursor and replays RNG streams (vs. --load-ckpt, a
        // weights-only warm start that begins a fresh run)
        driver.resume_from(path)?;
    } else if let Some(path) = args.get("load-ckpt") {
        let epoch = driver.load_checkpoint(path)?;
        println!("warm start from {path} (epoch {epoch})");
    }
    let report = driver.train(target)?.clone();
    if let Some(path) = args.get("save-ckpt") {
        driver.save_checkpoint(path, report.epochs.len())?;
        println!("checkpoint written to {path}");
    }
    if let Some(section) = args.get("bench-section") {
        // machine-readable run summary (CI smoke uploads this as
        // BENCH_fabric.json via DISTGNN_BENCH_OUT)
        let last = report.epochs.last();
        benchkit::write_bench_section(
            section,
            vec![
                ("fabric", json::s(driver.cfg.fabric.as_str())),
                ("rank", json::num(driver.cfg.rank as f64)),
                ("ranks", json::num(driver.cfg.ranks as f64)),
                ("epochs", json::num(report.epochs.len() as f64)),
                ("mean_epoch_time", json::num(report.mean_epoch_time(1))),
                (
                    "comm_clock",
                    json::s(if last.map(|e| e.comm_wall).unwrap_or(false) {
                        "wall"
                    } else {
                        "modeled"
                    }),
                ),
                (
                    "comm_bytes",
                    json::num(last.map(|e| e.comm_bytes as f64).unwrap_or(0.0)),
                ),
                (
                    "comm_wire_bytes",
                    json::num(last.map(|e| e.comm_wire_bytes as f64).unwrap_or(0.0)),
                ),
                ("hosts", json::s(&driver.cfg.hosts)),
                ("push_batch", json::num(driver.cfg.push_batch as f64)),
                (
                    "aep_flight",
                    json::num(last.map(|e| e.aep_flight).unwrap_or(0.0)),
                ),
                (
                    "aep_wait",
                    json::num(last.map(|e| e.aep_wait).unwrap_or(0.0)),
                ),
                (
                    "pipeline_depth",
                    json::num(last.map(|e| e.pipeline_depth as f64).unwrap_or(0.0)),
                ),
                (
                    "mbc_hidden",
                    json::num(last.map(|e| e.mbc_hidden).unwrap_or(0.0)),
                ),
                (
                    "prefetch_issued",
                    json::num(last.map(|e| e.prefetch_issued as f64).unwrap_or(0.0)),
                ),
                (
                    "prefetch_landed",
                    json::num(last.map(|e| e.prefetch_landed as f64).unwrap_or(0.0)),
                ),
                (
                    "prefetch_coverage",
                    json::num(last.map(|e| e.prefetch_coverage()).unwrap_or(0.0)),
                ),
                (
                    "hec_stall_secs",
                    json::num(last.map(|e| e.hec_stall_secs).unwrap_or(0.0)),
                ),
                (
                    "final_loss",
                    json::num(last.map(|e| e.train_loss).unwrap_or(f64::NAN)),
                ),
            ],
        )?;
    }
    driver.shutdown()?;
    println!(
        "mean epoch time (skip 1): {:.3}s over {} epochs",
        report.mean_epoch_time(1),
        report.epochs.len()
    );
    if let Some(e) = report.converged_epoch {
        println!("converged at epoch {e}");
    }
    if let Some(a) = report.final_test_acc {
        println!("final test accuracy: {a:.4}");
    }
    if let Some(path) = args.get("report") {
        std::fs::write(path, report.to_json().to_json_pretty())?;
        println!("report written to {path}");
    }
    Ok(())
}

/// Supervise a training run: spawn this binary as a child (same command,
/// `--restarts`/`--resume` stripped), and relaunch it when it dies
/// *retryably* — exit code [`faults::EXIT_RETRYABLE`] (typed peer death /
/// injected fault) or death by signal (SIGKILL, SIGABRT). Each relaunch
/// waits a deterministic exponential backoff, exports the attempt number
/// as `DISTGNN_RESTART_GEN` (so a generation-gated fault plan does not
/// re-kill the restarted incarnation), and resumes from the `--ckpt` file
/// when one has been written.
fn supervise(args: &Args, restarts: usize) -> Result<()> {
    let exe = std::env::current_exe().context("resolving current executable")?;
    let ckpt = args.get("ckpt").map(|s| s.to_string());
    let mut attempt = 0usize;
    loop {
        let mut cmd = std::process::Command::new(&exe);
        cmd.arg(&args.cmd);
        for (k, v) in &args.kv {
            if k == "restarts" || k == "resume" {
                continue;
            }
            // valueless flags were stored as "true"; re-emitting them as
            // `--flag true` parses identically
            cmd.arg(format!("--{k}")).arg(v);
        }
        if attempt > 0 {
            if let Some(ck) = ckpt.as_deref() {
                if std::path::Path::new(ck).exists() {
                    cmd.arg("--resume").arg(ck);
                }
            }
        }
        cmd.env(faults::RESTART_GEN_ENV, attempt.to_string());
        eprintln!(
            "supervisor: launching attempt {attempt} (restart budget {restarts})"
        );
        let status = cmd.status().context("spawning training child")?;
        if status.success() {
            return Ok(());
        }
        let retryable =
            status.code().is_none() || status.code() == Some(faults::EXIT_RETRYABLE);
        if !retryable {
            eprintln!("supervisor: child failed permanently ({status})");
            std::process::exit(status.code().unwrap_or(2));
        }
        if attempt >= restarts {
            eprintln!("supervisor: restart budget ({restarts}) exhausted ({status})");
            std::process::exit(status.code().unwrap_or(faults::EXIT_RETRYABLE));
        }
        let delay = faults::backoff_delay(attempt as u32, 200, 5000);
        eprintln!("supervisor: child died retryably ({status}); relaunching in {delay:?}");
        std::thread::sleep(delay);
        attempt += 1;
    }
}

/// Long-lived serving mode: restore a checkpoint, compose the whole
/// cluster in-process, and answer SCORE_REQ frames on a Unix socket
/// with deadline-batched forward-only passes (see `serve` module docs).
fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = match build_config(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e:#}\n\n{}", usage());
            std::process::exit(2);
        }
    };
    let ckpt = args
        .get("ckpt")
        .ok_or_else(|| anyhow::anyhow!("serve needs --ckpt FILE (a trained checkpoint)"))?;
    let socket = args
        .get("serve-socket")
        .ok_or_else(|| anyhow::anyhow!("serve needs --serve-socket PATH (unix socket)"))?;
    // CLI-level stop condition for smoke tests: exit once N requests
    // have received a reply. Without it the server runs until killed.
    let max_processed = args.usize_of("serve-max")?;
    println!("config: {}", cfg.to_json().to_json());
    let opts = ServeOptions::from_config(&cfg, socket);
    let engine = ScoreEngine::new(cfg, ckpt)?;
    println!(
        "serving {} vertices ({} classes, batch {}) on {socket} \
         [deadline {:?}, queue {}]",
        engine.num_hosted(),
        engine.num_classes(),
        engine.batch(),
        opts.deadline,
        opts.queue
    );
    let server = Server::start(engine, opts)?;
    let started = std::time::Instant::now();
    let mut last_log = 0u64;
    loop {
        std::thread::sleep(std::time::Duration::from_millis(200));
        let m = server.metrics();
        if let Some(n) = max_processed {
            if m.processed() >= n as u64 {
                break;
            }
        }
        // log roughly every 5s of uptime, but only when traffic moved
        let tick = started.elapsed().as_secs() / 5;
        if tick > last_log && m.processed() > 0 {
            last_log = tick;
            println!("serve: {}", m.render());
        }
    }
    let m = server.stop()?;
    println!("serve: {}", m.render());
    if let Some(section) = args.get("bench-section") {
        benchkit::write_bench_section(
            section,
            vec![
                ("served", json::num(m.served as f64)),
                ("rejected", json::num(m.rejected as f64)),
                ("bad_requests", json::num(m.bad_requests as f64)),
                ("batches", json::num(m.batches as f64)),
                ("p50_ms", json::num(m.p50() * 1e3)),
                ("p99_ms", json::num(m.p99() * 1e3)),
                ("hec_hit_rate", json::num(m.hit_rate())),
            ],
        )?;
    }
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let name = args.get("preset").unwrap_or("tiny");
    let preset = DatasetPreset::by_name(name)?;
    let ds = graph_io::load_or_generate(&preset, args.get("cache").unwrap_or("data-cache"))?;
    println!(
        "{:<18} {:>9} {:>11} {:>6} {:>7} {:>9} {:>9}",
        "dataset", "#vertex", "#edge", "#feat", "#class", "#train", "#test"
    );
    println!("{}", ds.table1_row());
    println!(
        "mean degree {:.1}, max degree {}",
        ds.graph.mean_degree(),
        ds.graph.max_degree()
    );
    Ok(())
}

fn cmd_partition(args: &Args) -> Result<()> {
    let name = args.get("preset").unwrap_or("tiny");
    let k = args.usize_of("ranks")?.unwrap_or(4);
    let seed = args.usize_of("seed")?.unwrap_or(42) as u64;
    let preset = DatasetPreset::by_name(name)?;
    let ds = graph_io::load_or_generate(&preset, args.get("cache").unwrap_or("data-cache"))?;
    let partitioners: Vec<Box<dyn Partitioner>> = vec![
        Box::new(MetisLikePartitioner::default()),
        Box::new(LdgPartitioner),
        Box::new(RandomPartitioner),
    ];
    for p in partitioners {
        let t0 = std::time::Instant::now();
        let a = p.partition(&ds.graph, &ds.train_vertices, k, seed);
        let dt = t0.elapsed().as_secs_f64();
        let stats = PartitionStats::compute(&ds.graph, &ds.train_vertices, &a);
        println!("{}  ({dt:.2}s)", stats.render(p.name()));
    }
    Ok(())
}

/// Write an out-of-core shard set (`shards.json` + `shard-r<rank>.dshd`)
/// that `train --data-shards DIR` later maps instead of regenerating and
/// repartitioning.
///
/// Two paths:
/// * preset (default): generate the preset dataset, partition it, and
///   stream each rank's partition into a shard. A `--data-shards` run
///   over these shards is bit-identical to a vanilla run of the same
///   preset/partitioner/seed.
/// * synthetic (`--scale`/`--edges` given): draw an R-MAT graph of
///   `2^scale` vertices directly into shards without ever holding it in
///   RAM — the 10⁸–10⁹-edge papers100M-class path. `--preset` then only
///   supplies the shapes (feat_dim / classes / noise).
fn cmd_shard(args: &Args) -> Result<()> {
    let out = args
        .get("out")
        .ok_or_else(|| anyhow::anyhow!("shard needs --out DIR"))?;
    let name = args.get("preset").unwrap_or("tiny");
    let k = args.usize_of("ranks")?.unwrap_or(2);
    let seed = args.usize_of("seed")?.unwrap_or(42) as u64;
    let dir = std::path::Path::new(out);
    if let Some(scale) = args.usize_of("scale")? {
        let edges = args
            .usize_of("edges")?
            .unwrap_or_else(|| 16usize << scale.min(34));
        let mut gc = generator::ShardGenConfig::new(name, scale as u32, edges as u64, k, seed);
        if let Some(t) = args.usize_of("train-per-mille")? {
            gc.train_per_mille = t as u32;
        }
        if let Some(t) = args.usize_of("test-per-mille")? {
            gc.test_per_mille = t as u32;
        }
        let t0 = std::time::Instant::now();
        let stats = generator::generate_rmat_shards(&gc, dir)?;
        println!(
            "sharded R-MAT: 2^{scale} vertices, {} edge draws -> {} directed edges, \
             {} ranks, {:.1} MiB in {:.2}s -> {out}",
            stats.edge_draws,
            stats.directed_edges,
            k,
            stats.bytes_written as f64 / (1024.0 * 1024.0),
            t0.elapsed().as_secs_f64()
        );
    } else {
        let preset = DatasetPreset::by_name(name)?;
        let ds = graph_io::load_or_generate(&preset, args.get("cache").unwrap_or("data-cache"))?;
        let partitioner = args.get("partitioner").unwrap_or("metis-like");
        let a = match partitioner {
            "metis-like" => {
                MetisLikePartitioner::default().partition(&ds.graph, &ds.train_vertices, k, seed)
            }
            "ldg" => LdgPartitioner.partition(&ds.graph, &ds.train_vertices, k, seed),
            "random" => RandomPartitioner.partition(&ds.graph, &ds.train_vertices, k, seed),
            other => anyhow::bail!("unknown partitioner '{other}' (metis-like|ldg|random)"),
        };
        let t0 = std::time::Instant::now();
        partition::write_shards(&ds, &a, dir, name, partitioner, seed)?;
        println!(
            "sharded preset {name}: {} vertices, {} ranks ({partitioner}) in {:.2}s -> {out}",
            ds.num_vertices(),
            k,
            t0.elapsed().as_secs_f64()
        );
    }
    // prove the set opens and checksums before declaring success
    let set = graph_io::ShardSet::open(dir)?;
    set.verify_all()?;
    println!("verified {} shards in {out}", set.k());
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let dir = args.get("artifacts").unwrap_or("artifacts");
    let manifest = Manifest::load_or_builtin(dir)?;
    let origin = if manifest.build_config.contains_key("builtin") {
        "builtin (no artifact dir)"
    } else {
        dir
    };
    println!("{} programs in {origin}:", manifest.programs.len());
    for (name, prog) in &manifest.programs {
        println!(
            "  {name}: {} inputs, {} outputs ({})",
            prog.inputs.len(),
            prog.outputs.len(),
            prog.hlo_file
        );
    }
    Ok(())
}

fn usage() -> &'static str {
    "distgnn-mb <train|serve|generate|partition|inspect> [--flags]\n\
     train:     --preset P --model sage|gat --ranks N --epochs E --mode aep|distdgl|nocomm\n\
     \u{20}          --sampler parallel|serial|serial-ipc --partitioner metis-like|ldg|random\n\
     \u{20}          --hec-cs N --hec-nc N --hec-ls N --hec-d N --eval-every N --max-mb N\n\
     \u{20}          --hec-policy ocf|reuse (replacement: oldest-created-first or\n\
     \u{20}           reuse-credit with ring pinning) --hec-prefetch [on|off]\n\
     \u{20}           (lookahead pull of staged minibatches' level-0 HEC misses;\n\
     \u{20}           accounting side-car — losses identical on or off)\n\
     \u{20}          --target-acc A --report out.json --config cfg.json --data-cache DIR\n\
     \u{20}          --save-ckpt m.dgnc --load-ckpt m.dgnc --bench-section NAME\n\
     \u{20}          --ckpt m.dgnc --ckpt-every N (periodic epoch-boundary checkpoints)\n\
     \u{20}          --resume m.dgnc (bit-exact continuation of an interrupted run)\n\
     \u{20}          --restarts N (supervise: relaunch from last checkpoint on\n\
     \u{20}           retryable death, exit code 75 or signal; backoff between tries)\n\
     \u{20}          --fault-plan 'kill:rank=R,iter=I[,gen=G];drop_conn:...'\n\
     \u{20}           (deterministic fault injection; DISTGNN_FAULT_PLAN overrides)\n\
     \u{20}          --dtype f32|bf16 (bf16: half-width feature/HEC/push storage)\n\
     \u{20}          --pipeline-depth P (sampled minibatches in flight per rank; default 1)\n\
     \u{20}          --fabric sim|socket|hier --rank R --peers addr0,addr1,...\n\
     \u{20}          (peers: one address per rank, index = rank; entries with '/'\n\
     \u{20}           are Unix socket paths, anything else host:port TCP)\n\
     \u{20}          --hosts a:2,b:2 (host-major rank placement; hier swaps\n\
     \u{20}           co-located ranks' sockets for shared-memory rings, sim uses\n\
     \u{20}           it to classify wire bytes; DISTGNN_SHM_RING_CAP sizes rings)\n\
     \u{20}          --push-batch P (batch P iterations of AEP pushes per frame\n\
     \u{20}           before watermarking; P <= min(hec-d, pipeline-depth))\n\
     \u{20}          --data-shards DIR (map partitions out of a shard set written by\n\
     \u{20}           'shard'; skips generation + partitioning; DISTGNN_DATA_SHARDS\n\
     \u{20}           overrides) --shards-mmap [on|off] (off: copy sections to heap\n\
     \u{20}           at load — the bit-identity comparator; DISTGNN_SHARDS_MMAP)\n\
     serve:     --ckpt m.dgnc --serve-socket /path.sock (answer SCORE_REQ frames\n\
     \u{20}           with forward-only packed passes; config flags as in train)\n\
     \u{20}          --serve-deadline-ms D (coalesce arrivals into one packed\n\
     \u{20}           minibatch for up to D ms; DISTGNN_SERVE_DEADLINE_MS overrides)\n\
     \u{20}          --serve-queue N (bounded admission queue; overflow is rejected\n\
     \u{20}           with a typed SCORE_OVERLOADED reply; DISTGNN_SERVE_QUEUE)\n\
     \u{20}          --serve-max N (exit after N replies — smoke-test hook)\n\
     \u{20}          --bench-section NAME (write serving counters via benchkit)\n\
     generate:  --preset P\n\
     partition: --preset P --ranks N\n\
     shard:     --out DIR --ranks N --seed S, then either\n\
     \u{20}          --preset P [--partitioner metis-like|ldg|random] (materialize a\n\
     \u{20}           preset into shards; bit-identical to the in-RAM run), or\n\
     \u{20}          --scale S [--edges M] [--preset P for shapes] (out-of-core R-MAT:\n\
     \u{20}           2^S vertices streamed straight to shards, never RAM-resident)\n\
     \u{20}          [--train-per-mille N --test-per-mille N]\n\
     inspect:   --artifacts DIR"
}

fn dispatch(args: &Args) -> Result<()> {
    match args.cmd.as_str() {
        "train" => cmd_train(args),
        "serve" => cmd_serve(args),
        "generate" => cmd_generate(args),
        "partition" => cmd_partition(args),
        "shard" => cmd_shard(args),
        "inspect" => cmd_inspect(args),
        "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => {
            eprintln!("error: unknown command '{other}'\n\n{}", usage());
            std::process::exit(2);
        }
    }
}

fn main() {
    logging::init_from_env();
    // Bad invocations (unknown command, unknown --mode/--fabric value,
    // malformed flag) print the usage block and exit nonzero instead of
    // surfacing a raw error/panic; runtime failures (rendezvous timeout,
    // dataset errors) keep their diagnostic front and center.
    let args = match Args::parse() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e:#}\n\n{}", usage());
            std::process::exit(2);
        }
    };
    if let Some(level) = args.get("log-level") {
        if let Some(l) = logging::Level::parse(level) {
            logging::set_level(l);
        }
    }
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        // typed peer-death / injected-fault errors exit with the
        // retryable code so a supervisor (--restarts) relaunches us
        if faults::is_retryable(&e) {
            std::process::exit(faults::EXIT_RETRYABLE);
        }
        eprintln!("run 'distgnn-mb help' for usage");
        std::process::exit(2);
    }
}
