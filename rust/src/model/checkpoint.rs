//! Checkpointing: save/restore model parameters + optimizer state +
//! training progress, so long convergence runs (paper §4.5 trains for tens
//! of epochs) can resume after interruption — bit-identically, see
//! ARCHITECTURE.md "Failure model and recovery contract" — and trained
//! models can be shipped to evaluation-only processes.
//!
//! Format (version 2): a JSON header (config echo, epoch, seed, global
//! iteration cursor, spec shapes) followed by the raw little-endian f32
//! payloads and a trailing FNV-1a-64 checksum, all in one file:
//!   magic "DGNC" u32, version u32, header_len u32, header JSON bytes,
//!   params[n] f32, opt state segments (lengths in header),
//!   fnv1a64(all preceding bytes) u64.
//!
//! Robustness contract: [`Checkpoint::save`] is atomic (tmp file, fsync,
//! rename — a crash mid-save never leaves a torn file at the target
//! path), and [`Checkpoint::load`] returns a typed [`CkptError`] for any
//! corrupt input — wrong magic, unsupported version, truncation, or a
//! single flipped bit anywhere in the header or payload (the checksum) —
//! and never panics or over-allocates (every read is bounded by the
//! actual file size).

use std::io::Write;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::params::ParamSet;
use crate::util::json::{self, Value};

const MAGIC: u32 = 0x434e_4744; // "DGNC"
const VERSION: u32 = 2;
/// magic + version + header_len before the header, checksum after the
/// payloads.
const PREFIX_LEN: usize = 12;
const CHECKSUM_LEN: usize = 8;
/// Sanity cap on the JSON header (a config echo is a few KiB).
const MAX_HEADER: usize = 16 << 20;

/// Typed error for a structurally invalid or corrupt checkpoint file.
/// I/O failures (missing file, permissions) surface as ordinary errors;
/// `CkptError` means the bytes themselves are wrong.
#[derive(Debug)]
pub struct CkptError(pub String);

impl std::fmt::Display for CkptError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "invalid checkpoint: {}", self.0)
    }
}

impl std::error::Error for CkptError {}

fn corrupt<T>(msg: impl Into<String>) -> Result<T> {
    Err(anyhow::Error::new(CkptError(msg.into())))
}

/// Everything needed to resume training.
pub struct Checkpoint {
    /// Completed epochs at save time (training resumes at this epoch).
    pub epoch: usize,
    /// The run's RNG seed — verified on resume, so a checkpoint can never
    /// silently continue a run it does not belong to.
    pub seed: u64,
    /// Global iteration cursor at save time (`epoch * m_max`); resume
    /// restores it so iteration-keyed RNG streams (dropout seeds) and the
    /// fabric watermark baseline line up bit-exactly.
    pub iter: u64,
    /// Flattened parameters (spec order).
    pub params: Vec<f32>,
    /// Opaque optimizer state segments (e.g. Adam m/v), label -> values.
    pub opt_state: Vec<(String, Vec<f32>)>,
    /// Config echo for provenance (not enforced on load).
    pub config: Value,
    /// Out-of-core shard binding: when the run read its partitions from a
    /// shard set (`--data-shards`), the set's directory and per-rank
    /// content checksums ride along so `--resume` can verify it reopens
    /// the *same* data (`{"dir": ..., "checksums": ["<16-hex>", ...]}`).
    /// `None` for in-RAM runs; enforced by the driver on resume, not here.
    pub shards: Option<Value>,
}

/// Streaming FNV-1a-64.
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }
    fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
}

impl Checkpoint {
    /// Atomically write the checkpoint: everything goes to a `.tmp`
    /// sibling first, is fsync'd, then renamed over `path`. A reader (or
    /// a restarted rank) therefore only ever sees the previous complete
    /// checkpoint or the new complete one — never a torn file.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let tmp = path.with_file_name(format!(
            "{}.tmp",
            path.file_name()
                .map(|n| n.to_string_lossy().to_string())
                .unwrap_or_else(|| "ckpt".into())
        ));
        let f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        let mut w = std::io::BufWriter::new(f);
        let header = json::obj(vec![
            ("epoch", json::num(self.epoch as f64)),
            // u64 fields ride through JSON f64: exact up to 2^53, far
            // beyond any real seed/iteration count in this project
            ("seed", json::num(self.seed as f64)),
            ("iter", json::num(self.iter as f64)),
            ("n_params", json::num(self.params.len() as f64)),
            (
                "opt_segments",
                json::arr(
                    self.opt_state
                        .iter()
                        .map(|(name, v)| {
                            json::obj(vec![
                                ("name", json::s(name)),
                                ("len", json::num(v.len() as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("config", self.config.clone()),
        ]);
        let header = match &self.shards {
            // appended conditionally: in-RAM checkpoints keep their exact
            // pre-existing byte layout (no version bump)
            Some(s) => match header {
                Value::Obj(mut kv) => {
                    kv.insert("shards".to_string(), s.clone());
                    Value::Obj(kv)
                }
                other => other,
            },
            None => header,
        }
        .to_json();
        let mut h = Fnv::new();
        let mut put = |w: &mut std::io::BufWriter<std::fs::File>,
                       h: &mut Fnv,
                       bytes: &[u8]|
         -> Result<()> {
            w.write_all(bytes)?;
            h.update(bytes);
            Ok(())
        };
        put(&mut w, &mut h, &MAGIC.to_le_bytes())?;
        put(&mut w, &mut h, &VERSION.to_le_bytes())?;
        put(&mut w, &mut h, &(header.len() as u32).to_le_bytes())?;
        put(&mut w, &mut h, header.as_bytes())?;
        put(&mut w, &mut h, f32_bytes(&self.params))?;
        for (_, seg) in &self.opt_state {
            put(&mut w, &mut h, f32_bytes(seg))?;
        }
        w.write_all(&h.0.to_le_bytes())?;
        w.flush()?;
        w.get_ref()
            .sync_all()
            .with_context(|| format!("fsync {}", tmp.display()))?;
        drop(w);
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let data = std::fs::read(path.as_ref())
            .with_context(|| format!("opening {}", path.as_ref().display()))?;
        if data.len() < PREFIX_LEN + CHECKSUM_LEN {
            return corrupt(format!("file is {} bytes, too short", data.len()));
        }
        let u32_at =
            |off: usize| u32::from_le_bytes(data[off..off + 4].try_into().unwrap());
        if u32_at(0) != MAGIC {
            return corrupt("not a DistGNN-MB checkpoint (bad magic)");
        }
        let version = u32_at(4);
        if version != VERSION {
            return corrupt(format!(
                "unsupported checkpoint version {version} (this build reads version {VERSION})"
            ));
        }
        let hlen = u32_at(8) as usize;
        if hlen > MAX_HEADER || PREFIX_LEN + hlen + CHECKSUM_LEN > data.len() {
            return corrupt(format!("header length {hlen} exceeds file size"));
        }
        // Verify the checksum before trusting a single header byte: any
        // flipped bit anywhere up to here fails typed, not as a JSON
        // parse quirk or a bogus payload.
        let body = &data[..data.len() - CHECKSUM_LEN];
        let mut h = Fnv::new();
        h.update(body);
        let stored =
            u64::from_le_bytes(data[data.len() - CHECKSUM_LEN..].try_into().unwrap());
        if h.0 != stored {
            return corrupt(format!(
                "checksum mismatch (stored {stored:#018x}, computed {:#018x}) — \
                 the file is corrupt or was truncated",
                h.0
            ));
        }
        let hbytes = &data[PREFIX_LEN..PREFIX_LEN + hlen];
        let htext = match std::str::from_utf8(hbytes) {
            Ok(t) => t,
            Err(e) => return corrupt(format!("header is not UTF-8: {e}")),
        };
        let header = match json::parse(htext) {
            Ok(v) => v,
            Err(e) => return corrupt(format!("header is not valid JSON: {e}")),
        };
        let epoch = header.req_usize("epoch")?;
        let seed = header.req_usize("seed")? as u64;
        let iter = header.req_usize("iter")? as u64;
        let n_params = header.req_usize("n_params")?;
        let mut seg_specs = Vec::new();
        let mut payload_f32s = n_params;
        for seg in header.req_arr("opt_segments")? {
            let name = seg.req_str("name")?.to_string();
            let len = seg.req_usize("len")?;
            payload_f32s += len;
            seg_specs.push((name, len));
        }
        let expected = PREFIX_LEN + hlen + payload_f32s * 4 + CHECKSUM_LEN;
        if expected != data.len() {
            return corrupt(format!(
                "payload size mismatch: header implies {expected} bytes, file has {}",
                data.len()
            ));
        }
        let mut off = PREFIX_LEN + hlen;
        let mut take = |n: usize| {
            let s = &data[off..off + n * 4];
            off += n * 4;
            f32s_from(s)
        };
        let params = take(n_params);
        let opt_state = seg_specs
            .into_iter()
            .map(|(name, len)| (name, take(len)))
            .collect();
        let config = header.get("config").cloned().unwrap_or(Value::Null);
        let shards = header.get("shards").cloned();
        Ok(Checkpoint {
            epoch,
            seed,
            iter,
            params,
            opt_state,
            config,
            shards,
        })
    }

    /// Apply the parameters to a ParamSet (shape-checked).
    pub fn restore_into(&self, params: &mut ParamSet) -> Result<()> {
        if params.flat.len() != self.params.len() {
            bail!(
                "checkpoint has {} parameters, model expects {}",
                self.params.len(),
                params.flat.len()
            );
        }
        params.flat.copy_from_slice(&self.params);
        Ok(())
    }
}

/// Single-memcpy byte view (little-endian host).
fn f32_bytes(xs: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4) }
}

fn f32s_from(bytes: &[u8]) -> Vec<f32> {
    debug_assert_eq!(bytes.len() % 4, 0);
    let n = bytes.len() / 4;
    let mut out = vec![0f32; n];
    unsafe {
        std::ptr::copy_nonoverlapping(bytes.as_ptr(), out.as_mut_ptr() as *mut u8, n * 4);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            epoch: 7,
            seed: 42,
            iter: 280,
            params: (0..100).map(|i| i as f32 * 0.5).collect(),
            opt_state: vec![
                ("adam_m".into(), vec![0.1; 100]),
                ("adam_v".into(), vec![0.2; 100]),
            ],
            config: json::obj(vec![("model", json::s("sage"))]),
            shards: None,
        }
    }

    fn tmp_dir() -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("distgnn-ckpt-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn roundtrip() {
        let path = tmp_dir().join("a.dgnc");
        let ck = sample();
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.epoch, 7);
        assert_eq!(back.seed, 42);
        assert_eq!(back.iter, 280);
        assert_eq!(back.params, ck.params);
        assert_eq!(back.opt_state, ck.opt_state);
        assert_eq!(back.config.get("model").unwrap().as_str(), Some("sage"));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn save_is_atomic_no_tmp_left_behind() {
        let dir = tmp_dir();
        let path = dir.join("atomic.dgnc");
        sample().save(&path).unwrap();
        assert!(path.exists());
        assert!(
            !dir.join("atomic.dgnc.tmp").exists(),
            "tmp file left behind after rename"
        );
        // overwriting an existing checkpoint is equally atomic
        sample().save(&path).unwrap();
        assert!(Checkpoint::load(&path).is_ok());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_garbage_and_shape_mismatch() {
        let path = tmp_dir().join("bad.dgnc");
        std::fs::write(&path, b"nope").unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(err.is::<CkptError>(), "{err:#}");
        std::fs::remove_file(path).ok();

        let ck = sample();
        let specs = vec![crate::runtime::artifacts::TensorSpec {
            name: "w".into(),
            dtype: crate::runtime::tensor::DType::F32,
            shape: vec![3, 3],
        }];
        let mut ps = ParamSet::init_glorot(specs, 0);
        assert!(ck.restore_into(&mut ps).is_err());
    }

    #[test]
    fn truncation_at_every_boundary_is_typed_error() {
        let path = tmp_dir().join("trunc.dgnc");
        sample().save(&path).unwrap();
        let full = std::fs::read(&path).unwrap();
        let cut_path = tmp_dir().join("trunc-cut.dgnc");
        for cut in 0..full.len() {
            std::fs::write(&cut_path, &full[..cut]).unwrap();
            let err = Checkpoint::load(&cut_path)
                .err()
                .unwrap_or_else(|| panic!("cut at {cut} loaded"));
            assert!(err.is::<CkptError>(), "cut {cut}: untyped error {err:#}");
        }
        std::fs::remove_file(path).ok();
        std::fs::remove_file(cut_path).ok();
    }

    #[test]
    fn single_bit_flip_anywhere_is_typed_error() {
        let path = tmp_dir().join("flip.dgnc");
        sample().save(&path).unwrap();
        let full = std::fs::read(&path).unwrap();
        let flip_path = tmp_dir().join("flip-mut.dgnc");
        // every 7th byte covers prefix, header, f32 payload and checksum
        for off in (0..full.len()).step_by(7) {
            let mut bad = full.clone();
            bad[off] ^= 1 << (off % 8);
            std::fs::write(&flip_path, &bad).unwrap();
            let err = Checkpoint::load(&flip_path)
                .err()
                .unwrap_or_else(|| panic!("flip at {off} loaded"));
            assert!(err.is::<CkptError>(), "flip {off}: untyped error {err:#}");
        }
        std::fs::remove_file(path).ok();
        std::fs::remove_file(flip_path).ok();
    }

    #[test]
    fn wrong_magic_and_future_version_are_typed_errors() {
        let path = tmp_dir().join("ver.dgnc");
        sample().save(&path).unwrap();
        let full = std::fs::read(&path).unwrap();
        let mut_path = tmp_dir().join("ver-mut.dgnc");

        let mut bad_magic = full.clone();
        bad_magic[0..4].copy_from_slice(&0xDEAD_BEEFu32.to_le_bytes());
        std::fs::write(&mut_path, &bad_magic).unwrap();
        let err = Checkpoint::load(&mut_path).unwrap_err();
        assert!(err.is::<CkptError>(), "{err:#}");
        assert!(format!("{err:#}").contains("magic"), "{err:#}");

        // both a legacy v1 file and a file from the future are rejected
        // with a version message, not misparsed
        for ver in [1u32, 3, u32::MAX] {
            let mut bad_ver = full.clone();
            bad_ver[4..8].copy_from_slice(&ver.to_le_bytes());
            std::fs::write(&mut_path, &bad_ver).unwrap();
            let err = Checkpoint::load(&mut_path).unwrap_err();
            assert!(err.is::<CkptError>(), "version {ver}: {err:#}");
            assert!(
                format!("{err:#}").contains("version"),
                "version {ver}: {err:#}"
            );
        }
        std::fs::remove_file(path).ok();
        std::fs::remove_file(mut_path).ok();
    }

    #[test]
    fn shard_binding_roundtrips_and_stays_optional() {
        let dir = tmp_dir();
        // absent stays absent
        let plain = dir.join("noshards.dgnc");
        sample().save(&plain).unwrap();
        assert!(Checkpoint::load(&plain).unwrap().shards.is_none());

        // present roundtrips verbatim
        let path = dir.join("shards.dgnc");
        let mut ck = sample();
        ck.shards = Some(json::obj(vec![
            ("dir", json::s("/tmp/shards")),
            (
                "checksums",
                json::arr(vec![json::s("00000000deadbeef"), json::s("0123456789abcdef")]),
            ),
        ]));
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        let shards = back.shards.expect("shards key lost");
        assert_eq!(shards.get("dir").unwrap().as_str(), Some("/tmp/shards"));
        assert_eq!(
            shards.get("checksums").unwrap().as_arr().unwrap().len(),
            2
        );
        std::fs::remove_file(plain).ok();
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn restore_into_matching_paramset() {
        let specs = vec![crate::runtime::artifacts::TensorSpec {
            name: "w".into(),
            dtype: crate::runtime::tensor::DType::F32,
            shape: vec![10, 10],
        }];
        let mut ps = ParamSet::init_glorot(specs, 0);
        let ck = sample();
        ck.restore_into(&mut ps).unwrap();
        assert_eq!(ps.flat, ck.params);
    }
}
