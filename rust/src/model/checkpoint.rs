//! Checkpointing: save/restore model parameters + optimizer state +
//! training progress, so long convergence runs (paper §4.5 trains for tens
//! of epochs) can resume after interruption and trained models can be
//! shipped to evaluation-only processes.
//!
//! Format: a JSON header (config echo, epoch, spec shapes) followed by the
//! raw little-endian f32 payloads, all in one file:
//!   magic "DGNC" u32, version u32, header_len u32, header JSON bytes,
//!   params[n] f32, opt state segments (lengths in header).

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::params::ParamSet;
use crate::util::json::{self, Value};

const MAGIC: u32 = 0x434e_4744; // "DGNC"
const VERSION: u32 = 1;

/// Everything needed to resume training.
pub struct Checkpoint {
    pub epoch: usize,
    /// Flattened parameters (spec order).
    pub params: Vec<f32>,
    /// Opaque optimizer state segments (e.g. Adam m/v), label -> values.
    pub opt_state: Vec<(String, Vec<f32>)>,
    /// Config echo for provenance (not enforced on load).
    pub config: Value,
}

impl Checkpoint {
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("creating {}", path.as_ref().display()))?;
        let mut w = BufWriter::new(f);
        let header = json::obj(vec![
            ("epoch", json::num(self.epoch as f64)),
            ("n_params", json::num(self.params.len() as f64)),
            (
                "opt_segments",
                json::arr(
                    self.opt_state
                        .iter()
                        .map(|(name, v)| {
                            json::obj(vec![
                                ("name", json::s(name)),
                                ("len", json::num(v.len() as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("config", self.config.clone()),
        ])
        .to_json();
        w.write_all(&MAGIC.to_le_bytes())?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&(header.len() as u32).to_le_bytes())?;
        w.write_all(header.as_bytes())?;
        write_f32s(&mut w, &self.params)?;
        for (_, seg) in &self.opt_state {
            write_f32s(&mut w, seg)?;
        }
        w.flush()?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let f = std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening {}", path.as_ref().display()))?;
        let mut r = BufReader::new(f);
        let mut b4 = [0u8; 4];
        r.read_exact(&mut b4)?;
        if u32::from_le_bytes(b4) != MAGIC {
            bail!("not a DistGNN-MB checkpoint");
        }
        r.read_exact(&mut b4)?;
        if u32::from_le_bytes(b4) != VERSION {
            bail!("unsupported checkpoint version");
        }
        r.read_exact(&mut b4)?;
        let hlen = u32::from_le_bytes(b4) as usize;
        let mut hbytes = vec![0u8; hlen];
        r.read_exact(&mut hbytes)?;
        let header = json::parse(std::str::from_utf8(&hbytes)?)?;
        let epoch = header.req_usize("epoch")?;
        let n_params = header.req_usize("n_params")?;
        let params = read_f32s(&mut r, n_params)?;
        let mut opt_state = Vec::new();
        for seg in header.req_arr("opt_segments")? {
            let name = seg.req_str("name")?.to_string();
            let len = seg.req_usize("len")?;
            opt_state.push((name, read_f32s(&mut r, len)?));
        }
        let config = header.get("config").cloned().unwrap_or(Value::Null);
        Ok(Checkpoint {
            epoch,
            params,
            opt_state,
            config,
        })
    }

    /// Apply the parameters to a ParamSet (shape-checked).
    pub fn restore_into(&self, params: &mut ParamSet) -> Result<()> {
        if params.flat.len() != self.params.len() {
            bail!(
                "checkpoint has {} parameters, model expects {}",
                self.params.len(),
                params.flat.len()
            );
        }
        params.flat.copy_from_slice(&self.params);
        Ok(())
    }
}

fn write_f32s<W: Write>(w: &mut W, xs: &[f32]) -> Result<()> {
    // single memcpy byte view (little-endian host)
    let bytes =
        unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4) };
    w.write_all(bytes)?;
    Ok(())
}

fn read_f32s<R: Read>(r: &mut R, n: usize) -> Result<Vec<f32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    let mut out = vec![0f32; n];
    unsafe {
        std::ptr::copy_nonoverlapping(bytes.as_ptr(), out.as_mut_ptr() as *mut u8, n * 4);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            epoch: 7,
            params: (0..100).map(|i| i as f32 * 0.5).collect(),
            opt_state: vec![
                ("adam_m".into(), vec![0.1; 100]),
                ("adam_v".into(), vec![0.2; 100]),
            ],
            config: json::obj(vec![("model", json::s("sage"))]),
        }
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("distgnn-ckpt-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.dgnc");
        let ck = sample();
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.epoch, 7);
        assert_eq!(back.params, ck.params);
        assert_eq!(back.opt_state, ck.opt_state);
        assert_eq!(back.config.get("model").unwrap().as_str(), Some("sage"));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_garbage_and_shape_mismatch() {
        let dir = std::env::temp_dir().join("distgnn-ckpt-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.dgnc");
        std::fs::write(&path, b"nope").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(path).ok();

        let ck = sample();
        let specs = vec![crate::runtime::artifacts::TensorSpec {
            name: "w".into(),
            dtype: crate::runtime::tensor::DType::F32,
            shape: vec![3, 3],
        }];
        let mut ps = ParamSet::init_glorot(specs, 0);
        assert!(ck.restore_into(&mut ps).is_err());
    }

    #[test]
    fn restore_into_matching_paramset() {
        let specs = vec![crate::runtime::artifacts::TensorSpec {
            name: "w".into(),
            dtype: crate::runtime::tensor::DType::F32,
            shape: vec![10, 10],
        }];
        let mut ps = ParamSet::init_glorot(specs, 0);
        let ck = sample();
        ck.restore_into(&mut ps).unwrap();
        assert_eq!(ps.flat, ck.params);
    }
}
