//! Model-side glue: parameter store + initialization, optimizers,
//! minibatch→tensor packing (including HEC search/load), accuracy eval.
//!
//! The actual forward/backward math lives in the AOT-compiled L2 artifacts;
//! this module owns everything around those calls.

pub mod checkpoint;
pub mod optimizer;
pub mod packing;
pub mod params;

pub use checkpoint::Checkpoint;
pub use optimizer::{Optimizer, OptimizerKind};
pub use packing::{PackStats, Packer};
pub use params::ParamSet;
