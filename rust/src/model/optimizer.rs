//! Optimizers over flat parameter vectors (run after the gradient
//! all-reduce, identically on every rank).

use anyhow::Result;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptimizerKind {
    Sgd,
    Adam,
}

impl OptimizerKind {
    pub fn parse(s: &str) -> Result<OptimizerKind> {
        match s {
            "sgd" => Ok(OptimizerKind::Sgd),
            "adam" => Ok(OptimizerKind::Adam),
            other => anyhow::bail!("unknown optimizer '{other}'"),
        }
    }
}

pub struct Optimizer {
    kind: OptimizerKind,
    lr: f32,
    // Adam state
    beta1: f32,
    beta2: f32,
    eps: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
    // SGD momentum
    momentum: f32,
    vel: Vec<f32>,
}

impl Optimizer {
    pub fn new(kind: OptimizerKind, lr: f32, n: usize) -> Optimizer {
        Optimizer {
            kind,
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: vec![0.0; if kind == OptimizerKind::Adam { n } else { 0 }],
            v: vec![0.0; if kind == OptimizerKind::Adam { n } else { 0 }],
            t: 0,
            momentum: 0.9,
            vel: vec![0.0; if kind == OptimizerKind::Sgd { n } else { 0 }],
        }
    }

    pub fn lr(&self) -> f32 {
        self.lr
    }

    /// State segments for checkpointing (label, values). The step counter
    /// rides along as a 1-element segment.
    pub fn state_segments(&self) -> Vec<(String, Vec<f32>)> {
        match self.kind {
            OptimizerKind::Adam => vec![
                ("adam_m".into(), self.m.clone()),
                ("adam_v".into(), self.v.clone()),
                ("t".into(), vec![self.t as f32]),
            ],
            OptimizerKind::Sgd => vec![
                ("sgd_vel".into(), self.vel.clone()),
                ("t".into(), vec![self.t as f32]),
            ],
        }
    }

    /// Restore from [`state_segments`] output (shape-checked).
    pub fn restore_segments(&mut self, segs: &[(String, Vec<f32>)]) -> anyhow::Result<()> {
        for (name, vals) in segs {
            match name.as_str() {
                "adam_m" => {
                    anyhow::ensure!(vals.len() == self.m.len(), "adam_m size");
                    self.m.copy_from_slice(vals);
                }
                "adam_v" => {
                    anyhow::ensure!(vals.len() == self.v.len(), "adam_v size");
                    self.v.copy_from_slice(vals);
                }
                "sgd_vel" => {
                    anyhow::ensure!(vals.len() == self.vel.len(), "sgd_vel size");
                    self.vel.copy_from_slice(vals);
                }
                "t" => self.t = vals.first().copied().unwrap_or(0.0) as u64,
                other => anyhow::bail!("unknown optimizer segment '{other}'"),
            }
        }
        Ok(())
    }

    /// One update step: params -= update(grads).
    pub fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        debug_assert_eq!(params.len(), grads.len());
        self.t += 1;
        match self.kind {
            OptimizerKind::Sgd => {
                for i in 0..params.len() {
                    self.vel[i] = self.momentum * self.vel[i] + grads[i];
                    params[i] -= self.lr * self.vel[i];
                }
            }
            OptimizerKind::Adam => {
                let b1t = 1.0 - self.beta1.powi(self.t as i32);
                let b2t = 1.0 - self.beta2.powi(self.t as i32);
                for i in 0..params.len() {
                    let g = grads[i];
                    self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g;
                    self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g * g;
                    let mhat = self.m[i] / b1t;
                    let vhat = self.v[i] / b2t;
                    params[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimize f(x) = (x-3)^2 and check convergence.
    fn minimize(kind: OptimizerKind, lr: f32, steps: usize) -> f32 {
        let mut opt = Optimizer::new(kind, lr, 1);
        let mut x = vec![0.0f32];
        for _ in 0..steps {
            let g = vec![2.0 * (x[0] - 3.0)];
            opt.step(&mut x, &g);
        }
        x[0]
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        let x = minimize(OptimizerKind::Sgd, 0.05, 200);
        assert!((x - 3.0).abs() < 1e-3, "x = {x}");
    }

    #[test]
    fn adam_converges_on_quadratic() {
        let x = minimize(OptimizerKind::Adam, 0.1, 500);
        assert!((x - 3.0).abs() < 1e-2, "x = {x}");
    }

    #[test]
    fn identical_ranks_stay_identical() {
        // two "ranks" applying the same averaged gradients must stay in sync
        let mut a = Optimizer::new(OptimizerKind::Adam, 0.01, 4);
        let mut b = Optimizer::new(OptimizerKind::Adam, 0.01, 4);
        let mut pa = vec![1.0f32, -2.0, 0.5, 3.0];
        let mut pb = pa.clone();
        let mut rng = crate::util::rng::Pcg64::seeded(3);
        for _ in 0..50 {
            let g: Vec<f32> = (0..4).map(|_| rng.gen_f32() - 0.5).collect();
            a.step(&mut pa, &g);
            b.step(&mut pb, &g);
        }
        assert_eq!(pa, pb);
    }

    #[test]
    fn state_segments_roundtrip() {
        let mut a = Optimizer::new(OptimizerKind::Adam, 0.01, 4);
        let mut p = vec![1.0f32; 4];
        for i in 0..5 {
            a.step(&mut p, &vec![0.1 * i as f32; 4]);
        }
        let segs = a.state_segments();
        let mut b = Optimizer::new(OptimizerKind::Adam, 0.01, 4);
        b.restore_segments(&segs).unwrap();
        // both must now produce identical updates
        let mut pa = p.clone();
        let mut pb = p.clone();
        a.step(&mut pa, &[0.3; 4]);
        b.step(&mut pb, &[0.3; 4]);
        assert_eq!(pa, pb);
        assert!(b.restore_segments(&[("bogus".into(), vec![])]).is_err());
    }

    #[test]
    fn parse_kinds() {
        assert_eq!(OptimizerKind::parse("adam").unwrap(), OptimizerKind::Adam);
        assert!(OptimizerKind::parse("rmsprop").is_err());
    }
}
