//! Minibatch → tensor packing: the bridge between sampled blocks and the
//! fixed-shape AOT programs.
//!
//! This is where the paper's HECSearch/HECLoad run (figure 1(c)/(d)): for
//! every halo vertex in every layer, the layer's HEC is consulted; hits
//! load the cached embedding (layer 0 caches raw features, layer l >= 1
//! caches h_l), misses eliminate the vertex from minibatch execution by
//! zeroing the weights of its outgoing edges (Algorithm 2 line 11).
//!
//! The packer emits feature and HEC-value tensors in the run's `--dtype`
//! (f32 or bf16): solid feature rows convert from the f32 shard on the
//! fly, halo hit rows block-copy byte-for-byte from the same-dtype HEC,
//! so the whole minibatch feature block (and downstream executor reads)
//! shrinks 2x under bf16. Edge weights, labels and masks stay f32/i32.

use anyhow::{bail, Result};

use crate::config::{DtypeKind, ModelKind};
use crate::hec::Hec;
use crate::partition::RankPartition;
use crate::runtime::artifacts::ProgramSpec;
use crate::runtime::bf16;
use crate::runtime::tensor::{as_bytes, HostTensor};
use crate::sampler::MinibatchBlocks;
use crate::util::parallel;

/// Per-pack statistics (feeds the paper's §4.4 hit-rate reporting).
#[derive(Clone, Debug, Default)]
pub struct PackStats {
    /// Per layer: halo occurrences searched / hits.
    pub halo_searches: Vec<u64>,
    pub halo_hits: Vec<u64>,
    /// Edges dropped because their source halo missed the cache.
    pub edges_dropped: u64,
    /// Positions of solid vertices per layer (VID_p), for the AEP push.
    pub solids_per_layer: Vec<Vec<(u32, u32)>>, // (position, vid_p)
    /// VID_o of every level-0 halo miss, in search order (AEP mode only).
    /// The prefetch staging layer classifies these as covered / late /
    /// cold — pure accounting, the miss itself still dropped its edges.
    pub missed_l0: Vec<u32>,
}

/// Packs minibatches for one program signature.
pub struct Packer {
    pub model: ModelKind,
    pub n_layers: usize,
    pub node_caps: Vec<usize>,
    pub edge_caps: Vec<usize>,
    pub feat_dim: usize,
    pub hidden: usize,
    pub batch: usize,
    pub n_params: usize,
    /// Storage dtype of the feature / HEC-value tensors (must match the
    /// dtype of the caches handed to [`Packer::pack`]).
    pub dtype: DtypeKind,
    n_batch_inputs: usize,
}

impl Packer {
    /// Derive the packing layout from a (train or fwd) program spec.
    pub fn from_program(prog: &ProgramSpec) -> Result<Packer> {
        let model = ModelKind::parse(prog.meta_str("model").unwrap_or(""))?;
        let n_params = prog.meta_usize("n_params")?;
        let batch = prog.meta_usize("batch")?;
        let hidden = prog.meta_usize("hidden")?;
        let feat_dim = prog.meta_usize("feat_dim")?;
        let node_caps: Vec<usize> = prog
            .meta
            .get("node_caps")
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
            .unwrap_or_default();
        if node_caps.is_empty() {
            bail!("program '{}' missing node_caps meta", prog.name);
        }
        let n_layers = node_caps.len() - 1;
        // edge caps from the esrc input shapes
        let mut edge_caps = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            let idx = prog.input_index(&format!("esrc{l}"))?;
            edge_caps.push(prog.inputs[idx].shape[0]);
        }
        let n_batch_inputs = prog.inputs.len() - n_params;
        Ok(Packer {
            model,
            n_layers,
            node_caps,
            edge_caps,
            feat_dim,
            hidden,
            batch,
            n_params,
            dtype: DtypeKind::F32,
            n_batch_inputs,
        })
    }

    /// Set the storage dtype of the packed feature / HEC-value tensors.
    pub fn with_dtype(mut self, dtype: DtypeKind) -> Packer {
        self.dtype = dtype;
        self
    }

    /// Pack one minibatch. `hecs[l]` is the layer-l cache (level 0 = raw
    /// features); `full_feats` supplies rows for *halo* vertices directly
    /// (DistDGL mode: features were fetched synchronously; None in AEP
    /// mode). Returns the batch-input tensors in program order.
    pub fn pack(
        &self,
        part: &RankPartition,
        mb: &MinibatchBlocks,
        hecs: &mut [Hec],
        full_feats: Option<&dyn Fn(u32) -> Option<Vec<f32>>>,
        seed: i32,
    ) -> Result<(Vec<HostTensor>, PackStats)> {
        if mb.n_layers() != self.n_layers {
            bail!("minibatch has {} layers, packer expects {}", mb.n_layers(), self.n_layers);
        }
        let mut stats = PackStats {
            halo_searches: vec![0; self.n_layers],
            halo_hits: vec![0; self.n_layers],
            edges_dropped: 0,
            solids_per_layer: vec![Vec::new(); self.n_layers],
            missed_l0: Vec::new(),
        };

        // ---- per-layer halo resolution (batched HECSearch) ---------------
        // halo_ok[l][pos] = layer-l position participates (solid, or halo
        // with a resolved embedding); hits_per_layer[l] = (pos, line).
        // Solid positions are recorded for the AEP push.
        let mut halo_ok: Vec<Vec<bool>> = Vec::with_capacity(self.n_layers);
        let mut hits_per_layer: Vec<Vec<(u32, u32)>> = vec![Vec::new(); self.n_layers];
        let mut fetched_rows: Vec<(u32, Vec<f32>)> = Vec::new(); // DistDGL layer-0
        let mut halo_pos: Vec<u32> = Vec::new();
        let mut halo_vids: Vec<u32> = Vec::new();
        for l in 0..self.n_layers {
            let nodes = &mb.layers[l];
            let mut ok = vec![true; nodes.len()];
            if let Some(fetch) = full_feats {
                // DistDGL mode: halo features were fetched synchronously;
                // only layer 0 matters, inner layers are computed from the
                // fully expanded frontier. HECs stay untouched.
                for (pos, &v) in nodes.iter().enumerate() {
                    if !part.is_halo(v) {
                        stats.solids_per_layer[l].push((pos as u32, v));
                        continue;
                    }
                    let vid_o = part.vid_o[v as usize];
                    stats.halo_searches[l] += 1;
                    if l == 0 {
                        if let Some(row) = fetch(vid_o) {
                            stats.halo_hits[l] += 1;
                            fetched_rows.push((pos as u32, row));
                        } else {
                            ok[pos] = false;
                        }
                    } else {
                        stats.halo_hits[l] += 1;
                    }
                }
            } else {
                // collect this layer's halos, then one batched search
                halo_pos.clear();
                halo_vids.clear();
                for (pos, &v) in nodes.iter().enumerate() {
                    if part.is_halo(v) {
                        halo_pos.push(pos as u32);
                        halo_vids.push(part.vid_o[v as usize]);
                    } else {
                        stats.solids_per_layer[l].push((pos as u32, v));
                    }
                }
                stats.halo_searches[l] += halo_vids.len() as u64;
                let lines = hecs[l].search_batch(&halo_vids);
                for (i, line) in lines.into_iter().enumerate() {
                    match line {
                        Some(ln) => {
                            stats.halo_hits[l] += 1;
                            hits_per_layer[l].push((halo_pos[i], ln));
                        }
                        None => {
                            ok[halo_pos[i] as usize] = false;
                            if l == 0 {
                                stats.missed_l0.push(halo_vids[i]);
                            }
                        }
                    }
                }
            }
            halo_ok.push(ok);
        }

        // ---- tensors in program order ------------------------------------
        let mut out = Vec::with_capacity(self.n_batch_inputs);

        // feats [NS0, F]: solid rows block-copied (bf16: packed) from the
        // f32 feature shard, halo rows byte-copied from the same-dtype HEC
        // level 0 (or fetched features); misses stay zero. The fill runs
        // as thread-parallel row chunks and is byte-identical for any
        // worker count.
        let feat_dt = self.dtype.tensor_dtype();
        let mut feats = HostTensor::zeros(feat_dt, vec![self.node_caps[0], self.feat_dim]);
        {
            let n0 = mb.layers[0].len();
            let row_bytes = self.feat_dim * feat_dt.size_bytes();
            let mut line_of: Vec<u32> = vec![u32::MAX; n0];
            for &(pos, ln) in &hits_per_layer[0] {
                line_of[pos as usize] = ln;
            }
            let nodes = &mb.layers[0];
            let hec0 = &hecs[0];
            debug_assert_eq!(hec0.dtype(), self.dtype, "HEC dtype must match packer dtype");
            let dtype = self.dtype;
            parallel::parallel_rows_mut(
                &mut feats.data[..n0 * row_bytes],
                row_bytes,
                |row0, chunk| {
                    for (j, dst) in chunk.chunks_exact_mut(row_bytes).enumerate() {
                        let pos = row0 + j;
                        let v = nodes[pos];
                        if !part.is_halo(v) {
                            match dtype {
                                DtypeKind::F32 => {
                                    dst.copy_from_slice(as_bytes(part.feature_row(v)))
                                }
                                DtypeKind::Bf16 => {
                                    bf16::pack_row_bytes(part.feature_row(v), dst)
                                }
                            }
                        } else if line_of[pos] != u32::MAX {
                            dst.copy_from_slice(hec0.row_bytes(line_of[pos]));
                        }
                    }
                },
            );
            for (pos, row) in &fetched_rows {
                feats.set_row_f32(*pos as usize, row);
            }
        }
        out.push(feats);

        // edge blocks
        for l in 0..self.n_layers {
            let cap = self.edge_caps[l];
            let e = &mb.edges[l];
            if e.len() > cap {
                bail!("block {l} has {} edges, cap {cap}", e.len());
            }
            let mut esrc = vec![0i32; cap];
            let mut edst = vec![0i32; cap];
            let mut ew = vec![0f32; cap];
            // validity: source halo must have hit the cache
            let nd = mb.layers[l + 1].len();
            let mut deg = vec![0f32; nd];
            for (i, (&s, &d)) in e.src.iter().zip(&e.dst).enumerate() {
                esrc[i] = s as i32;
                edst[i] = d as i32;
                let valid = halo_ok[l][s as usize];
                if valid {
                    ew[i] = 1.0;
                    deg[d as usize] += 1.0;
                } else {
                    stats.edges_dropped += 1;
                }
            }
            if self.model == ModelKind::Sage {
                // mean aggregation: 1/deg weights
                for i in 0..e.len() {
                    if ew[i] > 0.0 {
                        ew[i] /= deg[edst[i] as usize].max(1.0);
                    }
                }
            }
            out.push(HostTensor::i32(vec![cap], &esrc));
            out.push(HostTensor::i32(vec![cap], &edst));
            out.push(HostTensor::f32(vec![cap], &ew));
        }

        // hec overwrite inputs for inner layers (positions + values);
        // padded with out-of-bounds indices (dropped scatter). Hit rows
        // gather through one batched HECLoad straight into the tensor's
        // storage (same dtype as the cache, so no conversion).
        for l in 1..self.n_layers {
            let cap = self.node_caps[l];
            let mut idx = vec![cap as i32; cap];
            let mut val = HostTensor::zeros(feat_dt, vec![cap, self.hidden]);
            let hl = &hits_per_layer[l];
            if !hl.is_empty() {
                debug_assert_eq!(hecs[l].dtype(), self.dtype);
                let mut lines = Vec::with_capacity(hl.len());
                for (j, &(pos, ln)) in hl.iter().enumerate() {
                    idx[j] = pos as i32;
                    lines.push(ln);
                }
                let rb = self.hidden * feat_dt.size_bytes();
                hecs[l].load_batch_bytes(&lines, &mut val.data[..hl.len() * rb]);
            }
            out.push(HostTensor::i32(vec![cap], &idx));
            out.push(val);
        }

        // labels + mask (+ padding) and the dropout seed
        let seeds = mb.seeds();
        if seeds.len() > self.batch {
            bail!("seed set {} exceeds batch {}", seeds.len(), self.batch);
        }
        let mut labels = vec![0i32; self.batch];
        let mut lmask = vec![0f32; self.batch];
        for (i, &v) in seeds.iter().enumerate() {
            labels[i] = part.labels[v as usize] as i32;
            lmask[i] = 1.0;
        }
        out.push(HostTensor::i32(vec![self.batch], &labels));
        out.push(HostTensor::f32(vec![self.batch], &lmask));
        out.push(HostTensor::i32(vec![], &[seed]));

        debug_assert_eq!(out.len(), self.n_batch_inputs);
        Ok((out, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DatasetPreset;
    use crate::partition::metis_like::MetisLikePartitioner;
    use crate::partition::{materialize, Partitioner};
    use crate::sampler::neighbor::NeighborSampler;
    use crate::util::rng::Pcg64;

    /// Manifest stub matching the tiny preset's sage_train signature.
    fn tiny_packer() -> Packer {
        // caps mirror shapes.PRESETS["tiny"] (validated against the real
        // manifest in the integration tests)
        Packer {
            model: ModelKind::Sage,
            n_layers: 3,
            node_caps: vec![1792, 448, 128, 32],
            edge_caps: vec![448 * 4, 128 * 6, 32 * 8],
            feat_dim: 32,
            hidden: 64,
            batch: 32,
            n_params: 9,
            dtype: DtypeKind::F32,
            n_batch_inputs: 1 + 9 + 4 + 3,
        }
    }

    fn setup() -> Vec<RankPartition> {
        let ds = DatasetPreset::tiny().generate();
        let a = MetisLikePartitioner::default().partition(&ds.graph, &ds.train_vertices, 2, 5);
        materialize(&ds, &a)
    }

    fn sample_mb(part: &RankPartition, packer: &Packer, seed: u64) -> MinibatchBlocks {
        let mut s = NeighborSampler::new(
            vec![4, 6, 8],
            packer.node_caps.clone(),
            false,
            crate::config::SamplerKind::Serial,
        );
        let seeds: Vec<u32> = part.train_vertices.iter().take(32).copied().collect();
        s.sample(part, &seeds, &mut Pcg64::seeded(seed))
    }

    fn empty_hecs(packer: &Packer) -> Vec<Hec> {
        vec![
            Hec::new(1024, 2, packer.feat_dim),
            Hec::new(1024, 2, packer.hidden),
            Hec::new(1024, 2, packer.hidden),
        ]
    }

    #[test]
    fn pack_shapes_match_caps_and_misses_drop_edges() {
        let parts = setup();
        let part = &parts[0];
        let packer = tiny_packer();
        let mb = sample_mb(part, &packer, 1);
        let mut hecs = empty_hecs(&packer);
        let (tensors, stats) = packer.pack(part, &mb, &mut hecs, None, 7).unwrap();
        assert_eq!(tensors.len(), 17);
        assert_eq!(tensors[0].shape, vec![1792, 32]); // feats
        assert_eq!(tensors[1].shape, vec![448 * 4]); // esrc0
        // empty HECs: every halo is a miss
        assert!(stats.halo_searches.iter().sum::<u64>() > 0);
        assert_eq!(stats.halo_hits.iter().sum::<u64>(), 0);
        assert!(stats.edges_dropped > 0 || stats.halo_searches[0] == 0);
    }

    #[test]
    fn hec_hits_fill_feats_and_idx() {
        let parts = setup();
        let part = &parts[0];
        let packer = tiny_packer();
        let mb = sample_mb(part, &packer, 2);
        let mut hecs = empty_hecs(&packer);
        // warm level-0 cache with every halo's "remote features"
        for &v in &mb.layers[0] {
            if part.is_halo(v) {
                let vid_o = part.vid_o[v as usize];
                hecs[0].store(vid_o, &vec![0.5f32; packer.feat_dim]);
            }
        }
        let (tensors, stats) = packer.pack(part, &mb, &mut hecs, None, 0).unwrap();
        assert_eq!(stats.halo_hits[0], stats.halo_searches[0]);
        // find a halo position and check its feature row
        if let Some((pos, _)) = mb.layers[0]
            .iter()
            .enumerate()
            .find(|(_, &v)| part.is_halo(v))
        {
            let feats = tensors[0].to_f32().unwrap();
            let row = &feats[pos * 32..pos * 32 + 32];
            assert!(row.iter().all(|&x| x == 0.5));
        }
    }

    #[test]
    fn sage_weights_sum_to_one_per_dst() {
        let parts = setup();
        let part = &parts[1];
        let packer = tiny_packer();
        let mb = sample_mb(part, &packer, 3);
        let mut hecs = empty_hecs(&packer);
        let (tensors, _) = packer.pack(part, &mb, &mut hecs, None, 0).unwrap();
        for l in 0..3 {
            let edst = tensors[1 + 3 * l + 1].to_i32().unwrap();
            let ew = tensors[1 + 3 * l + 2].to_f32().unwrap();
            let nd = packer.node_caps[l + 1];
            let mut sums = vec![0f32; nd];
            for (d, w) in edst.iter().zip(&ew) {
                sums[*d as usize] += w;
            }
            for (d, &s) in sums.iter().enumerate() {
                assert!(
                    s == 0.0 || (s - 1.0).abs() < 1e-4,
                    "layer {l} dst {d} weight sum {s}"
                );
            }
        }
    }

    /// GAT packing: `ew` stays a 0/1 validity mask (no mean
    /// normalization — the edge-softmax normalizes), self-loop edges are
    /// present for every admitted destination, and dropped-halo edges
    /// are masked to 0 exactly like the SAGE path.
    #[test]
    fn gat_weights_are_validity_mask_with_self_loops() {
        let parts = setup();
        let part = &parts[0];
        let mut packer = tiny_packer();
        packer.model = ModelKind::Gat;
        // self-loop edge caps: fanout*nd + nd per layer
        packer.edge_caps = vec![448 * 4 + 448, 128 * 6 + 128, 32 * 8 + 32];
        packer.n_batch_inputs = 1 + 9 + 4 + 3;
        let mut s = NeighborSampler::new(
            vec![4, 6, 8],
            packer.node_caps.clone(),
            true, // self loops
            crate::config::SamplerKind::Serial,
        );
        let seeds: Vec<u32> = part.train_vertices.iter().take(32).copied().collect();
        let mb = s.sample(part, &seeds, &mut Pcg64::seeded(9));
        let mut hecs = empty_hecs(&packer);
        let (tensors, _) = packer.pack(part, &mb, &mut hecs, None, 1).unwrap();
        for l in 0..3 {
            let esrc = tensors[1 + 3 * l].to_i32().unwrap();
            let edst = tensors[1 + 3 * l + 1].to_i32().unwrap();
            let ew = tensors[1 + 3 * l + 2].to_f32().unwrap();
            assert!(
                ew.iter().all(|&w| w == 0.0 || w == 1.0),
                "layer {l}: GAT weights must stay a 0/1 mask"
            );
            // every admitted destination has its self loop (src == dst
            // position, prefix property)
            let nd = mb.layers[l + 1].len();
            let mut has_self = vec![false; nd];
            for (i, (&s_, &d)) in esrc.iter().zip(&edst).enumerate() {
                if i < mb.edges[l].len() && s_ == d {
                    has_self[d as usize] = true;
                }
            }
            assert!(
                has_self.iter().all(|&x| x),
                "layer {l}: missing self-loop edges"
            );
        }
    }

    #[test]
    fn label_mask_covers_only_real_seeds() {
        let parts = setup();
        let part = &parts[0];
        let packer = tiny_packer();
        let mut s = NeighborSampler::new(
            vec![4, 6, 8],
            packer.node_caps.clone(),
            false,
            crate::config::SamplerKind::Serial,
        );
        let seeds: Vec<u32> = part.train_vertices.iter().take(10).copied().collect();
        let mb = s.sample(part, &seeds, &mut Pcg64::seeded(4));
        let mut hecs = empty_hecs(&packer);
        let (tensors, _) = packer.pack(part, &mb, &mut hecs, None, 0).unwrap();
        let lmask = tensors[tensors.len() - 2].to_f32().unwrap();
        assert_eq!(lmask.iter().filter(|&&m| m == 1.0).count(), 10);
        assert_eq!(lmask.iter().filter(|&&m| m == 0.0).count(), 22);
    }

    /// bf16 packing: feature/HEC-value tensors shrink 2x, values match
    /// the f32 pack up to one rounding, hit/miss bookkeeping is identical.
    #[test]
    fn bf16_pack_halves_feature_bytes_and_tracks_f32_values() {
        use crate::runtime::tensor::DType;
        let parts = setup();
        let part = &parts[0];
        let packer_f = tiny_packer();
        let packer_b = tiny_packer().with_dtype(DtypeKind::Bf16);
        let mb = sample_mb(part, &packer_f, 6);
        let mut hecs_f = empty_hecs(&packer_f);
        let mut hecs_b = vec![
            Hec::new_with(1024, 2, packer_b.feat_dim, DtypeKind::Bf16),
            Hec::new_with(1024, 2, packer_b.hidden, DtypeKind::Bf16),
            Hec::new_with(1024, 2, packer_b.hidden, DtypeKind::Bf16),
        ];
        for &v in &mb.layers[0] {
            if part.is_halo(v) {
                let vid_o = part.vid_o[v as usize];
                hecs_f[0].store(vid_o, &vec![0.5f32; packer_f.feat_dim]);
                hecs_b[0].store(vid_o, &vec![0.5f32; packer_f.feat_dim]);
            }
        }
        let (tf, sf) = packer_f.pack(part, &mb, &mut hecs_f, None, 3).unwrap();
        let (tb, sb) = packer_b.pack(part, &mb, &mut hecs_b, None, 3).unwrap();
        assert_eq!(tb.len(), tf.len());
        // feats and the two inner-layer hec_val tensors are bf16, half size
        for i in [0usize, 11, 13] {
            assert_eq!(tb[i].dtype, DType::Bf16, "tensor {i}");
            assert_eq!(tb[i].shape, tf[i].shape, "tensor {i}");
            assert_eq!(tb[i].data.len() * 2, tf[i].data.len(), "tensor {i}");
        }
        // edge tensors and labels keep their exact dtypes/bytes
        assert_eq!(tb[3].dtype, DType::F32); // ew0
        assert_eq!(tb[1], tf[1]); // esrc0
        assert_eq!(sf.halo_hits, sb.halo_hits);
        assert_eq!(sf.halo_searches, sb.halo_searches);
        assert_eq!(sf.edges_dropped, sb.edges_dropped);
        // values match the f32 pack within one bf16 rounding
        let ff = tf[0].to_f32().unwrap();
        let fb = tb[0].to_f32().unwrap();
        assert_eq!(ff.len(), fb.len());
        for (a, b) in ff.iter().zip(&fb) {
            assert!((a - b).abs() <= a.abs() / 256.0 + 1e-7, "{a} vs {b}");
        }
    }

    #[test]
    fn distdgl_mode_fetches_halo_features() {
        let parts = setup();
        let ds = DatasetPreset::tiny().generate();
        let part = &parts[0];
        let packer = tiny_packer();
        let mb = sample_mb(part, &packer, 5);
        let mut hecs = empty_hecs(&packer);
        let fetch = |vid_o: u32| Some(ds.feature_row(vid_o).to_vec());
        let (_, stats) = packer.pack(part, &mb, &mut hecs, Some(&fetch), 0).unwrap();
        // every halo resolved, nothing dropped
        assert_eq!(stats.halo_hits[0], stats.halo_searches[0]);
        assert_eq!(stats.edges_dropped, 0);
        // HECs untouched in DistDGL mode
        assert_eq!(hecs[0].stats.searches, 0);
    }
}
