//! Model parameters: deterministic initialization against the artifact's
//! parameter specs, flat-vector views for all-reduce and the optimizer.
//!
//! All ranks initialize from the same seed, so data-parallel replicas start
//! identical (the paper's data-parallelism paradigm, §4.2).

use anyhow::Result;

use crate::runtime::artifacts::{ProgramSpec, TensorSpec};
use crate::runtime::tensor::{DType, HostTensor};
use crate::util::rng::Pcg64;

#[derive(Clone)]
pub struct ParamSet {
    pub specs: Vec<TensorSpec>,
    /// Flattened contiguous values (concatenation in spec order).
    pub flat: Vec<f32>,
    /// Start offset of each tensor in `flat`.
    offsets: Vec<usize>,
}

impl ParamSet {
    /// The first `meta.n_params` inputs of a train program are parameters.
    pub fn param_specs(prog: &ProgramSpec) -> Result<Vec<TensorSpec>> {
        let n = prog.meta_usize("n_params")?;
        Ok(prog.inputs[..n].to_vec())
    }

    /// Glorot-uniform init for matrices, zeros for vectors (biases).
    pub fn init_glorot(specs: Vec<TensorSpec>, seed: u64) -> ParamSet {
        let mut rng = Pcg64::new(seed, 0x9a7a);
        let mut flat = Vec::new();
        let mut offsets = Vec::with_capacity(specs.len());
        for s in &specs {
            offsets.push(flat.len());
            let n = s.num_elements();
            if s.shape.len() >= 2 {
                let fan_in = s.shape[0] as f64;
                let fan_out = s.shape[1] as f64;
                let limit = (6.0 / (fan_in + fan_out)).sqrt();
                for _ in 0..n {
                    flat.push(((rng.gen_f64() * 2.0 - 1.0) * limit) as f32);
                }
            } else {
                flat.extend(std::iter::repeat(0.0f32).take(n));
            }
        }
        ParamSet {
            specs,
            flat,
            offsets,
        }
    }

    pub fn num_values(&self) -> usize {
        self.flat.len()
    }

    pub fn bytes(&self) -> usize {
        self.flat.len() * 4
    }

    /// Slice of one parameter tensor.
    pub fn tensor_values(&self, i: usize) -> &[f32] {
        let start = self.offsets[i];
        &self.flat[start..start + self.specs[i].num_elements()]
    }

    /// Materialize as HostTensors (program inputs).
    pub fn to_tensors(&self) -> Vec<HostTensor> {
        self.specs
            .iter()
            .enumerate()
            .map(|(i, s)| HostTensor::f32(s.shape.clone(), self.tensor_values(i)))
            .collect()
    }

    /// Flatten gradient outputs (same spec order) into one vector.
    pub fn flatten_grads(&self, grads: &[HostTensor]) -> Result<Vec<f32>> {
        anyhow::ensure!(grads.len() == self.specs.len(), "grad arity mismatch");
        let mut flat = Vec::with_capacity(self.flat.len());
        for (g, s) in grads.iter().zip(&self.specs) {
            anyhow::ensure!(g.dtype == DType::F32 && g.shape == s.shape, "grad spec mismatch");
            flat.extend(g.to_f32()?);
        }
        Ok(flat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<TensorSpec> {
        vec![
            TensorSpec {
                name: "w".into(),
                dtype: DType::F32,
                shape: vec![4, 8],
            },
            TensorSpec {
                name: "b".into(),
                dtype: DType::F32,
                shape: vec![8],
            },
        ]
    }

    #[test]
    fn init_is_deterministic_and_bounded() {
        let a = ParamSet::init_glorot(specs(), 7);
        let b = ParamSet::init_glorot(specs(), 7);
        let c = ParamSet::init_glorot(specs(), 8);
        assert_eq!(a.flat, b.flat);
        assert_ne!(a.flat, c.flat);
        let limit = (6.0f64 / 12.0).sqrt() as f32;
        assert!(a.tensor_values(0).iter().all(|v| v.abs() <= limit));
        assert!(a.tensor_values(1).iter().all(|&v| v == 0.0));
        assert_eq!(a.num_values(), 40);
    }

    #[test]
    fn tensors_match_specs() {
        let p = ParamSet::init_glorot(specs(), 1);
        let ts = p.to_tensors();
        assert_eq!(ts[0].shape, vec![4, 8]);
        assert_eq!(ts[1].shape, vec![8]);
        assert_eq!(ts[0].to_f32().unwrap(), p.tensor_values(0));
    }

    #[test]
    fn grad_flatten_checks_shapes() {
        let p = ParamSet::init_glorot(specs(), 1);
        let g = vec![
            HostTensor::f32(vec![4, 8], &[0.5; 32]),
            HostTensor::f32(vec![8], &[1.0; 8]),
        ];
        let flat = p.flatten_grads(&g).unwrap();
        assert_eq!(flat.len(), 40);
        assert_eq!(flat[0], 0.5);
        assert_eq!(flat[39], 1.0);
        let bad = vec![HostTensor::f32(vec![4, 8], &[0.0; 32])];
        assert!(p.flatten_grads(&bad).is_err());
    }
}
