//! Linear Deterministic Greedy (LDG) streaming partitioner
//! (Stanton & Kliot, KDD'12) with a training-vertex balance term.
//!
//! Streams vertices in random order; assigns each to the part maximizing
//! `|N(v) ∩ part| * (1 - size/capacity)`, with ties broken toward the part
//! with fewer training vertices. Middle ground between hash partitioning
//! and the multilevel partitioner in the quality ablation.

use crate::graph::{Csr, Vid};
use crate::partition::{Assignment, Partitioner};
use crate::util::rng::Pcg64;

pub struct LdgPartitioner;

impl Partitioner for LdgPartitioner {
    fn name(&self) -> &'static str {
        "ldg"
    }

    fn partition(&self, graph: &Csr, train: &[Vid], k: usize, seed: u64) -> Assignment {
        let n = graph.num_vertices();
        let mut is_train = vec![false; n];
        for &t in train {
            is_train[t as usize] = true;
        }
        let capacity = (n as f64 / k as f64) * 1.05 + 1.0;
        let train_cap = (train.len() as f64 / k as f64) * 1.1 + 1.0;

        let mut order: Vec<Vid> = (0..n as u32).collect();
        let mut rng = Pcg64::new(seed, 0x1d9);
        rng.shuffle(&mut order);

        let mut parts = vec![u32::MAX; n];
        let mut sizes = vec![0usize; k];
        let mut train_sizes = vec![0usize; k];
        let mut neigh_count = vec![0u32; k];

        for &v in &order {
            // count already-placed neighbors per part
            for x in neigh_count.iter_mut() {
                *x = 0;
            }
            for &u in graph.neighbors(v) {
                let p = parts[u as usize];
                if p != u32::MAX {
                    neigh_count[p as usize] += 1;
                }
            }
            let mut best = 0usize;
            let mut best_score = f64::NEG_INFINITY;
            for p in 0..k {
                if is_train[v as usize] && train_sizes[p] as f64 >= train_cap {
                    continue;
                }
                if sizes[p] as f64 >= capacity {
                    continue;
                }
                let score = (1.0 + neigh_count[p] as f64) * (1.0 - sizes[p] as f64 / capacity)
                    - 0.01 * train_sizes[p] as f64 / train_cap;
                if score > best_score {
                    best_score = score;
                    best = p;
                }
            }
            if best_score == f64::NEG_INFINITY {
                // all parts at capacity (can happen at the tail): least-loaded
                best = (0..k).min_by_key(|&p| sizes[p]).unwrap();
            }
            parts[v as usize] = best as u32;
            sizes[best] += 1;
            if is_train[v as usize] {
                train_sizes[best] += 1;
            }
        }
        Assignment { parts, k }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DatasetPreset;
    use crate::partition::random::RandomPartitioner;
    use crate::partition::stats::PartitionStats;

    #[test]
    fn beats_random_on_edge_cut() {
        let ds = DatasetPreset::tiny().generate();
        let ldg = LdgPartitioner.partition(&ds.graph, &ds.train_vertices, 4, 7);
        let rnd = RandomPartitioner.partition(&ds.graph, &ds.train_vertices, 4, 7);
        let s_ldg = PartitionStats::compute(&ds.graph, &ds.train_vertices, &ldg);
        let s_rnd = PartitionStats::compute(&ds.graph, &ds.train_vertices, &rnd);
        assert!(
            s_ldg.edge_cut_fraction < s_rnd.edge_cut_fraction,
            "ldg {} >= random {}",
            s_ldg.edge_cut_fraction,
            s_rnd.edge_cut_fraction
        );
    }

    #[test]
    fn respects_balance() {
        let ds = DatasetPreset::tiny().generate();
        let a = LdgPartitioner.partition(&ds.graph, &ds.train_vertices, 8, 3);
        a.validate(ds.num_vertices()).unwrap();
        let s = PartitionStats::compute(&ds.graph, &ds.train_vertices, &a);
        assert!(s.vertex_imbalance < 1.15, "vertex imbalance {}", s.vertex_imbalance);
        assert!(s.train_imbalance < 1.35, "train imbalance {}", s.train_imbalance);
    }
}
