//! Materialize per-rank partitions: local CSR with solid + halo vertices,
//! VID_o ↔ VID_p lookup tables, per-rank feature/label shards, halo
//! ownership — the graph-partition data structure of paper §3.1/§3.2.
//!
//! VID_p numbering convention: solid vertices first `[0, n_solid)`, halo
//! vertices after `[n_solid, n_local)`. Halo vertices carry no features and
//! no neighbor lists (their neighborhoods live on the owning rank); they
//! appear only as sources in solid vertices' neighbor lists, exactly like
//! the paper's halo avatars.
//!
//! Two construction paths share one per-rank builder ([`build_rank`]):
//!
//! * [`materialize`] — the classic in-RAM path: build all `k` partitions
//!   at once and hand them to the driver.
//! * [`write_shards`] — the out-of-core path: build **one** rank at a
//!   time, stream it into a checksummed shard file
//!   ([`crate::graph::io::write_shard_from_partition`]), and drop it
//!   before the next — peak RSS is the dataset plus a single partition,
//!   never `k` partitions. The driver later maps the shards back with
//!   [`crate::graph::io::ShardSet`], reconstructing partitions whose
//!   array contents are byte-identical to this path's output.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::graph::{Csr, Dataset, Vid};
use crate::partition::Assignment;
use crate::util::mmap::Storage;

/// One rank's share of the graph.
///
/// Array fields live in [`Storage`]: heap vectors when built by
/// [`materialize`]/[`build_rank`], mapped slices over a shard file on the
/// out-of-core path. `global_to_local` is always heap-resident — it is
/// rebuilt from `vid_o` at shard-load time for the ranks this process
/// hosts (a documented residual RAM cost, local ranks only).
#[derive(Clone, Debug)]
pub struct RankPartition {
    pub rank: u32,
    pub k: usize,
    /// Local adjacency over VID_p ids. Rows exist for solids; halo rows are
    /// empty.
    pub local: Csr,
    pub n_solid: usize,
    /// VID_p -> VID_o lookup table (the paper's graph LUT).
    pub vid_o: Storage<Vid>,
    /// VID_o -> VID_p for vertices present locally (solid or halo).
    pub global_to_local: HashMap<Vid, u32>,
    /// For halo vertices (index by VID_p - n_solid): owning rank.
    pub halo_owner: Storage<u32>,
    /// Local training seeds / test vertices (VID_p, all solid).
    pub train_vertices: Storage<u32>,
    pub test_vertices: Storage<u32>,
    /// Features of solid vertices, row-major n_solid x feat_dim.
    pub features: Storage<f32>,
    pub feat_dim: usize,
    /// Labels of solid vertices.
    pub labels: Storage<u32>,
    /// Degree (in the full graph) of each local vertex — used for the
    /// paper's degree-biased solid-vertex subsampling.
    pub full_degree: Storage<u32>,
}

impl RankPartition {
    pub fn n_local(&self) -> usize {
        self.vid_o.len()
    }
    pub fn n_halo(&self) -> usize {
        self.n_local() - self.n_solid
    }
    pub fn is_halo(&self, vid_p: u32) -> bool {
        (vid_p as usize) >= self.n_solid
    }
    pub fn feature_row(&self, solid_vid_p: u32) -> &[f32] {
        debug_assert!(!self.is_halo(solid_vid_p));
        let d = self.feat_dim;
        &self.features[solid_vid_p as usize * d..(solid_vid_p as usize + 1) * d]
    }

    /// Halo VID_o list grouped by owning rank (input to db_halo broadcast).
    pub fn halos_by_owner(&self) -> Vec<Vec<Vid>> {
        let mut out = vec![Vec::new(); self.k];
        for h in 0..self.n_halo() {
            let owner = self.halo_owner[h] as usize;
            out[owner].push(self.vid_o[self.n_solid + h]);
        }
        out
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        if self.local.num_vertices() != self.n_local() {
            anyhow::bail!("local csr size mismatch");
        }
        if self.halo_owner.len() != self.n_halo() {
            anyhow::bail!("halo owner table size mismatch");
        }
        if self.features.len() != self.n_solid * self.feat_dim {
            anyhow::bail!("feature shard size mismatch");
        }
        for h in 0..self.n_halo() {
            if self.halo_owner[h] == self.rank {
                anyhow::bail!("halo {h} owned by this rank");
            }
            if self.local.degree((self.n_solid + h) as u32) != 0 {
                anyhow::bail!("halo {h} has a neighbor list");
            }
        }
        for (&vo, &vp) in &self.global_to_local {
            if self.vid_o[vp as usize] != vo {
                anyhow::bail!("LUT inconsistency at {vo}");
            }
        }
        for &t in self.train_vertices.iter().chain(self.test_vertices.iter()) {
            if self.is_halo(t) {
                anyhow::bail!("train/test vertex {t} is halo");
            }
        }
        Ok(())
    }
}

/// Rebuild the VID_o -> VID_p lookup table from a `vid_o` LUT (shard
/// files store only the forward table; the hash map is a load-time,
/// local-ranks-only reconstruction).
pub fn rebuild_global_to_local(vid_o: &[Vid]) -> HashMap<Vid, u32> {
    let mut m = HashMap::with_capacity(vid_o.len() * 2);
    for (i, &v) in vid_o.iter().enumerate() {
        m.insert(v, i as u32);
    }
    m
}

/// Build one rank's partition (shared by [`materialize`] and
/// [`write_shards`] so both paths produce byte-identical arrays).
pub fn build_rank(
    ds: &Dataset,
    assignment: &Assignment,
    my_solids: &[Vid],
    rank: usize,
) -> RankPartition {
    let k = assignment.k;
    let d = ds.feat_dim;
    let mut global_to_local: HashMap<Vid, u32> = HashMap::with_capacity(my_solids.len() * 2);
    for (i, &v) in my_solids.iter().enumerate() {
        global_to_local.insert(v, i as u32);
    }
    let n_solid = my_solids.len();

    // Discover halos: remote endpoints of cut edges.
    let mut vid_o: Vec<Vid> = my_solids.to_vec();
    let mut halo_owner: Vec<u32> = Vec::new();
    for &v in my_solids {
        for &u in ds.graph.neighbors(v) {
            let pu = assignment.parts[u as usize];
            if pu as usize != rank && !global_to_local.contains_key(&u) {
                global_to_local.insert(u, vid_o.len() as u32);
                vid_o.push(u);
                halo_owner.push(pu);
            }
        }
    }
    let n_local = vid_o.len();

    // Local CSR: solid rows get all neighbors (mapped); halo rows empty.
    let mut indptr = vec![0u64; n_local + 1];
    for (i, &v) in my_solids.iter().enumerate() {
        indptr[i + 1] = indptr[i] + ds.graph.degree(v) as u64;
    }
    for i in n_solid..n_local {
        indptr[i + 1] = indptr[i];
    }
    let mut indices = vec![0u32; indptr[n_local] as usize];
    for (i, &v) in my_solids.iter().enumerate() {
        let row_start = indptr[i] as usize;
        for (j, &u) in ds.graph.neighbors(v).iter().enumerate() {
            indices[row_start + j] = global_to_local[&u];
        }
    }
    let local = Csr {
        indptr: indptr.into(),
        indices: indices.into(),
    };

    // Shards.
    let mut features = vec![0f32; n_solid * d];
    let mut labels = vec![0u32; n_solid];
    for (i, &v) in my_solids.iter().enumerate() {
        features[i * d..(i + 1) * d].copy_from_slice(ds.feature_row(v));
        labels[i] = ds.labels[v as usize];
    }
    let full_degree: Vec<u32> = vid_o
        .iter()
        .map(|&vo| ds.graph.degree(vo) as u32)
        .collect();

    let train_vertices: Vec<u32> = ds
        .train_vertices
        .iter()
        .filter(|&&v| assignment.parts[v as usize] as usize == rank)
        .map(|&v| global_to_local[&v])
        .collect();
    let test_vertices: Vec<u32> = ds
        .test_vertices
        .iter()
        .filter(|&&v| assignment.parts[v as usize] as usize == rank)
        .map(|&v| global_to_local[&v])
        .collect();

    RankPartition {
        rank: rank as u32,
        k,
        local,
        n_solid,
        vid_o: vid_o.into(),
        global_to_local,
        halo_owner: halo_owner.into(),
        train_vertices: train_vertices.into(),
        test_vertices: test_vertices.into(),
        features: features.into(),
        feat_dim: d,
        labels: labels.into(),
        full_degree: full_degree.into(),
    }
}

/// Solid lists per rank (pass 1 of both construction paths).
fn solids_per_rank(assignment: &Assignment, n: usize) -> Vec<Vec<Vid>> {
    let mut solids: Vec<Vec<Vid>> = vec![Vec::new(); assignment.k];
    for v in 0..n {
        solids[assignment.parts[v] as usize].push(v as Vid);
    }
    solids
}

/// Split a dataset into `k` rank partitions according to `assignment`.
pub fn materialize(ds: &Dataset, assignment: &Assignment) -> Vec<RankPartition> {
    let solids = solids_per_rank(assignment, ds.num_vertices());
    (0..assignment.k)
        .map(|rank| build_rank(ds, assignment, &solids[rank], rank))
        .collect()
}

/// Out-of-core materialization: build each rank's partition in turn,
/// stream it into `dir/shard-r<rank>.dshd`, and drop it before building
/// the next — the full set of partitions never coexists in RAM. Writes
/// the shard-set manifest (`shards.json`) last, so a crash mid-write
/// leaves no openable set behind. Returns the per-rank content checksums
/// in rank order.
pub fn write_shards(
    ds: &Dataset,
    assignment: &Assignment,
    dir: &Path,
    preset: &str,
    partitioner: &str,
    seed: u64,
) -> Result<Vec<u64>> {
    use crate::graph::io::{shard_file_name, write_shard_from_partition, ShardManifest};
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating shard dir {}", dir.display()))?;
    let solids = solids_per_rank(assignment, ds.num_vertices());
    let mut manifest = ShardManifest::new(preset, assignment.k, seed, partitioner);
    manifest.feat_dim = ds.feat_dim as u32;
    manifest.num_classes = ds.num_classes as u32;
    let mut checksums = Vec::with_capacity(assignment.k);
    for rank in 0..assignment.k {
        let part = build_rank(ds, assignment, &solids[rank], rank);
        let file = shard_file_name(rank as u32);
        let crc =
            write_shard_from_partition(&dir.join(&file), &part, ds.num_classes as u32)?;
        manifest.push_rank(&file, crc, &part);
        checksums.push(crc);
        // `part` drops here: one partition resident at a time
    }
    manifest.save(dir)?;
    Ok(checksums)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DatasetPreset;
    use crate::partition::metis_like::MetisLikePartitioner;
    use crate::partition::Partitioner;

    fn setup(k: usize) -> (Dataset, Vec<RankPartition>) {
        let ds = DatasetPreset::tiny().generate();
        let a = MetisLikePartitioner::default().partition(&ds.graph, &ds.train_vertices, k, 3);
        let parts = materialize(&ds, &a);
        (ds, parts)
    }

    #[test]
    fn partitions_are_valid_and_cover_graph() {
        let (ds, parts) = setup(4);
        let mut solid_total = 0;
        for p in &parts {
            p.validate().unwrap();
            solid_total += p.n_solid;
        }
        assert_eq!(solid_total, ds.num_vertices());
        let train_total: usize = parts.iter().map(|p| p.train_vertices.len()).sum();
        assert_eq!(train_total, ds.train_vertices.len());
    }

    #[test]
    fn edges_preserved_for_solids() {
        let (ds, parts) = setup(3);
        for p in &parts {
            for vp in 0..p.n_solid as u32 {
                let vo = p.vid_o[vp as usize];
                let local_neigh: Vec<Vid> = p
                    .local
                    .neighbors(vp)
                    .iter()
                    .map(|&up| p.vid_o[up as usize])
                    .collect();
                let mut expect: Vec<Vid> = ds.graph.neighbors(vo).to_vec();
                let mut got = local_neigh.clone();
                expect.sort_unstable();
                got.sort_unstable();
                assert_eq!(got, expect, "rank {} vertex {}", p.rank, vo);
            }
        }
    }

    #[test]
    fn halo_owners_correct() {
        let (ds, parts) = setup(4);
        let a = MetisLikePartitioner::default().partition(&ds.graph, &ds.train_vertices, 4, 3);
        for p in &parts {
            for h in 0..p.n_halo() {
                let vo = p.vid_o[p.n_solid + h];
                assert_eq!(p.halo_owner[h], a.parts[vo as usize]);
            }
        }
    }

    #[test]
    fn features_shard_matches_dataset() {
        let (ds, parts) = setup(2);
        for p in &parts {
            for vp in 0..p.n_solid as u32 {
                let vo = p.vid_o[vp as usize];
                assert_eq!(p.feature_row(vp), ds.feature_row(vo));
                assert_eq!(p.labels[vp as usize], ds.labels[vo as usize]);
            }
        }
    }

    #[test]
    fn halos_by_owner_groups_correctly() {
        let (_, parts) = setup(4);
        for p in &parts {
            let groups = p.halos_by_owner();
            assert!(groups[p.rank as usize].is_empty());
            let total: usize = groups.iter().map(|g| g.len()).sum();
            assert_eq!(total, p.n_halo());
        }
    }
}
