//! Multilevel min-edge-cut partitioner with training-vertex balance — the
//! stand-in for METIS + DistDGL's balancing extension (paper §3.1).
//!
//! Classic three-phase scheme:
//! 1. **Coarsen** — repeated heavy-edge matching; matched pairs merge into
//!    super-vertices carrying (vertex weight, train weight) and weighted
//!    edges.
//! 2. **Initial partition** — greedy BFS region growing on the coarsest
//!    graph under both weight capacities.
//! 3. **Uncoarsen + refine** — project the assignment back level by level,
//!    then FM-style boundary passes move vertices to the neighboring part
//!    with maximal cut gain subject to the balance constraints.

use crate::graph::{Csr, Vid};
use crate::partition::{Assignment, Partitioner};
use crate::util::rng::Pcg64;

/// Weighted graph used during coarsening.
struct WGraph {
    /// adjacency: per vertex, (neighbor, edge weight)
    adj: Vec<Vec<(u32, u64)>>,
    vweight: Vec<u64>,
    tweight: Vec<u64>,
}

impl WGraph {
    fn n(&self) -> usize {
        self.adj.len()
    }

    fn from_csr(g: &Csr, train_mask: &[bool]) -> WGraph {
        let n = g.num_vertices();
        let adj = (0..n)
            .map(|v| g.neighbors(v as Vid).iter().map(|&u| (u, 1u64)).collect())
            .collect();
        WGraph {
            adj,
            vweight: vec![1; n],
            tweight: train_mask.iter().map(|&t| t as u64).collect(),
        }
    }
}

pub struct MetisLikePartitioner {
    /// Stop coarsening when the graph is below `coarsen_target * k` vertices.
    pub coarsen_target: usize,
    /// Number of FM refinement passes per level.
    pub refine_passes: usize,
    /// Allowed imbalance (1.05 = 5% over mean).
    pub epsilon: f64,
}

impl Default for MetisLikePartitioner {
    fn default() -> Self {
        MetisLikePartitioner {
            coarsen_target: 30,
            refine_passes: 4,
            epsilon: 1.05,
        }
    }
}

impl Partitioner for MetisLikePartitioner {
    fn name(&self) -> &'static str {
        "metis-like"
    }

    fn partition(&self, graph: &Csr, train: &[Vid], k: usize, seed: u64) -> Assignment {
        let n = graph.num_vertices();
        if k <= 1 {
            return Assignment {
                parts: vec![0; n],
                k: 1,
            };
        }
        let mut train_mask = vec![false; n];
        for &t in train {
            train_mask[t as usize] = true;
        }
        let mut rng = Pcg64::new(seed, 0x3e7);

        // ---- Phase 1: coarsen --------------------------------------------
        let mut levels: Vec<(WGraph, Vec<u32>)> = Vec::new(); // (graph, map fine->coarse)
        let mut cur = WGraph::from_csr(graph, &train_mask);
        while cur.n() > self.coarsen_target * k && levels.len() < 20 {
            let (coarse, map) = coarsen_once(&cur, &mut rng);
            if coarse.n() as f64 > cur.n() as f64 * 0.95 {
                break; // matching stalled
            }
            levels.push((std::mem::replace(&mut cur, coarse), map));
        }

        // ---- Phase 2: initial partition on the coarsest graph -----------
        let mut parts = initial_partition(&cur, k, self.epsilon, &mut rng);
        refine(&cur, &mut parts, k, self.epsilon, self.refine_passes, &mut rng);

        // ---- Phase 3: uncoarsen + refine ---------------------------------
        while let Some((fine, map)) = levels.pop() {
            let mut fine_parts = vec![0u32; fine.n()];
            for v in 0..fine.n() {
                fine_parts[v] = parts[map[v] as usize];
            }
            parts = fine_parts;
            refine(&fine, &mut parts, k, self.epsilon, self.refine_passes, &mut rng);
        }

        Assignment { parts, k }
    }
}

/// One round of heavy-edge matching. Returns the coarse graph and the
/// fine→coarse vertex map.
fn coarsen_once(g: &WGraph, rng: &mut Pcg64) -> (WGraph, Vec<u32>) {
    let n = g.n();
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    let mut mate = vec![u32::MAX; n];
    for &v in &order {
        if mate[v as usize] != u32::MAX {
            continue;
        }
        // heaviest unmatched neighbor
        let mut best = u32::MAX;
        let mut best_w = 0u64;
        for &(u, w) in &g.adj[v as usize] {
            if u != v && mate[u as usize] == u32::MAX && w > best_w {
                best = u;
                best_w = w;
            }
        }
        if best != u32::MAX {
            mate[v as usize] = best;
            mate[best as usize] = v;
        } else {
            mate[v as usize] = v; // self-matched
        }
    }
    // assign coarse ids
    let mut map = vec![u32::MAX; n];
    let mut next = 0u32;
    for v in 0..n {
        if map[v] != u32::MAX {
            continue;
        }
        let m = mate[v] as usize;
        map[v] = next;
        map[m] = next;
        next += 1;
    }
    let cn = next as usize;
    // build coarse adjacency via hashmap per row
    let mut vweight = vec![0u64; cn];
    let mut tweight = vec![0u64; cn];
    for v in 0..n {
        // count each fine vertex once (self-matched maps alone)
        if mate[v] as usize >= v {
            vweight[map[v] as usize] += g.vweight[v];
            tweight[map[v] as usize] += g.tweight[v];
            let m = mate[v] as usize;
            if m != v {
                vweight[map[v] as usize] += g.vweight[m];
                tweight[map[v] as usize] += g.tweight[m];
            }
        }
    }
    let mut adj: Vec<Vec<(u32, u64)>> = vec![Vec::new(); cn];
    let mut acc: std::collections::BTreeMap<u32, u64> = std::collections::BTreeMap::new();
    for cv in 0..cn as u32 {
        acc.clear();
        // fine members of cv
        // (collect lazily: we need reverse map; build once)
        adj[cv as usize] = Vec::new();
    }
    // reverse map: coarse -> fine members
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); cn];
    for v in 0..n {
        members[map[v] as usize].push(v as u32);
    }
    for cv in 0..cn {
        acc.clear();
        for &v in &members[cv] {
            for &(u, w) in &g.adj[v as usize] {
                let cu = map[u as usize];
                if cu as usize != cv {
                    *acc.entry(cu).or_insert(0) += w;
                }
            }
        }
        adj[cv] = acc.iter().map(|(&u, &w)| (u, w)).collect();
    }
    (
        WGraph {
            adj,
            vweight,
            tweight,
        },
        map,
    )
}

/// Greedy BFS region growing under vertex + train weight capacities.
fn initial_partition(g: &WGraph, k: usize, eps: f64, rng: &mut Pcg64) -> Vec<u32> {
    let n = g.n();
    let total_v: u64 = g.vweight.iter().sum();
    let total_t: u64 = g.tweight.iter().sum();
    let cap_v = ((total_v as f64 / k as f64) * eps).ceil() as u64 + 1;
    let cap_t = ((total_t as f64 / k as f64) * eps).ceil() as u64 + 1;

    let mut parts = vec![u32::MAX; n];
    let mut size_v = vec![0u64; k];
    let mut size_t = vec![0u64; k];
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    let mut oi = 0usize;

    for p in 0..k {
        // seed from an unassigned vertex
        while oi < n && parts[order[oi] as usize] != u32::MAX {
            oi += 1;
        }
        if oi >= n {
            break;
        }
        let mut queue = std::collections::VecDeque::new();
        queue.push_back(order[oi]);
        while let Some(v) = queue.pop_front() {
            if parts[v as usize] != u32::MAX {
                continue;
            }
            if size_v[p] + g.vweight[v as usize] > cap_v
                || size_t[p] + g.tweight[v as usize] > cap_t
            {
                continue;
            }
            parts[v as usize] = p as u32;
            size_v[p] += g.vweight[v as usize];
            size_t[p] += g.tweight[v as usize];
            if size_v[p] >= cap_v.saturating_sub(1) {
                break;
            }
            for &(u, _) in &g.adj[v as usize] {
                if parts[u as usize] == u32::MAX {
                    queue.push_back(u);
                }
            }
        }
    }
    // leftovers: least-loaded (by train weight first, then vertex weight)
    for v in 0..n {
        if parts[v] == u32::MAX {
            let p = (0..k)
                .min_by_key(|&p| (size_t[p], size_v[p]))
                .unwrap();
            parts[v] = p as u32;
            size_v[p] += g.vweight[v];
            size_t[p] += g.tweight[v];
        }
    }
    parts
}

/// FM-style boundary refinement: move boundary vertices to the neighbor
/// part with the largest positive cut gain, respecting both capacities.
fn refine(g: &WGraph, parts: &mut [u32], k: usize, eps: f64, passes: usize, rng: &mut Pcg64) {
    let n = g.n();
    let total_v: u64 = g.vweight.iter().sum();
    let total_t: u64 = g.tweight.iter().sum();
    let cap_v = ((total_v as f64 / k as f64) * eps).ceil() as u64 + 1;
    let cap_t = ((total_t as f64 / k as f64) * eps).ceil() as u64 + 1;

    let mut size_v = vec![0u64; k];
    let mut size_t = vec![0u64; k];
    for v in 0..n {
        size_v[parts[v] as usize] += g.vweight[v];
        size_t[parts[v] as usize] += g.tweight[v];
    }

    let mut order: Vec<u32> = (0..n as u32).collect();
    for _ in 0..passes {
        rng.shuffle(&mut order);
        let mut moved = 0usize;
        let mut conn: std::collections::BTreeMap<u32, i64> = std::collections::BTreeMap::new();
        for &v in &order {
            let vp = parts[v as usize];
            conn.clear();
            for &(u, w) in &g.adj[v as usize] {
                *conn.entry(parts[u as usize]).or_insert(0) += w as i64;
            }
            let internal = conn.get(&vp).copied().unwrap_or(0);
            let mut best_part = vp;
            let mut best_gain = 0i64;
            for (&p, &w) in conn.iter() {
                if p == vp {
                    continue;
                }
                let gain = w - internal;
                if gain > best_gain
                    && size_v[p as usize] + g.vweight[v as usize] <= cap_v
                    && size_t[p as usize] + g.tweight[v as usize] <= cap_t
                {
                    best_gain = gain;
                    best_part = p;
                }
            }
            if best_part != vp {
                size_v[vp as usize] -= g.vweight[v as usize];
                size_t[vp as usize] -= g.tweight[v as usize];
                size_v[best_part as usize] += g.vweight[v as usize];
                size_t[best_part as usize] += g.tweight[v as usize];
                parts[v as usize] = best_part;
                moved += 1;
            }
        }
        if moved == 0 {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DatasetPreset;
    use crate::partition::random::RandomPartitioner;
    use crate::partition::stats::PartitionStats;

    #[test]
    fn much_better_cut_than_random() {
        let ds = DatasetPreset::tiny().generate();
        let m = MetisLikePartitioner::default().partition(&ds.graph, &ds.train_vertices, 4, 11);
        let r = RandomPartitioner.partition(&ds.graph, &ds.train_vertices, 4, 11);
        m.validate(ds.num_vertices()).unwrap();
        let sm = PartitionStats::compute(&ds.graph, &ds.train_vertices, &m);
        let sr = PartitionStats::compute(&ds.graph, &ds.train_vertices, &r);
        assert!(
            sm.edge_cut_fraction < 0.8 * sr.edge_cut_fraction,
            "metis-like {} vs random {}",
            sm.edge_cut_fraction,
            sr.edge_cut_fraction
        );
    }

    #[test]
    fn balances_vertices_and_train() {
        let ds = DatasetPreset::tiny().generate();
        for k in [2usize, 4, 8] {
            let a = MetisLikePartitioner::default().partition(&ds.graph, &ds.train_vertices, k, 5);
            let s = PartitionStats::compute(&ds.graph, &ds.train_vertices, &a);
            assert!(s.vertex_imbalance < 1.30, "k={k} v-imb {}", s.vertex_imbalance);
            assert!(s.train_imbalance < 1.40, "k={k} t-imb {}", s.train_imbalance);
            // every part non-empty
            assert!(s.part_sizes.iter().all(|&x| x > 0));
        }
    }

    #[test]
    fn k1_is_identity() {
        let ds = DatasetPreset::tiny().generate();
        let a = MetisLikePartitioner::default().partition(&ds.graph, &ds.train_vertices, 1, 0);
        assert!(a.parts.iter().all(|&p| p == 0));
    }

    #[test]
    fn deterministic_in_seed() {
        let ds = DatasetPreset::tiny().generate();
        let a = MetisLikePartitioner::default().partition(&ds.graph, &ds.train_vertices, 4, 9);
        let b = MetisLikePartitioner::default().partition(&ds.graph, &ds.train_vertices, 4, 9);
        assert_eq!(a, b);
    }

    #[test]
    fn separates_two_cliques() {
        // two 10-cliques joined by one edge must split on the bridge
        let mut edges = Vec::new();
        for i in 0..10u32 {
            for j in (i + 1)..10 {
                edges.push((i, j));
                edges.push((i + 10, j + 10));
            }
        }
        edges.push((0, 10));
        let g = Csr::from_edges(20, &edges);
        let a = MetisLikePartitioner::default().partition(&g, &[], 2, 1);
        let s = PartitionStats::compute(&g, &[], &a);
        assert!(
            (s.edge_cut_fraction - 1.0 / 91.0).abs() < 1e-9,
            "cut {}",
            s.edge_cut_fraction
        );
    }
}
