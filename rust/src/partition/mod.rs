//! Graph partitioning (paper §3.1): min-edge-cut partitioning with
//! training-vertex balance, and materialization of per-rank partitions with
//! solid/halo vertices and VID_o <-> VID_p lookup tables.
//!
//! Partitioners:
//! * [`metis_like`] — from-scratch multilevel partitioner (heavy-edge
//!   matching coarsening, greedy growing, FM boundary refinement) standing
//!   in for METIS with DistDGL's training-vertex balancing extension.
//! * [`ldg`] — linear deterministic greedy streaming baseline.
//! * [`random`] — hash partitioning baseline.

pub mod ldg;
pub mod materialize;
pub mod metis_like;
pub mod random;
pub mod stats;

pub use materialize::{
    build_rank, materialize, rebuild_global_to_local, write_shards, RankPartition,
};
pub use stats::PartitionStats;

use crate::graph::{Csr, Vid};

/// A k-way assignment of every vertex to a rank.
#[derive(Clone, Debug, PartialEq)]
pub struct Assignment {
    /// parts[vid_o] = rank in [0, k).
    pub parts: Vec<u32>,
    pub k: usize,
}

impl Assignment {
    pub fn part_of(&self, v: Vid) -> u32 {
        self.parts[v as usize]
    }

    pub fn part_sizes(&self) -> Vec<usize> {
        let mut sizes = vec![0usize; self.k];
        for &p in &self.parts {
            sizes[p as usize] += 1;
        }
        sizes
    }

    pub fn validate(&self, n: usize) -> anyhow::Result<()> {
        if self.parts.len() != n {
            anyhow::bail!("assignment length {} != n {}", self.parts.len(), n);
        }
        if self.parts.iter().any(|&p| p as usize >= self.k) {
            anyhow::bail!("part id out of range");
        }
        Ok(())
    }
}

/// Common interface for all partitioners.
pub trait Partitioner {
    fn name(&self) -> &'static str;
    /// Partition `graph` into `k` parts, balancing both total vertices and
    /// the given training vertices.
    fn partition(&self, graph: &Csr, train: &[Vid], k: usize, seed: u64) -> Assignment;
}
