//! Hash/random partitioning baseline: ignores structure entirely. Upper
//! bound on edge-cut; used by the partitioner-quality ablation bench.

use crate::graph::{Csr, Vid};
use crate::partition::{Assignment, Partitioner};
use crate::util::rng::splitmix64;

pub struct RandomPartitioner;

impl Partitioner for RandomPartitioner {
    fn name(&self) -> &'static str {
        "random"
    }

    fn partition(&self, graph: &Csr, _train: &[Vid], k: usize, seed: u64) -> Assignment {
        let n = graph.num_vertices();
        let parts = (0..n)
            .map(|v| (splitmix64(v as u64 ^ seed) % k as u64) as u32)
            .collect();
        Assignment { parts, k }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DatasetPreset;

    #[test]
    fn covers_all_parts_roughly_evenly() {
        let ds = DatasetPreset::tiny().generate();
        let a = RandomPartitioner.partition(&ds.graph, &ds.train_vertices, 8, 42);
        a.validate(ds.num_vertices()).unwrap();
        let sizes = a.part_sizes();
        let n = ds.num_vertices();
        for &s in &sizes {
            assert!(s > n / 16 && s < n / 4, "size {s} of n {n}");
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let ds = DatasetPreset::tiny().generate();
        let a = RandomPartitioner.partition(&ds.graph, &[], 4, 1);
        let b = RandomPartitioner.partition(&ds.graph, &[], 4, 1);
        let c = RandomPartitioner.partition(&ds.graph, &[], 4, 2);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }
}
