//! Partition quality metrics: edge-cut fraction, vertex/train balance,
//! halo counts — the quantities §3.1 of the paper optimizes for.

use crate::graph::{Csr, Vid};
use crate::partition::Assignment;

#[derive(Clone, Debug)]
pub struct PartitionStats {
    pub k: usize,
    /// Fraction of undirected edges whose endpoints land in different parts.
    pub edge_cut_fraction: f64,
    /// max part size / mean part size.
    pub vertex_imbalance: f64,
    /// max train count / mean train count.
    pub train_imbalance: f64,
    /// Per-part halo-vertex counts (distinct remote neighbors).
    pub halo_counts: Vec<usize>,
    pub part_sizes: Vec<usize>,
    pub train_sizes: Vec<usize>,
}

impl PartitionStats {
    pub fn compute(graph: &Csr, train: &[Vid], a: &Assignment) -> PartitionStats {
        let n = graph.num_vertices();
        let k = a.k;
        let mut cut = 0u64;
        let mut total = 0u64;
        // halo of part p = set of vertices not in p adjacent to a vertex in p
        let mut halo_sets: Vec<std::collections::HashSet<Vid>> =
            vec![std::collections::HashSet::new(); k];
        for v in 0..n {
            let pv = a.parts[v];
            for &u in graph.neighbors(v as Vid) {
                if (u as usize) < v {
                    continue; // count each undirected edge once
                }
                total += 1;
                let pu = a.parts[u as usize];
                if pu != pv {
                    cut += 1;
                    halo_sets[pv as usize].insert(u);
                    halo_sets[pu as usize].insert(v as Vid);
                }
            }
        }
        let part_sizes = a.part_sizes();
        let mut train_sizes = vec![0usize; k];
        for &t in train {
            train_sizes[a.parts[t as usize] as usize] += 1;
        }
        let imb = |sizes: &[usize]| {
            let mean = sizes.iter().sum::<usize>() as f64 / sizes.len() as f64;
            if mean == 0.0 {
                1.0
            } else {
                *sizes.iter().max().unwrap() as f64 / mean
            }
        };
        PartitionStats {
            k,
            edge_cut_fraction: if total == 0 { 0.0 } else { cut as f64 / total as f64 },
            vertex_imbalance: imb(&part_sizes),
            train_imbalance: imb(&train_sizes),
            halo_counts: halo_sets.iter().map(|s| s.len()).collect(),
            part_sizes,
            train_sizes,
        }
    }

    pub fn render(&self, label: &str) -> String {
        format!(
            "{label}: k={} cut={:.3} v-imb={:.3} t-imb={:.3} halos(mean)={:.0}",
            self.k,
            self.edge_cut_fraction,
            self.vertex_imbalance,
            self.train_imbalance,
            self.halo_counts.iter().sum::<usize>() as f64 / self.k as f64
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_on_trivial_partition() {
        // path graph 0-1-2-3, split 0,1 | 2,3 -> 1 of 3 edges cut
        let g = Csr::from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        let a = Assignment {
            parts: vec![0, 0, 1, 1],
            k: 2,
        };
        let s = PartitionStats::compute(&g, &[0, 2], &a);
        assert!((s.edge_cut_fraction - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.part_sizes, vec![2, 2]);
        assert_eq!(s.train_sizes, vec![1, 1]);
        assert_eq!(s.vertex_imbalance, 1.0);
        // each side sees exactly one halo vertex
        assert_eq!(s.halo_counts, vec![1, 1]);
    }

    #[test]
    fn single_part_has_no_cut() {
        let g = Csr::from_edges(3, &[(0, 1), (1, 2)]);
        let a = Assignment {
            parts: vec![0, 0, 0],
            k: 1,
        };
        let s = PartitionStats::compute(&g, &[], &a);
        assert_eq!(s.edge_cut_fraction, 0.0);
        assert_eq!(s.halo_counts, vec![0]);
    }
}
