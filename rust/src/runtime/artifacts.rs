//! Artifact manifest: the contract between `python/compile/aot.py` (build
//! time) and the Rust coordinator (run time).
//!
//! The manifest records, for every exported program, its HLO file, the
//! ordered input tensor specs and the ordered output tensor specs, plus the
//! model/shape configuration it was built for. The Rust side validates
//! every execution against these specs, so a shape drift between the Python
//! model and the Rust packing code fails loudly instead of corrupting
//! training.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::runtime::tensor::DType;
use crate::util::json::{self, Value};

/// Spec of one tensor in a program signature.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn num_elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_value(v: &Value) -> Result<TensorSpec> {
        let name = v.req_str("name")?.to_string();
        let dtype = DType::parse(v.req_str("dtype")?)?;
        let shape = v
            .req_arr("shape")?
            .iter()
            .map(|d| {
                d.as_usize()
                    .ok_or_else(|| anyhow::anyhow!("bad shape dim in '{name}'"))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec { name, dtype, shape })
    }
}

/// One exported program (e.g. `sage_train_step`).
#[derive(Clone, Debug)]
pub struct ProgramSpec {
    pub name: String,
    /// Path of the HLO text file, relative to the manifest directory.
    pub hlo_file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Free-form metadata (model kind, fusion mode, shape caps...).
    pub meta: BTreeMap<String, Value>,
}

impl ProgramSpec {
    /// Index of a named input.
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|t| t.name == name)
            .ok_or_else(|| anyhow::anyhow!("program '{}' has no input '{name}'", self.name))
    }

    /// Index of a named output.
    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|t| t.name == name)
            .ok_or_else(|| anyhow::anyhow!("program '{}' has no output '{name}'", self.name))
    }

    pub fn meta_usize(&self, key: &str) -> Result<usize> {
        self.meta
            .get(key)
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow::anyhow!("program '{}' missing meta '{key}'", self.name))
    }

    pub fn meta_str(&self, key: &str) -> Option<&str> {
        self.meta.get(key).and_then(|v| v.as_str())
    }
}

/// The whole artifact manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub programs: BTreeMap<String, ProgramSpec>,
    /// Build-time configuration echo (dataset preset, caps, seeds).
    pub build_config: BTreeMap<String, Value>,
}

impl Manifest {
    /// Load `manifest.json` from an artifact directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}; run `make artifacts` first", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Load `manifest.json` if the artifact directory has one, otherwise
    /// fall back to the builtin manifest (identical signatures, native
    /// executor) so a clean checkout trains and benches without the
    /// Python AOT step. The fallback only applies to the default
    /// `artifacts` directory — an explicitly configured path that has no
    /// manifest is a hard error (typos must not silently change which
    /// program specs a run uses).
    pub fn load_or_builtin(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref();
        if dir.join("manifest.json").exists() {
            return Self::load(dir);
        }
        if dir.as_os_str() != "artifacts" {
            bail!(
                "artifact directory '{}' has no manifest.json; run `make artifacts` \
                 or use the default 'artifacts' dir for the builtin native specs",
                dir.display()
            );
        }
        crate::log_info!(
            "no artifact manifest under '{}'; using builtin program specs \
             (native executor — expected for clean checkouts)",
            dir.display()
        );
        Ok(crate::runtime::builtin::builtin_manifest())
    }

    /// Parse manifest JSON (exposed for tests).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let root = json::parse(text).context("manifest.json is not valid JSON")?;
        let version = root.req_usize("version")?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let mut programs = BTreeMap::new();
        for p in root.req_arr("programs")? {
            let name = p.req_str("name")?.to_string();
            let hlo_file = p.req_str("hlo_file")?.to_string();
            let inputs = p
                .req_arr("inputs")?
                .iter()
                .map(TensorSpec::from_value)
                .collect::<Result<Vec<_>>>()
                .with_context(|| format!("inputs of program '{name}'"))?;
            let outputs = p
                .req_arr("outputs")?
                .iter()
                .map(TensorSpec::from_value)
                .collect::<Result<Vec<_>>>()
                .with_context(|| format!("outputs of program '{name}'"))?;
            let meta = p
                .get("meta")
                .and_then(|m| m.as_obj())
                .cloned()
                .unwrap_or_default();
            programs.insert(
                name.clone(),
                ProgramSpec {
                    name,
                    hlo_file,
                    inputs,
                    outputs,
                    meta,
                },
            );
        }
        let build_config = root
            .get("build_config")
            .and_then(|m| m.as_obj())
            .cloned()
            .unwrap_or_default();
        Ok(Manifest {
            dir,
            programs,
            build_config,
        })
    }

    pub fn program(&self, name: &str) -> Result<&ProgramSpec> {
        self.programs.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "artifact manifest has no program '{name}' (available: {:?}); re-run `make artifacts`",
                self.programs.keys().collect::<Vec<_>>()
            )
        })
    }

    pub fn hlo_path(&self, prog: &ProgramSpec) -> PathBuf {
        self.dir.join(&prog.hlo_file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "build_config": {"preset": "mini", "seed": 7},
      "programs": [
        {
          "name": "sage_train_step",
          "hlo_file": "sage_train_step.hlo.txt",
          "inputs": [
            {"name": "feats", "dtype": "f32", "shape": [128, 32]},
            {"name": "esrc0", "dtype": "i32", "shape": [256]}
          ],
          "outputs": [
            {"name": "loss", "dtype": "f32", "shape": []}
          ],
          "meta": {"model": "graphsage", "fused": true, "batch": 16}
        }
      ]
    }"#;

    #[test]
    fn parse_sample_manifest() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        let p = m.program("sage_train_step").unwrap();
        assert_eq!(p.inputs.len(), 2);
        assert_eq!(p.inputs[0].shape, vec![128, 32]);
        assert_eq!(p.inputs[0].dtype, DType::F32);
        assert_eq!(p.inputs[1].dtype, DType::I32);
        assert_eq!(p.outputs[0].shape, Vec::<usize>::new());
        assert_eq!(p.input_index("esrc0").unwrap(), 1);
        assert!(p.input_index("nope").is_err());
        assert_eq!(p.meta_usize("batch").unwrap(), 16);
        assert_eq!(p.meta_str("model"), Some("graphsage"));
        assert_eq!(m.hlo_path(p), PathBuf::from("/tmp/a/sage_train_step.hlo.txt"));
    }

    #[test]
    fn missing_program_is_error() {
        let m = Manifest::parse(SAMPLE, PathBuf::from(".")).unwrap();
        assert!(m.program("gat_train_step").is_err());
    }

    #[test]
    fn bad_version_rejected() {
        let bad = SAMPLE.replace("\"version\": 1", "\"version\": 9");
        assert!(Manifest::parse(&bad, PathBuf::from(".")).is_err());
    }

    #[test]
    fn bad_dtype_rejected() {
        let bad = SAMPLE.replace("\"f32\"", "\"f64\"");
        assert!(Manifest::parse(&bad, PathBuf::from(".")).is_err());
    }
}
