//! bfloat16 storage format: round-to-nearest-even f32 → bf16 packing and
//! exact bf16 → f32 expansion.
//!
//! bf16 is the top half of an IEEE-754 f32 (1 sign, 8 exponent, 7 mantissa
//! bits): expansion is a left shift, packing is a rounded truncation. The
//! paper's LIBXSMM TPP kernels run on bf16 feature blocks with f32
//! accumulation because CPU GNN training is memory-bandwidth-bound — this
//! module is that storage seam. `--dtype bf16` routes *storage* through it
//! (HEC lines, packed minibatch features, AEP push payloads — all halved);
//! weights, gradients, activations and every accumulator stay f32, so
//! losses track the f32 run within the tolerance documented in the README
//! ("Numerics and precision") and asserted by `tests/bf16_equivalence.rs`.
//!
//! Conversion contract (exhaustively tested below):
//! * [`from_f32`] rounds to nearest, ties to even — the hardware
//!   (AVX512-BF16 `VCVTNE2PS2BF16`) behavior, including overflow to
//!   infinity;
//! * NaNs stay NaNs: payload bits that survive truncation are kept, a NaN
//!   whose payload lives only in the low 16 bits is quietened (`0x0040`)
//!   so it cannot collapse to an infinity;
//! * `from_f32(to_f32(b)) == b` for **all** 65536 bf16 bit patterns, so a
//!   store → load → store chain (HEC refresh, push re-forwarding) is
//!   lossless after the first rounding.

/// Expand one bf16 value to f32 (exact: bf16 ⊂ f32).
#[inline(always)]
pub fn to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Pack one f32 to bf16 with round-to-nearest-even.
#[inline(always)]
pub fn from_f32(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        let hi = (bits >> 16) as u16;
        // keep the payload when it survives truncation; otherwise force a
        // quiet bit so the result stays a NaN instead of an infinity
        return if hi & 0x007F != 0 { hi } else { hi | 0x0040 };
    }
    // RNE: add 0x7FFF plus the parity of the bit that will become the LSB;
    // the carry propagates the round-up (max-finite correctly overflows to
    // infinity, matching the hardware converters).
    let round = 0x7FFF + ((bits >> 16) & 1);
    (bits.wrapping_add(round) >> 16) as u16
}

/// Pack a slice (round-to-nearest-even per element).
pub fn pack_slice(src: &[f32]) -> Vec<u16> {
    src.iter().map(|&x| from_f32(x)).collect()
}

/// Pack into a pre-sized destination (`dst.len() == src.len()`).
pub fn pack_into(src: &[f32], dst: &mut [u16]) {
    debug_assert_eq!(src.len(), dst.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = from_f32(s);
    }
}

/// Expand a slice to f32.
pub fn unpack_slice(src: &[u16]) -> Vec<f32> {
    src.iter().map(|&b| to_f32(b)).collect()
}

/// Expand into a pre-sized destination (`dst.len() == src.len()`).
pub fn unpack_into(src: &[u16], dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = to_f32(s);
    }
}

/// Pack an f32 row directly as little-endian bf16 bytes
/// (`dst.len() == 2 * src.len()`) — the packer's feature-fill path writes
/// straight into tensor storage without an intermediate row buffer.
pub fn pack_row_bytes(src: &[f32], dst: &mut [u8]) {
    debug_assert_eq!(dst.len(), src.len() * 2);
    for (d, &s) in dst.chunks_exact_mut(2).zip(src) {
        d.copy_from_slice(&from_f32(s).to_le_bytes());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// `pack(unpack(x)) == x` for every one of the 65536 bf16 bit
    /// patterns — zeros, subnormals, normals, infinities and every NaN
    /// payload round-trip losslessly.
    #[test]
    fn all_65536_bit_patterns_roundtrip() {
        for b in 0..=u16::MAX {
            let back = from_f32(to_f32(b));
            assert_eq!(back, b, "pattern {b:#06x} -> {back:#06x}");
        }
    }

    #[test]
    fn rounds_to_nearest_even_on_ties() {
        // 0x3F80_8000 is exactly halfway between bf16 0x3F80 and 0x3F81:
        // ties go to the even LSB (0x3F80).
        assert_eq!(from_f32(f32::from_bits(0x3F80_8000)), 0x3F80);
        // halfway above an odd LSB rounds *up* to the even one
        assert_eq!(from_f32(f32::from_bits(0x3F81_8000)), 0x3F82);
        // one ULP above the tie always rounds up
        assert_eq!(from_f32(f32::from_bits(0x3F80_8001)), 0x3F81);
        // one ULP below the tie always rounds down
        assert_eq!(from_f32(f32::from_bits(0x3F81_7FFF)), 0x3F81);
        // same for negative values (sign does not affect the mantissa path)
        assert_eq!(from_f32(f32::from_bits(0xBF80_8000)), 0xBF80);
        assert_eq!(from_f32(f32::from_bits(0xBF81_8000)), 0xBF82);
    }

    #[test]
    fn nan_inf_zero_and_subnormal_edges() {
        // NaNs stay NaNs
        assert!(to_f32(from_f32(f32::NAN)).is_nan());
        // a NaN whose payload is only in the low 16 bits must not become inf
        let skinny_nan = f32::from_bits(0x7F80_0001);
        assert!(skinny_nan.is_nan());
        assert!(to_f32(from_f32(skinny_nan)).is_nan());
        let neg_skinny = f32::from_bits(0xFF80_0001);
        assert!(to_f32(from_f32(neg_skinny)).is_nan());
        // infinities pass through exactly
        assert_eq!(from_f32(f32::INFINITY), 0x7F80);
        assert_eq!(from_f32(f32::NEG_INFINITY), 0xFF80);
        assert_eq!(to_f32(0x7F80), f32::INFINITY);
        // signed zeros keep their sign
        assert_eq!(from_f32(0.0), 0x0000);
        assert_eq!(from_f32(-0.0), 0x8000);
        // overflow rounds to infinity (hardware RNE behavior)
        assert_eq!(from_f32(f32::MAX), 0x7F80);
        assert_eq!(from_f32(f32::MIN), 0xFF80);
        // an f32 subnormal whose high bits survive is kept as a bf16
        // subnormal; one entirely below bf16 resolution rounds to zero
        assert_eq!(from_f32(f32::from_bits(0x0040_0000)), 0x0040);
        assert_eq!(to_f32(0x0040).to_bits(), 0x0040_0000);
        assert_eq!(from_f32(f32::from_bits(0x0000_0001)), 0x0000);
    }

    #[test]
    fn relative_error_bounded_by_one_part_in_256() {
        // 7 mantissa bits => worst-case relative rounding error 2^-8
        let mut rng = crate::util::rng::Pcg64::seeded(11);
        for _ in 0..10_000 {
            let x = (rng.gen_f32() - 0.5) * 2e4;
            let y = to_f32(from_f32(x));
            assert!(
                (x - y).abs() <= x.abs() / 256.0 + f32::MIN_POSITIVE,
                "{x} -> {y}"
            );
        }
    }

    #[test]
    fn slice_helpers_agree_with_scalar() {
        let xs: Vec<f32> = vec![1.0, -2.5, 3.14159, 0.0, -0.0, 1e-20, 7e8];
        let packed = pack_slice(&xs);
        assert_eq!(packed, xs.iter().map(|&x| from_f32(x)).collect::<Vec<_>>());
        let mut packed2 = vec![0u16; xs.len()];
        pack_into(&xs, &mut packed2);
        assert_eq!(packed, packed2);
        let back = unpack_slice(&packed);
        let mut back2 = vec![0f32; xs.len()];
        unpack_into(&packed, &mut back2);
        assert_eq!(back, back2);
        // byte form matches the u16 little-endian encoding
        let mut bytes = vec![0u8; xs.len() * 2];
        pack_row_bytes(&xs, &mut bytes);
        for (i, b) in packed.iter().enumerate() {
            assert_eq!(&bytes[i * 2..i * 2 + 2], &b.to_le_bytes());
        }
    }
}
