//! Builtin program manifest for the native executor.
//!
//! `python/compile/aot.py` is the preferred source of program signatures
//! (`make artifacts` → `artifacts/manifest.json`). When no artifact
//! directory exists — the common case in the offline environment — this
//! module constructs the same manifest in Rust: identical program names,
//! input/output orders, shape caps (the `shapes.py` formula, ROW_ALIGN 64)
//! and metadata, so the packer/driver code paths are byte-compatible with
//! artifact-built runs. The Rust mirror is validated against the Python
//! ground-truth values in the unit tests below.

use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::runtime::artifacts::{Manifest, ProgramSpec, TensorSpec};
use crate::runtime::tensor::DType;
use crate::util::json::{self, Value};

const ROW_ALIGN: usize = 64;

fn round_up(x: usize) -> usize {
    x.div_ceil(ROW_ALIGN) * ROW_ALIGN
}

/// One (dataset, model-family) shape configuration — shapes.py::ModelShapes.
struct Shapes {
    preset: &'static str,
    batch: usize,
    fanouts: &'static [usize],
    feat_dim: usize,
    hidden: usize,
    num_classes: usize,
    num_heads: usize,
    dropout: f64,
    cap_factor: f64,
}

const PRESETS: &[Shapes] = &[
    Shapes {
        preset: "tiny",
        batch: 32,
        fanouts: &[4, 6, 8],
        feat_dim: 32,
        hidden: 64,
        num_classes: 8,
        num_heads: 4,
        dropout: 0.2,
        cap_factor: 0.7,
    },
    Shapes {
        preset: "products-mini",
        batch: 64,
        fanouts: &[4, 8, 12],
        feat_dim: 100,
        hidden: 64,
        num_classes: 47,
        num_heads: 4,
        dropout: 0.2,
        cap_factor: 0.5,
    },
    Shapes {
        preset: "papers100m-mini",
        batch: 64,
        fanouts: &[4, 8, 12],
        feat_dim: 128,
        hidden: 64,
        num_classes: 172,
        num_heads: 4,
        dropout: 0.2,
        cap_factor: 0.5,
    },
];

impl Shapes {
    fn n_layers(&self) -> usize {
        self.fanouts.len()
    }

    /// [NS_0, ..., NS_L], seeds innermost — shapes.py::node_caps.
    fn node_caps(&self) -> Vec<usize> {
        let mut caps = vec![self.batch];
        for &fo in self.fanouts.iter().rev() {
            let worst = caps[0] * (1 + fo);
            let provisioned =
                (caps[0] + ROW_ALIGN).max((worst as f64 * self.cap_factor).ceil() as usize);
            caps.insert(0, round_up(provisioned));
        }
        caps
    }

    fn edge_caps(&self, self_loops: bool) -> Vec<usize> {
        let caps = self.node_caps();
        self.fanouts
            .iter()
            .enumerate()
            .map(|(l, &fo)| caps[l + 1] * fo + if self_loops { caps[l + 1] } else { 0 })
            .collect()
    }
}

fn f32_spec(name: &str, shape: Vec<usize>) -> TensorSpec {
    TensorSpec {
        name: name.to_string(),
        dtype: DType::F32,
        shape,
    }
}

fn i32_spec(name: &str, shape: Vec<usize>) -> TensorSpec {
    TensorSpec {
        name: name.to_string(),
        dtype: DType::I32,
        shape,
    }
}

/// Ordered (wn{l}, ws{l}, b{l}) parameter specs — model.py::sage_param_specs.
fn sage_param_specs(s: &Shapes) -> Vec<TensorSpec> {
    let mut specs = Vec::new();
    let mut d_in = s.feat_dim;
    for l in 0..s.n_layers() {
        let d_out = if l == s.n_layers() - 1 {
            s.num_classes
        } else {
            s.hidden
        };
        specs.push(f32_spec(&format!("wn{l}"), vec![d_in, d_out]));
        specs.push(f32_spec(&format!("ws{l}"), vec![d_in, d_out]));
        specs.push(f32_spec(&format!("b{l}"), vec![d_out]));
        d_in = d_out;
    }
    specs
}

/// Ordered (w{l}, b{l}, au{l}, av{l}) specs — model.py::gat_param_specs.
fn gat_param_specs(s: &Shapes) -> Vec<TensorSpec> {
    let heads = s.num_heads;
    let mut specs = Vec::new();
    let mut d_in = s.feat_dim;
    for l in 0..s.n_layers() {
        let last = l == s.n_layers() - 1;
        let dh = if last {
            s.num_classes
        } else {
            s.hidden / heads
        };
        specs.push(f32_spec(&format!("w{l}"), vec![d_in, heads * dh]));
        specs.push(f32_spec(&format!("b{l}"), vec![heads * dh]));
        specs.push(f32_spec(&format!("au{l}"), vec![heads, dh]));
        specs.push(f32_spec(&format!("av{l}"), vec![heads, dh]));
        if !last {
            d_in = heads * dh;
        }
    }
    specs
}

/// Ordered minibatch input specs — model.py::batch_specs.
fn batch_specs(s: &Shapes, self_loops: bool) -> Vec<TensorSpec> {
    let caps = s.node_caps();
    let ecaps = s.edge_caps(self_loops);
    let mut specs = vec![f32_spec("feats", vec![caps[0], s.feat_dim])];
    for l in 0..s.n_layers() {
        specs.push(i32_spec(&format!("esrc{l}"), vec![ecaps[l]]));
        specs.push(i32_spec(&format!("edst{l}"), vec![ecaps[l]]));
        specs.push(f32_spec(&format!("ew{l}"), vec![ecaps[l]]));
    }
    for l in 1..s.n_layers() {
        specs.push(i32_spec(&format!("hec_idx{l}"), vec![caps[l]]));
        specs.push(f32_spec(&format!("hec_val{l}"), vec![caps[l], s.hidden]));
    }
    specs.push(i32_spec("labels", vec![s.batch]));
    specs.push(f32_spec("lmask", vec![s.batch]));
    specs.push(i32_spec("seed", vec![]));
    specs
}

fn model_meta(s: &Shapes, model: &str, kind: &str) -> BTreeMap<String, Value> {
    let self_loops = model == "gat";
    let n_params = if model == "sage" {
        3 * s.n_layers()
    } else {
        4 * s.n_layers()
    };
    let mut meta = BTreeMap::new();
    meta.insert("model".into(), json::s(model));
    meta.insert("kind".into(), json::s(kind));
    meta.insert("preset".into(), json::s(s.preset));
    meta.insert("batch".into(), json::num(s.batch as f64));
    meta.insert(
        "fanouts".into(),
        json::arr(s.fanouts.iter().map(|&f| json::num(f as f64)).collect()),
    );
    meta.insert("hidden".into(), json::num(s.hidden as f64));
    meta.insert("num_heads".into(), json::num(s.num_heads as f64));
    meta.insert("num_classes".into(), json::num(s.num_classes as f64));
    meta.insert("feat_dim".into(), json::num(s.feat_dim as f64));
    meta.insert("dropout".into(), json::num(s.dropout));
    meta.insert(
        "node_caps".into(),
        json::arr(s.node_caps().iter().map(|&c| json::num(c as f64)).collect()),
    );
    meta.insert("self_loops".into(), Value::Bool(self_loops));
    meta.insert("n_params".into(), json::num(n_params as f64));
    meta
}

fn model_programs(s: &Shapes) -> Vec<ProgramSpec> {
    let mut programs = Vec::new();
    let caps = s.node_caps();
    for model in ["sage", "gat"] {
        let pspecs = if model == "sage" {
            sage_param_specs(s)
        } else {
            gat_param_specs(s)
        };
        let bspecs = batch_specs(s, model == "gat");
        let mut inputs = pspecs.clone();
        inputs.extend(bspecs);
        // "serve" is the inference read path: the fwd signature plus the
        // final-layer logits surfaced as an explicit output (the score
        // vector returned to serving clients), no dropout, no grads.
        for kind in ["train", "fwd", "serve"] {
            let mut outputs = vec![f32_spec("loss", vec![]), f32_spec("correct", vec![])];
            for l in 1..s.n_layers() {
                outputs.push(f32_spec(&format!("h{l}"), vec![caps[l], s.hidden]));
            }
            if kind == "train" {
                for p in &pspecs {
                    outputs.push(f32_spec(&format!("grad_{}", p.name), p.shape.clone()));
                }
            }
            if kind == "serve" {
                outputs.push(f32_spec("logits", vec![s.batch, s.num_classes]));
            }
            let name = format!("{model}_{kind}_{}", s.preset);
            programs.push(ProgramSpec {
                name: name.clone(),
                hlo_file: format!("{name}.hlo.txt"),
                inputs: inputs.clone(),
                outputs,
                meta: model_meta(s, model, kind),
            });
        }
    }
    programs
}

/// Fig. 2 UPDATE micro programs at the given preset's dims.
fn update_micro_programs(s: &Shapes) -> Vec<ProgramSpec> {
    let n = s.node_caps()[0];
    let (f, h) = (s.feat_dim, s.hidden);
    let meta = |kind: &str| {
        let mut m = BTreeMap::new();
        m.insert("preset".into(), json::s(s.preset));
        m.insert("kind".into(), json::s(kind));
        m.insert("rows".into(), json::num(n as f64));
        m.insert("d_in".into(), json::num(f as f64));
        m.insert("d_out".into(), json::num(h as f64));
        m
    };
    let full_inputs = vec![
        f32_spec("xn", vec![n, f]),
        f32_spec("xs", vec![n, f]),
        f32_spec("wn", vec![f, h]),
        f32_spec("ws", vec![f, h]),
        f32_spec("b", vec![h]),
        f32_spec("mask", vec![n, h]),
    ];
    let prog = |name: String, inputs: Vec<TensorSpec>, out: &str, kind: &str| ProgramSpec {
        hlo_file: format!("{name}.hlo.txt"),
        inputs,
        outputs: vec![f32_spec(out, vec![n, h])],
        meta: meta(kind),
        name,
    };
    vec![
        prog(
            format!("update_fused_{}", s.preset),
            full_inputs.clone(),
            "y",
            "fused",
        ),
        prog(
            format!("update_unfused_full_{}", s.preset),
            full_inputs,
            "y",
            "unfused_full",
        ),
        prog(
            format!("update_mm_{}", s.preset),
            vec![f32_spec("xn", vec![n, f]), f32_spec("wn", vec![f, h])],
            "y",
            "op_mm",
        ),
        prog(
            format!("update_add_bias_{}", s.preset),
            vec![
                f32_spec("y", vec![n, h]),
                f32_spec("y2", vec![n, h]),
                f32_spec("b", vec![h]),
            ],
            "out",
            "op_add_bias",
        ),
        prog(
            format!("update_relu_{}", s.preset),
            vec![f32_spec("y", vec![n, h])],
            "out",
            "op_relu",
        ),
        prog(
            format!("update_dropout_{}", s.preset),
            vec![f32_spec("y", vec![n, h]), f32_spec("mask", vec![n, h])],
            "out",
            "op_dropout",
        ),
    ]
}

/// The full builtin manifest: every preset's model programs plus the
/// products-mini UPDATE micro programs (mirroring `aot.py --presets ...`).
pub fn builtin_manifest() -> Manifest {
    let mut programs = BTreeMap::new();
    for s in PRESETS {
        for p in model_programs(s) {
            programs.insert(p.name.clone(), p);
        }
        if s.preset == "products-mini" {
            for p in update_micro_programs(s) {
                programs.insert(p.name.clone(), p);
            }
        }
    }
    let mut build_config = BTreeMap::new();
    build_config.insert("builtin".into(), Value::Bool(true));
    Manifest {
        dir: PathBuf::from("builtin"),
        programs,
        build_config,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn preset(name: &str) -> &'static Shapes {
        PRESETS.iter().find(|s| s.preset == name).unwrap()
    }

    #[test]
    fn caps_match_python_ground_truth() {
        // Values computed by python/compile/shapes.py (the source of truth
        // when artifacts are built); the Rust mirror must agree exactly.
        assert_eq!(preset("tiny").node_caps(), vec![4480, 1280, 256, 32]);
        assert_eq!(preset("tiny").edge_caps(false), vec![5120, 1536, 256]);
        assert_eq!(preset("tiny").edge_caps(true), vec![6400, 1792, 288]);
        assert_eq!(
            preset("products-mini").node_caps(),
            vec![5120, 2048, 448, 64]
        );
        assert_eq!(
            preset("products-mini").edge_caps(false),
            vec![8192, 3584, 768]
        );
        assert_eq!(
            preset("papers100m-mini").node_caps(),
            vec![5120, 2048, 448, 64]
        );
        assert_eq!(
            preset("papers100m-mini").edge_caps(true),
            vec![10240, 4032, 832]
        );
    }

    #[test]
    fn manifest_contains_expected_programs() {
        let m = builtin_manifest();
        for preset in ["tiny", "products-mini", "papers100m-mini"] {
            for model in ["sage", "gat"] {
                for kind in ["train", "fwd"] {
                    assert!(
                        m.programs.contains_key(&format!("{model}_{kind}_{preset}")),
                        "{model}_{kind}_{preset} missing"
                    );
                }
            }
        }
        assert!(m.programs.contains_key("update_fused_products-mini"));
        assert!(m.programs.contains_key("update_dropout_products-mini"));
    }

    #[test]
    fn sage_train_signature_is_consistent() {
        let m = builtin_manifest();
        let p = m.program("sage_train_tiny").unwrap();
        let n_params = p.meta_usize("n_params").unwrap();
        assert_eq!(n_params, 9);
        assert_eq!(p.inputs[0].name, "wn0");
        assert_eq!(p.inputs[0].shape, vec![32, 64]);
        assert_eq!(p.inputs[8].name, "b2");
        assert_eq!(p.inputs[8].shape, vec![8]);
        assert_eq!(p.inputs[n_params].name, "feats");
        assert_eq!(p.inputs[n_params].shape, vec![4480, 32]);
        // 9 params + feats + 3 layers * (esrc, edst, ew) + 2 * (idx, val)
        // + labels + lmask + seed
        assert_eq!(p.inputs.len(), 9 + 1 + 9 + 4 + 3);
        assert_eq!(p.input_index("esrc0").unwrap(), 10);
        assert_eq!(p.input_index("hec_idx1").unwrap(), 19);
        // outputs: loss, correct, h1, h2, 9 grads
        assert_eq!(p.outputs.len(), 2 + 2 + 9);
        assert_eq!(p.outputs[2].name, "h1");
        assert_eq!(p.outputs[2].shape, vec![1280, 64]);
        assert_eq!(p.outputs[4].name, "grad_wn0");
        // fwd variant drops the grads
        let f = m.program("sage_fwd_tiny").unwrap();
        assert_eq!(f.outputs.len(), 4);
        assert_eq!(f.inputs.len(), p.inputs.len());
    }

    #[test]
    fn gat_signature_has_heads_and_self_loop_edges() {
        let m = builtin_manifest();
        let p = m.program("gat_train_tiny").unwrap();
        assert_eq!(p.meta_usize("n_params").unwrap(), 12);
        assert_eq!(p.inputs[0].name, "w0");
        assert_eq!(p.inputs[0].shape, vec![32, 64]); // 4 heads x dh 16
        assert_eq!(p.inputs[2].shape, vec![4, 16]); // au0
        // last layer: dh = num_classes
        assert_eq!(p.inputs[8].name, "w2");
        assert_eq!(p.inputs[8].shape, vec![64, 32]); // 4 heads x 8 classes
        let esrc0 = &p.inputs[p.input_index("esrc0").unwrap()];
        assert_eq!(esrc0.shape, vec![6400]); // self-loop edge caps
    }
}
