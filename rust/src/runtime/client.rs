//! PJRT CPU client wrapper: compile HLO-text artifacts once, execute many
//! times with shape-checked host tensors.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::artifacts::{Manifest, ProgramSpec};
use crate::runtime::tensor::HostTensor;

/// A compiled program plus its signature.
pub struct Executable {
    pub spec: ProgramSpec,
    exe: xla::PjRtLoadedExecutable,
    /// Cumulative execution statistics (for the perf report).
    pub calls: std::cell::Cell<u64>,
    pub exec_secs: std::cell::Cell<f64>,
}

impl Executable {
    /// Execute with the given inputs (order must match `spec.inputs`).
    /// Validates dtypes/shapes, unpacks the result tuple and validates the
    /// outputs against `spec.outputs`.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "program '{}': expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        for (t, s) in inputs.iter().zip(&self.spec.inputs) {
            if t.dtype != s.dtype || t.shape != s.shape {
                bail!(
                    "program '{}': input '{}' expects {:?}{:?}, got {:?}{:?}",
                    self.spec.name,
                    s.name,
                    s.dtype,
                    s.shape,
                    t.dtype,
                    t.shape
                );
            }
        }
        let lits = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()?;
        let t0 = std::time::Instant::now();
        let out = self
            .exe
            .execute::<xla::Literal>(&lits)
            .with_context(|| format!("executing '{}'", self.spec.name))?;
        let tuple = out[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        self.calls.set(self.calls.get() + 1);
        self.exec_secs
            .set(self.exec_secs.get() + t0.elapsed().as_secs_f64());
        let parts = tuple.to_tuple().context("decomposing result tuple")?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "program '{}': manifest declares {} outputs, executable returned {}",
                self.spec.name,
                self.spec.outputs.len(),
                parts.len()
            );
        }
        let mut tensors = Vec::with_capacity(parts.len());
        for (lit, s) in parts.iter().zip(&self.spec.outputs) {
            let t = HostTensor::from_literal(lit)
                .with_context(|| format!("output '{}' of '{}'", s.name, self.spec.name))?;
            if t.dtype != s.dtype || t.shape != s.shape {
                bail!(
                    "program '{}': output '{}' expects {:?}{:?}, got {:?}{:?}",
                    self.spec.name,
                    s.name,
                    s.dtype,
                    s.shape,
                    t.dtype,
                    t.shape
                );
            }
            tensors.push(t);
        }
        Ok(tensors)
    }

    /// Mean execution wall time per call so far.
    pub fn mean_exec_secs(&self) -> f64 {
        let c = self.calls.get();
        if c == 0 {
            0.0
        } else {
            self.exec_secs.get() / c as f64
        }
    }
}

/// The per-process PJRT runtime: one CPU client + compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    programs: HashMap<String, Executable>,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            programs: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one program from the manifest and cache it under its name.
    pub fn load_program(&mut self, manifest: &Manifest, name: &str) -> Result<()> {
        if self.programs.contains_key(name) {
            return Ok(());
        }
        let spec = manifest.program(name)?.clone();
        let path = manifest.hlo_path(&spec);
        let exe = self.compile_hlo_file(&path)?;
        self.programs.insert(
            name.to_string(),
            Executable {
                spec,
                exe,
                calls: std::cell::Cell::new(0),
                exec_secs: std::cell::Cell::new(0.0),
            },
        );
        Ok(())
    }

    /// Compile an HLO text file into an executable (no manifest checking).
    pub fn compile_hlo_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))
    }

    pub fn program(&self, name: &str) -> Result<&Executable> {
        self.programs
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("program '{name}' not loaded"))
    }

    pub fn loaded_programs(&self) -> Vec<&str> {
        self.programs.keys().map(|s| s.as_str()).collect()
    }
}
