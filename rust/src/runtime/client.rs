//! Program runtime: resolve manifest specs to executables once, execute
//! many times with shape-checked host tensors.
//!
//! Programs execute through the in-tree native CPU backend
//! ([`crate::runtime::native`]), which implements the same math the AOT
//! HLO artifacts encode. A PJRT/XLA backend (compiling the artifact HLO
//! text) existed before the dependency was cut for offline builds and is a
//! ROADMAP open item to reintroduce behind a feature gate — the
//! [`Runtime`]/[`Executable`] API is the seam it plugs back into.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::runtime::artifacts::Manifest;
use crate::runtime::artifacts::ProgramSpec;
use crate::runtime::native::NativeProgram;
use crate::runtime::tensor::{DType, HostTensor};

/// A resolved program plus its signature.
pub struct Executable {
    pub spec: ProgramSpec,
    native: NativeProgram,
    /// Cumulative execution statistics (for the perf report).
    pub calls: std::cell::Cell<u64>,
    pub exec_secs: std::cell::Cell<f64>,
}

impl Executable {
    /// Execute with the given inputs (order must match `spec.inputs`).
    /// Validates input dtypes/shapes, runs the native program, and
    /// validates the outputs against `spec.outputs`.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "program '{}': expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        for (t, s) in inputs.iter().zip(&self.spec.inputs) {
            // bf16 is a storage format of f32 (the --dtype bf16 packing
            // path): the native executor up-converts it per block, so a
            // Bf16 tensor satisfies an F32 input slot. Outputs are always
            // produced — and checked — in the exact manifest dtype.
            let dtype_ok =
                t.dtype == s.dtype || (t.dtype == DType::Bf16 && s.dtype == DType::F32);
            if !dtype_ok || t.shape != s.shape {
                bail!(
                    "program '{}': input '{}' expects {:?}{:?}, got {:?}{:?}",
                    self.spec.name,
                    s.name,
                    s.dtype,
                    s.shape,
                    t.dtype,
                    t.shape
                );
            }
        }
        let t0 = std::time::Instant::now();
        let outputs = self.native.execute(&self.spec, inputs)?;
        self.calls.set(self.calls.get() + 1);
        self.exec_secs
            .set(self.exec_secs.get() + t0.elapsed().as_secs_f64());
        if outputs.len() != self.spec.outputs.len() {
            bail!(
                "program '{}': manifest declares {} outputs, executor returned {}",
                self.spec.name,
                self.spec.outputs.len(),
                outputs.len()
            );
        }
        for (t, s) in outputs.iter().zip(&self.spec.outputs) {
            if t.dtype != s.dtype || t.shape != s.shape {
                bail!(
                    "program '{}': output '{}' expects {:?}{:?}, got {:?}{:?}",
                    self.spec.name,
                    s.name,
                    s.dtype,
                    s.shape,
                    t.dtype,
                    t.shape
                );
            }
        }
        Ok(outputs)
    }

    /// Mean execution wall time per call so far.
    pub fn mean_exec_secs(&self) -> f64 {
        let c = self.calls.get();
        if c == 0 {
            0.0
        } else {
            self.exec_secs.get() / c as f64
        }
    }
}

/// The per-process runtime: resolved executables keyed by program name.
pub struct Runtime {
    programs: HashMap<String, Executable>,
}

impl Runtime {
    /// Create a CPU runtime.
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime {
            programs: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        "native-cpu".to_string()
    }

    /// Resolve one program from the manifest and cache it under its name.
    pub fn load_program(&mut self, manifest: &Manifest, name: &str) -> Result<()> {
        if self.programs.contains_key(name) {
            return Ok(());
        }
        let spec = manifest.program(name)?.clone();
        let native = NativeProgram::from_spec(&spec)?;
        self.programs.insert(
            name.to_string(),
            Executable {
                spec,
                native,
                calls: std::cell::Cell::new(0),
                exec_secs: std::cell::Cell::new(0.0),
            },
        );
        Ok(())
    }

    pub fn program(&self, name: &str) -> Result<&Executable> {
        self.programs
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("program '{name}' not loaded"))
    }

    pub fn loaded_programs(&self) -> Vec<&str> {
        self.programs.keys().map(|s| s.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::builtin::builtin_manifest;
    use crate::runtime::tensor::DType;
    use crate::util::rng::Pcg64;

    fn rand_inputs(spec: &ProgramSpec, rng: &mut Pcg64) -> Vec<HostTensor> {
        spec.inputs
            .iter()
            .map(|s| {
                let n = s.num_elements();
                match s.dtype {
                    DType::F32 => HostTensor::f32(
                        s.shape.clone(),
                        &(0..n).map(|_| rng.gen_f32() - 0.5).collect::<Vec<_>>(),
                    ),
                    DType::Bf16 => HostTensor::bf16_from_f32(
                        s.shape.clone(),
                        &(0..n).map(|_| rng.gen_f32() - 0.5).collect::<Vec<_>>(),
                    ),
                    DType::I32 => HostTensor::i32(s.shape.clone(), &vec![0i32; n]),
                    DType::U32 => HostTensor::u32(s.shape.clone(), &vec![0u32; n]),
                }
            })
            .collect()
    }

    #[test]
    fn load_and_run_update_programs() {
        let manifest = builtin_manifest();
        let mut rt = Runtime::cpu().unwrap();
        let mut rng = Pcg64::seeded(1);
        for name in [
            "update_fused_products-mini",
            "update_unfused_full_products-mini",
            "update_mm_products-mini",
            "update_relu_products-mini",
        ] {
            rt.load_program(&manifest, name).unwrap();
            let exe = rt.program(name).unwrap();
            let inputs = rand_inputs(&exe.spec, &mut rng);
            let out = exe.run(&inputs).unwrap();
            assert_eq!(out.len(), exe.spec.outputs.len());
            assert_eq!(out[0].shape, exe.spec.outputs[0].shape);
        }
    }

    #[test]
    fn fused_and_unfused_update_agree() {
        let manifest = builtin_manifest();
        let mut rt = Runtime::cpu().unwrap();
        rt.load_program(&manifest, "update_fused_products-mini").unwrap();
        rt.load_program(&manifest, "update_unfused_full_products-mini").unwrap();
        let mut rng = Pcg64::seeded(2);
        let fused = rt.program("update_fused_products-mini").unwrap();
        let inputs = rand_inputs(&fused.spec, &mut rng);
        let a = fused.run(&inputs).unwrap()[0].to_f32().unwrap();
        let b = rt
            .program("update_unfused_full_products-mini")
            .unwrap()
            .run(&inputs)
            .unwrap()[0]
            .to_f32()
            .unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn input_shape_mismatch_rejected() {
        let manifest = builtin_manifest();
        let mut rt = Runtime::cpu().unwrap();
        rt.load_program(&manifest, "update_relu_products-mini").unwrap();
        let exe = rt.program("update_relu_products-mini").unwrap();
        let bad = vec![HostTensor::zeros(DType::F32, vec![2, 2])];
        assert!(exe.run(&bad).is_err());
    }

    /// The --dtype bf16 seam at the executor boundary: a bf16 feature
    /// block satisfies the f32 `feats` slot and the resulting loss tracks
    /// the f32 run closely (storage rounding only; all math stays f32).
    #[test]
    fn bf16_feats_satisfy_f32_slot_and_track_loss() {
        let manifest = builtin_manifest();
        let mut rt = Runtime::cpu().unwrap();
        rt.load_program(&manifest, "sage_train_tiny").unwrap();
        let exe = rt.program("sage_train_tiny").unwrap();
        let mut rng = Pcg64::seeded(7);
        let mut inputs = rand_inputs(&exe.spec, &mut rng);
        // all seeds labeled, so the loss denominator is well-conditioned
        let li = exe.spec.input_index("lmask").unwrap();
        let ln = exe.spec.inputs[li].num_elements();
        inputs[li] = HostTensor::f32(exe.spec.inputs[li].shape.clone(), &vec![1.0; ln]);
        let loss_f32 = exe.run(&inputs).unwrap()[0].scalar_f32().unwrap();

        let fi = exe.spec.input_index("feats").unwrap();
        let fv = inputs[fi].to_f32().unwrap();
        inputs[fi] = HostTensor::bf16_from_f32(exe.spec.inputs[fi].shape.clone(), &fv);
        let loss_bf16 = exe.run(&inputs).unwrap()[0].scalar_f32().unwrap();
        assert!(loss_f32.is_finite() && loss_bf16.is_finite());
        assert!(
            (loss_f32 - loss_bf16).abs() <= 0.05 * loss_f32.abs().max(1.0),
            "f32 {loss_f32} vs bf16 {loss_bf16}"
        );
        // bf16 is never accepted where the spec wants an integer tensor
        let si = exe.spec.input_index("labels").unwrap();
        let mut bad = rand_inputs(&exe.spec, &mut rng);
        bad[si] = HostTensor::zeros(DType::Bf16, exe.spec.inputs[si].shape.clone());
        assert!(exe.run(&bad).is_err());
    }

    /// GAT programs load and execute natively against the builtin
    /// manifest signatures (train emits grads, fwd does not), closing the
    /// former "not implemented" gap. Random inputs leave every edge
    /// masked-or-degenerate, which the edge-softmax must survive with a
    /// finite loss.
    #[test]
    fn gat_programs_load_and_run() {
        let manifest = builtin_manifest();
        let mut rt = Runtime::cpu().unwrap();
        let mut rng = Pcg64::seeded(13);
        for name in ["gat_train_tiny", "gat_fwd_tiny"] {
            rt.load_program(&manifest, name).unwrap();
            let exe = rt.program(name).unwrap();
            let mut inputs = rand_inputs(&exe.spec, &mut rng);
            let li = exe.spec.input_index("lmask").unwrap();
            let ln = exe.spec.inputs[li].num_elements();
            inputs[li] = HostTensor::f32(exe.spec.inputs[li].shape.clone(), &vec![1.0; ln]);
            let out = exe.run(&inputs).unwrap();
            assert_eq!(out.len(), exe.spec.outputs.len());
            let loss = out[0].scalar_f32().unwrap();
            assert!(loss.is_finite(), "{name} loss {loss}");
        }
        // train declares the 4-per-layer grads after loss/correct/embeds
        let train = rt.program("gat_train_tiny").unwrap();
        let fwd = rt.program("gat_fwd_tiny").unwrap();
        assert_eq!(
            train.spec.outputs.len(),
            fwd.spec.outputs.len() + train.spec.meta_usize("n_params").unwrap()
        );
    }
}
