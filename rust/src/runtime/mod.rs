//! PJRT runtime: loads AOT-compiled HLO artifacts and executes them on the
//! request path without any Python involvement.
//!
//! `make artifacts` runs `python/compile/aot.py` once, producing
//! `artifacts/manifest.json` plus one `<name>.hlo.txt` per program variant.
//! At startup the coordinator loads the manifest ([`artifacts::Manifest`]),
//! compiles the programs it needs through the PJRT CPU client
//! ([`client::Runtime`]) and keeps the executables for the lifetime of the
//! run. HLO *text* is the interchange format (not serialized protos): jax
//! >= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects,
//! while the text parser reassigns ids cleanly.

pub mod artifacts;
pub mod client;
pub mod tensor;

pub use artifacts::{Manifest, ProgramSpec, TensorSpec};
pub use client::{Executable, Runtime};
pub use tensor::{DType, HostTensor};
