//! Program runtime: manifest-described fixed-shape programs executed on
//! the training path without any Python involvement.
//!
//! `make artifacts` runs `python/compile/aot.py` once, producing
//! `artifacts/manifest.json` plus one `<name>.hlo.txt` per program variant;
//! when no artifact directory exists, [`builtin`] reconstructs the same
//! manifest in Rust. At startup the coordinator loads the manifest
//! ([`artifacts::Manifest`]) and resolves the programs it needs through
//! [`client::Runtime`], which executes them on the in-tree [`native`] CPU
//! backend (same math as the lowered HLO; a feature-gated PJRT/XLA backend
//! compiling the HLO text is a ROADMAP open item — the offline toolchain
//! cannot link xla_extension).
//!
//! Feature/embedding blocks may be stored as [`bf16`] (`--dtype bf16`):
//! the executor up-converts bf16 inputs per block and accumulates in f32
//! (see the [`native`] row-block kernels), so program signatures stay
//! f32 and outputs are always f32.

pub mod artifacts;
pub mod bf16;
pub mod builtin;
pub mod client;
pub mod native;
pub mod tensor;

pub use artifacts::{Manifest, ProgramSpec, TensorSpec};
pub use client::{Executable, Runtime};
pub use tensor::{DType, HostTensor};
