//! Native CPU executor for the AOT program signatures.
//!
//! The offline environment cannot link the PJRT/XLA runtime, so programs
//! described by the manifest execute through this hand-written Rust
//! implementation of the same math as `python/compile/model.py` +
//! `kernels/fused_update.py`: GraphSAGE forward/backward over padded
//! message-flow blocks (mean aggregation, fused UPDATE, historical-
//! embedding overwrite with gradient blocking, masked softmax
//! cross-entropy) and the Fig. 2 UPDATE micro programs. Matmuls run as
//! thread-parallel row blocks (`util::parallel`); every reduction has a
//! fixed order, so results are bit-identical for any worker count.
//!
//! Dropout derives its mask from the program's `seed` input through
//! [`Pcg64`] (JAX's threefry stream is not reproduced — the native backend
//! is self-consistent, which is what the determinism tests assert).
//!
//! GAT needs the edge-softmax backward and is not implemented natively yet
//! (ROADMAP open item); loading a GAT program reports that clearly.

use anyhow::{bail, Result};

use crate::runtime::artifacts::ProgramSpec;
use crate::runtime::tensor::HostTensor;
use crate::util::parallel;
use crate::util::rng::Pcg64;

/// One compiled-to-native program.
pub struct NativeProgram {
    kind: ProgKind,
}

enum ProgKind {
    SageStep { train: bool },
    UpdateFused,
    UpdateUnfused,
    OpMm,
    OpAddBias,
    OpRelu,
    OpDropout,
}

impl NativeProgram {
    pub fn from_spec(spec: &ProgramSpec) -> Result<NativeProgram> {
        let model = spec.meta_str("model").unwrap_or("");
        let kind = spec.meta_str("kind").unwrap_or("");
        let k = match (model, kind) {
            ("sage", "train") => ProgKind::SageStep { train: true },
            ("sage", "fwd") => ProgKind::SageStep { train: false },
            ("gat", _) => bail!(
                "program '{}': the native executor does not implement GAT yet \
                 (edge-softmax backward is a ROADMAP open item); use --model sage",
                spec.name
            ),
            (_, "fused") => ProgKind::UpdateFused,
            (_, "unfused_full") => ProgKind::UpdateUnfused,
            (_, "op_mm") => ProgKind::OpMm,
            (_, "op_add_bias") => ProgKind::OpAddBias,
            (_, "op_relu") => ProgKind::OpRelu,
            (_, "op_dropout") => ProgKind::OpDropout,
            _ => bail!(
                "program '{}' has no native implementation (model='{model}', kind='{kind}')",
                spec.name
            ),
        };
        Ok(NativeProgram { kind: k })
    }

    /// Execute with pre-validated inputs (order matches `spec.inputs`).
    pub fn execute(&self, spec: &ProgramSpec, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        match self.kind {
            ProgKind::SageStep { train } => sage_step(spec, inputs, train),
            ProgKind::UpdateFused => update_fused(spec, inputs),
            ProgKind::UpdateUnfused => update_unfused(spec, inputs),
            ProgKind::OpMm => {
                let (m, k) = dims2(&inputs[0]);
                let n = inputs[1].shape[1];
                let a = inputs[0].to_f32()?;
                let b = inputs[1].to_f32()?;
                Ok(vec![HostTensor::f32(vec![m, n], &matmul(&a, m, k, &b, n))])
            }
            ProgKind::OpAddBias => {
                let (m, n) = dims2(&inputs[0]);
                let mut y = inputs[0].to_f32()?;
                let y2 = inputs[1].to_f32()?;
                let b = inputs[2].to_f32()?;
                for i in 0..m {
                    for j in 0..n {
                        y[i * n + j] += y2[i * n + j] + b[j];
                    }
                }
                Ok(vec![HostTensor::f32(vec![m, n], &y)])
            }
            ProgKind::OpRelu => {
                let mut y = inputs[0].to_f32()?;
                for v in y.iter_mut() {
                    *v = v.max(0.0);
                }
                Ok(vec![HostTensor::f32(inputs[0].shape.clone(), &y)])
            }
            ProgKind::OpDropout => {
                let mut y = inputs[0].to_f32()?;
                let mask = inputs[1].to_f32()?;
                for (v, &m) in y.iter_mut().zip(&mask) {
                    *v *= m;
                }
                Ok(vec![HostTensor::f32(inputs[0].shape.clone(), &y)])
            }
        }
    }
}

fn dims2(t: &HostTensor) -> (usize, usize) {
    (t.shape[0], t.shape[1])
}

// ---------------------------------------------------------------------------
// parallel dense kernels (fixed reduction order => thread-count invariant)
// ---------------------------------------------------------------------------

/// C[m,n] = A[m,k] @ B[k,n]; rows of C computed in parallel blocks.
/// Zero A entries are skipped — padded minibatch rows are all-zero, which
/// makes this the dominant win on the packed-block path.
pub(crate) fn matmul(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0f32; m * n];
    parallel::parallel_rows_mut(&mut out, n.max(1), |row0, chunk| {
        for (j, orow) in chunk.chunks_exact_mut(n).enumerate() {
            let i = row0 + j;
            let arow = &a[i * k..(i + 1) * k];
            for (kk, &av) in arow.iter().enumerate() {
                if av != 0.0 {
                    let brow = &b[kk * n..(kk + 1) * n];
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += av * bv;
                    }
                }
            }
        }
    });
    out
}

/// dW[k,n] = A[m,k]^T @ G[m,n] (the backward-by-weight pattern: the k
/// output rows are independent, reduction over m stays in order).
fn matmul_tn(a: &[f32], m: usize, k: usize, g: &[f32], n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(g.len(), m * n);
    let mut out = vec![0f32; k * n];
    parallel::parallel_rows_mut(&mut out, n.max(1), |row0, chunk| {
        for (j, orow) in chunk.chunks_exact_mut(n).enumerate() {
            let kk = row0 + j;
            for i in 0..m {
                let av = a[i * k + kk];
                if av != 0.0 {
                    let grow = &g[i * n..(i + 1) * n];
                    for (o, &gv) in orow.iter_mut().zip(grow) {
                        *o += av * gv;
                    }
                }
            }
        }
    });
    out
}

/// dX[m,k] = G[m,n] @ W[k,n]^T (row-major dot products).
fn matmul_nt(g: &[f32], m: usize, n: usize, w: &[f32], k: usize) -> Vec<f32> {
    debug_assert_eq!(g.len(), m * n);
    debug_assert_eq!(w.len(), k * n);
    let mut out = vec![0f32; m * k];
    parallel::parallel_rows_mut(&mut out, k.max(1), |row0, chunk| {
        for (j, orow) in chunk.chunks_exact_mut(k).enumerate() {
            let i = row0 + j;
            let grow = &g[i * n..(i + 1) * n];
            for (kk, o) in orow.iter_mut().enumerate() {
                let wrow = &w[kk * n..(kk + 1) * n];
                let mut acc = 0f32;
                for (&gv, &wv) in grow.iter().zip(wrow) {
                    acc += gv * wv;
                }
                *o = acc;
            }
        }
    });
    out
}

/// AGG: out[nd,d] += ew[e] * h[esrc[e]] scattered into edst[e] rows.
/// Sequential — scatter order defines the float reduction order.
fn aggregate(h: &[f32], d: usize, esrc: &[i32], edst: &[i32], ew: &[f32], nd: usize) -> Vec<f32> {
    let mut out = vec![0f32; nd * d];
    for ((&s, &t), &w) in esrc.iter().zip(edst).zip(ew) {
        if w == 0.0 {
            continue;
        }
        let src = &h[s as usize * d..(s as usize + 1) * d];
        let dst = &mut out[t as usize * d..(t as usize + 1) * d];
        for (o, &x) in dst.iter_mut().zip(src) {
            *o += w * x;
        }
    }
    out
}

/// Backward of [`aggregate`]: dh[esrc[e]] += ew[e] * dagg[edst[e]].
fn aggregate_bwd(
    dh: &mut [f32],
    d: usize,
    esrc: &[i32],
    edst: &[i32],
    ew: &[f32],
    dagg: &[f32],
) {
    for ((&s, &t), &w) in esrc.iter().zip(edst).zip(ew) {
        if w == 0.0 {
            continue;
        }
        let src = &dagg[t as usize * d..(t as usize + 1) * d];
        let dst = &mut dh[s as usize * d..(s as usize + 1) * d];
        for (o, &x) in dst.iter_mut().zip(src) {
            *o += w * x;
        }
    }
}

/// Inverted-dropout mask: 0 or 1/keep, from a deterministic stream.
fn dropout_mask(n: usize, rate: f64, seed: i32, layer: usize) -> Vec<f32> {
    let keep = 1.0 - rate;
    let inv = (1.0 / keep) as f32;
    let mut rng = Pcg64::new(seed as u32 as u64, 0xD6 + layer as u64);
    (0..n)
        .map(|_| if rng.gen_f64() < keep { inv } else { 0.0 })
        .collect()
}

// ---------------------------------------------------------------------------
// GraphSAGE train/eval step (model.py::sage_forward + its VJP)
// ---------------------------------------------------------------------------

struct LayerSave {
    /// AGG output (nd x d_in).
    agg: Vec<f32>,
    /// Post ReLU*mask, pre HEC-overwrite (inner layers only).
    y: Vec<f32>,
    /// Dropout mask (train + inner layers with rate > 0).
    mask: Option<Vec<f32>>,
    /// Output row positions overwritten by historical embeddings —
    /// gradients must not flow into them.
    hec_rows: Vec<usize>,
    d_in: usize,
    d_out: usize,
    nd: usize,
}

fn sage_step(spec: &ProgramSpec, inputs: &[HostTensor], train: bool) -> Result<Vec<HostTensor>> {
    let caps: Vec<usize> = spec
        .meta
        .get("node_caps")
        .and_then(|v| v.as_arr())
        .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
        .unwrap_or_default();
    let n_params = spec.meta_usize("n_params")?;
    let hidden = spec.meta_usize("hidden")?;
    let feat_dim = spec.meta_usize("feat_dim")?;
    let batch = spec.meta_usize("batch")?;
    let num_classes = spec.meta_usize("num_classes")?;
    let dropout = spec.meta.get("dropout").and_then(|v| v.as_f64()).unwrap_or(0.0);
    anyhow::ensure!(caps.len() >= 2, "program '{}' missing node_caps", spec.name);
    let n_layers = caps.len() - 1;
    anyhow::ensure!(n_params == 3 * n_layers, "sage expects 3 params per layer");

    // parameters
    let mut wn: Vec<Vec<f32>> = Vec::with_capacity(n_layers);
    let mut ws: Vec<Vec<f32>> = Vec::with_capacity(n_layers);
    let mut bias: Vec<Vec<f32>> = Vec::with_capacity(n_layers);
    for l in 0..n_layers {
        wn.push(inputs[3 * l].to_f32()?);
        ws.push(inputs[3 * l + 1].to_f32()?);
        bias.push(inputs[3 * l + 2].to_f32()?);
    }

    // batch inputs
    let feats = inputs[n_params].to_f32()?;
    let mut esrc: Vec<Vec<i32>> = Vec::with_capacity(n_layers);
    let mut edst: Vec<Vec<i32>> = Vec::with_capacity(n_layers);
    let mut ew: Vec<Vec<f32>> = Vec::with_capacity(n_layers);
    for l in 0..n_layers {
        let o = n_params + 1 + 3 * l;
        esrc.push(inputs[o].to_i32()?);
        edst.push(inputs[o + 1].to_i32()?);
        ew.push(inputs[o + 2].to_f32()?);
    }
    let hec_off = n_params + 1 + 3 * n_layers;
    let lab_off = hec_off + 2 * (n_layers - 1);
    let labels = inputs[lab_off].to_i32()?;
    let lmask = inputs[lab_off + 1].to_f32()?;
    let seed = inputs[lab_off + 2].to_i32()?[0];

    // ---- forward ----------------------------------------------------------
    let mut h: Vec<f32> = feats;
    let mut d_in = feat_dim;
    let mut h_stack: Vec<Vec<f32>> = Vec::with_capacity(n_layers); // layer inputs
    let mut saves: Vec<LayerSave> = Vec::with_capacity(n_layers);
    let mut embeds: Vec<HostTensor> = Vec::with_capacity(n_layers - 1);
    for l in 0..n_layers {
        let nd = caps[l + 1];
        let last = l == n_layers - 1;
        let d_out = if last { num_classes } else { hidden };
        let agg = aggregate(&h, d_in, &esrc[l], &edst[l], &ew[l], nd);
        let mut pre = matmul(&agg, nd, d_in, &wn[l], d_out);
        let self_part = matmul(&h[..nd * d_in], nd, d_in, &ws[l], d_out);
        for i in 0..nd {
            for j in 0..d_out {
                pre[i * d_out + j] += self_part[i * d_out + j] + bias[l][j];
            }
        }
        if last {
            h_stack.push(std::mem::replace(&mut h, pre));
            saves.push(LayerSave {
                agg,
                y: Vec::new(),
                mask: None,
                hec_rows: Vec::new(),
                d_in,
                d_out,
                nd,
            });
            d_in = d_out;
        } else {
            for v in pre.iter_mut() {
                *v = v.max(0.0);
            }
            let mask = if train && dropout > 0.0 {
                let m = dropout_mask(nd * d_out, dropout, seed, l);
                for (v, &mv) in pre.iter_mut().zip(&m) {
                    *v *= mv;
                }
                Some(m)
            } else {
                None
            };
            let y_saved = if train { pre.clone() } else { Vec::new() };
            // historical-embedding overwrite for halo rows of A_{l+1}
            let idx = inputs[hec_off + 2 * l].to_i32()?;
            let val = inputs[hec_off + 2 * l + 1].to_f32()?;
            let mut hec_rows = Vec::new();
            for (j, &p) in idx.iter().enumerate() {
                let p = p as i64;
                if p >= 0 && (p as usize) < nd {
                    let p = p as usize;
                    pre[p * d_out..(p + 1) * d_out]
                        .copy_from_slice(&val[j * d_out..(j + 1) * d_out]);
                    hec_rows.push(p);
                }
            }
            embeds.push(HostTensor::f32(vec![nd, d_out], &pre));
            saves.push(LayerSave {
                agg,
                y: y_saved,
                mask,
                hec_rows,
                d_in,
                d_out,
                nd,
            });
            h_stack.push(std::mem::replace(&mut h, pre));
            d_in = d_out;
        }
    }

    // ---- masked softmax cross-entropy + accuracy --------------------------
    let logits = &h; // caps[L] x num_classes; caps[L] == batch
    debug_assert_eq!(caps[n_layers], batch);
    let denom: f32 = lmask.iter().sum::<f32>().max(1.0);
    let mut loss = 0f64;
    let mut correct = 0f64;
    let mut dlogits = if train {
        vec![0f32; batch * num_classes]
    } else {
        Vec::new()
    };
    for i in 0..batch {
        let row = &logits[i * num_classes..(i + 1) * num_classes];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0f32;
        for &x in row {
            sum += (x - m).exp();
        }
        let lse = m + sum.ln();
        let label = labels[i].clamp(0, num_classes as i32 - 1) as usize;
        let lm = lmask[i];
        loss += (-(row[label] - lse) * lm / denom) as f64;
        // argmax with first-index tie-break (jnp.argmax semantics)
        let mut best = 0usize;
        for (c, &x) in row.iter().enumerate() {
            if x > row[best] {
                best = c;
            }
        }
        if best == label {
            correct += lm as f64;
        }
        if train && lm != 0.0 {
            for c in 0..num_classes {
                let p = (row[c] - lse).exp();
                let ind = if c == label { 1.0 } else { 0.0 };
                dlogits[i * num_classes + c] = (p - ind) * lm / denom;
            }
        }
    }

    let mut outputs = Vec::with_capacity(2 + (n_layers - 1) + if train { n_params } else { 0 });
    outputs.push(HostTensor::f32(vec![], &[loss as f32]));
    outputs.push(HostTensor::f32(vec![], &[correct as f32]));
    outputs.extend(embeds);
    if !train {
        return Ok(outputs);
    }

    // ---- backward ---------------------------------------------------------
    let mut grads: Vec<Option<(Vec<f32>, Vec<f32>, Vec<f32>)>> = (0..n_layers).map(|_| None).collect();
    let mut g = dlogits; // gradient wrt layer output, rows caps[l+1]
    for l in (0..n_layers).rev() {
        let s = &saves[l];
        let last = l == n_layers - 1;
        if !last {
            // grads do not flow into historical-embedding rows
            for &p in &s.hec_rows {
                for v in g[p * s.d_out..(p + 1) * s.d_out].iter_mut() {
                    *v = 0.0;
                }
            }
            // Dropout(ReLU(..)) backward: g * mask * 1[y > 0]
            if let Some(mask) = &s.mask {
                for (v, &mv) in g.iter_mut().zip(mask) {
                    *v *= mv;
                }
            }
            for (v, &yv) in g.iter_mut().zip(&s.y) {
                if yv <= 0.0 {
                    *v = 0.0;
                }
            }
        }
        let h_in = &h_stack[l];
        let dwn = matmul_tn(&s.agg, s.nd, s.d_in, &g, s.d_out);
        let dws = matmul_tn(&h_in[..s.nd * s.d_in], s.nd, s.d_in, &g, s.d_out);
        let mut db = vec![0f32; s.d_out];
        for i in 0..s.nd {
            for j in 0..s.d_out {
                db[j] += g[i * s.d_out + j];
            }
        }
        if l > 0 {
            let dagg = matmul_nt(&g, s.nd, s.d_out, &wn[l], s.d_in);
            let dself = matmul_nt(&g, s.nd, s.d_out, &ws[l], s.d_in);
            let rows_l = caps[l];
            let mut dh = vec![0f32; rows_l * s.d_in];
            aggregate_bwd(&mut dh, s.d_in, &esrc[l], &edst[l], &ew[l], &dagg);
            for (v, &x) in dh[..s.nd * s.d_in].iter_mut().zip(&dself) {
                *v += x;
            }
            g = dh;
        }
        grads[l] = Some((dwn, dws, db));
    }
    for l in 0..n_layers {
        let (dwn, dws, db) = grads[l].take().unwrap();
        outputs.push(HostTensor::f32(inputs[3 * l].shape.clone(), &dwn));
        outputs.push(HostTensor::f32(inputs[3 * l + 1].shape.clone(), &dws));
        outputs.push(HostTensor::f32(inputs[3 * l + 2].shape.clone(), &db));
    }
    Ok(outputs)
}

// ---------------------------------------------------------------------------
// UPDATE micro programs (Fig. 2)
// ---------------------------------------------------------------------------

/// Fused UPDATE: Dropout(ReLU(xn·wn + xs·ws + b)) in one pass per output
/// row block — both matmuls accumulate into the register tile, then the
/// epilogue (bias, ReLU, mask) runs before the tile is stored.
fn update_fused(spec: &ProgramSpec, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
    let _ = spec;
    let (m, k) = dims2(&inputs[0]);
    let n = inputs[2].shape[1];
    let xn = inputs[0].to_f32()?;
    let xs = inputs[1].to_f32()?;
    let wn = inputs[2].to_f32()?;
    let ws = inputs[3].to_f32()?;
    let b = inputs[4].to_f32()?;
    let mask = inputs[5].to_f32()?;
    let mut out = vec![0f32; m * n];
    parallel::parallel_rows_mut(&mut out, n, |row0, chunk| {
        for (j, orow) in chunk.chunks_exact_mut(n).enumerate() {
            let i = row0 + j;
            for (kk, &av) in xn[i * k..(i + 1) * k].iter().enumerate() {
                if av != 0.0 {
                    for (o, &bv) in orow.iter_mut().zip(&wn[kk * n..(kk + 1) * n]) {
                        *o += av * bv;
                    }
                }
            }
            for (kk, &av) in xs[i * k..(i + 1) * k].iter().enumerate() {
                if av != 0.0 {
                    for (o, &bv) in orow.iter_mut().zip(&ws[kk * n..(kk + 1) * n]) {
                        *o += av * bv;
                    }
                }
            }
            for (jj, o) in orow.iter_mut().enumerate() {
                *o = (*o + b[jj]).max(0.0) * mask[i * n + jj];
            }
        }
    });
    Ok(vec![HostTensor::f32(vec![m, n], &out)])
}

/// The same chain with every intermediate materialized (framework-style
/// op dispatch inside one program).
fn update_unfused(spec: &ProgramSpec, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
    let _ = spec;
    let (m, k) = dims2(&inputs[0]);
    let n = inputs[2].shape[1];
    let xn = inputs[0].to_f32()?;
    let xs = inputs[1].to_f32()?;
    let wn = inputs[2].to_f32()?;
    let ws = inputs[3].to_f32()?;
    let b = inputs[4].to_f32()?;
    let mask = inputs[5].to_f32()?;
    let mm1 = matmul(&xn, m, k, &wn, n);
    let mm2 = matmul(&xs, m, k, &ws, n);
    let mut y: Vec<f32> = mm1.iter().zip(&mm2).map(|(&a, &c)| a + c).collect();
    for i in 0..m {
        for j in 0..n {
            y[i * n + j] += b[j];
        }
    }
    let y: Vec<f32> = y.into_iter().map(|v| v.max(0.0)).collect();
    let y: Vec<f32> = y.iter().zip(&mask).map(|(&v, &mv)| v * mv).collect();
    Ok(vec![HostTensor::f32(vec![m, n], &y)])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_matches_naive_and_is_thread_invariant() {
        let mut rng = Pcg64::seeded(3);
        let (m, k, n) = (13, 7, 9);
        let a: Vec<f32> = (0..m * k).map(|_| rng.gen_f32() - 0.5).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.gen_f32() - 0.5).collect();
        let got = matmul(&a, m, k, &b, n);
        let mut want = vec![0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    want[i * n + j] += a[i * k + kk] * b[kk * n + j];
                }
            }
        }
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4, "{g} vs {w}");
        }
    }

    #[test]
    fn transposed_matmuls_agree_with_naive() {
        let mut rng = Pcg64::seeded(4);
        let (m, k, n) = (11, 5, 6);
        let a: Vec<f32> = (0..m * k).map(|_| rng.gen_f32() - 0.5).collect();
        let g: Vec<f32> = (0..m * n).map(|_| rng.gen_f32() - 0.5).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.gen_f32() - 0.5).collect();
        let dw = matmul_tn(&a, m, k, &g, n);
        let dx = matmul_nt(&g, m, n, &w, k);
        for kk in 0..k {
            for j in 0..n {
                let mut want = 0f32;
                for i in 0..m {
                    want += a[i * k + kk] * g[i * n + j];
                }
                assert!((dw[kk * n + j] - want).abs() < 1e-4);
            }
        }
        for i in 0..m {
            for kk in 0..k {
                let mut want = 0f32;
                for j in 0..n {
                    want += g[i * n + j] * w[kk * n + j];
                }
                assert!((dx[i * k + kk] - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn aggregate_roundtrip_shapes() {
        // 3 src rows, 2 dst rows, dim 2
        let h = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let esrc = vec![0, 1, 2, 0];
        let edst = vec![0, 0, 1, 1];
        let ew = vec![0.5, 0.5, 1.0, 0.0]; // last edge dropped
        let agg = aggregate(&h, 2, &esrc, &edst, &ew, 2);
        assert_eq!(agg, vec![2.0, 3.0, 5.0, 6.0]);
        let mut dh = vec![0f32; 6];
        aggregate_bwd(&mut dh, 2, &esrc, &edst, &ew, &agg);
        assert_eq!(&dh[0..2], &[1.0, 1.5]); // 0.5 * dagg[dst 0]
        assert_eq!(&dh[4..6], &[5.0, 6.0]); // 1.0 * dagg[dst 1]
    }

    #[test]
    fn dropout_mask_deterministic_and_inverted() {
        let a = dropout_mask(1000, 0.2, 7, 1);
        let b = dropout_mask(1000, 0.2, 7, 1);
        let c = dropout_mask(1000, 0.2, 8, 1);
        assert_eq!(a, b);
        assert_ne!(a, c);
        let keep = a.iter().filter(|&&v| v > 0.0).count();
        assert!((700..900).contains(&keep), "keep {keep}");
        assert!(a.iter().all(|&v| v == 0.0 || (v - 1.25).abs() < 1e-6));
    }
}
