//! Native CPU executor for the AOT program signatures.
//!
//! The offline environment cannot link the PJRT/XLA runtime, so programs
//! described by the manifest execute through this hand-written Rust
//! implementation of the same math as `python/compile/model.py` +
//! `kernels/fused_update.py`: GraphSAGE forward/backward over padded
//! message-flow blocks (mean aggregation, fused UPDATE, historical-
//! embedding overwrite with gradient blocking, masked softmax
//! cross-entropy) and the Fig. 2 UPDATE micro programs.
//!
//! # Determinism invariant
//!
//! Matmuls run as thread-parallel row blocks (`util::parallel`); every
//! reduction has a **fixed order** — per output element, the contraction
//! index ascends regardless of worker count or partitioning — so results
//! are bit-identical for any `DISTGNN_THREADS`. This is one half of the
//! repo's bit-identical-loss contract (the other half is the fabric's
//! ordered delivery, see [`crate::comm::fabric`]).
//!
//! # bf16 storage seam
//!
//! Feature and historical-embedding inputs may arrive as
//! [`DType::Bf16`] tensors (`--dtype bf16`): [`matmul_bf16`] /
//! [`matmul_tn_bf16`] / [`matmul_nt_bf16`] / [`aggregate_bf16`] are the
//! packed row-block kernels (the paper's LIBXSMM TPP bf16 analogue) —
//! they up-convert bf16 operands per block and accumulate in f32, with a
//! 4-unrolled contraction loop and L1-resident output tiles. Weights,
//! gradients, activations and program outputs stay f32; only storage
//! bytes halve. The bf16 reduction order is fixed (k ascending in blocks
//! of 4) and therefore thread-count invariant, but it is a *different*
//! order than the f32 scalar kernels — bf16 runs are bit-identical to
//! themselves across transports/threads, and track f32 runs within the
//! tolerance documented in the README ("Numerics and precision").
//!
//! Dropout derives its mask from the program's `seed` input through
//! [`Pcg64`] (JAX's threefry stream is not reproduced — the native backend
//! is self-consistent, which is what the determinism tests assert).
//!
//! # GAT edge-softmax contract
//!
//! `gat_step` implements the paper's modified GAT (eq. 2,
//! `model.py::gat_forward`): per layer, `z = ReLU(h·W + b)` (bias and
//! non-linearity *before* attention), per-edge logits
//! `s_e = a_u∘z_src + a_v∘z_dst` through LeakyReLU (slope 0.2), then a
//! numerically-stable per-destination edge-softmax — the running maximum
//! over each destination's edges is subtracted before `exp` (masked edges
//! contribute `-1e30`, empty destinations clamp to `-1e29`, denominators
//! floor at `1e-9`, exactly mirroring `kernels/ref.py::gat_attention_ref`)
//! — and the attention-weighted aggregation of `z_src`. The final layer
//! averages heads into class logits; inner layers apply dropout and the
//! historical-embedding overwrite like SAGE. All edge reductions (max,
//! denominator, aggregation, and every backward scatter) run sequentially
//! in edge order, so the reduction order is fixed and results are
//! bit-identical for any thread count; the dense projections reuse the
//! parallel row-block matmuls (bf16 feature blocks included). The
//! backward VJP — softmax Jacobian `ds_e = α_e(dα_e − Σ_{e'→t} α_{e'}
//! dα_{e'})` per destination, LeakyReLU gate, `da_u`/`da_v`, `dW`/`db`
//! and input grads — is finite-difference checked by
//! `tests/grad_check.rs`.
//!
//! Both step programs optionally emit the input-feature gradient: when a
//! (test-constructed) spec declares a `grad_feats` output, the layer-0
//! backward extends to the feature block so every gradient the kernels
//! produce is finite-difference checkable. Production manifests do not
//! declare it and skip the extra work.

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Result};

use crate::runtime::artifacts::ProgramSpec;
use crate::runtime::bf16;
use crate::runtime::tensor::{DType, HostTensor};
use crate::util::parallel;
use crate::util::rng::Pcg64;

/// One compiled-to-native program.
pub struct NativeProgram {
    kind: ProgKind,
}

enum ProgKind {
    SageStep { train: bool },
    GatStep { train: bool },
    UpdateFused,
    UpdateUnfused,
    OpMm,
    OpAddBias,
    OpRelu,
    OpDropout,
}

impl NativeProgram {
    pub fn from_spec(spec: &ProgramSpec) -> Result<NativeProgram> {
        let model = spec.meta_str("model").unwrap_or("");
        let kind = spec.meta_str("kind").unwrap_or("");
        let k = match (model, kind) {
            ("sage", "train") => ProgKind::SageStep { train: true },
            // "serve" shares the dropout-free forward; it differs from
            // "fwd" only in declaring the final-layer logits as an output
            ("sage", "fwd") | ("sage", "serve") => ProgKind::SageStep { train: false },
            ("gat", "train") => ProgKind::GatStep { train: true },
            ("gat", "fwd") | ("gat", "serve") => ProgKind::GatStep { train: false },
            (_, "fused") => ProgKind::UpdateFused,
            (_, "unfused_full") => ProgKind::UpdateUnfused,
            (_, "op_mm") => ProgKind::OpMm,
            (_, "op_add_bias") => ProgKind::OpAddBias,
            (_, "op_relu") => ProgKind::OpRelu,
            (_, "op_dropout") => ProgKind::OpDropout,
            _ => bail!(
                "program '{}' has no native implementation (model='{model}', kind='{kind}')",
                spec.name
            ),
        };
        Ok(NativeProgram { kind: k })
    }

    /// Execute with pre-validated inputs (order matches `spec.inputs`).
    pub fn execute(&self, spec: &ProgramSpec, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        match self.kind {
            ProgKind::SageStep { train } => sage_step(spec, inputs, train),
            ProgKind::GatStep { train } => gat_step(spec, inputs, train),
            ProgKind::UpdateFused => update_fused(spec, inputs),
            ProgKind::UpdateUnfused => update_unfused(spec, inputs),
            ProgKind::OpMm => {
                let (m, k) = dims2(&inputs[0]);
                let n = inputs[1].shape[1];
                let a = inputs[0].to_f32()?;
                let b = inputs[1].to_f32()?;
                Ok(vec![HostTensor::f32(vec![m, n], &matmul(&a, m, k, &b, n))])
            }
            ProgKind::OpAddBias => {
                let (m, n) = dims2(&inputs[0]);
                let mut y = inputs[0].to_f32()?;
                let y2 = inputs[1].to_f32()?;
                let b = inputs[2].to_f32()?;
                for i in 0..m {
                    for j in 0..n {
                        y[i * n + j] += y2[i * n + j] + b[j];
                    }
                }
                Ok(vec![HostTensor::f32(vec![m, n], &y)])
            }
            ProgKind::OpRelu => {
                let mut y = inputs[0].to_f32()?;
                for v in y.iter_mut() {
                    *v = v.max(0.0);
                }
                Ok(vec![HostTensor::f32(inputs[0].shape.clone(), &y)])
            }
            ProgKind::OpDropout => {
                let mut y = inputs[0].to_f32()?;
                let mask = inputs[1].to_f32()?;
                for (v, &m) in y.iter_mut().zip(&mask) {
                    *v *= m;
                }
                Ok(vec![HostTensor::f32(inputs[0].shape.clone(), &y)])
            }
        }
    }
}

fn dims2(t: &HostTensor) -> (usize, usize) {
    (t.shape[0], t.shape[1])
}

// ---------------------------------------------------------------------------
// parallel dense kernels (fixed reduction order => thread-count invariant)
// ---------------------------------------------------------------------------

/// C[m,n] = A[m,k] @ B[k,n]; rows of C computed in parallel blocks.
/// Zero A entries are skipped — padded minibatch rows are all-zero, which
/// makes this the dominant win on the packed-block path.
///
/// This is the f32 *scalar* kernel (one contraction step at a time) that
/// the bf16 row-block kernels are benchmarked against
/// (`benches/update_kernel_bench.rs`).
pub fn matmul(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0f32; m * n];
    parallel::parallel_rows_mut(&mut out, n.max(1), |row0, chunk| {
        for (j, orow) in chunk.chunks_exact_mut(n).enumerate() {
            let i = row0 + j;
            let arow = &a[i * k..(i + 1) * k];
            for (kk, &av) in arow.iter().enumerate() {
                if av != 0.0 {
                    let brow = &b[kk * n..(kk + 1) * n];
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += av * bv;
                    }
                }
            }
        }
    });
    out
}

/// dW[k,n] = A[m,k]^T @ G[m,n] (the backward-by-weight pattern: the k
/// output rows are independent, reduction over m stays in order).
pub fn matmul_tn(a: &[f32], m: usize, k: usize, g: &[f32], n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(g.len(), m * n);
    let mut out = vec![0f32; k * n];
    parallel::parallel_rows_mut(&mut out, n.max(1), |row0, chunk| {
        for (j, orow) in chunk.chunks_exact_mut(n).enumerate() {
            let kk = row0 + j;
            for i in 0..m {
                let av = a[i * k + kk];
                if av != 0.0 {
                    let grow = &g[i * n..(i + 1) * n];
                    for (o, &gv) in orow.iter_mut().zip(grow) {
                        *o += av * gv;
                    }
                }
            }
        }
    });
    out
}

/// dX[m,k] = G[m,n] @ W[k,n]^T (row-major dot products).
pub fn matmul_nt(g: &[f32], m: usize, n: usize, w: &[f32], k: usize) -> Vec<f32> {
    debug_assert_eq!(g.len(), m * n);
    debug_assert_eq!(w.len(), k * n);
    let mut out = vec![0f32; m * k];
    parallel::parallel_rows_mut(&mut out, k.max(1), |row0, chunk| {
        for (j, orow) in chunk.chunks_exact_mut(k).enumerate() {
            let i = row0 + j;
            let grow = &g[i * n..(i + 1) * n];
            for (kk, o) in orow.iter_mut().enumerate() {
                let wrow = &w[kk * n..(kk + 1) * n];
                let mut acc = 0f32;
                for (&gv, &wv) in grow.iter().zip(wrow) {
                    acc += gv * wv;
                }
                *o = acc;
            }
        }
    });
    out
}

/// AGG: out[nd,d] += ew[e] * h[esrc[e]] scattered into edst[e] rows.
/// Sequential — scatter order defines the float reduction order.
fn aggregate(h: &[f32], d: usize, esrc: &[i32], edst: &[i32], ew: &[f32], nd: usize) -> Vec<f32> {
    let mut out = vec![0f32; nd * d];
    for ((&s, &t), &w) in esrc.iter().zip(edst).zip(ew) {
        if w == 0.0 {
            continue;
        }
        let src = &h[s as usize * d..(s as usize + 1) * d];
        let dst = &mut out[t as usize * d..(t as usize + 1) * d];
        for (o, &x) in dst.iter_mut().zip(src) {
            *o += w * x;
        }
    }
    out
}

/// Backward of [`aggregate`]: dh[esrc[e]] += ew[e] * dagg[edst[e]].
fn aggregate_bwd(
    dh: &mut [f32],
    d: usize,
    esrc: &[i32],
    edst: &[i32],
    ew: &[f32],
    dagg: &[f32],
) {
    for ((&s, &t), &w) in esrc.iter().zip(edst).zip(ew) {
        if w == 0.0 {
            continue;
        }
        let src = &dagg[t as usize * d..(t as usize + 1) * d];
        let dst = &mut dh[s as usize * d..(s as usize + 1) * d];
        for (o, &x) in dst.iter_mut().zip(src) {
            *o += w * x;
        }
    }
}

// ---------------------------------------------------------------------------
// bf16 packed row-block kernels (f32 accumulation)
// ---------------------------------------------------------------------------

/// Output-tile width of the bf16 kernels: a 4-row B panel plus the output
/// tile (5 * NB f32 = 5 KiB) stays L1-resident while the k loop streams.
const BF16_NB: usize = 256;

/// All-±0 test for a 4-element bf16 block (sign bit masked off): padded
/// minibatch rows are entirely zero, so whole blocks skip without
/// touching B.
#[inline(always)]
fn bf16_block_zero(a: &[u16], i: usize) -> bool {
    (a[i] | a[i + 1] | a[i + 2] | a[i + 3]) & 0x7FFF == 0
}

/// C[m,n] = A[m,k] @ B[k,n] with A stored as packed bf16, accumulating in
/// f32. Rows of C are computed in parallel blocks like [`matmul`]; within
/// a row the contraction is 4-unrolled over k against an L1-resident
/// output tile, so per-element accumulation order is fixed (k ascending in
/// blocks of 4) and results are thread-count invariant. All-zero a-blocks
/// (padded rows) are skipped.
pub fn matmul_bf16(a: &[u16], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0f32; m * n];
    parallel::parallel_rows_mut(&mut out, n.max(1), |row0, chunk| {
        for (j, orow) in chunk.chunks_exact_mut(n).enumerate() {
            let i = row0 + j;
            let arow = &a[i * k..(i + 1) * k];
            let mut jb = 0usize;
            while jb < n {
                let je = (jb + BF16_NB).min(n);
                let otile = &mut orow[jb..je];
                let mut kk = 0usize;
                while kk + 4 <= k {
                    if !bf16_block_zero(arow, kk) {
                        let a0 = bf16::to_f32(arow[kk]);
                        let a1 = bf16::to_f32(arow[kk + 1]);
                        let a2 = bf16::to_f32(arow[kk + 2]);
                        let a3 = bf16::to_f32(arow[kk + 3]);
                        let b0 = &b[kk * n + jb..kk * n + je];
                        let b1 = &b[(kk + 1) * n + jb..(kk + 1) * n + je];
                        let b2 = &b[(kk + 2) * n + jb..(kk + 2) * n + je];
                        let b3 = &b[(kk + 3) * n + jb..(kk + 3) * n + je];
                        for (jj, o) in otile.iter_mut().enumerate() {
                            *o += a0 * b0[jj] + a1 * b1[jj] + a2 * b2[jj] + a3 * b3[jj];
                        }
                    }
                    kk += 4;
                }
                while kk < k {
                    if arow[kk] & 0x7FFF != 0 {
                        let av = bf16::to_f32(arow[kk]);
                        let brow = &b[kk * n + jb..kk * n + je];
                        for (o, &bv) in otile.iter_mut().zip(brow) {
                            *o += av * bv;
                        }
                    }
                    kk += 1;
                }
                jb = je;
            }
        }
    });
    out
}

/// dW[k,n] = A[m,k]^T @ G[m,n] with A stored as packed bf16 (layer-0
/// backward-by-weight over the bf16 feature block). Parallel over the k
/// output rows; the m reduction is 4-unrolled with a fixed ascending
/// order per element.
pub fn matmul_tn_bf16(a: &[u16], m: usize, k: usize, g: &[f32], n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(g.len(), m * n);
    let mut out = vec![0f32; k * n];
    parallel::parallel_rows_mut(&mut out, n.max(1), |row0, chunk| {
        for (j, orow) in chunk.chunks_exact_mut(n).enumerate() {
            let kk = row0 + j;
            let mut i = 0usize;
            while i + 4 <= m {
                let r0 = a[i * k + kk];
                let r1 = a[(i + 1) * k + kk];
                let r2 = a[(i + 2) * k + kk];
                let r3 = a[(i + 3) * k + kk];
                if (r0 | r1 | r2 | r3) & 0x7FFF != 0 {
                    let a0 = bf16::to_f32(r0);
                    let a1 = bf16::to_f32(r1);
                    let a2 = bf16::to_f32(r2);
                    let a3 = bf16::to_f32(r3);
                    let g0 = &g[i * n..(i + 1) * n];
                    let g1 = &g[(i + 1) * n..(i + 2) * n];
                    let g2 = &g[(i + 2) * n..(i + 3) * n];
                    let g3 = &g[(i + 3) * n..(i + 4) * n];
                    for (jj, o) in orow.iter_mut().enumerate() {
                        *o += a0 * g0[jj] + a1 * g1[jj] + a2 * g2[jj] + a3 * g3[jj];
                    }
                }
                i += 4;
            }
            while i < m {
                if a[i * k + kk] & 0x7FFF != 0 {
                    let av = bf16::to_f32(a[i * k + kk]);
                    let grow = &g[i * n..(i + 1) * n];
                    for (o, &gv) in orow.iter_mut().zip(grow) {
                        *o += av * gv;
                    }
                }
                i += 1;
            }
        }
    });
    out
}

/// dX[m,k] = G[m,n] @ W[k,n]^T with G stored as packed bf16 (row-major
/// dot products, 4-unrolled over n with a fixed order).
pub fn matmul_nt_bf16(g: &[u16], m: usize, n: usize, w: &[f32], k: usize) -> Vec<f32> {
    debug_assert_eq!(g.len(), m * n);
    debug_assert_eq!(w.len(), k * n);
    let mut out = vec![0f32; m * k];
    parallel::parallel_rows_mut(&mut out, k.max(1), |row0, chunk| {
        for (j, orow) in chunk.chunks_exact_mut(k).enumerate() {
            let i = row0 + j;
            let grow = &g[i * n..(i + 1) * n];
            for (kk, o) in orow.iter_mut().enumerate() {
                let wrow = &w[kk * n..(kk + 1) * n];
                let mut acc = 0f32;
                let mut jj = 0usize;
                while jj + 4 <= n {
                    acc += bf16::to_f32(grow[jj]) * wrow[jj]
                        + bf16::to_f32(grow[jj + 1]) * wrow[jj + 1]
                        + bf16::to_f32(grow[jj + 2]) * wrow[jj + 2]
                        + bf16::to_f32(grow[jj + 3]) * wrow[jj + 3];
                    jj += 4;
                }
                while jj < n {
                    acc += bf16::to_f32(grow[jj]) * wrow[jj];
                    jj += 1;
                }
                *o = acc;
            }
        }
    });
    out
}

/// [`aggregate`] over a packed bf16 feature block: out[nd,d] +=
/// ew[e] * bf16(h[esrc[e]]) scattered into edst[e] rows, accumulating in
/// f32. Sequential like the f32 version — scatter order defines the float
/// reduction order.
pub fn aggregate_bf16(
    h: &[u16],
    d: usize,
    esrc: &[i32],
    edst: &[i32],
    ew: &[f32],
    nd: usize,
) -> Vec<f32> {
    let mut out = vec![0f32; nd * d];
    for ((&s, &t), &w) in esrc.iter().zip(edst).zip(ew) {
        if w == 0.0 {
            continue;
        }
        let src = &h[s as usize * d..(s as usize + 1) * d];
        let dst = &mut out[t as usize * d..(t as usize + 1) * d];
        for (o, &x) in dst.iter_mut().zip(src) {
            *o += w * bf16::to_f32(x);
        }
    }
    out
}

/// Inverted-dropout mask: 0 or 1/keep, from a deterministic stream.
fn dropout_mask(n: usize, rate: f64, seed: i32, layer: usize) -> Vec<f32> {
    let keep = 1.0 - rate;
    let inv = (1.0 / keep) as f32;
    let mut rng = Pcg64::new(seed as u32 as u64, 0xD6 + layer as u64);
    (0..n)
        .map(|_| if rng.gen_f64() < keep { inv } else { 0.0 })
        .collect()
}

// ---------------------------------------------------------------------------
// GraphSAGE train/eval step (model.py::sage_forward + its VJP)
// ---------------------------------------------------------------------------

/// The layer-0 input block in its storage dtype. Activations of layers
/// >= 1 are always f32; only the raw feature block (and the HEC overwrite
/// values) may arrive bf16-packed.
enum FeatBlock {
    F32(Vec<f32>),
    Bf16(Vec<u16>),
}

/// Decoded batch inputs shared by both step programs. The input layout
/// after the first `n_params` tensors is identical for SAGE and GAT
/// (`model.py::batch_specs`: feats, per-layer esrc/edst/ew, per-inner-
/// layer hec_idx/hec_val, labels, lmask, seed), so both steps decode it
/// here — a layout change cannot skew one model's reads. The feature
/// block keeps its storage dtype (the bf16 path runs the packed
/// row-block kernels instead of up-converting wholesale).
struct StepBatch {
    feats: FeatBlock,
    esrc: Vec<Vec<i32>>,
    edst: Vec<Vec<i32>>,
    ew: Vec<Vec<f32>>,
    /// Input index of `hec_idx1` (the first HEC overwrite tensor).
    hec_off: usize,
    labels: Vec<i32>,
    lmask: Vec<f32>,
    seed: i32,
}

fn decode_batch(
    spec: &ProgramSpec,
    inputs: &[HostTensor],
    n_params: usize,
    n_layers: usize,
) -> Result<StepBatch> {
    let feats_t = &inputs[n_params];
    let feats = match feats_t.dtype {
        DType::F32 => FeatBlock::F32(feats_t.to_f32()?),
        DType::Bf16 => FeatBlock::Bf16(feats_t.to_bf16()?),
        other => bail!("program '{}': feats must be f32/bf16, got {other:?}", spec.name),
    };
    let mut esrc: Vec<Vec<i32>> = Vec::with_capacity(n_layers);
    let mut edst: Vec<Vec<i32>> = Vec::with_capacity(n_layers);
    let mut ew: Vec<Vec<f32>> = Vec::with_capacity(n_layers);
    for l in 0..n_layers {
        let o = n_params + 1 + 3 * l;
        esrc.push(inputs[o].to_i32()?);
        edst.push(inputs[o + 1].to_i32()?);
        ew.push(inputs[o + 2].to_f32()?);
    }
    let hec_off = n_params + 1 + 3 * n_layers;
    let lab_off = hec_off + 2 * (n_layers - 1);
    Ok(StepBatch {
        feats,
        esrc,
        edst,
        ew,
        hec_off,
        labels: inputs[lab_off].to_i32()?,
        lmask: inputs[lab_off + 1].to_f32()?,
        seed: inputs[lab_off + 2].to_i32()?[0],
    })
}

struct LayerSave {
    /// AGG output (nd x d_in).
    agg: Vec<f32>,
    /// Post ReLU*mask, pre HEC-overwrite (inner layers only).
    y: Vec<f32>,
    /// Dropout mask (train + inner layers with rate > 0).
    mask: Option<Vec<f32>>,
    /// Output row positions overwritten by historical embeddings —
    /// gradients must not flow into them.
    hec_rows: Vec<usize>,
    d_in: usize,
    d_out: usize,
    nd: usize,
}

fn sage_step(spec: &ProgramSpec, inputs: &[HostTensor], train: bool) -> Result<Vec<HostTensor>> {
    let caps: Vec<usize> = spec
        .meta
        .get("node_caps")
        .and_then(|v| v.as_arr())
        .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
        .unwrap_or_default();
    let n_params = spec.meta_usize("n_params")?;
    let hidden = spec.meta_usize("hidden")?;
    let feat_dim = spec.meta_usize("feat_dim")?;
    let batch = spec.meta_usize("batch")?;
    let num_classes = spec.meta_usize("num_classes")?;
    let dropout = spec.meta.get("dropout").and_then(|v| v.as_f64()).unwrap_or(0.0);
    anyhow::ensure!(caps.len() >= 2, "program '{}' missing node_caps", spec.name);
    let n_layers = caps.len() - 1;
    anyhow::ensure!(n_params == 3 * n_layers, "sage expects 3 params per layer");

    // parameters
    let mut wn: Vec<Vec<f32>> = Vec::with_capacity(n_layers);
    let mut ws: Vec<Vec<f32>> = Vec::with_capacity(n_layers);
    let mut bias: Vec<Vec<f32>> = Vec::with_capacity(n_layers);
    for l in 0..n_layers {
        wn.push(inputs[3 * l].to_f32()?);
        ws.push(inputs[3 * l + 1].to_f32()?);
        bias.push(inputs[3 * l + 2].to_f32()?);
    }

    let StepBatch {
        feats,
        esrc,
        edst,
        ew,
        hec_off,
        labels,
        lmask,
        seed,
    } = decode_batch(spec, inputs, n_params, n_layers)?;

    // ---- forward ----------------------------------------------------------
    // `h` carries the (always f32) input of layers >= 1; layer 0 reads the
    // feature block through `feats` in its storage dtype, so h_stack[0]
    // stays an empty placeholder and the layer-0 backward re-reads `feats`.
    let mut h: Vec<f32> = Vec::new();
    let mut d_in = feat_dim;
    let mut h_stack: Vec<Vec<f32>> = Vec::with_capacity(n_layers); // layer inputs
    let mut saves: Vec<LayerSave> = Vec::with_capacity(n_layers);
    let mut embeds: Vec<HostTensor> = Vec::with_capacity(n_layers - 1);
    for l in 0..n_layers {
        let nd = caps[l + 1];
        let last = l == n_layers - 1;
        let d_out = if last { num_classes } else { hidden };
        let agg = if l == 0 {
            match &feats {
                FeatBlock::F32(x) => aggregate(x, d_in, &esrc[l], &edst[l], &ew[l], nd),
                FeatBlock::Bf16(x) => aggregate_bf16(x, d_in, &esrc[l], &edst[l], &ew[l], nd),
            }
        } else {
            aggregate(&h, d_in, &esrc[l], &edst[l], &ew[l], nd)
        };
        let mut pre = matmul(&agg, nd, d_in, &wn[l], d_out);
        let self_part = if l == 0 {
            match &feats {
                FeatBlock::F32(x) => matmul(&x[..nd * d_in], nd, d_in, &ws[l], d_out),
                FeatBlock::Bf16(x) => matmul_bf16(&x[..nd * d_in], nd, d_in, &ws[l], d_out),
            }
        } else {
            matmul(&h[..nd * d_in], nd, d_in, &ws[l], d_out)
        };
        for i in 0..nd {
            for j in 0..d_out {
                pre[i * d_out + j] += self_part[i * d_out + j] + bias[l][j];
            }
        }
        if last {
            h_stack.push(std::mem::replace(&mut h, pre));
            saves.push(LayerSave {
                agg,
                y: Vec::new(),
                mask: None,
                hec_rows: Vec::new(),
                d_in,
                d_out,
                nd,
            });
            d_in = d_out;
        } else {
            for v in pre.iter_mut() {
                *v = v.max(0.0);
            }
            let mask = if train && dropout > 0.0 {
                let m = dropout_mask(nd * d_out, dropout, seed, l);
                for (v, &mv) in pre.iter_mut().zip(&m) {
                    *v *= mv;
                }
                Some(m)
            } else {
                None
            };
            let y_saved = if train { pre.clone() } else { Vec::new() };
            // historical-embedding overwrite for halo rows of A_{l+1}
            // (to_f32 expands a bf16-cached value tensor exactly)
            let idx = inputs[hec_off + 2 * l].to_i32()?;
            let val = inputs[hec_off + 2 * l + 1].to_f32()?;
            let mut hec_rows = Vec::new();
            for (j, &p) in idx.iter().enumerate() {
                let p = p as i64;
                if p >= 0 && (p as usize) < nd {
                    let p = p as usize;
                    pre[p * d_out..(p + 1) * d_out]
                        .copy_from_slice(&val[j * d_out..(j + 1) * d_out]);
                    hec_rows.push(p);
                }
            }
            embeds.push(HostTensor::f32(vec![nd, d_out], &pre));
            saves.push(LayerSave {
                agg,
                y: y_saved,
                mask,
                hec_rows,
                d_in,
                d_out,
                nd,
            });
            h_stack.push(std::mem::replace(&mut h, pre));
            d_in = d_out;
        }
    }

    // ---- masked softmax cross-entropy + accuracy --------------------------
    debug_assert_eq!(caps[n_layers], batch);
    let (loss, correct, dlogits) =
        masked_softmax_xent(&h, &labels, &lmask, batch, num_classes, train);

    let mut outputs = Vec::with_capacity(2 + (n_layers - 1) + if train { n_params } else { 0 });
    outputs.push(HostTensor::f32(vec![], &[loss]));
    outputs.push(HostTensor::f32(vec![], &[correct]));
    outputs.extend(embeds);
    if !train {
        // serve programs surface the final-layer logits to the caller
        if spec.output_index("logits").is_ok() {
            outputs.push(HostTensor::f32(vec![batch, num_classes], &h));
        }
        return Ok(outputs);
    }
    let want_dfeats = spec.output_index("grad_feats").is_ok();
    let mut dfeats: Option<Vec<f32>> = None;

    // ---- backward ---------------------------------------------------------
    let mut grads: Vec<Option<(Vec<f32>, Vec<f32>, Vec<f32>)>> = (0..n_layers).map(|_| None).collect();
    let mut g = dlogits; // gradient wrt layer output, rows caps[l+1]
    for l in (0..n_layers).rev() {
        let s = &saves[l];
        let last = l == n_layers - 1;
        if !last {
            // grads do not flow into historical-embedding rows
            for &p in &s.hec_rows {
                for v in g[p * s.d_out..(p + 1) * s.d_out].iter_mut() {
                    *v = 0.0;
                }
            }
            // Dropout(ReLU(..)) backward: g * mask * 1[y > 0]
            if let Some(mask) = &s.mask {
                for (v, &mv) in g.iter_mut().zip(mask) {
                    *v *= mv;
                }
            }
            for (v, &yv) in g.iter_mut().zip(&s.y) {
                if yv <= 0.0 {
                    *v = 0.0;
                }
            }
        }
        let dwn = matmul_tn(&s.agg, s.nd, s.d_in, &g, s.d_out);
        let dws = if l == 0 {
            // layer 0's input is the feature block in its storage dtype
            match &feats {
                FeatBlock::F32(x) => matmul_tn(&x[..s.nd * s.d_in], s.nd, s.d_in, &g, s.d_out),
                FeatBlock::Bf16(x) => {
                    matmul_tn_bf16(&x[..s.nd * s.d_in], s.nd, s.d_in, &g, s.d_out)
                }
            }
        } else {
            let h_in = &h_stack[l];
            matmul_tn(&h_in[..s.nd * s.d_in], s.nd, s.d_in, &g, s.d_out)
        };
        let mut db = vec![0f32; s.d_out];
        for i in 0..s.nd {
            for j in 0..s.d_out {
                db[j] += g[i * s.d_out + j];
            }
        }
        if l > 0 || want_dfeats {
            let dagg = matmul_nt(&g, s.nd, s.d_out, &wn[l], s.d_in);
            let dself = matmul_nt(&g, s.nd, s.d_out, &ws[l], s.d_in);
            let rows_l = caps[l];
            let mut dh = vec![0f32; rows_l * s.d_in];
            aggregate_bwd(&mut dh, s.d_in, &esrc[l], &edst[l], &ew[l], &dagg);
            for (v, &x) in dh[..s.nd * s.d_in].iter_mut().zip(&dself) {
                *v += x;
            }
            if l > 0 {
                g = dh;
            } else {
                dfeats = Some(dh);
            }
        }
        grads[l] = Some((dwn, dws, db));
    }
    for l in 0..n_layers {
        let (dwn, dws, db) = grads[l].take().unwrap();
        outputs.push(HostTensor::f32(inputs[3 * l].shape.clone(), &dwn));
        outputs.push(HostTensor::f32(inputs[3 * l + 1].shape.clone(), &dws));
        outputs.push(HostTensor::f32(inputs[3 * l + 2].shape.clone(), &db));
    }
    if let Some(df) = dfeats {
        outputs.push(HostTensor::f32(vec![caps[0], feat_dim], &df));
    }
    Ok(outputs)
}

/// Masked softmax cross-entropy + accuracy over the seed batch, shared by
/// the SAGE and GAT steps (identical arithmetic order, so extracting it
/// kept the SAGE losses bit-identical). Returns `(loss, correct,
/// dlogits)`; `dlogits` is empty unless `train`.
fn masked_softmax_xent(
    logits: &[f32],
    labels: &[i32],
    lmask: &[f32],
    batch: usize,
    num_classes: usize,
    train: bool,
) -> (f32, f32, Vec<f32>) {
    debug_assert_eq!(logits.len(), batch * num_classes);
    let denom: f32 = lmask.iter().sum::<f32>().max(1.0);
    let mut loss = 0f64;
    let mut correct = 0f64;
    let mut dlogits = if train {
        vec![0f32; batch * num_classes]
    } else {
        Vec::new()
    };
    for i in 0..batch {
        let row = &logits[i * num_classes..(i + 1) * num_classes];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0f32;
        for &x in row {
            sum += (x - m).exp();
        }
        let lse = m + sum.ln();
        let label = labels[i].clamp(0, num_classes as i32 - 1) as usize;
        let lm = lmask[i];
        loss += (-(row[label] - lse) * lm / denom) as f64;
        // argmax with first-index tie-break (jnp.argmax semantics)
        let mut best = 0usize;
        for (c, &x) in row.iter().enumerate() {
            if x > row[best] {
                best = c;
            }
        }
        if best == label {
            correct += lm as f64;
        }
        if train && lm != 0.0 {
            for c in 0..num_classes {
                let p = (row[c] - lse).exp();
                let ind = if c == label { 1.0 } else { 0.0 };
                dlogits[i * num_classes + c] = (p - ind) * lm / denom;
            }
        }
    }
    (loss as f32, correct as f32, dlogits)
}

// ---------------------------------------------------------------------------
// GAT train/eval step (model.py::gat_forward + its VJP)
// ---------------------------------------------------------------------------

/// LeakyReLU slope of the attention logits (ref.py::gat_attention_ref).
const GAT_NEG_SLOPE: f32 = 0.2;

/// Per-layer attention-phase nanoseconds (logits + edge-softmax +
/// weighted aggregation, forward only) accumulated by `gat_step` since
/// the last [`take_gat_attention_secs`] call. Bench instrumentation for
/// `benches/fig4_gat_scaling.rs`; layers beyond the cap fold into the
/// last slot. Timing never feeds any computed value, so it cannot perturb
/// the bit-identical-loss contract.
const GAT_PROF_LAYERS: usize = 8;
static GAT_ATTN_NANOS: [AtomicU64; GAT_PROF_LAYERS] =
    [const { AtomicU64::new(0) }; GAT_PROF_LAYERS];

/// Drain the per-layer attention-time counters (seconds, layer-indexed).
pub fn take_gat_attention_secs(n_layers: usize) -> Vec<f64> {
    (0..n_layers.min(GAT_PROF_LAYERS))
        .map(|l| GAT_ATTN_NANOS[l].swap(0, Ordering::Relaxed) as f64 * 1e-9)
        .collect()
}

/// Per-node attention logits `out[i, hd] = Σ_j z[i, hd·dh+j] · avec[hd·dh+j]`
/// over the first `rows` rows of `z` — the `a_u ∘ z_src` / `a_v ∘ z_dst`
/// terms of the GAT edge logits. Parallel row blocks; each per-row
/// reduction ascends over `dh`, so results are thread-count invariant.
fn attn_logits(z: &[f32], avec: &[f32], rows: usize, heads: usize, dh: usize) -> Vec<f32> {
    let d_out = heads * dh;
    let mut out = vec![0f32; rows * heads];
    parallel::parallel_rows_mut(&mut out, heads, |row0, chunk| {
        for (j, orow) in chunk.chunks_exact_mut(heads).enumerate() {
            let zrow = &z[(row0 + j) * d_out..(row0 + j + 1) * d_out];
            for (hd, o) in orow.iter_mut().enumerate() {
                let mut acc = 0f32;
                for (zv, av) in zrow[hd * dh..(hd + 1) * dh]
                    .iter()
                    .zip(&avec[hd * dh..(hd + 1) * dh])
                {
                    acc += zv * av;
                }
                *o = acc;
            }
        }
    });
    out
}

/// What the GAT backward needs from each layer's forward.
struct GatSave {
    /// Post-ReLU projection z = ReLU(h·W + b), all `ns` source rows.
    z: Vec<f32>,
    /// Edge-softmax coefficients, `[E, heads]` (0 for masked edges and
    /// edges whose destination had no valid neighbor).
    alpha: Vec<f32>,
    /// LeakyReLU derivative per edge-head: 1.0, `GAT_NEG_SLOPE`, or 0.0
    /// for masked edges.
    gate: Vec<f32>,
    /// Dropout mask (train + inner layers with rate > 0).
    mask: Option<Vec<f32>>,
    /// Output rows overwritten by historical embeddings (grads blocked).
    hec_rows: Vec<usize>,
    d_in: usize,
    /// Per-head output width (num_classes on the last layer).
    dh: usize,
    /// heads * dh.
    d_out: usize,
    ns: usize,
    nd: usize,
}

fn gat_step(spec: &ProgramSpec, inputs: &[HostTensor], train: bool) -> Result<Vec<HostTensor>> {
    let caps: Vec<usize> = spec
        .meta
        .get("node_caps")
        .and_then(|v| v.as_arr())
        .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
        .unwrap_or_default();
    let n_params = spec.meta_usize("n_params")?;
    let hidden = spec.meta_usize("hidden")?;
    let heads = spec.meta_usize("num_heads")?;
    let feat_dim = spec.meta_usize("feat_dim")?;
    let batch = spec.meta_usize("batch")?;
    let num_classes = spec.meta_usize("num_classes")?;
    let dropout = spec.meta.get("dropout").and_then(|v| v.as_f64()).unwrap_or(0.0);
    anyhow::ensure!(caps.len() >= 2, "program '{}' missing node_caps", spec.name);
    let n_layers = caps.len() - 1;
    anyhow::ensure!(n_params == 4 * n_layers, "gat expects 4 params per layer");
    anyhow::ensure!(heads > 0 && hidden % heads == 0, "hidden must divide by heads");

    // parameters: (w, b, au, av) per layer
    let mut w: Vec<Vec<f32>> = Vec::with_capacity(n_layers);
    let mut b: Vec<Vec<f32>> = Vec::with_capacity(n_layers);
    let mut au: Vec<Vec<f32>> = Vec::with_capacity(n_layers);
    let mut av: Vec<Vec<f32>> = Vec::with_capacity(n_layers);
    for l in 0..n_layers {
        w.push(inputs[4 * l].to_f32()?);
        b.push(inputs[4 * l + 1].to_f32()?);
        au.push(inputs[4 * l + 2].to_f32()?);
        av.push(inputs[4 * l + 3].to_f32()?);
    }

    // shared batch layout; for GAT the edge weights are a 0/1 validity
    // mask, not mean-aggregation weights
    let StepBatch {
        feats,
        esrc,
        edst,
        ew,
        hec_off,
        labels,
        lmask,
        seed,
    } = decode_batch(spec, inputs, n_params, n_layers)?;

    // ---- forward ----------------------------------------------------------
    // `h` carries the (always f32) input of layers >= 1; layer 0 reads the
    // feature block through `feats` in its storage dtype.
    let mut h: Vec<f32> = Vec::new();
    let mut d_in = feat_dim;
    let mut h_stack: Vec<Vec<f32>> = Vec::with_capacity(n_layers); // layer inputs
    let mut saves: Vec<GatSave> = Vec::with_capacity(n_layers);
    let mut embeds: Vec<HostTensor> = Vec::with_capacity(n_layers - 1);
    for l in 0..n_layers {
        let ns = caps[l];
        let nd = caps[l + 1];
        let last = l == n_layers - 1;
        let dh = if last { num_classes } else { hidden / heads };
        let d_out = heads * dh;
        // z = ReLU(h·W + b) over every source row (paper's modification:
        // bias + non-linearity before the attention coefficients)
        let mut z = if l == 0 {
            match &feats {
                FeatBlock::F32(x) => matmul(&x[..ns * d_in], ns, d_in, &w[l], d_out),
                FeatBlock::Bf16(x) => matmul_bf16(&x[..ns * d_in], ns, d_in, &w[l], d_out),
            }
        } else {
            matmul(&h[..ns * d_in], ns, d_in, &w[l], d_out)
        };
        for i in 0..ns {
            for j in 0..d_out {
                z[i * d_out + j] = (z[i * d_out + j] + b[l][j]).max(0.0);
            }
        }

        let attn_t0 = std::time::Instant::now();
        // attention logits e_src = a_u ∘ z, e_dst = a_v ∘ z[:nd]
        let e_src = attn_logits(&z, &au[l], ns, heads, dh);
        let e_dst = attn_logits(&z, &av[l], nd, heads, dh);

        // per-edge logits through LeakyReLU; masked edges pinned to -1e30
        // exactly like ref.py (sequential: fixed reduction order)
        let es = &esrc[l];
        let ed = &edst[l];
        let m = &ew[l];
        let ne = es.len();
        let mut sv = vec![0f32; ne * heads];
        let mut gate = vec![0f32; ne * heads];
        for e in 0..ne {
            if m[e] <= 0.0 {
                for hd in 0..heads {
                    sv[e * heads + hd] = -1e30;
                }
                continue;
            }
            let sp = es[e] as usize;
            let t = ed[e] as usize;
            for hd in 0..heads {
                let raw = e_src[sp * heads + hd] + e_dst[t * heads + hd];
                let gt = if raw >= 0.0 { 1.0 } else { GAT_NEG_SLOPE };
                gate[e * heads + hd] = gt;
                sv[e * heads + hd] = raw * gt;
            }
        }
        // numerically-stable edge-softmax: subtract the per-destination
        // maximum (clamped to -1e29 for destinations with no valid edge),
        // floor denominators at 1e-9
        let mut smax = vec![f32::NEG_INFINITY; nd * heads];
        for e in 0..ne {
            let t = ed[e] as usize;
            for hd in 0..heads {
                let v = sv[e * heads + hd];
                if v > smax[t * heads + hd] {
                    smax[t * heads + hd] = v;
                }
            }
        }
        for v in smax.iter_mut() {
            if *v < -1e29 {
                *v = -1e29;
            }
        }
        let mut alpha = vec![0f32; ne * heads]; // ex, normalized in place below
        let mut denom = vec![0f32; nd * heads];
        for e in 0..ne {
            if m[e] <= 0.0 {
                continue;
            }
            let t = ed[e] as usize;
            for hd in 0..heads {
                let v = (sv[e * heads + hd] - smax[t * heads + hd]).exp();
                alpha[e * heads + hd] = v;
                denom[t * heads + hd] += v;
            }
        }
        for v in denom.iter_mut() {
            if *v < 1e-9 {
                *v = 1e-9;
            }
        }
        // normalize + attention-weighted aggregation (sequential scatter:
        // edge order is the reduction order, like `aggregate`)
        let mut hn = vec![0f32; nd * d_out];
        for e in 0..ne {
            if m[e] <= 0.0 {
                continue;
            }
            let sp = es[e] as usize;
            let t = ed[e] as usize;
            for hd in 0..heads {
                let a = alpha[e * heads + hd] / denom[t * heads + hd];
                alpha[e * heads + hd] = a;
                if a != 0.0 {
                    let src = &z[sp * d_out + hd * dh..sp * d_out + (hd + 1) * dh];
                    let dst = &mut hn[t * d_out + hd * dh..t * d_out + (hd + 1) * dh];
                    for (o, &x) in dst.iter_mut().zip(src) {
                        *o += a * x;
                    }
                }
            }
        }
        GAT_ATTN_NANOS[l.min(GAT_PROF_LAYERS - 1)]
            .fetch_add(attn_t0.elapsed().as_nanos() as u64, Ordering::Relaxed);

        if last {
            // average heads into class logits
            let inv = 1.0 / heads as f32;
            let mut logits = vec![0f32; nd * num_classes];
            for i in 0..nd {
                for c in 0..num_classes {
                    let mut acc = 0f32;
                    for hd in 0..heads {
                        acc += hn[i * d_out + hd * dh + c];
                    }
                    logits[i * num_classes + c] = acc * inv;
                }
            }
            saves.push(GatSave {
                z,
                alpha,
                gate,
                mask: None,
                hec_rows: Vec::new(),
                d_in,
                dh,
                d_out,
                ns,
                nd,
            });
            h_stack.push(std::mem::replace(&mut h, logits));
            d_in = d_out;
        } else {
            let mask = if train && dropout > 0.0 {
                let mk = dropout_mask(nd * d_out, dropout, seed, l);
                for (v, &mv) in hn.iter_mut().zip(&mk) {
                    *v *= mv;
                }
                Some(mk)
            } else {
                None
            };
            // historical-embedding overwrite for halo rows of A_{l+1}
            let idx = inputs[hec_off + 2 * l].to_i32()?;
            let val = inputs[hec_off + 2 * l + 1].to_f32()?;
            let mut hec_rows = Vec::new();
            for (j, &p) in idx.iter().enumerate() {
                let p = p as i64;
                if p >= 0 && (p as usize) < nd {
                    let p = p as usize;
                    hn[p * d_out..(p + 1) * d_out]
                        .copy_from_slice(&val[j * d_out..(j + 1) * d_out]);
                    hec_rows.push(p);
                }
            }
            embeds.push(HostTensor::f32(vec![nd, d_out], &hn));
            saves.push(GatSave {
                z,
                alpha,
                gate,
                mask,
                hec_rows,
                d_in,
                dh,
                d_out,
                ns,
                nd,
            });
            h_stack.push(std::mem::replace(&mut h, hn));
            d_in = d_out;
        }
    }

    // ---- masked softmax cross-entropy + accuracy --------------------------
    debug_assert_eq!(caps[n_layers], batch);
    let (loss, correct, dlogits) =
        masked_softmax_xent(&h, &labels, &lmask, batch, num_classes, train);

    let mut outputs = Vec::with_capacity(2 + (n_layers - 1) + if train { n_params } else { 0 });
    outputs.push(HostTensor::f32(vec![], &[loss]));
    outputs.push(HostTensor::f32(vec![], &[correct]));
    outputs.extend(embeds);
    if !train {
        // serve programs surface the final-layer logits to the caller
        if spec.output_index("logits").is_ok() {
            outputs.push(HostTensor::f32(vec![batch, num_classes], &h));
        }
        return Ok(outputs);
    }
    let want_dfeats = spec.output_index("grad_feats").is_ok();
    let mut dfeats: Option<Vec<f32>> = None;

    // ---- backward ---------------------------------------------------------
    type GatGrads = (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>);
    let mut grads: Vec<Option<GatGrads>> = (0..n_layers).map(|_| None).collect();
    let mut g = dlogits; // gradient wrt layer output, rows caps[l+1]
    for l in (0..n_layers).rev() {
        let s = &saves[l];
        let last = l == n_layers - 1;
        // gradient wrt hn [nd, d_out]
        let dhn: Vec<f32> = if last {
            // head-mean backward: every head gets dlogits / heads
            let inv = 1.0 / heads as f32;
            let mut d = vec![0f32; s.nd * s.d_out];
            for i in 0..s.nd {
                for hd in 0..heads {
                    for c in 0..s.dh {
                        d[i * s.d_out + hd * s.dh + c] = g[i * s.dh + c] * inv;
                    }
                }
            }
            d
        } else {
            // grads do not flow into historical-embedding rows
            for &p in &s.hec_rows {
                for v in g[p * s.d_out..(p + 1) * s.d_out].iter_mut() {
                    *v = 0.0;
                }
            }
            if let Some(mask) = &s.mask {
                for (v, &mv) in g.iter_mut().zip(mask) {
                    *v *= mv;
                }
            }
            std::mem::take(&mut g)
        };

        let es = &esrc[l];
        let ed = &edst[l];
        let m = &ew[l];
        let ne = es.len();
        // message backward: dα_e = dhn[t]·z[s] per head, dz[s] += α_e dhn[t]
        let mut dz = vec![0f32; s.ns * s.d_out];
        let mut dalpha = vec![0f32; ne * heads];
        for e in 0..ne {
            if m[e] <= 0.0 {
                continue;
            }
            let sp = es[e] as usize;
            let t = ed[e] as usize;
            for hd in 0..heads {
                let drow = &dhn[t * s.d_out + hd * s.dh..t * s.d_out + (hd + 1) * s.dh];
                let zrow = &s.z[sp * s.d_out + hd * s.dh..sp * s.d_out + (hd + 1) * s.dh];
                let mut acc = 0f32;
                for (dv, zv) in drow.iter().zip(zrow) {
                    acc += dv * zv;
                }
                dalpha[e * heads + hd] = acc;
                let a = s.alpha[e * heads + hd];
                if a != 0.0 {
                    let dst = &mut dz[sp * s.d_out + hd * s.dh..sp * s.d_out + (hd + 1) * s.dh];
                    for (o, &dv) in dst.iter_mut().zip(drow) {
                        *o += a * dv;
                    }
                }
            }
        }
        // softmax Jacobian through the per-destination normalization:
        // ds_e = α_e (dα_e − Σ_{e'→t} α_{e'} dα_{e'}), then the LeakyReLU
        // gate; the max-subtraction shift cancels exactly and needs no term
        let mut sdot = vec![0f32; s.nd * heads];
        for e in 0..ne {
            let t = ed[e] as usize;
            for hd in 0..heads {
                sdot[t * heads + hd] += s.alpha[e * heads + hd] * dalpha[e * heads + hd];
            }
        }
        let mut de_src = vec![0f32; s.ns * heads];
        let mut de_dst = vec![0f32; s.nd * heads];
        for e in 0..ne {
            if m[e] <= 0.0 {
                continue;
            }
            let sp = es[e] as usize;
            let t = ed[e] as usize;
            for hd in 0..heads {
                let a = s.alpha[e * heads + hd];
                let ds = a * (dalpha[e * heads + hd] - sdot[t * heads + hd])
                    * s.gate[e * heads + hd];
                de_src[sp * heads + hd] += ds;
                de_dst[t * heads + hd] += ds;
            }
        }
        // attention-vector grads and the logit contribution to dz
        let mut dau = vec![0f32; heads * s.dh];
        let mut dav = vec![0f32; heads * s.dh];
        for i in 0..s.ns {
            for hd in 0..heads {
                let c = de_src[i * heads + hd];
                if c != 0.0 {
                    for j in 0..s.dh {
                        dz[i * s.d_out + hd * s.dh + j] += c * au[l][hd * s.dh + j];
                        dau[hd * s.dh + j] += c * s.z[i * s.d_out + hd * s.dh + j];
                    }
                }
            }
        }
        for i in 0..s.nd {
            for hd in 0..heads {
                let c = de_dst[i * heads + hd];
                if c != 0.0 {
                    for j in 0..s.dh {
                        dz[i * s.d_out + hd * s.dh + j] += c * av[l][hd * s.dh + j];
                        dav[hd * s.dh + j] += c * s.z[i * s.d_out + hd * s.dh + j];
                    }
                }
            }
        }
        // ReLU backward (z > 0 ⇔ pre-activation > 0)
        for (v, &zv) in dz.iter_mut().zip(&s.z) {
            if zv <= 0.0 {
                *v = 0.0;
            }
        }
        // projection backward
        let dw = if l == 0 {
            match &feats {
                FeatBlock::F32(x) => matmul_tn(&x[..s.ns * s.d_in], s.ns, s.d_in, &dz, s.d_out),
                FeatBlock::Bf16(x) => {
                    matmul_tn_bf16(&x[..s.ns * s.d_in], s.ns, s.d_in, &dz, s.d_out)
                }
            }
        } else {
            matmul_tn(&h_stack[l][..s.ns * s.d_in], s.ns, s.d_in, &dz, s.d_out)
        };
        let mut db = vec![0f32; s.d_out];
        for i in 0..s.ns {
            for j in 0..s.d_out {
                db[j] += dz[i * s.d_out + j];
            }
        }
        if l > 0 {
            g = matmul_nt(&dz, s.ns, s.d_out, &w[l], s.d_in);
        } else if want_dfeats {
            dfeats = Some(matmul_nt(&dz, s.ns, s.d_out, &w[l], s.d_in));
        }
        grads[l] = Some((dw, db, dau, dav));
    }
    for l in 0..n_layers {
        let (dw, db, dau, dav) = grads[l].take().unwrap();
        outputs.push(HostTensor::f32(inputs[4 * l].shape.clone(), &dw));
        outputs.push(HostTensor::f32(inputs[4 * l + 1].shape.clone(), &db));
        outputs.push(HostTensor::f32(inputs[4 * l + 2].shape.clone(), &dau));
        outputs.push(HostTensor::f32(inputs[4 * l + 3].shape.clone(), &dav));
    }
    if let Some(df) = dfeats {
        outputs.push(HostTensor::f32(vec![caps[0], feat_dim], &df));
    }
    Ok(outputs)
}

// ---------------------------------------------------------------------------
// UPDATE micro programs (Fig. 2)
// ---------------------------------------------------------------------------

/// Fused UPDATE: Dropout(ReLU(xn·wn + xs·ws + b)) in one pass per output
/// row block — both matmuls accumulate into the register tile, then the
/// epilogue (bias, ReLU, mask) runs before the tile is stored.
fn update_fused(spec: &ProgramSpec, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
    let _ = spec;
    let (m, k) = dims2(&inputs[0]);
    let n = inputs[2].shape[1];
    let xn = inputs[0].to_f32()?;
    let xs = inputs[1].to_f32()?;
    let wn = inputs[2].to_f32()?;
    let ws = inputs[3].to_f32()?;
    let b = inputs[4].to_f32()?;
    let mask = inputs[5].to_f32()?;
    let mut out = vec![0f32; m * n];
    parallel::parallel_rows_mut(&mut out, n, |row0, chunk| {
        for (j, orow) in chunk.chunks_exact_mut(n).enumerate() {
            let i = row0 + j;
            for (kk, &av) in xn[i * k..(i + 1) * k].iter().enumerate() {
                if av != 0.0 {
                    for (o, &bv) in orow.iter_mut().zip(&wn[kk * n..(kk + 1) * n]) {
                        *o += av * bv;
                    }
                }
            }
            for (kk, &av) in xs[i * k..(i + 1) * k].iter().enumerate() {
                if av != 0.0 {
                    for (o, &bv) in orow.iter_mut().zip(&ws[kk * n..(kk + 1) * n]) {
                        *o += av * bv;
                    }
                }
            }
            for (jj, o) in orow.iter_mut().enumerate() {
                *o = (*o + b[jj]).max(0.0) * mask[i * n + jj];
            }
        }
    });
    Ok(vec![HostTensor::f32(vec![m, n], &out)])
}

/// The same chain with every intermediate materialized (framework-style
/// op dispatch inside one program).
fn update_unfused(spec: &ProgramSpec, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
    let _ = spec;
    let (m, k) = dims2(&inputs[0]);
    let n = inputs[2].shape[1];
    let xn = inputs[0].to_f32()?;
    let xs = inputs[1].to_f32()?;
    let wn = inputs[2].to_f32()?;
    let ws = inputs[3].to_f32()?;
    let b = inputs[4].to_f32()?;
    let mask = inputs[5].to_f32()?;
    let mm1 = matmul(&xn, m, k, &wn, n);
    let mm2 = matmul(&xs, m, k, &ws, n);
    let mut y: Vec<f32> = mm1.iter().zip(&mm2).map(|(&a, &c)| a + c).collect();
    for i in 0..m {
        for j in 0..n {
            y[i * n + j] += b[j];
        }
    }
    let y: Vec<f32> = y.into_iter().map(|v| v.max(0.0)).collect();
    let y: Vec<f32> = y.iter().zip(&mask).map(|(&v, &mv)| v * mv).collect();
    Ok(vec![HostTensor::f32(vec![m, n], &y)])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_matches_naive_and_is_thread_invariant() {
        let mut rng = Pcg64::seeded(3);
        let (m, k, n) = (13, 7, 9);
        let a: Vec<f32> = (0..m * k).map(|_| rng.gen_f32() - 0.5).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.gen_f32() - 0.5).collect();
        let got = matmul(&a, m, k, &b, n);
        let mut want = vec![0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    want[i * n + j] += a[i * k + kk] * b[kk * n + j];
                }
            }
        }
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4, "{g} vs {w}");
        }
    }

    #[test]
    fn transposed_matmuls_agree_with_naive() {
        let mut rng = Pcg64::seeded(4);
        let (m, k, n) = (11, 5, 6);
        let a: Vec<f32> = (0..m * k).map(|_| rng.gen_f32() - 0.5).collect();
        let g: Vec<f32> = (0..m * n).map(|_| rng.gen_f32() - 0.5).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.gen_f32() - 0.5).collect();
        let dw = matmul_tn(&a, m, k, &g, n);
        let dx = matmul_nt(&g, m, n, &w, k);
        for kk in 0..k {
            for j in 0..n {
                let mut want = 0f32;
                for i in 0..m {
                    want += a[i * k + kk] * g[i * n + j];
                }
                assert!((dw[kk * n + j] - want).abs() < 1e-4);
            }
        }
        for i in 0..m {
            for kk in 0..k {
                let mut want = 0f32;
                for j in 0..n {
                    want += g[i * n + j] * w[kk * n + j];
                }
                assert!((dx[i * k + kk] - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn aggregate_roundtrip_shapes() {
        // 3 src rows, 2 dst rows, dim 2
        let h = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let esrc = vec![0, 1, 2, 0];
        let edst = vec![0, 0, 1, 1];
        let ew = vec![0.5, 0.5, 1.0, 0.0]; // last edge dropped
        let agg = aggregate(&h, 2, &esrc, &edst, &ew, 2);
        assert_eq!(agg, vec![2.0, 3.0, 5.0, 6.0]);
        let mut dh = vec![0f32; 6];
        aggregate_bwd(&mut dh, 2, &esrc, &edst, &ew, &agg);
        assert_eq!(&dh[0..2], &[1.0, 1.5]); // 0.5 * dagg[dst 0]
        assert_eq!(&dh[4..6], &[5.0, 6.0]); // 1.0 * dagg[dst 1]
    }

    /// bf16-exact inputs through the bf16 kernels must agree with the f32
    /// kernels up to accumulation-order effects (the bf16 kernels contract
    /// in 4-blocks; values themselves are identical).
    #[test]
    fn bf16_kernels_agree_with_f32_on_exact_inputs() {
        let mut rng = Pcg64::seeded(8);
        let (m, k, n) = (17, 23, 9);
        // round once so both paths see identical values
        let a: Vec<f32> = (0..m * k)
            .map(|_| bf16::to_f32(bf16::from_f32(rng.gen_f32() - 0.5)))
            .collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.gen_f32() - 0.5).collect();
        let g: Vec<f32> = (0..m * n).map(|_| rng.gen_f32() - 0.5).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.gen_f32() - 0.5).collect();
        let a16 = bf16::pack_slice(&a);
        let close = |x: &[f32], y: &[f32]| {
            assert_eq!(x.len(), y.len());
            for (u, v) in x.iter().zip(y) {
                assert!((u - v).abs() < 1e-3, "{u} vs {v}");
            }
        };
        close(&matmul_bf16(&a16, m, k, &b, n), &matmul(&a, m, k, &b, n));
        close(&matmul_tn_bf16(&a16, m, k, &g, n), &matmul_tn(&a, m, k, &g, n));
        // NT contracts over n: pack G instead
        let g_rounded: Vec<f32> = g.iter().map(|&x| bf16::to_f32(bf16::from_f32(x))).collect();
        let g16 = bf16::pack_slice(&g_rounded);
        close(
            &matmul_nt_bf16(&g16, m, n, &w, k),
            &matmul_nt(&g_rounded, m, n, &w, k),
        );
    }

    #[test]
    fn bf16_aggregate_matches_f32_and_padded_rows_stay_zero() {
        let h = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let h16 = bf16::pack_slice(&h);
        let esrc = vec![0, 1, 2, 0];
        let edst = vec![0, 0, 1, 1];
        let ew = vec![0.5, 0.5, 1.0, 0.0];
        assert_eq!(
            aggregate_bf16(&h16, 2, &esrc, &edst, &ew, 2),
            aggregate(&h, 2, &esrc, &edst, &ew, 2)
        );
        // all-zero (padded) A rows must produce exactly-zero output rows
        let (m, k, n) = (6, 8, 5);
        let mut a = vec![0f32; m * k];
        for v in a[..2 * k].iter_mut() {
            *v = 1.5;
        }
        let b: Vec<f32> = (0..k * n).map(|i| i as f32 * 0.25).collect();
        let out = matmul_bf16(&bf16::pack_slice(&a), m, k, &b, n);
        assert!(out[2 * n..].iter().all(|&x| x == 0.0));
        assert!(out[..2 * n].iter().any(|&x| x != 0.0));
    }

    /// Non-multiple-of-4 contraction lengths exercise the scalar
    /// remainders of every bf16 kernel.
    #[test]
    fn bf16_kernel_remainder_paths() {
        let mut rng = Pcg64::seeded(9);
        for (m, k, n) in [(1usize, 1usize, 1usize), (3, 5, 2), (4, 7, 3), (5, 4, 6)] {
            let a: Vec<f32> = (0..m * k)
                .map(|_| bf16::to_f32(bf16::from_f32(rng.gen_f32() - 0.5)))
                .collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.gen_f32() - 0.5).collect();
            let got = matmul_bf16(&bf16::pack_slice(&a), m, k, &b, n);
            let want = matmul(&a, m, k, &b, n);
            for (u, v) in got.iter().zip(&want) {
                assert!((u - v).abs() < 1e-3, "({m},{k},{n}): {u} vs {v}");
            }
        }
    }

    /// Minimal 2-layer GAT spec (caps [6,4,2], 2 heads, hidden 4,
    /// 3 classes) plus matching inputs, for exercising `gat_step`
    /// directly. `au`/`av` default to zero => uniform attention.
    fn mini_gat(train: bool) -> (ProgramSpec, Vec<HostTensor>) {
        use crate::util::json;
        use std::collections::BTreeMap;
        let caps = [6usize, 4, 2];
        let (feat, hidden, heads, classes) = (3usize, 4usize, 2usize, 3usize);
        let mut meta = BTreeMap::new();
        meta.insert("model".to_string(), json::s("gat"));
        meta.insert(
            "kind".to_string(),
            json::s(if train { "train" } else { "fwd" }),
        );
        meta.insert(
            "node_caps".to_string(),
            json::arr(caps.iter().map(|&c| json::num(c as f64)).collect()),
        );
        meta.insert("n_params".to_string(), json::num(8.0));
        meta.insert("hidden".to_string(), json::num(hidden as f64));
        meta.insert("num_heads".to_string(), json::num(heads as f64));
        meta.insert("feat_dim".to_string(), json::num(feat as f64));
        meta.insert("batch".to_string(), json::num(caps[2] as f64));
        meta.insert("num_classes".to_string(), json::num(classes as f64));
        meta.insert("dropout".to_string(), json::num(0.0));
        let spec = ProgramSpec {
            name: "gat_mini".into(),
            hlo_file: String::new(),
            inputs: Vec::new(),
            outputs: Vec::new(),
            meta,
        };
        let mut rng = Pcg64::seeded(11);
        let mut randt = |shape: Vec<usize>| {
            let n: usize = shape.iter().product();
            HostTensor::f32(
                shape,
                &(0..n).map(|_| rng.gen_f32() - 0.5).collect::<Vec<_>>(),
            )
        };
        let mut inputs = Vec::new();
        // layer 0: w [3,4], b [4], au/av [2,2] (zero => uniform attention)
        inputs.push(randt(vec![feat, hidden]));
        inputs.push(randt(vec![hidden]));
        inputs.push(HostTensor::zeros(DType::F32, vec![heads, hidden / heads]));
        inputs.push(HostTensor::zeros(DType::F32, vec![heads, hidden / heads]));
        // layer 1: w [4,6], b [6], au/av [2,3]
        inputs.push(randt(vec![hidden, heads * classes]));
        inputs.push(randt(vec![heads * classes]));
        inputs.push(HostTensor::zeros(DType::F32, vec![heads, classes]));
        inputs.push(HostTensor::zeros(DType::F32, vec![heads, classes]));
        // feats [6,3]
        inputs.push(randt(vec![caps[0], feat]));
        // layer-0 edges: each dst 0..4 pulls two sources + self loop, one
        // masked pad edge at the end
        let esrc0 = vec![4, 5, 0, 5, 1, 4, 2, 1, 3, 0];
        let edst0 = vec![0, 0, 0, 1, 1, 2, 2, 3, 3, 0];
        let ew0 = vec![1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 0.0];
        inputs.push(HostTensor::i32(vec![10], &esrc0));
        inputs.push(HostTensor::i32(vec![10], &edst0));
        inputs.push(HostTensor::f32(vec![10], &ew0));
        // layer-1 edges: seeds aggregate two sources + self loop
        let esrc1 = vec![2, 0, 3, 1];
        let edst1 = vec![0, 0, 1, 1];
        let ew1 = vec![1.0, 1.0, 1.0, 1.0];
        inputs.push(HostTensor::i32(vec![4], &esrc1));
        inputs.push(HostTensor::i32(vec![4], &edst1));
        inputs.push(HostTensor::f32(vec![4], &ew1));
        // hec overwrite for layer 1: all indices out of bounds (no hits)
        inputs.push(HostTensor::i32(vec![4], &[4, 4, 4, 4]));
        inputs.push(HostTensor::zeros(DType::F32, vec![4, hidden]));
        // labels / lmask / seed
        inputs.push(HostTensor::i32(vec![2], &[1, 2]));
        inputs.push(HostTensor::f32(vec![2], &[1.0, 1.0]));
        inputs.push(HostTensor::i32(vec![], &[5]));
        (spec, inputs)
    }

    /// With au = av = 0 every valid in-edge gets the same attention
    /// weight, so the layer-0 output must equal the plain mean of
    /// z = ReLU(feats·W + b) over each destination's valid neighbors —
    /// an independent oracle for projection, edge-softmax and
    /// aggregation (the masked pad edge must not contribute).
    #[test]
    fn gat_uniform_attention_matches_mean_aggregation() {
        let (spec, inputs) = mini_gat(true);
        let out = gat_step(&spec, &inputs, true).unwrap();
        // outputs: loss, correct, h1, 8 grads
        assert_eq!(out.len(), 2 + 1 + 8);
        let loss = out[0].scalar_f32().unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        let h1 = out[2].to_f32().unwrap();
        assert_eq!(out[2].shape, vec![4, 4]);
        // oracle: z then uniform mean over valid in-edges
        let feats = inputs[8].to_f32().unwrap();
        let w0 = inputs[0].to_f32().unwrap();
        let b0 = inputs[1].to_f32().unwrap();
        let mut z = matmul(&feats, 6, 3, &w0, 4);
        for i in 0..6 {
            for j in 0..4 {
                z[i * 4 + j] = (z[i * 4 + j] + b0[j]).max(0.0);
            }
        }
        let esrc0 = inputs[9].to_i32().unwrap();
        let edst0 = inputs[10].to_i32().unwrap();
        let ew0 = inputs[11].to_f32().unwrap();
        let mut want = vec![0f32; 4 * 4];
        let mut deg = vec![0f32; 4];
        for e in 0..esrc0.len() {
            if ew0[e] <= 0.0 {
                continue;
            }
            deg[edst0[e] as usize] += 1.0;
            for j in 0..4 {
                want[edst0[e] as usize * 4 + j] += z[esrc0[e] as usize * 4 + j];
            }
        }
        for t in 0..4 {
            for j in 0..4 {
                want[t * 4 + j] /= deg[t].max(1.0);
            }
        }
        for (a, b) in h1.iter().zip(&want) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn gat_step_deterministic_and_fwd_drops_grads() {
        let (spec, inputs) = mini_gat(true);
        let a = gat_step(&spec, &inputs, true).unwrap();
        let b = gat_step(&spec, &inputs, true).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.data, y.data, "gat_step must be bit-deterministic");
        }
        // grad shapes match the parameter inputs
        for (i, g) in a[3..].iter().enumerate() {
            assert_eq!(g.shape, inputs[i].shape, "grad {i}");
        }
        let (fspec, finputs) = mini_gat(false);
        let f = gat_step(&fspec, &finputs, false).unwrap();
        assert_eq!(f.len(), 3); // loss, correct, h1
        // same parameters + dropout 0 => identical forward values
        assert_eq!(f[0].data, a[0].data);
    }

    /// bf16 feature storage reuses the packed kernels on the GAT path
    /// too: losses must track the f32 run closely on bf16-exact inputs.
    #[test]
    fn gat_step_accepts_bf16_feats() {
        let (spec, mut inputs) = mini_gat(true);
        let loss_f32 = gat_step(&spec, &inputs, true).unwrap()[0]
            .scalar_f32()
            .unwrap();
        let fv = inputs[8].to_f32().unwrap();
        // bf16-exact values => identical math up to kernel accumulation order
        let rounded: Vec<f32> = fv
            .iter()
            .map(|&x| bf16::to_f32(bf16::from_f32(x)))
            .collect();
        inputs[8] = HostTensor::bf16_from_f32(inputs[8].shape.clone(), &rounded);
        let loss_b16 = gat_step(&spec, &inputs, true).unwrap()[0]
            .scalar_f32()
            .unwrap();
        assert!((loss_f32 - loss_b16).abs() < 0.05, "{loss_f32} vs {loss_b16}");
    }

    /// The per-layer attention counters accumulate and drain. Other GAT
    /// tests may run concurrently in this binary, so only monotone facts
    /// are asserted (no exact-zero-after-drain check).
    #[test]
    fn attention_profile_counters_accumulate() {
        let (spec, inputs) = mini_gat(true);
        gat_step(&spec, &inputs, true).unwrap();
        let t = take_gat_attention_secs(2);
        assert_eq!(t.len(), 2);
        assert!(t.iter().all(|&x| x.is_finite() && x >= 0.0));
        assert_eq!(take_gat_attention_secs(GAT_PROF_LAYERS + 4).len(), GAT_PROF_LAYERS);
    }

    #[test]
    fn dropout_mask_deterministic_and_inverted() {
        let a = dropout_mask(1000, 0.2, 7, 1);
        let b = dropout_mask(1000, 0.2, 7, 1);
        let c = dropout_mask(1000, 0.2, 8, 1);
        assert_eq!(a, b);
        assert_ne!(a, c);
        let keep = a.iter().filter(|&&v| v > 0.0).count();
        assert!((700..900).contains(&keep), "keep {keep}");
        assert!(a.iter().all(|&v| v == 0.0 || (v - 1.25).abs() < 1e-6));
    }
}
