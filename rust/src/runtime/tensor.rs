//! Host-side tensors exchanged with the program executables.
//!
//! The L2 programs take flat (non-tupled) parameter lists and return a
//! tuple of outputs. [`HostTensor`] is the typed host representation;
//! packing code in `model::packing` builds these from minibatch blocks and
//! [`crate::runtime::client::Executable`] validates them against the
//! manifest specs.

use anyhow::{bail, Result};

use crate::runtime::bf16;

/// Element type of a host tensor (subset used by the artifacts).
///
/// `Bf16` is a storage format of f32 (top 16 bits, round-to-nearest-even
/// — see [`crate::runtime::bf16`]): the native executor up-converts it
/// per block and accumulates in f32, so a `Bf16` tensor satisfies an
/// `F32` input slot of a program signature.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    Bf16,
    I32,
    U32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" | "float32" => Ok(DType::F32),
            "bf16" | "bfloat16" => Ok(DType::Bf16),
            "i32" | "int32" | "s32" => Ok(DType::I32),
            "u32" | "uint32" => Ok(DType::U32),
            other => bail!("unsupported dtype '{other}'"),
        }
    }
    pub fn size_bytes(self) -> usize {
        match self {
            DType::Bf16 => 2,
            _ => 4,
        }
    }
}

/// A dense host tensor with row-major layout.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub dtype: DType,
    pub shape: Vec<usize>,
    /// Raw little-endian bytes, length = product(shape) * dtype.size_bytes().
    pub data: Vec<u8>,
}

/// View a numeric slice as raw little-endian bytes (single memcpy; this
/// crate only targets little-endian hosts, checked at compile time).
/// Crate-visible so hot gather paths (packer feature fill, HEC row copies)
/// can block-copy f32/bf16 rows straight into tensor storage.
#[cfg(target_endian = "little")]
pub(crate) fn as_bytes<T: Copy>(values: &[T]) -> &[u8] {
    // SAFETY: T is a plain-old-data numeric type; any byte pattern is a
    // valid u8; lifetime tied to the input slice.
    unsafe {
        std::slice::from_raw_parts(values.as_ptr() as *const u8, std::mem::size_of_val(values))
    }
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, values: &[f32]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        HostTensor {
            dtype: DType::F32,
            shape,
            data: as_bytes(values).to_vec(),
        }
    }

    pub fn i32(shape: Vec<usize>, values: &[i32]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        HostTensor {
            dtype: DType::I32,
            shape,
            data: as_bytes(values).to_vec(),
        }
    }

    pub fn u32(shape: Vec<usize>, values: &[u32]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        HostTensor {
            dtype: DType::U32,
            shape,
            data: as_bytes(values).to_vec(),
        }
    }

    /// Bf16 tensor from raw bf16 bit patterns.
    pub fn bf16(shape: Vec<usize>, values: &[u16]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        HostTensor {
            dtype: DType::Bf16,
            shape,
            data: as_bytes(values).to_vec(),
        }
    }

    /// Bf16 tensor packed from f32 values (round-to-nearest-even).
    pub fn bf16_from_f32(shape: Vec<usize>, values: &[f32]) -> Self {
        HostTensor::bf16(shape, &bf16::pack_slice(values))
    }

    pub fn zeros(dtype: DType, shape: Vec<usize>) -> Self {
        let n: usize = shape.iter().product();
        HostTensor {
            dtype,
            shape,
            data: vec![0u8; n * dtype.size_bytes()],
        }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// View as f32 values (copies; bf16 tensors are expanded exactly).
    pub fn to_f32(&self) -> Result<Vec<f32>> {
        match self.dtype {
            DType::F32 => {
                let mut out = vec![0f32; self.len()];
                // SAFETY: see as_bytes — symmetric byte view for the copy-out.
                unsafe {
                    std::ptr::copy_nonoverlapping(
                        self.data.as_ptr(),
                        out.as_mut_ptr() as *mut u8,
                        self.data.len(),
                    );
                }
                Ok(out)
            }
            DType::Bf16 => Ok(self
                .data
                .chunks_exact(2)
                .map(|c| bf16::to_f32(u16::from_le_bytes([c[0], c[1]])))
                .collect()),
            other => bail!("tensor is {other:?}, expected F32/Bf16"),
        }
    }

    /// View as raw bf16 bit patterns (copies).
    pub fn to_bf16(&self) -> Result<Vec<u16>> {
        if self.dtype != DType::Bf16 {
            bail!("tensor is {:?}, expected Bf16", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(2)
            .map(|c| u16::from_le_bytes([c[0], c[1]]))
            .collect())
    }

    pub fn to_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != DType::I32 {
            bail!("tensor is {:?}, expected I32", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Scalar f32 extraction (loss values).
    pub fn scalar_f32(&self) -> Result<f32> {
        let v = self.to_f32()?;
        if v.len() != 1 {
            bail!("expected scalar, got {} elements", v.len());
        }
        Ok(v[0])
    }

    /// Write an f32 at flat index `i`.
    pub fn set_f32(&mut self, i: usize, v: f32) {
        debug_assert_eq!(self.dtype, DType::F32);
        self.data[i * 4..i * 4 + 4].copy_from_slice(&v.to_le_bytes());
    }

    /// Write an i32 at flat index `i`.
    pub fn set_i32(&mut self, i: usize, v: i32) {
        debug_assert_eq!(self.dtype, DType::I32);
        self.data[i * 4..i * 4 + 4].copy_from_slice(&v.to_le_bytes());
    }

    /// Copy a contiguous row of f32 values into row `r` of a 2-D tensor,
    /// converting to the tensor's element type (f32: byte copy; bf16:
    /// round-to-nearest-even pack).
    pub fn set_row_f32(&mut self, r: usize, row: &[f32]) {
        debug_assert_eq!(self.shape.len(), 2);
        debug_assert_eq!(self.shape[1], row.len());
        let w = self.shape[1];
        match self.dtype {
            DType::Bf16 => {
                let base = r * w * 2;
                bf16::pack_row_bytes(row, &mut self.data[base..base + w * 2]);
            }
            _ => {
                debug_assert_eq!(self.dtype, DType::F32);
                let base = r * w * 4;
                self.data[base..base + w * 4].copy_from_slice(as_bytes(row));
            }
        }
    }

}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip_via_bytes() {
        let t = HostTensor::f32(vec![2, 2], &[1.0, -2.5, 3.25, 0.0]);
        assert_eq!(t.len(), 4);
        assert_eq!(t.to_f32().unwrap(), vec![1.0, -2.5, 3.25, 0.0]);
        assert!(t.to_i32().is_err());
    }

    #[test]
    fn i32_roundtrip_and_set() {
        let mut t = HostTensor::zeros(DType::I32, vec![3]);
        t.set_i32(0, -7);
        t.set_i32(2, 42);
        assert_eq!(t.to_i32().unwrap(), vec![-7, 0, 42]);
    }

    #[test]
    fn set_row() {
        let mut t = HostTensor::zeros(DType::F32, vec![2, 3]);
        t.set_row_f32(1, &[1.0, 2.0, 3.0]);
        assert_eq!(t.to_f32().unwrap(), vec![0.0, 0.0, 0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::parse("f32").unwrap(), DType::F32);
        assert_eq!(DType::parse("int32").unwrap(), DType::I32);
        assert_eq!(DType::parse("bf16").unwrap(), DType::Bf16);
        assert!(DType::parse("f64").is_err());
    }

    #[test]
    fn bf16_tensor_roundtrip_and_row_write() {
        let t = HostTensor::bf16_from_f32(vec![2, 2], &[1.0, -2.5, 0.25, 0.0]);
        assert_eq!(t.dtype.size_bytes(), 2);
        assert_eq!(t.data.len(), 4 * 2);
        // these values are exactly bf16-representable
        assert_eq!(t.to_f32().unwrap(), vec![1.0, -2.5, 0.25, 0.0]);
        assert_eq!(t.to_bf16().unwrap().len(), 4);
        assert!(t.to_i32().is_err());

        let mut z = HostTensor::zeros(DType::Bf16, vec![2, 3]);
        z.set_row_f32(1, &[1.0, 2.0, 3.0]);
        assert_eq!(z.to_f32().unwrap(), vec![0.0, 0.0, 0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn scalar_extraction() {
        let t = HostTensor::f32(vec![], &[2.5]);
        assert_eq!(t.scalar_f32().unwrap(), 2.5);
        let t2 = HostTensor::f32(vec![2], &[1.0, 2.0]);
        assert!(t2.scalar_f32().is_err());
    }
}
