//! Message-flow-graph blocks produced by the sampler.
//!
//! Node sets A_0 ⊇ A_1 ⊇ ... ⊇ A_L (VID_p ids) with A_{l+1} stored as a
//! prefix of A_l — the VID_b of a vertex is its position in the layer
//! array. Block l connects source positions (into A_l) to destination
//! positions (into A_{l+1}).

/// One block's edges in positional (VID_b) coordinates.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BlockEdges {
    pub src: Vec<u32>,
    pub dst: Vec<u32>,
}

impl BlockEdges {
    pub fn len(&self) -> usize {
        self.src.len()
    }
    pub fn is_empty(&self) -> bool {
        self.src.is_empty()
    }
}

/// A sampled minibatch: L+1 node layers and L edge blocks.
#[derive(Clone, Debug, Default)]
pub struct MinibatchBlocks {
    /// layers[l] = A_l as VID_p ids; layers[L] = seeds.
    pub layers: Vec<Vec<u32>>,
    /// edges[l] connects positions in layers[l] to positions in layers[l+1].
    pub edges: Vec<BlockEdges>,
    /// Number of sampled nodes that could not be admitted because the
    /// layer hit its AOT shape cap (truncation counter, reported).
    pub overflow_nodes: usize,
    /// Edges dropped because their endpoint overflowed.
    pub overflow_edges: usize,
}

impl MinibatchBlocks {
    pub fn n_layers(&self) -> usize {
        self.edges.len()
    }
    pub fn seeds(&self) -> &[u32] {
        self.layers.last().unwrap()
    }

    /// Structural invariants (used by property tests):
    /// prefix property, positional bounds, seed set non-empty.
    pub fn validate(&self) -> anyhow::Result<()> {
        let l = self.n_layers();
        if self.layers.len() != l + 1 {
            anyhow::bail!("layers/edges arity mismatch");
        }
        for i in 0..l {
            let (outer, inner) = (&self.layers[i], &self.layers[i + 1]);
            if inner.len() > outer.len() {
                anyhow::bail!("layer {i} smaller than layer {}", i + 1);
            }
            if outer[..inner.len()] != inner[..] {
                anyhow::bail!("layer {} is not a prefix of layer {i}", i + 1);
            }
            let e = &self.edges[i];
            if e.src.len() != e.dst.len() {
                anyhow::bail!("block {i} src/dst length mismatch");
            }
            for (&s, &d) in e.src.iter().zip(&e.dst) {
                if s as usize >= outer.len() {
                    anyhow::bail!("block {i} src position {s} out of bounds");
                }
                if d as usize >= inner.len() {
                    anyhow::bail!("block {i} dst position {d} out of bounds");
                }
            }
        }
        Ok(())
    }

    /// Serialize to bytes (used by the DGL-worker-IPC emulation baseline).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        let push_u32 = |out: &mut Vec<u8>, v: u32| out.extend_from_slice(&v.to_le_bytes());
        push_u32(&mut out, self.layers.len() as u32);
        for layer in &self.layers {
            push_u32(&mut out, layer.len() as u32);
            for &v in layer {
                push_u32(&mut out, v);
            }
        }
        push_u32(&mut out, self.edges.len() as u32);
        for e in &self.edges {
            push_u32(&mut out, e.len() as u32);
            for &s in &e.src {
                push_u32(&mut out, s);
            }
            for &d in &e.dst {
                push_u32(&mut out, d);
            }
        }
        push_u32(&mut out, self.overflow_nodes as u32);
        push_u32(&mut out, self.overflow_edges as u32);
        out
    }

    /// Inverse of [`to_bytes`].
    pub fn from_bytes(data: &[u8]) -> anyhow::Result<MinibatchBlocks> {
        let mut pos = 0usize;
        let mut next = || -> anyhow::Result<u32> {
            let b = data
                .get(pos..pos + 4)
                .ok_or_else(|| anyhow::anyhow!("truncated block bytes"))?;
            pos += 4;
            Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        };
        let n_layers = next()? as usize;
        let mut layers = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            let n = next()? as usize;
            let mut layer = Vec::with_capacity(n);
            for _ in 0..n {
                layer.push(next()?);
            }
            layers.push(layer);
        }
        let n_blocks = next()? as usize;
        let mut edges = Vec::with_capacity(n_blocks);
        for _ in 0..n_blocks {
            let n = next()? as usize;
            let mut e = BlockEdges::default();
            for _ in 0..n {
                e.src.push(next()?);
            }
            for _ in 0..n {
                e.dst.push(next()?);
            }
            edges.push(e);
        }
        let overflow_nodes = next()? as usize;
        let overflow_edges = next()? as usize;
        Ok(MinibatchBlocks {
            layers,
            edges,
            overflow_nodes,
            overflow_edges,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_mb() -> MinibatchBlocks {
        MinibatchBlocks {
            layers: vec![vec![5, 6, 7, 8, 9], vec![5, 6, 7], vec![5, 6]],
            edges: vec![
                BlockEdges {
                    src: vec![3, 4, 0],
                    dst: vec![0, 1, 2],
                },
                BlockEdges {
                    src: vec![2, 1],
                    dst: vec![0, 1],
                },
            ],
            overflow_nodes: 1,
            overflow_edges: 2,
        }
    }

    #[test]
    fn validate_accepts_wellformed() {
        sample_mb().validate().unwrap();
    }

    #[test]
    fn validate_rejects_broken_prefix() {
        let mut mb = sample_mb();
        mb.layers[1][0] = 99;
        assert!(mb.validate().is_err());
    }

    #[test]
    fn validate_rejects_out_of_bounds_edge() {
        let mut mb = sample_mb();
        mb.edges[0].src[0] = 50;
        assert!(mb.validate().is_err());
    }

    #[test]
    fn bytes_roundtrip() {
        let mb = sample_mb();
        let back = MinibatchBlocks::from_bytes(&mb.to_bytes()).unwrap();
        assert_eq!(mb.layers, back.layers);
        assert_eq!(mb.edges, back.edges);
        assert_eq!(mb.overflow_nodes, back.overflow_nodes);
        assert_eq!(mb.overflow_edges, back.overflow_edges);
        assert!(MinibatchBlocks::from_bytes(&mb.to_bytes()[..7]).is_err());
    }
}
