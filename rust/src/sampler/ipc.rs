//! Helpers for the DGL dataloader-worker emulation (Fig. 2 baseline).
//!
//! DGL's asynchronous minibatch pipeline runs sampler *processes* that ship
//! each sampled minibatch to the trainer over IPC, paying a
//! serialize + copy + deserialize round-trip per minibatch. DistGNN-MB's
//! synchronous in-process sampler removes that overhead. The round-trip
//! itself lives in `NeighborSampler::sample` (SamplerKind::SerialIpc uses
//! `MinibatchBlocks::{to_bytes,from_bytes}`); this module measures it.

use crate::partition::RankPartition;
use crate::sampler::MinibatchBlocks;
use crate::util::timer::Stopwatch;

/// Measured cost of one IPC round-trip for a given minibatch, plus the
/// payload size — used by the Fig. 2 bench to report the sampler overhead
/// the paper's SYNC_MBC removes.
pub fn measure_ipc_roundtrip(mb: &MinibatchBlocks) -> (f64, usize) {
    let sw = Stopwatch::start();
    let bytes = mb.to_bytes();
    let back = MinibatchBlocks::from_bytes(&bytes).expect("roundtrip");
    let t = sw.secs();
    assert_eq!(back.layers.len(), mb.layers.len());
    (t, bytes.len())
}

/// Feature-payload size of a minibatch if features also crossed the IPC
/// boundary (DGL ships gathered features with the blocks).
pub fn feature_payload_bytes(mb: &MinibatchBlocks, part: &RankPartition) -> usize {
    mb.layers[0].len() * part.feat_dim * 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::block::BlockEdges;

    #[test]
    fn roundtrip_measured_positive() {
        let mb = MinibatchBlocks {
            layers: vec![vec![0, 1, 2], vec![0, 1]],
            edges: vec![BlockEdges {
                src: vec![2],
                dst: vec![0],
            }],
            overflow_nodes: 0,
            overflow_edges: 0,
        };
        let (t, bytes) = measure_ipc_roundtrip(&mb);
        assert!(t >= 0.0);
        assert!(bytes > 20);
    }
}
