//! Minibatch creation (the paper's MBC component): fan-out neighbor
//! sampling over the local partition, producing padded message-flow blocks.
//!
//! * [`neighbor`] — the thread-parallel synchronous sampler (the paper's
//!   SYNC_MBC optimization, §3.3): candidate selection per destination is
//!   parallelized; block assembly is a serial merge.
//! * [`ipc`] — DGL-dataloader emulation used as the Fig. 2 baseline: same
//!   sampling, plus a worker-IPC serialize/deserialize round-trip of the
//!   whole minibatch, which is the overhead the paper's synchronous
//!   sampler removes.
//! * [`block`] — the `MinibatchBlocks` structure shared with the packer.

pub mod block;
pub mod ipc;
pub mod neighbor;

pub use block::MinibatchBlocks;
pub use neighbor::{NeighborSampler, SampleScratch, SamplerStats};
