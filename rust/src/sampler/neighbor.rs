//! Fan-out neighbor sampling over the local partition.
//!
//! For each destination layer A_{l+1} (starting from the seed batch), each
//! *solid* destination samples up to `fanout[l]` neighbors without
//! replacement from its local adjacency; halo destinations cannot be
//! expanded (their neighborhoods are remote) — their embeddings come from
//! the HEC instead, per paper §3.2. Node admission respects the AOT shape
//! caps; overflowing nodes/edges are dropped and counted.
//!
//! The paper's SYNC_MBC optimization implements sampling as a synchronous
//! thread-parallel operation (OpenMP); here candidate selection per
//! destination runs under `util::parallel`, followed by a serial positional
//! merge (the merge is inherently order-dependent because positions are
//! VID_b ids). The merge's VID_p → VID_b remap lives in a reusable
//! open-addressing table ([`SampleScratch`]) instead of a per-layer
//! `HashMap`, killing the per-iteration allocation and rehash churn that
//! previously showed up in the driver's MBC component.
//!
//! [`NeighborSampler::sample_with`] is the re-entrant form (caller-owned
//! scratch, stats returned as a delta): the training pipeline uses it to
//! sample iteration k+1 on a worker thread while iteration k's fwd/bwd
//! runs, with the rank state only borrowed immutably.

use crate::config::SamplerKind;
use crate::partition::RankPartition;
use crate::sampler::block::{BlockEdges, MinibatchBlocks};
use crate::util::parallel;
use crate::util::rng::Pcg64;
use crate::util::vidmap::VidMap;

#[derive(Clone, Copy, Debug, Default)]
pub struct SamplerStats {
    pub minibatches: u64,
    pub sampled_nodes: u64,
    pub sampled_edges: u64,
    pub overflow_nodes: u64,
    pub overflow_edges: u64,
    /// Bytes round-tripped through the IPC emulation (SerialIpc only).
    pub ipc_bytes: u64,
}

impl SamplerStats {
    pub fn merge(&mut self, other: &SamplerStats) {
        self.minibatches += other.minibatches;
        self.sampled_nodes += other.sampled_nodes;
        self.sampled_edges += other.sampled_edges;
        self.overflow_nodes += other.overflow_nodes;
        self.overflow_edges += other.overflow_edges;
        self.ipc_bytes += other.ipc_bytes;
    }
}

/// Reusable per-sampler working memory: the positional-merge remap table.
/// Kept outside the minibatch (which is returned to the caller) so its
/// storage survives across iterations.
#[derive(Default)]
pub struct SampleScratch {
    map: VidMap,
}

impl SampleScratch {
    pub fn new() -> SampleScratch {
        SampleScratch::default()
    }
}

pub struct NeighborSampler {
    /// Fan-out per block, input-most first (same order as shapes.py).
    pub fanouts: Vec<usize>,
    /// Per-layer node caps [NS_0..NS_L] from the artifact manifest.
    pub node_caps: Vec<usize>,
    /// Add a self-edge for every (admitted) destination (GAT).
    pub self_loops: bool,
    pub kind: SamplerKind,
    pub stats: SamplerStats,
    scratch: SampleScratch,
}

impl NeighborSampler {
    pub fn new(
        fanouts: Vec<usize>,
        node_caps: Vec<usize>,
        self_loops: bool,
        kind: SamplerKind,
    ) -> Self {
        assert_eq!(fanouts.len() + 1, node_caps.len());
        NeighborSampler {
            fanouts,
            node_caps,
            self_loops,
            kind,
            stats: SamplerStats::default(),
            scratch: SampleScratch::new(),
        }
    }

    /// Sample one minibatch rooted at `seeds` (VID_p, all solid).
    pub fn sample(
        &mut self,
        part: &RankPartition,
        seeds: &[u32],
        rng: &mut Pcg64,
    ) -> MinibatchBlocks {
        let mut scratch = std::mem::take(&mut self.scratch);
        let (mb, delta) = self.sample_with(part, seeds, rng, &mut scratch);
        self.scratch = scratch;
        self.stats.merge(&delta);
        mb
    }

    /// Re-entrant sampling: identical output to [`sample`] for the same
    /// inputs, but `self` stays immutable — stats come back as a delta and
    /// working memory is the caller's `scratch`. This is what the driver's
    /// prefetch thread calls while the rank is mid-iteration.
    pub fn sample_with(
        &self,
        part: &RankPartition,
        seeds: &[u32],
        rng: &mut Pcg64,
        scratch: &mut SampleScratch,
    ) -> (MinibatchBlocks, SamplerStats) {
        let mut mb = self.sample_inner(part, seeds, rng, scratch);
        let mut delta = SamplerStats::default();
        if self.kind == SamplerKind::SerialIpc {
            // DGL dataloader-worker emulation: the minibatch crosses a
            // process boundary, costing a serialize + deserialize pass.
            let bytes = mb.to_bytes();
            delta.ipc_bytes += bytes.len() as u64;
            mb = MinibatchBlocks::from_bytes(&bytes).expect("ipc roundtrip");
        }
        delta.minibatches += 1;
        delta.sampled_nodes += mb.layers[0].len() as u64;
        delta.sampled_edges += mb.edges.iter().map(|e| e.len() as u64).sum::<u64>();
        delta.overflow_nodes += mb.overflow_nodes as u64;
        delta.overflow_edges += mb.overflow_edges as u64;
        (mb, delta)
    }

    fn sample_inner(
        &self,
        part: &RankPartition,
        seeds: &[u32],
        rng: &mut Pcg64,
        scratch: &mut SampleScratch,
    ) -> MinibatchBlocks {
        let n_layers = self.fanouts.len();
        debug_assert!(seeds.len() <= self.node_caps[n_layers]);
        let mut layers: Vec<Vec<u32>> = vec![Vec::new(); n_layers + 1];
        let mut edges: Vec<BlockEdges> = vec![BlockEdges::default(); n_layers];
        layers[n_layers] = seeds.to_vec();
        let mut overflow_nodes = 0usize;
        let mut overflow_edges = 0usize;

        // Expand from the seed layer outward: block l has dst = layers[l+1].
        for l in (0..n_layers).rev() {
            let fanout = self.fanouts[l];
            let cap = self.node_caps[l];

            // -- parallel phase: per-destination candidate selection -------
            // (each dst draws its neighbor subset with an independent,
            // deterministically derived RNG stream)
            let base_seed = rng.next_u64();
            let dst: &[u32] = &layers[l + 1];
            let candidates: Vec<Vec<u32>> = if self.kind == SamplerKind::Parallel {
                parallel::parallel_map(dst.len(), |di| {
                    select_neighbors(part, dst[di], fanout, base_seed, di)
                })
            } else {
                (0..dst.len())
                    .map(|di| select_neighbors(part, dst[di], fanout, base_seed, di))
                    .collect()
            };

            // -- serial phase: positional merge ----------------------------
            // A_l starts as a copy of A_{l+1} (prefix property); the remap
            // table is the reusable scratch VidMap, cleared in O(1).
            let mut nodes: Vec<u32> = Vec::with_capacity((dst.len() * (fanout + 1)).min(cap));
            nodes.extend_from_slice(dst);
            let pos = &mut scratch.map;
            pos.clear();
            pos.reserve(nodes.capacity());
            for (i, &v) in nodes.iter().enumerate() {
                pos.insert(v, i as u32);
            }
            let block = &mut edges[l];
            for (di, cands) in candidates.iter().enumerate() {
                for &u in cands {
                    let si = match pos.get(u) {
                        Some(p) => p,
                        None => {
                            if nodes.len() >= cap {
                                overflow_nodes += 1;
                                overflow_edges += 1;
                                continue;
                            }
                            let p = nodes.len() as u32;
                            nodes.push(u);
                            pos.insert(u, p);
                            p
                        }
                    };
                    block.src.push(si);
                    block.dst.push(di as u32);
                }
                if self.self_loops {
                    // dst position di is also its position in the src layer
                    // (prefix property)
                    block.src.push(di as u32);
                    block.dst.push(di as u32);
                }
            }
            layers[l] = nodes;
        }

        MinibatchBlocks {
            layers,
            edges,
            overflow_nodes,
            overflow_edges,
        }
    }
}

/// Select up to `fanout` distinct neighbors of `v` (all of them when the
/// degree is small). Halo vertices return no candidates.
fn select_neighbors(
    part: &RankPartition,
    v: u32,
    fanout: usize,
    base_seed: u64,
    stream: usize,
) -> Vec<u32> {
    if part.is_halo(v) {
        return Vec::new();
    }
    let neigh = part.local.neighbors(v);
    if neigh.len() <= fanout {
        return neigh.to_vec();
    }
    let mut rng = Pcg64::new(base_seed, stream as u64);
    rng.sample_indices(neigh.len(), fanout)
        .into_iter()
        .map(|i| neigh[i])
        .collect()
}

/// Number of seed batches [`make_seed_batches`] will produce for a rank
/// with `n_train` training vertices — a pure function of the sizes, so a
/// multi-process rank can compute every peer's per-epoch minibatch count
/// (and thus the global iteration count) without communication.
pub fn seed_batch_count(n_train: usize, batch: usize, max_minibatches: Option<usize>) -> usize {
    if n_train == 0 {
        return 0;
    }
    let mut n = (n_train + batch - 1) / batch;
    let last = n_train - (n - 1) * batch;
    if n > 1 && last < batch / 2 {
        n -= 1; // trailing sub-half batch dropped
    }
    if let Some(m) = max_minibatches {
        n = n.min(m);
    }
    n
}

/// Split a rank's (shuffled) training vertices into seed batches.
pub fn make_seed_batches(
    train: &[u32],
    batch: usize,
    rng: &mut Pcg64,
    max_minibatches: Option<usize>,
) -> Vec<Vec<u32>> {
    let mut order = train.to_vec();
    rng.shuffle(&mut order);
    let mut out: Vec<Vec<u32>> = order.chunks(batch).map(|c| c.to_vec()).collect();
    // drop a trailing sub-half batch only if there are other batches
    if out.len() > 1 && out.last().map(|b| b.len() < batch / 2).unwrap_or(false) {
        out.pop();
    }
    if let Some(m) = max_minibatches {
        out.truncate(m);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DatasetPreset;
    use crate::partition::metis_like::MetisLikePartitioner;
    use crate::partition::{materialize, Partitioner};

    fn setup() -> Vec<RankPartition> {
        let ds = DatasetPreset::tiny().generate();
        let a = MetisLikePartitioner::default().partition(&ds.graph, &ds.train_vertices, 2, 5);
        materialize(&ds, &a)
    }

    fn caps() -> Vec<usize> {
        vec![2048, 512, 128, 32]
    }

    #[test]
    fn blocks_validate_and_respect_fanout() {
        let parts = setup();
        let part = &parts[0];
        let mut s = NeighborSampler::new(vec![4, 6, 8], caps(), false, SamplerKind::Serial);
        let mut rng = Pcg64::seeded(1);
        let seeds: Vec<u32> = part.train_vertices.iter().take(32).copied().collect();
        let mb = s.sample(part, &seeds, &mut rng);
        mb.validate().unwrap();
        assert_eq!(mb.seeds(), &seeds[..]);
        // per-dst degree <= fanout
        for (l, fo) in [(0usize, 4usize), (1, 6), (2, 8)] {
            let mut deg = vec![0usize; mb.layers[l + 1].len()];
            for &d in &mb.edges[l].dst {
                deg[d as usize] += 1;
            }
            assert!(deg.iter().all(|&x| x <= fo), "layer {l}");
        }
    }

    #[test]
    fn halos_never_expanded() {
        let parts = setup();
        let part = &parts[0];
        let mut s = NeighborSampler::new(vec![4, 6, 8], caps(), false, SamplerKind::Serial);
        let mut rng = Pcg64::seeded(2);
        let seeds: Vec<u32> = part.train_vertices.iter().take(32).copied().collect();
        let mb = s.sample(part, &seeds, &mut rng);
        // a halo dst must have no incoming edges
        for l in 0..3 {
            for (&_s, &d) in mb.edges[l].src.iter().zip(&mb.edges[l].dst) {
                let dv = mb.layers[l + 1][d as usize];
                assert!(!part.is_halo(dv), "halo {dv} was expanded at layer {l}");
            }
        }
    }

    #[test]
    fn parallel_and_serial_agree() {
        let parts = setup();
        let part = &parts[1];
        let seeds: Vec<u32> = part.train_vertices.iter().take(16).copied().collect();
        let mut sp = NeighborSampler::new(vec![3, 5, 7], caps(), false, SamplerKind::Parallel);
        let mut ss = NeighborSampler::new(vec![3, 5, 7], caps(), false, SamplerKind::Serial);
        let a = sp.sample(part, &seeds, &mut Pcg64::seeded(3));
        let b = ss.sample(part, &seeds, &mut Pcg64::seeded(3));
        assert_eq!(a.layers, b.layers);
        assert_eq!(a.edges, b.edges);
    }

    #[test]
    fn ipc_roundtrip_preserves_blocks_and_counts_bytes() {
        let parts = setup();
        let part = &parts[0];
        let seeds: Vec<u32> = part.train_vertices.iter().take(16).copied().collect();
        let mut si = NeighborSampler::new(vec![3, 5, 7], caps(), false, SamplerKind::SerialIpc);
        let mut ss = NeighborSampler::new(vec![3, 5, 7], caps(), false, SamplerKind::Serial);
        let a = si.sample(part, &seeds, &mut Pcg64::seeded(4));
        let b = ss.sample(part, &seeds, &mut Pcg64::seeded(4));
        assert_eq!(a.layers, b.layers);
        assert!(si.stats.ipc_bytes > 0);
    }

    #[test]
    fn caps_are_enforced_with_overflow_counted() {
        let parts = setup();
        let part = &parts[0];
        let tight = vec![64, 48, 40, 32];
        let mut s = NeighborSampler::new(vec![8, 8, 8], tight.clone(), false, SamplerKind::Serial);
        let mut rng = Pcg64::seeded(5);
        let seeds: Vec<u32> = part.train_vertices.iter().take(32).copied().collect();
        let mb = s.sample(part, &seeds, &mut rng);
        mb.validate().unwrap();
        for (l, &cap) in tight.iter().enumerate() {
            assert!(mb.layers[l].len() <= cap, "layer {l} over cap");
        }
        assert!(mb.overflow_nodes > 0, "expected truncation with tight caps");
    }

    #[test]
    fn self_loops_add_diagonal_edges() {
        let parts = setup();
        let part = &parts[0];
        let mut s = NeighborSampler::new(vec![3, 3, 3], caps(), true, SamplerKind::Serial);
        let mut rng = Pcg64::seeded(6);
        let seeds: Vec<u32> = part.train_vertices.iter().take(8).copied().collect();
        let mb = s.sample(part, &seeds, &mut rng);
        for l in 0..3 {
            for di in 0..mb.layers[l + 1].len() as u32 {
                let has_self = mb.edges[l]
                    .src
                    .iter()
                    .zip(&mb.edges[l].dst)
                    .any(|(&s, &d)| s == di && d == di);
                assert!(has_self, "layer {l} dst {di} missing self loop");
            }
        }
    }

    #[test]
    fn sample_with_matches_sample_and_reuses_scratch() {
        let parts = setup();
        let part = &parts[0];
        let seeds: Vec<u32> = part.train_vertices.iter().take(24).copied().collect();
        let mut stateful = NeighborSampler::new(vec![4, 6, 8], caps(), false, SamplerKind::Parallel);
        let stateless = NeighborSampler::new(vec![4, 6, 8], caps(), false, SamplerKind::Parallel);
        let mut scratch = SampleScratch::new();
        let mut total = SamplerStats::default();
        for it in 0..5u64 {
            let a = stateful.sample(part, &seeds, &mut Pcg64::seeded(100 + it));
            let (b, delta) =
                stateless.sample_with(part, &seeds, &mut Pcg64::seeded(100 + it), &mut scratch);
            assert_eq!(a.layers, b.layers, "iteration {it}");
            assert_eq!(a.edges, b.edges, "iteration {it}");
            total.merge(&delta);
        }
        assert_eq!(total.minibatches, stateful.stats.minibatches);
        assert_eq!(total.sampled_nodes, stateful.stats.sampled_nodes);
        assert_eq!(total.sampled_edges, stateful.stats.sampled_edges);
    }

    #[test]
    fn seed_batches_cover_and_truncate() {
        let mut rng = Pcg64::seeded(7);
        let train: Vec<u32> = (0..100).collect();
        let batches = make_seed_batches(&train, 32, &mut rng, None);
        // 100 = 32+32+32+4; trailing 4 < 16 dropped
        assert_eq!(batches.len(), 3);
        let total: usize = batches.iter().map(|b| b.len()).sum();
        assert_eq!(total, 96);
        let capped = make_seed_batches(&train, 32, &mut rng, Some(2));
        assert_eq!(capped.len(), 2);
    }

    #[test]
    fn seed_batch_count_matches_make_seed_batches() {
        let mut rng = Pcg64::seeded(11);
        for n_train in [0usize, 1, 15, 16, 31, 32, 33, 47, 48, 96, 100, 129] {
            for cap in [None, Some(1), Some(2), Some(100)] {
                let train: Vec<u32> = (0..n_train as u32).collect();
                let made = make_seed_batches(&train, 32, &mut rng, cap).len();
                let counted = seed_batch_count(n_train, 32, cap);
                assert_eq!(made, counted, "n_train={n_train} cap={cap:?}");
            }
        }
    }
}
